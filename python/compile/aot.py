"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the Rust runtime.

HLO text (NOT `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Python runs ONLY here; the Rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Lower every artifact; returns {name: hlo_text}."""
    fns = {
        "train_step": model.train_step_tuple,
        "predict": model.predict,
        "kernel_fwd": model.kernel_fwd,
    }
    args = model.example_args()
    out = {}
    for name, fn in fns.items():
        lowered = jax.jit(fn).lower(*args[name])
        out[name] = to_hlo_text(lowered)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file target (writes train_step)")
    ns = ap.parse_args()

    os.makedirs(ns.out_dir, exist_ok=True)
    texts = lower_all()
    manifest_lines = []
    for name, text in texts.items():
        path = os.path.join(ns.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest_lines.append(f"{name}\t{len(text)}\t{digest}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(ns.out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    if ns.out:
        with open(ns.out, "w") as f:
            f.write(texts["train_step"])
        print(f"wrote {ns.out}")


if __name__ == "__main__":
    main()
