"""Layer-2 JAX model: a small ReLU CNN whose conv hot-spots are the Pallas
block-sparse kernel (`kernels.sparse_conv.conv2d`).

Geometry must match `rust/src/runtime/artifacts.rs::geometry`:
  input  [N=16, C=16, 16, 16] float32, labels [16] int32, 8 classes
  conv1: 16→32 3×3 pad 1 (Pallas fwd) + ReLU
  conv2: 32→32 3×3 pad 1 (Pallas fwd) + ReLU
  global average pool → FC → softmax cross-entropy
The train step does one SGD update and also returns the measured ReLU
output sparsities — the dynamic-sparsity signal the Rust coordinator logs
(Fig-3-style trace from a real run).
"""

import jax
import jax.numpy as jnp

from .kernels.sparse_conv import conv2d

# Geometry (keep in sync with rust/src/runtime/artifacts.rs).
N = 16
C_IN = 16
HW = 16
C1 = 32
C2 = 32
CLASSES = 8
LR = 0.2


def init_params(key):
    """He-uniform init, matching the Rust trainer's host-side init."""
    k1, k2, k3 = jax.random.split(key, 3)
    b1 = (2.0 / (C_IN * 9)) ** 0.5
    b2 = (2.0 / (C1 * 9)) ** 0.5
    b3 = (1.0 / C2) ** 0.5
    return {
        "w1": jax.random.uniform(k1, (C1, C_IN, 3, 3), jnp.float32, -b1, b1),
        "w2": jax.random.uniform(k2, (C2, C1, 3, 3), jnp.float32, -b2, b2),
        "wfc": jax.random.uniform(k3, (CLASSES, C2), jnp.float32, -b3, b3),
        "bfc": jnp.zeros((CLASSES,), jnp.float32),
    }


def forward(w1, w2, wfc, bfc, x):
    """Returns (logits, relu1_sparsity, relu2_sparsity)."""
    a1 = jnp.maximum(conv2d(x, w1, 1), 0.0)
    s1 = jnp.mean((a1 == 0.0).astype(jnp.float32))
    a2 = jnp.maximum(conv2d(a1, w2, 1), 0.0)
    s2 = jnp.mean((a2 == 0.0).astype(jnp.float32))
    pooled = jnp.mean(a2, axis=(2, 3))  # [N, C2]
    logits = pooled @ wfc.T + bfc
    return logits, s1, s2


def loss_fn(w1, w2, wfc, bfc, x, labels):
    logits, s1, s2 = forward(w1, w2, wfc, bfc, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return nll, (s1, s2)


def train_step(w1, w2, wfc, bfc, x, labels):
    """One SGD step. Returns (w1', w2', wfc', bfc', loss, s1, s2) — the
    7-output contract the Rust trainer expects."""
    (loss, (s1, s2)), grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3), has_aux=True)(
        w1, w2, wfc, bfc, x, labels
    )
    g1, g2, gfc, gb = grads
    return (
        w1 - LR * g1,
        w2 - LR * g2,
        wfc - LR * gfc,
        bfc - LR * gb,
        loss,
        s1,
        s2,
    )


def predict(w1, w2, wfc, bfc, x):
    """Returns (logits,)."""
    logits, _, _ = forward(w1, w2, wfc, bfc, x)
    return (logits,)


def kernel_fwd(x, w):
    """Single Pallas conv layer — the L1 kernel exposed as its own artifact
    for Rust-side kernel validation."""
    return (conv2d(x, w, 1),)


def example_args():
    """Example (shape-only) arguments for AOT lowering."""
    f32 = jnp.float32
    return {
        "train_step": (
            jax.ShapeDtypeStruct((C1, C_IN, 3, 3), f32),
            jax.ShapeDtypeStruct((C2, C1, 3, 3), f32),
            jax.ShapeDtypeStruct((CLASSES, C2), f32),
            jax.ShapeDtypeStruct((CLASSES,), f32),
            jax.ShapeDtypeStruct((N, C_IN, HW, HW), f32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ),
        "predict": (
            jax.ShapeDtypeStruct((C1, C_IN, 3, 3), f32),
            jax.ShapeDtypeStruct((C2, C1, 3, 3), f32),
            jax.ShapeDtypeStruct((CLASSES, C2), f32),
            jax.ShapeDtypeStruct((CLASSES,), f32),
            jax.ShapeDtypeStruct((N, C_IN, HW, HW), f32),
        ),
        "kernel_fwd": (
            jax.ShapeDtypeStruct((N, C_IN, HW, HW), f32),
            jax.ShapeDtypeStruct((C1, C_IN, 3, 3), f32),
        ),
    }


def train_step_tuple(*args):
    """Tuple-returning wrapper (AOT lowers with return_tuple=True)."""
    return tuple(train_step(*args))
