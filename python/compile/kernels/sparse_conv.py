"""Layer-1 Pallas kernel: block-sparse direct convolution.

TPU adaptation of SparseTrain's insight (DESIGN.md §3 "Hardware
adaptation"): AVX-512 checks one broadcast element and skips T = R·Q/V
register-resident FMAs; a TPU has no scalar branch inside the systolic
pipeline, so the check unit is lifted to an *input-channel block* staged in
VMEM and the skip unit is the whole MXU contraction of that block against
its filter slice, guarded by `pl.when`.

The grid walks input-channel blocks; each step:
  1. stages `x` block [N, BC, H+2p, W+2p] in VMEM (BlockSpec),
  2. one vector compare + reduce (`jnp.any(block != 0)`) — the analogue of
     vcmpps+popcnt,
  3. `pl.when(nonzero)`: R·S shifted einsum contractions over the block —
     the analogue of the T skippable FMAs,
  4. accumulates into the output block (resident across grid steps).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls; real-TPU numbers are estimated in DESIGN.md §Perf from the
VMEM footprint and MXU occupancy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Input-channel block size (the zero-check granularity). 16 matches the
# Rust layer's V and keeps the VMEM block well under budget for the model's
# shapes; `vmem_footprint_bytes` documents the budget arithmetic.
DEFAULT_BLOCK_C = 16


def _kernel(x_ref, w_ref, o_ref, *, s, r, pad, out_h, out_w):
    """One grid step: contract one input-channel block, skip if all-zero."""
    cb = pl.program_id(0)

    @pl.when(cb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    block = x_ref[...]  # [N, BC, H+2p, W+2p] in VMEM

    # Vectorized zero check on the whole staged block (vcmpps analogue).
    @pl.when(jnp.any(block != 0.0))
    def _contract():
        acc = o_ref[...]
        for si in range(s):
            for ri in range(r):
                patch = block[:, :, si : si + out_h, ri : ri + out_w]
                tap = w_ref[:, :, si, ri]  # [K, BC]
                # MXU contraction over the channel block.
                acc = acc + jnp.einsum(
                    "nchw,kc->nkhw", patch, tap, preferred_element_type=jnp.float32
                )
        o_ref[...] = acc


def conv_fwd_pallas(x, w, *, block_c=DEFAULT_BLOCK_C, padding=1):
    """Block-sparse Pallas forward conv (unit stride), NCHW/OIHW.

    x: [N, C, H, W] float32 (ReLU output: zeros mark skippable blocks)
    w: [K, C, S, R] float32
    returns [N, K, H', W'] with H' = H + 2·padding − S + 1.
    """
    n, c, h, wd = x.shape
    k, cw, s, r = w.shape
    assert c == cw, f"channel mismatch {c} != {cw}"
    assert c % block_c == 0, f"C={c} not a multiple of block_c={block_c}"
    out_h = h + 2 * padding - s + 1
    out_w = wd + 2 * padding - r + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))

    kern = functools.partial(_kernel, s=s, r=r, pad=padding, out_h=out_h, out_w=out_w)
    return pl.pallas_call(
        kern,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec(
                (n, block_c, h + 2 * padding, wd + 2 * padding), lambda cb: (0, cb, 0, 0)
            ),
            pl.BlockSpec((k, block_c, s, r), lambda cb: (0, cb, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n, k, out_h, out_w), lambda cb: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k, out_h, out_w), jnp.float32),
        interpret=True,
    )(xp, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def conv2d(x, w, padding=1):
    """Differentiable conv: Pallas block-sparse kernel forward, analytic
    (lax.conv) backward — the L2 model builds on this."""
    return conv_fwd_pallas(x, w, padding=padding)


def _conv2d_fwd(x, w, padding):
    return conv2d(x, w, padding), (x, w)


def _conv2d_bwd(padding, res, dy):
    x, w = res
    dx = ref.conv_bwi_ref(dy, w, x.shape, stride=1, padding=padding)
    dw = ref.conv_bww_ref(x, dy, w.shape, stride=1, padding=padding)
    return (dx, dw)


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


def vmem_footprint_bytes(n, c, h, w, k, s, r, block_c=DEFAULT_BLOCK_C, padding=1):
    """VMEM bytes staged per grid step (the TPU 'register budget' check):
    input block + filter slice + output block, f32."""
    hp, wp = h + 2 * padding, w + 2 * padding
    out_h, out_w = h + 2 * padding - s + 1, w + 2 * padding - r + 1
    x_block = n * block_c * hp * wp * 4
    w_block = k * block_c * s * r * 4
    o_block = n * k * out_h * out_w * 4
    return x_block + w_block + o_block


def block_skip_fraction(x, block_c=DEFAULT_BLOCK_C):
    """Fraction of channel blocks that are entirely zero — the MXU work the
    kernel actually skips (TPU-granularity analogue of Table 4's skipped-FMA
    fraction)."""
    n, c, h, w = x.shape
    blocks = x.reshape(n, c // block_c, block_c, h, w)
    zero = jnp.all(blocks == 0.0, axis=(0, 2, 3, 4))
    return float(jnp.mean(zero.astype(jnp.float32)))
