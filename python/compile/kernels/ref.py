"""Pure-jnp correctness oracles for the Pallas kernels.

`conv_fwd_ref` is the production reference (lax.conv); `conv_fwd_loops` is a
deliberately naive loop-nest oracle used to validate the reference itself on
tiny shapes.
"""

import jax.numpy as jnp
from jax import lax


def conv_fwd_ref(x, w, stride=1, padding=1):
    """NCHW correlation: x [N,C,H,W], w [K,C,S,R] -> [N,K,H',W']."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv_bwi_ref(dy, w, x_shape, stride=1, padding=1):
    """Gradient w.r.t. the input of `conv_fwd_ref`."""
    n, c, h, w_dim = x_shape
    k, _, s, r = w.shape
    # transposed convolution: dilate dy by stride, correlate with mirrored,
    # channel-transposed filters
    wt = jnp.transpose(w[:, :, ::-1, ::-1], (1, 0, 2, 3))  # [C,K,S,R]
    return lax.conv_general_dilated(
        dy,
        wt,
        window_strides=(1, 1),
        padding=((s - 1 - padding, s - 1 - padding), (r - 1 - padding, r - 1 - padding)),
        lhs_dilation=(stride, stride),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[:, :, :h, :w_dim]


def conv_bww_ref(x, dy, w_shape, stride=1, padding=1):
    """Gradient w.r.t. the weights of `conv_fwd_ref`."""
    k, c, s, r = w_shape
    # dG[k,c,s,r] = sum_{i,y',x'} X[i,c,y'*P+s-p, x'*O+r-p] * dY[i,k,y',x']
    out = lax.conv_general_dilated(
        jnp.transpose(x, (1, 0, 2, 3)),  # C as batch
        jnp.transpose(dy, (1, 0, 2, 3)),  # K as out-channels, N contracted
        window_strides=(1, 1),
        padding=((padding, padding), (padding, padding)),
        rhs_dilation=(stride, stride),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [C, K, S, R]
    return jnp.transpose(out, (1, 0, 2, 3))[:, :, :s, :r]


def conv_fwd_loops(x, w, stride=1, padding=1):
    """Naive loop-nest oracle (tiny shapes only)."""
    import numpy as np

    x = np.asarray(x)
    w = np.asarray(w)
    n, c, h, wd = x.shape
    k, _, s, r = w.shape
    oh = (h + 2 * padding - s) // stride + 1
    ow = (wd + 2 * padding - r) // stride + 1
    y = np.zeros((n, k, oh, ow), dtype=np.float32)
    for i in range(n):
        for ko in range(k):
            for oy in range(oh):
                for ox in range(ow):
                    acc = 0.0
                    for ci in range(c):
                        for si in range(s):
                            iy = oy * stride + si - padding
                            if iy < 0 or iy >= h:
                                continue
                            for ri in range(r):
                                ix = ox * stride + ri - padding
                                if ix < 0 or ix >= wd:
                                    continue
                                acc += x[i, ci, iy, ix] * w[ko, ci, si, ri]
                    y[i, ko, oy, ox] = acc
    return jnp.asarray(y)
