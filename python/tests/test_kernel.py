"""L1 kernel correctness: Pallas block-sparse conv vs the jnp reference.

The hypothesis sweep is the CORE correctness signal — shapes, sparsity
levels and block sizes are all generated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sparse_conv import (
    block_skip_fraction,
    conv2d,
    conv_fwd_pallas,
    vmem_footprint_bytes,
)


def relu_sparse(rng, shape, sparsity):
    x = rng.uniform(0.05, 1.0, size=shape).astype(np.float32)
    mask = rng.uniform(size=shape) < sparsity
    x[mask] = 0.0
    return jnp.asarray(x)


def test_reference_matches_loop_oracle():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 3, 5, 5)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((4, 3, 3, 3)).astype(np.float32))
    got = ref.conv_fwd_ref(x, w)
    want = ref.conv_fwd_loops(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    cb=st.integers(1, 3),  # channel blocks of 8
    kk=st.sampled_from([8, 16, 24]),
    h=st.integers(4, 10),
    w=st.integers(4, 10),
    sparsity=st.sampled_from([0.0, 0.5, 0.9, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_reference_hypothesis(n, cb, kk, h, w, sparsity, seed):
    c = cb * 8
    rng = np.random.default_rng(seed)
    x = relu_sparse(rng, (n, c, h, w), sparsity)
    wt = jnp.asarray(rng.standard_normal((kk, c, 3, 3)).astype(np.float32) * 0.2)
    got = conv_fwd_pallas(x, wt, block_c=8)
    want = ref.conv_fwd_ref(x, wt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("block_c", [8, 16, 32])
def test_block_sizes_equivalent(block_c):
    rng = np.random.default_rng(3)
    x = relu_sparse(rng, (2, 32, 8, 8), 0.6)
    w = jnp.asarray(rng.standard_normal((16, 32, 3, 3)).astype(np.float32) * 0.2)
    got = conv_fwd_pallas(x, w, block_c=block_c)
    want = ref.conv_fwd_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_all_zero_input_gives_zero_output_and_full_skip():
    x = jnp.zeros((2, 32, 8, 8), jnp.float32)
    w = jnp.ones((16, 32, 3, 3), jnp.float32)
    y = conv_fwd_pallas(x, w)
    assert float(jnp.abs(y).max()) == 0.0
    assert block_skip_fraction(x) == 1.0


def test_block_skip_fraction_tracks_structured_sparsity():
    rng = np.random.default_rng(5)
    x = np.array(relu_sparse(rng, (2, 64, 8, 8), 0.0), copy=True)
    # zero out half the channel blocks entirely
    x[:, :32] = 0.0
    frac = block_skip_fraction(jnp.asarray(x), block_c=16)
    assert frac == pytest.approx(0.5)


def test_custom_vjp_gradients_match_autodiff_reference():
    rng = np.random.default_rng(7)
    x = relu_sparse(rng, (2, 16, 6, 6), 0.4)
    w = jnp.asarray(rng.standard_normal((8, 16, 3, 3)).astype(np.float32) * 0.3)
    dy = jnp.asarray(rng.standard_normal((2, 8, 6, 6)).astype(np.float32))

    def loss_pallas(x, w):
        return jnp.sum(conv2d(x, w, 1) * dy)

    def loss_ref(x, w):
        return jnp.sum(ref.conv_fwd_ref(x, w) * dy)

    gx, gw = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=2e-4, atol=2e-4)


def test_vmem_footprint_within_budget():
    # the model's largest conv: conv2 32→32 over 16×16 at N=16
    bytes_ = vmem_footprint_bytes(16, 32, 16, 16, 32, 3, 3, block_c=16)
    assert bytes_ < 16 * 1024 * 1024, f"VMEM block too large: {bytes_}"


def test_rejects_untileable_channels():
    x = jnp.zeros((1, 12, 4, 4), jnp.float32)
    w = jnp.zeros((8, 12, 3, 3), jnp.float32)
    with pytest.raises(AssertionError):
        conv_fwd_pallas(x, w, block_c=16)
