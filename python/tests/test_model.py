"""L2 model tests: shapes, gradient sanity, short-horizon learning, and the
7-output train-step contract the Rust trainer depends on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def make_batch(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((model.N, model.C_IN, model.HW, model.HW)).astype(np.float32)
    labels = rng.integers(0, model.CLASSES, size=(model.N,)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(labels)


def test_forward_shapes_and_sparsity_range():
    p = model.init_params(jax.random.PRNGKey(0))
    x, _ = make_batch()
    logits, s1, s2 = model.forward(p["w1"], p["w2"], p["wfc"], p["bfc"], x)
    assert logits.shape == (model.N, model.CLASSES)
    assert 0.0 <= float(s1) <= 1.0
    assert 0.0 <= float(s2) <= 1.0
    # ReLU over roughly zero-centered preactivations → sparsity near 0.5
    assert 0.15 <= float(s1) <= 0.85


def test_train_step_contract_seven_outputs():
    p = model.init_params(jax.random.PRNGKey(1))
    x, labels = make_batch(1)
    outs = model.train_step(p["w1"], p["w2"], p["wfc"], p["bfc"], x, labels)
    assert len(outs) == 7
    w1n, w2n, wfcn, bfcn, loss, s1, s2 = outs
    assert w1n.shape == p["w1"].shape
    assert w2n.shape == p["w2"].shape
    assert wfcn.shape == p["wfc"].shape
    assert bfcn.shape == p["bfc"].shape
    assert loss.shape == ()
    assert float(loss) > 0.0
    # parameters must actually move
    assert float(jnp.abs(w1n - p["w1"]).max()) > 0.0


def test_loss_decreases_over_a_few_steps():
    p = model.init_params(jax.random.PRNGKey(2))
    params = (p["w1"], p["w2"], p["wfc"], p["bfc"])
    x, labels = make_batch(2)
    step = jax.jit(model.train_step)
    losses = []
    for _ in range(60):
        *params, loss, _, _ = step(*params, x, labels)
        params = tuple(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, f"no learning: {losses[0]} -> {losses[-1]}"


def test_gradients_match_finite_difference():
    p = model.init_params(jax.random.PRNGKey(3))
    x, labels = make_batch(3)

    def scalar_loss(wfc):
        loss, _ = model.loss_fn(p["w1"], p["w2"], wfc, p["bfc"], x, labels)
        return loss

    g = jax.grad(scalar_loss)(p["wfc"])
    eps = 1e-3
    rng = np.random.default_rng(0)
    for _ in range(4):
        i = rng.integers(0, model.CLASSES)
        j = rng.integers(0, model.C2)
        e = jnp.zeros_like(p["wfc"]).at[i, j].set(eps)
        fd = (scalar_loss(p["wfc"] + e) - scalar_loss(p["wfc"] - e)) / (2 * eps)
        assert abs(float(fd) - float(g[i, j])) < 5e-3


def test_predict_matches_forward():
    p = model.init_params(jax.random.PRNGKey(4))
    x, _ = make_batch(4)
    (logits,) = model.predict(p["w1"], p["w2"], p["wfc"], p["bfc"], x)
    ref_logits, _, _ = model.forward(p["w1"], p["w2"], p["wfc"], p["bfc"], x)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits))
