"""AOT pipeline tests: every artifact lowers to parseable HLO text with the
expected parameter/result structure (text format — see aot.py docstring)."""

import os

from compile import aot, model


def test_lower_all_produces_hlo_text():
    texts = aot.lower_all()
    assert set(texts) == {"train_step", "predict", "kernel_fwd"}
    for name, text in texts.items():
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text, f"{name} missing entry computation"


def test_train_step_artifact_has_six_params():
    texts = aot.lower_all()
    entry = [l for l in texts["train_step"].splitlines() if l.startswith("ENTRY")]
    assert entry, "no ENTRY line"
    # 6 parameters: w1, w2, wfc, bfc, x, labels
    assert entry[0].count("parameter") >= 0  # structural sanity
    assert texts["train_step"].count("parameter(") >= 6 or texts["train_step"].count(
        "parameter"
    ) >= 6


def test_artifact_shapes_match_geometry():
    texts = aot.lower_all()
    t = texts["train_step"]
    # the input batch appears with its lowered shape
    assert f"f32[{model.N},{model.C_IN},{model.HW},{model.HW}]" in t
    assert f"s32[{model.N}]" in t


def test_main_writes_files(tmp_path):
    import sys
    from unittest import mock

    out = tmp_path / "artifacts"
    argv = ["aot", "--out-dir", str(out)]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    for name in ["train_step", "predict", "kernel_fwd"]:
        p = out / f"{name}.hlo.txt"
        assert p.is_file(), f"missing {p}"
        assert p.stat().st_size > 1000
    assert (out / "manifest.tsv").is_file()
    assert len((out / "manifest.tsv").read_text().strip().splitlines()) == 3
    assert os.path.getsize(out / "train_step.hlo.txt") > 0
