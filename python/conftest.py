import os
import sys

# Make the build-time `compile` package importable when pytest runs from
# the repo root (`pytest python/tests/`).
sys.path.insert(0, os.path.dirname(__file__))
