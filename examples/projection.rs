//! End-to-end projection (Figure 4 / Table 6) with per-network detail:
//! which algorithm the `combined` policy picks per layer and component.
//!
//! ```bash
//! cargo run --release --example projection
//! cargo run --release --example projection -- --network ResNet-50 --detail
//! ```

use sparsetrain::bench::experiments::{fig4_table6, layer_sparsities};
use sparsetrain::coordinator::selector::{AlgoPolicy, Selector};
use sparsetrain::kernels::Component;
use sparsetrain::nets::zoo::{NetSpec, Network};
use sparsetrain::sim::Machine;
use sparsetrain::util::cli::Args;
use sparsetrain::util::table::Table;

fn main() {
    let args = Args::from_env(&["network", "epochs"], &["detail"]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let epochs = args.get_usize("epochs", 100).unwrap();
    let m = Machine::skylake_x();

    let (_proj, fig, tab) = fig4_table6(&m, epochs);
    fig.print();
    tab.print();

    if args.flag("detail") {
        let name = args.get_or("network", "VGG16");
        let net = Network::ALL
            .into_iter()
            .find(|n| n.name().eq_ignore_ascii_case(name))
            .unwrap_or_else(|| {
                eprintln!("unknown network '{name}'");
                std::process::exit(2);
            });
        let spec = NetSpec::build(net);
        let sparsities = layer_sparsities(&spec, epochs);
        let sel = Selector::new(m);
        let mut t = Table::new(&format!("combined policy per layer — {}", net.name()))
            .header(&["layer", "shape", "s(in)", "s(grad)", "FWD", "BWI", "BWW"]);
        for (l, sp) in spec.layers.iter().zip(&sparsities) {
            let pick = |comp: Component| {
                let (s, ok) = match comp {
                    Component::Fwd => (sp.input, !l.is_first && sp.input > 0.0),
                    Component::Bwi => (sp.grad.unwrap_or(0.0), sp.grad.is_some()),
                    Component::Bww => {
                        let b = sp.grad.map_or(sp.input, |g| g.max(sp.input));
                        (b, !l.is_first && b > 0.0)
                    }
                };
                sel.select(AlgoPolicy::Combined, &l.cfg, comp, s, ok).name().to_string()
            };
            t.row_strings(vec![
                l.name.clone(),
                format!("{}x{} {}x{}/{}", l.cfg.c, l.cfg.k, l.cfg.r, l.cfg.s, l.cfg.stride_o),
                format!("{:.2}", sp.input),
                sp.grad.map(|g| format!("{g:.2}")).unwrap_or_else(|| "BN".into()),
                pick(Component::Fwd),
                pick(Component::Bwi),
                pick(Component::Bww),
            ]);
        }
        t.print();
    }
}
