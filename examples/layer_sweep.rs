//! Sweep a Table-2 layer across sparsity levels and algorithms — the
//! single-layer view behind Figures 1/2.
//!
//! ```bash
//! cargo run --release --example layer_sweep -- --layer vgg3_2
//! cargo run --release --example layer_sweep -- --layer resnet4_3 --csv
//! ```

use sparsetrain::bench::experiments::{speedup_over_direct, SPARSITY_GRID};
use sparsetrain::kernels::{onebyone, winograd, Component};
use sparsetrain::nets::table2::layer_by_name;
use sparsetrain::sim::{Algorithm, Machine};
use sparsetrain::util::cli::Args;
use sparsetrain::util::table::Table;

fn main() {
    let args = Args::from_env(&["layer"], &["csv"]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let layer = args.get_or("layer", "vgg3_2");
    let nl = layer_by_name(layer).unwrap_or_else(|| {
        eprintln!("unknown layer '{layer}'; see Table 2 names (e.g. vgg3_2, resnet4_2)");
        std::process::exit(2);
    });
    let m = Machine::skylake_x();
    println!(
        "layer {layer}: C={} K={} H=W={} R=S={} stride={}  (batch {})",
        nl.cfg.c, nl.cfg.k, nl.cfg.h, nl.cfg.r, nl.cfg.stride_o, nl.cfg.n
    );

    let mut tab = Table::new(&format!("modeled speedup over direct — {layer}")).header(&[
        "comp", "0%", "10%", "20%", "30%", "40%", "50%", "60%", "70%", "80%", "90%", "im2col",
        "win/1x1",
    ]);
    for comp in Component::ALL {
        let mut cells = vec![comp.name().to_string()];
        for &s in &SPARSITY_GRID {
            cells.push(format!(
                "{:.2}",
                speedup_over_direct(&m, Algorithm::SparseTrain, &nl.cfg, comp, s)
            ));
        }
        cells.push(format!(
            "{:.2}",
            speedup_over_direct(&m, Algorithm::Im2col, &nl.cfg, comp, 0.0)
        ));
        cells.push(if winograd::applicable(&nl.cfg) {
            format!("{:.2}", speedup_over_direct(&m, Algorithm::Winograd, &nl.cfg, comp, 0.0))
        } else if onebyone::applicable(&nl.cfg) {
            format!("{:.2}", speedup_over_direct(&m, Algorithm::OneByOne, &nl.cfg, comp, 0.0))
        } else {
            "-".into()
        });
        tab.row_strings(cells);
    }
    if args.flag("csv") {
        print!("{}", tab.to_csv());
    } else {
        tab.print();
    }
}
