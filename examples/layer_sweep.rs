//! Sweep a Table-2 layer across sparsity levels and algorithms — the
//! single-layer view behind Figures 1/2 — and exercise the row-sweep
//! scheduler's parallel FWD/BWI/BWW on a scaled-down copy of the layer.
//!
//! ```bash
//! cargo run --release --example layer_sweep -- --layer vgg3_2
//! cargo run --release --example layer_sweep -- --layer resnet4_3 --csv
//! cargo run --release --example layer_sweep -- --layer vgg3_2 --threads 4
//! ```

use sparsetrain::bench::experiments::{machine_with_threads, speedup_over_direct, SPARSITY_GRID};
use sparsetrain::coordinator::Scheduler;
use sparsetrain::kernels::{
    onebyone, sparse_bwi, sparse_bww, sparse_fwd, winograd, Component, ConvConfig, KernelStats,
    SkipMode,
};
use sparsetrain::nets::table2::layer_by_name;
use sparsetrain::sim::{Algorithm, Machine};
use sparsetrain::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::cli::Args;
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::table::Table;

/// Time one component serial-vs-scheduled and append a table row; the
/// closures run the serial kernel and the scheduler launch respectively.
/// Returns the scheduler's report so callers can assert on the outputs.
fn timed_row(
    tab: &mut Table,
    comp: &str,
    serial: impl FnOnce(),
    scheduled: impl FnOnce() -> sparsetrain::coordinator::scheduler::RunReport,
) -> sparsetrain::coordinator::scheduler::RunReport {
    let t0 = std::time::Instant::now();
    serial();
    let serial_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = std::time::Instant::now();
    let report = scheduled();
    let par_ms = t1.elapsed().as_secs_f64() * 1e3;
    tab.row_strings(vec![
        comp.to_string(),
        report.total_tasks.to_string(),
        format!("{serial_ms:.2}"),
        format!("{par_ms:.2}"),
        format!("{:.2}", serial_ms / par_ms.max(1e-9)),
        format!("{:.0}", 100.0 * report.stats.skip_fraction()),
    ]);
    report
}

/// Run the parallel training triad on a scaled-down copy of the layer:
/// serial kernels vs the scheduler at `threads` workers, wallclock + task
/// counts. Scaling keeps the functional kernels fast while preserving the
/// layer's filter geometry and stride.
fn parallel_host_demo(layer_cfg: &ConvConfig, threads: usize, sparsity: f64) {
    let cfg = ConvConfig::square(
        16, // batch multiple of V so BWW applies
        layer_cfg.c.min(64),
        layer_cfg.k.min(64),
        layer_cfg.h.min(16).max(layer_cfg.r),
        layer_cfg.r,
        layer_cfg.stride_o,
    );
    let mut rng = Xorshift::new(11);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, sparsity);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);
    let gt = g.transpose_channels();
    let dt = BatchTiledTensor::from_act(&d);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_relu_sparse(&mut rng, sparsity);

    let sched = Scheduler::new(threads);
    let mut tab = Table::new(&format!(
        "parallel path, scaled {}x{} {}x{}/{} at s={sparsity:.1}, {threads} threads",
        cfg.c, cfg.k, cfg.r, cfg.s, cfg.stride_o
    ))
    .header(&["comp", "tasks", "serial ms", "parallel ms", "speedup", "skip%"]);

    let mut y_s = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut y_p = y_s.clone();
    timed_row(
        &mut tab,
        "FWD",
        || {
            let mut st = KernelStats::new();
            sparse_fwd::fwd(&cfg, &d, &g, &mut y_s, SkipMode::MaskLoop, &mut st);
        },
        || sched.run_fwd(&cfg, &d, &g, &mut y_p, SkipMode::MaskLoop),
    );
    assert_eq!(y_p.data(), y_s.data(), "parallel FWD must be bit-exact");

    let mut dd_s = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let mut dd_p = dd_s.clone();
    timed_row(
        &mut tab,
        "BWI",
        || {
            let mut st = KernelStats::new();
            sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd_s, SkipMode::MaskLoop, &mut st);
        },
        || sched.run_bwi(&cfg, &dy, &gt, &mut dd_p, SkipMode::MaskLoop),
    );
    assert_eq!(dd_p.data(), dd_s.data(), "parallel BWI must be bit-exact");

    let mut dg_s = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    let mut dg_p = dg_s.clone();
    timed_row(
        &mut tab,
        "BWW",
        || {
            let mut st = KernelStats::new();
            sparse_bww::bww(&cfg, &dt, &dy, &mut dg_s, SkipMode::MaskLoop, &mut st);
        },
        || sched.run_bww(&cfg, &dt, &dy, &mut dg_p, SkipMode::MaskLoop),
    );
    assert_eq!(dg_p.data(), dg_s.data(), "parallel BWW must be bit-exact");

    tab.print();
    println!("parallel outputs verified bit-exact against the serial kernels ✓");
}

fn main() {
    let args = Args::from_env(&["layer", "threads"], &["csv"]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let layer = args.get_or("layer", "vgg3_2");
    let nl = layer_by_name(layer).unwrap_or_else(|| {
        eprintln!("unknown layer '{layer}'; see Table 2 names (e.g. vgg3_2, resnet4_2)");
        std::process::exit(2);
    });
    let base = Machine::skylake_x();
    let threads = args.get_usize("threads", base.cores).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let m = machine_with_threads(&base, threads);
    println!(
        "layer {layer}: C={} K={} H=W={} R=S={} stride={}  (batch {}, {} modeled cores)",
        nl.cfg.c, nl.cfg.k, nl.cfg.h, nl.cfg.r, nl.cfg.stride_o, nl.cfg.n, m.cores
    );

    let mut tab = Table::new(&format!("modeled speedup over direct — {layer}")).header(&[
        "comp", "0%", "10%", "20%", "30%", "40%", "50%", "60%", "70%", "80%", "90%", "im2col",
        "win/1x1",
    ]);
    for comp in Component::ALL {
        let mut cells = vec![comp.name().to_string()];
        for &s in &SPARSITY_GRID {
            cells.push(format!(
                "{:.2}",
                speedup_over_direct(&m, Algorithm::SparseTrain, &nl.cfg, comp, s)
            ));
        }
        cells.push(format!(
            "{:.2}",
            speedup_over_direct(&m, Algorithm::Im2col, &nl.cfg, comp, 0.0)
        ));
        cells.push(if winograd::applicable(&nl.cfg) {
            format!("{:.2}", speedup_over_direct(&m, Algorithm::Winograd, &nl.cfg, comp, 0.0))
        } else if onebyone::applicable(&nl.cfg) {
            format!("{:.2}", speedup_over_direct(&m, Algorithm::OneByOne, &nl.cfg, comp, 0.0))
        } else {
            "-".into()
        });
        tab.row_strings(cells);
    }
    if args.flag("csv") {
        print!("{}", tab.to_csv());
    } else {
        tab.print();
    }

    parallel_host_demo(&nl.cfg, threads, 0.6);
}
