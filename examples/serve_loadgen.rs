//! Serving-latency demo: the batched sparse-inference front end under
//! synthetic open-loop load (ISSUE 9).
//!
//! Spawns the [`coordinator::serve`] server over the routed predict
//! ladder, replays a seeded Poisson arrival schedule against it, and
//! prints p50/p95/p99 latency, throughput and the batch-size histogram
//! per scenario — the same rig as `sparsetrain serve`, kept as an example
//! so `cargo run --example` users can poke rates and batching knobs
//! without the CLI's smoke-gating.
//!
//! ```bash
//! cargo run --release --example serve_loadgen
//! cargo run --release --example serve_loadgen -- --rate 2000 --requests 1000 --max-batch 16
//! cargo run --release --example serve_loadgen -- --scenario wide64 --deadline-us 500
//! ```

use sparsetrain::bench::loadgen::{
    self, run_serve_bench, scenario_by_name, wallclock_report, ArrivalKind, ServeBenchConfig,
};
use sparsetrain::coordinator::serve::ServeConfig;
use sparsetrain::util::cli::Args;

const USAGE: &str = "\
serve_loadgen — open-loop load against the batching predict server

USAGE: cargo run --release --example serve_loadgen -- [options]

  --rate RPS         mean arrival rate (default 400)
  --requests N       requests per scenario (default 400)
  --max-batch N      batch-size cap / top ladder rung (default 8)
  --deadline-us N    max queueing delay before an under-full batch closes
                     (default 2000)
  --depth N          bounded-queue shed limit (default 64)
  --threads N        op-router worker threads (default 2)
  --seed N           arrival/input/weight seed (default 42)
  --scenario NAME    paper | hires32 | wide64 | all (default all)
  --out FILE         also write wallclock-v5 serve rows here (optional)";

fn main() {
    let args = Args::from_env(
        &[
            "rate",
            "requests",
            "max-batch",
            "deadline-us",
            "depth",
            "threads",
            "seed",
            "scenario",
            "out",
        ],
        &[],
    )
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    });
    let die = |e: String| -> ! {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    };
    let rate = args.get_f64("rate", 400.0).unwrap_or_else(|e| die(e));
    let requests = args.get_usize("requests", 400).unwrap_or_else(|e| die(e));
    let max_batch = args.get_usize("max-batch", 8).unwrap_or_else(|e| die(e));
    let deadline_us = args.get_usize("deadline-us", 2000).unwrap_or_else(|e| die(e));
    let depth = args.get_usize("depth", 64).unwrap_or_else(|e| die(e));
    let threads = args.get_usize("threads", 2).unwrap_or_else(|e| die(e));
    let seed = args.get_usize("seed", 42).unwrap_or_else(|e| die(e)) as u64;
    if !(rate > 0.0 && rate.is_finite()) || requests == 0 || max_batch == 0 || depth == 0 {
        die("--rate must be positive; --requests/--max-batch/--depth at least 1".to_string());
    }
    let scenario = args.get_or("scenario", "all");
    let scs = if scenario == "all" {
        loadgen::scenarios()
    } else {
        match scenario_by_name(scenario) {
            Some(sc) => vec![sc],
            None => die(format!("unknown --scenario '{scenario}'")),
        }
    };

    let cfg = ServeBenchConfig {
        rate_rps: rate,
        requests,
        seed,
        serve: ServeConfig {
            max_batch,
            max_delay_ns: deadline_us as u64 * 1_000,
            queue_depth: depth,
        },
        threads,
        arrivals: ArrivalKind::Poisson,
    };
    println!(
        "== serve loadgen: {} scenario(s), {requests} req @ {rate} rps, \
         max-batch {max_batch}, deadline {deadline_us} µs, depth {depth} ==",
        scs.len()
    );
    let reports = run_serve_bench(&scs, &cfg).unwrap_or_else(|e| {
        eprintln!("serve bench failed: {e:#}");
        std::process::exit(1);
    });
    if let Some(out) = args.get("out") {
        let report = wallclock_report(&reports);
        if let Err(e) = report.write_json(std::path::Path::new(out)) {
            eprintln!("writing {out} failed: {e}");
            std::process::exit(1);
        }
        println!("wrote {} serve rows ({}) to {out}", reports.len(), loadgen::schema());
    }
}
