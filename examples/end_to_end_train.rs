//! End-to-end driver: proves all three layers compose.
//!
//! The Rust coordinator loads the AOT-compiled JAX/Pallas train-step
//! artifact (whose conv hot-spots are the block-sparse Pallas kernel),
//! trains the small CNN for a few hundred steps on synthetic labeled data,
//! logs the loss curve and the **measured** per-layer ReLU sparsities, then
//! feeds the measured sparsities back into the Skylake-X model and runs the
//! **parallel training triad** — `Scheduler::run_fwd`/`run_bwi`/`run_bww` —
//! on the trained model's conv2 geometry at those sparsities.
//!
//! Without artifacts (`make artifacts` not run) the Rust-side reference
//! HLO is materialized automatically and executed by the vendored mini-HLO
//! interpreter, so the training phase runs on a cold checkout with no
//! Python at all.
//!
//! ```bash
//! cargo run --release --example end_to_end_train -- --steps 40 --threads 4
//! make artifacts && cargo run --release --example end_to_end_train   # real JAX lowering
//! ```

use sparsetrain::bench::experiments::speedup_over_direct;
use sparsetrain::coordinator::trainer::{Trainer, TrainerConfig};
use sparsetrain::coordinator::Scheduler;
use sparsetrain::kernels::{reference, sparse_fwd, Component, ConvConfig, KernelStats, SkipMode};
use sparsetrain::runtime::artifacts::{geometry, ArtifactSet};
use sparsetrain::sim::{Algorithm, Machine};
use sparsetrain::tensor::{allclose, ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::cli::Args;
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::stats::mean;

/// Train through the PJRT runtime (real JAX artifacts when `make
/// artifacts` has run, the Rust-emitted reference HLO through the mini-HLO
/// interpreter otherwise). Returns the measured (input, gradient) ReLU
/// sparsities of conv2.
fn pjrt_training_phase(steps: usize, seed: u64, threads: usize) -> (f64, f64) {
    let artifacts = ArtifactSet::bootstrap_offline().expect("materializing offline artifacts");

    println!("== end-to-end training: rust coordinator → PJRT → train-step artifact ==");
    let mut trainer =
        Trainer::new(&artifacts, TrainerConfig { steps, seed, log_every: 20, threads, pipeline: None })
            .expect("trainer init");
    let report = trainer.run().unwrap_or_else(|e| {
        eprintln!(
            "training failed: {e:#}\n\
             note: artifacts in `{}` take precedence over the built-in fallback. \
             If they are raw XLA text dumps outside the offline interpreter's \
             reference grammar, delete them (or point SPARSETRAIN_ARTIFACTS at \
             another directory) and re-run.",
            artifacts.dir.display()
        );
        std::process::exit(1);
    });

    let head = mean(&report.losses[..report.losses.len().min(10)]);
    let tail = mean(&report.losses[report.losses.len().saturating_sub(10)..]);
    println!("\nloss: first-10 mean {head:.4} → last-10 mean {tail:.4}");
    println!("throughput: {:.1} steps/s (single CPU PJRT client)", report.steps_per_sec);
    assert!(report.learned(), "loss did not drop ≥20% — training failed");
    println!("learned ✓ (≥20% loss reduction)");

    report.profiler.report().print();
    let s_in = report.profiler.mean("conv1_relu").unwrap_or(0.5);
    let s_dy = report.profiler.mean("conv2_relu").unwrap_or(0.5);
    (s_in, s_dy)
}

/// The full sparse training triad on conv2's geometry, serial and
/// scheduled, verified against the scalar reference.
fn parallel_triad(threads: usize, s_in: f64, s_dy: f64) {
    use geometry::*;
    let cfg = ConvConfig::square(N, C1, C2, HW, 3, 1);
    println!(
        "\n== parallel triad on conv2 ({}x{} {}x{}, batch {}) at s_in={s_in:.2} s_dy={s_dy:.2}, \
         {threads} threads ==",
        cfg.c, cfg.k, cfg.r, cfg.s, cfg.n
    );

    let mut rng = Xorshift::new(42);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, s_in);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.3, 0.3);
    let gt = g.transpose_channels();
    let dt = BatchTiledTensor::from_act(&d);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_relu_sparse(&mut rng, s_dy);

    let sched = Scheduler::new(threads);

    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let rf = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
    let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
    assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5), "FWD reference mismatch");

    let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let ri = sched.run_bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop);
    let ddref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
    assert!(allclose(&dd.to_nchw(), &ddref, 1e-4, 1e-5), "BWI reference mismatch");

    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    let rw = sched.run_bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop);
    let dgref = reference::conv_bww(&cfg, &d.to_nchw(), &dy.to_nchw());
    assert!(allclose(&dg.to_kcsr(), &dgref, 1e-3, 1e-4), "BWW reference mismatch");

    // serial-parity spot check on the stats path
    let mut y2 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut st = KernelStats::new();
    sparse_fwd::fwd(&cfg, &d, &g, &mut y2, SkipMode::MaskLoop, &mut st);
    assert_eq!(rf.stats.fma_vec, st.fma_vec);
    assert_eq!(y.data(), y2.data(), "scheduled FWD must be bit-exact vs serial");

    for (name, r) in [("FWD", &rf), ("BWI", &ri), ("BWW", &rw)] {
        println!(
            "  {name}: {} tasks over {} chunks, skip {:.0}%",
            r.total_tasks,
            r.tasks_per_chunk.iter().filter(|&&t| t > 0).count(),
            100.0 * r.stats.skip_fraction()
        );
    }
    println!("parallel triad verified against the scalar reference ✓");
}

fn main() {
    let args = Args::from_env(&["steps", "seed", "threads"], &[]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let steps = args.get_usize("steps", 200).unwrap();
    let seed = args.get_usize("seed", 7).unwrap() as u64;
    let threads = args.get_usize("threads", 4).unwrap();

    // The same --threads width drives both the kernel-routed training
    // phase and the explicit triad below.
    let (s_in, s_dy) = pjrt_training_phase(steps, seed, threads);

    // Feed the measured sparsities into the Skylake-X model.
    let m = Machine::skylake_x();
    use geometry::*;
    let conv2_cfg = ConvConfig::square(N, C1, C2, HW, 3, 1);
    let fwd = speedup_over_direct(&m, Algorithm::SparseTrain, &conv2_cfg, Component::Fwd, s_in);
    let bwi = speedup_over_direct(&m, Algorithm::SparseTrain, &conv2_cfg, Component::Bwi, s_dy);
    let bww = speedup_over_direct(
        &m,
        Algorithm::SparseTrain,
        &conv2_cfg,
        Component::Bww,
        s_in.max(s_dy),
    );
    println!(
        "\nmodeled SparseTrain speedup on conv2 at sparsity \
         (in={s_in:.2}, grad={s_dy:.2}): FWD {fwd:.2}x  BWI {bwi:.2}x  BWW {bww:.2}x"
    );

    parallel_triad(threads, s_in, s_dy);
    println!("end_to_end_train OK");
}
