//! End-to-end driver: proves all three layers compose.
//!
//! The Rust coordinator loads the AOT-compiled JAX/Pallas train-step
//! artifact (whose conv hot-spots are the block-sparse Pallas kernel),
//! trains the small CNN for a few hundred steps on synthetic labeled data,
//! logs the loss curve and the **measured** per-layer ReLU sparsities, and
//! finally feeds the measured sparsities back into the Skylake-X model to
//! show what SparseTrain would buy at this (real, not synthetic) sparsity.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_train -- --steps 200
//! ```

use sparsetrain::bench::experiments::speedup_over_direct;
use sparsetrain::coordinator::trainer::{Trainer, TrainerConfig};
use sparsetrain::kernels::{Component, ConvConfig};
use sparsetrain::runtime::artifacts::{geometry, ArtifactSet};
use sparsetrain::sim::{Algorithm, Machine};
use sparsetrain::util::cli::Args;
use sparsetrain::util::stats::mean;

fn main() {
    let args = Args::from_env(&["steps", "seed"], &[]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let steps = args.get_usize("steps", 200).unwrap();
    let seed = args.get_usize("seed", 7).unwrap() as u64;

    let artifacts = ArtifactSet::default_location();
    if !artifacts.complete() {
        eprintln!(
            "artifacts missing ({:?}); run `make artifacts` first",
            artifacts.missing()
        );
        std::process::exit(1);
    }

    println!("== end-to-end training: rust coordinator → PJRT → JAX/Pallas artifact ==");
    let mut trainer = Trainer::new(&artifacts, TrainerConfig { steps, seed, log_every: 20 })
        .expect("trainer init");
    let report = trainer.run().expect("training run");

    let head = mean(&report.losses[..report.losses.len().min(10)]);
    let tail = mean(&report.losses[report.losses.len().saturating_sub(10)..]);
    println!("\nloss: first-10 mean {head:.4} → last-10 mean {tail:.4}");
    println!("throughput: {:.1} steps/s (single CPU PJRT client)", report.steps_per_sec);
    assert!(report.learned(), "loss did not drop ≥20% — training failed");
    println!("learned ✓ (≥20% loss reduction)");

    report.profiler.report().print();

    // Feed the *measured* sparsities into the Skylake-X model: what would
    // SparseTrain buy on this model's conv layers at this real sparsity?
    let m = Machine::skylake_x();
    use geometry::*;
    let conv2_cfg = ConvConfig::square(N, C1, C2, HW, 3, 1);
    let s_in = report.profiler.mean("conv1_relu").unwrap_or(0.5);
    let fwd = speedup_over_direct(&m, Algorithm::SparseTrain, &conv2_cfg, Component::Fwd, s_in);
    let s_dy = report.profiler.mean("conv2_relu").unwrap_or(0.5);
    let bwi = speedup_over_direct(&m, Algorithm::SparseTrain, &conv2_cfg, Component::Bwi, s_dy);
    let bww = speedup_over_direct(
        &m,
        Algorithm::SparseTrain,
        &conv2_cfg,
        Component::Bww,
        s_in.max(s_dy),
    );
    println!(
        "\nmodeled SparseTrain speedup on conv2 at measured sparsity \
         (in={s_in:.2}, grad={s_dy:.2}): FWD {fwd:.2}x  BWI {bwi:.2}x  BWW {bww:.2}x"
    );
    println!("end_to_end_train OK");
}
