//! Real-kernel wall-clock benchmark → `BENCH_kernels.json`.
//!
//! Times FWD/BWI/BWW × {dense `direct`, Dense, PerLaneBranch, MaskLoop} ×
//! sparsity {0.0, 0.5, 0.9} on Table-2 layers across thread counts, on the
//! runtime-dispatched SIMD backend, and writes the JSON perf trajectory.
//!
//! ```bash
//! cargo run --release --example wallclock                      # full sweep
//! cargo run --release --example wallclock -- --smoke           # seconds-scale CI smoke
//! cargo run --release --example wallclock -- --layers resnet5_2,vgg5_1
//! cargo run --release --example wallclock -- --threads 1,2,4,8 --out BENCH_kernels.json
//! SPARSETRAIN_BACKEND=scalar cargo run --release --example wallclock -- --smoke
//! ```

use sparsetrain::bench::wallclock::{run, WallclockConfig};
use sparsetrain::coordinator::CostDb;
use sparsetrain::kernels::simd;
use sparsetrain::nets::table2::layer_by_name;
use sparsetrain::util::cli::Args;
use std::sync::Arc;

const USAGE: &str = "\
wallclock — real-kernel wall-clock sweep (writes BENCH_kernels.json)

OPTIONS
  --layers A,B,C     comma-separated Table-2 layer names
  --threads 1,2,4    comma-separated thread counts (default: powers of two up to host)
  --sparsities 0,0.9 comma-separated sparsity levels (default: 0.0,0.5,0.9)
  --out PATH         output JSON path (default: BENCH_kernels.json)
  --cost-db PATH     bulk-populate the measured-cost DB at PATH with every
                     timed kernel cell (existing entries are loaded and
                     EMA-merged; the file is saved atomically on exit)
  --smoke            tiny layer, seconds-scale run (CI emitter check)
  --min-trainer-speedup X
                     fail (exit 1) unless the kernel-routed trainer step at
                     2 threads (analytic selector) is at least X times the
                     naive interpreter (the CI perf floor; 0 = no gate)

Set SPARSETRAIN_BENCH_FAST=1 for shorter measurements and
SPARSETRAIN_BACKEND=scalar|avx2|avx512|neon to force a backend.";

fn parse_list<T: std::str::FromStr>(s: &str, what: &str) -> Vec<T> {
    s.split(',')
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse().unwrap_or_else(|_| {
                eprintln!("error: bad {what} entry '{t}'\n\n{USAGE}");
                std::process::exit(2);
            })
        })
        .collect()
}

fn main() {
    let args = Args::from_env(
        &["layers", "threads", "sparsities", "out", "cost-db", "min-trainer-speedup"],
        &["smoke"],
    )
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    });

    let mut wcfg = if args.flag("smoke") {
        WallclockConfig::smoke()
    } else {
        WallclockConfig::default_sweep()
    };
    if let Some(names) = args.get("layers") {
        wcfg.layers = names
            .split(',')
            .filter(|n| !n.is_empty())
            .map(|n| {
                layer_by_name(n).unwrap_or_else(|| {
                    eprintln!("error: unknown Table-2 layer '{n}'\n\n{USAGE}");
                    std::process::exit(2);
                })
            })
            .collect();
    }
    if let Some(t) = args.get("threads") {
        wcfg.threads = parse_list(t, "--threads");
    }
    if let Some(s) = args.get("sparsities") {
        wcfg.sparsities = parse_list(s, "--sparsities");
    }
    let out = args.get_or("out", "BENCH_kernels.json").to_string();
    if let Some(p) = args.get("cost-db") {
        wcfg.cost_db = Some(Arc::new(CostDb::at_path(std::path::PathBuf::from(p), true)));
    }

    let bk = simd::dispatch();
    println!(
        "backend: {} (V=16); layers: {}; threads: {:?}; sparsities: {:?}",
        bk.name(),
        wcfg.layers.iter().map(|l| l.name).collect::<Vec<_>>().join(", "),
        wcfg.threads,
        wcfg.sparsities
    );

    let report = run(&wcfg);
    report.write_json(std::path::Path::new(&out)).unwrap_or_else(|e| {
        eprintln!("error: cannot write {out}: {e}");
        std::process::exit(1);
    });
    println!("\nwrote {out} ({} records, backend {})", report.records.len(), report.backend);
    if let Some(s) = report.best_maskloop_speedup(0.9, 1) {
        println!("best 1-thread MaskLoop speedup vs dense direct at 90% sparsity: {s:.2}x");
    }
    for &t in &wcfg.threads {
        if let Some(s) = report.trainer_step_speedup(t) {
            println!("kernel-routed trainer step at {t} threads: {s:.2}x vs naive interpreter");
        }
    }
    for (layer, t, ratio) in report.measured_vs_analytic() {
        println!("measured vs analytic selector on {layer} at {t} threads: {ratio:.2}x");
    }
    if let Some(db) = &wcfg.cost_db {
        match db.save() {
            Ok(()) => println!("cost DB saved: {} entries", db.len()),
            Err(e) => eprintln!("warning: cost DB save failed: {e}"),
        }
    }

    // Perf floor gate (CI): the routed trainer step at 2 threads must beat
    // the naive interpreter by at least the requested factor.
    let floor = args.get_f64("min-trainer-speedup", 0.0).unwrap_or_else(|e| {
        eprintln!("error: {e}\n\n{USAGE}");
        std::process::exit(2);
    });
    if floor > 0.0 {
        match report.trainer_step_speedup(2) {
            Some(s) if s < floor => {
                eprintln!(
                    "FAIL: kernel-routed trainer step at 2 threads is {s:.2}x vs naive, \
                     below the {floor:.2}x floor"
                );
                std::process::exit(1);
            }
            Some(s) => {
                println!("trainer-step perf floor passed: {s:.2}x >= {floor:.2}x at 2 threads");
            }
            None => {
                eprintln!(
                    "FAIL: --min-trainer-speedup {floor} given but no trainer_step rows were \
                     recorded (need both naive-interp and kernel-routed at 2 threads; \
                     release build with routing enabled and 2 in --threads)"
                );
                std::process::exit(1);
            }
        }
    }
}
