//! Quickstart: run SparseTrain on one convolution layer and compare it
//! against the dense `direct` baseline — functionally (same numerics) and
//! in performance (host wallclock + modeled Skylake-X cycles).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sparsetrain::bench::{bench, black_box, BenchConfig};
use sparsetrain::kernels::{direct, sparse_fwd, ConvConfig, KernelStats, SkipMode};
use sparsetrain::sim::{estimate_layer_iid, Algorithm, Machine};
use sparsetrain::kernels::Component;
use sparsetrain::tensor::{allclose, ActTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;

fn main() {
    // A ReLU-sparse conv layer: 64→64 channels, 32×32, 3×3, 60 % sparsity.
    let cfg = ConvConfig::square(1, 64, 64, 32, 3, 1);
    let sparsity = 0.6;
    let mut rng = Xorshift::new(1);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, sparsity);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);

    // 1. Functional equivalence: SparseTrain computes the same convolution.
    let mut y_direct = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut y_sparse = y_direct.clone();
    let mut st_d = KernelStats::new();
    let mut st_s = KernelStats::new();
    direct::fwd(&cfg, &d, &g, &mut y_direct, &mut st_d);
    sparse_fwd::fwd(&cfg, &d, &g, &mut y_sparse, SkipMode::MaskLoop, &mut st_s);
    assert!(allclose(y_direct.data(), y_sparse.data(), 1e-5, 1e-6));
    println!("functional: SparseTrain == direct  ✓");
    println!(
        "work skipped: {:.1}% of vector FMAs ({} of {})",
        100.0 * st_s.skip_fraction(),
        st_s.fma_vec_skipped,
        st_s.fma_total()
    );

    // 2. Host wallclock.
    let cfgb = BenchConfig::default();
    let td = bench("direct", &cfgb, || {
        y_direct.fill_zero();
        let mut st = KernelStats::new();
        direct::fwd(&cfg, &d, &g, &mut y_direct, &mut st);
        black_box(&y_direct);
    });
    let ts = bench("sparse", &cfgb, || {
        y_sparse.fill_zero();
        let mut st = KernelStats::new();
        sparse_fwd::fwd(&cfg, &d, &g, &mut y_sparse, SkipMode::MaskLoop, &mut st);
        black_box(&y_sparse);
    });
    println!("host: direct {}  sparse {}  speedup {:.2}x",
        sparsetrain::util::table::fmt_duration_ns(td.ns()),
        sparsetrain::util::table::fmt_duration_ns(ts.ns()),
        td.ns() / ts.ns());

    // 3. Modeled Skylake-X (the paper's platform) at the same sparsity.
    let m = Machine::skylake_x();
    let dm = estimate_layer_iid(&m, Algorithm::Direct, Component::Fwd, &cfg, 0.0).wall;
    let sm = estimate_layer_iid(&m, Algorithm::SparseTrain, Component::Fwd, &cfg, sparsity).wall;
    println!("model (Skylake-X): speedup {:.2}x", dm / sm);
}
