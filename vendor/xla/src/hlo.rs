//! HLO-text AST + parser for the mini-HLO interpreter.
//!
//! Parses the textual HLO module format the repository's AOT pipeline
//! emits (`python/compile/aot.py` with real JAX, or the Rust-side
//! reference emitter in `sparsetrain::runtime::hlo_builder`): named
//! computations of SSA instructions with declared shapes, operand lists
//! and `key=value` attributes, one `ENTRY` computation per module.
//!
//! The parser is **total**: any input — truncated, mangled, shape-edited —
//! produces `Err`, never a panic. This is fuzzed from the sparsetrain side
//! (`util::proptest` over mutated artifact text) and is why shapes are
//! bounded ([`MAX_ELEMENTS`]) at parse time: a corrupted dimension digit
//! must not turn into a multi-gigabyte allocation downstream.

use crate::{Error, Result};
use std::collections::HashMap;

/// Upper bound on elements per array shape (and on any parsed dimension,
/// window extent, stride or padding). 16M f32 elements = 64 MiB — far above
/// every artifact this repo lowers, far below an OOM.
pub const MAX_ELEMENTS: usize = 1 << 24;

/// Array element types the interpreter supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    F32,
    S32,
    Pred,
}

impl ElemType {
    pub fn name(self) -> &'static str {
        match self {
            ElemType::F32 => "f32",
            ElemType::S32 => "s32",
            ElemType::Pred => "pred",
        }
    }
}

/// An array shape: element type + row-major dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape {
    pub ty: ElemType,
    pub dims: Vec<usize>,
}

impl Shape {
    pub fn scalar(ty: ElemType) -> Shape {
        Shape { ty, dims: Vec::new() }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total element count (1 for scalars). Bounded by [`MAX_ELEMENTS`]
    /// at parse time, so this cannot overflow.
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Declared result shape of an instruction: a single array, or — for the
/// `tuple` root — a list of array shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShapeDecl {
    Single(Shape),
    Tuple(Vec<Shape>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinKind {
    Add,
    Sub,
    Mul,
    Div,
    Max,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryKind {
    Neg,
    Exp,
    Log,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Convolution window: per-spatial-dim size, stride and low/high padding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub size: [usize; 2],
    pub stride: [usize; 2],
    pub pad_lo: [usize; 2],
    pub pad_hi: [usize; 2],
}

/// Parsed `dim_labels` (e.g. `bf01_oi01->bf01`): which dimension of each
/// operand/output plays the batch / feature / spatial roles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    pub lhs_b: usize,
    pub lhs_f: usize,
    pub lhs_s: [usize; 2],
    pub rhs_i: usize,
    pub rhs_o: usize,
    pub rhs_s: [usize; 2],
    pub out_b: usize,
    pub out_f: usize,
    pub out_s: [usize; 2],
}

/// The op set the interpreter evaluates — exactly what the repository's
/// train-step / predict / kernel graphs lower to.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Parameter(usize),
    ConstantF32(f32),
    ConstantS32(i32),
    Binary(BinKind),
    Unary(UnaryKind),
    Compare(CmpDir),
    Select,
    Convert,
    Iota { dim: usize },
    Broadcast { dims: Vec<usize> },
    Reshape,
    Transpose { perm: Vec<usize> },
    Reverse { dims: Vec<usize> },
    Reduce { dims: Vec<usize>, to_apply: usize },
    Dot { lhs_c: usize, rhs_c: usize },
    Convolution { window: Window, spec: ConvSpec },
    Tuple,
}

/// One SSA instruction.
#[derive(Debug, Clone)]
pub struct Instr {
    pub name: String,
    pub shape: ShapeDecl,
    pub op: Op,
    /// Indices of operand instructions (always earlier in the computation).
    pub operands: Vec<usize>,
    pub is_root: bool,
}

/// A named computation: instruction list in SSA order plus its root and
/// parameter table (`params[k]` = instruction index of `parameter(k)`).
#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub root: usize,
    pub params: Vec<usize>,
}

/// A parsed module: computations in definition order; `entry` indexes the
/// `ENTRY` computation. `to_apply` references always point to earlier
/// computations, so call graphs are acyclic by construction.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub comps: Vec<Computation>,
    pub entry: usize,
}

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

/// Split `s` on `sep` at brace/bracket depth zero (so `f32[2,3]` and
/// `dimensions={0,1}` survive comma splitting intact).
fn split_top(s: &str, sep: char) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '{' | '[' => depth += 1,
            '}' | ']' => depth = depth.saturating_sub(1),
            c if c == sep && depth == 0 => {
                out.push(s[start..i].trim());
                start = i + c.len_utf8();
            }
            _ => {}
        }
    }
    out.push(s[start..].trim());
    out
}

fn parse_bounded(s: &str, what: &str) -> Result<usize> {
    let v: usize = s.trim().parse().map_err(|_| err(format!("bad {what} {s:?}")))?;
    if v > MAX_ELEMENTS {
        return Err(err(format!("{what} {v} exceeds the {MAX_ELEMENTS} bound")));
    }
    Ok(v)
}

/// Parse `f32[16,32]` / `s32[]` / `pred[2,3]`.
pub fn parse_shape(s: &str) -> Result<Shape> {
    let s = s.trim();
    let (ty, body) = if let Some(b) = s.strip_prefix("f32[") {
        (ElemType::F32, b)
    } else if let Some(b) = s.strip_prefix("s32[") {
        (ElemType::S32, b)
    } else if let Some(b) = s.strip_prefix("pred[") {
        (ElemType::Pred, b)
    } else {
        return Err(err(format!("bad shape {s:?}")));
    };
    let body = body.strip_suffix(']').ok_or_else(|| err(format!("unterminated shape {s:?}")))?;
    let mut dims = Vec::new();
    if !body.trim().is_empty() {
        for d in body.split(',') {
            dims.push(parse_bounded(d, "dimension")?);
        }
    }
    if dims.len() > 8 {
        return Err(err(format!("rank {} too high in {s:?}", dims.len())));
    }
    let mut n: usize = 1;
    for &d in &dims {
        n = n
            .checked_mul(d)
            .filter(|&n| n <= MAX_ELEMENTS)
            .ok_or_else(|| err(format!("shape {s:?} exceeds the element bound")))?;
    }
    Ok(Shape { ty, dims })
}

/// Parse `{0,1,2}` into a dimension list.
fn parse_dim_list(v: &str) -> Result<Vec<usize>> {
    let body = v
        .trim()
        .strip_prefix('{')
        .and_then(|b| b.strip_suffix('}'))
        .ok_or_else(|| err(format!("bad dimension list {v:?}")))?;
    let mut dims = Vec::new();
    if !body.trim().is_empty() {
        for d in body.split(',') {
            dims.push(parse_bounded(d, "dimension index")?);
        }
    }
    Ok(dims)
}

fn parse_x2(v: &str, what: &str) -> Result<[usize; 2]> {
    let (a, b) = v.split_once('x').ok_or_else(|| err(format!("bad {what} {v:?}")))?;
    Ok([parse_bounded(a, what)?, parse_bounded(b, what)?])
}

/// Parse `{size=3x3 pad=1_1x1_1 stride=1x1}` (stride/pad optional).
fn parse_window(v: &str) -> Result<Window> {
    let body = v
        .trim()
        .strip_prefix('{')
        .and_then(|b| b.strip_suffix('}'))
        .ok_or_else(|| err(format!("bad window {v:?}")))?;
    let mut size = None;
    let mut stride = [1usize, 1];
    let mut pad_lo = [0usize, 0];
    let mut pad_hi = [0usize, 0];
    for tok in body.split_whitespace() {
        let (k, val) = tok.split_once('=').ok_or_else(|| err(format!("bad window token {tok:?}")))?;
        match k {
            "size" => size = Some(parse_x2(val, "window size")?),
            "stride" => stride = parse_x2(val, "window stride")?,
            "pad" => {
                let mut parts = val.split('x');
                for i in 0..2 {
                    let p = parts.next().ok_or_else(|| err(format!("bad window pad {val:?}")))?;
                    let (lo, hi) =
                        p.split_once('_').ok_or_else(|| err(format!("bad window pad {val:?}")))?;
                    pad_lo[i] = parse_bounded(lo, "padding")?;
                    pad_hi[i] = parse_bounded(hi, "padding")?;
                }
                if parts.next().is_some() {
                    return Err(err(format!("window pad {val:?} is not 2-d")));
                }
            }
            other => return Err(err(format!("unknown window key {other:?}"))),
        }
    }
    let size = size.ok_or_else(|| err("window is missing size="))?;
    if stride[0] == 0 || stride[1] == 0 {
        return Err(err("window stride must be positive"));
    }
    if size[0] == 0 || size[1] == 0 {
        return Err(err("window size must be positive"));
    }
    Ok(Window { size, stride, pad_lo, pad_hi })
}

/// Parse one third of a `dim_labels` string: role chars `a`/`b` plus the
/// spatial digits `0` and `1`, each exactly once.
fn parse_label_part(s: &str, a: char, b: char) -> Result<(usize, usize, [usize; 2])> {
    let mut pa = None;
    let mut pb = None;
    let mut s0 = None;
    let mut s1 = None;
    let mut count = 0usize;
    for (i, ch) in s.chars().enumerate() {
        count += 1;
        let slot = if ch == a {
            &mut pa
        } else if ch == b {
            &mut pb
        } else if ch == '0' {
            &mut s0
        } else if ch == '1' {
            &mut s1
        } else {
            return Err(err(format!("bad dim label char {ch:?} in {s:?}")));
        };
        if slot.is_some() {
            return Err(err(format!("duplicate dim label {ch:?} in {s:?}")));
        }
        *slot = Some(i);
    }
    match (pa, pb, s0, s1, count) {
        (Some(pa), Some(pb), Some(s0), Some(s1), 4) => Ok((pa, pb, [s0, s1])),
        _ => Err(err(format!("dim labels {s:?} must name b/f and spatial 0,1 once each"))),
    }
}

/// Parse `bf01_oi01->bf01`.
fn parse_dim_labels(v: &str) -> Result<ConvSpec> {
    let (lhs_rhs, out) = v.split_once("->").ok_or_else(|| err(format!("bad dim_labels {v:?}")))?;
    let (lhs, rhs) = lhs_rhs.split_once('_').ok_or_else(|| err(format!("bad dim_labels {v:?}")))?;
    let (lhs_b, lhs_f, lhs_s) = parse_label_part(lhs, 'b', 'f')?;
    let (rhs_o, rhs_i, rhs_s) = parse_label_part(rhs, 'o', 'i')?;
    let (out_b, out_f, out_s) = parse_label_part(out, 'b', 'f')?;
    Ok(ConvSpec { lhs_b, lhs_f, lhs_s, rhs_i, rhs_o, rhs_s, out_b, out_f, out_s })
}

fn parse_cmp_dir(v: &str) -> Result<CmpDir> {
    Ok(match v {
        "EQ" => CmpDir::Eq,
        "NE" => CmpDir::Ne,
        "LT" => CmpDir::Lt,
        "LE" => CmpDir::Le,
        "GT" => CmpDir::Gt,
        "GE" => CmpDir::Ge,
        other => return Err(err(format!("unknown compare direction {other:?}"))),
    })
}

/// `key=value` attributes after the operand list, in source order.
struct Attrs<'a> {
    kvs: Vec<(&'a str, &'a str)>,
}

impl<'a> Attrs<'a> {
    fn get(&self, key: &str) -> Result<&'a str> {
        self.kvs
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| *v)
            .ok_or_else(|| err(format!("missing attribute {key}=")))
    }
}

fn parse_attrs(text: &str) -> Result<Attrs<'_>> {
    let text = text.trim();
    let mut kvs = Vec::new();
    if !text.is_empty() {
        let body = text
            .strip_prefix(',')
            .ok_or_else(|| err(format!("junk after operand list: {text:?}")))?;
        for kv in split_top(body, ',') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| err(format!("attribute {kv:?} is not key=value")))?;
            kvs.push((k.trim(), v.trim()));
        }
    }
    Ok(Attrs { kvs })
}

/// Parse one instruction line inside a computation body.
fn parse_instr(
    line: &str,
    names: &HashMap<String, usize>,
    comp_idx: &HashMap<String, usize>,
) -> Result<Instr> {
    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest.trim_start()),
        None => (false, line),
    };
    let (lhs, rest) =
        line.split_once(" = ").ok_or_else(|| err(format!("no `=` in instruction {line:?}")))?;
    let name = lhs.trim().trim_start_matches('%');
    if name.is_empty() || name.contains(char::is_whitespace) {
        return Err(err(format!("bad instruction name {lhs:?}")));
    }

    // Declared shape: `(s, s, ...)` tuple or a single `ty[dims]` token.
    let rest = rest.trim_start();
    let (shape, rest) = if let Some(r) = rest.strip_prefix('(') {
        let (body, tail) =
            r.split_once(')').ok_or_else(|| err(format!("unterminated tuple shape in {line:?}")))?;
        let mut shapes = Vec::new();
        for part in split_top(body, ',') {
            shapes.push(parse_shape(part)?);
        }
        (ShapeDecl::Tuple(shapes), tail.trim_start())
    } else {
        let (tok, tail) =
            rest.split_once(' ').ok_or_else(|| err(format!("missing op in {line:?}")))?;
        (ShapeDecl::Single(parse_shape(tok)?), tail.trim_start())
    };

    // `op(args)` + attributes. Operand names and constant/parameter payloads
    // never contain parentheses, so the first `)` closes the list.
    let (opname, after) =
        rest.split_once('(').ok_or_else(|| err(format!("missing operand list in {line:?}")))?;
    let opname = opname.trim();
    let (args_text, attrs_text) =
        after.split_once(')').ok_or_else(|| err(format!("unterminated operand list in {line:?}")))?;
    let attrs = parse_attrs(attrs_text)?;

    let operands: Vec<usize> = if matches!(opname, "constant" | "parameter") {
        Vec::new()
    } else {
        let t = args_text.trim();
        if t.is_empty() {
            Vec::new()
        } else {
            let mut ops = Vec::new();
            for o in t.split(',') {
                let nm = o.trim().trim_start_matches('%');
                ops.push(
                    names
                        .get(nm)
                        .copied()
                        .ok_or_else(|| err(format!("operand %{nm} is not defined before use")))?,
                );
            }
            ops
        }
    };

    let single_ty = match &shape {
        ShapeDecl::Single(s) => Some(s.ty),
        ShapeDecl::Tuple(_) => None,
    };
    let op = match opname {
        "parameter" => Op::Parameter(parse_bounded(args_text, "parameter number")?),
        "constant" => match single_ty {
            Some(ElemType::F32) => Op::ConstantF32(
                args_text
                    .trim()
                    .parse::<f32>()
                    .map_err(|_| err(format!("bad f32 constant {args_text:?}")))?,
            ),
            Some(ElemType::S32) => Op::ConstantS32(
                args_text
                    .trim()
                    .parse::<i32>()
                    .map_err(|_| err(format!("bad s32 constant {args_text:?}")))?,
            ),
            _ => return Err(err(format!("constant must be f32 or s32 in {line:?}"))),
        },
        "add" => Op::Binary(BinKind::Add),
        "subtract" => Op::Binary(BinKind::Sub),
        "multiply" => Op::Binary(BinKind::Mul),
        "divide" => Op::Binary(BinKind::Div),
        "maximum" => Op::Binary(BinKind::Max),
        "negate" => Op::Unary(UnaryKind::Neg),
        "exponential" => Op::Unary(UnaryKind::Exp),
        "log" => Op::Unary(UnaryKind::Log),
        "compare" => Op::Compare(parse_cmp_dir(attrs.get("direction")?)?),
        "select" => Op::Select,
        "convert" => Op::Convert,
        "iota" => Op::Iota { dim: parse_bounded(attrs.get("iota_dimension")?, "iota dimension")? },
        "broadcast" => Op::Broadcast { dims: parse_dim_list(attrs.get("dimensions")?)? },
        "reshape" => Op::Reshape,
        "transpose" => Op::Transpose { perm: parse_dim_list(attrs.get("dimensions")?)? },
        "reverse" => Op::Reverse { dims: parse_dim_list(attrs.get("dimensions")?)? },
        "reduce" => {
            let comp_name = attrs.get("to_apply")?.trim_start_matches('%');
            let to_apply = comp_idx
                .get(comp_name)
                .copied()
                .ok_or_else(|| err(format!("to_apply references unknown computation %{comp_name}")))?;
            Op::Reduce { dims: parse_dim_list(attrs.get("dimensions")?)?, to_apply }
        }
        "dot" => {
            let lhs = parse_dim_list(attrs.get("lhs_contracting_dims")?)?;
            let rhs = parse_dim_list(attrs.get("rhs_contracting_dims")?)?;
            match (lhs.as_slice(), rhs.as_slice()) {
                (&[l], &[r]) => Op::Dot { lhs_c: l, rhs_c: r },
                _ => return Err(err("dot supports exactly one contracting dim per side")),
            }
        }
        "convolution" => Op::Convolution {
            window: parse_window(attrs.get("window")?)?,
            spec: parse_dim_labels(attrs.get("dim_labels")?)?,
        },
        "tuple" => Op::Tuple,
        other => return Err(err(format!("unsupported op {other:?}"))),
    };

    Ok(Instr { name: name.to_string(), shape, op, operands, is_root })
}

/// Finish a computation body: resolve the root and the parameter table.
fn finish_computation(name: String, instrs: Vec<Instr>) -> Result<Computation> {
    let mut root = None;
    for (i, ins) in instrs.iter().enumerate() {
        if ins.is_root {
            if root.is_some() {
                return Err(err(format!("computation %{name} has multiple ROOTs")));
            }
            root = Some(i);
        }
    }
    let root = root.ok_or_else(|| err(format!("computation %{name} has no ROOT")))?;

    let mut by_number: Vec<Option<usize>> = Vec::new();
    for (i, ins) in instrs.iter().enumerate() {
        if let Op::Parameter(k) = ins.op {
            // Each parameter is itself an instruction, so a valid number is
            // always < instrs.len() — this bound (not MAX_ELEMENTS) keeps a
            // corrupted digit from forcing a huge table allocation.
            if k >= instrs.len() {
                return Err(err(format!("parameter({k}) number out of range in %{name}")));
            }
            if by_number.len() <= k {
                by_number.resize(k + 1, None);
            }
            if by_number[k].is_some() {
                return Err(err(format!("duplicate parameter({k}) in %{name}")));
            }
            by_number[k] = Some(i);
        }
    }
    let mut params = Vec::with_capacity(by_number.len());
    for (k, slot) in by_number.into_iter().enumerate() {
        params.push(slot.ok_or_else(|| err(format!("%{name} is missing parameter({k})")))?);
    }
    Ok(Computation { name, instrs, root, params })
}

/// Parse a full HLO-text module. Never panics; every malformed input is a
/// descriptive `Err`.
pub fn parse_module(text: &str) -> Result<Module> {
    let mut module_name = String::new();
    let mut saw_header = false;
    let mut comps: Vec<Computation> = Vec::new();
    let mut comp_idx: HashMap<String, usize> = HashMap::new();
    let mut entry: Option<usize> = None;
    // (name, is_entry, instrs, name -> instr index)
    let mut cur: Option<(String, bool, Vec<Instr>, HashMap<String, usize>)> = None;

    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if !saw_header {
            let rest = line
                .strip_prefix("HloModule")
                .ok_or_else(|| err("expected `HloModule <name>` header"))?;
            module_name = rest.trim().trim_end_matches(',').to_string();
            saw_header = true;
            continue;
        }
        if line == "}" {
            let (name, is_entry, instrs, _) =
                cur.take().ok_or_else(|| err("stray `}` outside a computation"))?;
            let comp = finish_computation(name, instrs)?;
            if comp_idx.contains_key(&comp.name) {
                return Err(err(format!("duplicate computation %{}", comp.name)));
            }
            comp_idx.insert(comp.name.clone(), comps.len());
            if is_entry {
                if entry.is_some() {
                    return Err(err("multiple ENTRY computations"));
                }
                entry = Some(comps.len());
            }
            comps.push(comp);
            continue;
        }
        if !line.contains('=') {
            if let Some(head) = line.strip_suffix('{') {
                if cur.is_some() {
                    return Err(err("nested computation"));
                }
                let head = head.trim();
                let (is_entry, head) = match head.strip_prefix("ENTRY") {
                    Some(h) => (true, h.trim()),
                    None => (false, head),
                };
                let name = head.trim_start_matches('%');
                if name.is_empty() || name.contains(char::is_whitespace) {
                    return Err(err(format!("bad computation header {line:?}")));
                }
                cur = Some((name.to_string(), is_entry, Vec::new(), HashMap::new()));
                continue;
            }
        }
        let Some((_, _, instrs, names)) = cur.as_mut() else {
            return Err(err(format!("instruction outside a computation: {line:?}")));
        };
        let instr = parse_instr(line, names, &comp_idx)?;
        if names.contains_key(&instr.name) {
            return Err(err(format!("duplicate instruction name %{}", instr.name)));
        }
        names.insert(instr.name.clone(), instrs.len());
        instrs.push(instr);
    }
    if cur.is_some() {
        return Err(err("unterminated computation (missing `}`)"));
    }
    let entry = entry.ok_or_else(|| err("module has no ENTRY computation"))?;
    Ok(Module { name: module_name, comps, entry })
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "HloModule tiny\n\
        \n\
        %add_f32 {\n\
        \x20 %p0 = f32[] parameter(0)\n\
        \x20 %p1 = f32[] parameter(1)\n\
        \x20 ROOT %add = f32[] add(%p0, %p1)\n\
        }\n\
        \n\
        ENTRY %main {\n\
        \x20 %x = f32[2,3] parameter(0)\n\
        \x20 %zero = f32[] constant(0)\n\
        \x20 ROOT %sum = f32[2] reduce(%x, %zero), dimensions={1}, to_apply=%add_f32\n\
        }\n";

    #[test]
    fn miri_parses_reduce_module() {
        let m = parse_module(TINY).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.comps.len(), 2);
        assert_eq!(m.entry, 1);
        let main = &m.comps[1];
        assert_eq!(main.params, vec![0]);
        assert_eq!(main.root, 2);
        match &main.instrs[2].op {
            Op::Reduce { dims, to_apply } => {
                assert_eq!(dims, &[1]);
                assert_eq!(*to_apply, 0);
            }
            other => panic!("expected reduce, got {other:?}"),
        }
    }

    #[test]
    fn miri_parses_convolution_attrs() {
        let text = "HloModule c\nENTRY %m {\n\
            \x20 %x = f32[1,2,4,4] parameter(0)\n\
            \x20 %w = f32[3,2,3,3] parameter(1)\n\
            \x20 ROOT %y = f32[1,3,4,4] convolution(%x, %w), \
            window={size=3x3 pad=1_1x1_1}, dim_labels=bf01_oi01->bf01\n}\n";
        let m = parse_module(text).unwrap();
        match &m.comps[0].instrs[2].op {
            Op::Convolution { window, spec } => {
                assert_eq!(window.size, [3, 3]);
                assert_eq!(window.stride, [1, 1]);
                assert_eq!(window.pad_lo, [1, 1]);
                assert_eq!(window.pad_hi, [1, 1]);
                assert_eq!((spec.lhs_b, spec.lhs_f), (0, 1));
                assert_eq!((spec.rhs_o, spec.rhs_i), (0, 1));
                assert_eq!(spec.out_s, [2, 3]);
            }
            other => panic!("expected convolution, got {other:?}"),
        }
    }

    #[test]
    fn miri_rejects_malformed_text() {
        for bad in [
            "",
            "not hlo at all",
            "HloModule m",                                      // no ENTRY
            "HloModule m\nENTRY %e {\n  %p = f32[2 parameter(0)\n}\n", // unterminated shape
            "HloModule m\nENTRY %e {\n  %p = f32[2] parameter(0)\n", // missing }
            "HloModule m\nENTRY %e {\n  %p = f32[2] parameter(0)\n}\n", // no ROOT
            "HloModule m\nENTRY %e {\n  ROOT %y = f32[2] add(%a, %b)\n}\n", // undefined operands
            "HloModule m\nENTRY %e {\n  ROOT %p = f32[99999999999999] parameter(0)\n}\n",
            "HloModule m\nENTRY %e {\n  ROOT %p = f32[4096,4096,4096] parameter(0)\n}\n",
            "HloModule m\nENTRY %e {\n  ROOT %p = f32[] frobnicate()\n}\n",
        ] {
            assert!(parse_module(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn miri_constant_parsing_handles_inf_and_exponents() {
        let text = "HloModule k\nENTRY %e {\n\
            \x20 %a = f32[] constant(-inf)\n\
            \x20 %b = f32[] constant(7.6293945e-6)\n\
            \x20 %c = s32[] constant(-3)\n\
            \x20 ROOT %r = f32[] add(%a, %b)\n}\n";
        let m = parse_module(text).unwrap();
        match m.comps[0].instrs[0].op {
            Op::ConstantF32(v) => assert!(v.is_infinite() && v < 0.0),
            ref other => panic!("{other:?}"),
        }
        match m.comps[0].instrs[1].op {
            Op::ConstantF32(v) => assert_eq!(v, 7.629_394_5e-6),
            ref other => panic!("{other:?}"),
        }
        match m.comps[0].instrs[2].op {
            Op::ConstantS32(v) => assert_eq!(v, -3),
            ref other => panic!("{other:?}"),
        }
    }
}
