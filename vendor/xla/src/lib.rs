//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The build environment cannot link the real PJRT runtime, so this crate
//! implements the API surface `sparsetrain::runtime` uses with host-side
//! behavior wherever possible:
//!
//! * [`Literal`] packing/reshaping/unpacking is fully functional (it is
//!   plain host memory), so literal round-trip tests run for real;
//! * [`PjRtClient::cpu`] succeeds and reports a `cpu-stub` platform;
//! * [`HloModuleProto::from_text_file`] reads the artifact file (missing
//!   artifacts produce real, descriptive errors);
//! * [`PjRtClient::compile`] returns an error explaining that execution
//!   requires the real PJRT plugin. All trainer/runtime tests that need to
//!   *execute* artifacts are gated on artifact presence and skip cleanly.

use std::fmt;
use std::path::Path;

/// Stub error type.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Internal element storage — public only because [`NativeType`] mentions
/// it; not part of the stable stub surface.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host literal: typed buffer + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> Payload;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { payload: T::wrap(data), dims: vec![data.len() as i64] }
    }

    /// Number of scalar elements (0 for tuples).
    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// The literal's shape.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements out as a host `Vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(elems) => Ok(elems),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module text (the stub only carries the raw text through).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file. Fails with a path-carrying error when the
    /// artifact is missing — exercised by the runtime's error-path tests.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error(format!("reading HLO text {}: {e}", p.display())))?;
        if text.trim().is_empty() {
            return Err(Error(format!("HLO text {} is empty", p.display())));
        }
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// A compiled executable. The stub can never construct one; the real crate
/// is required for execution.
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// A device buffer handle.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs. Unreachable in the stub (compile
    /// always fails), but kept API-compatible.
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error("PJRT stub: execution requires the real xla crate".into()))
    }
}

/// A PJRT client.
pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    /// Create the CPU client (always succeeds in the stub).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-stub".to_string() })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    /// HLO compilation is not available offline: the stub returns a
    /// descriptive error so artifact-gated callers fail loudly instead of
    /// producing wrong numerics.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(
            "PJRT stub: HLO compilation unavailable in the offline build; \
             link the real `xla` crate to execute artifacts"
                .into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn literal_i32_typed() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_up_compile_gated() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        assert!(c.compile(&comp).is_err());
    }

    #[test]
    fn missing_file_error_names_path() {
        let e = HloModuleProto::from_text_file("/no/such/artifact.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("artifact.hlo.txt"));
    }
}
