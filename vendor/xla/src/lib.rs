//! Offline substitute for the `xla` crate (PJRT bindings) with a built-in
//! **mini-HLO interpreter**.
//!
//! The build environment cannot link the real PJRT runtime, so this crate
//! implements the API surface `sparsetrain::runtime` uses entirely on the
//! host:
//!
//! * [`Literal`] packing/reshaping/unpacking is plain host memory;
//! * [`PjRtClient::cpu`] succeeds and reports a `cpu-interp` platform;
//! * [`HloModuleProto::from_text_file`] reads HLO-text artifacts (missing
//!   artifacts produce real, descriptive errors);
//! * [`PjRtClient::compile`] **parses and shape-checks** the HLO text
//!   ([`hlo::parse_module`] + [`eval::validate`]) and returns a runnable
//!   [`PjRtLoadedExecutable`]; [`PjRtLoadedExecutable::execute`] evaluates
//!   the module's `ENTRY` computation with the [`eval`] interpreter.
//!
//! The supported op set is exactly what the repository's train-step /
//! predict / kernel graphs lower to: `convolution` (arbitrary
//! `dim_labels`, so the weight-gradient and input-gradient convolutions
//! work), `dot`, `reduce` (with scalar `to_apply` bodies), elementwise
//! arithmetic, `maximum`/`exponential`/`log`, `compare`/`select`/`convert`
//! / `iota` (one-hot and ReLU masks), `broadcast`/`reshape`/`transpose`/
//! `reverse`, and `tuple` roots. Malformed or shape-inconsistent text is
//! rejected with `Err` at compile time — never a panic — which is fuzzed
//! from the sparsetrain side.

use std::fmt;
use std::path::Path;
use std::sync::{Arc, Mutex};

pub mod eval;
pub mod hlo;

pub use eval::{Arena, OpCall};

/// One convolution call site, flattened from an [`OpCall`] by the op
/// router before it hands the instruction to the sparse conv kernels. All
/// buffers are row-major host `f32` slices with their dimensions attached;
/// `window`/`spec` are the instruction's parsed attributes.
pub struct ConvCall<'a> {
    pub window: &'a hlo::Window,
    pub spec: &'a hlo::ConvSpec,
    pub lhs: &'a [f32],
    pub lhs_dims: &'a [usize],
    pub rhs: &'a [f32],
    pub rhs_dims: &'a [usize],
    pub out_dims: &'a [usize],
}

/// A pluggable per-instruction op executor (the SparseTrain kernel /
/// scheduler stack on the host side). The evaluator consults it for every
/// instruction whose declared type is `f32` (parameters, tuples and
/// constants excepted), handing it an [`OpCall`] describing the
/// instruction plus an output buffer of exactly `out_elements()` floats.
/// Returning `true` means the hook filled the whole buffer and that buffer
/// IS the instruction's result; returning `false` declines, the buffer is
/// recycled, and the built-in evaluator produces a bit-identical naive
/// result. The hook must not panic: it runs inside `execute`, whose
/// contract is `Err`, never a panic.
pub type OpExecutor = dyn for<'a> Fn(&eval::OpCall<'a>, &mut [f32]) -> bool + Send + Sync;

/// A boxed one-shot task handed to a [`JoinFn`]. Deliberately **not**
/// `'static`: the evaluator's co-scheduled tasks borrow the instruction
/// slots and arenas of the in-flight computation, so the join function
/// must run both closures to completion before returning (structured
/// fork-join, never fire-and-forget).
pub type TaskBox<'a> = Box<dyn FnOnce() + Send + 'a>;

/// Runs two independent tasks to completion, possibly concurrently.
/// Supplied by the host (the SparseTrain coordinator runs one of the two
/// on its persistent thread pool); both closures MUST have returned when
/// this function returns. A trivial conforming implementation is
/// `|a, b| { a(); b(); }` — the evaluator's correctness never depends on
/// actual concurrency, only on completion.
pub type JoinFn = dyn for<'a> Fn(TaskBox<'a>, TaskBox<'a>) + Send + Sync;

/// Decides whether two *ready, data-independent* instructions (by index
/// into the computation's instruction list) should be co-scheduled. The
/// evaluator only consults this for pairs it has already proven
/// independent via the dependency DAG; the host gates on measured costs
/// (e.g. "does the first op's inner parallelism under-fill the pool?").
pub type OverlapFn = dyn Fn(&hlo::Computation, usize, usize) -> bool + Send + Sync;

/// Host-supplied policy pair that turns the sequential evaluator into a
/// dependency-scheduled one: `overlap` picks which ready instruction
/// pairs to co-schedule, `join` runs them. Installed via
/// [`PjRtClient::set_pipeline_planner`]; executables compiled without one
/// run strictly sequentially (bit-identical either way — each op fully
/// owns its output buffer and independent ops commute).
pub struct PipelinePlanner {
    pub join: Arc<JoinFn>,
    pub overlap: Arc<OverlapFn>,
}

/// Stub error type.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Internal element storage — public only because [`NativeType`] mentions
/// it; not part of the stable crate surface.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host literal: typed buffer + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> Payload;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { payload: T::wrap(data), dims: vec![data.len() as i64] }
    }

    /// Number of scalar elements (0 for tuples).
    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// The literal's shape.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements out as a host `Vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(elems) => Ok(elems),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// Build a tuple literal from element literals.
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { payload: Payload::Tuple(elems), dims: Vec::new() }
    }

    /// Internal constructor for the interpreter.
    pub(crate) fn from_parts(payload: Payload, dims: Vec<i64>) -> Literal {
        Literal { payload, dims }
    }
}

/// Raw HLO module text, read from an artifact file. Parsing and shape
/// checking happen at [`PjRtClient::compile`] time.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file. Fails with a path-carrying error when the
    /// artifact is missing — exercised by the runtime's error-path tests.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error(format!("reading HLO text {}: {e}", p.display())))?;
        if text.trim().is_empty() {
            return Err(Error(format!("HLO text {} is empty", p.display())));
        }
        Ok(HloModuleProto { text })
    }

    /// Wrap in-memory HLO text (used by tests and the artifact fallback).
    pub fn from_text(text: &str) -> Result<HloModuleProto> {
        if text.trim().is_empty() {
            return Err(Error("HLO text is empty".into()));
        }
        Ok(HloModuleProto { text: text.to_string() })
    }
}

/// An XLA computation built from a parsed module (carries the HLO text;
/// parsing happens at [`PjRtClient::compile`] time so parse errors surface
/// as compile errors, matching the real crate's behavior).
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// A compiled (parsed + shape-checked) executable over the mini-HLO
/// interpreter. Carries the client's op executor (if any) so every
/// `execute` consults it per instruction, plus a private buffer arena so
/// repeated executions of the same module recycle their f32 scratch
/// instead of re-allocating per op.
pub struct PjRtLoadedExecutable {
    module: hlo::Module,
    op_exec: Option<Arc<OpExecutor>>,
    pipeline: Option<Arc<PipelinePlanner>>,
    arena: Mutex<eval::Arena>,
    /// Second arena for the co-scheduled instruction during an overlap
    /// window (each concurrent op needs exclusive arena access; the pools
    /// re-merge into per-executable reuse over successive calls).
    spare: Mutex<eval::Arena>,
}

/// A device buffer handle (host memory in this offline build).
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

impl PjRtLoadedExecutable {
    /// Execute the module's `ENTRY` computation with the given inputs.
    /// Mirrors the real crate's nesting: one device, one result buffer
    /// (holding the tuple when the root is a tuple). Instructions go
    /// through the client's [`OpExecutor`] when one is installed. The
    /// executable's arena is reused across calls; if another caller
    /// poisoned the lock, we fall back to a throwaway arena rather than
    /// propagate the poison (results are identical either way).
    pub fn execute<T>(&self, inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lit = match (self.arena.lock(), self.spare.lock()) {
            (Ok(mut guard), Ok(mut spare)) => eval::execute_pipelined_in(
                &self.module,
                inputs,
                self.op_exec.as_deref(),
                self.pipeline.as_deref(),
                &mut guard,
                &mut spare,
            )?,
            _ => {
                let mut arena = eval::Arena::new();
                let mut spare = eval::Arena::new();
                eval::execute_pipelined_in(
                    &self.module,
                    inputs,
                    self.op_exec.as_deref(),
                    self.pipeline.as_deref(),
                    &mut arena,
                    &mut spare,
                )?
            }
        };
        Ok(vec![vec![PjRtBuffer { lit }]])
    }

    /// The parsed module (exposed for diagnostics and tests).
    pub fn module(&self) -> &hlo::Module {
        &self.module
    }
}

/// A PJRT client.
pub struct PjRtClient {
    platform: String,
    op_exec: Option<Arc<OpExecutor>>,
    pipeline: Option<Arc<PipelinePlanner>>,
}

impl PjRtClient {
    /// Create the CPU client (always succeeds offline).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-interp".to_string(), op_exec: None, pipeline: None })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    /// Install a pluggable op executor. Every executable compiled *after*
    /// this call consults the hook per f32 instruction (with bit-identical
    /// fallback to the naive evaluators on `false`).
    pub fn set_op_executor(&mut self, exec: Arc<OpExecutor>) {
        self.op_exec = Some(exec);
    }

    /// Install a pipeline planner. Every executable compiled *after* this
    /// call evaluates through the dependency-scheduled executor, which
    /// co-schedules planner-approved independent instruction pairs (see
    /// [`PipelinePlanner`]); results stay bit-identical to the sequential
    /// evaluator by construction.
    pub fn set_pipeline_planner(&mut self, planner: Arc<PipelinePlanner>) {
        self.pipeline = Some(planner);
    }

    /// Parse and shape-check the HLO text, returning a runnable
    /// executable. Malformed or shape-inconsistent modules are rejected
    /// here (never a panic), so runtime callers fail loudly at load time
    /// instead of producing wrong numerics.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let module = hlo::parse_module(&comp.text)?;
        eval::validate(&module)?;
        Ok(PjRtLoadedExecutable {
            module,
            op_exec: self.op_exec.clone(),
            pipeline: self.pipeline.clone(),
            arena: Mutex::new(eval::Arena::new()),
            spare: Mutex::new(eval::Arena::new()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn literal_i32_typed() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn miri_client_compiles_and_executes_valid_hlo() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        let proto = HloModuleProto {
            text: "HloModule m\nENTRY %e {\n  %x = f32[3] parameter(0)\n  \
                   ROOT %y = f32[3] add(%x, %x)\n}\n"
                .into(),
        };
        let exe = c.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        let outs = exe.execute::<Literal>(&[x]).unwrap();
        let lit = outs[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn miri_compile_rejects_invalid_hlo() {
        let c = PjRtClient::cpu().unwrap();
        for text in [
            "HloModule m",                       // no ENTRY computation
            "HloModule m\nENTRY %e {\n  %x = f32[3] parameter(0)\n  \
             ROOT %y = f32[4] add(%x, %x)\n}\n", // shape lie
        ] {
            let proto = HloModuleProto { text: text.into() };
            assert!(c.compile(&XlaComputation::from_proto(&proto)).is_err(), "{text:?}");
        }
    }

    #[test]
    fn missing_file_error_names_path() {
        let e = HloModuleProto::from_text_file("/no/such/artifact.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("artifact.hlo.txt"));
    }
}
