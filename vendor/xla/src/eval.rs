//! Shape inference + evaluation for the mini-HLO interpreter.
//!
//! [`validate`] runs at `PjRtClient::compile` time: it re-derives every
//! instruction's shape from its operands and rejects the module on any
//! mismatch, so execution can trust declared shapes. [`execute`] evaluates
//! the `ENTRY` computation over host [`Literal`]s.
//!
//! Numerics contract: convolution and dot accumulate in `f32` with plain
//! multiply-then-add in a fixed loop order — for `dim_labels=bf01_oi01->bf01`
//! the contraction order is (feature, ky, kx), which makes the forward
//! convolution **bit-identical** to `kernels::reference::conv_fwd` on the
//! sparsetrain side (pinned by a golden test there). Reductions fold
//! elements in row-major operand order.
//!
//! **Pluggable op execution (ISSUE 6).** [`execute_with_hook`] threads an
//! optional [`OpExecutor`] down to every f32 array-producing instruction:
//! the hook sees the instruction and its operand buffers — plus, for
//! fusion decisions, the defining ops of those operands — through an
//! [`OpCall`], and either fills the caller-provided output buffer
//! completely (returning `true`) or declines (`false`), in which case the
//! built-in evaluator below runs — so anything outside the external
//! executor's envelope keeps the reference numerics above, bit for bit.
//!
//! **Arena allocation (ISSUE 6).** Intermediate f32 buffers come from an
//! [`Arena`]: a pool keyed by element count, refilled by last-use
//! recycling (a buffer returns to the pool right after the instruction
//! that reads it last, with [`FUSION_READ_DEPTH`] levels of slack for the
//! hook's operand-chain reads). Every op fully overwrites its output
//! buffer, which makes an arena-reusing run bit-identical to a
//! fresh-allocation run ([`Arena::disabled`]) — pinned by
//! `miri_arena_reuse_is_bit_identical_to_fresh_alloc`.
//!
//! **Dependency-scheduled execution (ISSUE 10).** With a
//! [`PipelinePlanner`] installed, [`execute_pipelined_in`] replaces the
//! strict instruction-list walk with a ready-queue walk over the
//! computation's data-dependency DAG: an instruction becomes *ready* when
//! every direct operand has completed, and the planner may approve
//! co-scheduling one extra ready instruction alongside the one being
//! dispatched (the host runs the pair on its persistent thread pool via
//! the planner's `join`). Correctness is structural, not numerical:
//!
//! * **Buffer ownership** — each instruction exclusively owns its output
//!   buffer from `take_uninit` until the result lands in its slot; the
//!   two co-scheduled instructions draw from *disjoint* arenas (main +
//!   spare), so no allocation path is shared during an overlap window.
//! * **Read safety** — readiness by direct operands implies (inductively)
//!   that every [`FUSION_READ_DEPTH`]-transitive operand a fusing hook
//!   may inspect has also completed; pending slots read as absent (`None`
//!   from [`OpCall::value_f32`]), same as retired ones.
//! * **Retirement** — a buffer is recycled only when *every* instruction
//!   whose depth-extended read set contains it has completed (reader
//!   counting generalizes the sequential last-use schedule to
//!   out-of-order completion). The root is never retired.
//! * **Bit-identity** — per-op arithmetic is untouched and independent
//!   ops commute, so any topological completion order produces the same
//!   bits as the sequential walk at any thread count — pinned by the
//!   `miri_dag_*` smokes here and `rust/tests/pipeline_route_parity.rs`
//!   on the sparsetrain side.

use crate::hlo::{
    BinKind, CmpDir, Computation, ConvSpec, ElemType, Instr, Module, Op, Shape, ShapeDecl,
    UnaryKind, Window, MAX_ELEMENTS,
};
use crate::{Error, Literal, OpExecutor, Payload, PipelinePlanner, Result, TaskBox};
use std::collections::HashMap;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

// ---------------------------------------------------------------------------
// Values
// ---------------------------------------------------------------------------

/// A typed host buffer (the interpreter's runtime value).
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    S32(Vec<i32>),
    Pred(Vec<bool>),
}

/// A buffer plus its shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Value {
    pub shape: Shape,
    pub buf: Buf,
}

impl Value {
    fn f32s(&self) -> Result<&[f32]> {
        match &self.buf {
            Buf::F32(v) => Ok(v),
            _ => Err(err("expected an f32 buffer")),
        }
    }

    fn ty(&self) -> ElemType {
        match self.buf {
            Buf::F32(_) => ElemType::F32,
            Buf::S32(_) => ElemType::S32,
            Buf::Pred(_) => ElemType::Pred,
        }
    }
}

/// An evaluated instruction slot: array value or (for `tuple`) a list.
enum Slot {
    Single(Value),
    Tuple(Vec<Value>),
}

impl Slot {
    fn single(&self) -> Result<&Value> {
        match self {
            Slot::Single(v) => Ok(v),
            Slot::Tuple(_) => Err(err("tuple value used as an array operand")),
        }
    }
}

// ---------------------------------------------------------------------------
// Arena allocator
// ---------------------------------------------------------------------------

/// How many operand-chain levels an [`OpExecutor`] may read through when
/// recognizing fusible patterns (e.g. `select → compare → broadcast →
/// scalar`). Last-use recycling keeps a buffer alive this many consumer
/// levels past its direct readers, so [`OpCall::value_f32`] on a fusion
/// chain never observes a retired buffer.
pub const FUSION_READ_DEPTH: usize = 3;

/// An f32 buffer pool keyed by element count. [`execute_with_hook_in`]
/// draws every intermediate f32 buffer from it and returns each buffer as
/// soon as its last (transitive, [`FUSION_READ_DEPTH`]-deep) reader has
/// run, so steady-state execution of the same module stops allocating.
/// Recycled buffers carry **unspecified contents**; every evaluator path
/// (and every hook that returns `true`) fully overwrites its output, which
/// keeps reuse bit-identical to fresh allocation.
#[derive(Debug, Default)]
pub struct Arena {
    pools: HashMap<usize, Vec<Vec<f32>>>,
    disabled: bool,
}

impl Arena {
    /// Recycled buffers kept per element-count class; beyond this they are
    /// dropped (bounds memory on modules with many same-shape dead values).
    const MAX_PER_CLASS: usize = 8;

    /// A fresh, recycling arena.
    pub fn new() -> Arena {
        Arena::default()
    }

    /// An arena that never recycles: every take is a fresh zeroed
    /// allocation and every give is dropped — the reference allocator the
    /// reuse path must match bit for bit.
    pub fn disabled() -> Arena {
        Arena { pools: HashMap::new(), disabled: true }
    }

    /// Whether this arena recycles buffers.
    pub fn enabled(&self) -> bool {
        !self.disabled
    }

    /// A buffer of exactly `n` elements with unspecified contents (stale
    /// values from a retired instruction when recycled): the caller must
    /// fully overwrite it.
    fn take_uninit(&mut self, n: usize) -> Vec<f32> {
        if !self.disabled {
            if let Some(buf) = self.pools.get_mut(&n).and_then(|pool| pool.pop()) {
                return buf;
            }
        }
        vec![0.0; n]
    }

    /// Return a buffer to the pool for reuse by a later same-size output.
    fn give(&mut self, buf: Vec<f32>) {
        if self.disabled || buf.is_empty() {
            return;
        }
        let pool = self.pools.entry(buf.len()).or_default();
        if pool.len() < Self::MAX_PER_CLASS {
            pool.push(buf);
        }
    }
}

// ---------------------------------------------------------------------------
// Op-executor call sites
// ---------------------------------------------------------------------------

/// One instruction call site, handed to an external [`OpExecutor`] before
/// the built-in evaluator runs. Exposes the instruction, its output shape,
/// its operand buffers, and — for fusion decisions — the defining
/// instructions and buffers of values up to [`FUSION_READ_DEPTH`] operand
/// levels away. All buffers are row-major host `f32` slices.
pub struct OpCall<'a> {
    module: &'a Module,
    comp: &'a Computation,
    instr: &'a Instr,
    slots: &'a [Slot],
    out_shape: &'a Shape,
}

impl<'a> OpCall<'a> {
    /// The instruction being evaluated.
    pub fn instr(&self) -> &'a Instr {
        self.instr
    }

    /// The instruction's opcode (with attributes).
    pub fn op(&self) -> &'a Op {
        &self.instr.op
    }

    /// The declared output dimensions (row-major).
    pub fn out_dims(&self) -> &'a [usize] {
        &self.out_shape.dims
    }

    /// The output element count — the length of the hook's `out` buffer.
    pub fn out_elements(&self) -> usize {
        self.out_shape.elements()
    }

    /// The instruction index of the `k`-th operand.
    pub fn operand_idx(&self, k: usize) -> Option<usize> {
        self.instr.operands.get(k).copied()
    }

    /// The instruction at `idx` in the enclosing computation — use to walk
    /// the defining ops of operands when recognizing fusible chains.
    pub fn instr_at(&self, idx: usize) -> Option<&'a Instr> {
        self.comp.instrs.get(idx)
    }

    /// The defining instruction of the `k`-th operand.
    pub fn operand_instr(&self, k: usize) -> Option<&'a Instr> {
        self.instr_at(self.operand_idx(k)?)
    }

    /// The live f32 buffer (and dims) of the value at instruction `idx`.
    /// `None` for non-f32 values, tuples, and retired (arena-recycled)
    /// slots — the latter cannot occur within [`FUSION_READ_DEPTH`] operand
    /// levels of the current instruction, but the check keeps this total.
    pub fn value_f32(&self, idx: usize) -> Option<(&'a [f32], &'a [usize])> {
        let Slot::Single(v) = self.slots.get(idx)? else {
            return None;
        };
        let Buf::F32(buf) = &v.buf else {
            return None;
        };
        if buf.len() != v.shape.elements() {
            return None;
        }
        Some((buf.as_slice(), v.shape.dims.as_slice()))
    }

    /// The f32 buffer (and dims) of the `k`-th operand.
    pub fn operand_f32(&self, k: usize) -> Option<(&'a [f32], &'a [usize])> {
        self.value_f32(self.operand_idx(k)?)
    }

    /// The live s32 buffer (and dims) of the value at instruction `idx` —
    /// the same contract as [`value_f32`](Self::value_f32), for `s32`
    /// values (e.g. a hook serving `convert` from an integer operand).
    pub fn value_s32(&self, idx: usize) -> Option<(&'a [i32], &'a [usize])> {
        let Slot::Single(v) = self.slots.get(idx)? else {
            return None;
        };
        let Buf::S32(buf) = &v.buf else {
            return None;
        };
        if buf.len() != v.shape.elements() {
            return None;
        }
        Some((buf.as_slice(), v.shape.dims.as_slice()))
    }

    /// The s32 buffer (and dims) of the `k`-th operand.
    pub fn operand_s32(&self, k: usize) -> Option<(&'a [i32], &'a [usize])> {
        self.value_s32(self.operand_idx(k)?)
    }

    /// The live pred buffer (and dims) of the value at instruction `idx` —
    /// the same contract as [`value_f32`](Self::value_f32), for `pred`
    /// values.
    pub fn value_pred(&self, idx: usize) -> Option<(&'a [bool], &'a [usize])> {
        let Slot::Single(v) = self.slots.get(idx)? else {
            return None;
        };
        let Buf::Pred(buf) = &v.buf else {
            return None;
        };
        if buf.len() != v.shape.elements() {
            return None;
        }
        Some((buf.as_slice(), v.shape.dims.as_slice()))
    }

    /// The pred buffer (and dims) of the `k`-th operand.
    pub fn operand_pred(&self, k: usize) -> Option<(&'a [bool], &'a [usize])> {
        self.value_pred(self.operand_idx(k)?)
    }

    /// When computation `to_apply` is a plain two-parameter binary fold
    /// body — `root = bin(param0, param1)` exactly, matching the fold
    /// `acc = bin(acc, elem)` the interpreter applies in row-major operand
    /// order — return its operator. `None` for anything more elaborate.
    pub fn reduce_body_kind(&self, to_apply: usize) -> Option<BinKind> {
        let comp = self.module.comps.get(to_apply)?;
        let root = comp.instrs.get(comp.root)?;
        let Op::Binary(kind) = root.op else {
            return None;
        };
        let [a, b] = root.operands[..] else {
            return None;
        };
        let scalar_f32 = |i: &Instr| {
            matches!(&i.shape, ShapeDecl::Single(s) if s.ty == ElemType::F32 && s.dims.is_empty())
        };
        if !scalar_f32(root)
            || !matches!(comp.instrs.get(a)?.op, Op::Parameter(0))
            || !matches!(comp.instrs.get(b)?.op, Op::Parameter(1))
        {
            return None;
        }
        Some(kind)
    }
}

// ---------------------------------------------------------------------------
// Index helpers
// ---------------------------------------------------------------------------

/// Row-major element strides for `dims`.
fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Decompose the linear index `i` into `out` using row-major `strides`.
fn decompose(mut i: usize, strides: &[usize], out: &mut [usize]) {
    for (k, &s) in strides.iter().enumerate() {
        out[k] = i / s;
        i %= s;
    }
}

/// `out[multi] = src[src_multi]` where `src_multi[k] = multi[map[k]]` —
/// shared by broadcast (map = broadcast dimensions) and transpose
/// (map = inverse permutation).
fn gather_map<T: Copy>(src: &[T], src_dims: &[usize], map: &[usize], out_dims: &[usize]) -> Vec<T> {
    let out_strides = strides_of(out_dims);
    let src_strides = strides_of(src_dims);
    let n: usize = out_dims.iter().product();
    let mut mi = vec![0usize; out_dims.len()];
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        decompose(i, &out_strides, &mut mi);
        let mut si = 0usize;
        for (k, &m) in map.iter().enumerate() {
            si += mi[m] * src_strides[k];
        }
        out.push(src[si]);
    }
    out
}

/// [`gather_map`] writing into a caller-provided (arena) buffer, which must
/// have exactly `out_dims` elements.
fn gather_map_into<T: Copy>(
    src: &[T],
    src_dims: &[usize],
    map: &[usize],
    out_dims: &[usize],
    out: &mut [T],
) {
    let out_strides = strides_of(out_dims);
    let src_strides = strides_of(src_dims);
    let mut mi = vec![0usize; out_dims.len()];
    for (i, o) in out.iter_mut().enumerate() {
        decompose(i, &out_strides, &mut mi);
        let mut si = 0usize;
        for (k, &m) in map.iter().enumerate() {
            si += mi[m] * src_strides[k];
        }
        *o = src[si];
    }
}

// ---------------------------------------------------------------------------
// Scalar computations (reduce bodies)
// ---------------------------------------------------------------------------

/// A reduce body compiled to a flat op list over an f32 value stack. Only
/// scalar-f32 computations qualify (parameters, constants, unary/binary
/// arithmetic) — which covers every `to_apply` the repo's graphs use.
struct ScalarComp {
    ops: Vec<SOp>,
    root: usize,
}

enum SOp {
    Param(usize),
    Const(f32),
    Bin(BinKind, usize, usize),
    Un(UnaryKind, usize),
}

/// The interpreter's elementwise binary semantics — public so an external
/// [`OpExecutor`] fusing binary chains can reproduce them bit for bit.
pub fn bin_f32(kind: BinKind, a: f32, b: f32) -> f32 {
    match kind {
        BinKind::Add => a + b,
        BinKind::Sub => a - b,
        BinKind::Mul => a * b,
        BinKind::Div => a / b,
        BinKind::Max => a.max(b),
    }
}

/// The interpreter's elementwise unary semantics — public (like
/// [`bin_f32`]) so an external [`OpExecutor`] can reproduce them bit for
/// bit.
pub fn un_f32(kind: UnaryKind, a: f32) -> f32 {
    match kind {
        UnaryKind::Neg => -a,
        UnaryKind::Exp => a.exp(),
        UnaryKind::Log => a.ln(),
    }
}

impl ScalarComp {
    fn compile(comp: &Computation) -> Result<ScalarComp> {
        if comp.params.len() != 2 {
            return Err(err(format!(
                "reduce body %{} must take exactly 2 parameters",
                comp.name
            )));
        }
        let mut ops = Vec::with_capacity(comp.instrs.len());
        for ins in &comp.instrs {
            let scalar_f32 = matches!(&ins.shape, ShapeDecl::Single(s) if s.ty == ElemType::F32 && s.dims.is_empty());
            if !scalar_f32 {
                return Err(err(format!(
                    "reduce body %{} must be scalar f32 throughout",
                    comp.name
                )));
            }
            let op = match &ins.op {
                Op::Parameter(k) => {
                    if *k >= 2 {
                        return Err(err("reduce body parameter out of range"));
                    }
                    SOp::Param(*k)
                }
                Op::ConstantF32(v) => SOp::Const(*v),
                Op::Binary(kind) => match ins.operands.as_slice() {
                    &[a, b] => SOp::Bin(*kind, a, b),
                    _ => return Err(err("binary op needs 2 operands")),
                },
                Op::Unary(kind) => match ins.operands.as_slice() {
                    &[a] => SOp::Un(*kind, a),
                    _ => return Err(err("unary op needs 1 operand")),
                },
                _ => {
                    return Err(err(format!(
                        "reduce body %{} may only use scalar arithmetic",
                        comp.name
                    )))
                }
            };
            ops.push(op);
        }
        Ok(ScalarComp { ops, root: comp.root })
    }

    /// Apply to `(acc, elem)`; `stack` is reused scratch.
    fn eval(&self, acc: f32, elem: f32, stack: &mut Vec<f32>) -> f32 {
        stack.clear();
        for op in &self.ops {
            let v = match *op {
                SOp::Param(0) => acc,
                SOp::Param(_) => elem,
                SOp::Const(c) => c,
                SOp::Bin(kind, a, b) => bin_f32(kind, stack[a], stack[b]),
                SOp::Un(kind, a) => un_f32(kind, stack[a]),
            };
            stack.push(v);
        }
        stack[self.root]
    }
}

// ---------------------------------------------------------------------------
// Shape inference / validation
// ---------------------------------------------------------------------------

fn single_shape(decl: &ShapeDecl) -> Result<&Shape> {
    match decl {
        ShapeDecl::Single(s) => Ok(s),
        ShapeDecl::Tuple(_) => Err(err("tuple shape where an array was required")),
    }
}

fn checked_elements(dims: &[usize]) -> Result<usize> {
    let mut n: usize = 1;
    for &d in dims {
        n = n
            .checked_mul(d)
            .filter(|&n| n <= MAX_ELEMENTS)
            .ok_or_else(|| err("inferred shape exceeds the element bound"))?;
    }
    Ok(n)
}

/// Output spatial extent of one convolution window dimension.
fn conv_out_dim(input: usize, pad_lo: usize, pad_hi: usize, k: usize, stride: usize) -> Result<usize> {
    let padded = input + pad_lo + pad_hi;
    if padded < k {
        return Err(err(format!(
            "convolution window {k} larger than padded input {padded}"
        )));
    }
    Ok((padded - k) / stride + 1)
}

struct ConvDims {
    batch: usize,
    cin: usize,
    h: usize,
    w: usize,
    kout: usize,
    kh: usize,
    kw: usize,
    oh: usize,
    ow: usize,
}

fn conv_dims(window: &Window, spec: &ConvSpec, lhs: &Shape, rhs: &Shape) -> Result<ConvDims> {
    if lhs.rank() != 4 || rhs.rank() != 4 {
        return Err(err("convolution operands must be rank 4"));
    }
    if lhs.ty != ElemType::F32 || rhs.ty != ElemType::F32 {
        return Err(err("convolution operands must be f32"));
    }
    let batch = lhs.dims[spec.lhs_b];
    let cin = lhs.dims[spec.lhs_f];
    let h = lhs.dims[spec.lhs_s[0]];
    let w = lhs.dims[spec.lhs_s[1]];
    let kin = rhs.dims[spec.rhs_i];
    let kout = rhs.dims[spec.rhs_o];
    let kh = rhs.dims[spec.rhs_s[0]];
    let kw = rhs.dims[spec.rhs_s[1]];
    if kin != cin {
        return Err(err(format!(
            "convolution feature mismatch: lhs has {cin}, rhs contracts {kin}"
        )));
    }
    if [kh, kw] != window.size {
        return Err(err(format!(
            "window size {:?} does not match kernel spatial dims [{kh}, {kw}]",
            window.size
        )));
    }
    let oh = conv_out_dim(h, window.pad_lo[0], window.pad_hi[0], kh, window.stride[0])?;
    let ow = conv_out_dim(w, window.pad_lo[1], window.pad_hi[1], kw, window.stride[1])?;
    Ok(ConvDims { batch, cin, h, w, kout, kh, kw, oh, ow })
}

/// Infer the result shape of `instr` from its operands' declared shapes.
fn infer_instr(module: &Module, comp: &Computation, instr: &Instr) -> Result<ShapeDecl> {
    let opnd = |i: usize| -> Result<&Shape> {
        let idx = *instr
            .operands
            .get(i)
            .ok_or_else(|| err(format!("%{} is missing operand {i}", instr.name)))?;
        single_shape(&comp.instrs[idx].shape)
    };
    let arity = |n: usize| -> Result<()> {
        if instr.operands.len() == n {
            Ok(())
        } else {
            Err(err(format!(
                "%{} takes {n} operands, got {}",
                instr.name,
                instr.operands.len()
            )))
        }
    };
    let declared = single_shape(&instr.shape);

    let inferred = match &instr.op {
        Op::Parameter(_) => {
            arity(0)?;
            ShapeDecl::Single(declared?.clone())
        }
        Op::ConstantF32(_) => {
            arity(0)?;
            ShapeDecl::Single(Shape::scalar(ElemType::F32))
        }
        Op::ConstantS32(_) => {
            arity(0)?;
            ShapeDecl::Single(Shape::scalar(ElemType::S32))
        }
        Op::Binary(_) => {
            arity(2)?;
            let (a, b) = (opnd(0)?, opnd(1)?);
            if a != b || a.ty != ElemType::F32 {
                return Err(err(format!("%{}: binary ops need matching f32 shapes", instr.name)));
            }
            ShapeDecl::Single(a.clone())
        }
        Op::Unary(_) => {
            arity(1)?;
            let a = opnd(0)?;
            if a.ty != ElemType::F32 {
                return Err(err(format!("%{}: unary ops need f32", instr.name)));
            }
            ShapeDecl::Single(a.clone())
        }
        Op::Compare(_) => {
            arity(2)?;
            let (a, b) = (opnd(0)?, opnd(1)?);
            if a != b || a.ty == ElemType::Pred {
                return Err(err(format!(
                    "%{}: compare needs matching f32/s32 shapes",
                    instr.name
                )));
            }
            ShapeDecl::Single(Shape { ty: ElemType::Pred, dims: a.dims.clone() })
        }
        Op::Select => {
            arity(3)?;
            let (p, t, f) = (opnd(0)?, opnd(1)?, opnd(2)?);
            if p.ty != ElemType::Pred || p.dims != t.dims || t != f {
                return Err(err(format!(
                    "%{}: select needs pred + two matching operands",
                    instr.name
                )));
            }
            ShapeDecl::Single(t.clone())
        }
        Op::Convert => {
            arity(1)?;
            let a = opnd(0)?;
            let to = declared?.ty;
            if to == ElemType::Pred {
                return Err(err(format!("%{}: convert to pred is unsupported", instr.name)));
            }
            ShapeDecl::Single(Shape { ty: to, dims: a.dims.clone() })
        }
        Op::Iota { dim } => {
            arity(0)?;
            let d = declared?;
            if d.ty != ElemType::S32 {
                return Err(err(format!("%{}: iota must be s32", instr.name)));
            }
            if *dim >= d.rank() {
                return Err(err(format!("%{}: iota dimension out of range", instr.name)));
            }
            ShapeDecl::Single(d.clone())
        }
        Op::Broadcast { dims } => {
            arity(1)?;
            let a = opnd(0)?;
            let d = declared?;
            if dims.len() != a.rank() {
                return Err(err(format!(
                    "%{}: broadcast dimensions must map every operand dim",
                    instr.name
                )));
            }
            let mut prev: Option<usize> = None;
            for (k, &m) in dims.iter().enumerate() {
                if m >= d.rank() {
                    return Err(err(format!("%{}: broadcast dim {m} out of range", instr.name)));
                }
                if prev.is_some_and(|p| m <= p) {
                    return Err(err(format!(
                        "%{}: broadcast dimensions must be increasing",
                        instr.name
                    )));
                }
                prev = Some(m);
                if d.dims[m] != a.dims[k] {
                    return Err(err(format!(
                        "%{}: broadcast dim {k} size mismatch",
                        instr.name
                    )));
                }
            }
            ShapeDecl::Single(Shape { ty: a.ty, dims: d.dims.clone() })
        }
        Op::Reshape => {
            arity(1)?;
            let a = opnd(0)?;
            let d = declared?;
            if checked_elements(&d.dims)? != a.elements() {
                return Err(err(format!("%{}: reshape changes element count", instr.name)));
            }
            ShapeDecl::Single(Shape { ty: a.ty, dims: d.dims.clone() })
        }
        Op::Transpose { perm } => {
            arity(1)?;
            let a = opnd(0)?;
            if perm.len() != a.rank() {
                return Err(err(format!("%{}: transpose permutation rank mismatch", instr.name)));
            }
            let mut seen = vec![false; a.rank()];
            let mut dims = Vec::with_capacity(a.rank());
            for &p in perm {
                if p >= a.rank() || seen[p] {
                    return Err(err(format!("%{}: bad transpose permutation", instr.name)));
                }
                seen[p] = true;
                dims.push(a.dims[p]);
            }
            ShapeDecl::Single(Shape { ty: a.ty, dims })
        }
        Op::Reverse { dims } => {
            arity(1)?;
            let a = opnd(0)?;
            let mut seen = vec![false; a.rank()];
            for &d in dims {
                if d >= a.rank() || seen[d] {
                    return Err(err(format!("%{}: bad reverse dimensions", instr.name)));
                }
                seen[d] = true;
            }
            ShapeDecl::Single(a.clone())
        }
        Op::Reduce { dims, to_apply } => {
            arity(2)?;
            let a = opnd(0)?;
            let init = opnd(1)?;
            if a.ty != ElemType::F32 || init.ty != ElemType::F32 || init.rank() != 0 {
                return Err(err(format!(
                    "%{}: reduce needs an f32 operand and a scalar f32 init",
                    instr.name
                )));
            }
            let mut reduced = vec![false; a.rank()];
            for &d in dims {
                if d >= a.rank() || reduced[d] {
                    return Err(err(format!("%{}: bad reduce dimensions", instr.name)));
                }
                reduced[d] = true;
            }
            let body = module
                .comps
                .get(*to_apply)
                .ok_or_else(|| err(format!("%{}: to_apply out of range", instr.name)))?;
            ScalarComp::compile(body)?;
            let dims_out: Vec<usize> = a
                .dims
                .iter()
                .zip(&reduced)
                .filter(|(_, &r)| !r)
                .map(|(&d, _)| d)
                .collect();
            ShapeDecl::Single(Shape { ty: ElemType::F32, dims: dims_out })
        }
        Op::Dot { lhs_c, rhs_c } => {
            arity(2)?;
            let (a, b) = (opnd(0)?, opnd(1)?);
            if a.ty != ElemType::F32 || b.ty != ElemType::F32 {
                return Err(err(format!("%{}: dot needs f32 operands", instr.name)));
            }
            if a.rank() == 0 || a.rank() > 2 || b.rank() == 0 || b.rank() > 2 {
                return Err(err(format!("%{}: dot supports rank 1-2 operands", instr.name)));
            }
            if *lhs_c >= a.rank() || *rhs_c >= b.rank() {
                return Err(err(format!("%{}: contracting dim out of range", instr.name)));
            }
            if a.dims[*lhs_c] != b.dims[*rhs_c] {
                return Err(err(format!("%{}: contracting dim size mismatch", instr.name)));
            }
            let mut dims = Vec::new();
            for (d, &v) in a.dims.iter().enumerate() {
                if d != *lhs_c {
                    dims.push(v);
                }
            }
            for (d, &v) in b.dims.iter().enumerate() {
                if d != *rhs_c {
                    dims.push(v);
                }
            }
            checked_elements(&dims)?;
            ShapeDecl::Single(Shape { ty: ElemType::F32, dims })
        }
        Op::Convolution { window, spec } => {
            arity(2)?;
            let cd = conv_dims(window, spec, opnd(0)?, opnd(1)?)?;
            let mut dims = vec![0usize; 4];
            dims[spec.out_b] = cd.batch;
            dims[spec.out_f] = cd.kout;
            dims[spec.out_s[0]] = cd.oh;
            dims[spec.out_s[1]] = cd.ow;
            checked_elements(&dims)?;
            ShapeDecl::Single(Shape { ty: ElemType::F32, dims })
        }
        Op::Tuple => {
            let mut shapes = Vec::with_capacity(instr.operands.len());
            for i in 0..instr.operands.len() {
                shapes.push(opnd(i)?.clone());
            }
            ShapeDecl::Tuple(shapes)
        }
    };
    Ok(inferred)
}

/// Validate the whole module: every instruction's declared shape must match
/// the shape inferred from its operands. Runs at compile time so execution
/// can trust declarations.
pub fn validate(module: &Module) -> Result<()> {
    for comp in &module.comps {
        for instr in &comp.instrs {
            let inferred = infer_instr(module, comp, instr)?;
            if inferred != instr.shape {
                return Err(err(format!(
                    "%{} in %{}: declared shape {:?} but inferred {:?}",
                    instr.name, comp.name, instr.shape, inferred
                )));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Evaluation
// ---------------------------------------------------------------------------

fn eval_compare(dir: CmpDir, a: &Value, b: &Value) -> Result<Buf> {
    fn cmp<T: PartialOrd>(dir: CmpDir, a: &[T], b: &[T]) -> Vec<bool> {
        a.iter()
            .zip(b)
            .map(|(x, y)| match dir {
                CmpDir::Eq => x == y,
                CmpDir::Ne => x != y,
                CmpDir::Lt => x < y,
                CmpDir::Le => x <= y,
                CmpDir::Gt => x > y,
                CmpDir::Ge => x >= y,
            })
            .collect()
    }
    match (&a.buf, &b.buf) {
        (Buf::F32(x), Buf::F32(y)) => Ok(Buf::Pred(cmp(dir, x, y))),
        (Buf::S32(x), Buf::S32(y)) => Ok(Buf::Pred(cmp(dir, x, y))),
        _ => Err(err("compare operand type mismatch")),
    }
}

fn eval_select(p: &Value, t: &Value, f: &Value) -> Result<Buf> {
    let Buf::Pred(pp) = &p.buf else {
        return Err(err("select predicate must be pred"));
    };
    match (&t.buf, &f.buf) {
        (Buf::F32(a), Buf::F32(b)) => Ok(Buf::F32(
            pp.iter().zip(a.iter().zip(b)).map(|(&c, (&x, &y))| if c { x } else { y }).collect(),
        )),
        (Buf::S32(a), Buf::S32(b)) => Ok(Buf::S32(
            pp.iter().zip(a.iter().zip(b)).map(|(&c, (&x, &y))| if c { x } else { y }).collect(),
        )),
        _ => Err(err("select branch type mismatch")),
    }
}

fn eval_convert(src: &Value, to: ElemType) -> Result<Buf> {
    Ok(match (&src.buf, to) {
        (Buf::F32(v), ElemType::F32) => Buf::F32(v.clone()),
        (Buf::S32(v), ElemType::S32) => Buf::S32(v.clone()),
        (Buf::S32(v), ElemType::F32) => Buf::F32(v.iter().map(|&x| x as f32).collect()),
        (Buf::Pred(v), ElemType::F32) => {
            Buf::F32(v.iter().map(|&x| if x { 1.0 } else { 0.0 }).collect())
        }
        (Buf::Pred(v), ElemType::S32) => {
            Buf::S32(v.iter().map(|&x| i32::from(x)).collect())
        }
        (Buf::F32(v), ElemType::S32) => Buf::S32(v.iter().map(|&x| x as i32).collect()),
        _ => return Err(err("unsupported convert")),
    })
}

fn eval_broadcast(src: &Value, map: &[usize], out_dims: &[usize]) -> Buf {
    match &src.buf {
        Buf::F32(v) => Buf::F32(gather_map(v, &src.shape.dims, map, out_dims)),
        Buf::S32(v) => Buf::S32(gather_map(v, &src.shape.dims, map, out_dims)),
        Buf::Pred(v) => Buf::Pred(gather_map(v, &src.shape.dims, map, out_dims)),
    }
}

fn eval_transpose(src: &Value, perm: &[usize], out_dims: &[usize]) -> Buf {
    // gather_map wants `map[src_dim] = out_dim`; transpose declares
    // `out_dim i <- src_dim perm[i]`, so invert the permutation.
    let mut map = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        map[p] = i;
    }
    match &src.buf {
        Buf::F32(v) => Buf::F32(gather_map(v, &src.shape.dims, &map, out_dims)),
        Buf::S32(v) => Buf::S32(gather_map(v, &src.shape.dims, &map, out_dims)),
        Buf::Pred(v) => Buf::Pred(gather_map(v, &src.shape.dims, &map, out_dims)),
    }
}

fn eval_reverse(src: &Value, rev: &[usize]) -> Buf {
    let dims = &src.shape.dims;
    let strides = strides_of(dims);
    let n = src.shape.elements();
    let mut flip = vec![false; dims.len()];
    for &d in rev {
        flip[d] = true;
    }
    let mut mi = vec![0usize; dims.len()];
    let mut idx = Vec::with_capacity(n);
    for i in 0..n {
        decompose(i, &strides, &mut mi);
        let mut si = 0usize;
        for k in 0..dims.len() {
            let v = if flip[k] { dims[k] - 1 - mi[k] } else { mi[k] };
            si += v * strides[k];
        }
        idx.push(si);
    }
    match &src.buf {
        Buf::F32(v) => Buf::F32(idx.iter().map(|&i| v[i]).collect()),
        Buf::S32(v) => Buf::S32(idx.iter().map(|&i| v[i]).collect()),
        Buf::Pred(v) => Buf::Pred(idx.iter().map(|&i| v[i]).collect()),
    }
}

fn eval_iota(dim: usize, dims: &[usize]) -> Buf {
    let strides = strides_of(dims);
    let n: usize = dims.iter().product();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(((i / strides[dim]) % dims[dim]) as i32);
    }
    Buf::S32(out)
}

fn eval_reduce(
    module: &Module,
    src: &Value,
    init: &Value,
    dims: &[usize],
    to_apply: usize,
    arena: &mut Arena,
) -> Result<Buf> {
    let body = ScalarComp::compile(
        module.comps.get(to_apply).ok_or_else(|| err("to_apply out of range"))?,
    )?;
    let init = match &init.buf {
        Buf::F32(v) if v.len() == 1 => v[0],
        _ => return Err(err("reduce init must be a scalar f32")),
    };
    let in_dims = &src.shape.dims;
    let in_strides = strides_of(in_dims);
    let mut reduced = vec![false; in_dims.len()];
    for &d in dims {
        reduced[d] = true;
    }
    let out_dims: Vec<usize> =
        in_dims.iter().zip(&reduced).filter(|(_, &r)| !r).map(|(&d, _)| d).collect();
    let out_strides = strides_of(&out_dims);
    // Per input dim: the stride of its output position (0 when reduced).
    let mut out_stride_by_in = vec![0usize; in_dims.len()];
    let mut kept = 0usize;
    for d in 0..in_dims.len() {
        if !reduced[d] {
            out_stride_by_in[d] = out_strides[kept];
            kept += 1;
        }
    }
    let n: usize = out_dims.iter().product();
    let mut out = arena.take_uninit(n);
    out.fill(init);
    let vals = src.f32s()?;
    let mut mi = vec![0usize; in_dims.len()];
    let mut stack = Vec::new();
    for (i, &v) in vals.iter().enumerate() {
        decompose(i, &in_strides, &mut mi);
        let mut oi = 0usize;
        for d in 0..in_dims.len() {
            oi += mi[d] * out_stride_by_in[d];
        }
        out[oi] = body.eval(out[oi], v, &mut stack);
    }
    Ok(Buf::F32(out))
}

fn eval_dot(
    lhs: &Value,
    rhs: &Value,
    lhs_c: usize,
    rhs_c: usize,
    arena: &mut Arena,
) -> Result<Buf> {
    let (a, b) = (lhs.f32s()?, rhs.f32s()?);
    let (ad, bd) = (&lhs.shape.dims, &rhs.shape.dims);
    let (astr, bstr) = (strides_of(ad), strides_of(bd));
    let lfree: Vec<usize> = (0..ad.len()).filter(|&d| d != lhs_c).collect();
    let rfree: Vec<usize> = (0..bd.len()).filter(|&d| d != rhs_c).collect();
    let m = lfree.first().map_or(1, |&d| ad[d]);
    let ms = lfree.first().map_or(0, |&d| astr[d]);
    let n = rfree.first().map_or(1, |&d| bd[d]);
    let ns = rfree.first().map_or(0, |&d| bstr[d]);
    let k = ad[lhs_c];
    let (ks_a, ks_b) = (astr[lhs_c], bstr[rhs_c]);
    // Every output element is assigned below, so a stale recycled buffer
    // is fully overwritten.
    let mut out = arena.take_uninit(m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += a[i * ms + t * ks_a] * b[j * ns + t * ks_b];
            }
            out[i * n + j] = acc;
        }
    }
    Ok(Buf::F32(out))
}

/// Direct 7-loop convolution over permuted layouts. Contraction order is
/// (feature, ky, kx) with plain multiply-then-add, matching
/// `kernels::reference::conv_fwd` bit-for-bit on `bf01_oi01->bf01`.
fn eval_conv(
    window: &Window,
    spec: &ConvSpec,
    lhs: &Value,
    rhs: &Value,
    out_shape: &Shape,
    arena: &mut Arena,
) -> Result<Buf> {
    let cd = conv_dims(window, spec, &lhs.shape, &rhs.shape)?;
    let lf = lhs.f32s()?;
    let rf = rhs.f32s()?;
    let ls = strides_of(&lhs.shape.dims);
    let rs = strides_of(&rhs.shape.dims);
    let os = strides_of(&out_shape.dims);
    // The (b, o, oy, ox) loops below assign every output element, so a
    // stale recycled buffer is fully overwritten.
    let mut out = arena.take_uninit(out_shape.elements());
    let (sy, sx) = (window.stride[0], window.stride[1]);
    let (ply, plx) = (window.pad_lo[0] as isize, window.pad_lo[1] as isize);
    for b in 0..cd.batch {
        for o in 0..cd.kout {
            for oy in 0..cd.oh {
                for ox in 0..cd.ow {
                    let mut acc = 0.0f32;
                    for ci in 0..cd.cin {
                        let lb = b * ls[spec.lhs_b] + ci * ls[spec.lhs_f];
                        let rb = o * rs[spec.rhs_o] + ci * rs[spec.rhs_i];
                        for ky in 0..cd.kh {
                            let iy = (oy * sy + ky) as isize - ply;
                            if iy < 0 || iy >= cd.h as isize {
                                continue;
                            }
                            let lby = lb + iy as usize * ls[spec.lhs_s[0]];
                            let rby = rb + ky * rs[spec.rhs_s[0]];
                            for kx in 0..cd.kw {
                                let ix = (ox * sx + kx) as isize - plx;
                                if ix < 0 || ix >= cd.w as isize {
                                    continue;
                                }
                                acc += lf[lby + ix as usize * ls[spec.lhs_s[1]]]
                                    * rf[rby + kx * rs[spec.rhs_s[1]]];
                            }
                        }
                    }
                    out[b * os[spec.out_b]
                        + o * os[spec.out_f]
                        + oy * os[spec.out_s[0]]
                        + ox * os[spec.out_s[1]]] = acc;
                }
            }
        }
    }
    Ok(Buf::F32(out))
}

fn eval_instr(
    module: &Module,
    comp: &Computation,
    instr: &Instr,
    slots: &[Slot],
    args: &[Value],
    hook: Option<&OpExecutor>,
    arena: &mut Arena,
) -> Result<Slot> {
    // Bounds-checked even though `validate` enforces arities, so `execute`
    // stays panic-free if ever called on an unvalidated module.
    let opnd = |i: usize| -> Result<&Value> {
        let idx = *instr
            .operands
            .get(i)
            .ok_or_else(|| err(format!("%{} is missing operand {i}", instr.name)))?;
        slots.get(idx).ok_or_else(|| err("operand index out of range"))?.single()
    };

    // Parameter and tuple don't produce a fresh single-array buffer.
    match &instr.op {
        Op::Parameter(k) => {
            let v = args
                .get(*k)
                .ok_or_else(|| err(format!("missing argument for parameter({k})")))?;
            return Ok(Slot::Single(v.clone()));
        }
        Op::Tuple => {
            let mut vals = Vec::with_capacity(instr.operands.len());
            for i in 0..instr.operands.len() {
                vals.push(opnd(i)?.clone());
            }
            return Ok(Slot::Tuple(vals));
        }
        _ => {}
    }

    let declared = single_shape(&instr.shape)?;

    // Consult the external op executor first: any f32 array-producing
    // instruction may be taken over (constants are never worth routing).
    // The hook gets a buffer of exactly the declared element count; `true`
    // means it filled the buffer completely, `false` falls through to the
    // built-in evaluator below.
    if let Some(hook) = hook {
        if declared.ty == ElemType::F32 && !matches!(instr.op, Op::ConstantF32(_)) {
            let call = OpCall { module, comp, instr, slots, out_shape: declared };
            let mut out = arena.take_uninit(declared.elements());
            if hook(&call, &mut out) {
                return Ok(Slot::Single(Value { shape: declared.clone(), buf: Buf::F32(out) }));
            }
            arena.give(out);
        }
    }

    let buf = match &instr.op {
        Op::ConstantF32(v) => Buf::F32(vec![*v]),
        Op::ConstantS32(v) => Buf::S32(vec![*v]),
        Op::Binary(kind) => {
            let (x, y) = (opnd(0)?.f32s()?, opnd(1)?.f32s()?);
            let mut out = arena.take_uninit(x.len());
            for ((o, &u), &v) in out.iter_mut().zip(x).zip(y) {
                *o = bin_f32(*kind, u, v);
            }
            Buf::F32(out)
        }
        Op::Unary(kind) => {
            let x = opnd(0)?.f32s()?;
            let mut out = arena.take_uninit(x.len());
            for (o, &u) in out.iter_mut().zip(x) {
                *o = un_f32(*kind, u);
            }
            Buf::F32(out)
        }
        Op::Compare(dir) => eval_compare(*dir, opnd(0)?, opnd(1)?)?,
        Op::Select => {
            let (p, t, f) = (opnd(0)?, opnd(1)?, opnd(2)?);
            if let (Buf::Pred(pp), Buf::F32(a), Buf::F32(b)) = (&p.buf, &t.buf, &f.buf) {
                let mut out = arena.take_uninit(a.len());
                for (o, ((&c, &x), &y)) in out.iter_mut().zip(pp.iter().zip(a).zip(b)) {
                    *o = if c { x } else { y };
                }
                Buf::F32(out)
            } else {
                eval_select(p, t, f)?
            }
        }
        Op::Convert => {
            let src = opnd(0)?;
            match (&src.buf, declared.ty) {
                (Buf::F32(v), ElemType::F32) => {
                    let mut out = arena.take_uninit(v.len());
                    out.copy_from_slice(v);
                    Buf::F32(out)
                }
                (Buf::S32(v), ElemType::F32) => {
                    let mut out = arena.take_uninit(v.len());
                    for (o, &x) in out.iter_mut().zip(v) {
                        *o = x as f32;
                    }
                    Buf::F32(out)
                }
                (Buf::Pred(v), ElemType::F32) => {
                    let mut out = arena.take_uninit(v.len());
                    for (o, &x) in out.iter_mut().zip(v) {
                        *o = if x { 1.0 } else { 0.0 };
                    }
                    Buf::F32(out)
                }
                _ => eval_convert(src, declared.ty)?,
            }
        }
        Op::Iota { dim } => eval_iota(*dim, &declared.dims),
        Op::Broadcast { dims } => {
            let src = opnd(0)?;
            if let Buf::F32(v) = &src.buf {
                let mut out = arena.take_uninit(declared.elements());
                gather_map_into(v, &src.shape.dims, dims, &declared.dims, &mut out);
                Buf::F32(out)
            } else {
                eval_broadcast(src, dims, &declared.dims)
            }
        }
        Op::Reshape => match &opnd(0)?.buf {
            Buf::F32(v) => {
                let mut out = arena.take_uninit(v.len());
                out.copy_from_slice(v);
                Buf::F32(out)
            }
            Buf::S32(v) => Buf::S32(v.clone()),
            Buf::Pred(v) => Buf::Pred(v.clone()),
        },
        Op::Transpose { perm } => {
            let src = opnd(0)?;
            if let Buf::F32(v) = &src.buf {
                // gather_map wants `map[src_dim] = out_dim`; transpose
                // declares `out_dim i <- src_dim perm[i]`, so invert.
                let mut map = vec![0usize; perm.len()];
                for (i, &p) in perm.iter().enumerate() {
                    map[p] = i;
                }
                let mut out = arena.take_uninit(declared.elements());
                gather_map_into(v, &src.shape.dims, &map, &declared.dims, &mut out);
                Buf::F32(out)
            } else {
                eval_transpose(src, perm, &declared.dims)
            }
        }
        Op::Reverse { dims } => eval_reverse(opnd(0)?, dims),
        Op::Reduce { dims, to_apply } => {
            eval_reduce(module, opnd(0)?, opnd(1)?, dims, *to_apply, arena)?
        }
        Op::Dot { lhs_c, rhs_c } => eval_dot(opnd(0)?, opnd(1)?, *lhs_c, *rhs_c, arena)?,
        Op::Convolution { window, spec } => {
            eval_conv(window, spec, opnd(0)?, opnd(1)?, declared, arena)?
        }
        Op::Parameter(_) | Op::Tuple => return Err(err("unreachable op dispatch")),
    };
    Ok(Slot::Single(Value { shape: declared.clone(), buf }))
}

/// Compute, per instruction index `j`, the list of earlier instructions
/// whose f32 buffers can be retired into the arena once `j` has executed.
///
/// "Last use" is deliberately conservative: an instruction counts as live
/// not only for its direct consumers but for `FUSION_READ_DEPTH` levels of
/// transitive consumers, because a fused op executor may reach *through*
/// its operands (e.g. a fused select reads the compare's operands, and the
/// compare's broadcast operand's scalar). The root is never retired.
fn retire_schedule(comp: &Computation, enabled: bool) -> Vec<Vec<usize>> {
    let n = comp.instrs.len();
    let mut retire_at = vec![Vec::new(); n];
    if !enabled || n == 0 {
        return retire_at;
    }
    // last[i] = highest instruction index that may still read instr i.
    let mut last: Vec<usize> = (0..n).collect();
    for (j, instr) in comp.instrs.iter().enumerate() {
        let mut frontier: Vec<usize> = instr.operands.clone();
        for _ in 0..FUSION_READ_DEPTH {
            let mut next = Vec::new();
            for &o in &frontier {
                last[o] = j;
                next.extend_from_slice(&comp.instrs[o].operands);
            }
            frontier = next;
            if frontier.is_empty() {
                break;
            }
        }
    }
    for (i, &l) in last.iter().enumerate() {
        if i != comp.root {
            retire_at[l].push(i);
        }
    }
    retire_at
}

fn eval_comp(
    module: &Module,
    comp: &Computation,
    args: &[Value],
    hook: Option<&OpExecutor>,
    arena: &mut Arena,
) -> Result<Slot> {
    let retire_at = retire_schedule(comp, arena.enabled());
    let mut slots = Vec::with_capacity(comp.instrs.len());
    for (j, instr) in comp.instrs.iter().enumerate() {
        let slot = eval_instr(module, comp, instr, &slots, args, hook, arena)?;
        slots.push(slot);
        // Recycle buffers whose last (possibly transitive) reader was `j`.
        // The retired slot keeps its shape but loses its data; nothing may
        // read it again, which `OpCall::value_f32` double-checks.
        for &o in &retire_at[j] {
            if let Slot::Single(v) = &mut slots[o] {
                if let Buf::F32(buf) = &mut v.buf {
                    if !buf.is_empty() {
                        arena.give(std::mem::take(buf));
                    }
                }
            }
        }
    }
    Ok(slots.swap_remove(comp.root))
}

/// `reads[j]` = every instruction index within [`FUSION_READ_DEPTH`]
/// operand levels of `j` — the exact set the sequential `retire_schedule`
/// walks, kept per consumer (duplicates included; increments and
/// decrements are symmetric) so the DAG executor can retire a buffer the
/// moment its *last* depth-extended reader completes, in any order.
fn extended_reads(comp: &Computation) -> Vec<Vec<usize>> {
    let mut reads = Vec::with_capacity(comp.instrs.len());
    for instr in &comp.instrs {
        let mut seen = Vec::new();
        let mut frontier: Vec<usize> = instr.operands.clone();
        for _ in 0..FUSION_READ_DEPTH {
            let mut next = Vec::new();
            for &o in &frontier {
                seen.push(o);
                next.extend_from_slice(&comp.instrs[o].operands);
            }
            frontier = next;
            if frontier.is_empty() {
                break;
            }
        }
        reads.push(seen);
    }
    reads
}

/// Dependency-scheduled twin of [`eval_comp`]: walks the computation as a
/// DAG, dispatching the lowest-index ready instruction and — when the
/// planner approves a pair — co-scheduling a second ready instruction
/// through the planner's `join`. See the module docs for the
/// buffer-ownership / read-safety / retirement invariants that make every
/// completion order bit-identical to the sequential walk.
fn eval_comp_dag(
    module: &Module,
    comp: &Computation,
    args: &[Value],
    hook: Option<&OpExecutor>,
    planner: &PipelinePlanner,
    arena: &mut Arena,
    spare: &mut Arena,
) -> Result<Slot> {
    let n = comp.instrs.len();
    let reads = extended_reads(comp);
    let recycling = arena.enabled();

    // readers_left[o] = completions still owed before o's buffer is dead.
    let mut readers_left = vec![0usize; n];
    for r in &reads {
        for &o in r {
            readers_left[o] += 1;
        }
    }
    let mut pending: Vec<usize> = comp.instrs.iter().map(|i| i.operands.len()).collect();
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (j, instr) in comp.instrs.iter().enumerate() {
        for &o in &instr.operands {
            consumers[o].push(j);
        }
    }

    // Placeholder for not-yet-evaluated slots: an empty tuple reads as
    // absent through every OpCall accessor, exactly like a retired buffer
    // — and readiness guarantees no evaluator path ever reads one.
    let mut slots: Vec<Slot> = (0..n).map(|_| Slot::Tuple(Vec::new())).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&j| pending[j] == 0).collect();
    ready.sort_unstable();

    let mut completed = 0usize;
    while completed < n {
        let Some(&a) = ready.first() else {
            // validate() rejects cyclic/malformed graphs, so every stall
            // would be an executor bug; fail loudly rather than hang.
            return Err(err("dependency-scheduled executor stalled (no ready instruction)"));
        };
        ready.remove(0);

        // Try to co-schedule one more ready instruction alongside `a`.
        let partner = ready
            .iter()
            .position(|&b| (planner.overlap)(comp, a, b))
            .map(|pos| ready.remove(pos));

        if let Some(b) = partner {
            let mut out_a: Option<Result<Slot>> = None;
            let mut out_b: Option<Result<Slot>> = None;
            {
                let (oa, ob) = (&mut out_a, &mut out_b);
                let slots_ref: &[Slot] = &slots;
                let arena_a = &mut *arena;
                let arena_b = &mut *spare;
                let task_a: TaskBox<'_> = Box::new(move || {
                    *oa = Some(eval_instr(
                        module,
                        comp,
                        &comp.instrs[a],
                        slots_ref,
                        args,
                        hook,
                        arena_a,
                    ));
                });
                let task_b: TaskBox<'_> = Box::new(move || {
                    *ob = Some(eval_instr(
                        module,
                        comp,
                        &comp.instrs[b],
                        slots_ref,
                        args,
                        hook,
                        arena_b,
                    ));
                });
                (planner.join)(task_a, task_b);
            }
            // `a < b` (a was the queue minimum), so propagating a's error
            // first matches the sequential executor's error choice.
            let ra = out_a.ok_or_else(|| err("pipeline join dropped a task"))?;
            let rb = out_b.ok_or_else(|| err("pipeline join dropped a task"))?;
            slots[a] = ra?;
            slots[b] = rb?;
            for j in [a, b] {
                completed += 1;
                finish_instr(
                    comp,
                    j,
                    &consumers,
                    &reads,
                    &mut pending,
                    &mut ready,
                    &mut readers_left,
                    &mut slots,
                    arena,
                    recycling,
                );
            }
        } else {
            slots[a] = eval_instr(module, comp, &comp.instrs[a], &slots, args, hook, arena)?;
            completed += 1;
            finish_instr(
                comp,
                a,
                &consumers,
                &reads,
                &mut pending,
                &mut ready,
                &mut readers_left,
                &mut slots,
                arena,
                recycling,
            );
        }
    }
    Ok(slots.swap_remove(comp.root))
}

/// Post-completion bookkeeping for one instruction: wake consumers whose
/// last dependency this was, and retire buffers whose last depth-extended
/// reader this was (both arenas' buffers funnel back through the main
/// arena — pool membership is not identity-tracked, only size-keyed).
#[allow(clippy::too_many_arguments)]
fn finish_instr(
    comp: &Computation,
    j: usize,
    consumers: &[Vec<usize>],
    reads: &[Vec<usize>],
    pending: &mut [usize],
    ready: &mut Vec<usize>,
    readers_left: &mut [usize],
    slots: &mut [Slot],
    arena: &mut Arena,
    recycling: bool,
) {
    for &c in &consumers[j] {
        pending[c] -= 1;
        if pending[c] == 0 {
            let pos = ready.binary_search(&c).unwrap_or_else(|p| p);
            ready.insert(pos, c);
        }
    }
    if !recycling {
        return;
    }
    let retire = |o: usize, slots: &mut [Slot], arena: &mut Arena| {
        if o == comp.root {
            return;
        }
        if let Slot::Single(v) = &mut slots[o] {
            if let Buf::F32(buf) = &mut v.buf {
                if !buf.is_empty() {
                    arena.give(std::mem::take(buf));
                }
            }
        }
    };
    // A value nobody (transitively) reads dies with its own completion.
    if readers_left[j] == 0 {
        retire(j, slots, arena);
    }
    for &o in &reads[j] {
        readers_left[o] -= 1;
        if readers_left[o] == 0 {
            retire(o, slots, arena);
        }
    }
}

// ---------------------------------------------------------------------------
// Literal boundary
// ---------------------------------------------------------------------------

fn literal_to_value(lit: &Literal, want: &Shape, which: usize) -> Result<Value> {
    let got_dims: Vec<usize> = lit
        .dims()
        .iter()
        .map(|&d| usize::try_from(d).map_err(|_| err("negative literal dimension")))
        .collect::<Result<_>>()?;
    let buf = match &lit.payload {
        Payload::F32(v) => Buf::F32(v.clone()),
        Payload::I32(v) => Buf::S32(v.clone()),
        Payload::Tuple(_) => return Err(err("tuple literals cannot be passed as inputs")),
    };
    let value = Value { shape: Shape { ty: value_ty(&buf), dims: got_dims }, buf };
    if value.shape != *want {
        return Err(err(format!(
            "argument {which}: expected {}{:?}, got {}{:?}",
            want.ty.name(),
            want.dims,
            value.ty().name(),
            value.shape.dims
        )));
    }
    Ok(value)
}

fn value_ty(buf: &Buf) -> ElemType {
    match buf {
        Buf::F32(_) => ElemType::F32,
        Buf::S32(_) => ElemType::S32,
        Buf::Pred(_) => ElemType::Pred,
    }
}

fn value_to_literal(v: Value) -> Result<Literal> {
    let dims: Vec<i64> = v.shape.dims.iter().map(|&d| d as i64).collect();
    let payload = match v.buf {
        Buf::F32(data) => Payload::F32(data),
        Buf::S32(data) => Payload::I32(data),
        Buf::Pred(_) => return Err(err("pred outputs cannot be returned as literals")),
    };
    Ok(Literal::from_parts(payload, dims))
}

/// Execute the module's `ENTRY` computation with the built-in evaluators
/// only (no external op executor) and a throwaway arena.
pub fn execute(module: &Module, inputs: &[Literal]) -> Result<Literal> {
    execute_with_hook(module, inputs, None)
}

/// Like [`execute_with_hook_in`] with a fresh arena per call (no buffer
/// reuse across calls; reuse still happens within the call).
pub fn execute_with_hook(
    module: &Module,
    inputs: &[Literal],
    hook: Option<&OpExecutor>,
) -> Result<Literal> {
    let mut arena = Arena::new();
    execute_with_hook_in(module, inputs, hook, &mut arena)
}

/// Execute the module's `ENTRY` computation. The module is (re-)validated
/// first — microseconds against milliseconds of evaluation — so this is
/// total even for callers that skipped `compile`; inputs are checked
/// against the declared parameter shapes. The result is the root value (a
/// tuple literal when the root is `tuple(...)`). When `hook` is given,
/// every f32 array-producing instruction consults it before the naive
/// evaluators (see the module docs). `arena` supplies (and receives back)
/// f32 scratch buffers; pass a persistent [`Arena`] to amortize
/// allocations across repeated executions, or [`Arena::disabled`] to force
/// fresh allocation for every op.
pub fn execute_with_hook_in(
    module: &Module,
    inputs: &[Literal],
    hook: Option<&OpExecutor>,
    arena: &mut Arena,
) -> Result<Literal> {
    let mut spare = Arena::new(); // untouched: no planner, no co-scheduling
    execute_pipelined_in(module, inputs, hook, None, arena, &mut spare)
}

/// [`execute_with_hook_in`] plus dependency-scheduled execution: when
/// `planner` is `Some`, the entry computation runs through the DAG
/// executor (see the module docs), with `spare` supplying the second,
/// disjoint buffer arena for the co-scheduled instruction of each overlap
/// window (retired buffers from both funnel back into `arena`). With
/// `planner == None` this is exactly the sequential evaluator. Results
/// are bit-identical either way.
pub fn execute_pipelined_in(
    module: &Module,
    inputs: &[Literal],
    hook: Option<&OpExecutor>,
    planner: Option<&PipelinePlanner>,
    arena: &mut Arena,
    spare: &mut Arena,
) -> Result<Literal> {
    validate(module)?;
    let comp =
        module.comps.get(module.entry).ok_or_else(|| err("entry computation out of range"))?;
    if inputs.len() != comp.params.len() {
        return Err(err(format!(
            "entry takes {} arguments, got {}",
            comp.params.len(),
            inputs.len()
        )));
    }
    let mut args = Vec::with_capacity(inputs.len());
    for (k, lit) in inputs.iter().enumerate() {
        let want = single_shape(&comp.instrs[comp.params[k]].shape)?;
        args.push(literal_to_value(lit, want, k)?);
    }
    let root = match planner {
        Some(p) => eval_comp_dag(module, comp, &args, hook, p, arena, spare)?,
        None => eval_comp(module, comp, &args, hook, arena)?,
    };
    match root {
        Slot::Single(v) => value_to_literal(v),
        Slot::Tuple(vals) => {
            let lits: Vec<Literal> = vals.into_iter().map(value_to_literal).collect::<Result<_>>()?;
            Ok(Literal::tuple(lits))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parse_module;

    fn run(text: &str, inputs: &[Literal]) -> Result<Literal> {
        let module = parse_module(text)?;
        validate(&module)?;
        execute(&module, inputs)
    }

    const ADD: &str = "%add_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %add = f32[] add(%p0, %p1)\n}\n";

    #[test]
    fn miri_dot_golden() {
        // [[1,2,3],[4,5,6]] . [[1,0],[0,1],[1,1]] = [[4,5],[10,11]]
        let text = "HloModule dot\nENTRY %m {\n\
            \x20 %a = f32[2,3] parameter(0)\n\
            \x20 %b = f32[3,2] parameter(1)\n\
            \x20 ROOT %d = f32[2,2] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let a = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        let b = Literal::vec1(&[1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0]).reshape(&[3, 2]).unwrap();
        let out = run(text, &[a, b]).unwrap();
        assert_eq!(out.dims(), &[2, 2]);
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn miri_dot_contracts_leading_dims() {
        // lhs_c=0, rhs_c=0: out[i,j] = sum_t a[t,i] * b[t,j] over f32[3,2]s
        let text = "HloModule dot\nENTRY %m {\n\
            \x20 %a = f32[3,2] parameter(0)\n\
            \x20 %b = f32[3,2] parameter(1)\n\
            \x20 ROOT %d = f32[2,2] dot(%a, %b), lhs_contracting_dims={0}, rhs_contracting_dims={0}\n}\n";
        let a = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[3, 2]).unwrap();
        let b = Literal::vec1(&[1.0f32, 1.0, 2.0, 0.0, 0.0, 3.0]).reshape(&[3, 2]).unwrap();
        let out = run(text, &[a, b]).unwrap();
        // out[0,0]=1+6+0=7  out[0,1]=1+0+15=16  out[1,0]=2+8+0=10  out[1,1]=2+0+18=20
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![7.0, 16.0, 10.0, 20.0]);
    }

    #[test]
    fn miri_reduce_golden_rows_and_all() {
        let text = format!(
            "HloModule r\n{ADD}ENTRY %m {{\n\
             \x20 %x = f32[2,3] parameter(0)\n\
             \x20 %zero = f32[] constant(0)\n\
             \x20 %rows = f32[2] reduce(%x, %zero), dimensions={{1}}, to_apply=%add_f32\n\
             \x20 %cols = f32[3] reduce(%x, %zero), dimensions={{0}}, to_apply=%add_f32\n\
             \x20 %all = f32[] reduce(%x, %zero), dimensions={{0,1}}, to_apply=%add_f32\n\
             \x20 ROOT %out = (f32[2], f32[3], f32[]) tuple(%rows, %cols, %all)\n}}\n"
        );
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 10.0, 20.0, 30.0]).reshape(&[2, 3]).unwrap();
        let parts = run(&text, &[x]).unwrap().to_tuple().unwrap();
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![6.0, 60.0]);
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![11.0, 22.0, 33.0]);
        assert_eq!(parts[2].to_vec::<f32>().unwrap(), vec![66.0]);
    }

    #[test]
    fn miri_elementwise_broadcast_select_convert_iota() {
        let text = "HloModule e\nENTRY %m {\n\
            \x20 %x = f32[2,2] parameter(0)\n\
            \x20 %zero = f32[] constant(0)\n\
            \x20 %zb = f32[2,2] broadcast(%zero), dimensions={}\n\
            \x20 %mask = pred[2,2] compare(%x, %zb), direction=GT\n\
            \x20 %relu = f32[2,2] select(%mask, %x, %zb)\n\
            \x20 %maskf = f32[2,2] convert(%mask)\n\
            \x20 %iot = s32[2,2] iota(), iota_dimension=1\n\
            \x20 %iotf = f32[2,2] convert(%iot)\n\
            \x20 %sum = f32[2,2] add(%relu, %iotf)\n\
            \x20 ROOT %out = (f32[2,2], f32[2,2]) tuple(%sum, %maskf)\n}\n";
        let x = Literal::vec1(&[-1.0f32, 2.0, 3.0, -4.0]).reshape(&[2, 2]).unwrap();
        let parts = run(text, &[x]).unwrap().to_tuple().unwrap();
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![0.0, 3.0, 3.0, 1.0]);
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn miri_transpose_reverse_reshape() {
        let text = "HloModule t\nENTRY %m {\n\
            \x20 %x = f32[2,3] parameter(0)\n\
            \x20 %t = f32[3,2] transpose(%x), dimensions={1,0}\n\
            \x20 %r = f32[3,2] reverse(%t), dimensions={0}\n\
            \x20 ROOT %flat = f32[6] reshape(%r)\n}\n";
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        let out = run(text, &[x]).unwrap();
        // transpose: [[1,4],[2,5],[3,6]]; reverse dim0: [[3,6],[2,5],[1,4]]
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![3.0, 6.0, 2.0, 5.0, 1.0, 4.0]);
    }

    #[test]
    fn miri_conv_identity_and_padding() {
        // 1x1 kernel = identity; 2x2 input, pad 1: corner sums.
        let text = "HloModule c\nENTRY %m {\n\
            \x20 %x = f32[1,1,2,2] parameter(0)\n\
            \x20 %w1 = f32[1,1,1,1] parameter(1)\n\
            \x20 %w3 = f32[1,1,3,3] parameter(2)\n\
            \x20 %id = f32[1,1,2,2] convolution(%x, %w1), window={size=1x1 pad=0_0x0_0}, dim_labels=bf01_oi01->bf01\n\
            \x20 %sm = f32[1,1,2,2] convolution(%x, %w3), window={size=3x3 pad=1_1x1_1}, dim_labels=bf01_oi01->bf01\n\
            \x20 ROOT %out = (f32[1,1,2,2], f32[1,1,2,2]) tuple(%id, %sm)\n}\n";
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[1, 1, 2, 2]).unwrap();
        let w1 = Literal::vec1(&[1.0f32]).reshape(&[1, 1, 1, 1]).unwrap();
        let w3 = Literal::vec1(&[1.0f32; 9]).reshape(&[1, 1, 3, 3]).unwrap();
        let parts = run(text, &[x, w1, w3]).unwrap().to_tuple().unwrap();
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        // all-ones 3x3 with pad 1 over a 2x2 image: every output sees all 4
        assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn miri_validate_rejects_shape_lies() {
        // reduce output keeps the reduced dim
        let bad_reduce = format!(
            "HloModule v\n{ADD}ENTRY %m {{\n  %x = f32[2,3] parameter(0)\n  %z = f32[] constant(0)\n  ROOT %r = f32[2,3] reduce(%x, %z), dimensions={{1}}, to_apply=%add_f32\n}}\n"
        );
        let mut cases: Vec<&str> = vec![
            // declared add shape is wrong
            "HloModule v\nENTRY %m {\n  %x = f32[2] parameter(0)\n  ROOT %y = f32[3] add(%x, %x)\n}\n",
            // convolution output spatial dims are wrong
            "HloModule v\nENTRY %m {\n  %x = f32[1,1,4,4] parameter(0)\n  %w = f32[1,1,3,3] parameter(1)\n  ROOT %y = f32[1,1,4,4] convolution(%x, %w), window={size=3x3 pad=0_0x0_0}, dim_labels=bf01_oi01->bf01\n}\n",
            // dot contracting sizes differ
            "HloModule v\nENTRY %m {\n  %a = f32[2,3] parameter(0)\n  %b = f32[4,2] parameter(1)\n  ROOT %d = f32[2,2] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n",
            // select over mismatched branches
            "HloModule v\nENTRY %m {\n  %a = f32[2] parameter(0)\n  %b = f32[3] parameter(1)\n  %p = pred[2] compare(%a, %a), direction=EQ\n  ROOT %s = f32[2] select(%p, %a, %b)\n}\n",
        ];
        cases.push(bad_reduce.as_str());
        for bad in cases {
            let module = parse_module(bad).expect("these parse");
            assert!(validate(&module).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn miri_execute_checks_argument_shapes() {
        let text = "HloModule a\nENTRY %m {\n  ROOT %x = f32[2,2] parameter(0)\n}\n";
        let module = parse_module(text).unwrap();
        validate(&module).unwrap();
        let wrong = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(execute(&module, &[wrong]).is_err());
        assert!(execute(&module, &[]).is_err());
        let right = Literal::vec1(&[1.0f32; 4]).reshape(&[2, 2]).unwrap();
        assert_eq!(execute(&module, &[right]).unwrap().to_vec::<f32>().unwrap(), vec![1.0; 4]);
    }

    #[test]
    fn miri_op_hook_overrides_declines_and_falls_back() {
        let text = "HloModule h\nENTRY %m {\n\
            \x20 %x = f32[1,1,2,2] parameter(0)\n\
            \x20 %w = f32[1,1,1,1] parameter(1)\n\
            \x20 ROOT %y = f32[1,1,2,2] convolution(%x, %w), window={size=1x1 pad=0_0x0_0}, dim_labels=bf01_oi01->bf01\n}\n";
        let module = parse_module(text).unwrap();
        let x = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[1, 1, 2, 2]).unwrap();
        let w = Literal::vec1(&[2.0f32]).reshape(&[1, 1, 1, 1]).unwrap();
        let inputs = [x, w];

        // A hook that takes the convolution: it fills the provided buffer
        // and that buffer IS the result. Other ops are declined.
        let take: Box<OpExecutor> = Box::new(|call: &OpCall<'_>, out: &mut [f32]| {
            if !matches!(call.op(), Op::Convolution { .. }) {
                return false;
            }
            let (lhs, lhs_dims) = call.operand_f32(0).unwrap();
            assert_eq!(lhs, &[1.0, 2.0, 3.0, 4.0][..]);
            assert_eq!(lhs_dims, &[1, 1, 2, 2][..]);
            assert_eq!(call.operand_f32(1).unwrap().1, &[1, 1, 1, 1][..]);
            assert_eq!(call.out_dims(), &[1, 1, 2, 2][..]);
            assert_eq!(out.len(), call.out_elements());
            out.fill(9.0);
            true
        });
        let out = execute_with_hook(&module, &inputs, Some(&*take)).unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![9.0; 4]);

        // A declining hook falls back to the naive loop, bit-identically.
        let decline: Box<OpExecutor> = Box::new(|_, _| false);
        let naive = execute(&module, &inputs).unwrap();
        let routed = execute_with_hook(&module, &inputs, Some(&*decline)).unwrap();
        assert_eq!(routed.to_vec::<f32>().unwrap(), naive.to_vec::<f32>().unwrap());
        assert_eq!(naive.to_vec::<f32>().unwrap(), vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn miri_arena_reuse_is_bit_identical_to_fresh_alloc() {
        // Exercises every arena-backed evaluator arm that the train step
        // uses (broadcast, compare, select, unary, reduce, broadcast-back,
        // binary, dot, tuple root) and re-runs with a persistent arena so
        // buffers recycled from earlier rounds carry stale contents.
        let text = "HloModule a\n\
            %add_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %add = f32[] add(%p0, %p1)\n}\n\
            ENTRY %m {\n\
            \x20 %x = f32[3,4] parameter(0)\n\
            \x20 %w = f32[4,2] parameter(1)\n\
            \x20 %zero = f32[] constant(0)\n\
            \x20 %zb = f32[3,4] broadcast(%zero), dimensions={}\n\
            \x20 %mask = pred[3,4] compare(%x, %zb), direction=GT\n\
            \x20 %relu = f32[3,4] select(%mask, %x, %zb)\n\
            \x20 %e = f32[3,4] exponential(%relu)\n\
            \x20 %rows = f32[3] reduce(%e, %zero), dimensions={1}, to_apply=%add_f32\n\
            \x20 %rb = f32[3,4] broadcast(%rows), dimensions={0}\n\
            \x20 %nrm = f32[3,4] divide(%e, %rb)\n\
            \x20 %d = f32[3,2] dot(%nrm, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n\
            \x20 ROOT %t = (f32[3,2], f32[3]) tuple(%d, %rows)\n}\n";
        let module = parse_module(text).unwrap();
        let xs: Vec<f32> = (0..12).map(|i| (i as f32) - 5.5).collect();
        let ws: Vec<f32> = (0..8).map(|i| 0.25 * (i as f32) - 1.0).collect();
        let inputs = [
            Literal::vec1(&xs).reshape(&[3, 4]).unwrap(),
            Literal::vec1(&ws).reshape(&[4, 2]).unwrap(),
        ];
        let bits = |lit: &Literal| -> Vec<Vec<u32>> {
            lit.clone()
                .to_tuple()
                .unwrap()
                .iter()
                .map(|e| e.to_vec::<f32>().unwrap().iter().map(|v| v.to_bits()).collect())
                .collect()
        };

        let mut off = Arena::disabled();
        let reference = bits(&execute_with_hook_in(&module, &inputs, None, &mut off).unwrap());

        let mut arena = Arena::new();
        for round in 0..3 {
            let got = execute_with_hook_in(&module, &inputs, None, &mut arena).unwrap();
            assert_eq!(bits(&got), reference, "round {round}");
        }
    }

    /// A toy planner for the DAG-executor smokes: `join` runs the pair on
    /// a real second thread (`std::thread::scope`, Miri-clean), `overlap`
    /// approves every proposed pair and counts them.
    fn scoped_planner(counter: std::sync::Arc<std::sync::atomic::AtomicUsize>) -> PipelinePlanner {
        use std::sync::Arc;
        let join: Arc<crate::JoinFn> = Arc::new(|a: TaskBox<'_>, b: TaskBox<'_>| {
            std::thread::scope(|s| {
                s.spawn(move || b());
                a();
            });
        });
        let overlap: Arc<crate::OverlapFn> = Arc::new(move |_comp, _a, _b| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            true
        });
        PipelinePlanner { join, overlap }
    }

    #[test]
    fn miri_dag_executor_matches_sequential_bit_for_bit() {
        // The widest evaluator graph in this suite (broadcast, compare,
        // select, unary, reduce, dot, tuple root) with a diamond of
        // independent branches, run three rounds against a persistent
        // arena so recycled buffers carry stale contents — the pipelined
        // result must equal the sequential fresh-alloc reference bit for
        // bit, with real co-scheduling happening on a second thread.
        let text = "HloModule a\n\
            %add_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %add = f32[] add(%p0, %p1)\n}\n\
            ENTRY %m {\n\
            \x20 %x = f32[3,4] parameter(0)\n\
            \x20 %w = f32[4,2] parameter(1)\n\
            \x20 %zero = f32[] constant(0)\n\
            \x20 %zb = f32[3,4] broadcast(%zero), dimensions={}\n\
            \x20 %mask = pred[3,4] compare(%x, %zb), direction=GT\n\
            \x20 %relu = f32[3,4] select(%mask, %x, %zb)\n\
            \x20 %e = f32[3,4] exponential(%relu)\n\
            \x20 %sq = f32[3,4] multiply(%x, %x)\n\
            \x20 %rows = f32[3] reduce(%e, %zero), dimensions={1}, to_apply=%add_f32\n\
            \x20 %rb = f32[3,4] broadcast(%rows), dimensions={0}\n\
            \x20 %nrm = f32[3,4] divide(%e, %rb)\n\
            \x20 %d = f32[3,2] dot(%nrm, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n\
            \x20 %g = f32[3,2] dot(%sq, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n\
            \x20 ROOT %t = (f32[3,2], f32[3,2], f32[3]) tuple(%d, %g, %rows)\n}\n";
        let module = parse_module(text).unwrap();
        let xs: Vec<f32> = (0..12).map(|i| (i as f32) - 5.5).collect();
        let ws: Vec<f32> = (0..8).map(|i| 0.25 * (i as f32) - 1.0).collect();
        let inputs = [
            Literal::vec1(&xs).reshape(&[3, 4]).unwrap(),
            Literal::vec1(&ws).reshape(&[4, 2]).unwrap(),
        ];
        let bits = |lit: &Literal| -> Vec<Vec<u32>> {
            lit.clone()
                .to_tuple()
                .unwrap()
                .iter()
                .map(|e| e.to_vec::<f32>().unwrap().iter().map(|v| v.to_bits()).collect())
                .collect()
        };

        let mut off = Arena::disabled();
        let reference = bits(&execute_with_hook_in(&module, &inputs, None, &mut off).unwrap());

        let proposed = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let planner = scoped_planner(proposed.clone());
        let mut arena = Arena::new();
        let mut spare = Arena::new();
        for round in 0..3 {
            let got = execute_pipelined_in(
                &module,
                &inputs,
                None,
                Some(&planner),
                &mut arena,
                &mut spare,
            )
            .unwrap();
            assert_eq!(bits(&got), reference, "round {round}");
        }
        // The graph has independent branches (%sq ‖ the softmax chain),
        // so the planner must actually have been consulted.
        assert!(proposed.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }

    #[test]
    fn miri_dag_without_overlap_or_planner_is_sequential() {
        let text = "HloModule d\nENTRY %m {\n\
            \x20 %x = f32[2,2] parameter(0)\n\
            \x20 %a = f32[2,2] add(%x, %x)\n\
            \x20 %b = f32[2,2] multiply(%x, %x)\n\
            \x20 ROOT %t = (f32[2,2], f32[2,2]) tuple(%a, %b)\n}\n";
        let module = parse_module(text).unwrap();
        let x = Literal::vec1(&[1.0f32, -2.0, 3.0, -4.0]).reshape(&[2, 2]).unwrap();
        let inputs = [x];
        let reference = execute(&module, &inputs).unwrap();

        // A planner that always declines: the ready-queue walk must
        // degrade to exactly the sequential order, never calling join.
        use std::sync::Arc;
        let join: Arc<crate::JoinFn> =
            Arc::new(|_a: TaskBox<'_>, _b: TaskBox<'_>| panic!("join must not be called"));
        let planner = PipelinePlanner { join, overlap: Arc::new(|_, _, _| false) };
        let mut arena = Arena::new();
        let mut spare = Arena::new();
        let got =
            execute_pipelined_in(&module, &inputs, None, Some(&planner), &mut arena, &mut spare)
                .unwrap();
        assert_eq!(
            got.clone().to_tuple().unwrap()[0].to_vec::<f32>().unwrap(),
            reference.clone().to_tuple().unwrap()[0].to_vec::<f32>().unwrap()
        );
        assert_eq!(
            got.to_tuple().unwrap()[1].to_vec::<f32>().unwrap(),
            reference.to_tuple().unwrap()[1].to_vec::<f32>().unwrap()
        );
    }

    #[test]
    fn miri_dag_join_dropping_a_task_is_an_error_not_a_hang() {
        let text = "HloModule d\nENTRY %m {\n\
            \x20 %x = f32[2] parameter(0)\n\
            \x20 %a = f32[2] add(%x, %x)\n\
            \x20 %b = f32[2] multiply(%x, %x)\n\
            \x20 ROOT %t = (f32[2], f32[2]) tuple(%a, %b)\n}\n";
        let module = parse_module(text).unwrap();
        let x = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        use std::sync::Arc;
        // A non-conforming join that runs only one of the two tasks.
        let join: Arc<crate::JoinFn> = Arc::new(|a: TaskBox<'_>, _b: TaskBox<'_>| a());
        let planner = PipelinePlanner { join, overlap: Arc::new(|_, _, _| true) };
        let mut arena = Arena::new();
        let mut spare = Arena::new();
        let e = execute_pipelined_in(&module, &[x], None, Some(&planner), &mut arena, &mut spare)
            .unwrap_err();
        assert!(e.to_string().contains("dropped a task"), "{e}");
    }

    #[test]
    fn miri_log_softmax_subgraph_matches_hand_values() {
        let text = "HloModule s\n\
            %add_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %add = f32[] add(%p0, %p1)\n}\n\
            %max_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %max = f32[] maximum(%p0, %p1)\n}\n\
            ENTRY %m {\n\
            \x20 %logits = f32[2,3] parameter(0)\n\
            \x20 %neg_inf = f32[] constant(-inf)\n\
            \x20 %zero = f32[] constant(0)\n\
            \x20 %mx = f32[2] reduce(%logits, %neg_inf), dimensions={1}, to_apply=%max_f32\n\
            \x20 %mxb = f32[2,3] broadcast(%mx), dimensions={0}\n\
            \x20 %c = f32[2,3] subtract(%logits, %mxb)\n\
            \x20 %e = f32[2,3] exponential(%c)\n\
            \x20 %se = f32[2] reduce(%e, %zero), dimensions={1}, to_apply=%add_f32\n\
            \x20 %ls = f32[2] log(%se)\n\
            \x20 %lsb = f32[2,3] broadcast(%ls), dimensions={0}\n\
            \x20 ROOT %logp = f32[2,3] subtract(%c, %lsb)\n}\n";
        let logits =
            Literal::vec1(&[0.0f32, 0.0, 0.0, 1.0, 2.0, 3.0]).reshape(&[2, 3]).unwrap();
        let out = run(text, &[logits]).unwrap().to_vec::<f32>().unwrap();
        let ln3 = 3.0f64.ln();
        let lse = ((-2.0f64).exp() + (-1.0f64).exp() + 1.0).ln();
        let expect = [-ln3, -ln3, -ln3, -2.0 - lse, -1.0 - lse, -lse];
        for (got, want) in out.iter().zip(expect) {
            assert!((*got as f64 - want).abs() < 1e-6, "{got} vs {want}");
        }
    }
}
