//! Minimal offline substitute for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the surface the repository uses: [`Error`] with a
//! context chain, [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `ensure!` / `bail!` macros. Like the
//! real crate, `{:#}` formatting prints the whole context chain
//! (`context: ...: root cause`) while `{}` prints the outermost message.

use std::fmt;

/// A dynamic error carrying a chain of context messages. The first element
/// is the most recently attached context; the last is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn push_context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, which makes
// this blanket conversion coherent (the same trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (or missing `Option` values).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_formats() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert!(format!("{e:#}").contains("nothing here"));
    }

    #[test]
    fn ensure_and_macros() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {}", x);
            Ok(x)
        }
        assert!(check(5).is_ok());
        assert!(format!("{:#}", check(20).unwrap_err()).contains("x too big: 20"));
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<()> = Err(io_err()).with_context(|| format!("step {}", 3));
        assert_eq!(format!("{:#}", r.unwrap_err()), "step 3: missing file");
    }
}
