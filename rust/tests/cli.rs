//! CLI argument handling: malformed numeric options must be fatal usage
//! errors (exit code 2, `error:` on stderr) on every subcommand — the
//! trainer path used to silently fall back to defaults while the
//! analytics path exited, so a typo like `--steps 2O` trained for 200
//! steps without a word.

use std::process::Command;

fn run(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_sparsetrain"))
        .args(args)
        .output()
        .expect("spawning the sparsetrain binary")
}

fn assert_usage_error(args: &[&str]) {
    let out = run(args);
    assert_eq!(
        out.status.code(),
        Some(2),
        "{args:?} must exit 2, got {:?}\nstderr: {}",
        out.status.code(),
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error:"), "{args:?} stderr missing 'error:': {stderr}");
}

#[test]
fn malformed_train_numeric_options_are_fatal() {
    assert_usage_error(&["train", "--steps", "2O"]); // letter O, the classic typo
    assert_usage_error(&["train", "--seed", "seven"]);
    assert_usage_error(&["train", "--threads", "-1"]);
}

#[test]
fn malformed_analytics_options_are_fatal() {
    assert_usage_error(&["table6", "--epochs", "1e2"]);
    assert_usage_error(&["plan", "--k", "256.0"]);
    assert_usage_error(&["plan", "--r", ""]);
    assert_usage_error(&["sweep", "--threads", "x"]);
}

#[test]
fn unknown_net_and_scale_are_fatal() {
    assert_usage_error(&["train", "--net", "alexnet"]);
    assert_usage_error(&["train", "--net", "resnet34", "--scale", "huge"]);
    assert_usage_error(&["train", "--scale", "small"]); // --scale without --net
}

#[test]
fn malformed_serve_options_are_fatal() {
    assert_usage_error(&["serve", "--rate", "fast"]);
    assert_usage_error(&["serve", "--rate", "-50"]);
    assert_usage_error(&["serve", "--max-batch", "0"]);
    assert_usage_error(&["serve", "--requests", "1O0"]); // letter O again
    assert_usage_error(&["serve", "--scenario", "imagenet"]);
}

#[test]
fn no_subcommand_prints_usage_and_succeeds() {
    let out = run(&[]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("USAGE"), "{stdout}");
    assert!(stdout.contains("--net"), "train help must document --net: {stdout}");
    assert!(stdout.contains("--scale"), "train help must document --scale: {stdout}");
    assert!(stdout.contains("serve"), "help must document the serve subcommand: {stdout}");
    assert!(stdout.contains("--max-batch"), "serve help must document --max-batch: {stdout}");
}
