//! Parity + persistence suite for the measured-cost autotuner (ISSUE 8).
//!
//! The skip modes are mutually bit-identical and chunk count never touches
//! numerics (disjoint owned views), so the measured-cost DB is free to flip
//! modes and retune chunks without changing a single output bit. This suite
//! pins that contract end to end:
//!
//! * **Every selector decision state produces the same bits.** One
//!   in-envelope FWD probe is routed under the kill switch (no DB), a cold
//!   DB (miss → analytic mode + lazy record), and warm DBs rigged so the
//!   measured argmin is Dense, MaskLoop, or bulk-seeded PerLaneBranch.
//!   All five runs must be bit-identical to each other *and* to the serial
//!   sparse kernel, with the hit/miss/update counters proving which path
//!   each router actually took.
//! * **Cold keys warm up in the documented order**: analytic pick first,
//!   then the other branch-free candidate, then measured argmin — exactly
//!   one hit after two misses on a fixed probe.
//! * **The DB survives the filesystem**: save → load round-trips every
//!   entry (EMA within the serialized precision, samples exact); corrupt,
//!   truncated, wrong-schema, and unwritable stores never panic and fall
//!   back to analytic selection bit-identically.
//! * **The new elementwise routes** (`exponential`/`log`/`negate`,
//!   `convert` from f32/s32/pred, and the fused `convert(iota)` index
//!   fill) are bit-identical to the naive evaluator at any thread count,
//!   on both sides of the parallel-launch threshold, and are counted in
//!   [`RouteStats::ew_routed`].

use sparsetrain::coordinator::costdb::{mode_tag, BUCKETS};
use sparsetrain::coordinator::{CostDb, CostKey, DbDecision, Selector};
use sparsetrain::kernels::{sparse_fwd, Component, ConvConfig, KernelStats, SkipMode};
use sparsetrain::runtime::executor::{self, OpRouter};
use sparsetrain::runtime::hlo_builder::conv_module_hlo;
use sparsetrain::runtime::pjrt::literal_f32;
use sparsetrain::sim::Machine;
use sparsetrain::tensor::{ActTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config as PropConfig, UsizeIn};
use sparsetrain::V;
use std::path::PathBuf;
use std::sync::Arc;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Compile + execute one probe module, optionally with a router installed;
/// tuple roots are flattened in order.
fn run_probe(text: &str, inputs: &[xla::Literal], router: Option<Arc<OpRouter>>) -> Vec<Vec<f32>> {
    let mut client = xla::PjRtClient::cpu().unwrap();
    if let Some(r) = router {
        client.set_op_executor(executor::hook(r));
    }
    let proto = xla::HloModuleProto::from_text(text).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let outs = exe.execute::<xla::Literal>(inputs).unwrap();
    let lit = outs[0][0].to_literal_sync().unwrap();
    match lit.clone().to_tuple() {
        Ok(parts) => parts.iter().map(|p| p.to_vec::<f32>().unwrap()).collect(),
        Err(_) => vec![lit.to_vec::<f32>().unwrap()],
    }
}

/// Seed every sparsity bucket of one `(comp, cfg, threads, backend)` key
/// with a fixed EMA for `mode`. The router keys on the *measured* operand
/// sparsity, whose bucket is data-dependent — pricing all eleven buckets
/// makes the rigged DB state hold regardless of where the tensor lands.
fn seed_all_buckets(
    db: &CostDb,
    comp: Component,
    cfg: &ConvConfig,
    threads: usize,
    backend: &str,
    mode: SkipMode,
    ns: f64,
) {
    for b in 0..=BUCKETS {
        db.record(CostKey::conv(comp, cfg, b as f64 / BUCKETS as f64, threads, backend, mode), ns);
    }
}

/// One in-envelope FWD probe: config, module text, literals, and the
/// serial-kernel reference bits (unique across modes by mutual
/// bit-equality).
fn fwd_probe(case: usize, sparsity: f64) -> (ConvConfig, String, Vec<xla::Literal>, Vec<u32>) {
    let hw = 4 + case % 3;
    let c = V;
    let k = V * (1 + case % 2);
    let cfg = ConvConfig::square(2, c, k, hw, 3, 1);
    let mut rng = Xorshift::new(0x800 + case as u64);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, sparsity);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);

    let lhs_dims = [cfg.n, cfg.c, cfg.h, cfg.w];
    let rhs_dims = [cfg.k, cfg.c, cfg.s, cfg.r];
    let out_dims = [cfg.n, cfg.k, cfg.out_h(), cfg.out_w()];
    let text = conv_module_hlo(
        &lhs_dims,
        &rhs_dims,
        &out_dims,
        "{size=3x3 pad=1_1x1_1 stride=1x1}",
        "bf01_oi01->bf01",
    );
    let inputs = vec![
        literal_f32(&d.to_nchw(), &lhs_dims.map(|d| d as i64)).unwrap(),
        literal_f32(&g.to_kcsr(), &rhs_dims.map(|d| d as i64)).unwrap(),
    ];

    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut st = KernelStats::new();
    sparse_fwd::fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
    (cfg, text, inputs, bits(&y.to_nchw()))
}

// ---------------------------------------------------------------------------
// Every selector decision state: same bits, counters prove the path
// ---------------------------------------------------------------------------

#[test]
fn property_routed_fwd_is_bit_identical_across_all_selector_decision_states() {
    if !executor::routing_enabled() {
        return; // conv routing disabled by env: nothing to decide
    }
    let gen = UsizeIn { lo: 0, hi: 7 };
    check(PropConfig { cases: 8, seed: 0x81, max_shrink_steps: 8 }, &gen, |&case| {
        let threads = 1 + case % 3;
        let sparsity = [0.0, 0.5, 0.9][case % 3];
        let (cfg, text, inputs, kernel_bits) = fwd_probe(case, sparsity);

        // Kill-switch state: no DB, pure analytic selection (PR 7 path).
        let analytic = Arc::new(OpRouter::with_cost_db(threads, None));
        let base = run_probe(&text, &inputs, Some(Arc::clone(&analytic)));
        if analytic.routed_calls() != 1 {
            return Err(format!("case {case}: analytic router did not route"));
        }
        if bits(&base[0]) != kernel_bits {
            return Err(format!("case {case}: analytic route not bit-equal to serial kernel"));
        }

        // Cold DB: miss → analytic mode, plus one lazy EMA record.
        let cold = Arc::new(CostDb::in_memory());
        let miss_router = Arc::new(OpRouter::with_cost_db(threads, Some(Arc::clone(&cold))));
        let missed = run_probe(&text, &inputs, Some(Arc::clone(&miss_router)));
        let (h, m, u) = cold.counters();
        if h != 0 || m != 1 || u != 1 || cold.len() != 1 {
            return Err(format!("case {case}: cold DB counters off (h={h} m={m} u={u})"));
        }

        // Warm DBs rigged so each mode in turn is the measured argmin.
        let mut runs = vec![("miss", missed)];
        for (tag, costs) in [
            ("hit-dense", [(SkipMode::Dense, 1e3), (SkipMode::MaskLoop, 9e3)].as_slice()),
            ("hit-mask", [(SkipMode::Dense, 9e3), (SkipMode::MaskLoop, 1e3)].as_slice()),
            (
                "hit-plb",
                [
                    (SkipMode::Dense, 9e3),
                    (SkipMode::MaskLoop, 8e3),
                    (SkipMode::PerLaneBranch, 1e3),
                ]
                .as_slice(),
            ),
        ] {
            let db = Arc::new(CostDb::in_memory());
            let router = Arc::new(OpRouter::with_cost_db(threads, Some(Arc::clone(&db))));
            let bk = sparsetrain::kernels::simd::dispatch().name();
            for &(mode, ns) in costs {
                seed_all_buckets(&db, Component::Fwd, &cfg, router.threads(), bk, mode, ns);
            }
            let seeded = db.len();
            let out = run_probe(&text, &inputs, Some(Arc::clone(&router)));
            if router.routed_calls() != 1 {
                return Err(format!("case {case} {tag}: did not route"));
            }
            let (h, m, _) = db.counters();
            if h != 1 || m != 0 {
                return Err(format!(
                    "case {case} {tag}: expected exactly one DB hit (h={h} m={m}, \
                     {seeded} seeded entries)"
                ));
            }
            runs.push((tag, out));
        }
        for (tag, out) in &runs {
            if bits(&out[0]) != kernel_bits {
                return Err(format!(
                    "case {case} {tag}: selector decision changed the output bits"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Cold → explored → warm on a fixed probe: counters advance deterministically
// ---------------------------------------------------------------------------

#[test]
fn cold_key_warms_in_the_documented_exploration_order() {
    if !executor::routing_enabled() {
        return;
    }
    let (_, text, inputs, kernel_bits) = fwd_probe(0, 0.5);
    let db = Arc::new(CostDb::in_memory());
    let router = Arc::new(OpRouter::with_cost_db(2, Some(Arc::clone(&db))));
    // Run 1: cold (miss, analytic pick recorded). Run 2: the other
    // branch-free candidate (miss, recorded). Run 3: both priced → hit.
    for run in 1..=3 {
        let out = run_probe(&text, &inputs, Some(Arc::clone(&router)));
        assert_eq!(bits(&out[0]), kernel_bits, "run {run} diverged from the serial kernel");
    }
    assert_eq!(router.routed_calls(), 3);
    let (hits, misses, updates) = db.counters();
    assert_eq!(
        (hits, misses, updates),
        (1, 2, 3),
        "exploration must go miss, miss, hit with one record per run"
    );
    // One geometry, one bucket, two lazily-explored modes.
    assert_eq!(db.len(), 2, "exactly Dense and MaskLoop should be priced");
}

// ---------------------------------------------------------------------------
// Selector decision states through the public coordinator API
// ---------------------------------------------------------------------------

#[test]
fn selector_reports_analytic_miss_and_hit_decisions() {
    let cfg = ConvConfig::square(2, V, V, 6, 3, 1);
    let sel = Selector::with_threads(Machine::skylake_x(), 2);
    let (analytic_mode, d) = sel.skip_mode_decision(&cfg, Component::Fwd, 0.9);
    assert_eq!(d, DbDecision::Analytic, "no DB attached must mean Analytic");
    assert_eq!(analytic_mode, sel.skip_mode_analytic(&cfg, Component::Fwd, 0.9));

    let db = Arc::new(CostDb::in_memory());
    let sel = sel.with_cost_db(Some(Arc::clone(&db)));
    let (cold_mode, d) = sel.skip_mode_decision(&cfg, Component::Fwd, 0.9);
    assert_eq!(d, DbDecision::Miss, "cold key must be a Miss");
    assert_eq!(cold_mode, analytic_mode, "cold pick must be the analytic mode");

    let key = |mode| CostKey::conv(Component::Fwd, &cfg, 0.9, sel.threads, sel.backend, mode);
    db.record(key(SkipMode::Dense), 9_000.0);
    db.record(key(SkipMode::MaskLoop), 1_000.0);
    assert_eq!(
        sel.skip_mode_decision(&cfg, Component::Fwd, 0.9),
        (SkipMode::MaskLoop, DbDecision::Hit),
        "warm key must follow the measured argmin"
    );
    // The decision is read-only: re-query sees the same answer.
    assert_eq!(sel.skip_mode(&cfg, Component::Fwd, 0.9), SkipMode::MaskLoop);

    // Swing the EMA until Dense is cheapest: the data overrules the model.
    for _ in 0..40 {
        db.record(key(SkipMode::Dense), 10.0);
    }
    assert_eq!(
        sel.skip_mode_decision(&cfg, Component::Fwd, 0.9),
        (SkipMode::Dense, DbDecision::Hit)
    );
}

// ---------------------------------------------------------------------------
// Persistence: round-trip, Drop autosave, corruption tolerance
// ---------------------------------------------------------------------------

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("sparsetrain-costdb-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn costdb_round_trips_through_the_filesystem() {
    let dir = scratch_dir("roundtrip");
    let file = dir.join("costdb.json");
    let cfg = ConvConfig::square(2, V, 2 * V, 8, 3, 1);

    let db = CostDb::at_path(file.clone(), true);
    assert!(db.is_empty(), "no file yet: the DB must start empty");
    let bk = "avx512";
    db.record(CostKey::conv(Component::Fwd, &cfg, 0.9, 2, bk, SkipMode::MaskLoop), 1234.5);
    db.record(CostKey::conv(Component::Fwd, &cfg, 0.9, 2, bk, SkipMode::MaskLoop), 2000.0);
    db.record(CostKey::conv(Component::Bww, &cfg, 0.0, 4, bk, SkipMode::Dense), 77.25);
    db.record(CostKey::gemm(64, 10, 512, 4, bk), 990.0);
    db.save().unwrap();

    let back = CostDb::at_path(file.clone(), true);
    assert_eq!(back.len(), db.len());
    for key in [
        CostKey::conv(Component::Fwd, &cfg, 0.9, 2, bk, SkipMode::MaskLoop),
        CostKey::conv(Component::Bww, &cfg, 0.0, 4, bk, SkipMode::Dense),
        CostKey::gemm(64, 10, 512, 4, bk),
    ] {
        let a = db.lookup(&key).expect("entry in the source DB");
        let b = back.lookup(&key).expect("entry after reload");
        assert_eq!(a.samples, b.samples, "samples must round-trip exactly");
        // ema_ns is serialized at millinanosecond precision.
        assert!((a.ema_ns - b.ema_ns).abs() <= 5e-4, "ema drifted: {} vs {}", a.ema_ns, b.ema_ns);
    }

    // `=fresh` semantics: same path, load=false ignores the file.
    assert!(CostDb::at_path(file.clone(), false).is_empty());

    // Drop autosave: a dirty DB with a path persists without save().
    let file2 = dir.join("autosave.json");
    {
        let db2 = CostDb::at_path(file2.clone(), true);
        db2.record(CostKey::gemm(8, 8, 8, 1, bk), 50.0);
    }
    assert_eq!(CostDb::at_path(file2, true).len(), 1, "Drop must flush a dirty DB");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_stores_never_panic_and_fall_back_to_analytic_selection() {
    let dir = scratch_dir("corrupt");
    let cfg = ConvConfig::square(2, V, V, 6, 3, 1);
    let bk = "scalar";
    let good_line = {
        let db = CostDb::in_memory();
        db.record(CostKey::conv(Component::Fwd, &cfg, 0.9, 2, bk, SkipMode::MaskLoop), 500.0);
        let json = db.to_json();
        json.lines().find(|l| l.contains("\"component\"")).unwrap().trim_end_matches(',').to_string()
    };

    // Wholesale-rejected stores: wrong/absent schema, garbage, emptiness.
    for (tag, content) in [
        ("empty", String::new()),
        ("garbage", "\u{0}\u{1}definitely not json {{{".to_string()),
        ("no-schema", format!("{{\n  \"entries\": [\n{good_line}\n  ]\n}}\n")),
        (
            "wrong-version",
            format!(
                "{{\n  \"schema\": \"sparsetrain-costdb-v0\",\n  \"entries\": [\n{good_line}\n  ]\n}}\n"
            ),
        ),
    ] {
        let file = dir.join(format!("{tag}.json"));
        std::fs::write(&file, content).unwrap();
        let db = CostDb::at_path(file, true);
        assert!(db.is_empty(), "{tag}: rejected store must load as empty");
        // Empty DB behind the selector = cold key = analytic mode (Miss).
        let sel = Selector::with_threads(Machine::skylake_x(), 2)
            .with_cost_db(Some(Arc::new(db)));
        let (mode, d) = sel.skip_mode_decision(&cfg, Component::Fwd, 0.9);
        assert_eq!(d, DbDecision::Miss, "{tag}");
        assert_eq!(mode, sel.skip_mode_analytic(&cfg, Component::Fwd, 0.9), "{tag}");
    }

    // Line-level tolerance: bad lines are skipped, good lines survive.
    let mixed = format!(
        "{{\n  \"schema\": \"sparsetrain-costdb-v1\",\n  \"entries\": [\n\
         {good_line},\n\
             {{\"component\": \"fwd\", \"geom\": \"truncated-mid-li\n\
             {{\"component\": \"nonsense\", \"geom\": \"x\", \"bucket\": 1, \"threads\": 2, \
         \"backend\": \"t\", \"mode\": \"dense\", \"ema_ns\": 1.0, \"samples\": 1}},\n\
             {{\"component\": \"fwd\", \"geom\": \"x\", \"bucket\": 99, \"threads\": 2, \
         \"backend\": \"t\", \"mode\": \"dense\", \"ema_ns\": NaN, \"samples\": 0}}\n\
           ]\n}}\n"
    );
    let file = dir.join("mixed.json");
    std::fs::write(&file, mixed).unwrap();
    let db = CostDb::at_path(file, true);
    assert_eq!(db.len(), 1, "exactly the one well-formed line must survive");
    let key = CostKey::conv(Component::Fwd, &cfg, 0.9, 2, bk, SkipMode::MaskLoop);
    assert_eq!(db.lookup(&key).map(|e| e.samples), Some(1));
    assert_eq!(mode_tag(SkipMode::MaskLoop), key.mode);

    // Unwritable path: save errors, Drop swallows it — neither panics.
    let orphan = dir.join("no-such-subdir").join("db.json");
    let db = CostDb::at_path(orphan, true);
    db.record(CostKey::gemm(4, 4, 4, 1, bk), 10.0);
    assert!(db.save().is_err(), "saving into a missing directory must error, not panic");
    drop(db); // dirty + failing path: Drop must not panic either

    // And a corrupt store behind a live router is still bit-safe.
    if executor::routing_enabled() {
        let (_, text, inputs, kernel_bits) = fwd_probe(1, 0.5);
        let file = dir.join("behind-router.json");
        std::fs::write(&file, "not a database").unwrap();
        let router = Arc::new(OpRouter::with_cost_db(
            2,
            Some(Arc::new(CostDb::at_path(file, true))),
        ));
        let out = run_probe(&text, &inputs, Some(Arc::clone(&router)));
        assert_eq!(router.routed_calls(), 1);
        assert_eq!(bits(&out[0]), kernel_bits, "corrupt DB changed routed bits");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// New elementwise routes: unary, convert, fused convert(iota)
// ---------------------------------------------------------------------------

/// Every form the new elementwise routes serve: `exponential`, `log`,
/// `negate`, `convert` from pred / s32 / f32, and `convert(iota)` over
/// both dims (the fused index fill).
fn ew_module(n: usize, c: usize) -> String {
    let s = format!("f32[{n},{c}]");
    format!(
        "HloModule ew_probe\n\nENTRY %ew_probe {{\n  \
         %x = {s} parameter(0)\n  \
         %e = {s} exponential(%x)\n  \
         %l = {s} log(%e)\n  \
         %neg = {s} negate(%x)\n  \
         %cc = {s} convert(%e)\n  \
         %zero = f32[] constant(0)\n  \
         %zb = {s} broadcast(%zero), dimensions={{}}\n  \
         %mask = pred[{n},{c}] compare(%x, %zb), direction=GT\n  \
         %mf = {s} convert(%mask)\n  \
         %i0 = s32[{n},{c}] iota(), iota_dimension=0\n  \
         %f0 = {s} convert(%i0)\n  \
         %i1 = s32[{n},{c}] iota(), iota_dimension=1\n  \
         %f1 = {s} convert(%i1)\n  \
         ROOT %t = ({s}, {s}, {s}, {s}, {s}, {s}, {s}) \
         tuple(%e, %l, %neg, %cc, %mf, %f0, %f1)\n}}\n"
    )
}

#[test]
fn routed_unary_convert_and_iota_are_bit_identical_to_naive() {
    // (5, 7) stays under the parallel-launch threshold (serial closure);
    // (64, 80) = 5120 elements crosses it and chunks across workers.
    for (n, c) in [(5usize, 7usize), (64, 80)] {
        let text = ew_module(n, c);
        let mut rng = Xorshift::new(0x88 + n as u64);
        let x: Vec<f32> = (0..n * c).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let inputs = [literal_f32(&x, &[n as i64, c as i64]).unwrap()];
        let naive = run_probe(&text, &inputs, None);
        assert_eq!(naive.len(), 7);
        for threads in [1usize, 2, 3] {
            let router = Arc::new(OpRouter::with_cost_db(threads, None));
            let routed = run_probe(&text, &inputs, Some(Arc::clone(&router)));
            for (i, (a, r)) in naive.iter().zip(&routed).enumerate() {
                assert_eq!(
                    bits(a),
                    bits(r),
                    "{n}x{c} t={threads}: elementwise output {i} not bit-identical"
                );
            }
            if executor::op_routing_enabled() {
                let stats = router.stats();
                // exponential, log, negate, convert x4 (+ the zero-splat
                // broadcast fast path) must all be served, none declined.
                assert!(
                    stats.ew_routed >= 7,
                    "{n}x{c} t={threads}: expected >= 7 routed elementwise ops, got {stats:?}"
                );
                assert_eq!(
                    stats.ew_fallback, 0,
                    "{n}x{c} t={threads}: nothing here should decline: {stats:?}"
                );
            }
        }
    }
}

/// The fused `convert(iota)` path never materializes the s32 operand —
/// its whole contract is "equal to eval_iota then convert". Pin it
/// against a hand-rolled index fill for awkward dims (dim-0, singleton,
/// trailing dim of a rank-3 shape).
#[test]
fn fused_convert_iota_matches_hand_rolled_index_fill() {
    for (dims, dim) in [
        (vec![4usize, 6, 5], 0usize),
        (vec![4, 6, 5], 1),
        (vec![4, 6, 5], 2),
        (vec![1, 9], 0),
        (vec![9, 1], 1),
    ] {
        let total: usize = dims.iter().product();
        let shape = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
        let text = format!(
            "HloModule iota_probe\n\nENTRY %iota_probe {{\n  \
             %i = s32[{shape}] iota(), iota_dimension={dim}\n  \
             ROOT %f = f32[{shape}] convert(%i)\n}}\n"
        );
        let stride: usize = dims[dim + 1..].iter().product();
        let want: Vec<f32> =
            (0..total).map(|i| ((i / stride) % dims[dim]) as i32 as f32).collect();
        let naive = run_probe(&text, &[], None);
        assert_eq!(bits(&naive[0]), bits(&want), "naive iota dims={dims:?} dim={dim}");
        let router = Arc::new(OpRouter::with_cost_db(2, None));
        let routed = run_probe(&text, &[], Some(Arc::clone(&router)));
        assert_eq!(
            bits(&routed[0]),
            bits(&want),
            "routed convert(iota) dims={dims:?} dim={dim}"
        );
        if executor::op_routing_enabled() {
            assert!(router.stats().ew_routed >= 1, "convert(iota) must route");
        }
    }
}
