//! SIMD-vs-scalar backend parity suite.
//!
//! The explicit-SIMD backend (`kernels::simd`) promises that every
//! implementation of the three primitives — AVX-512F, AVX2+FMA, NEON,
//! scalar — computes the *same* arithmetic: a fused multiply-add with one
//! rounding and an IEEE `!= 0.0` compare. These tests pin that promise at
//! the kernel level: for **every `SkipMode`** and a randomized
//! [`ConvGeomGen`] geometry sweep, the dispatched backend must produce
//! **bit-identical outputs and identical `KernelStats`** to the
//! forced-scalar backend on all three training components.
//!
//! On an x86-64 CI runner the dispatched backend is AVX2 (or AVX-512 with
//! `--features avx512`), so this is a real cross-ISA comparison; under
//! `SPARSETRAIN_BACKEND=scalar` (the forced-scalar CI leg) it degenerates
//! to scalar-vs-scalar, which still pins the dispatch plumbing.

use sparsetrain::kernels::simd::{self, Backend};
use sparsetrain::kernels::{
    sparse_bwi, sparse_bww, sparse_fwd, ConvConfig, KernelStats, Scratch, SkipMode,
};
use sparsetrain::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config as PropConfig, ConvGeomGen};

struct Triad {
    y: ActTensor,
    dd: ActTensor,
    dg: FilterTensor,
    st_fwd: KernelStats,
    st_bwi: KernelStats,
    st_bww: KernelStats,
}

/// Run FWD, BWI and BWW serially on one backend with a reusable scratch.
fn run_triad(cfg: &ConvConfig, mode: SkipMode, bk: Backend, seed: u64) -> Triad {
    let mut rng = Xorshift::new(seed);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.55);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_relu_sparse(&mut rng, 0.45);
    for v in dy.data_mut().iter_mut() {
        if *v != 0.0 && rng.bernoulli(0.5) {
            *v = -*v;
        }
    }
    let gt = g.transpose_channels();
    let dt = BatchTiledTensor::from_act(&d);
    let mut scratch = Scratch::new();

    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut st_fwd = KernelStats::new();
    sparse_fwd::fwd_with(cfg, &d, &g, &mut y, mode, bk, &mut scratch, &mut st_fwd);

    let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let mut st_bwi = KernelStats::new();
    sparse_bwi::bwi_with(cfg, &dy, &gt, &mut dd, mode, bk, &mut scratch, &mut st_bwi);

    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    let mut st_bww = KernelStats::new();
    sparse_bww::bww_with(cfg, &dt, &dy, &mut dg, mode, bk, &mut scratch, &mut st_bww);

    Triad { y, dd, dg, st_fwd, st_bwi, st_bww }
}

fn assert_parity(cfg: &ConvConfig, mode: SkipMode, seed: u64) -> Result<(), String> {
    let auto = run_triad(cfg, mode, simd::dispatch(), seed);
    let scalar = run_triad(cfg, mode, Backend::scalar(), seed);
    if auto.y.data() != scalar.y.data() {
        return Err(format!("FWD outputs diverge (mode={mode:?}, cfg={cfg:?})"));
    }
    if auto.st_fwd != scalar.st_fwd {
        return Err(format!("FWD stats diverge (mode={mode:?}, cfg={cfg:?})"));
    }
    if auto.dd.data() != scalar.dd.data() {
        return Err(format!("BWI outputs diverge (mode={mode:?}, cfg={cfg:?})"));
    }
    if auto.st_bwi != scalar.st_bwi {
        return Err(format!("BWI stats diverge (mode={mode:?}, cfg={cfg:?})"));
    }
    if auto.dg.data() != scalar.dg.data() {
        return Err(format!("BWW outputs diverge (mode={mode:?}, cfg={cfg:?})"));
    }
    if auto.st_bww != scalar.st_bww {
        return Err(format!("BWW stats diverge (mode={mode:?}, cfg={cfg:?})"));
    }
    Ok(())
}

/// Every `SkipMode` on a fixed Table-2-derived 3×3 shape.
#[test]
#[cfg_attr(miri, ignore = "dispatched backend is scalar under miri; covered by lib tests")]
fn parity_all_modes_fixed_3x3() {
    let cfg = ConvConfig::square(16, 32, 32, 8, 3, 1);
    println!("dispatched backend: {}", simd::dispatch().name());
    for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
        assert_parity(&cfg, mode, 0xFACE).unwrap();
    }
}

/// Every `SkipMode` on a strided shape and a 1×1 shape.
#[test]
#[cfg_attr(miri, ignore = "dispatched backend is scalar under miri; covered by lib tests")]
fn parity_all_modes_strided_and_1x1() {
    for cfg in [ConvConfig::square(16, 32, 32, 9, 3, 2), ConvConfig::square(16, 64, 32, 6, 1, 1)] {
        for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
            assert_parity(&cfg, mode, 0xB0A7).unwrap();
        }
    }
}

/// Randomized-geometry sweep (odd/even spatial sizes, strides 1–2, filter
/// 1/3/5, extra padding) × every `SkipMode`: the dispatched backend must
/// stay bit-identical to forced scalar everywhere.
#[test]
#[cfg_attr(miri, ignore = "dispatched backend is scalar under miri; covered by lib tests")]
fn parity_over_random_geometry_all_modes() {
    let gen = ConvGeomGen { min_hw: 4, max_hw: 9, max_threads: 1 };
    check(PropConfig { cases: 8, seed: 0x51D0, max_shrink_steps: 12 }, &gen, |g| {
        let mut cfg = ConvConfig::square(16, 16, 32, g.hw, g.rs, g.stride);
        cfg.pad_h += g.extra_pad;
        cfg.pad_w += g.extra_pad;
        if cfg.validate().is_err() {
            return Ok(());
        }
        for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
            assert_parity(&cfg, mode, 0xD1CE + g.hw as u64)?;
        }
        Ok(())
    });
}
