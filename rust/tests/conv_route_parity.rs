//! Parity suite for the kernel-routed convolution executor (ISSUE 5).
//!
//! Drives the mini-HLO interpreter twice over single-convolution probe
//! modules — once naive (no hook) and once with the SparseTrain
//! [`OpRouter`] installed — across randomized geometries, `dim_labels`
//! and paddings, and pins the routing contract:
//!
//! * **In-envelope** calls must actually route (counter-checked), be
//!   **bit-identical to the serial sparse kernel** at the same packing
//!   (the scheduler's serial-parity + the skip modes' mutual bit-equality
//!   make the kernel stack's answer unique), and agree with the naive
//!   evaluator within tight reassociation tolerance — the kernels sum the
//!   same products in row-sweep order with fused multiply-adds, so exact
//!   bit-equality with the naive (feature, ky, kx) multiply-then-add loop
//!   is not a meaningful target, but anything beyond last-bits is a bug.
//! * **Out-of-envelope** calls (channels not multiples of V, strided
//!   backward labels, asymmetric padding, exotic label permutations) must
//!   fall back to the naive loop **bit-identically** — the fallback IS the
//!   reference evaluator.
//! * The full `train_step` graph at the paper geometry routes all five
//!   convolutions and matches the naive run within tolerance end to end.

use sparsetrain::kernels::{reference, sparse_bwi, sparse_bww, sparse_fwd};
use sparsetrain::kernels::{ConvConfig, KernelStats, SkipMode};
use sparsetrain::runtime::executor::{self, OpRouter};
use sparsetrain::runtime::hlo_builder::{self, conv_module_hlo, Geometry};
use sparsetrain::runtime::pjrt::{literal_f32, literal_i32, Runtime};
use sparsetrain::tensor::{allclose, ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config as PropConfig, UsizeIn};
use sparsetrain::V;
use std::sync::Arc;

/// Compile + execute one probe module, optionally with a router installed.
fn run_probe(text: &str, inputs: &[xla::Literal], router: Option<Arc<OpRouter>>) -> Vec<f32> {
    let mut client = xla::PjRtClient::cpu().unwrap();
    if let Some(r) = router {
        client.set_op_executor(executor::hook(r));
    }
    let proto = xla::HloModuleProto::from_text(text).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let outs = exe.execute::<xla::Literal>(inputs).unwrap();
    outs[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap()
}

fn window_text(s: usize, r: usize, pad: usize, stride: usize) -> String {
    format!("{{size={s}x{r} pad={pad}_{pad}x{pad}_{pad} stride={stride}x{stride}}}")
}

/// Both runs of one probe: (naive, routed, routed-call count).
fn probe_pair(
    text: &str,
    inputs: &[xla::Literal],
    threads: usize,
) -> (Vec<f32>, Vec<f32>, usize) {
    let naive = run_probe(text, inputs, None);
    let router = Arc::new(OpRouter::new(threads));
    let routed = run_probe(text, inputs, Some(Arc::clone(&router)));
    (naive, routed, router.routed_calls())
}

// ---------------------------------------------------------------------------
// FWD form: bf01_oi01->bf01
// ---------------------------------------------------------------------------

#[test]
fn property_routed_fwd_matches_naive_and_is_bitexact_vs_serial_kernel() {
    let gen = UsizeIn { lo: 0, hi: 11 };
    check(PropConfig { cases: 12, seed: 0x51, max_shrink_steps: 16 }, &gen, |&case| {
        let hw = 4 + case % 4; // 4..=7
        let stride = 1 + case % 2;
        let c = V * (1 + case % 2);
        let k = V * (1 + (case / 2) % 2);
        let threads = 1 + case % 3;
        let sparsity = [0.0, 0.5, 0.9][case % 3];
        let cfg = ConvConfig::square(2, c, k, hw, 3, stride);
        if cfg.validate().is_err() {
            return Ok(());
        }

        let mut rng = Xorshift::new(100 + case as u64);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, sparsity);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let (lhs, rhs) = (d.to_nchw(), g.to_kcsr());

        let lhs_dims = [cfg.n, cfg.c, cfg.h, cfg.w];
        let rhs_dims = [cfg.k, cfg.c, cfg.s, cfg.r];
        let out_dims = [cfg.n, cfg.k, cfg.out_h(), cfg.out_w()];
        let text = conv_module_hlo(
            &lhs_dims,
            &rhs_dims,
            &out_dims,
            &window_text(3, 3, 1, stride),
            "bf01_oi01->bf01",
        );
        let inputs = [
            literal_f32(&lhs, &lhs_dims.map(|d| d as i64)).unwrap(),
            literal_f32(&rhs, &rhs_dims.map(|d| d as i64)).unwrap(),
        ];
        let (naive, routed, routed_calls) = probe_pair(&text, &inputs, threads);
        if routed_calls != 1 {
            return Err(format!("in-envelope FWD case {case} did not route"));
        }
        if !allclose(&routed, &naive, 1e-4, 1e-4) {
            return Err(format!("FWD case {case}: routed vs naive diverged"));
        }
        // Bit-exact against the serial sparse kernel (any mode: the skip
        // modes are mutually bit-identical).
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        sparse_fwd::fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
        if routed != y.to_nchw() {
            return Err(format!("FWD case {case}: routed vs serial kernel not bit-equal"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// BWI form: reversed filter + bf01_io01->bf01
// ---------------------------------------------------------------------------

#[test]
fn property_routed_bwi_matches_naive_and_is_bitexact_vs_serial_kernel() {
    let gen = UsizeIn { lo: 0, hi: 7 };
    check(PropConfig { cases: 8, seed: 0x52, max_shrink_steps: 16 }, &gen, |&case| {
        let hw = 4 + case % 4;
        let c = V * (1 + case % 2); // forward input channels
        let k = V; // forward output channels (= contracted dim)
        let threads = 1 + case % 3;
        let cfg = ConvConfig::square(2, c, k, hw, 3, 1);

        let mut rng = Xorshift::new(200 + case as u64);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_relu_sparse(&mut rng, 0.5);
        for v in dy.data_mut().iter_mut() {
            if *v != 0.0 && rng.bernoulli(0.5) {
                *v = -*v;
            }
        }
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);

        // rhs = spatially reversed forward filter, [K][C][S][R] with `io`
        // labels — exactly what the train-step graph's %w_r feeds the
        // input-gradient convolution.
        let mut rhs = vec![0.0f32; cfg.k * cfg.c * cfg.s * cfg.r];
        for ki in 0..cfg.k {
            for ci in 0..cfg.c {
                for s in 0..cfg.s {
                    for r in 0..cfg.r {
                        rhs[((ki * cfg.c + ci) * cfg.s + s) * cfg.r + r] =
                            g.get(ki, ci, cfg.s - 1 - s, cfg.r - 1 - r);
                    }
                }
            }
        }
        let lhs = dy.to_nchw();
        let lhs_dims = [cfg.n, cfg.k, cfg.out_h(), cfg.out_w()];
        let rhs_dims = [cfg.k, cfg.c, cfg.s, cfg.r];
        let out_dims = [cfg.n, cfg.c, cfg.h, cfg.w];
        let text = conv_module_hlo(
            &lhs_dims,
            &rhs_dims,
            &out_dims,
            &window_text(3, 3, 1, 1),
            "bf01_io01->bf01",
        );
        let inputs = [
            literal_f32(&lhs, &lhs_dims.map(|d| d as i64)).unwrap(),
            literal_f32(&rhs, &rhs_dims.map(|d| d as i64)).unwrap(),
        ];
        let (naive, routed, routed_calls) = probe_pair(&text, &inputs, threads);
        if routed_calls != 1 {
            return Err(format!("in-envelope BWI case {case} did not route"));
        }
        if !allclose(&routed, &naive, 1e-4, 1e-4) {
            return Err(format!("BWI case {case}: routed vs naive diverged"));
        }
        // Bit-exact vs the serial BWI kernel over the equivalent packing.
        let gt = g.transpose_channels();
        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st = KernelStats::new();
        sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop, &mut st);
        if routed != dd.to_nchw() {
            return Err(format!("BWI case {case}: routed vs serial kernel not bit-equal"));
        }
        // ... and sane against the scalar reference.
        let ddref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
        if !allclose(&routed, &ddref, 1e-4, 1e-4) {
            return Err(format!("BWI case {case}: routed vs reference diverged"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// BWW form: batch-contracting fb01_io01->bf01
// ---------------------------------------------------------------------------

#[test]
fn property_routed_bww_matches_naive_and_is_bitexact_vs_serial_kernel() {
    let gen = UsizeIn { lo: 0, hi: 5 };
    check(PropConfig { cases: 6, seed: 0x53, max_shrink_steps: 16 }, &gen, |&case| {
        let hw = 4 + case % 3;
        let c = V;
        let k = V * (1 + case % 2);
        let threads = 1 + case % 3;
        let cfg = ConvConfig::square(V, c, k, hw, 3, 1); // n = V for BWW

        let mut rng = Xorshift::new(300 + case as u64);
        let mut x = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        x.fill_relu_sparse(&mut rng, 0.5);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_uniform(&mut rng, -1.0, 1.0);

        let lhs = x.to_nchw();
        let rhs = dy.to_nchw();
        let lhs_dims = [cfg.n, cfg.c, cfg.h, cfg.w];
        let rhs_dims = [cfg.n, cfg.k, cfg.out_h(), cfg.out_w()];
        let out_dims = [cfg.c, cfg.k, cfg.s, cfg.r];
        let text = conv_module_hlo(
            &lhs_dims,
            &rhs_dims,
            &out_dims,
            &window_text(cfg.out_h(), cfg.out_w(), 1, 1),
            "fb01_io01->bf01",
        );
        let inputs = [
            literal_f32(&lhs, &lhs_dims.map(|d| d as i64)).unwrap(),
            literal_f32(&rhs, &rhs_dims.map(|d| d as i64)).unwrap(),
        ];
        let (naive, routed, routed_calls) = probe_pair(&text, &inputs, threads);
        if routed_calls != 1 {
            return Err(format!("in-envelope BWW case {case} did not route"));
        }
        if !allclose(&routed, &naive, 1e-3, 1e-4) {
            return Err(format!("BWW case {case}: routed vs naive diverged"));
        }
        // Bit-exact vs the serial BWW kernel, transposed to [C,K,S,R].
        let dt = BatchTiledTensor::from_act(&x);
        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut st = KernelStats::new();
        sparse_bww::bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop, &mut st);
        let mut want = vec![0.0f32; routed.len()];
        for ci in 0..cfg.c {
            for ki in 0..cfg.k {
                for s in 0..cfg.s {
                    for r in 0..cfg.r {
                        want[((ci * cfg.k + ki) * cfg.s + s) * cfg.r + r] = dg.get(ki, ci, s, r);
                    }
                }
            }
        }
        if routed != want {
            return Err(format!("BWW case {case}: routed vs serial kernel not bit-equal"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Fallback: out-of-envelope configs must be bit-identical to the naive loop
// ---------------------------------------------------------------------------

#[test]
fn property_unsupported_configs_fall_back_bit_identically() {
    // Each class deliberately breaks one envelope condition; shapes stay
    // consistent with the interpreter's shape inference so the module
    // compiles and the *router* is what declines.
    let gen = UsizeIn { lo: 0, hi: 19 };
    check(PropConfig { cases: 20, seed: 0x54, max_shrink_steps: 8 }, &gen, |&case| {
        let mut rng = Xorshift::new(400 + case as u64);
        let hw = 4 + case % 3;
        let (s, r) = (3usize, 3usize);
        // (lhs_dims, rhs_dims, out_batch, out_feat, labels, stride, pad)
        let (lhs_dims, rhs_dims, ob, of, labels, stride, pad) = match case % 5 {
            // channels not multiples of V
            0 => {
                let c = 3 + case % 4;
                ([2, c, hw, hw], [8, c, s, r], 2, 8, "bf01_oi01->bf01", 1, 1)
            }
            // K below the V tile
            1 => ([2, V, hw, hw], [8, V, s, r], 2, 8, "bf01_oi01->bf01", 1, 1),
            // strided backward labels (needs dilation → must decline)
            2 => ([2, V, hw, hw], [V, V, s, r], 2, V, "bf01_io01->bf01", 2, 1),
            // label permutation outside the canonical three: fb lhs with an
            // oi filter — contracted dim is lhs dim0
            3 => ([V, 2, hw, hw], [8, V, s, r], 2, 8, "fb01_oi01->bf01", 1, 1),
            // oversized pad for the BWI pad identity (pad > S-1)
            _ => ([2, V, hw, hw], [V, V, s, r], 2, V, "bf01_io01->bf01", 1, 3),
        };
        let padded = hw + 2 * pad;
        if padded < s {
            return Ok(());
        }
        let oh = (padded - s) / stride + 1;
        let out_dims = [ob, of, oh, oh];
        let text = conv_module_hlo(
            &lhs_dims,
            &rhs_dims,
            &out_dims,
            &window_text(s, r, pad, stride),
            labels,
        );
        let n_lhs: usize = lhs_dims.iter().product();
        let n_rhs: usize = rhs_dims.iter().product();
        let lhs: Vec<f32> = (0..n_lhs).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let rhs: Vec<f32> = (0..n_rhs).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let inputs = [
            literal_f32(&lhs, &lhs_dims.map(|d| d as i64)).unwrap(),
            literal_f32(&rhs, &rhs_dims.map(|d| d as i64)).unwrap(),
        ];
        let (naive, routed, routed_calls) = probe_pair(&text, &inputs, 2);
        if routed_calls != 0 {
            return Err(format!("case {case} ({labels}) must not route"));
        }
        // Fallback is the naive loop itself: bit-identical, not allclose.
        if naive.len() != routed.len()
            || naive
                .iter()
                .zip(&routed)
                .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(format!("case {case} ({labels}): fallback not bit-identical"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Full train step: naive vs kernel-routed, paper geometry
// ---------------------------------------------------------------------------

/// All five convolutions of the paper-geometry train step must route, and
/// the complete 7-output contract (updated params, loss, sparsities) must
/// agree with the naive interpreter within reassociation tolerance.
#[test]
fn train_step_kernel_routed_matches_naive_end_to_end() {
    let g = Geometry::paper();
    let dir = std::env::temp_dir()
        .join(format!("sparsetrain-routeparity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("train_step.hlo.txt"),
        hlo_builder::train_step_hlo(&g),
    )
    .unwrap();

    let mut rng = Xorshift::new(77);
    let bound = |fan: usize| (2.0f32 / fan as f32).sqrt();
    let w1: Vec<f32> =
        (0..g.c1 * g.c_in * 9).map(|_| rng.range_f32(-bound(g.c_in * 9), bound(g.c_in * 9))).collect();
    let w2: Vec<f32> =
        (0..g.c2 * g.c1 * 9).map(|_| rng.range_f32(-bound(g.c1 * 9), bound(g.c1 * 9))).collect();
    let wfc: Vec<f32> =
        (0..g.classes * g.c2).map(|_| rng.range_f32(-bound(g.c2), bound(g.c2))).collect();
    let bfc = vec![0.0f32; g.classes];
    let x: Vec<f32> =
        (0..g.n * g.c_in * g.hw * g.hw).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let labels: Vec<i32> = (0..g.n).map(|_| rng.below(g.classes) as i32).collect();
    let inputs = vec![
        literal_f32(&w1, &[g.c1 as i64, g.c_in as i64, 3, 3]).unwrap(),
        literal_f32(&w2, &[g.c2 as i64, g.c1 as i64, 3, 3]).unwrap(),
        literal_f32(&wfc, &[g.classes as i64, g.c2 as i64]).unwrap(),
        literal_f32(&bfc, &[g.classes as i64]).unwrap(),
        literal_f32(&x, &[g.n as i64, g.c_in as i64, g.hw as i64, g.hw as i64]).unwrap(),
        literal_i32(&labels, &[g.n as i64]).unwrap(),
    ];

    let mut naive_rt = Runtime::cpu_naive(&dir).unwrap();
    let naive = naive_rt.load("train_step").unwrap().run(&inputs).unwrap();

    let mut routed_rt = Runtime::cpu_with_threads(&dir, 2).unwrap();
    let routed = routed_rt.load("train_step").unwrap().run(&inputs).unwrap();

    assert_eq!(naive.len(), 7);
    assert_eq!(routed.len(), 7);
    if executor::routing_enabled() {
        let router = routed_rt.op_router().expect("router installed");
        assert_eq!(
            router.routed_calls(),
            5,
            "all five train-step convolutions must route at the paper geometry \
             (fallbacks: {})",
            router.fallback_calls()
        );
        assert_eq!(router.fallback_calls(), 0);
    }
    for (i, (a, b)) in naive.iter().zip(&routed).enumerate() {
        let (av, bv) = (a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        assert!(
            allclose(&bv, &av, 1e-3, 1e-4),
            "train_step output {i} diverged between naive and kernel-routed"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
