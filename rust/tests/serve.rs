//! Gating suite for the batched serving front end (ISSUE 9).
//!
//! Every batching/timing assertion here runs on the manually-advanced
//! [`VirtualClock`] — there is not a single sleep-based timing assertion
//! in this file. The contract under test (see `coordinator::serve` module
//! docs): a batch closes at **exactly** `max_batch` arrivals or at
//! **exactly** the deadline tick, whichever comes first; replies are FIFO
//! and exactly-once; the bounded queue sheds at **exactly** the
//! configured depth with an explicit `Rejected`; a drained shutdown loses
//! zero accepted requests; and — because every routed op is per-sample
//! independent — a batch of B single-sample requests is **bit-identical**
//! to B sequential single-sample predicts, padded rungs included.
//!
//! The threaded [`Server`] test at the bottom uses the real
//! [`MonotonicClock`], but only asserts schedule-independent invariants
//! (conservation, bounds, per-sample bits); batch composition there may
//! legitimately vary with machine speed.

use anyhow::Result;
use sparsetrain::coordinator::serve::{
    wait_reply, BatchExecutor, Clock, MonotonicClock, Nanos, PredictExecutor, ServeConfig,
    ServeReply, ServeRequest, ServeSession, ServeStats, Server, VirtualClock,
};
use sparsetrain::runtime::hlo_builder::Geometry;
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config as PropConfig, Gen};
use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;

/// Echoes `input[0] + 1.0` per sample and advances the shared virtual
/// clock by `service_ns` per batch — the "executor service time" pattern:
/// latency assertions then cover queueing *and* execution on one timebase.
struct EchoExec {
    clock: Arc<VirtualClock>,
    service_ns: Nanos,
}

impl BatchExecutor for EchoExec {
    fn run_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.clock.advance(self.service_ns);
        Ok(inputs.iter().map(|v| vec![v[0] + 1.0]).collect())
    }
}

/// Pull the one-and-only reply off a request's channel; a second reply is
/// a protocol violation.
fn one_reply(rx: &Receiver<ServeReply>) -> ServeReply {
    let r = rx.try_recv().expect("exactly one reply must have been sent");
    assert!(rx.try_recv().is_err(), "a request must receive exactly one reply");
    r
}

fn done(reply: ServeReply) -> sparsetrain::coordinator::serve::Prediction {
    match reply {
        ServeReply::Done(p) => p,
        other => panic!("expected Done, got {other:?}"),
    }
}

fn session_with(
    cfg: ServeConfig,
    service_ns: Nanos,
) -> (Arc<VirtualClock>, ServeSession<EchoExec>) {
    let clock = Arc::new(VirtualClock::new());
    let exec = EchoExec { clock: Arc::clone(&clock), service_ns };
    let session = ServeSession::new(cfg, Arc::clone(&clock) as Arc<dyn Clock>, exec);
    (clock, session)
}

// ---------------------------------------------------------------------------
// Exact close points: size at the Nth arrival, deadline at the exact tick
// ---------------------------------------------------------------------------

#[test]
fn batch_closes_at_exactly_max_batch_arrivals() {
    let cfg = ServeConfig { max_batch: 4, max_delay_ns: 1_000_000, queue_depth: 16 };
    let (_clock, mut s) = session_with(cfg, 0);
    let mut rxs = Vec::new();
    for i in 0..3 {
        let (tx, rx) = mpsc::channel();
        s.submit(vec![i as f32], tx).unwrap();
        rxs.push(rx);
    }
    assert_eq!(s.depth(), 3, "one under max_batch: nothing may execute");
    assert!(s.stats().batch_sizes.is_empty());

    let (tx, rx) = mpsc::channel();
    s.submit(vec![3.0], tx).unwrap();
    rxs.push(rx);
    assert_eq!(s.depth(), 0, "the max_batch-th arrival closes the batch");
    assert_eq!(s.stats().batch_sizes, vec![4]);
    for (i, rx) in rxs.iter().enumerate() {
        let p = done(one_reply(rx));
        assert_eq!((p.id, p.batch_size), (i as u64, 4));
        assert_eq!(p.output, vec![i as f32 + 1.0]);
    }
}

#[test]
fn deadline_closes_at_exactly_the_tick_with_exact_latency() {
    let cfg = ServeConfig { max_batch: 8, max_delay_ns: 1_000, queue_depth: 8 };
    let (clock, mut s) = session_with(cfg, 7);
    let (tx, rx) = mpsc::channel();
    s.submit(vec![41.0], tx).unwrap();
    assert_eq!(s.next_deadline(), Some(1_000));

    clock.set(999);
    s.tick().unwrap();
    assert_eq!(s.depth(), 1, "one tick before the deadline: still coalescing");

    clock.set(1_000);
    s.tick().unwrap();
    assert_eq!(s.depth(), 0, "fires at exactly enqueue + max_delay");
    let p = done(one_reply(&rx));
    assert_eq!(p.id, 0);
    assert_eq!(p.output, vec![42.0]);
    assert_eq!(p.enqueued_at, 0);
    assert_eq!(p.completed_at, 1_007, "deadline + service time, on the shared clock");
    assert_eq!(p.batch_size, 1);
}

// ---------------------------------------------------------------------------
// FIFO, exactly-once, shedding, drained shutdown
// ---------------------------------------------------------------------------

#[test]
fn replies_are_fifo_and_exactly_once_across_batches() {
    let cfg = ServeConfig { max_batch: 4, max_delay_ns: 1_000_000, queue_depth: 32 };
    let (_clock, mut s) = session_with(cfg, 1);
    let mut rxs = Vec::new();
    for i in 0..10 {
        let (tx, rx) = mpsc::channel();
        let id = s.submit(vec![i as f32], tx).unwrap();
        assert_eq!(id, i as u64, "ids are assigned in submission order");
        rxs.push(rx);
    }
    assert_eq!(s.stats().batch_sizes, vec![4, 4], "two size-closed batches so far");
    assert_eq!(s.depth(), 2);
    let stats = s.shutdown().unwrap();
    assert_eq!(stats.batch_sizes, vec![4, 4, 2], "shutdown drains the FIFO tail");
    assert_eq!((stats.accepted, stats.rejected, stats.completed), (10, 0, 10));
    for (i, rx) in rxs.iter().enumerate() {
        let p = done(one_reply(rx));
        assert_eq!(p.id, i as u64, "FIFO: reply i carries id i");
        assert_eq!(p.output, vec![i as f32 + 1.0], "no cross-request mixing");
    }
}

#[test]
fn queue_sheds_at_exactly_the_configured_depth_and_recovers() {
    let cfg = ServeConfig { max_batch: 8, max_delay_ns: 1_000, queue_depth: 4 };
    let (clock, mut s) = session_with(cfg, 0);
    let mut rxs = Vec::new();
    for i in 0..4 {
        let (tx, rx) = mpsc::channel();
        s.submit(vec![i as f32], tx).unwrap();
        rxs.push(rx);
        assert!(rxs[i].try_recv().is_err(), "request {i} must still be queued");
    }
    assert_eq!(s.depth(), 4);

    // The depth+1-th arrival is shed — explicitly, with its id echoed.
    let (tx, rx) = mpsc::channel();
    let shed_id = s.submit(vec![99.0], tx).unwrap();
    assert_eq!(shed_id, 4);
    assert_eq!(one_reply(&rx), ServeReply::Rejected { id: 4, depth: 4 });
    assert_eq!((s.stats().accepted, s.stats().rejected), (4, 1));
    assert_eq!(s.depth(), 4, "a shed request never enters the queue");

    // Deadline-drain the queue: shedding must recover immediately.
    clock.set(1_000);
    s.tick().unwrap();
    assert_eq!(s.depth(), 0);
    let (tx, rx2) = mpsc::channel();
    s.submit(vec![5.0], tx).unwrap();
    let stats = s.shutdown().unwrap();
    assert_eq!((stats.accepted, stats.rejected, stats.completed), (5, 1, 5));
    assert_eq!(done(one_reply(&rx2)).output, vec![6.0]);
    for rx in &rxs {
        assert!(matches!(one_reply(rx), ServeReply::Done(_)));
    }
}

#[test]
fn drained_shutdown_loses_zero_accepted_requests() {
    let cfg = ServeConfig { max_batch: 8, max_delay_ns: 1_000_000, queue_depth: 64 };
    let (_clock, mut s) = session_with(cfg, 0);
    let mut rxs = Vec::new();
    for i in 0..5 {
        let (tx, rx) = mpsc::channel();
        s.submit(vec![i as f32], tx).unwrap();
        rxs.push(rx);
    }
    assert_eq!(s.depth(), 5, "under-full and under-deadline: all queued");
    let stats = s.shutdown().unwrap();
    assert_eq!(stats.batch_sizes, vec![5]);
    assert_eq!((stats.accepted, stats.completed), (5, 5));
    for (i, rx) in rxs.iter().enumerate() {
        assert_eq!(done(one_reply(rx)).id, i as u64);
    }
}

// ---------------------------------------------------------------------------
// Determinism: the same schedule replays bit-identically
// ---------------------------------------------------------------------------

fn scripted_run() -> (ServeStats, Vec<ServeReply>) {
    let cfg = ServeConfig { max_batch: 3, max_delay_ns: 100, queue_depth: 4 };
    let (clock, mut s) = session_with(cfg, 5);
    let mut rxs = Vec::new();
    for (i, gap) in [0u64, 3, 1, 120, 2, 40, 200, 0, 0, 0, 0].into_iter().enumerate() {
        clock.advance(gap);
        let (tx, rx) = mpsc::channel();
        s.submit(vec![i as f32], tx).unwrap();
        rxs.push(rx);
    }
    clock.advance(250);
    s.tick().unwrap();
    let stats = s.shutdown().unwrap();
    let replies = rxs.iter().map(one_reply).collect();
    (stats, replies)
}

#[test]
fn identical_schedules_replay_bit_identically() {
    let (stats_a, replies_a) = scripted_run();
    let (stats_b, replies_b) = scripted_run();
    assert_eq!(stats_a, stats_b, "stats must be a pure function of the schedule");
    assert_eq!(replies_a, replies_b, "every reply — ids, bits, timestamps — must replay");
    assert_eq!(stats_a.accepted + stats_a.rejected, 11);
    assert_eq!(stats_a.completed, stats_a.accepted);
}

// ---------------------------------------------------------------------------
// Executor failure is a server error, not a hang or a lost request
// ---------------------------------------------------------------------------

struct FailExec;
impl BatchExecutor for FailExec {
    fn run_batch(&mut self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("backend down")
    }
}

/// Returns no outputs for a non-empty batch: the arity contract breaker.
struct ShortExec;
impl BatchExecutor for ShortExec {
    fn run_batch(&mut self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(Vec::new())
    }
}

#[test]
fn executor_failure_surfaces_as_an_error_not_a_hang() {
    let cfg = ServeConfig { max_batch: 1, max_delay_ns: 1_000, queue_depth: 8 };
    let clock = Arc::new(VirtualClock::new());
    let mut s = ServeSession::new(cfg, Arc::clone(&clock) as Arc<dyn Clock>, FailExec);
    let (tx, _rx) = mpsc::channel();
    assert!(s.submit(vec![1.0], tx).is_err(), "a failing executor must fail the call");

    let mut s = ServeSession::new(cfg, Arc::clone(&clock) as Arc<dyn Clock>, ShortExec);
    let (tx, _rx) = mpsc::channel();
    assert!(s.submit(vec![1.0], tx).is_err(), "an arity-cheating executor must be caught");
}

// ---------------------------------------------------------------------------
// Property: randomized arrival schedules on the virtual clock
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct ArrivalCase {
    max_batch: usize,
    depth: usize,
    delay: Nanos,
    /// Inter-arrival gaps; one submission per entry.
    gaps: Vec<Nanos>,
}

struct ArrivalGen;

impl Gen<ArrivalCase> for ArrivalGen {
    fn generate(&self, rng: &mut Xorshift) -> ArrivalCase {
        let max_batch = 1 + rng.below(6);
        let depth = 1 + rng.below(10);
        let delay = (1 + rng.below(1_000)) as Nanos;
        let gaps =
            (0..rng.below(41)).map(|_| rng.below(2 * delay as usize + 2) as Nanos).collect();
        ArrivalCase { max_batch, depth, delay, gaps }
    }
    fn shrink(&self, v: &ArrivalCase) -> Vec<ArrivalCase> {
        let mut out = Vec::new();
        if !v.gaps.is_empty() {
            out.push(ArrivalCase { gaps: v.gaps[..v.gaps.len() / 2].to_vec(), ..v.clone() });
            let mut one_less = v.clone();
            one_less.gaps.pop();
            out.push(one_less);
        }
        if v.max_batch > 1 {
            out.push(ArrivalCase { max_batch: 1, ..v.clone() });
        }
        if v.depth > 1 {
            out.push(ArrivalCase { depth: 1, ..v.clone() });
        }
        out
    }
}

#[test]
fn property_randomized_arrivals_preserve_serving_invariants() {
    check(PropConfig { cases: 96, seed: 0x5E17E, max_shrink_steps: 256 }, &ArrivalGen, |c| {
        let cfg = ServeConfig {
            max_batch: c.max_batch,
            max_delay_ns: c.delay,
            queue_depth: c.depth,
        };
        let (clock, mut s) = session_with(cfg, 0);
        let mut rxs = Vec::new();
        for (i, &gap) in c.gaps.iter().enumerate() {
            clock.advance(gap);
            let (tx, rx) = mpsc::channel();
            let id = s.submit(vec![i as f32], tx).map_err(|e| format!("submit: {e}"))?;
            if id != i as u64 {
                return Err(format!("id {id} assigned to submission {i}"));
            }
            if s.depth() > c.depth {
                return Err(format!("depth {} exceeds the limit {}", s.depth(), c.depth));
            }
            rxs.push(rx);
        }
        let stats = s.shutdown().map_err(|e| format!("shutdown: {e}"))?;

        // Conservation: every submission is accepted xor rejected; every
        // accepted request completes; batches account for every completion.
        let submitted = c.gaps.len() as u64;
        if stats.accepted + stats.rejected != submitted {
            return Err(format!("{stats:?} does not conserve {submitted} submissions"));
        }
        if stats.completed != stats.accepted {
            return Err(format!("{stats:?} lost accepted requests"));
        }
        if stats.batch_sizes.iter().any(|&b| b == 0 || b > c.max_batch) {
            return Err(format!("batch size out of 1..={}: {:?}", c.max_batch, stats.batch_sizes));
        }
        if stats.batch_sizes.iter().sum::<usize>() as u64 != stats.completed {
            return Err(format!(
                "batch sizes {:?} != completed {}",
                stats.batch_sizes, stats.completed
            ));
        }

        // Exactly-once replies; FIFO completion order; bounded waiting.
        let max_gap = c.gaps.iter().copied().max().unwrap_or(0);
        let (mut dones, mut rejects) = (0u64, 0u64);
        let mut last_completed = 0;
        for (i, rx) in rxs.iter().enumerate() {
            let reply = rx.try_recv().map_err(|_| format!("request {i}: no reply"))?;
            if rx.try_recv().is_ok() {
                return Err(format!("request {i}: more than one reply"));
            }
            match reply {
                ServeReply::Done(p) => {
                    dones += 1;
                    if p.id != i as u64 || p.output != vec![i as f32 + 1.0] {
                        return Err(format!("request {i}: wrong reply {p:?}"));
                    }
                    if p.completed_at < last_completed {
                        return Err(format!("request {i}: completed before request {}", i - 1));
                    }
                    last_completed = p.completed_at;
                    let wait = p.completed_at - p.enqueued_at;
                    if wait > c.delay + max_gap {
                        return Err(format!(
                            "request {i} waited {wait} ns > deadline {} + max gap {max_gap}",
                            c.delay
                        ));
                    }
                }
                ServeReply::Rejected { id, depth } => {
                    rejects += 1;
                    if id != i as u64 {
                        return Err(format!("request {i}: rejection carries id {id}"));
                    }
                    if depth != c.depth {
                        return Err(format!(
                            "request {i}: shed at depth {depth}, limit is {}",
                            c.depth
                        ));
                    }
                }
            }
        }
        if dones != stats.completed || rejects != stats.rejected {
            return Err(format!(
                "replies ({dones} done, {rejects} rejected) disagree with {stats:?}"
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Parity: a batch of B requests is bit-identical to B sequential predicts
// ---------------------------------------------------------------------------

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits2(v: &[Vec<f32>]) -> Vec<Vec<u32>> {
    v.iter().map(|s| bits(s)).collect()
}

/// Routed-envelope geometry (channels = V) kept small: the CI parity legs
/// run this both with routing on and under `SPARSETRAIN_OP_ROUTE=off` /
/// `SPARSETRAIN_CONV_ROUTE=off`, so the same assertions pin the padded
/// batch path on the SIMD kernels *and* on the naive interpreter.
fn parity_geometry() -> Geometry {
    Geometry { hw: 8, c1: 16, c2: 16, classes: 5, ..Geometry::paper() }
}

#[test]
fn batched_predict_is_bit_identical_to_sequential_singles() {
    let g = parity_geometry();
    let seed = 0xA11CE;
    let mut rng = Xorshift::new(77);
    let sample_len = g.c_in * g.hw * g.hw;
    let samples: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..sample_len).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect();

    // Kernel-routed executor (honors the route kill-switch env vars).
    let mut routed = PredictExecutor::new(g, 4, 1, seed).unwrap();
    assert_eq!(routed.sample_len(), sample_len);
    let batched = routed.run_batch(&samples).unwrap();
    let singles: Vec<Vec<f32>> =
        samples.iter().map(|s| routed.predict_one(s).unwrap()).collect();
    assert_eq!(
        bits2(&batched),
        bits2(&singles),
        "a batch of 3 (padded to the 4-rung) must be bit-identical to 3 sequential predicts"
    );

    // All-naive interpreter executor: the same parity, and — because op
    // routing is bit-identical to naive evaluation by contract — the same
    // bits as the routed executor built from the same seed.
    let mut naive = PredictExecutor::new_naive(g, 4, seed).unwrap();
    let naive_batched = naive.run_batch(&samples).unwrap();
    let naive_singles: Vec<Vec<f32>> =
        samples.iter().map(|s| naive.predict_one(s).unwrap()).collect();
    assert_eq!(bits2(&naive_batched), bits2(&naive_singles), "naive batched vs sequential");
    assert_eq!(
        bits2(&batched),
        bits2(&naive_batched),
        "routed and naive executors with one seed must serve one model"
    );
}

// ---------------------------------------------------------------------------
// The real executor behind the session (virtual clock) and the threaded
// server (monotonic clock, schedule-independent assertions only)
// ---------------------------------------------------------------------------

#[test]
fn session_serves_real_predictions_on_the_virtual_clock() {
    let g = Geometry::tiny();
    let seed = 99;
    let mut reference = PredictExecutor::new_naive(g, 2, seed).unwrap();
    let exec = PredictExecutor::new_naive(g, 2, seed).unwrap();
    let clock = Arc::new(VirtualClock::new());
    let cfg = ServeConfig { max_batch: 2, max_delay_ns: 1_000, queue_depth: 8 };
    let mut s = ServeSession::new(cfg, Arc::clone(&clock) as Arc<dyn Clock>, exec);

    let mut rng = Xorshift::new(5);
    let sample_len = g.c_in * g.hw * g.hw;
    let samples: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..sample_len).map(|_| rng.range_f32(-1.0, 1.0)).collect())
        .collect();
    let mut rxs = Vec::new();
    for s_in in &samples[..2] {
        let (tx, rx) = mpsc::channel();
        s.submit(s_in.clone(), tx).unwrap();
        rxs.push(rx);
    }
    assert_eq!(s.stats().batch_sizes, vec![2], "size-closed at max_batch");

    clock.advance(1_000);
    let (tx, rx) = mpsc::channel();
    s.submit(samples[2].clone(), tx).unwrap();
    rxs.push(rx);
    clock.set(2_000);
    s.tick().unwrap();
    let stats = s.shutdown().unwrap();
    assert_eq!(stats.batch_sizes, vec![2, 1], "the straggler deadline-closes alone");

    for (i, rx) in rxs.iter().enumerate() {
        let p = done(one_reply(rx));
        let want = reference.predict_one(&samples[i]).unwrap();
        assert_eq!(
            bits(&p.output),
            bits(&want),
            "request {i}: served logits must match a sequential predict bit-for-bit"
        );
    }
}

#[test]
fn threaded_server_drains_cleanly_with_zero_rejects() {
    let g = Geometry::tiny();
    let seed = 7;
    let mut reference = PredictExecutor::new_naive(g, 4, seed).unwrap();
    let clock: Arc<dyn Clock> = Arc::new(MonotonicClock::new());
    let cfg = ServeConfig { max_batch: 4, max_delay_ns: 500_000, queue_depth: 64 };
    let server =
        Server::spawn(cfg, Arc::clone(&clock), move || PredictExecutor::new_naive(g, 4, seed));
    let tx = server.handle();

    let mut rng = Xorshift::new(13);
    let sample_len = g.c_in * g.hw * g.hw;
    let mut samples = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..6 {
        let input: Vec<f32> = (0..sample_len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let (reply, rx) = mpsc::channel();
        tx.send(ServeRequest { input: input.clone(), reply }).unwrap();
        samples.push(input);
        rxs.push(rx);
    }
    drop(tx);
    let stats = server.shutdown().unwrap();

    // Batch composition is machine-dependent here; the invariants are not.
    assert_eq!((stats.accepted, stats.rejected, stats.completed), (6, 0, 6));
    assert_eq!(stats.batch_sizes.iter().sum::<usize>(), 6);
    assert!(stats.batch_sizes.iter().all(|&b| (1..=4).contains(&b)));
    for (i, rx) in rxs.iter().enumerate() {
        let p = match wait_reply(rx).unwrap() {
            ServeReply::Done(p) => p,
            other => panic!("request {i}: expected Done, got {other:?}"),
        };
        assert!((1..=4).contains(&p.batch_size));
        let want = reference.predict_one(&samples[i]).unwrap();
        assert_eq!(
            bits(&p.output),
            bits(&want),
            "request {i}: batching must never change the answer"
        );
    }
}
