//! Golden [`KernelStats`] tests (ISSUE 2 satellite): pin the serialized
//! per-component counters for two fixed layers and assert parallel merge
//! parity, so stat drift is caught by `cargo test` without running benches.
//!
//! The two geometries are Table-2-derived: same filter/stride/padding shape
//! as the paper's rows, with channel and spatial dims scaled down so the
//! functional kernels run in milliseconds under `cargo test`:
//!
//! * `G1` — `square(16, 32, 32, 8, 3, 2)`: the strided-3×3 ResNet
//!   downsampling shape (`resnet3_2/r`-like, C=K), batch 16;
//! * `G2` — `square(16, 32, 64, 6, 3, 1)`: the stride-1 3×3
//!   channel-doubling VGG shape (`vgg3_1`-like, K=2C), batch 16.
//!
//! The golden lines cover every **data-independent** counter (total FMA
//! slots, zero checks, sweeps, vector loads/stores, and the post-merge
//! filter-footprint floor). The data-dependent split (issued vs skipped
//! FMAs, popcount histogram, integer ops) is covered by the exact
//! serial-vs-parallel stats equality plus conservation assertions, so any
//! accounting drift — serial or in the scheduler's chunk merge — fails one
//! of the assertions below.

use sparsetrain::coordinator::scheduler::Scheduler;
use sparsetrain::kernels::{
    sparse_bwi, sparse_bww, sparse_fwd, ConvConfig, KernelStats, SkipMode,
};
use sparsetrain::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;

/// Serialize the data-independent counters of a stats block.
fn golden_line(st: &KernelStats) -> String {
    format!(
        "fma_total={} zero_checks={} sweeps={} loads_in={} loads_out={} stores_out={} filter_bytes_per_sweep={}",
        st.fma_total(),
        st.zero_checks,
        st.sweeps,
        st.loads_in,
        st.loads_out,
        st.stores_out,
        st.filter_bytes_per_sweep
    )
}

struct TriadStats {
    fwd: KernelStats,
    bwi: KernelStats,
    bww: KernelStats,
}

fn run_serial(cfg: &ConvConfig, seed: u64) -> TriadStats {
    let (d, g, dy) = setup(cfg, seed);
    let gt = g.transpose_channels();
    let dt = BatchTiledTensor::from_act(&d);
    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut fwd = KernelStats::new();
    sparse_fwd::fwd(cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut fwd);
    let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let mut bwi = KernelStats::new();
    sparse_bwi::bwi(cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop, &mut bwi);
    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    let mut bww = KernelStats::new();
    sparse_bww::bww(cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop, &mut bww);
    TriadStats { fwd, bwi, bww }
}

fn run_parallel(cfg: &ConvConfig, seed: u64, threads: usize) -> TriadStats {
    let (d, g, dy) = setup(cfg, seed);
    let gt = g.transpose_channels();
    let dt = BatchTiledTensor::from_act(&d);
    let sched = Scheduler::new(threads);
    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let fwd = sched.run_fwd(cfg, &d, &g, &mut y, SkipMode::MaskLoop).stats;
    let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let bwi = sched.run_bwi(cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop).stats;
    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    let bww = sched.run_bww(cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop).stats;
    TriadStats { fwd, bwi, bww }
}

fn setup(cfg: &ConvConfig, seed: u64) -> (ActTensor, FilterTensor, ActTensor) {
    let mut rng = Xorshift::new(seed);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.5);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    g.fill_uniform(&mut rng, -0.5, 0.5);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_relu_sparse(&mut rng, 0.45);
    (d, g, dy)
}

fn check_layer(cfg: &ConvConfig, seed: u64, golden: [&str; 3]) {
    let serial = run_serial(cfg, seed);
    let [gf, gi, gw] = golden;
    assert_eq!(golden_line(&serial.fwd), gf, "FWD golden drift");
    assert_eq!(golden_line(&serial.bwi), gi, "BWI golden drift");
    assert_eq!(golden_line(&serial.bww), gw, "BWW golden drift");

    for st in [&serial.fwd, &serial.bwi, &serial.bww] {
        // conservation: the data-dependent split and histogram must agree
        // with the data-independent totals
        assert_eq!(st.fma_vec + st.fma_vec_skipped, st.fma_total());
        assert_eq!(st.popcount_hist.iter().sum::<u64>(), st.zero_checks);
        assert!(st.fma_vec > 0 && st.fma_vec_skipped > 0, "50% sparsity must split FMAs");
    }

    // Parallel merge parity: the chunk-merged stats — including the
    // post-merge filter-footprint floor — must equal the serial counters
    // exactly, for an uneven and an even thread count.
    for threads in [3, 4] {
        let par = run_parallel(cfg, seed, threads);
        assert_eq!(par.fwd, serial.fwd, "FWD merge parity, threads={threads}");
        assert_eq!(par.bwi, serial.bwi, "BWI merge parity, threads={threads}");
        assert_eq!(par.bww, serial.bww, "BWW merge parity, threads={threads}");
        assert_eq!(golden_line(&par.fwd), gf, "FWD parallel golden drift");
        assert_eq!(golden_line(&par.bwi), gi, "BWI parallel golden drift");
        assert_eq!(golden_line(&par.bww), gw, "BWW parallel golden drift");
    }
}

/// G1: strided-3×3 ResNet downsampling shape (`resnet3_2/r`-derived).
#[test]
#[cfg_attr(miri, ignore = "too slow under miri; the lib miri_* tests cover the reduced set")]
fn golden_stats_strided_resnet_shape() {
    let cfg = ConvConfig::square(16, 32, 32, 8, 3, 2);
    check_layer(
        &cfg,
        0x6015EED,
        [
            "fma_total=123904 zero_checks=2816 sweeps=352 loads_in=2816 loads_out=512 stores_out=512 filter_bytes_per_sweep=18432",
            "fma_total=123904 zero_checks=1408 sweeps=352 loads_in=1408 loads_out=2048 stores_out=2048 filter_bytes_per_sweep=18432",
            "fma_total=123904 zero_checks=2816 sweeps=352 loads_in=2816 loads_out=2112 stores_out=2112 filter_bytes_per_sweep=384",
        ],
    );
}

/// G2: stride-1 3×3 channel-doubling VGG shape (`vgg3_1`-derived).
#[test]
#[cfg_attr(miri, ignore = "too slow under miri; the lib miri_* tests cover the reduced set")]
fn golden_stats_vgg_shape() {
    let cfg = ConvConfig::square(16, 32, 64, 6, 3, 1);
    check_layer(
        &cfg,
        0xBEE5,
        [
            "fma_total=524288 zero_checks=3072 sweeps=512 loads_in=3072 loads_out=2304 stores_out=2304 filter_bytes_per_sweep=36864",
            "fma_total=524288 zero_checks=6144 sweeps=1024 loads_in=6144 loads_out=1152 stores_out=1152 filter_bytes_per_sweep=18432",
            "fma_total=524288 zero_checks=3072 sweeps=512 loads_in=3072 loads_out=6144 stores_out=6144 filter_bytes_per_sweep=768",
        ],
    );
}
