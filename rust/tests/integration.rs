//! Integration tests across modules: kernels ↔ scheduler ↔ selector ↔ sim,
//! the E9 prose claims of the paper, and (artifact-gated) the PJRT trainer.

use sparsetrain::bench::experiments::{self, speedup_over_direct};
use sparsetrain::coordinator::selector::{AlgoPolicy, Selector};
use sparsetrain::coordinator::scheduler::Scheduler;
use sparsetrain::coordinator::trainer::{Trainer, TrainerConfig};
use sparsetrain::kernels::{
    direct, layers, reference, sparse_bwi, sparse_bww, sparse_fwd, Component, ConvConfig,
    KernelStats, SkipMode,
};
use sparsetrain::runtime::artifacts::ArtifactSet;
use sparsetrain::sim::{estimate_layer_iid, Algorithm, Machine};
use sparsetrain::tensor::{allclose, ActTensor, BatchTiledTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config as PropConfig, ConvGeomGen, UsizeIn};

/// A full training micro-step through all three sparse components on one
/// layer must equal the scalar reference end to end.
#[test]
#[cfg_attr(miri, ignore = "too slow under miri; the lib miri_* tests cover the reduced set")]
fn full_conv_training_step_matches_reference() {
    let cfg = ConvConfig::square(16, 32, 32, 8, 3, 1);
    let mut rng = Xorshift::new(555);

    // forward input: a ReLU output
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.55);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
    g.fill_uniform(&mut rng, -0.4, 0.4);

    // FWD
    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut st = KernelStats::new();
    sparse_fwd::fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop, &mut st);
    let y_ref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
    assert!(allclose(&y.to_nchw(), &y_ref, 1e-4, 1e-5));

    // ReLU + backprop gate: dY carries the ReLU zero pattern
    let mut act = y.clone();
    let s_out = layers::relu_fwd(&mut act);
    assert!(s_out > 0.2 && s_out < 0.8, "relu sparsity {s_out}");
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_uniform(&mut rng, -1.0, 1.0);
    layers::relu_bwd(&act, &mut dy);
    assert!(dy.sparsity() >= s_out - 1e-9);

    // BWI on the gated gradient
    let gt = g.transpose_channels();
    let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let mut st2 = KernelStats::new();
    sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop, &mut st2);
    let dd_ref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
    assert!(allclose(&dd.to_nchw(), &dd_ref, 1e-4, 1e-5));
    assert!(st2.skip_fraction() > 0.2, "BWI must exploit the gated gradient");

    // BWW checking D
    let dt = BatchTiledTensor::from_act(&d);
    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
    let mut st3 = KernelStats::new();
    sparse_bww::bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop, &mut st3);
    let dg_ref = reference::conv_bww(&cfg, &d.to_nchw(), &dy.to_nchw());
    assert!(allclose(&dg.to_kcsr(), &dg_ref, 1e-3, 1e-4));
}

/// E9: SparseTrain's modeled execution time scales linearly with N
/// (§5.3: "confirmed that SparseTrain's execution time scales linearly").
#[test]
fn model_scales_linearly_with_batch() {
    let m = Machine::skylake_x();
    let mk = |n: usize| ConvConfig::square(n, 128, 128, 28, 3, 1);
    let t16 = estimate_layer_iid(&m, Algorithm::SparseTrain, Component::Fwd, &mk(16), 0.6).wall;
    let t32 = estimate_layer_iid(&m, Algorithm::SparseTrain, Component::Fwd, &mk(32), 0.6).wall;
    let t64 = estimate_layer_iid(&m, Algorithm::SparseTrain, Component::Fwd, &mk(64), 0.6).wall;
    assert!((t32 / t16 - 2.0).abs() < 0.1, "t32/t16 = {}", t32 / t16);
    assert!((t64 / t16 - 4.0).abs() < 0.2, "t64/t16 = {}", t64 / t16);
}

/// E9: dense-input overhead within ~10 % and crossover by 20–30 % on a
/// representative 3×3 layer.
#[test]
fn dense_overhead_and_crossover() {
    let m = Machine::skylake_x();
    let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
    let at = |s: f64| speedup_over_direct(&m, Algorithm::SparseTrain, &cfg, Component::Fwd, s);
    assert!(at(0.0) > 0.88, "dense overhead too high: {}", at(0.0));
    assert!(at(0.0) < 1.0, "sparse cannot beat direct on dense input");
    assert!(at(0.3) > 1.0, "no crossover by 30%: {}", at(0.3));
    assert!(at(0.9) > 2.0, "90% speedup too low: {}", at(0.9));
}

/// E9: SparseTrain passes Winograd between 50–60 % sparsity on 3×3 layers
/// (§5.1) — allow a band around it.
#[test]
fn winograd_crossover_band() {
    let m = Machine::skylake_x();
    let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
    let win = speedup_over_direct(&m, Algorithm::Winograd, &cfg, Component::Fwd, 0.0);
    let sp = |s: f64| speedup_over_direct(&m, Algorithm::SparseTrain, &cfg, Component::Fwd, s);
    assert!(sp(0.3) < win, "SparseTrain should trail Winograd at 30%");
    assert!(sp(0.7) > win, "SparseTrain should pass Winograd by 70%");
}

/// The full training triad through the parallel scheduler: FWD, BWI and
/// BWW all run output-parallel, match the scalar reference, and merge
/// stats identical to the serial kernels — the end-to-end composition the
/// paper's §3.2.2/§3.3/§3.4 parallelization scheme promises.
#[test]
#[cfg_attr(miri, ignore = "too slow under miri; the lib miri_* tests cover the reduced set")]
fn parallel_triad_matches_reference_end_to_end() {
    let cfg = ConvConfig::square(16, 32, 32, 8, 3, 1);
    let mut rng = Xorshift::new(4242);

    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.55);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
    g.fill_uniform(&mut rng, -0.4, 0.4);
    let sched = Scheduler::new(4);

    // FWD (parallel) → ReLU gate → BWI/BWW (parallel) on the gated grad
    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let rf = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
    let y_ref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
    assert!(allclose(&y.to_nchw(), &y_ref, 1e-4, 1e-5));

    let mut act = y.clone();
    layers::relu_fwd(&mut act);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_uniform(&mut rng, -1.0, 1.0);
    layers::relu_bwd(&act, &mut dy);

    let gt = g.transpose_channels();
    let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let ri = sched.run_bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop);
    let dd_ref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
    assert!(allclose(&dd.to_nchw(), &dd_ref, 1e-4, 1e-5));
    assert!(ri.stats.skip_fraction() > 0.2, "BWI must exploit the gated gradient");

    let dt = BatchTiledTensor::from_act(&d);
    let mut dg = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
    let rw = sched.run_bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop);
    let dg_ref = reference::conv_bww(&cfg, &d.to_nchw(), &dy.to_nchw());
    assert!(allclose(&dg.to_kcsr(), &dg_ref, 1e-3, 1e-4));

    // serial-stat parity for each component
    let mut st = KernelStats::new();
    let mut y2 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    sparse_fwd::fwd(&cfg, &d, &g, &mut y2, SkipMode::MaskLoop, &mut st);
    assert_eq!(rf.stats, st);
    let mut st2 = KernelStats::new();
    let mut dd2 = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd2, SkipMode::MaskLoop, &mut st2);
    assert_eq!(ri.stats, st2);
    let mut st3 = KernelStats::new();
    let mut dg2 = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
    sparse_bww::bww(&cfg, &dt, &dy, &mut dg2, SkipMode::MaskLoop, &mut st3);
    assert_eq!(rw.stats, st3);
}

/// The thread-count-aware selector agrees with the scheduler's width: a
/// 1-thread cost estimate is dearer than a 6-thread one, and the combined
/// policy still returns the modeled-fastest candidate at every width.
#[test]
fn selector_thread_awareness_composes_with_scheduler() {
    let m = Machine::skylake_x();
    let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
    let c1 = Selector::with_threads(m, 1).cost(Algorithm::SparseTrain, &cfg, Component::Fwd, 0.6);
    let c6 = Selector::with_threads(m, 6).cost(Algorithm::SparseTrain, &cfg, Component::Fwd, 0.6);
    assert!(c1 > c6 && c1 / c6 <= 6.0 + 1e-9);

    // the selection is actually runnable through the scheduler
    let sel = Selector::with_threads(m, 3);
    let small = ConvConfig::square(2, 32, 64, 8, 3, 1);
    if sel.select(AlgoPolicy::Combined, &small, Component::Fwd, 0.9, true)
        == Algorithm::SparseTrain
    {
        let mut rng = Xorshift::new(31);
        let mut d = ActTensor::zeros(small.n, small.c, small.h, small.w);
        d.fill_relu_sparse(&mut rng, 0.9);
        let mut g = FilterTensor::zeros(small.k, small.c, 3, 3);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let sched = Scheduler::new(3);
        let mut y = ActTensor::zeros(small.n, small.k, small.out_h(), small.out_w());
        let report = sched.run_fwd(&small, &d, &g, &mut y, SkipMode::MaskLoop);
        assert!(report.stats.skip_fraction() > 0.8);
    }
}

/// Scheduler + selector compose: run a layer with the policy-selected
/// algorithm in parallel and match the reference.
#[test]
fn scheduler_with_selected_algorithm_matches_reference() {
    let m = Machine::skylake_x();
    let sel = Selector::new(m);
    let cfg = ConvConfig::square(2, 32, 64, 8, 3, 1);
    let alg = sel.select(AlgoPolicy::Combined, &cfg, Component::Fwd, 0.9, true);
    assert_eq!(alg, Algorithm::SparseTrain);

    let mut rng = Xorshift::new(777);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.9);
    let mut g = FilterTensor::zeros(cfg.k, cfg.c, 3, 3);
    g.fill_uniform(&mut rng, -0.5, 0.5);
    let sched = Scheduler::new(3);
    let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let report = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
    assert!(report.stats.skip_fraction() > 0.8);
    let y_ref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
    assert!(allclose(&y.to_nchw(), &y_ref, 1e-4, 1e-5));
}

/// Property: on random geometry, sparse FWD == dense direct numerics.
#[test]
#[cfg_attr(miri, ignore = "too slow under miri; the lib miri_* tests cover the reduced set")]
fn property_sparse_equals_direct_random_geometry() {
    check(
        PropConfig { cases: 12, seed: 0xBEEF, max_shrink_steps: 24 },
        &UsizeIn { lo: 0, hi: 500 },
        |&case| {
            let mut rng = Xorshift::new(case as u64);
            let hw = 4 + rng.below(8);
            let stride = 1 + rng.below(2);
            let rs = [1, 3, 5][rng.below(3)];
            if hw + 2 * ((rs - 1) / 2) < rs {
                return Ok(());
            }
            let cfg = ConvConfig::square(1 + rng.below(2), 16, 32, hw, rs, stride);
            if cfg.validate().is_err() {
                return Ok(());
            }
            let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let sparsity = rng.next_f64();
            d.fill_relu_sparse(&mut rng, sparsity);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let mut y1 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let mut y2 = y1.clone();
            let mut s1 = KernelStats::new();
            let mut s2 = KernelStats::new();
            sparse_fwd::fwd(&cfg, &d, &g, &mut y1, SkipMode::MaskLoop, &mut s1);
            direct::fwd(&cfg, &d, &g, &mut y2, &mut s2);
            if allclose(y1.data(), y2.data(), 1e-4, 1e-5) {
                Ok(())
            } else {
                Err(format!("mismatch at {cfg:?}"))
            }
        },
    );
}

/// Property (ISSUE 2): the slice-view triad is **bit-identical** to the
/// serial kernels — numerics and merged stats — across randomized
/// geometry (odd/even H=W, stride 1–2, filter 1/3/5, extra padding) and
/// thread counts, and FWD/BWI/BWW stay within tolerance of the scalar
/// reference. This is the standing regression gate for the disjoint
/// slice-view task API: any aliasing or mis-routed view shows up as a
/// numeric or stat divergence at some geometry/thread combination.
#[test]
#[cfg_attr(miri, ignore = "too slow under miri; the lib miri_* tests cover the reduced set")]
fn property_slice_view_triad_bitexact_over_random_geometry() {
    let gen = ConvGeomGen { min_hw: 4, max_hw: 9, max_threads: 8 };
    check(PropConfig { cases: 10, seed: 0x51AB, max_shrink_steps: 12 }, &gen, |g| {
        // n = 16 so BWW (batch multiple of V) runs on every case.
        let mut cfg = ConvConfig::square(16, 16, 32, g.hw, g.rs, g.stride);
        cfg.pad_h += g.extra_pad;
        cfg.pad_w += g.extra_pad;
        if cfg.validate().is_err() {
            return Ok(());
        }
        let mut rng = Xorshift::new(0xA11A + g.hw as u64 * 37 + g.threads as u64);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, 0.55);
        let mut gflt = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        gflt.fill_uniform(&mut rng, -0.5, 0.5);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_relu_sparse(&mut rng, 0.45);
        for v in dy.data_mut().iter_mut() {
            if *v != 0.0 && rng.bernoulli(0.5) {
                *v = -*v;
            }
        }
        let gt = gflt.transpose_channels();
        let dt = BatchTiledTensor::from_act(&d);
        let sched = Scheduler::new(g.threads);

        // serial baselines
        let mut y_s = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st_f = KernelStats::new();
        sparse_fwd::fwd(&cfg, &d, &gflt, &mut y_s, SkipMode::MaskLoop, &mut st_f);
        let mut dd_s = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st_i = KernelStats::new();
        sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd_s, SkipMode::MaskLoop, &mut st_i);
        let mut dg_s = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut st_w = KernelStats::new();
        sparse_bww::bww(&cfg, &dt, &dy, &mut dg_s, SkipMode::MaskLoop, &mut st_w);

        // parallel through the slice-view scheduler
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let rf = sched.run_fwd(&cfg, &d, &gflt, &mut y, SkipMode::MaskLoop);
        if y.data() != y_s.data() || rf.stats != st_f {
            return Err(format!("FWD diverges at {g:?}"));
        }
        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let ri = sched.run_bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop);
        if dd.data() != dd_s.data() || ri.stats != st_i {
            return Err(format!("BWI diverges at {g:?}"));
        }
        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let rw = sched.run_bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop);
        if dg.data() != dg_s.data() || rw.stats != st_w {
            return Err(format!("BWW diverges at {g:?}"));
        }

        // and all three agree with the scalar reference
        let y_ref = reference::conv_fwd(&cfg, &d.to_nchw(), &gflt.to_kcsr());
        if !allclose(&y.to_nchw(), &y_ref, 1e-4, 1e-5) {
            return Err(format!("FWD reference mismatch at {g:?}"));
        }
        let dd_ref = reference::conv_bwi(&cfg, &dy.to_nchw(), &gflt.to_kcsr());
        if !allclose(&dd.to_nchw(), &dd_ref, 1e-4, 1e-5) {
            return Err(format!("BWI reference mismatch at {g:?}"));
        }
        let dg_ref = reference::conv_bww(&cfg, &d.to_nchw(), &dy.to_nchw());
        if !allclose(&dg.to_kcsr(), &dg_ref, 1e-3, 1e-4) {
            return Err(format!("BWW reference mismatch at {g:?}"));
        }
        Ok(())
    });
}

/// The fixed 1..=8-thread sweep from the acceptance criteria, on an
/// asymmetric geometry (odd spatial size, stride 2, extra padding) chosen
/// to exercise truncated boundary taps through the slice-view API.
#[test]
#[cfg_attr(miri, ignore = "too slow under miri; the lib miri_* tests cover the reduced set")]
fn slice_view_thread_sweep_1_to_8_bitexact() {
    let mut cfg = ConvConfig::square(16, 16, 32, 7, 3, 2);
    cfg.pad_h += 1; // asymmetric vs "same": more boundary rows
    cfg.pad_w += 1;
    assert!(cfg.validate().is_ok());
    let mut rng = Xorshift::new(0x7EAD);
    let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    d.fill_relu_sparse(&mut rng, 0.5);
    let mut gflt = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    gflt.fill_uniform(&mut rng, -0.5, 0.5);
    let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    dy.fill_relu_sparse(&mut rng, 0.4);
    let gt = gflt.transpose_channels();
    let dt = BatchTiledTensor::from_act(&d);

    let mut y_s = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
    let mut st_f = KernelStats::new();
    sparse_fwd::fwd(&cfg, &d, &gflt, &mut y_s, SkipMode::MaskLoop, &mut st_f);
    let mut dd_s = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    let mut st_i = KernelStats::new();
    sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd_s, SkipMode::MaskLoop, &mut st_i);
    let mut dg_s = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    let mut st_w = KernelStats::new();
    sparse_bww::bww(&cfg, &dt, &dy, &mut dg_s, SkipMode::MaskLoop, &mut st_w);

    for threads in 1..=8 {
        let sched = Scheduler::new(threads);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let rf = sched.run_fwd(&cfg, &d, &gflt, &mut y, SkipMode::MaskLoop);
        assert_eq!(y.data(), y_s.data(), "FWD numerics, threads={threads}");
        assert_eq!(rf.stats, st_f, "FWD stats, threads={threads}");
        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let ri = sched.run_bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop);
        assert_eq!(dd.data(), dd_s.data(), "BWI numerics, threads={threads}");
        assert_eq!(ri.stats, st_i, "BWI stats, threads={threads}");
        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let rw = sched.run_bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop);
        assert_eq!(dg.data(), dg_s.data(), "BWW numerics, threads={threads}");
        assert_eq!(rw.stats, st_w, "BWW stats, threads={threads}");
    }
}

/// Projection pipeline produces the paper's ordering (E8) end to end.
#[test]
fn projection_pipeline_ordering() {
    let m = Machine::skylake_x();
    let (projections, _, _) = experiments::fig4_table6(&m, 50);
    let by_name = |n: &str| {
        projections
            .iter()
            .find(|p| p.network.name() == n)
            .unwrap()
            .speedup_excl_first(AlgoPolicy::SparseTrainOnly)
    };
    let vgg = by_name("VGG16");
    let r34 = by_name("ResNet-34");
    let r50 = by_name("ResNet-50");
    let fix = by_name("Fixup ResNet-50");
    assert!(vgg > r34 && vgg > r50 && vgg > fix, "VGG16 must benefit most");
    assert!(fix > r50, "Fixup (no BN) must beat plain ResNet-50");
}

/// §5.2: "we also experimented with several 5×5 layers and got even
/// higher speedup". In our model 5×5 lands in the same band as 3×3
/// (slightly below at high sparsity: Table 3 forces Q=64 for R=5, so
/// T=20 < 24 and the per-check floor bites marginally harder) — recorded
/// as a known small deviation in EXPERIMENTS.md; the kernel itself
/// supports R=5 end to end (functional tests in sparse_fwd).
#[test]
fn five_by_five_same_band_as_three_by_three() {
    let m = Machine::skylake_x();
    let c3 = ConvConfig::square(16, 256, 256, 28, 3, 1);
    let c5 = ConvConfig::square(16, 256, 256, 28, 5, 1);
    for s in [0.6, 0.8] {
        let s3 = speedup_over_direct(&m, Algorithm::SparseTrain, &c3, Component::Fwd, s);
        let s5 = speedup_over_direct(&m, Algorithm::SparseTrain, &c5, Component::Fwd, s);
        assert!(s5 > 1.5, "5x5 must still clearly win at s={s}: {s5:.2}");
        assert!(s5 > s3 * 0.9, "5x5 ({s5:.2}) within band of 3x3 ({s3:.2}) at s={s}");
    }
}

/// Gating since the mini-HLO interpreter landed: the three-layer stack
/// trains on a cold checkout (offline artifact fallback into a scratch
/// dir, independent of `./artifacts`) and the measured ReLU sparsity
/// lands in a plausible band.
#[test]
#[cfg_attr(miri, ignore)] // full-geometry interpreted train steps
fn pjrt_trainer_smoke() {
    let arts = ArtifactSet::scratch_fallback("integration-smoke").expect("offline fallback");
    let mut t =
        Trainer::new(&arts, TrainerConfig { steps: 8, seed: 3, log_every: 0, threads: 2, pipeline: None }).unwrap();
    let report = t.run().expect("interpreted training run");
    assert_eq!(report.losses.len(), 8);
    assert!(report.losses.iter().all(|l| l.is_finite() && *l > 0.0));
    for layer in ["conv1_relu", "conv2_relu"] {
        let s = report.profiler.mean(layer).unwrap();
        assert!((0.05..0.95).contains(&s), "{layer} sparsity {s}");
    }
}
