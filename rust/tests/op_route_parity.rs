//! Parity suite for the whole-graph op router (ISSUE 6): the blocked
//! parallel GEMM behind `dot`, the fused elementwise chains, the
//! broadcast/reduce fast paths, and the arena-backed evaluator.
//!
//! Contract pinned here (extending `conv_route_parity.rs`, which owns the
//! convolution half):
//!
//! * The **parallel GEMM is bit-exact vs the pinned serial blocked
//!   kernel** at any thread count and shape — per-C-row accumulation is
//!   p-ascending regardless of panel grouping — and allclose vs a naive
//!   triple loop (the kernel contracts with FMAs, so bit-equality with
//!   multiply-then-add is not a meaningful target).
//! * **Routed `dot` instructions** match the naive `Op::Dot` evaluator
//!   within tight tolerance across all four contracting-dim layouts and
//!   across thread counts, and actually route (counter-checked).
//! * **Fused elementwise chains** (bias add, ReLU max, SGD `w - lr·g`,
//!   log-softmax row subtract, ReLU-backward select) and the
//!   broadcast/reduce fast paths are **bit-identical** to the unfused
//!   naive evaluator — same per-element ops, same rounding count, same
//!   fold order.
//! * **Arena reuse** across repeated executions of one compiled
//!   executable is bit-identical to fresh-allocation runs.
//! * **Out-of-envelope ops** (rank-1 dots, plain tensor-tensor binaries,
//!   unrecognized reduce shapes) decline and fall back to the naive
//!   evaluator **bit-identically**, with the fallback counters showing
//!   the decline.
//! * The full `train_step` graph at the paper geometry routes all five
//!   convolutions and all three dots, fuses chains, and matches the
//!   naive interpreter end to end.

use sparsetrain::kernels::gemm::{gemm_parallel, gemm_with, pack_transpose, MB};
use sparsetrain::kernels::simd;
use sparsetrain::runtime::executor::{self, OpRouter};
use sparsetrain::runtime::hlo_builder::{self, Geometry};
use sparsetrain::runtime::pjrt::{literal_f32, literal_i32, Runtime};
use sparsetrain::tensor::allclose;
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config as PropConfig, UsizeIn};
use sparsetrain::util::threadpool::ThreadPool;
use std::sync::Arc;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Compile + execute one probe module, optionally with a router installed;
/// returns the flattened root (tuple roots are concatenated in order).
fn run_probe(text: &str, inputs: &[xla::Literal], router: Option<Arc<OpRouter>>) -> Vec<Vec<f32>> {
    let mut client = xla::PjRtClient::cpu().unwrap();
    if let Some(r) = router {
        client.set_op_executor(executor::hook(r));
    }
    let proto = xla::HloModuleProto::from_text(text).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let outs = exe.execute::<xla::Literal>(inputs).unwrap();
    let lit = outs[0][0].to_literal_sync().unwrap();
    match lit.clone().to_tuple() {
        Ok(parts) => parts.iter().map(|p| p.to_vec::<f32>().unwrap()).collect(),
        Err(_) => vec![lit.to_vec::<f32>().unwrap()],
    }
}

/// Naive row-major triple loop: the reassociation-free reference.
fn naive_matmul(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

// ---------------------------------------------------------------------------
// GEMM kernel: serial blocked vs parallel, and vs the naive triple loop
// ---------------------------------------------------------------------------

#[test]
fn property_gemm_parallel_is_bitexact_vs_serial_across_shapes_and_threads() {
    let bk = simd::dispatch();
    let gen = UsizeIn { lo: 0, hi: 15 };
    check(PropConfig { cases: 16, seed: 0x61, max_shrink_steps: 16 }, &gen, |&case| {
        let mut rng = Xorshift::new(500 + case as u64);
        // Cross panel boundaries (MB = 32) and the V-wide column tail.
        let m = [1, 3, MB - 1, MB, MB + 1, 2 * MB + 5][case % 6];
        let n = [1, 7, 17, 33][case / 4];
        let k = 1 + case % 9;
        let threads = 1 + case % 4;
        let a: Vec<f32> = (0..m * k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        let mut serial = vec![0.0f32; m * n];
        gemm_with(bk, m, n, k, &a, &b, &mut serial);
        let pool = ThreadPool::new(threads);
        let mut par = vec![0.0f32; m * n];
        gemm_parallel(&pool, bk, m, n, k, &a, &b, &mut par);
        if bits(&serial) != bits(&par) {
            return Err(format!(
                "case {case}: gemm_parallel not bit-equal to serial (m={m} n={n} k={k} t={threads})"
            ));
        }
        Ok(())
    });
}

#[test]
fn property_gemm_matches_naive_triple_loop() {
    let bk = simd::dispatch();
    let gen = UsizeIn { lo: 0, hi: 9 };
    check(PropConfig { cases: 10, seed: 0x62, max_shrink_steps: 16 }, &gen, |&case| {
        let mut rng = Xorshift::new(600 + case as u64);
        let m = 1 + case * 7 % 40;
        let n = 1 + case * 5 % 23;
        let k = 1 + case * 3 % 17;
        let a: Vec<f32> = (0..m * k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let want = naive_matmul(m, n, k, &a, &b);
        let mut got = vec![0.0f32; m * n];
        gemm_with(bk, m, n, k, &a, &b, &mut got);
        if !allclose(&got, &want, 1e-4, 1e-4) {
            return Err(format!("case {case}: gemm diverged from naive (m={m} n={n} k={k})"));
        }
        Ok(())
    });
}

#[test]
fn pack_transpose_is_an_exact_gather() {
    let mut rng = Xorshift::new(7);
    let (r, c) = (5, 9);
    let src: Vec<f32> = (0..r * c).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let t = pack_transpose(&src, r, c);
    for i in 0..r {
        for j in 0..c {
            assert_eq!(t[j * r + i].to_bits(), src[i * c + j].to_bits());
        }
    }
}

// ---------------------------------------------------------------------------
// Routed dot vs the naive Op::Dot evaluator, all four contracting layouts
// ---------------------------------------------------------------------------

fn dot_module(ld: [usize; 2], rd: [usize; 2], od: [usize; 2], lc: usize, rc: usize) -> String {
    format!(
        "HloModule dot_probe\n\nENTRY %dot_probe {{\n  \
         %lhs = f32[{},{}] parameter(0)\n  \
         %rhs = f32[{},{}] parameter(1)\n  \
         ROOT %out = f32[{},{}] dot(%lhs, %rhs), \
         lhs_contracting_dims={{{lc}}}, rhs_contracting_dims={{{rc}}}\n}}\n",
        ld[0], ld[1], rd[0], rd[1], od[0], od[1]
    )
}

#[test]
fn property_routed_dot_matches_naive_evaluator_all_layouts() {
    let gen = UsizeIn { lo: 0, hi: 15 };
    check(PropConfig { cases: 16, seed: 0x63, max_shrink_steps: 16 }, &gen, |&case| {
        let mut rng = Xorshift::new(700 + case as u64);
        // Both sides of the serial/parallel cutover (m <= MB stays serial).
        let m = [3, 16, MB + 3, 2 * MB][case % 4];
        let n = [5, 17][(case / 4) % 2];
        let k = 2 + case % 7;
        let threads = 1 + case % 3;
        let (lc, rc) = [(1, 0), (0, 0), (1, 1), (0, 1)][case % 4];
        let ld = if lc == 1 { [m, k] } else { [k, m] };
        let rd = if rc == 0 { [k, n] } else { [n, k] };
        let text = dot_module(ld, rd, [m, n], lc, rc);
        let lhs: Vec<f32> = (0..m * k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let rhs: Vec<f32> = (0..k * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let inputs = [
            literal_f32(&lhs, &ld.map(|d| d as i64)).unwrap(),
            literal_f32(&rhs, &rd.map(|d| d as i64)).unwrap(),
        ];
        let naive = run_probe(&text, &inputs, None);
        let router = Arc::new(OpRouter::new(threads));
        let routed = run_probe(&text, &inputs, Some(Arc::clone(&router)));
        let stats = router.stats();
        if stats.dot_routed != 1 || stats.dot_fallback != 0 {
            return Err(format!(
                "case {case} (lc={lc} rc={rc}): dot did not route ({stats:?})"
            ));
        }
        if !allclose(&routed[0], &naive[0], 1e-4, 1e-4) {
            return Err(format!("case {case} (lc={lc} rc={rc}): routed dot diverged"));
        }
        Ok(())
    });
}

/// The routed dot is deterministic across thread counts: the GEMM's
/// per-row accumulation order is p-ascending regardless of panel split.
#[test]
fn routed_dot_is_bit_identical_across_thread_counts() {
    let (m, n, k) = (2 * MB + 7, 17, 9);
    let mut rng = Xorshift::new(42);
    let lhs: Vec<f32> = (0..m * k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let rhs: Vec<f32> = (0..k * n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let text = dot_module([m, k], [k, n], [m, n], 1, 0);
    let inputs = [
        literal_f32(&lhs, &[m as i64, k as i64]).unwrap(),
        literal_f32(&rhs, &[k as i64, n as i64]).unwrap(),
    ];
    let reference = run_probe(&text, &inputs, Some(Arc::new(OpRouter::new(1))));
    for threads in [2, 3, 4] {
        let got = run_probe(&text, &inputs, Some(Arc::new(OpRouter::new(threads))));
        assert_eq!(
            bits(&reference[0]),
            bits(&got[0]),
            "routed dot differs between 1 and {threads} threads"
        );
    }
}

// ---------------------------------------------------------------------------
// Fused elementwise chains + broadcast/reduce fast paths: bit-identical
// ---------------------------------------------------------------------------

/// One module exercising every fused/fast-path form the router recognizes:
/// bias add (dim-1 vector broadcast), ReLU max vs a zero splat, the
/// ReLU-backward compare+select chain, the SGD `w - lr·g` chain, the
/// log-softmax-style row subtract (dim-0 vector broadcast), and row /
/// column / full reductions.
fn fused_chain_module(n: usize, c: usize) -> String {
    let s2 = format!("f32[{n},{c}]");
    let p2 = format!("pred[{n},{c}]");
    format!(
        "HloModule fused_probe\n\n\
         %add_f32 {{\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  \
         ROOT %s = f32[] add(%p0, %p1)\n}}\n\n\
         %max_f32 {{\n  %q0 = f32[] parameter(0)\n  %q1 = f32[] parameter(1)\n  \
         ROOT %m = f32[] maximum(%q0, %q1)\n}}\n\n\
         ENTRY %fused_probe {{\n  \
         %x = {s2} parameter(0)\n  \
         %b = f32[{c}] parameter(1)\n  \
         %g = {s2} parameter(2)\n  \
         %zero = f32[] constant(0)\n  \
         %zb = {s2} broadcast(%zero), dimensions={{}}\n  \
         %bb = {s2} broadcast(%b), dimensions={{1}}\n  \
         %biased = {s2} add(%x, %bb)\n  \
         %relu = {s2} maximum(%biased, %zb)\n  \
         %mask = {p2} compare(%biased, %zb), direction=GT\n  \
         %dz = {s2} select(%mask, %g, %zb)\n  \
         %lr = f32[] constant(0.25)\n  \
         %lrb = {s2} broadcast(%lr), dimensions={{}}\n  \
         %step = {s2} multiply(%lrb, %dz)\n  \
         %new_x = {s2} subtract(%x, %step)\n  \
         %rows = f32[{n}] reduce(%relu, %zero), dimensions={{1}}, to_apply=%add_f32\n  \
         %rows_b = {s2} broadcast(%rows), dimensions={{0}}\n  \
         %centered = {s2} subtract(%relu, %rows_b)\n  \
         %cols = f32[{c}] reduce(%centered, %zero), dimensions={{0}}, to_apply=%add_f32\n  \
         %peak = f32[] reduce(%centered, %zero), dimensions={{0,1}}, to_apply=%max_f32\n  \
         ROOT %t = ({s2}, {s2}, f32[{n}], f32[{c}], f32[]) \
         tuple(%new_x, %centered, %rows, %cols, %peak)\n}}\n"
    )
}

#[test]
fn fused_chains_are_bit_identical_to_the_unfused_evaluator() {
    let (n, c) = (5, 7);
    let mut rng = Xorshift::new(11);
    let x: Vec<f32> = (0..n * c).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..c).map(|_| rng.range_f32(-0.5, 0.5)).collect();
    let g: Vec<f32> = (0..n * c).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let inputs = [
        literal_f32(&x, &[n as i64, c as i64]).unwrap(),
        literal_f32(&b, &[c as i64]).unwrap(),
        literal_f32(&g, &[n as i64, c as i64]).unwrap(),
    ];
    let text = fused_chain_module(n, c);
    let naive = run_probe(&text, &inputs, None);
    let router = Arc::new(OpRouter::new(2));
    let routed = run_probe(&text, &inputs, Some(Arc::clone(&router)));
    assert_eq!(naive.len(), routed.len());
    for (i, (a, r)) in naive.iter().zip(&routed).enumerate() {
        assert_eq!(bits(a), bits(r), "fused output {i} not bit-identical to unfused");
    }
    let stats = router.stats();
    // bias add, ReLU max, select, SGD subtract, row-centering subtract
    assert!(stats.fused >= 5, "expected >= 5 fused chains, got {stats:?}");
    // splat/vector broadcasts + the three reduces take the fast paths
    assert!(stats.ew_routed >= 4, "expected broadcast/reduce fast paths, got {stats:?}");
    assert_eq!(stats.dot_routed + stats.dot_fallback, 0, "no dots in this module");
}

// ---------------------------------------------------------------------------
// Arena reuse across repeated executions of one compiled executable
// ---------------------------------------------------------------------------

#[test]
fn arena_reuse_across_executions_is_bit_identical() {
    let (n, c) = (6, 9);
    let text = fused_chain_module(n, c);
    let mut rng = Xorshift::new(23);
    let x: Vec<f32> = (0..n * c).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..c).map(|_| rng.range_f32(-0.5, 0.5)).collect();
    let g: Vec<f32> = (0..n * c).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let inputs = [
        literal_f32(&x, &[n as i64, c as i64]).unwrap(),
        literal_f32(&b, &[c as i64]).unwrap(),
        literal_f32(&g, &[n as i64, c as i64]).unwrap(),
    ];

    // Fresh client per run: every execution allocates from an empty arena.
    let fresh = run_probe(&text, &inputs, Some(Arc::new(OpRouter::new(2))));

    // One client, one executable, repeated runs: later executions recycle
    // the earlier runs' buffers through the persistent arena.
    let mut client = xla::PjRtClient::cpu().unwrap();
    client.set_op_executor(executor::hook(Arc::new(OpRouter::new(2))));
    let proto = xla::HloModuleProto::from_text(&text).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    for round in 0..3 {
        let outs = exe.execute::<xla::Literal>(&inputs).unwrap();
        let parts = outs[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        for (i, (f, p)) in fresh.iter().zip(&parts).enumerate() {
            assert_eq!(
                bits(f),
                bits(&p.to_vec::<f32>().unwrap()),
                "round {round} output {i}: arena reuse changed the result"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Out-of-envelope ops: decline, count the fallback, stay bit-identical
// ---------------------------------------------------------------------------

#[test]
fn rank1_dot_falls_back_bit_identically() {
    let k = 13;
    let text = format!(
        "HloModule r1dot\n\nENTRY %r1dot {{\n  \
         %lhs = f32[{k}] parameter(0)\n  \
         %rhs = f32[{k}] parameter(1)\n  \
         ROOT %out = f32[] dot(%lhs, %rhs), \
         lhs_contracting_dims={{0}}, rhs_contracting_dims={{0}}\n}}\n"
    );
    let mut rng = Xorshift::new(31);
    let a: Vec<f32> = (0..k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let inputs = [
        literal_f32(&a, &[k as i64]).unwrap(),
        literal_f32(&b, &[k as i64]).unwrap(),
    ];
    let naive = run_probe(&text, &inputs, None);
    let router = Arc::new(OpRouter::new(2));
    let routed = run_probe(&text, &inputs, Some(Arc::clone(&router)));
    let stats = router.stats();
    assert_eq!(stats.dot_routed, 0, "rank-1 dot must not route");
    assert_eq!(stats.dot_fallback, 1, "rank-1 dot must count as a dot fallback");
    assert_eq!(bits(&naive[0]), bits(&routed[0]), "fallback not bit-identical");
}

#[test]
fn unrecognized_elementwise_and_reduce_shapes_fall_back_bit_identically() {
    // A plain tensor - tensor subtract (no broadcast operand: outside the
    // fusion envelope) and a rank-3 reduce over a middle dim (no fast
    // path). Both must decline, count, and reproduce the naive bits.
    let (a, b, c) = (3, 4, 5);
    let text = format!(
        "HloModule oov\n\n\
         %add_f32 {{\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  \
         ROOT %s = f32[] add(%p0, %p1)\n}}\n\n\
         ENTRY %oov {{\n  \
         %x = f32[{a},{b},{c}] parameter(0)\n  \
         %y = f32[{a},{b},{c}] parameter(1)\n  \
         %zero = f32[] constant(0)\n  \
         %diff = f32[{a},{b},{c}] subtract(%x, %y)\n  \
         %mid = f32[{a},{c}] reduce(%diff, %zero), dimensions={{1}}, to_apply=%add_f32\n  \
         ROOT %t = (f32[{a},{b},{c}], f32[{a},{c}]) tuple(%diff, %mid)\n}}\n"
    );
    let n = a * b * c;
    let mut rng = Xorshift::new(37);
    let xv: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let yv: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let inputs = [
        literal_f32(&xv, &[a as i64, b as i64, c as i64]).unwrap(),
        literal_f32(&yv, &[a as i64, b as i64, c as i64]).unwrap(),
    ];
    let naive = run_probe(&text, &inputs, None);
    let router = Arc::new(OpRouter::new(2));
    let routed = run_probe(&text, &inputs, Some(Arc::clone(&router)));
    let stats = router.stats();
    assert!(stats.ew_fallback >= 2, "subtract + rank-3 reduce must both decline: {stats:?}");
    assert_eq!(stats.fused, 0, "nothing in this module is fusable: {stats:?}");
    for (i, (av, rv)) in naive.iter().zip(&routed).enumerate() {
        assert_eq!(bits(av), bits(rv), "fallback output {i} not bit-identical");
    }
}

/// The kill switch works per class: a router built with
/// `SPARSETRAIN_OP_ROUTE=off` semantics never touches non-conv ops. (The
/// env var itself is read at construction; `route_op`'s envelope tests
/// above cover the on state, and `conv_route_parity` covers convs.)
#[test]
fn op_route_kill_switch_counts_nothing_when_disabled() {
    if executor::op_routing_enabled() {
        return; // only meaningful when the suite runs with the switch off
    }
    let text = dot_module([4, 3], [3, 5], [4, 5], 1, 0);
    let mut rng = Xorshift::new(41);
    let a: Vec<f32> = (0..12).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..15).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let inputs =
        [literal_f32(&a, &[4, 3]).unwrap(), literal_f32(&b, &[3, 5]).unwrap()];
    let naive = run_probe(&text, &inputs, None);
    let router = Arc::new(OpRouter::new(1));
    let routed = run_probe(&text, &inputs, Some(Arc::clone(&router)));
    let stats = router.stats();
    assert_eq!(stats.dot_routed + stats.dot_fallback + stats.fused + stats.ew_routed, 0);
    assert_eq!(bits(&naive[0]), bits(&routed[0]));
}

// ---------------------------------------------------------------------------
// Full train step: routed vs naive, paper geometry, all counters
// ---------------------------------------------------------------------------

/// The paper-geometry train step must route all five convolutions AND all
/// three dots, fuse elementwise chains, and agree with the naive
/// interpreter across the complete 7-output contract.
#[test]
fn train_step_op_routed_matches_naive_end_to_end() {
    let g = Geometry::paper();
    let dir = std::env::temp_dir()
        .join(format!("sparsetrain-oprouteparity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("train_step.hlo.txt"), hlo_builder::train_step_hlo(&g)).unwrap();

    let mut rng = Xorshift::new(99);
    let bound = |fan: usize| (2.0f32 / fan as f32).sqrt();
    let w1: Vec<f32> = (0..g.c1 * g.c_in * 9)
        .map(|_| rng.range_f32(-bound(g.c_in * 9), bound(g.c_in * 9)))
        .collect();
    let w2: Vec<f32> =
        (0..g.c2 * g.c1 * 9).map(|_| rng.range_f32(-bound(g.c1 * 9), bound(g.c1 * 9))).collect();
    let wfc: Vec<f32> =
        (0..g.classes * g.c2).map(|_| rng.range_f32(-bound(g.c2), bound(g.c2))).collect();
    let bfc = vec![0.0f32; g.classes];
    let x: Vec<f32> = (0..g.n * g.c_in * g.hw * g.hw).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let labels: Vec<i32> = (0..g.n).map(|_| rng.below(g.classes) as i32).collect();
    let inputs = vec![
        literal_f32(&w1, &[g.c1 as i64, g.c_in as i64, 3, 3]).unwrap(),
        literal_f32(&w2, &[g.c2 as i64, g.c1 as i64, 3, 3]).unwrap(),
        literal_f32(&wfc, &[g.classes as i64, g.c2 as i64]).unwrap(),
        literal_f32(&bfc, &[g.classes as i64]).unwrap(),
        literal_f32(&x, &[g.n as i64, g.c_in as i64, g.hw as i64, g.hw as i64]).unwrap(),
        literal_i32(&labels, &[g.n as i64]).unwrap(),
    ];

    let mut naive_rt = Runtime::cpu_naive(&dir).unwrap();
    let naive = naive_rt.load("train_step").unwrap().run(&inputs).unwrap();

    let mut routed_rt = Runtime::cpu_with_threads(&dir, 2).unwrap();
    let routed = routed_rt.load("train_step").unwrap().run(&inputs).unwrap();

    assert_eq!(naive.len(), 7);
    assert_eq!(routed.len(), 7);
    if let Some(router) = routed_rt.op_router() {
        let stats = router.stats();
        if executor::routing_enabled() {
            assert_eq!(stats.conv_routed, 5, "all five convolutions must route: {stats:?}");
            assert_eq!(stats.conv_fallback, 0, "{stats:?}");
        }
        if executor::op_routing_enabled() {
            assert_eq!(stats.dot_routed, 3, "all three dots must route: {stats:?}");
            assert_eq!(stats.dot_fallback, 0, "{stats:?}");
            assert!(stats.fused > 0, "the train step must fuse chains: {stats:?}");
            assert!(stats.ew_routed > 0, "broadcast/reduce fast paths must run: {stats:?}");
        }
    }
    for (i, (a, b)) in naive.iter().zip(&routed).enumerate() {
        let (av, bv) = (a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        assert!(
            allclose(&bv, &av, 1e-3, 1e-4),
            "train_step output {i} diverged between naive and op-routed"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
