//! Parity suite for the dependency-scheduled evaluator (ISSUE 10).
//!
//! The DAG executor may co-schedule any instruction pair it has proven
//! data-independent, and independent ops commute — so pipelined
//! evaluation must be **bit-identical** to sequential evaluation under
//! every planner policy, at every thread count, warm or cold cost DB.
//! This suite pins that contract end to end:
//!
//! * **Every planner policy produces the same bits.** A fan-out probe
//!   (three independent in-envelope convolutions feeding one root) is
//!   run naive, sequential-routed, and pipelined under the real
//!   cost-gated planner plus rigged always-overlap / never-overlap
//!   planners, across 1–3 threads — all runs bit-equal, with
//!   [`OpRouter::overlap_pairs`] proving which policy actually fired.
//! * **The measured-scaling gate works end to end**: a DB rigged with
//!   near-linear scaling keeps the whole module sequential (zero pairs);
//!   one rigged with poor scaling co-schedules. Bits never move.
//! * **The real train-step graph survives forced overlap**: the full
//!   reduced-geometry `train_step` artifact — the graph whose BWI‖BWW
//!   independence this ISSUE exploits — is bit-compared against naive
//!   evaluation under an always-overlap planner and the gated one.
//! * **The trainer kill switch restores sequential behavior exactly**:
//!   `TrainerConfig { pipeline: Some(false) }` (the race-free spelling
//!   of `SPARSETRAIN_PIPELINE=off`) yields a loss series bit-identical
//!   to `Some(true)` at 2 threads.
//!
//! CI runs this target twice — default env and `SPARSETRAIN_PIPELINE=off`
//! — because the explicit `pipeline:` overrides here must beat the
//! environment in both directions.

use sparsetrain::coordinator::pipeline;
use sparsetrain::coordinator::trainer::{Trainer, TrainerConfig};
use sparsetrain::coordinator::{CostDb, CostKey};
use sparsetrain::kernels::{Component, ConvConfig, SkipMode};
use sparsetrain::runtime::artifacts::ArtifactSet;
use sparsetrain::runtime::executor::{self, OpRouter};
use sparsetrain::runtime::hlo_builder::{self, Geometry};
use sparsetrain::runtime::pjrt::{literal_f32, literal_i32};
use sparsetrain::tensor::{ActTensor, FilterTensor};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config as PropConfig, UsizeIn};
use sparsetrain::V;
use std::sync::Arc;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Compile + execute one probe module, optionally with a router hook
/// and/or a pipeline planner installed; tuple roots flatten in order.
fn run_probe(
    text: &str,
    inputs: &[xla::Literal],
    router: Option<Arc<OpRouter>>,
    planner: Option<Arc<xla::PipelinePlanner>>,
) -> Vec<Vec<f32>> {
    let mut client = xla::PjRtClient::cpu().unwrap();
    if let Some(r) = router {
        client.set_op_executor(executor::hook(r));
    }
    if let Some(p) = planner {
        client.set_pipeline_planner(p);
    }
    let proto = xla::HloModuleProto::from_text(text).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let outs = exe.execute::<xla::Literal>(inputs).unwrap();
    let lit = outs[0][0].to_literal_sync().unwrap();
    match lit.clone().to_tuple() {
        Ok(parts) => parts.iter().map(|p| p.to_vec::<f32>().unwrap()).collect(),
        Err(_) => vec![lit.to_vec::<f32>().unwrap()],
    }
}

/// Coerce a closure to the vendored crate's higher-ranked join type.
fn join_arc<F>(f: F) -> Arc<xla::JoinFn>
where
    F: for<'a> Fn(xla::TaskBox<'a>, xla::TaskBox<'a>) + Send + Sync + 'static,
{
    Arc::new(f)
}

/// A planner with the production `join` (the router's pool fork-join)
/// but a rigged constant `overlap` — `true` forces co-scheduling of
/// every independent ready pair, `false` declines all of them.
fn fixed_planner(router: &Arc<OpRouter>, allow: bool) -> Arc<xla::PipelinePlanner> {
    let jr = Arc::clone(router);
    Arc::new(xla::PipelinePlanner {
        join: join_arc(move |a, b| jr.overlap_join(a, b)),
        overlap: Arc::new(move |_: &xla::hlo::Computation, _: usize, _: usize| allow),
    })
}

/// The fan-out probe: three mutually independent, in-envelope FWD convs
/// over shared parameters, joined by elementwise ops — after the
/// parameters evaluate, all three convs are ready at once, so the DAG
/// executor has real overlap opportunities on every run.
fn fanout_probe(case: usize, sparsity: f64) -> (ConvConfig, String, Vec<xla::Literal>) {
    let hw = 4 + case % 3;
    let cfg = ConvConfig::square(2, V, V * (1 + case % 2), hw, 3, 1);
    let mut rng = Xorshift::new(0xA10 + case as u64);
    let mut x = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
    x.fill_relu_sparse(&mut rng, sparsity);
    let mut w1 = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    w1.fill_uniform(&mut rng, -0.5, 0.5);
    let mut w2 = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
    w2.fill_uniform(&mut rng, -0.25, 0.25);

    let (n, c, k, h, w) = (cfg.n, cfg.c, cfg.k, cfg.h, cfg.w);
    let text = format!(
        "HloModule pipeline_probe\n\nENTRY %pipeline_probe {{\n  \
         %x = f32[{n},{c},{h},{w}] parameter(0)\n  \
         %w1 = f32[{k},{c},3,3] parameter(1)\n  \
         %w2 = f32[{k},{c},3,3] parameter(2)\n  \
         %ca = f32[{n},{k},{h},{w}] convolution(%x, %w1), \
         window={{size=3x3 pad=1_1x1_1 stride=1x1}}, dim_labels=bf01_oi01->bf01\n  \
         %cb = f32[{n},{k},{h},{w}] convolution(%x, %w2), \
         window={{size=3x3 pad=1_1x1_1 stride=1x1}}, dim_labels=bf01_oi01->bf01\n  \
         %cc = f32[{n},{k},{h},{w}] convolution(%x, %w1), \
         window={{size=3x3 pad=1_1x1_1 stride=1x1}}, dim_labels=bf01_oi01->bf01\n  \
         %s = f32[{n},{k},{h},{w}] add(%ca, %cb)\n  \
         ROOT %p = f32[{n},{k},{h},{w}] multiply(%s, %cc)\n}}\n"
    );
    let inputs = vec![
        literal_f32(&x.to_nchw(), &[n as i64, c as i64, h as i64, w as i64]).unwrap(),
        literal_f32(&w1.to_kcsr(), &[k as i64, c as i64, 3, 3]).unwrap(),
        literal_f32(&w2.to_kcsr(), &[k as i64, c as i64, 3, 3]).unwrap(),
    ];
    (cfg, text, inputs)
}

// ---------------------------------------------------------------------------
// Every planner policy, every thread count: same bits, counters prove policy
// ---------------------------------------------------------------------------

#[test]
fn property_pipelined_run_is_bit_identical_to_sequential_across_policies() {
    let gen = UsizeIn { lo: 0, hi: 7 };
    check(PropConfig { cases: 8, seed: 0x101, max_shrink_steps: 8 }, &gen, |&case| {
        let threads = 1 + case % 3;
        let sparsity = [0.0, 0.5, 0.9][case % 3];
        let (_, text, inputs) = fanout_probe(case, sparsity);

        // Reference: the strictly sequential naive evaluator.
        let want = bits(&run_probe(&text, &inputs, None, None)[0]);

        // Routed but planner-free: PR 9 behavior, still sequential.
        let seq = Arc::new(OpRouter::with_cost_db(threads, None));
        let seq_out = run_probe(&text, &inputs, Some(Arc::clone(&seq)), None);
        if bits(&seq_out[0]) != want {
            return Err(format!("case {case} t={threads}: sequential routed run diverged"));
        }
        if seq.overlap_pairs() != 0 {
            return Err(format!("case {case}: pairs overlapped without a planner"));
        }

        let gated = Arc::new(OpRouter::with_cost_db(threads, None));
        let always = Arc::new(OpRouter::with_cost_db(threads, None));
        let never = Arc::new(OpRouter::with_cost_db(threads, None));
        let runs = [
            ("cost-gated", Arc::clone(&gated), pipeline::planner(&gated)),
            ("always-overlap", Arc::clone(&always), fixed_planner(&always, true)),
            ("never-overlap", Arc::clone(&never), fixed_planner(&never, false)),
        ];
        for (tag, router, planner) in runs {
            let out = run_probe(&text, &inputs, Some(Arc::clone(&router)), Some(planner));
            if bits(&out[0]) != want {
                return Err(format!("case {case} t={threads} {tag}: pipelined run changed bits"));
            }
            let pairs = router.overlap_pairs();
            let policy_held = match tag {
                // Rigged off: the ready-queue walk must degenerate to
                // the sequential order.
                "never-overlap" => pairs == 0,
                // Rigged on: some pair is always ready together (the
                // three parameters, then the three convs).
                "always-overlap" => pairs >= 1,
                // Real gate, cold DB: convs overlap iff there is a
                // second worker to overlap onto.
                _ => (threads >= 2) == (pairs >= 1),
            };
            if !policy_held {
                return Err(format!(
                    "case {case} t={threads} {tag}: unexpected overlap count {pairs}"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// The measured-scaling gate, end to end through a live evaluator
// ---------------------------------------------------------------------------

#[test]
fn measured_scaling_gate_controls_overlap_end_to_end() {
    let threads = 2;
    let (cfg, text, inputs) = fanout_probe(0, 0.5);
    let want = bits(&run_probe(&text, &inputs, None, None)[0]);
    let bk = sparsetrain::kernels::simd::dispatch().name();
    let seed = |db: &CostDb, t: usize, ns: f64| {
        db.record(CostKey::conv(Component::Fwd, &cfg, 0.5, t, bk, SkipMode::Dense), ns);
    };

    // Near-linear measured scaling (1.9x at 2 threads, efficiency 0.95):
    // the conv already fills the pool, so the gate must keep every pair
    // sequential. Seeded astronomically large so the run's own lazy cost
    // records (real microsecond samples) can only *raise* the measured
    // speedup ratio — the refusal is stable for the whole module.
    let db = Arc::new(CostDb::in_memory());
    seed(&db, 1, 1.9e12);
    seed(&db, 2, 1.0e12);
    let router = Arc::new(OpRouter::with_cost_db(threads, Some(Arc::clone(&db))));
    let out =
        run_probe(&text, &inputs, Some(Arc::clone(&router)), Some(pipeline::planner(&router)));
    assert_eq!(bits(&out[0]), want, "gated-off pipelined run changed bits");
    assert_eq!(router.overlap_pairs(), 0, "near-linear scaling must stay sequential");

    // Poor scaling (1.05x at 2 threads, efficiency 0.53 < 0.6): a worker
    // idles during the conv, so the gate co-schedules the ready partner.
    let db = Arc::new(CostDb::in_memory());
    seed(&db, 1, 2.0e12);
    seed(&db, 2, 1.9e12);
    let router = Arc::new(OpRouter::with_cost_db(threads, Some(Arc::clone(&db))));
    let out =
        run_probe(&text, &inputs, Some(Arc::clone(&router)), Some(pipeline::planner(&router)));
    assert_eq!(bits(&out[0]), want, "gated-on pipelined run changed bits");
    assert!(router.overlap_pairs() >= 1, "under-filled pool must co-schedule");
}

// ---------------------------------------------------------------------------
// The real train-step graph under forced and gated overlap
// ---------------------------------------------------------------------------

/// The graph this ISSUE is actually about: the reduced-geometry
/// `train_step` artifact, whose backward pass contains the independent
/// BWI‖BWW convolution pairs. Forced overlap stresses every independent
/// pair the DAG admits (including elementwise/reduce ops); the gated
/// planner exercises the production policy. All seven outputs — updated
/// weights, loss, sparsity stats — must match naive evaluation bit for
/// bit.
#[test]
#[cfg_attr(miri, ignore)] // several full interpreted train-step evaluations
fn train_step_graph_is_bit_identical_under_forced_overlap() {
    let g = Geometry::tiny();
    let text = hlo_builder::train_step_hlo(&g);
    let mut rng = Xorshift::new(0x57E9);
    let mut rand = |n: usize, b: f32| -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-b, b)).collect()
    };
    let w1 = rand(g.c1 * g.c_in * 9, 0.4);
    let w2 = rand(g.c2 * g.c1 * 9, 0.4);
    let wfc = rand(g.classes * g.c2, 0.4);
    let bfc = vec![0.0f32; g.classes];
    let x = rand(g.n * g.c_in * g.hw * g.hw, 1.0);
    let labels: Vec<i32> = (0..g.n).map(|i| (i % g.classes) as i32).collect();
    let inputs = vec![
        literal_f32(&w1, &[g.c1 as i64, g.c_in as i64, 3, 3]).unwrap(),
        literal_f32(&w2, &[g.c2 as i64, g.c1 as i64, 3, 3]).unwrap(),
        literal_f32(&wfc, &[g.classes as i64, g.c2 as i64]).unwrap(),
        literal_f32(&bfc, &[g.classes as i64]).unwrap(),
        literal_f32(&x, &[g.n as i64, g.c_in as i64, g.hw as i64, g.hw as i64]).unwrap(),
        literal_i32(&labels, &[g.n as i64]).unwrap(),
    ];

    let naive = run_probe(&text, &inputs, None, None);
    assert_eq!(naive.len(), 7, "train_step must keep the 7-output contract");

    for threads in [2usize, 3] {
        let forced = Arc::new(OpRouter::with_cost_db(threads, None));
        let piped = run_probe(
            &text,
            &inputs,
            Some(Arc::clone(&forced)),
            Some(fixed_planner(&forced, true)),
        );
        assert!(
            forced.overlap_pairs() >= 1,
            "t={threads}: forced overlap must co-schedule on the train-step graph"
        );
        for (i, (a, b)) in naive.iter().zip(&piped).enumerate() {
            assert_eq!(bits(a), bits(b), "t={threads} forced-overlap output {i} diverged");
        }

        let gated = Arc::new(OpRouter::with_cost_db(threads, None));
        let piped = run_probe(
            &text,
            &inputs,
            Some(Arc::clone(&gated)),
            Some(pipeline::planner(&gated)),
        );
        for (i, (a, b)) in naive.iter().zip(&piped).enumerate() {
            assert_eq!(bits(a), bits(b), "t={threads} cost-gated output {i} diverged");
        }
    }
}

// ---------------------------------------------------------------------------
// Trainer kill switch: pipeline off restores sequential behavior exactly
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore)] // two full interpreted training runs
fn trainer_losses_are_bit_identical_with_pipeline_on_and_off() {
    let arts = ArtifactSet::scratch_fallback("pipeline-parity").expect("offline fallback");
    let steps = 6;
    let run = |pipeline: bool| {
        let mut t = Trainer::new(
            &arts,
            TrainerConfig {
                steps,
                seed: 11,
                log_every: 0,
                threads: 2,
                pipeline: Some(pipeline),
            },
        )
        .expect("trainer init");
        // The explicit override must beat the environment in both
        // directions; a router-less runtime (route kill switches) can
        // only force it off, never on.
        let routed = executor::routing_enabled() || executor::op_routing_enabled();
        assert_eq!(t.pipelined(), pipeline && routed, "pipelined flag must follow the override");
        t.run().expect("training run").losses
    };

    let on = run(true);
    let off = run(false);
    assert_eq!(on.len(), steps);
    assert!(on.iter().all(|l| l.is_finite() && *l > 0.0), "{on:?}");
    let on_bits: Vec<u32> = on.iter().map(|l| l.to_bits()).collect();
    let off_bits: Vec<u32> = off.iter().map(|l| l.to_bits()).collect();
    assert_eq!(
        on_bits, off_bits,
        "pipeline on/off loss series must be bit-identical: {on:?} vs {off:?}"
    );
}
