//! End-to-end trainer coverage through the mini-HLO interpreter — gating,
//! cold-checkout, no Python, no pre-built artifacts.
//!
//! * the full `Trainer` loop runs and **learns** (`TrainReport::learned`)
//!   with a monotone-ish loss drop at a fixed seed;
//! * the `SparsityProfiler` series from the interpreted run is non-empty
//!   with per-layer ReLU sparsity strictly inside (0, 1) — the paper's
//!   dynamic-sparsity premise measured inside a real training loop;
//! * interpreter `convolution` is bit-compared against
//!   `kernels::reference::conv_fwd`;
//! * `dot` / `reduce` / the softmax-cross-entropy subgraph match
//!   hand-computed golden values;
//! * the train-step backward pass is finite-difference-verified on a
//!   reduced geometry;
//! * the HLO parser survives `util::proptest` mangling of artifact text
//!   (`Err`, never a panic).

use sparsetrain::coordinator::trainer::{Trainer, TrainerConfig};
use sparsetrain::kernels::{reference, ConvConfig};
use sparsetrain::nets::{Network, Scale};
use sparsetrain::runtime::artifacts::{ArtifactSet, KERNEL_FWD, TRAIN_STEP};
use sparsetrain::runtime::hlo_builder::{self, Geometry};
use sparsetrain::runtime::pjrt::{literal_f32, literal_i32, Runtime};
use sparsetrain::util::prng::Xorshift;
use sparsetrain::util::proptest::{check, Config, UsizeIn, VecOfUsize};
use sparsetrain::util::stats::mean;

/// A unique scratch artifacts directory for tests that write custom
/// (reduced-geometry) artifact files. Wiped on creation so pid reuse
/// cannot resurrect files from an older run.
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sparsetrain-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn rand_vec(rng: &mut Xorshift, n: usize, bound: f32) -> Vec<f32> {
    (0..n).map(|_| rng.range_f32(-bound, bound)).collect()
}

// ---------------------------------------------------------------------------
// The headline E2E: cold checkout → fallback artifacts → learning run
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore)] // full-geometry interpreted training loop
fn e2e_trainer_learns_on_cold_checkout() {
    let arts = ArtifactSet::scratch_fallback("e2e-trainer").expect("offline fallback");
    assert!(arts.complete(), "fallback must satisfy the manifest: {:?}", arts.missing());

    let steps = 30;
    let mut trainer =
        Trainer::new(&arts, TrainerConfig { steps, seed: 1, log_every: 0, threads: 2, pipeline: None })
            .expect("trainer init");
    let report = trainer.run().expect("interpreted training run");

    assert_eq!(report.losses.len(), steps);
    assert!(report.losses.iter().all(|l| l.is_finite() && *l > 0.0), "{:?}", report.losses);
    assert!(report.steps_per_sec > 0.0);
    assert!(
        report.learned(),
        "loss did not drop ≥20% over {steps} interpreted steps: {:?}",
        report.losses
    );

    // Monotone-ish: the mean loss of each third of the run strictly
    // decreases (robust to per-step noise, strict about the trend).
    let (a, b, c) = (
        mean(&report.losses[..steps / 3]),
        mean(&report.losses[steps / 3..2 * steps / 3]),
        mean(&report.losses[2 * steps / 3..]),
    );
    assert!(b < a && c < b, "loss thirds must decrease: {a:.4} -> {b:.4} -> {c:.4}");

    // E2E dynamic-sparsity signal: both ReLU layers report a non-empty
    // series with every observation strictly inside (0, 1).
    for layer in ["conv1_relu", "conv2_relu"] {
        let series = report.profiler.series(layer).unwrap_or_else(|| panic!("{layer} missing"));
        assert_eq!(series.len(), steps, "{layer} series must cover every step");
        assert!(
            series.iter().all(|&s| s > 0.0 && s < 1.0),
            "{layer} sparsity left (0,1): {series:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Multi-layer zoo training: emitted ResNet graphs run, route, and measure
// ---------------------------------------------------------------------------

/// The CI smoke behind `train --net resnet34`: a reduced-scale ResNet-34
/// trains for a few steps with finite loss, every strided/downsample conv
/// is served by the widened router envelope (routed, zero fallbacks), and
/// per-layer sparsity is measured each step. Doubles as the §2.3 check on
/// the BN side: with BN after every conv, the measured output-gradient
/// (dz) sparsity collapses to ~0.
#[test]
#[cfg_attr(miri, ignore)] // multi-layer interpreted training steps
fn e2e_resnet34_small_trains_with_routed_strided_convs() {
    let dir = scratch_dir("resnet34-small");
    let arts = ArtifactSet::new(&dir);
    let steps = 4;
    let mut t = Trainer::new_net(
        &arts,
        Network::ResNet34,
        Scale::Small,
        TrainerConfig { steps, seed: 1, log_every: 0, threads: 2, pipeline: None },
    )
    .expect("net trainer init");
    let plan = t.net_plan().expect("net trainer carries a plan").clone();
    assert!(
        plan.strided_fwd.len() >= 4,
        "resnet34 must hit strided forms: {:?}",
        plan.strided_fwd
    );

    let report = t.run().expect("resnet34-small training");
    assert_eq!(report.losses.len(), steps);
    assert!(report.losses.iter().all(|l| l.is_finite() && *l > 0.0), "{:?}", report.losses);

    // per-layer measured sparsity: every ReLU and dz series covers the run
    for key in plan.relu_keys.iter().chain(&plan.dz_keys) {
        let series = report.profiler.series(key).unwrap_or_else(|| panic!("{key} missing"));
        assert_eq!(series.len(), steps, "{key} series must cover every step");
    }
    // §2.3, BN side: BatchNorm's backward mean terms densify the gradient
    for key in &plan.dz_keys {
        let series = report.profiler.series(key).unwrap();
        let m = mean(series);
        assert!(m < 0.05, "{key}: BN layer dz sparsity should be ~0, got {m:.3}");
    }

    if let Some(router) = t.op_router() {
        let stats: std::collections::BTreeMap<String, (usize, usize)> =
            router.conv_layer_stats().into_iter().map(|(n, r, f)| (n, (r, f))).collect();
        assert!(!stats.is_empty(), "convs must reach the router");
        for instr in &plan.strided_fwd {
            let &(routed, fb) = stats
                .get(instr)
                .unwrap_or_else(|| panic!("strided conv {instr} never reached the router"));
            assert!(routed > 0, "{instr} must be kernel-routed");
            assert_eq!(fb, 0, "{instr} silently fell back {fb} times");
        }
        // the whole emitted graph stays inside the conv envelope
        for (nm, (routed, fb)) in &stats {
            assert_eq!(*fb, 0, "{nm} fell back ({routed} routed)");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// §2.3, Fixup side: with no BN anywhere, the backward gradient keeps the
/// ReLU mask's zeros, so the measured dz sparsity — the BWI operand
/// sparsity the paper exploits — stays far from zero for every layer.
#[test]
#[cfg_attr(miri, ignore)] // multi-layer interpreted training steps
fn e2e_fixup_resnet50_reports_bwi_gradient_sparsity() {
    let dir = scratch_dir("fixup-small");
    let arts = ArtifactSet::new(&dir);
    let steps = 2;
    let mut t = Trainer::new_net(
        &arts,
        Network::FixupResNet50,
        Scale::Small,
        TrainerConfig { steps, seed: 3, log_every: 0, threads: 2, pipeline: None },
    )
    .expect("net trainer init");
    let plan = t.net_plan().unwrap().clone();
    let report = t.run().expect("fixup-small training");
    assert!(report.losses.iter().all(|l| l.is_finite()), "{:?}", report.losses);

    let mut means = Vec::new();
    for key in &plan.dz_keys {
        let series = report.profiler.series(key).unwrap_or_else(|| panic!("{key} missing"));
        let m = mean(series);
        assert!(m > 0.02, "{key}: BN-free dz sparsity should be ReLU-like, got {m:.3}");
        means.push(m);
    }
    assert!(
        mean(&means) > 0.2,
        "mean BN-free dz sparsity should be substantial, got {:.3}",
        mean(&means)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Golden numerics: convolution bit-parity with kernels::reference
// ---------------------------------------------------------------------------

/// The interpreter's forward convolution accumulates in (c, s, r) order
/// with plain multiply-then-add — exactly `reference::conv_fwd`'s loop —
/// so the two must agree bit for bit, through the real artifact-load path.
#[test]
#[cfg_attr(miri, ignore)] // filesystem + a few hundred KFLOP
fn interpreter_convolution_bit_matches_reference_kernel() {
    let g = Geometry { n: 2, c_in: 3, hw: 7, c1: 4, c2: 4, classes: 3, lr: 0.1 };
    let dir = scratch_dir("conv-golden");
    std::fs::write(dir.join(format!("{KERNEL_FWD}.hlo.txt")), hlo_builder::kernel_fwd_hlo(&g))
        .unwrap();

    let mut rng = Xorshift::new(123);
    let x = rand_vec(&mut rng, g.n * g.c_in * g.hw * g.hw, 1.0);
    let w = rand_vec(&mut rng, g.c1 * g.c_in * 9, 0.5);

    let mut rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load(KERNEL_FWD).unwrap();
    let outs = exe
        .run(&[
            literal_f32(&x, &[g.n as i64, g.c_in as i64, g.hw as i64, g.hw as i64]).unwrap(),
            literal_f32(&w, &[g.c1 as i64, g.c_in as i64, 3, 3]).unwrap(),
        ])
        .unwrap();
    assert_eq!(outs.len(), 1);
    let got = outs[0].to_vec::<f32>().unwrap();

    let cfg = ConvConfig::square(g.n, g.c_in, g.c1, g.hw, 3, 1);
    let want = reference::conv_fwd(&cfg, &x, &w);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "element {i}: interpreter {a} vs reference {b}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Golden numerics: dot / reduce / softmax-cross-entropy
// ---------------------------------------------------------------------------

fn run_module(text: &str, inputs: &[xla::Literal]) -> Vec<xla::Literal> {
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text(text).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let outs = exe.execute::<xla::Literal>(inputs).unwrap();
    let lit = outs[0][0].to_literal_sync().unwrap();
    match lit.clone().to_tuple() {
        Ok(parts) => parts,
        Err(_) => vec![lit],
    }
}

#[test]
fn dot_and_reduce_golden_values() {
    let text = "HloModule golden\n\
        %add_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %add = f32[] add(%p0, %p1)\n}\n\
        ENTRY %m {\n\
        \x20 %a = f32[2,3] parameter(0)\n\
        \x20 %b = f32[3,2] parameter(1)\n\
        \x20 %zero = f32[] constant(0)\n\
        \x20 %d = f32[2,2] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n\
        \x20 %rows = f32[2] reduce(%a, %zero), dimensions={1}, to_apply=%add_f32\n\
        \x20 %all = f32[] reduce(%a, %zero), dimensions={0,1}, to_apply=%add_f32\n\
        \x20 ROOT %out = (f32[2,2], f32[2], f32[]) tuple(%d, %rows, %all)\n}\n";
    let a = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
    let b = literal_f32(&[1.0, 0.0, 0.0, 1.0, 1.0, 1.0], &[3, 2]).unwrap();
    let parts = run_module(text, &[a, b]);
    // [[1,2,3],[4,5,6]] · [[1,0],[0,1],[1,1]] = [[4,5],[10,11]] (exact ints)
    assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![4.0, 5.0, 10.0, 11.0]);
    assert_eq!(parts[1].to_vec::<f32>().unwrap(), vec![6.0, 15.0]);
    assert_eq!(parts[2].to_vec::<f32>().unwrap(), vec![21.0]);
}

/// The exact softmax-cross-entropy subgraph the train-step artifact uses,
/// against hand-computed values: logits [[0,0,0],[1,2,3]], labels [2,0]
/// → loss = ((ln 3) + (2 + ln(e⁻² + e⁻¹ + 1))) / 2 ≈ 1.7531092.
#[test]
fn softmax_cross_entropy_subgraph_golden() {
    let text = "HloModule xent\n\
        %add_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %add = f32[] add(%p0, %p1)\n}\n\
        %max_f32 {\n  %p0 = f32[] parameter(0)\n  %p1 = f32[] parameter(1)\n  ROOT %max = f32[] maximum(%p0, %p1)\n}\n\
        ENTRY %m {\n\
        \x20 %logits = f32[2,3] parameter(0)\n\
        \x20 %labels = s32[2] parameter(1)\n\
        \x20 %zero = f32[] constant(0)\n\
        \x20 %neg_inf = f32[] constant(-inf)\n\
        \x20 %row_max = f32[2] reduce(%logits, %neg_inf), dimensions={1}, to_apply=%max_f32\n\
        \x20 %row_max_b = f32[2,3] broadcast(%row_max), dimensions={0}\n\
        \x20 %centered = f32[2,3] subtract(%logits, %row_max_b)\n\
        \x20 %exp_c = f32[2,3] exponential(%centered)\n\
        \x20 %sum_exp = f32[2] reduce(%exp_c, %zero), dimensions={1}, to_apply=%add_f32\n\
        \x20 %log_sum = f32[2] log(%sum_exp)\n\
        \x20 %log_sum_b = f32[2,3] broadcast(%log_sum), dimensions={0}\n\
        \x20 %logp = f32[2,3] subtract(%centered, %log_sum_b)\n\
        \x20 %iota_cl = s32[2,3] iota(), iota_dimension=1\n\
        \x20 %labels_b = s32[2,3] broadcast(%labels), dimensions={0}\n\
        \x20 %onehot_p = pred[2,3] compare(%labels_b, %iota_cl), direction=EQ\n\
        \x20 %onehot = f32[2,3] convert(%onehot_p)\n\
        \x20 %picked = f32[2,3] multiply(%onehot, %logp)\n\
        \x20 %picked_sum = f32[] reduce(%picked, %zero), dimensions={0,1}, to_apply=%add_f32\n\
        \x20 %neg_inv_n = f32[] constant(-0.5)\n\
        \x20 ROOT %loss = f32[] multiply(%picked_sum, %neg_inv_n)\n}\n";
    let logits = literal_f32(&[0.0, 0.0, 0.0, 1.0, 2.0, 3.0], &[2, 3]).unwrap();
    let labels = literal_i32(&[2, 0], &[2]).unwrap();
    let parts = run_module(text, &[logits, labels]);
    let loss = parts[0].to_vec::<f32>().unwrap()[0] as f64;
    let want = 0.5 * (3.0f64.ln() + 2.0 + ((-2.0f64).exp() + (-1.0f64).exp() + 1.0).ln());
    assert!((loss - want).abs() < 1e-6, "loss {loss} vs hand-computed {want}");
    assert!((loss - 1.7531092).abs() < 1e-5);
}

// ---------------------------------------------------------------------------
// Finite-difference verification of the hand-lowered backward pass
// ---------------------------------------------------------------------------

/// On a reduced geometry, the gradients implied by the SGD update
/// (`g = (w - w') / lr`) must match central finite differences of the
/// loss for every parameter tensor.
#[test]
#[cfg_attr(miri, ignore)] // dozens of interpreted train-step evaluations
fn train_step_backward_matches_finite_differences() {
    let g = Geometry::tiny();
    let dir = scratch_dir("fd");
    std::fs::write(dir.join(format!("{TRAIN_STEP}.hlo.txt")), hlo_builder::train_step_hlo(&g))
        .unwrap();
    let mut rt = Runtime::cpu(&dir).unwrap();

    let mut rng = Xorshift::new(42);
    let b1 = (2.0f32 / (g.c_in * 9) as f32).sqrt();
    let b2 = (2.0f32 / (g.c1 * 9) as f32).sqrt();
    let b3 = (1.0f32 / g.c2 as f32).sqrt();
    let w1 = rand_vec(&mut rng, g.c1 * g.c_in * 9, b1);
    let w2 = rand_vec(&mut rng, g.c2 * g.c1 * 9, b2);
    let wfc = rand_vec(&mut rng, g.classes * g.c2, b3);
    let bfc = vec![0.0f32; g.classes];
    let x = rand_vec(&mut rng, g.n * g.c_in * g.hw * g.hw, 1.0);
    let labels: Vec<i32> = (0..g.n).map(|_| rng.below(g.classes) as i32).collect();

    let run = |rt: &mut Runtime, w1: &[f32], w2: &[f32], wfc: &[f32], bfc: &[f32]| {
        let exe = rt.load(TRAIN_STEP).unwrap();
        exe.run(&[
            literal_f32(w1, &[g.c1 as i64, g.c_in as i64, 3, 3]).unwrap(),
            literal_f32(w2, &[g.c2 as i64, g.c1 as i64, 3, 3]).unwrap(),
            literal_f32(wfc, &[g.classes as i64, g.c2 as i64]).unwrap(),
            literal_f32(bfc, &[g.classes as i64]).unwrap(),
            literal_f32(&x, &[g.n as i64, g.c_in as i64, g.hw as i64, g.hw as i64]).unwrap(),
            literal_i32(&labels, &[g.n as i64]).unwrap(),
        ])
        .unwrap()
    };

    let outs = run(&mut rt, &w1, &w2, &wfc, &bfc);
    assert_eq!(outs.len(), 7, "train_step must keep the 7-output contract");
    let grad = |new: &xla::Literal, old: &[f32]| -> Vec<f32> {
        new.to_vec::<f32>()
            .unwrap()
            .iter()
            .zip(old)
            .map(|(n, o)| (o - n) / g.lr)
            .collect()
    };
    let grads =
        [grad(&outs[0], &w1), grad(&outs[1], &w2), grad(&outs[2], &wfc), grad(&outs[3], &bfc)];
    let params: [&[f32]; 4] = [&w1, &w2, &wfc, &bfc];

    let loss_with = |rt: &mut Runtime, which: usize, idx: usize, delta: f32| -> f64 {
        let mut p: Vec<Vec<f32>> = params.iter().map(|p| p.to_vec()).collect();
        p[which][idx] += delta;
        let outs = run(rt, &p[0], &p[1], &p[2], &p[3]);
        outs[4].to_vec::<f32>().unwrap()[0] as f64
    };

    let eps = 1e-3f32;
    let mut coord_rng = Xorshift::new(7);
    for which in 0..4 {
        for _ in 0..4 {
            let idx = coord_rng.below(params[which].len());
            let fd = (loss_with(&mut rt, which, idx, eps) - loss_with(&mut rt, which, idx, -eps))
                / (2.0 * eps as f64);
            let analytic = grads[which][idx] as f64;
            let denom = fd.abs().max(analytic.abs()).max(5e-3);
            assert!(
                ((fd - analytic) / denom).abs() < 0.1,
                "param {which} coord {idx}: finite-diff {fd:+.6} vs analytic {analytic:+.6}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Parser robustness: mangled artifact text must error, never panic
// ---------------------------------------------------------------------------

/// Apply one deterministic mutation, selected and positioned by `m`.
fn mangle(text: &str, m: usize) -> String {
    let lines: Vec<&str> = text.lines().collect();
    let kind = m % 5;
    let pos = m / 5;
    match kind {
        // truncate at an arbitrary byte (ASCII text, so always a char edge)
        0 => text[..pos % text.len().max(1)].to_string(),
        // delete a line
        1 => {
            let drop = pos % lines.len().max(1);
            lines
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop)
                .map(|(_, l)| *l)
                .collect::<Vec<_>>()
                .join("\n")
        }
        // replace one byte with structural junk
        2 => {
            let junk = [b'}', b'{', b'(', b')', b',', b'=', b'[', b']', b'9', b'x'];
            let mut bytes = text.as_bytes().to_vec();
            if !bytes.is_empty() {
                let at = pos % bytes.len();
                bytes[at] = junk[m % junk.len()];
            }
            String::from_utf8_lossy(&bytes).into_owned()
        }
        // duplicate a line (duplicate instruction names, double ROOTs, ...)
        3 => {
            let dup = pos % lines.len().max(1);
            let mut out = Vec::with_capacity(lines.len() + 1);
            for (i, l) in lines.iter().enumerate() {
                out.push(*l);
                if i == dup {
                    out.push(*l);
                }
            }
            out.join("\n")
        }
        // inflate a digit run (oversized shapes must be rejected, not OOM)
        _ => text.replacen(char::from_digit((pos % 10) as u32, 10).unwrap_or('1'), "987654321", 1),
    }
}

#[test]
#[cfg_attr(miri, ignore)] // hundreds of parse attempts over kilobyte texts
fn hlo_parser_never_panics_on_mangled_artifact_text() {
    let base = hlo_builder::train_step_hlo(&Geometry::tiny());
    let gen = VecOfUsize { min_len: 1, max_len: 4, elem: UsizeIn { lo: 0, hi: 200_000 } };
    check(Config { cases: 300, seed: 0xE2E, max_shrink_steps: 200 }, &gen, |muts| {
        let mut text = base.clone();
        for &m in muts {
            text = mangle(&text, m);
        }
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Ok(module) = xla::hlo::parse_module(&text) {
                let _ = xla::eval::validate(&module);
            }
        }));
        outcome.map_err(|_| format!("parser/validator panicked on mutations {muts:?}"))
    });
}

/// The specific malformations the ISSUE calls out: truncated, structurally
/// malformed, and shape-mismatched artifact text must all return `Err`
/// from the compile path (and a valid artifact must still compile).
#[test]
fn malformed_artifact_text_is_rejected_with_errors() {
    let client = xla::PjRtClient::cpu().unwrap();
    let compile = |text: &str| {
        xla::HloModuleProto::from_text(text)
            .and_then(|p| client.compile(&xla::XlaComputation::from_proto(&p)))
    };

    let good = hlo_builder::train_step_hlo(&Geometry::tiny());
    assert!(compile(&good).is_ok(), "the reference artifact must compile");

    // truncation at many depths
    for frac in [1, 3, 10, 30, 80] {
        let cut = good.len() * frac / 100;
        assert!(compile(&good[..cut]).is_err(), "truncation at {frac}% must fail");
    }
    // a shape edit that keeps the text well-formed but inconsistent
    let lied = good.replacen("f32[4,4,3,3]", "f32[4,4,3,2]", 1);
    assert_ne!(lied, good, "shape-edit target must exist");
    assert!(compile(&lied).is_err(), "shape-mismatched text must fail validation");
    // empty / junk
    assert!(xla::HloModuleProto::from_text("").is_err());
    assert!(compile("HloModule junk\n").is_err());
}

// ---------------------------------------------------------------------------
// Reduced-geometry emit→execute smoke
// ---------------------------------------------------------------------------

/// A tiny end-to-end emit → parse → validate → execute pass: a 1-batch
/// 2-channel 3×3-input kernel_fwd artifact, checked against the scalar
/// reference. (The Miri CI gate runs the equivalent lib-tree smokes in
/// vendor/xla and runtime::hlo_builder; integration targets are not built
/// under `miri test --lib`, so no `miri_` prefix here.)
#[test]
fn emit_parse_execute_kernel_smoke() {
    let g = Geometry { n: 1, c_in: 2, hw: 3, c1: 2, c2: 2, classes: 2, lr: 0.1 };
    let text = hlo_builder::kernel_fwd_hlo(&g);
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text(&text).unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();

    let mut rng = Xorshift::new(5);
    let x = rand_vec(&mut rng, g.n * g.c_in * g.hw * g.hw, 1.0);
    let w = rand_vec(&mut rng, g.c1 * g.c_in * 9, 0.5);
    let outs = exe
        .execute::<xla::Literal>(&[
            literal_f32(&x, &[1, 2, 3, 3]).unwrap(),
            literal_f32(&w, &[2, 2, 3, 3]).unwrap(),
        ])
        .unwrap();
    let got = outs[0][0].to_literal_sync().unwrap().to_tuple().unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let cfg = ConvConfig::square(1, 2, 2, 3, 3, 1);
    let want = reference::conv_fwd(&cfg, &x, &w);
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
