//! Network definitions: the paper's Table 2 layer configurations and the
//! full conv-layer inventories of the four evaluated networks.

pub mod table2;
pub mod zoo;

pub use table2::{layer_by_name, resnet_layers, table2_layers, vgg_layers, NamedLayer};
pub use zoo::{NetSpec, NetLayer, Network, Scale};
