//! Full conv-layer inventories of the evaluated networks (ImageNet
//! geometry, batch 16): VGG16, ResNet-34, ResNet-50, and the Fixup
//! ResNet-50 variant (BatchNorm-free, scalar biases removed — §4).
//!
//! These drive the end-to-end projections (Figure 4 / Table 6): per layer
//! we need the convolution shape, whether its *input* carries ReLU
//! sparsity (FWD/BWW), whether its *output gradient* carries ReLU sparsity
//! (BWI — destroyed by BatchNorm, §2.3), and its depth position for the
//! trajectory model.

use crate::kernels::ConvConfig;

/// One convolution layer inside a network.
#[derive(Debug, Clone)]
pub struct NetLayer {
    pub name: String,
    pub cfg: ConvConfig,
    /// First conv of the network: input is a zero-free image → SparseTrain
    /// inapplicable; the paper charges it as constant `direct` overhead.
    pub is_first: bool,
    /// A BatchNorm sits between this conv and its ReLU.
    pub has_bn: bool,
    /// This conv's ReLU follows a residual-shortcut add (lower sparsity).
    pub after_shortcut: bool,
}

/// The four evaluated networks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Network {
    Vgg16,
    ResNet34,
    ResNet50,
    FixupResNet50,
}

impl Network {
    pub const ALL: [Network; 4] =
        [Network::Vgg16, Network::ResNet34, Network::ResNet50, Network::FixupResNet50];

    pub fn name(&self) -> &'static str {
        match self {
            Network::Vgg16 => "VGG16",
            Network::ResNet34 => "ResNet-34",
            Network::ResNet50 => "ResNet-50",
            Network::FixupResNet50 => "Fixup ResNet-50",
        }
    }

    /// Trajectory-model parameters for this network (Fig 3).
    pub fn trajectory(&self) -> crate::sparsity::TrajectoryParams {
        use crate::sparsity::TrajectoryParams as P;
        match self {
            Network::Vgg16 => P::vgg16(),
            Network::ResNet34 => P::resnet34(),
            Network::ResNet50 => P::resnet50(),
            Network::FixupResNet50 => P::fixup_resnet50(),
        }
    }
}

/// A network's conv inventory.
#[derive(Debug, Clone)]
pub struct NetSpec {
    pub network: Network,
    pub layers: Vec<NetLayer>,
}

const BATCH: usize = 16;

fn conv(
    name: String,
    c: usize,
    k: usize,
    hw: usize,
    rs: usize,
    stride: usize,
    has_bn: bool,
) -> NetLayer {
    NetLayer {
        name,
        cfg: ConvConfig::square(BATCH, c, k, hw, rs, stride),
        is_first: false,
        has_bn,
        after_shortcut: false,
    }
}

/// The first conv: 3 input channels, padded to V=16 for the tiled layout
/// (cost model approximation; the paper charges this layer as constant
/// `direct` overhead either way).
fn first_conv(name: &str, k: usize, hw: usize, rs: usize, stride: usize, has_bn: bool) -> NetLayer {
    let mut l = conv(name.to_string(), 16, k, hw, rs, stride, has_bn);
    l.is_first = true;
    l
}

impl NetSpec {
    pub fn build(network: Network) -> NetSpec {
        match network {
            Network::Vgg16 => NetSpec { network, layers: vgg16_layers() },
            Network::ResNet34 => NetSpec { network, layers: resnet34_layers(true) },
            Network::ResNet50 => NetSpec { network, layers: resnet50_layers(true) },
            Network::FixupResNet50 => NetSpec { network, layers: resnet50_layers(false) },
        }
    }

    /// Layers excluding the first conv (the paper's "excl. 1st layer" rows).
    pub fn non_initial(&self) -> impl Iterator<Item = &NetLayer> {
        self.layers.iter().filter(|l| !l.is_first)
    }

    /// Total dense forward FLOPs of all conv layers.
    pub fn total_fwd_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.cfg.fwd_flops()).sum()
    }
}

fn vgg16_layers() -> Vec<NetLayer> {
    let spec: [(usize, usize, usize); 13] = [
        (3, 64, 224), // conv1_1 (first)
        (64, 64, 224),
        (64, 128, 112),
        (128, 128, 112),
        (128, 256, 56),
        (256, 256, 56),
        (256, 256, 56),
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 28),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
    ];
    spec.iter()
        .enumerate()
        .map(|(i, &(c, k, hw))| {
            if i == 0 {
                first_conv("conv1_1", k, hw, 3, 1, false)
            } else {
                conv(format!("conv{}", i + 1), c, k, hw, 3, 1, false)
            }
        })
        .collect()
}

/// ResNet-34: basic blocks [3, 4, 6, 3], channels [64, 128, 256, 512].
fn resnet34_layers(has_bn: bool) -> Vec<NetLayer> {
    let mut layers = vec![first_conv("conv1", 64, 224, 7, 2, has_bn)];
    let stages: [(usize, usize, usize); 4] =
        [(64, 56, 3), (128, 28, 4), (256, 14, 6), (512, 7, 3)];
    let mut in_c = 64;
    for (si, &(ch, hw, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let downsample = si > 0 && b == 0;
            let stride = if downsample { 2 } else { 1 };
            let in_hw = if downsample { hw * 2 } else { hw };
            let mut l1 = conv(
                format!("s{}b{}_conv1", si + 2, b + 1),
                in_c,
                ch,
                in_hw,
                3,
                stride,
                has_bn,
            );
            let mut l2 = conv(format!("s{}b{}_conv2", si + 2, b + 1), ch, ch, hw, 3, 1, has_bn);
            l2.after_shortcut = true; // its ReLU follows the shortcut add
            let _ = &mut l1;
            layers.push(l1);
            layers.push(l2);
            if downsample {
                // projection shortcut 1x1/2
                let mut sc = conv(
                    format!("s{}b{}_down", si + 2, b + 1),
                    in_c,
                    ch,
                    in_hw,
                    1,
                    2,
                    has_bn,
                );
                sc.cfg.pad_h = 0;
                sc.cfg.pad_w = 0;
                layers.push(sc);
            }
            in_c = ch;
        }
    }
    layers
}

/// ResNet-50: bottleneck blocks [3, 4, 6, 3], widths [64, 128, 256, 512]
/// (output 4× wider). `has_bn = false` gives the Fixup variant.
fn resnet50_layers(has_bn: bool) -> Vec<NetLayer> {
    let mut layers = vec![first_conv("conv1", 64, 224, 7, 2, has_bn)];
    let stages: [(usize, usize, usize); 4] =
        [(64, 56, 3), (128, 28, 4), (256, 14, 6), (512, 7, 3)];
    let mut in_c = 64;
    for (si, &(w, hw, blocks)) in stages.iter().enumerate() {
        let out_c = w * 4;
        for b in 0..blocks {
            let downsample = b == 0; // every stage's first block projects
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let in_hw = if stride == 2 { hw * 2 } else { hw };
            // 1x1 reduce (stride 1; v1.5 puts the stride on the 3x3)
            let mut l1 =
                conv(format!("s{}b{}_conv1", si + 2, b + 1), in_c, w, in_hw, 1, 1, has_bn);
            l1.cfg.pad_h = 0;
            l1.cfg.pad_w = 0;
            layers.push(l1);
            // 3x3 (carries the stride in v1.5)
            layers.push(conv(
                format!("s{}b{}_conv2", si + 2, b + 1),
                w,
                w,
                in_hw,
                3,
                stride,
                has_bn,
            ));
            // 1x1 expand; its ReLU is after the shortcut add
            let mut l3 = conv(format!("s{}b{}_conv3", si + 2, b + 1), w, out_c, hw, 1, 1, has_bn);
            l3.cfg.pad_h = 0;
            l3.cfg.pad_w = 0;
            l3.after_shortcut = true;
            layers.push(l3);
            if downsample {
                let mut sc = conv(
                    format!("s{}b{}_down", si + 2, b + 1),
                    in_c,
                    out_c,
                    in_hw,
                    1,
                    stride,
                    has_bn,
                );
                sc.cfg.pad_h = 0;
                sc.cfg.pad_w = 0;
                layers.push(sc);
            }
            in_c = out_c;
        }
    }
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_has_13_convs() {
        let net = NetSpec::build(Network::Vgg16);
        assert_eq!(net.layers.len(), 13);
        assert_eq!(net.non_initial().count(), 12);
        assert!(net.layers.iter().all(|l| !l.has_bn));
    }

    #[test]
    fn resnet34_conv_count() {
        // 1 (stem) + 2·(3+4+6+3) + 3 downsample projections = 36
        let net = NetSpec::build(Network::ResNet34);
        assert_eq!(net.layers.len(), 1 + 32 + 3);
        assert!(net.layers.iter().all(|l| l.has_bn));
    }

    #[test]
    fn resnet50_conv_count() {
        // 1 + 3·(3+4+6+3) + 4 downsample projections = 53
        let net = NetSpec::build(Network::ResNet50);
        assert_eq!(net.layers.len(), 1 + 48 + 4);
        // Fixup variant identical but BN-free
        let fix = NetSpec::build(Network::FixupResNet50);
        assert_eq!(fix.layers.len(), net.layers.len());
        assert!(fix.layers.iter().all(|l| !l.has_bn));
    }

    #[test]
    fn all_configs_valid() {
        for net in Network::ALL {
            for l in &NetSpec::build(net).layers {
                l.cfg.validate().unwrap_or_else(|e| panic!("{} {}: {e}", net.name(), l.name));
            }
        }
    }

    #[test]
    fn geometry_chains_consistently() {
        // each stage's first conv input H/W equals previous stage output
        let net = NetSpec::build(Network::ResNet50);
        // spot: s2 spatial = 56, s5 = 7
        let s2 = net.layers.iter().find(|l| l.name == "s2b1_conv2").unwrap();
        assert_eq!(s2.cfg.h, 56);
        let s5 = net.layers.iter().find(|l| l.name == "s5b3_conv3").unwrap();
        assert_eq!(s5.cfg.h, 7);
        assert_eq!((s5.cfg.c, s5.cfg.k), (512, 2048));
    }

    #[test]
    fn vgg16_flops_order_of_magnitude() {
        // ~15.3 GFLOPs ×2 (MAC=2) × batch16 ≈ 4.9e11; allow wide band.
        let net = NetSpec::build(Network::Vgg16);
        let flops = net.total_fwd_flops() as f64;
        assert!(flops > 3e11 && flops < 8e11, "flops={flops:e}");
    }

    #[test]
    fn resnet50_flops_order_of_magnitude() {
        // ~4.1 GFLOPs ×2 × batch16 ≈ 1.3e11
        let net = NetSpec::build(Network::ResNet50);
        let flops = net.total_fwd_flops() as f64;
        assert!(flops > 0.8e11 && flops < 2.0e11, "flops={flops:e}");
    }

    #[test]
    fn shortcut_relus_marked() {
        let net = NetSpec::build(Network::ResNet34);
        let marked = net.layers.iter().filter(|l| l.after_shortcut).count();
        assert_eq!(marked, 16); // one per basic block
    }
}
