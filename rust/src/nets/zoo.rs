//! Full conv-layer inventories of the evaluated networks (ImageNet
//! geometry, batch 16): VGG16, ResNet-34, ResNet-50, and the Fixup
//! ResNet-50 variant (BatchNorm-free, scalar biases removed — §4).
//!
//! These drive the end-to-end projections (Figure 4 / Table 6): per layer
//! we need the convolution shape, whether its *input* carries ReLU
//! sparsity (FWD/BWW), whether its *output gradient* carries ReLU sparsity
//! (BWI — destroyed by BatchNorm, §2.3), and its depth position for the
//! trajectory model.

use crate::kernels::ConvConfig;

/// One convolution layer inside a network.
#[derive(Debug, Clone)]
pub struct NetLayer {
    pub name: String,
    pub cfg: ConvConfig,
    /// Real input-channel count. Equal to `cfg.c` everywhere except the
    /// first conv, whose 3 image channels are padded to V=16 in `cfg` for
    /// the tiled layout; FLOP accounting must use this field, not `cfg.c`.
    pub real_c: usize,
    /// First conv of the network: input is a zero-free image → SparseTrain
    /// inapplicable; the paper charges it as constant `direct` overhead.
    pub is_first: bool,
    /// A BatchNorm sits between this conv and its ReLU.
    pub has_bn: bool,
    /// This conv's ReLU follows a residual-shortcut add (lower sparsity).
    pub after_shortcut: bool,
}

impl NetLayer {
    /// Dense forward FLOPs charged at the real channel count (the padded
    /// `cfg.c` would overcount the first layer 16/3 ≈ 5.3×).
    pub fn real_fwd_flops(&self) -> u64 {
        let mut cfg = self.cfg;
        cfg.c = self.real_c;
        cfg.fwd_flops()
    }
}

/// The four evaluated networks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Network {
    Vgg16,
    ResNet34,
    ResNet50,
    FixupResNet50,
}

impl Network {
    pub const ALL: [Network; 4] =
        [Network::Vgg16, Network::ResNet34, Network::ResNet50, Network::FixupResNet50];

    pub fn name(&self) -> &'static str {
        match self {
            Network::Vgg16 => "VGG16",
            Network::ResNet34 => "ResNet-34",
            Network::ResNet50 => "ResNet-50",
            Network::FixupResNet50 => "Fixup ResNet-50",
        }
    }

    /// Identifier-safe key, used for artifact names and `--net` parsing.
    pub fn key(&self) -> &'static str {
        match self {
            Network::Vgg16 => "vgg16",
            Network::ResNet34 => "resnet34",
            Network::ResNet50 => "resnet50",
            Network::FixupResNet50 => "fixup_resnet50",
        }
    }

    /// Parse a `--net` argument (accepts the `key()` spellings).
    pub fn parse(s: &str) -> Option<Network> {
        Network::ALL.into_iter().find(|n| n.key() == s)
    }

    /// Trajectory-model parameters for this network (Fig 3).
    pub fn trajectory(&self) -> crate::sparsity::TrajectoryParams {
        use crate::sparsity::TrajectoryParams as P;
        match self {
            Network::Vgg16 => P::vgg16(),
            Network::ResNet34 => P::resnet34(),
            Network::ResNet50 => P::resnet50(),
            Network::FixupResNet50 => P::fixup_resnet50(),
        }
    }
}

/// Spatial/depth preset for building a network inventory. `Full` is the
/// paper's ImageNet geometry; `Small`/`Medium` shrink input extent, channel
/// widths and stage depths so a real multi-layer train loop fits the
/// vendored mini-HLO interpreter (and `cargo test`) while keeping every
/// structural feature — strided convs, projection shortcuts, BN placement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scale {
    /// 32×32 input, channels ÷4, one residual block per stage.
    Small,
    /// 64×64 input, channels ÷2, two residual blocks per stage.
    Medium,
    /// 224×224 input, the real inventory (projection/emission only: train
    /// graphs at this extent exceed the mini interpreter's tensor budget).
    Full,
}

impl Scale {
    pub const ALL: [Scale; 3] = [Scale::Small, Scale::Medium, Scale::Full];

    pub fn key(&self) -> &'static str {
        match self {
            Scale::Small => "small",
            Scale::Medium => "medium",
            Scale::Full => "full",
        }
    }

    /// Parse a `--scale` argument.
    pub fn parse(s: &str) -> Option<Scale> {
        Scale::ALL.into_iter().find(|x| x.key() == s)
    }

    /// Network input spatial extent (H = W).
    pub fn input_hw(&self) -> usize {
        match self {
            Scale::Small => 32,
            Scale::Medium => 64,
            Scale::Full => 224,
        }
    }

    /// Channel-width divisor (keeps every width a multiple of V=16).
    fn chdiv(&self) -> usize {
        match self {
            Scale::Small => 4,
            Scale::Medium => 2,
            Scale::Full => 1,
        }
    }

    /// Residual blocks per stage.
    fn depths(&self) -> [usize; 4] {
        match self {
            Scale::Small => [1, 1, 1, 1],
            Scale::Medium => [2, 2, 2, 2],
            Scale::Full => [3, 4, 6, 3],
        }
    }

    fn ch(&self, c: usize) -> usize {
        (c / self.chdiv()).max(crate::V)
    }
}

/// A network's conv inventory.
#[derive(Debug, Clone)]
pub struct NetSpec {
    pub network: Network,
    pub layers: Vec<NetLayer>,
}

const BATCH: usize = 16;

fn conv(
    name: String,
    c: usize,
    k: usize,
    hw: usize,
    rs: usize,
    stride: usize,
    has_bn: bool,
) -> NetLayer {
    NetLayer {
        name,
        cfg: ConvConfig::square(BATCH, c, k, hw, rs, stride),
        real_c: c,
        is_first: false,
        has_bn,
        after_shortcut: false,
    }
}

/// The first conv: 3 input channels, padded to V=16 for the tiled layout
/// (cost model approximation; the paper charges this layer as constant
/// `direct` overhead either way). `real_c` stays 3 so FLOP accounting is
/// honest about the actual image.
fn first_conv(name: &str, k: usize, hw: usize, rs: usize, stride: usize, has_bn: bool) -> NetLayer {
    let mut l = conv(name.to_string(), 16, k, hw, rs, stride, has_bn);
    l.real_c = 3;
    l.is_first = true;
    l
}

impl NetSpec {
    pub fn build(network: Network) -> NetSpec {
        NetSpec::build_scaled(network, Scale::Full)
    }

    /// Build the inventory at a given [`Scale`] preset. `Scale::Full` is the
    /// paper inventory; smaller presets keep the same structure (and layer
    /// naming scheme) with reduced extent/width/depth.
    pub fn build_scaled(network: Network, scale: Scale) -> NetSpec {
        match network {
            Network::Vgg16 => NetSpec { network, layers: vgg16_layers(scale) },
            Network::ResNet34 => NetSpec { network, layers: resnet34_layers(true, scale) },
            Network::ResNet50 => NetSpec { network, layers: resnet50_layers(true, scale) },
            Network::FixupResNet50 => {
                NetSpec { network, layers: resnet50_layers(false, scale) }
            }
        }
    }

    /// Layers excluding the first conv (the paper's "excl. 1st layer" rows).
    pub fn non_initial(&self) -> impl Iterator<Item = &NetLayer> {
        self.layers.iter().filter(|l| !l.is_first)
    }

    /// Total dense forward FLOPs of all conv layers, charged at real
    /// channel counts (the first conv reads 3 image channels, not the
    /// padded 16).
    pub fn total_fwd_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.real_fwd_flops()).sum()
    }
}

fn vgg16_layers(scale: Scale) -> Vec<NetLayer> {
    // (real in channels, out channels, spatial divisor vs the input extent)
    let spec: [(usize, usize, usize); 13] = [
        (3, 64, 1), // conv1_1 (first)
        (64, 64, 1),
        (64, 128, 2),
        (128, 128, 2),
        (128, 256, 4),
        (256, 256, 4),
        (256, 256, 4),
        (256, 512, 8),
        (512, 512, 8),
        (512, 512, 8),
        (512, 512, 16),
        (512, 512, 16),
        (512, 512, 16),
    ];
    let hw0 = scale.input_hw();
    spec.iter()
        .enumerate()
        .map(|(i, &(c, k, div))| {
            let hw = hw0 / div;
            if i == 0 {
                first_conv("conv1_1", scale.ch(k), hw, 3, 1, false)
            } else {
                conv(format!("conv{}", i + 1), scale.ch(c), scale.ch(k), hw, 3, 1, false)
            }
        })
        .collect()
}

/// ResNet stage table: (base width, output spatial divisor vs input extent).
/// Stage spatial = input/4 at stage 2 (stem /2, maxpool /2), halving after.
const RESNET_STAGES: [(usize, usize); 4] = [(64, 4), (128, 8), (256, 16), (512, 32)];

/// ResNet-34: basic blocks, channels [64, 128, 256, 512] (scaled).
fn resnet34_layers(has_bn: bool, scale: Scale) -> Vec<NetLayer> {
    let hw0 = scale.input_hw();
    let depths = scale.depths();
    let mut layers = vec![first_conv("conv1", scale.ch(64), hw0, 7, 2, has_bn)];
    let mut in_c = scale.ch(64);
    for (si, &(w, div)) in RESNET_STAGES.iter().enumerate() {
        let ch = scale.ch(w);
        let hw = hw0 / div;
        for b in 0..depths[si] {
            let downsample = si > 0 && b == 0;
            let stride = if downsample { 2 } else { 1 };
            let in_hw = if downsample { hw * 2 } else { hw };
            let l1 = conv(
                format!("s{}b{}_conv1", si + 2, b + 1),
                in_c,
                ch,
                in_hw,
                3,
                stride,
                has_bn,
            );
            let mut l2 = conv(format!("s{}b{}_conv2", si + 2, b + 1), ch, ch, hw, 3, 1, has_bn);
            l2.after_shortcut = true; // its ReLU follows the shortcut add
            layers.push(l1);
            layers.push(l2);
            if downsample {
                // projection shortcut 1x1/2
                let mut sc = conv(
                    format!("s{}b{}_down", si + 2, b + 1),
                    in_c,
                    ch,
                    in_hw,
                    1,
                    2,
                    has_bn,
                );
                sc.cfg.pad_h = 0;
                sc.cfg.pad_w = 0;
                layers.push(sc);
            }
            in_c = ch;
        }
    }
    layers
}

/// ResNet-50: bottleneck blocks, widths [64, 128, 256, 512] (scaled;
/// output 4× wider). `has_bn = false` gives the Fixup variant.
fn resnet50_layers(has_bn: bool, scale: Scale) -> Vec<NetLayer> {
    let hw0 = scale.input_hw();
    let depths = scale.depths();
    let mut layers = vec![first_conv("conv1", scale.ch(64), hw0, 7, 2, has_bn)];
    let mut in_c = scale.ch(64);
    for (si, &(base, div)) in RESNET_STAGES.iter().enumerate() {
        let w = scale.ch(base);
        let out_c = w * 4;
        let hw = hw0 / div;
        for b in 0..depths[si] {
            let downsample = b == 0; // every stage's first block projects
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let in_hw = if stride == 2 { hw * 2 } else { hw };
            // 1x1 reduce (stride 1; v1.5 puts the stride on the 3x3)
            let mut l1 =
                conv(format!("s{}b{}_conv1", si + 2, b + 1), in_c, w, in_hw, 1, 1, has_bn);
            l1.cfg.pad_h = 0;
            l1.cfg.pad_w = 0;
            layers.push(l1);
            // 3x3 (carries the stride in v1.5)
            layers.push(conv(
                format!("s{}b{}_conv2", si + 2, b + 1),
                w,
                w,
                in_hw,
                3,
                stride,
                has_bn,
            ));
            // 1x1 expand; its ReLU is after the shortcut add
            let mut l3 = conv(format!("s{}b{}_conv3", si + 2, b + 1), w, out_c, hw, 1, 1, has_bn);
            l3.cfg.pad_h = 0;
            l3.cfg.pad_w = 0;
            l3.after_shortcut = true;
            layers.push(l3);
            if downsample {
                let mut sc = conv(
                    format!("s{}b{}_down", si + 2, b + 1),
                    in_c,
                    out_c,
                    in_hw,
                    1,
                    stride,
                    has_bn,
                );
                sc.cfg.pad_h = 0;
                sc.cfg.pad_w = 0;
                layers.push(sc);
            }
            in_c = out_c;
        }
    }
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_has_13_convs() {
        let net = NetSpec::build(Network::Vgg16);
        assert_eq!(net.layers.len(), 13);
        assert_eq!(net.non_initial().count(), 12);
        assert!(net.layers.iter().all(|l| !l.has_bn));
    }

    #[test]
    fn resnet34_conv_count() {
        // 1 (stem) + 2·(3+4+6+3) + 3 downsample projections = 36
        let net = NetSpec::build(Network::ResNet34);
        assert_eq!(net.layers.len(), 1 + 32 + 3);
        assert!(net.layers.iter().all(|l| l.has_bn));
    }

    #[test]
    fn resnet50_conv_count() {
        // 1 + 3·(3+4+6+3) + 4 downsample projections = 53
        let net = NetSpec::build(Network::ResNet50);
        assert_eq!(net.layers.len(), 1 + 48 + 4);
        // Fixup variant identical but BN-free
        let fix = NetSpec::build(Network::FixupResNet50);
        assert_eq!(fix.layers.len(), net.layers.len());
        assert!(fix.layers.iter().all(|l| !l.has_bn));
    }

    #[test]
    fn all_configs_valid() {
        for net in Network::ALL {
            for l in &NetSpec::build(net).layers {
                l.cfg.validate().unwrap_or_else(|e| panic!("{} {}: {e}", net.name(), l.name));
            }
        }
    }

    #[test]
    fn geometry_chains_consistently() {
        // each stage's first conv input H/W equals previous stage output
        let net = NetSpec::build(Network::ResNet50);
        // spot: s2 spatial = 56, s5 = 7
        let s2 = net.layers.iter().find(|l| l.name == "s2b1_conv2").unwrap();
        assert_eq!(s2.cfg.h, 56);
        let s5 = net.layers.iter().find(|l| l.name == "s5b3_conv3").unwrap();
        assert_eq!(s5.cfg.h, 7);
        assert_eq!((s5.cfg.c, s5.cfg.k), (512, 2048));
    }

    #[test]
    fn vgg16_flops_order_of_magnitude() {
        // ~15.3 GFLOPs ×2 (MAC=2) × batch16 ≈ 4.9e11; allow wide band.
        let net = NetSpec::build(Network::Vgg16);
        let flops = net.total_fwd_flops() as f64;
        assert!(flops > 3e11 && flops < 8e11, "flops={flops:e}");
    }

    #[test]
    fn resnet50_flops_order_of_magnitude() {
        // ~4.1 GFLOPs ×2 × batch16 ≈ 1.3e11
        let net = NetSpec::build(Network::ResNet50);
        let flops = net.total_fwd_flops() as f64;
        assert!(flops > 0.8e11 && flops < 2.0e11, "flops={flops:e}");
    }

    #[test]
    fn shortcut_relus_marked() {
        let net = NetSpec::build(Network::ResNet34);
        let marked = net.layers.iter().filter(|l| l.after_shortcut).count();
        assert_eq!(marked, 16); // one per basic block
    }

    #[test]
    fn first_conv_carries_real_channel_count() {
        for net in Network::ALL {
            let spec = NetSpec::build(net);
            let first = &spec.layers[0];
            assert!(first.is_first);
            assert_eq!(first.cfg.c, 16, "{}: tiled layout pads to V", net.name());
            assert_eq!(first.real_c, 3, "{}: images have 3 channels", net.name());
            assert!(spec.layers[1..].iter().all(|l| l.real_c == l.cfg.c));
        }
    }

    /// Pin per-image conv GFLOPs (2 FLOPs per MAC) against the published
    /// figures: VGG16 ≈ 30.7, ResNet-50 (v1.5) ≈ 8.2. The padded-first-conv
    /// bug charged conv1 at 16 input channels, inflating VGG16 to ~31.4 and
    /// ResNet-50 to ~9.2 — both outside these bands.
    #[test]
    fn flops_pinned_to_published_figures() {
        let per_image = |n: Network| {
            NetSpec::build(n).total_fwd_flops() as f64 / BATCH as f64 / 1e9
        };
        let vgg = per_image(Network::Vgg16);
        assert!((30.4..31.0).contains(&vgg), "VGG16 GFLOPs/image = {vgg}");
        let r50 = per_image(Network::ResNet50);
        assert!((8.0..8.4).contains(&r50), "ResNet-50 GFLOPs/image = {r50}");
    }

    #[test]
    fn scaled_specs_are_valid_and_structural() {
        for net in Network::ALL {
            for scale in Scale::ALL {
                let spec = NetSpec::build_scaled(net, scale);
                for l in &spec.layers {
                    l.cfg.validate().unwrap_or_else(|e| {
                        panic!("{} {} {}: {e}", net.name(), scale.key(), l.name)
                    });
                }
                // same layer count and naming at every scale
                assert_eq!(
                    spec.layers.len(),
                    match (net, scale) {
                        (Network::Vgg16, _) => 13,
                        (Network::ResNet34, Scale::Small) => 1 + 2 + 3 * 3,
                        (Network::ResNet34, Scale::Medium) => 1 + 2 * 8 + 3,
                        (Network::ResNet34, Scale::Full) => 36,
                        (_, Scale::Small) => 1 + 3 * 4 + 4,
                        (_, Scale::Medium) => 1 + 3 * 8 + 4,
                        (_, Scale::Full) => 53,
                    },
                    "{} {}",
                    net.name(),
                    scale.key()
                );
                // strided convs survive scaling (stem + stage transitions)
                let strided = spec.layers.iter().filter(|l| l.cfg.stride_p == 2).count();
                if net != Network::Vgg16 {
                    assert!(strided >= 4, "{} {}: {strided} strided", net.name(), scale.key());
                }
            }
        }
    }

    #[test]
    fn small_resnet34_chains_to_1x1() {
        let spec = NetSpec::build_scaled(Network::ResNet34, Scale::Small);
        let last = spec.layers.iter().find(|l| l.name == "s5b1_conv2").unwrap();
        assert_eq!((last.cfg.c, last.cfg.k, last.cfg.h), (128, 128, 1));
    }
}
