//! The paper's Table 2: evaluated layer configurations from VGG and
//! ResNet v1.5, batch size 16 (§4).

use crate::kernels::ConvConfig;

/// A named layer configuration from Table 2.
#[derive(Debug, Clone)]
pub struct NamedLayer {
    pub name: &'static str,
    pub cfg: ConvConfig,
}

/// Batch size used throughout the paper's per-layer evaluation (§4).
pub const BATCH: usize = 16;

fn l(name: &'static str, c: usize, k: usize, hw: usize, rs: usize, stride: usize) -> NamedLayer {
    NamedLayer { name, cfg: ConvConfig::square(BATCH, c, k, hw, rs, stride) }
}

/// All VGG rows of Table 2 (the non-initial 3×3 layers).
pub fn vgg_layers() -> Vec<NamedLayer> {
    vec![
        l("vgg1_2", 64, 64, 224, 3, 1),
        l("vgg2_1", 64, 128, 112, 3, 1),
        l("vgg2_2", 128, 128, 112, 3, 1),
        l("vgg3_1", 128, 256, 56, 3, 1),
        l("vgg3_2", 256, 256, 56, 3, 1),
        l("vgg4_1", 256, 512, 28, 3, 1),
        l("vgg4_2", 512, 512, 28, 3, 1),
        l("vgg5_1", 512, 512, 14, 3, 1),
    ]
}

/// All ResNet rows of Table 2 (1×1 and 3×3, incl. the strided `/r` rows).
pub fn resnet_layers() -> Vec<NamedLayer> {
    vec![
        l("resnet2_1a", 64, 64, 56, 1, 1),
        l("resnet2_1b", 256, 64, 56, 1, 1),
        l("resnet2_2", 64, 64, 56, 3, 1),
        l("resnet2_3", 64, 256, 56, 1, 1),
        l("resnet3_1a", 256, 128, 56, 1, 1),
        l("resnet3_1b", 512, 128, 28, 1, 1),
        l("resnet3_2", 128, 128, 28, 3, 1),
        l("resnet3_2/r", 128, 128, 56, 3, 2),
        l("resnet3_3", 128, 512, 28, 1, 1),
        l("resnet4_1a", 512, 256, 28, 1, 1),
        l("resnet4_1b", 1024, 256, 14, 1, 1),
        l("resnet4_2", 256, 256, 14, 3, 1),
        l("resnet4_2/r", 256, 256, 28, 3, 2),
        l("resnet4_3", 256, 1024, 14, 1, 1),
        l("resnet5_1a", 1024, 512, 14, 1, 1),
        l("resnet5_1b", 2048, 512, 7, 1, 1),
        l("resnet5_2", 512, 512, 7, 3, 1),
        l("resnet5_2/r", 512, 512, 14, 3, 2),
        l("resnet5_3", 512, 2048, 7, 1, 1),
    ]
}

/// Every row of Table 2.
pub fn table2_layers() -> Vec<NamedLayer> {
    let mut v = vgg_layers();
    v.extend(resnet_layers());
    v
}

/// The 3×3 subset (Figure 1 / Table 4).
pub fn layers_3x3() -> Vec<NamedLayer> {
    table2_layers().into_iter().filter(|nl| nl.cfg.r == 3).collect()
}

/// The 1×1 subset (Figure 2 / Table 5).
pub fn layers_1x1() -> Vec<NamedLayer> {
    table2_layers().into_iter().filter(|nl| nl.cfg.r == 1).collect()
}

/// Look up a Table 2 layer by name.
pub fn layer_by_name(name: &str) -> Option<NamedLayer> {
    table2_layers().into_iter().find(|nl| nl.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_table2() {
        assert_eq!(vgg_layers().len(), 8);
        assert_eq!(resnet_layers().len(), 19);
        assert_eq!(layers_3x3().len(), 8 + 7); // 8 VGG + 7 ResNet 3x3 rows
        assert_eq!(layers_1x1().len(), 12);
    }

    #[test]
    fn all_configs_valid() {
        for nl in table2_layers() {
            nl.cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", nl.name));
            assert_eq!(nl.cfg.n, BATCH);
        }
    }

    #[test]
    fn strided_rows_have_stride_2() {
        for nl in table2_layers() {
            let strided = nl.name.ends_with("/r");
            assert_eq!(nl.cfg.stride_o == 2, strided, "{}", nl.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        let nl = layer_by_name("vgg3_2").unwrap();
        assert_eq!((nl.cfg.c, nl.cfg.k, nl.cfg.h), (256, 256, 56));
        assert!(layer_by_name("nope").is_none());
    }

    #[test]
    fn spot_check_dimensions() {
        let r52 = layer_by_name("resnet5_2").unwrap().cfg;
        assert_eq!((r52.c, r52.k, r52.h, r52.r), (512, 512, 7, 3));
        let r31b = layer_by_name("resnet3_1b").unwrap().cfg;
        assert_eq!((r31b.c, r31b.k, r31b.h, r31b.r), (512, 128, 28, 1));
    }
}
