//! Reference HLO-text emitters: the Rust-side artifact fallback and the
//! inventory-driven network graph builder.
//!
//! `python/compile/aot.py` is the primary artifact producer (real JAX +
//! Pallas, run via `make artifacts`). This module emits functionally
//! equivalent HLO text for the same three artifacts — `train_step`,
//! `predict`, `kernel_fwd` — straight from the [`Geometry`] constants, so
//! a cold checkout with **no Python and no pre-built artifacts** can still
//! light up the full `Trainer` loop through the vendored mini-HLO
//! interpreter (`xla::eval`).
//!
//! The classic train-step graph is the hand-lowered forward + backward +
//! SGD of `python/compile/model.py`: two 3×3 pad-1 convolutions with ReLU
//! (and measured ReLU-output sparsity, the paper's dynamic-sparsity
//! signal), global average pool, a fully-connected layer, numerically
//! stable softmax cross-entropy, and one SGD update. The input-gradient
//! convolution is expressed as `reverse` + `dim_labels=bf01_io01->bf01`;
//! the weight-gradient convolutions contract the batch dimension via
//! `dim_labels=fb01_io01->bf01` with the activation spatial extent as the
//! window. The backward graph is finite-difference-verified in
//! `rust/tests/e2e_train.rs`.
//!
//! [`net_train_step_hlo`] / [`net_predict_hlo`] generalize that
//! hand-lowering to an arbitrary `nets::zoo` conv inventory (ISSUE 7):
//! layer names are parsed back into stage/block topology, residual blocks
//! get their adds and 1×1 projection shortcuts, inter-stage maxpools are
//! inferred from spatial-extent drops, and a [`Scale`] preset shrinks the
//! Full geometry so a real multi-layer loop runs under `cargo test`.
//! Two paper-fidelity rules shape the emission:
//!
//! * **§2.3 BN placement** — with BatchNorm between conv and ReLU the
//!   output gradient `dz` is dense (BN backward smears the ReLU mask), so
//!   BN layers measure the *post-BN* gradient; BN-free (Fixup) layers
//!   mask first and measure the sparse gradient BWI actually consumes.
//!   Per-layer ReLU (`sp_*`) and gradient (`dsp_*`) sparsity scalars ride
//!   in the root tuple so the profiler sees what the model predicts.
//! * **Strided backward as zero-insertion** — `dY` of a stride-`s` conv is
//!   upsampled (iota-mask broadcast) to the stride-1 footprint before the
//!   BWW/BWI convs, which keeps every backward conv in the exact window
//!   form the `OpRouter` envelope and sparse kernels already handle.
//!
//! [`NetTrainPlan`] is the emission manifest: parameter names/dims,
//! sparsity-series keys, and the `(instr, series)` feeds the trainer uses
//! to hand measured sparsity to the selector. The emitted text publishes
//! through `ArtifactSet::publish_fallback_text` as
//! `train_step_<net>_<scale>` / `predict_<net>_<scale>`.

use super::artifacts::geometry;
use crate::nets::zoo::{NetLayer, NetSpec, Network, Scale};
use std::fmt::Write;

/// Training-problem geometry an emitted module is specialized to (AOT —
/// shapes are baked into the text, exactly like the JAX lowering).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Geometry {
    /// Batch size.
    pub n: usize,
    /// Input channels.
    pub c_in: usize,
    /// Input spatial size (H = W).
    pub hw: usize,
    /// conv1 / conv2 output channels.
    pub c1: usize,
    pub c2: usize,
    /// Label classes.
    pub classes: usize,
    /// SGD learning rate baked into the train-step graph.
    pub lr: f32,
}

impl Geometry {
    /// The artifact geometry (`runtime::artifacts::geometry`, kept in sync
    /// with `python/compile/model.py`).
    pub fn paper() -> Geometry {
        Geometry {
            n: geometry::N,
            c_in: geometry::C_IN,
            hw: geometry::HW,
            c1: geometry::C1,
            c2: geometry::C2,
            classes: geometry::CLASSES,
            lr: geometry::LR,
        }
    }

    /// A reduced geometry for fast interpreter tests (finite-difference
    /// gradient checks, parser fuzzing).
    pub fn tiny() -> Geometry {
        Geometry { n: 4, c_in: 4, hw: 6, c1: 4, c2: 4, classes: 3, lr: 0.2 }
    }
}

/// `f32[a,b,...]` shape text.
fn sh(dims: &[usize]) -> String {
    let mut s = String::from("f32[");
    for (i, d) in dims.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{d}");
    }
    s.push(']');
    s
}

/// `pred[a,b,...]` shape text.
fn shp(dims: &[usize]) -> String {
    format!("pred{}", &sh(dims)[3..])
}

/// Shortest-roundtrip f32 text (`{:?}` prints e.g. `0.2`, `7.6293945e-6`,
/// `-inf` — all exactly re-parsed by the interpreter's `str::parse::<f32>`).
fn f32_text(v: f32) -> String {
    format!("{v:?}")
}

/// First-line marker stamped on every emitted fallback artifact (the
/// parser skips `//` comment lines). `ArtifactSet::write_fallback` uses it
/// to tell its own output apart from real lowerings: files carrying the
/// prefix with a *different* fingerprint are stale fallback output and get
/// refreshed; files without it are real artifacts and are never touched.
pub const FALLBACK_PREFIX: &str = "// sparsetrain-offline-fallback";

/// Bump when the emitted graphs change without a geometry change, so
/// existing fallback artifacts regenerate.
pub const FALLBACK_VERSION: u32 = 1;

/// The exact marker line for `g` (version + full geometry fingerprint).
pub fn fallback_marker(g: &Geometry) -> String {
    format!("{FALLBACK_PREFIX} v{FALLBACK_VERSION} {g:?}")
}

const SCALAR_COMPS: &str = "%add_f32 {\n\
\x20 %p0 = f32[] parameter(0)\n\
\x20 %p1 = f32[] parameter(1)\n\
\x20 ROOT %add = f32[] add(%p0, %p1)\n\
}\n\
\n\
%max_f32 {\n\
\x20 %p0 = f32[] parameter(0)\n\
\x20 %p1 = f32[] parameter(1)\n\
\x20 ROOT %max = f32[] maximum(%p0, %p1)\n\
}\n";

/// Emit the shared forward pass: parameters 0-4 (`w1 w2 wfc bfc x`),
/// `%zero`, conv1/ReLU (`%z1`/`%a1`), conv2/ReLU (`%z2`/`%a2`), optional
/// ReLU-sparsity scalars (`%s1`/`%s2`), global average pool (`%pooled`,
/// plus `%inv_hw_b` which the backward pass reuses) and `%logits`.
fn emit_forward(out: &mut String, g: &Geometry, with_sparsity: bool) {
    let Geometry { n, c_in, hw, c1, c2, classes: cl, .. } = *g;
    let s4_1 = sh(&[n, c1, hw, hw]);
    let s4_2 = sh(&[n, c2, hw, hw]);
    let snl = sh(&[n, cl]);
    let a = |out: &mut String, line: String| {
        out.push_str("  ");
        out.push_str(&line);
        out.push('\n');
    };
    a(out, format!("%w1 = {} parameter(0)", sh(&[c1, c_in, 3, 3])));
    a(out, format!("%w2 = {} parameter(1)", sh(&[c2, c1, 3, 3])));
    a(out, format!("%wfc = {} parameter(2)", sh(&[cl, c2])));
    a(out, format!("%bfc = {} parameter(3)", sh(&[cl])));
    a(out, format!("%x = {} parameter(4)", sh(&[n, c_in, hw, hw])));
    a(out, "%zero = f32[] constant(0)".to_string());
    // conv1 + ReLU
    a(
        out,
        format!(
            "%z1 = {s4_1} convolution(%x, %w1), window={{size=3x3 pad=1_1x1_1}}, \
             dim_labels=bf01_oi01->bf01"
        ),
    );
    a(out, format!("%zeros1 = {s4_1} broadcast(%zero), dimensions={{}}"));
    a(out, format!("%a1 = {s4_1} maximum(%z1, %zeros1)"));
    // conv2 + ReLU
    a(
        out,
        format!(
            "%z2 = {s4_2} convolution(%a1, %w2), window={{size=3x3 pad=1_1x1_1}}, \
             dim_labels=bf01_oi01->bf01"
        ),
    );
    a(out, format!("%zeros2 = {s4_2} broadcast(%zero), dimensions={{}}"));
    a(out, format!("%a2 = {s4_2} maximum(%z2, %zeros2)"));
    if with_sparsity {
        // measured ReLU-output sparsity: mean(a == 0)
        a(out, format!("%a1_is0 = {} compare(%a1, %zeros1), direction=EQ", shp(&[n, c1, hw, hw])));
        a(out, format!("%a1_is0f = {s4_1} convert(%a1_is0)"));
        a(
            out,
            "%s1_sum = f32[] reduce(%a1_is0f, %zero), dimensions={0,1,2,3}, to_apply=%add_f32"
                .to_string(),
        );
        a(out, format!("%inv_e1 = f32[] constant({})", f32_text(1.0 / (n * c1 * hw * hw) as f32)));
        a(out, "%s1 = f32[] multiply(%s1_sum, %inv_e1)".to_string());
        a(out, format!("%a2_is0 = {} compare(%a2, %zeros2), direction=EQ", shp(&[n, c2, hw, hw])));
        a(out, format!("%a2_is0f = {s4_2} convert(%a2_is0)"));
        a(
            out,
            "%s2_sum = f32[] reduce(%a2_is0f, %zero), dimensions={0,1,2,3}, to_apply=%add_f32"
                .to_string(),
        );
        a(out, format!("%inv_e2 = f32[] constant({})", f32_text(1.0 / (n * c2 * hw * hw) as f32)));
        a(out, "%s2 = f32[] multiply(%s2_sum, %inv_e2)".to_string());
    }
    // global average pool → FC
    a(
        out,
        format!(
            "%pool_sum = {} reduce(%a2, %zero), dimensions={{2,3}}, to_apply=%add_f32",
            sh(&[n, c2])
        ),
    );
    a(out, format!("%inv_hw = f32[] constant({})", f32_text(1.0 / (hw * hw) as f32)));
    a(out, format!("%inv_hw_b = {} broadcast(%inv_hw), dimensions={{}}", sh(&[n, c2])));
    a(out, format!("%pooled = {} multiply(%pool_sum, %inv_hw_b)", sh(&[n, c2])));
    a(
        out,
        format!(
            "%logits0 = {snl} dot(%pooled, %wfc), lhs_contracting_dims={{1}}, \
             rhs_contracting_dims={{1}}"
        ),
    );
    a(out, format!("%bfc_b = {snl} broadcast(%bfc), dimensions={{1}}"));
    a(out, format!("%logits = {snl} add(%logits0, %bfc_b)"));
}

/// The full train-step module: forward + softmax-cross-entropy loss +
/// hand-lowered backward + SGD. Returns the 7-output tuple contract the
/// trainer expects: `(w1', w2', wfc', bfc', loss, s1, s2)`.
pub fn train_step_hlo(g: &Geometry) -> String {
    let Geometry { n, c_in, hw, c1, c2, classes: cl, lr } = *g;
    let s4_1 = sh(&[n, c1, hw, hw]);
    let s4_2 = sh(&[n, c2, hw, hw]);
    let p4_1 = shp(&[n, c1, hw, hw]);
    let p4_2 = shp(&[n, c2, hw, hw]);
    let snl = sh(&[n, cl]);
    let pnl = shp(&[n, cl]);

    let mut out = String::with_capacity(8192);
    out.push_str(&fallback_marker(g));
    out.push_str("\nHloModule train_step\n\n");
    out.push_str(SCALAR_COMPS);
    out.push_str("\nENTRY %train_step {\n");
    emit_forward(&mut out, g, true);
    let a = |out: &mut String, line: String| {
        out.push_str("  ");
        out.push_str(&line);
        out.push('\n');
    };
    a(&mut out, format!("%labels = s32[{n}] parameter(5)"));
    a(&mut out, "%neg_inf = f32[] constant(-inf)".to_string());
    // numerically stable log-softmax + probabilities
    a(
        &mut out,
        format!(
            "%row_max = {} reduce(%logits, %neg_inf), dimensions={{1}}, to_apply=%max_f32",
            sh(&[n])
        ),
    );
    a(&mut out, format!("%row_max_b = {snl} broadcast(%row_max), dimensions={{0}}"));
    a(&mut out, format!("%centered = {snl} subtract(%logits, %row_max_b)"));
    a(&mut out, format!("%exp_c = {snl} exponential(%centered)"));
    a(
        &mut out,
        format!(
            "%sum_exp = {} reduce(%exp_c, %zero), dimensions={{1}}, to_apply=%add_f32",
            sh(&[n])
        ),
    );
    a(&mut out, format!("%log_sum = {} log(%sum_exp)", sh(&[n])));
    a(&mut out, format!("%log_sum_b = {snl} broadcast(%log_sum), dimensions={{0}}"));
    a(&mut out, format!("%logp = {snl} subtract(%centered, %log_sum_b)"));
    a(&mut out, format!("%sum_exp_b = {snl} broadcast(%sum_exp), dimensions={{0}}"));
    a(&mut out, format!("%probs = {snl} divide(%exp_c, %sum_exp_b)"));
    // one-hot labels via iota + compare
    a(&mut out, format!("%iota_cl = s32[{n},{cl}] iota(), iota_dimension=1"));
    a(&mut out, format!("%labels_b = s32[{n},{cl}] broadcast(%labels), dimensions={{0}}"));
    a(&mut out, format!("%onehot_p = {pnl} compare(%labels_b, %iota_cl), direction=EQ"));
    a(&mut out, format!("%onehot = {snl} convert(%onehot_p)"));
    // loss = -(1/N) * Σ onehot ⊙ logp
    a(&mut out, format!("%picked = {snl} multiply(%onehot, %logp)"));
    a(
        &mut out,
        "%picked_sum = f32[] reduce(%picked, %zero), dimensions={0,1}, to_apply=%add_f32"
            .to_string(),
    );
    a(&mut out, format!("%neg_inv_n = f32[] constant({})", f32_text(-1.0 / n as f32)));
    a(&mut out, "%loss = f32[] multiply(%picked_sum, %neg_inv_n)".to_string());
    // backward: softmax-cross-entropy → dlogits = (probs - onehot)/N
    a(&mut out, format!("%pdiff = {snl} subtract(%probs, %onehot)"));
    a(&mut out, format!("%inv_n = f32[] constant({})", f32_text(1.0 / n as f32)));
    a(&mut out, format!("%inv_n_b = {snl} broadcast(%inv_n), dimensions={{}}"));
    a(&mut out, format!("%dlogits = {snl} multiply(%pdiff, %inv_n_b)"));
    // FC gradients
    a(
        &mut out,
        format!(
            "%g_bfc = {} reduce(%dlogits, %zero), dimensions={{0}}, to_apply=%add_f32",
            sh(&[cl])
        ),
    );
    a(
        &mut out,
        format!(
            "%g_wfc = {} dot(%dlogits, %pooled), lhs_contracting_dims={{0}}, \
             rhs_contracting_dims={{0}}",
            sh(&[cl, c2])
        ),
    );
    a(
        &mut out,
        format!(
            "%d_pooled = {} dot(%dlogits, %wfc), lhs_contracting_dims={{1}}, \
             rhs_contracting_dims={{0}}",
            sh(&[n, c2])
        ),
    );
    // backward through the mean pool
    a(&mut out, format!("%d_pool_scaled = {} multiply(%d_pooled, %inv_hw_b)", sh(&[n, c2])));
    a(&mut out, format!("%d_a2 = {s4_2} broadcast(%d_pool_scaled), dimensions={{0,1}}"));
    // ReLU2 mask
    a(&mut out, format!("%m2 = {p4_2} compare(%z2, %zeros2), direction=GT"));
    a(&mut out, format!("%d_z2 = {s4_2} select(%m2, %d_a2, %zeros2)"));
    // conv2 gradients: weight grad contracts batch (fb01_io01->bf01),
    // input grad is reverse(w) with io01 labels
    a(
        &mut out,
        format!(
            "%g_w2_t = {} convolution(%a1, %d_z2), window={{size={hw}x{hw} pad=1_1x1_1}}, \
             dim_labels=fb01_io01->bf01",
            sh(&[c1, c2, 3, 3])
        ),
    );
    a(&mut out, format!("%g_w2 = {} transpose(%g_w2_t), dimensions={{1,0,2,3}}", sh(&[c2, c1, 3, 3])));
    a(&mut out, format!("%w2_r = {} reverse(%w2), dimensions={{2,3}}", sh(&[c2, c1, 3, 3])));
    a(
        &mut out,
        format!(
            "%d_a1 = {s4_1} convolution(%d_z2, %w2_r), window={{size=3x3 pad=1_1x1_1}}, \
             dim_labels=bf01_io01->bf01"
        ),
    );
    // ReLU1 mask + conv1 weight gradient
    a(&mut out, format!("%m1 = {p4_1} compare(%z1, %zeros1), direction=GT"));
    a(&mut out, format!("%d_z1 = {s4_1} select(%m1, %d_a1, %zeros1)"));
    a(
        &mut out,
        format!(
            "%g_w1_t = {} convolution(%x, %d_z1), window={{size={hw}x{hw} pad=1_1x1_1}}, \
             dim_labels=fb01_io01->bf01",
            sh(&[c_in, c1, 3, 3])
        ),
    );
    a(&mut out, format!("%g_w1 = {} transpose(%g_w1_t), dimensions={{1,0,2,3}}", sh(&[c1, c_in, 3, 3])));
    // SGD: p' = p - lr * g
    a(&mut out, format!("%lr = f32[] constant({})", f32_text(lr)));
    for (nm, dims) in [
        ("w1", vec![c1, c_in, 3, 3]),
        ("w2", vec![c2, c1, 3, 3]),
        ("wfc", vec![cl, c2]),
        ("bfc", vec![cl]),
    ] {
        let s = sh(&dims);
        a(&mut out, format!("%lr_{nm} = {s} broadcast(%lr), dimensions={{}}"));
        a(&mut out, format!("%step_{nm} = {s} multiply(%lr_{nm}, %g_{nm})"));
        a(&mut out, format!("%new_{nm} = {s} subtract(%{nm}, %step_{nm})"));
    }
    a(
        &mut out,
        format!(
            "ROOT %out = ({}, {}, {}, {}, f32[], f32[], f32[]) \
             tuple(%new_w1, %new_w2, %new_wfc, %new_bfc, %loss, %s1, %s2)",
            sh(&[c1, c_in, 3, 3]),
            sh(&[c2, c1, 3, 3]),
            sh(&[cl, c2]),
            sh(&[cl]),
        ),
    );
    out.push_str("}\n");
    out
}

/// The predict module: forward only, `(logits,)`.
pub fn predict_hlo(g: &Geometry) -> String {
    let mut out = String::with_capacity(2048);
    out.push_str(&fallback_marker(g));
    out.push_str("\nHloModule predict\n\n");
    out.push_str(SCALAR_COMPS);
    out.push_str("\nENTRY %predict {\n");
    emit_forward(&mut out, g, false);
    let _ = writeln!(out, "  ROOT %out = ({}) tuple(%logits)", sh(&[g.n, g.classes]));
    out.push_str("}\n");
    out
}

/// The single-convolution kernel module: `(conv2d(x, w, pad 1),)` — the L1
/// kernel exposed for Rust-side validation (bit-compared against
/// `kernels::reference::conv_fwd` in the e2e tests).
pub fn kernel_fwd_hlo(g: &Geometry) -> String {
    let Geometry { n, c_in, hw, c1, .. } = *g;
    let mut out = String::with_capacity(512);
    out.push_str(&fallback_marker(g));
    out.push_str("\nHloModule kernel_fwd\n\nENTRY %kernel_fwd {\n");
    let _ = writeln!(out, "  %x = {} parameter(0)", sh(&[n, c_in, hw, hw]));
    let _ = writeln!(out, "  %w = {} parameter(1)", sh(&[c1, c_in, 3, 3]));
    let _ = writeln!(
        out,
        "  %y = {} convolution(%x, %w), window={{size=3x3 pad=1_1x1_1}}, \
         dim_labels=bf01_oi01->bf01",
        sh(&[n, c1, hw, hw])
    );
    let _ = writeln!(out, "  ROOT %out = ({}) tuple(%y)", sh(&[n, c1, hw, hw]));
    out.push_str("}\n");
    out
}

/// A single-convolution probe module (no artifact marker — this is test
/// plumbing, not a fallback artifact): `ROOT = convolution(lhs, rhs)` with
/// the given shapes and raw `window=`/`dim_labels=` attribute text. Used
/// by the conv-routing parity suite to drive the interpreter — naive and
/// kernel-routed — over arbitrary geometries and label permutations.
pub fn conv_module_hlo(
    lhs: &[usize],
    rhs: &[usize],
    out: &[usize],
    window: &str,
    dim_labels: &str,
) -> String {
    let mut text = String::with_capacity(256);
    text.push_str("HloModule conv_probe\n\nENTRY %conv_probe {\n");
    let _ = writeln!(text, "  %lhs = {} parameter(0)", sh(lhs));
    let _ = writeln!(text, "  %rhs = {} parameter(1)", sh(rhs));
    let _ = writeln!(
        text,
        "  ROOT %out = {} convolution(%lhs, %rhs), window={window}, dim_labels={dim_labels}",
        sh(out)
    );
    text.push_str("}\n");
    text
}

// ---------------------------------------------------------------------------
// Inventory-driven emitter: train_step / predict for any `nets::zoo` spec.
// ---------------------------------------------------------------------------

/// A zoo network at a concrete [`Scale`], ready for emission.
#[derive(Debug, Clone)]
pub struct NetModel {
    pub spec: NetSpec,
    pub scale: Scale,
    /// Label classes of the synthetic task (≤ input channels so the
    /// per-class channel signatures of `kernels::layers::synthetic_batch`
    /// survive the global average pool).
    pub classes: usize,
    /// SGD learning rate baked into the train-step graph.
    pub lr: f32,
}

impl NetModel {
    pub fn new(network: Network, scale: Scale) -> NetModel {
        let spec = NetSpec::build_scaled(network, scale);
        // BN keeps the deep loss surface well-conditioned; the BN-free
        // inventories (VGG16, Fixup) need a gentler step to stay stable.
        let lr = if spec.layers.iter().any(|l| l.has_bn) { 0.05 } else { 0.02 };
        NetModel { spec, scale, classes: 8, lr }
    }

    /// Identifier-safe key, e.g. `resnet34_small`.
    pub fn key(&self) -> String {
        format!("{}_{}", self.spec.network.key(), self.scale.key())
    }

    /// `[n, c, h, w]` of the input images (channels padded to V=16).
    pub fn input_dims(&self) -> [usize; 4] {
        let c = &self.spec.layers[0].cfg;
        [c.n, c.c, c.h, c.w]
    }
}

/// Artifact stems for a model: (`train_step_<key>`, `predict_<key>`).
pub fn net_artifact_names(m: &NetModel) -> (String, String) {
    (format!("train_step_{}", m.key()), format!("predict_{}", m.key()))
}

/// Marker line for emitted net artifacts (same contract as
/// [`fallback_marker`]: first line of the file, fingerprints the model).
pub fn net_fallback_marker(m: &NetModel) -> String {
    format!(
        "{FALLBACK_PREFIX} v{FALLBACK_VERSION} net={} layers={} classes={} lr={}",
        m.key(),
        m.spec.layers.len(),
        m.classes,
        f32_text(m.lr),
    )
}

/// Manifest of an emitted net train-step graph: what the trainer feeds in,
/// what it reads out, and how conv instructions map to profiler series.
#[derive(Debug, Clone)]
pub struct NetTrainPlan {
    /// Trainable parameters in positional order (name without `%`, dims).
    /// The input image is the next parameter after these, labels the last.
    pub params: Vec<(String, Vec<usize>)>,
    /// Per-ReLU measured-sparsity series `<layer>_relu`, in root-tuple
    /// order directly after the loss scalar.
    pub relu_keys: Vec<String>,
    /// Per-layer output-gradient sparsity series `<layer>_dz`, following
    /// the ReLU block in the root tuple.
    pub dz_keys: Vec<String>,
    /// Conv instruction name → profiler series whose recent mean predicts
    /// that conv's checked-operand sparsity (feeds the `Selector` through
    /// `OpRouter::set_profiled_sparsity`).
    pub sparsity_feeds: Vec<(String, String)>,
    /// Instruction names of strided forward convs (the downsample forms
    /// the widened router envelope must handle).
    pub strided_fwd: Vec<String>,
    pub input_dims: [usize; 4],
    pub classes: usize,
}

impl NetTrainPlan {
    /// Root-tuple arity: updated params, loss, ReLU and dz sparsities.
    pub fn n_outputs(&self) -> usize {
        self.params.len() + 1 + self.relu_keys.len() + self.dz_keys.len()
    }
}

/// Emission-level view of the inventory: plain convs (stem / VGG) and
/// residual blocks, with 2×2/2 maxpools inferred from spatial jumps.
#[derive(Debug, Clone)]
enum ItemKind {
    Single(usize),
    Block { convs: Vec<usize>, down: Option<usize> },
}

#[derive(Debug, Clone)]
struct TopoItem {
    kind: ItemKind,
    pool_after: bool,
}

/// `s3b1_conv2` → `("s3b1", "conv2")`; VGG names (`conv1_1`, `conv7`) and
/// the stem don't match and stay `Single`.
fn block_parts(name: &str) -> Option<(&str, &str)> {
    let (pfx, role) = name.rsplit_once('_')?;
    if pfx.starts_with('s') && matches!(role, "conv1" | "conv2" | "conv3" | "down") {
        Some((pfx, role))
    } else {
        None
    }
}

fn item_first_layer(item: &TopoItem) -> usize {
    match &item.kind {
        ItemKind::Single(li) => *li,
        ItemKind::Block { convs, .. } => convs[0],
    }
}

fn item_last_layer(item: &TopoItem) -> usize {
    match &item.kind {
        ItemKind::Single(li) => *li,
        ItemKind::Block { convs, .. } => *convs.last().unwrap(),
    }
}

/// Group the layer inventory into stem/VGG singles and residual blocks
/// (by the `s<stage>b<block>_` naming scheme), then infer the maxpool
/// positions from spatial discontinuities between consecutive items.
fn topology(spec: &NetSpec) -> Result<Vec<TopoItem>, String> {
    let ls = &spec.layers;
    let mut items: Vec<TopoItem> = Vec::new();
    let mut i = 0;
    while i < ls.len() {
        if let Some((pfx, _)) = block_parts(&ls[i].name) {
            let pfx = pfx.to_string();
            let mut convs = Vec::new();
            let mut down = None;
            while i < ls.len() {
                match block_parts(&ls[i].name) {
                    Some((p, "down")) if p == pfx => {
                        down = Some(i);
                        i += 1;
                    }
                    Some((p, _)) if p == pfx => {
                        convs.push(i);
                        i += 1;
                    }
                    _ => break,
                }
            }
            if !(2..=3).contains(&convs.len()) {
                return Err(format!("block {pfx}: expected 2-3 main convs, got {}", convs.len()));
            }
            if !ls[*convs.last().unwrap()].after_shortcut {
                return Err(format!("block {pfx}: last conv must carry after_shortcut"));
            }
            items.push(TopoItem { kind: ItemKind::Block { convs, down }, pool_after: false });
        } else {
            items.push(TopoItem { kind: ItemKind::Single(i), pool_after: false });
            i += 1;
        }
    }
    for j in 0..items.len().saturating_sub(1) {
        let out_cfg = &ls[item_last_layer(&items[j])].cfg;
        let next_cfg = &ls[item_first_layer(&items[j + 1])].cfg;
        if next_cfg.c != out_cfg.k {
            return Err(format!(
                "channel chain broken between items {j} and {}: {} -> {}",
                j + 1,
                out_cfg.k,
                next_cfg.c
            ));
        }
        let out_hw = out_cfg.out_h();
        if next_cfg.h == out_hw {
            continue;
        }
        if next_cfg.h * 2 == out_hw {
            items[j].pool_after = true; // 2×2/2 maxpool bridges the halving
        } else {
            return Err(format!("no pooling form bridges spatial {out_hw} -> {}", next_cfg.h));
        }
    }
    Ok(items)
}

/// Pre-flight checks so the emitters proper are infallible: symmetric-pad
/// backward forms must exist (`s ≥ pad+1`), and strided convs must satisfy
/// the zero-insertion upsampling invariant `out·t == h + 2p − s + 1`, which
/// makes their BWI/BWW exact stride-1 convolutions of the upsampled
/// gradient (the form the kernel router handles).
fn validate_emission(spec: &NetSpec, items: &[TopoItem]) -> Result<(), String> {
    let _ = items;
    for l in &spec.layers {
        let c = &l.cfg;
        if c.stride_p != c.stride_o {
            return Err(format!("{}: anisotropic stride unsupported", l.name));
        }
        if !l.is_first && (c.s < c.pad_h + 1 || c.r < c.pad_w + 1) {
            return Err(format!("{}: BWI needs s > pad", l.name));
        }
        let t = c.stride_p;
        if t > 1
            && (c.out_h() * t != c.h + 2 * c.pad_h - c.s + 1
                || c.out_w() * t != c.w + 2 * c.pad_w - c.r + 1)
        {
            return Err(format!(
                "{}: stride-{t} conv violates the upsampling invariant \
                 (out·t must equal h + 2p − s + 1)",
                l.name
            ));
        }
    }
    Ok(())
}

/// Forward-pass record for one conv layer, consumed by the backward pass.
#[derive(Debug, Clone)]
struct FwdRec {
    /// Activation value feeding this conv (BWW's lhs).
    input: String,
    /// Pre-activation its ReLU mask compares against (`%z_*`, `%bn_*`, or
    /// the residual `%res_*` for the post-shortcut ReLU).
    pre: String,
    /// Conv-output-shaped zeros, shared by ReLU/mask/select emission.
    zeros: String,
    /// Conv output dims `[n, k, oh, ow]`.
    dims: [usize; 4],
}

#[derive(Debug, Clone)]
struct PoolRec {
    nm: String,
    six: [usize; 6],
    in4: [usize; 4],
    out4: [usize; 4],
}

struct NetEmitter<'a> {
    m: &'a NetModel,
    items: Vec<TopoItem>,
    train: bool,
    out: String,
    recs: Vec<Option<FwdRec>>,
    pools: Vec<Option<PoolRec>>,
    relu_keys: Vec<String>,
    dz_keys: Vec<String>,
    feeds: Vec<(String, String)>,
    strided: Vec<String>,
}

impl<'a> NetEmitter<'a> {
    fn new(m: &'a NetModel, items: Vec<TopoItem>, train: bool) -> NetEmitter<'a> {
        let nl = m.spec.layers.len();
        NetEmitter {
            m,
            items,
            train,
            out: String::with_capacity(64 * 1024),
            recs: vec![None; nl],
            pools: Vec::new(),
            relu_keys: Vec::new(),
            dz_keys: Vec::new(),
            feeds: Vec::new(),
            strided: Vec::new(),
        }
    }

    fn ln(&mut self, line: String) {
        self.out.push_str("  ");
        self.out.push_str(&line);
        self.out.push('\n');
    }

    fn layer(&self, li: usize) -> NetLayer {
        self.m.spec.layers[li].clone()
    }

    /// Parameters, shared constants, and the module preamble.
    fn prelude(&mut self) -> Vec<(String, Vec<usize>)> {
        let mut params: Vec<(String, Vec<usize>)> = Vec::new();
        for l in &self.m.spec.layers {
            params.push((format!("w_{}", l.name), vec![l.cfg.k, l.cfg.c, l.cfg.s, l.cfg.r]));
        }
        let last = self.m.spec.layers.last().unwrap().cfg.k;
        params.push(("wfc".to_string(), vec![self.m.classes, last]));
        params.push(("bfc".to_string(), vec![self.m.classes]));
        for (i, (nm, dims)) in params.iter().enumerate() {
            self.ln(format!("%{nm} = {} parameter({i})", sh(dims)));
        }
        let np = params.len();
        let id = self.m.input_dims();
        self.ln(format!("%x = {} parameter({np})", sh(&id)));
        if self.train {
            self.ln(format!("%labels = s32[{}] parameter({})", id[0], np + 1));
        }
        self.ln("%zero = f32[] constant(0)".to_string());
        self.ln("%neg_inf = f32[] constant(-inf)".to_string());
        if self.m.spec.layers.iter().any(|l| l.has_bn) {
            self.ln("%bn_eps = f32[] constant(1e-5)".to_string());
            self.ln("%bn_nh = f32[] constant(-0.5)".to_string());
        }
        params
    }

    /// Simplified batch norm (no affine): per-channel standardization with
    /// batch statistics; `1/σ` is lowered as `exp(-0.5·log(var+ε))` since
    /// the interpreter has no rsqrt. Returns the normalized value `%bn_<nm>`.
    fn bn_fwd(&mut self, nm: &str, z: &str, od: [usize; 4]) -> String {
        let k = od[1];
        let m = (od[0] * od[2] * od[3]) as f32;
        let sk = sh(&[k]);
        let s4 = sh(&od);
        self.ln(format!(
            "%bn_ms_{nm} = {sk} reduce({z}, %zero), dimensions={{0,2,3}}, to_apply=%add_f32"
        ));
        self.ln(format!("%bn_invm_{nm} = f32[] constant({})", f32_text(1.0 / m)));
        self.ln(format!("%bn_invmb_{nm} = {sk} broadcast(%bn_invm_{nm}), dimensions={{}}"));
        self.ln(format!("%bn_mu_{nm} = {sk} multiply(%bn_ms_{nm}, %bn_invmb_{nm})"));
        self.ln(format!("%bn_mub_{nm} = {s4} broadcast(%bn_mu_{nm}), dimensions={{1}}"));
        self.ln(format!("%bn_xc_{nm} = {s4} subtract({z}, %bn_mub_{nm})"));
        self.ln(format!("%bn_xc2_{nm} = {s4} multiply(%bn_xc_{nm}, %bn_xc_{nm})"));
        self.ln(format!(
            "%bn_vs_{nm} = {sk} reduce(%bn_xc2_{nm}, %zero), dimensions={{0,2,3}}, \
             to_apply=%add_f32"
        ));
        self.ln(format!("%bn_var_{nm} = {sk} multiply(%bn_vs_{nm}, %bn_invmb_{nm})"));
        self.ln(format!("%bn_epsb_{nm} = {sk} broadcast(%bn_eps), dimensions={{}}"));
        self.ln(format!("%bn_ve_{nm} = {sk} add(%bn_var_{nm}, %bn_epsb_{nm})"));
        self.ln(format!("%bn_lve_{nm} = {sk} log(%bn_ve_{nm})"));
        self.ln(format!("%bn_nhb_{nm} = {sk} broadcast(%bn_nh), dimensions={{}}"));
        self.ln(format!("%bn_larg_{nm} = {sk} multiply(%bn_lve_{nm}, %bn_nhb_{nm})"));
        self.ln(format!("%bn_isig_{nm} = {sk} exponential(%bn_larg_{nm})"));
        self.ln(format!("%bn_isigb_{nm} = {s4} broadcast(%bn_isig_{nm}), dimensions={{1}}"));
        self.ln(format!("%bn_{nm} = {s4} multiply(%bn_xc_{nm}, %bn_isigb_{nm})"));
        format!("%bn_{nm}")
    }

    /// BN backward: given `g` = ∂L/∂x̂, emit
    /// `dz = (g − mean(g) − x̂·mean(g·x̂)) / σ` — the mean-subtraction terms
    /// are what densify the output gradient (§2.3: BN destroys BWI
    /// sparsity). Returns `%dz value` name.
    fn bn_bwd(&mut self, nm: &str, g: &str, od: [usize; 4]) -> String {
        let k = od[1];
        let sk = sh(&[k]);
        let s4 = sh(&od);
        self.ln(format!(
            "%gbs_{nm} = {sk} reduce({g}, %zero), dimensions={{0,2,3}}, to_apply=%add_f32"
        ));
        self.ln(format!("%gbm_{nm} = {sk} multiply(%gbs_{nm}, %bn_invmb_{nm})"));
        self.ln(format!("%gbmb_{nm} = {s4} broadcast(%gbm_{nm}), dimensions={{1}}"));
        self.ln(format!("%gx0_{nm} = {s4} multiply({g}, %bn_{nm})"));
        self.ln(format!(
            "%gxs_{nm} = {sk} reduce(%gx0_{nm}, %zero), dimensions={{0,2,3}}, to_apply=%add_f32"
        ));
        self.ln(format!("%gxm_{nm} = {sk} multiply(%gxs_{nm}, %bn_invmb_{nm})"));
        self.ln(format!("%gxmb_{nm} = {s4} broadcast(%gxm_{nm}), dimensions={{1}}"));
        self.ln(format!("%gxh_{nm} = {s4} multiply(%bn_{nm}, %gxmb_{nm})"));
        self.ln(format!("%gt1_{nm} = {s4} subtract({g}, %gbmb_{nm})"));
        self.ln(format!("%gt2_{nm} = {s4} subtract(%gt1_{nm}, %gxh_{nm})"));
        self.ln(format!("%dz_{nm} = {s4} multiply(%gt2_{nm}, %bn_isigb_{nm})"));
        format!("%dz_{nm}")
    }

    /// One forward conv (+BN). Leaves `pre` at the value the ReLU (or the
    /// residual add) consumes. Records selector feeds for the FWD and BWW
    /// forms, keyed by the input activation's producing ReLU series.
    fn conv_fwd(&mut self, l: &NetLayer, input: &str, input_feed: Option<&str>) -> FwdRec {
        let nm = &l.name;
        let c = &l.cfg;
        let od = [c.n, c.k, c.out_h(), c.out_w()];
        let so = sh(&od);
        let stride = if c.stride_p != 1 {
            format!(" stride={}x{}", c.stride_p, c.stride_o)
        } else {
            String::new()
        };
        self.ln(format!(
            "%z_{nm} = {so} convolution({input}, %w_{nm}), window={{size={}x{} \
             pad={}_{}x{}_{}{stride}}}, dim_labels=bf01_oi01->bf01",
            c.s, c.r, c.pad_h, c.pad_h, c.pad_w, c.pad_w
        ));
        if c.stride_p != 1 {
            self.strided.push(format!("z_{nm}"));
        }
        if let Some(f) = input_feed {
            self.feeds.push((format!("z_{nm}"), f.to_string()));
            self.feeds.push((format!("bww_{nm}"), f.to_string()));
        }
        self.ln(format!("%zer_{nm} = {so} broadcast(%zero), dimensions={{}}"));
        let pre = if l.has_bn { self.bn_fwd(nm, &format!("%z_{nm}"), od) } else { format!("%z_{nm}") };
        FwdRec { input: input.to_string(), pre, zeros: format!("%zer_{nm}"), dims: od }
    }

    /// ReLU on `pre`; in train graphs also measures output sparsity
    /// (`mean(a == 0)` → root-tuple scalar, profiler series `<nm>_relu`).
    fn relu(&mut self, nm: &str, pre: &str, zeros: &str, od: [usize; 4]) -> String {
        let s4 = sh(&od);
        self.ln(format!("%a_{nm} = {s4} maximum({pre}, {zeros})"));
        if self.train {
            self.ln(format!(
                "%sq_{nm} = {} compare(%a_{nm}, {zeros}), direction=EQ",
                shp(&od)
            ));
            self.ln(format!("%sqf_{nm} = {s4} convert(%sq_{nm})"));
            self.ln(format!(
                "%sqs_{nm} = f32[] reduce(%sqf_{nm}, %zero), dimensions={{0,1,2,3}}, \
                 to_apply=%add_f32"
            ));
            let inv = 1.0 / (od.iter().product::<usize>() as f32);
            self.ln(format!("%sinv_{nm} = f32[] constant({})", f32_text(inv)));
            self.ln(format!("%sp_{nm} = f32[] multiply(%sqs_{nm}, %sinv_{nm})"));
            self.relu_keys.push(format!("{nm}_relu"));
        }
        format!("%a_{nm}")
    }

    /// 2×2/2 maxpool via reshape-to-rank-6 + max-reduce over the window
    /// dims. The tie-splitting backward lives in `pool_bwd`.
    fn pool_fwd(&mut self, ii: usize, nm: &str, act: &str, d4: [usize; 4]) -> (String, [usize; 4]) {
        let (h2, w2) = (d4[2] / 2, d4[3] / 2);
        let six = [d4[0], d4[1], h2, 2, w2, 2];
        let out4 = [d4[0], d4[1], h2, w2];
        self.ln(format!("%p6_{nm} = {} reshape({act})", sh(&six)));
        self.ln(format!(
            "%pool_{nm} = {} reduce(%p6_{nm}, %neg_inf), dimensions={{3,5}}, to_apply=%max_f32",
            sh(&out4)
        ));
        self.pools[ii] = Some(PoolRec { nm: nm.to_string(), six, in4: d4, out4 });
        (format!("%pool_{nm}"), out4)
    }

    /// Maxpool backward: route the pooled gradient to every element tying
    /// the window max, split evenly among ties (matches the equal-share
    /// convention; keeps the graph free of argmax plumbing).
    fn pool_bwd(&mut self, rec: &PoolRec, d: &str) -> String {
        let nm = &rec.nm;
        let s6 = sh(&rec.six);
        let s4 = sh(&rec.out4);
        self.ln(format!("%pb_{nm} = {s6} broadcast(%pool_{nm}), dimensions={{0,1,2,4}}"));
        self.ln(format!(
            "%peq_{nm} = {} compare(%p6_{nm}, %pb_{nm}), direction=EQ",
            shp(&rec.six)
        ));
        self.ln(format!("%peqf_{nm} = {s6} convert(%peq_{nm})"));
        self.ln(format!(
            "%pcnt_{nm} = {s4} reduce(%peqf_{nm}, %zero), dimensions={{3,5}}, to_apply=%add_f32"
        ));
        self.ln(format!("%pdn_{nm} = {s4} divide({d}, %pcnt_{nm})"));
        self.ln(format!("%pdb_{nm} = {s6} broadcast(%pdn_{nm}), dimensions={{0,1,2,4}}"));
        self.ln(format!("%pd6_{nm} = {s6} multiply(%peqf_{nm}, %pdb_{nm})"));
        self.ln(format!("%dap_{nm} = {} reshape(%pd6_{nm})", sh(&rec.in4)));
        format!("%dap_{nm}")
    }

    /// ReLU backward: mask the incoming gradient by `pre > 0`.
    fn relu_bwd(&mut self, nm: &str, rec: &FwdRec, d: &str, out_name: &str) -> String {
        self.ln(format!(
            "%rm_{nm} = {} compare({}, {}), direction=GT",
            shp(&rec.dims),
            rec.pre,
            rec.zeros
        ));
        self.ln(format!(
            "%{out_name} = {} select(%rm_{nm}, {d}, {})",
            sh(&rec.dims),
            rec.zeros
        ));
        format!("%{out_name}")
    }

    /// Zero-insertion upsampling of a strided conv's output gradient:
    /// `dz[n,k,oh,ow]` → `[n,k,oh·t,ow·t]` with the gradient at stride-t
    /// positions and zeros between. Turns strided BWI/BWW into exact
    /// stride-1 convolutions (the invariant is pre-checked in
    /// `validate_emission`).
    fn upsample(&mut self, nm: &str, dz: &str, od: [usize; 4], t: usize) -> (String, [usize; 4]) {
        let six = [od[0], od[1], od[2], t, od[3], t];
        let up = [od[0], od[1], od[2] * t, od[3] * t];
        let s6 = sh(&six);
        self.ln(format!("%ui_{nm} = s32[{t}] iota(), iota_dimension=0"));
        self.ln(format!("%uz_{nm} = s32[] constant(0)"));
        self.ln(format!("%uzb_{nm} = s32[{t}] broadcast(%uz_{nm}), dimensions={{}}"));
        self.ln(format!("%ue_{nm} = pred[{t}] compare(%ui_{nm}, %uzb_{nm}), direction=EQ"));
        self.ln(format!("%uf_{nm} = f32[{t}] convert(%ue_{nm})"));
        self.ln(format!("%u6_{nm} = {s6} broadcast({dz}), dimensions={{0,1,2,4}}"));
        self.ln(format!("%um3_{nm} = {s6} broadcast(%uf_{nm}), dimensions={{3}}"));
        self.ln(format!("%um5_{nm} = {s6} broadcast(%uf_{nm}), dimensions={{5}}"));
        self.ln(format!("%ua_{nm} = {s6} multiply(%u6_{nm}, %um3_{nm})"));
        self.ln(format!("%ub_{nm} = {s6} multiply(%ua_{nm}, %um5_{nm})"));
        self.ln(format!("%dzu_{nm} = {} reshape(%ub_{nm})", sh(&up)));
        (format!("%dzu_{nm}"), up)
    }

    /// Backward through one conv layer. `d` is the gradient w.r.t. this
    /// layer's activation output (`masked = false`, a private ReLU) or
    /// already w.r.t. its pre-activation (`masked = true`, the shared
    /// post-shortcut mask was applied by the caller). Emits BN backward,
    /// dz-sparsity measurement, the weight gradient (`%bww_*`/`%gw_*`) and
    /// — except for the first layer, whose input is the image — the input
    /// gradient (`%bwi_*`), which is returned.
    fn conv_bwd(&mut self, li: usize, d: &str, masked: bool) -> Option<String> {
        let l = self.layer(li);
        let nm = l.name.clone();
        let c = l.cfg;
        let rec = self.recs[li].clone().expect("forward emitted");
        let od = rec.dims;
        let g = if masked {
            d.to_string()
        } else {
            self.relu_bwd(&nm, &rec, d, &format!("dm_{nm}"))
        };
        let dz = if l.has_bn { self.bn_bwd(&nm, &g, od) } else { g };
        if self.train {
            // measured output-gradient sparsity: mean(dz == 0) — the §2.3
            // signal (BWI sparsity exists only where no BN follows the conv)
            let s4 = sh(&od);
            self.ln(format!(
                "%dq_{nm} = {} compare({dz}, {}), direction=EQ",
                shp(&od),
                rec.zeros
            ));
            self.ln(format!("%dqf_{nm} = {s4} convert(%dq_{nm})"));
            self.ln(format!(
                "%dqs_{nm} = f32[] reduce(%dqf_{nm}, %zero), dimensions={{0,1,2,3}}, \
                 to_apply=%add_f32"
            ));
            let inv = 1.0 / (od.iter().product::<usize>() as f32);
            self.ln(format!("%dinv_{nm} = f32[] constant({})", f32_text(inv)));
            self.ln(format!("%dsp_{nm} = f32[] multiply(%dqs_{nm}, %dinv_{nm})"));
            self.dz_keys.push(format!("{nm}_dz"));
        }
        let t = c.stride_p;
        let (dzsrc, ud) = if t > 1 { self.upsample(&nm, &dz, od, t) } else { (dz, od) };
        // weight gradient: contract the batch dim (fb01_io01->bf01), window
        // = the (upsampled) gradient's spatial extent, output [c,k,s,r]
        self.ln(format!(
            "%bww_{nm} = {} convolution({}, {dzsrc}), window={{size={}x{} \
             pad={}_{}x{}_{}}}, dim_labels=fb01_io01->bf01",
            sh(&[c.c, c.k, c.s, c.r]),
            rec.input,
            ud[2],
            ud[3],
            c.pad_h,
            c.pad_h,
            c.pad_w,
            c.pad_w
        ));
        self.ln(format!(
            "%gw_{nm} = {} transpose(%bww_{nm}), dimensions={{1,0,2,3}}",
            sh(&[c.k, c.c, c.s, c.r])
        ));
        if l.is_first {
            return None; // image gradient is unused; skip the stem BWI
        }
        self.feeds.push((format!("bwi_{nm}"), format!("{nm}_dz")));
        let (qh, qw) = (c.s - 1 - c.pad_h, c.r - 1 - c.pad_w);
        self.ln(format!(
            "%wr_{nm} = {} reverse(%w_{nm}), dimensions={{2,3}}",
            sh(&[c.k, c.c, c.s, c.r])
        ));
        self.ln(format!(
            "%bwi_{nm} = {} convolution({dzsrc}, %wr_{nm}), window={{size={}x{} \
             pad={qh}_{qh}x{qw}_{qw}}}, dim_labels=bf01_io01->bf01",
            sh(&[c.n, c.c, c.h, c.w]),
            c.s,
            c.r
        ));
        Some(format!("%bwi_{nm}"))
    }

    /// Forward over the whole item list; returns the final activation and
    /// its dims.
    fn forward(&mut self) -> (String, [usize; 4]) {
        self.pools = vec![None; self.items.len()];
        let items = self.items.clone();
        let mut act = "%x".to_string();
        let mut feed: Option<String> = None;
        let mut dims = self.m.input_dims();
        for (ii, item) in items.iter().enumerate() {
            match &item.kind {
                ItemKind::Single(li) => {
                    let l = self.layer(*li);
                    let rec = self.conv_fwd(&l, &act, feed.as_deref());
                    dims = rec.dims;
                    act = self.relu(&l.name, &rec.pre, &rec.zeros, dims);
                    self.recs[*li] = Some(rec);
                    feed = Some(format!("{}_relu", l.name));
                }
                ItemKind::Block { convs, down } => {
                    let block_in = act.clone();
                    let block_feed = feed.clone();
                    let mut cur = act.clone();
                    let mut cfeed = feed.clone();
                    for (ci, &li) in convs.iter().enumerate() {
                        let l = self.layer(li);
                        let rec = self.conv_fwd(&l, &cur, cfeed.as_deref());
                        dims = rec.dims;
                        if ci + 1 < convs.len() {
                            cur = self.relu(&l.name, &rec.pre, &rec.zeros, dims);
                            cfeed = Some(format!("{}_relu", l.name));
                        } else {
                            cur = rec.pre.clone(); // awaits the shortcut add
                        }
                        self.recs[li] = Some(rec);
                    }
                    let short = match down {
                        Some(dli) => {
                            let l = self.layer(*dli);
                            let rec = self.conv_fwd(&l, &block_in, block_feed.as_deref());
                            let s = rec.pre.clone();
                            self.recs[*dli] = Some(rec);
                            s
                        }
                        None => block_in,
                    };
                    let last_li = *convs.last().unwrap();
                    let lname = self.layer(last_li).name;
                    let pfx = block_parts(&lname).unwrap().0.to_string();
                    self.ln(format!("%res_{pfx} = {} add({cur}, {short})", sh(&dims)));
                    // the post-shortcut ReLU masks against the residual sum
                    let zeros = {
                        let r = self.recs[last_li].as_mut().unwrap();
                        r.pre = format!("%res_{pfx}");
                        r.zeros.clone()
                    };
                    act = self.relu(&lname, &format!("%res_{pfx}"), &zeros, dims);
                    feed = Some(format!("{}_relu", lname));
                }
            }
            if item.pool_after {
                let nm = self.layer(item_last_layer(item)).name;
                let (p, pd) = self.pool_fwd(ii, &nm, &act, dims);
                act = p;
                dims = pd;
                // the pooled activation keeps (at least) the ReLU's zeros;
                // its sparsity series remains the best live predictor
            }
        }
        (act, dims)
    }

    /// Backward over the whole item list, starting from the gradient
    /// w.r.t. the final activation.
    fn backward(&mut self, mut d: String) {
        let items = self.items.clone();
        for (ii, item) in items.iter().enumerate().rev() {
            if item.pool_after {
                let rec = self.pools[ii].clone().expect("pool emitted");
                d = self.pool_bwd(&rec, &d);
            }
            match &item.kind {
                ItemKind::Single(li) => {
                    match self.conv_bwd(*li, &d, false) {
                        Some(next) => d = next,
                        None => break, // the stem consumed the gradient
                    }
                }
                ItemKind::Block { convs, down } => {
                    let last_li = *convs.last().unwrap();
                    let lname = self.layer(last_li).name;
                    let pfx = block_parts(&lname).unwrap().0.to_string();
                    let last_rec = self.recs[last_li].clone().expect("forward emitted");
                    // shared post-shortcut mask feeds both branches
                    let dres = self.relu_bwd(&lname, &last_rec, &d, &format!("dres_{pfx}"));
                    let mut g = self
                        .conv_bwd(last_li, &dres, true)
                        .expect("block convs are never first");
                    for &li in convs[..convs.len() - 1].iter().rev() {
                        g = self.conv_bwd(li, &g, false).expect("not first");
                    }
                    let dshort = match down {
                        Some(dli) => self
                            .conv_bwd(*dli, &dres, true)
                            .expect("projection convs are never first"),
                        None => dres,
                    };
                    let in_li = convs[0];
                    let ic = self.layer(in_li).cfg;
                    self.ln(format!(
                        "%din_{pfx} = {} add({g}, {dshort})",
                        sh(&[ic.n, ic.c, ic.h, ic.w])
                    ));
                    d = format!("%din_{pfx}");
                }
            }
        }
    }
}

/// The train-step module for a zoo model: forward with per-ReLU sparsity
/// measurement, softmax cross-entropy, full hand-lowered backward
/// (residual fan-ins, BN backward, upsampled strided conv gradients), SGD,
/// and per-layer dz-sparsity outputs. Returns the text and its
/// [`NetTrainPlan`] manifest.
pub fn net_train_step_hlo(m: &NetModel) -> Result<(String, NetTrainPlan), String> {
    let items = topology(&m.spec)?;
    validate_emission(&m.spec, &items)?;
    let mut e = NetEmitter::new(m, items, true);
    let n = m.input_dims()[0];
    let cl = m.classes;
    let snl = sh(&[n, cl]);
    let pnl = shp(&[n, cl]);

    e.out.push_str(&net_fallback_marker(m));
    let _ = writeln!(e.out, "\nHloModule train_step_{}\n", m.key());
    e.out.push_str(SCALAR_COMPS);
    let _ = writeln!(e.out, "\nENTRY %train_step_{} {{", m.key());
    let params = e.prelude();
    let (act, fdims) = e.forward();

    // head: global average pool → FC → stable log-softmax cross-entropy
    let kf = fdims[1];
    let snk = sh(&[n, kf]);
    e.ln(format!(
        "%gap_sum = {snk} reduce({act}, %zero), dimensions={{2,3}}, to_apply=%add_f32"
    ));
    e.ln(format!(
        "%inv_hw = f32[] constant({})",
        f32_text(1.0 / (fdims[2] * fdims[3]) as f32)
    ));
    e.ln(format!("%inv_hw_b = {snk} broadcast(%inv_hw), dimensions={{}}"));
    e.ln(format!("%pooled = {snk} multiply(%gap_sum, %inv_hw_b)"));
    e.ln(format!(
        "%logits0 = {snl} dot(%pooled, %wfc), lhs_contracting_dims={{1}}, \
         rhs_contracting_dims={{1}}"
    ));
    e.ln(format!("%bfc_b = {snl} broadcast(%bfc), dimensions={{1}}"));
    e.ln(format!("%logits = {snl} add(%logits0, %bfc_b)"));
    e.ln(format!(
        "%row_max = {} reduce(%logits, %neg_inf), dimensions={{1}}, to_apply=%max_f32",
        sh(&[n])
    ));
    e.ln(format!("%row_max_b = {snl} broadcast(%row_max), dimensions={{0}}"));
    e.ln(format!("%centered = {snl} subtract(%logits, %row_max_b)"));
    e.ln(format!("%exp_c = {snl} exponential(%centered)"));
    e.ln(format!(
        "%sum_exp = {} reduce(%exp_c, %zero), dimensions={{1}}, to_apply=%add_f32",
        sh(&[n])
    ));
    e.ln(format!("%log_sum = {} log(%sum_exp)", sh(&[n])));
    e.ln(format!("%log_sum_b = {snl} broadcast(%log_sum), dimensions={{0}}"));
    e.ln(format!("%logp = {snl} subtract(%centered, %log_sum_b)"));
    e.ln(format!("%sum_exp_b = {snl} broadcast(%sum_exp), dimensions={{0}}"));
    e.ln(format!("%probs = {snl} divide(%exp_c, %sum_exp_b)"));
    e.ln(format!("%iota_cl = s32[{n},{cl}] iota(), iota_dimension=1"));
    e.ln(format!("%labels_b = s32[{n},{cl}] broadcast(%labels), dimensions={{0}}"));
    e.ln(format!("%onehot_p = {pnl} compare(%labels_b, %iota_cl), direction=EQ"));
    e.ln(format!("%onehot = {snl} convert(%onehot_p)"));
    e.ln(format!("%picked = {snl} multiply(%onehot, %logp)"));
    e.ln(
        "%picked_sum = f32[] reduce(%picked, %zero), dimensions={0,1}, to_apply=%add_f32"
            .to_string(),
    );
    e.ln(format!("%neg_inv_n = f32[] constant({})", f32_text(-1.0 / n as f32)));
    e.ln("%loss = f32[] multiply(%picked_sum, %neg_inv_n)".to_string());

    // backward head: dlogits = (probs - onehot)/N, FC grads, GAP backward
    e.ln(format!("%pdiff = {snl} subtract(%probs, %onehot)"));
    e.ln(format!("%inv_n = f32[] constant({})", f32_text(1.0 / n as f32)));
    e.ln(format!("%inv_n_b = {snl} broadcast(%inv_n), dimensions={{}}"));
    e.ln(format!("%dlogits = {snl} multiply(%pdiff, %inv_n_b)"));
    e.ln(format!(
        "%gw_bfc = {} reduce(%dlogits, %zero), dimensions={{0}}, to_apply=%add_f32",
        sh(&[cl])
    ));
    e.ln(format!(
        "%gw_wfc = {} dot(%dlogits, %pooled), lhs_contracting_dims={{0}}, \
         rhs_contracting_dims={{0}}",
        sh(&[cl, kf])
    ));
    e.ln(format!(
        "%d_pooled = {snk} dot(%dlogits, %wfc), lhs_contracting_dims={{1}}, \
         rhs_contracting_dims={{0}}"
    ));
    e.ln(format!("%d_gap = {snk} multiply(%d_pooled, %inv_hw_b)"));
    e.ln(format!("%d_final = {} broadcast(%d_gap), dimensions={{0,1}}", sh(&fdims)));
    e.backward("%d_final".to_string());

    // SGD: p' = p - lr * g  (conv grads are %gw_w_<layer> via transpose
    // naming below; FC grads are %gw_wfc / %gw_bfc)
    e.ln(format!("%lr = f32[] constant({})", f32_text(m.lr)));
    for (pname, dims) in &params {
        let s = sh(dims);
        let gname = match pname.strip_prefix("w_") {
            Some(layer) => format!("%gw_{layer}"),
            None => format!("%gw_{pname}"),
        };
        e.ln(format!("%lr_{pname} = {s} broadcast(%lr), dimensions={{}}"));
        e.ln(format!("%step_{pname} = {s} multiply(%lr_{pname}, {gname})"));
        e.ln(format!("%new_{pname} = {s} subtract(%{pname}, %step_{pname})"));
    }
    let mut shapes: Vec<String> = params.iter().map(|(_, d)| sh(d)).collect();
    let mut opnds: Vec<String> = params.iter().map(|(p, _)| format!("%new_{p}")).collect();
    shapes.push("f32[]".to_string());
    opnds.push("%loss".to_string());
    for k in &e.relu_keys {
        shapes.push("f32[]".to_string());
        opnds.push(format!("%sp_{}", k.strip_suffix("_relu").unwrap()));
    }
    for k in &e.dz_keys {
        shapes.push("f32[]".to_string());
        opnds.push(format!("%dsp_{}", k.strip_suffix("_dz").unwrap()));
    }
    let _ = writeln!(
        e.out,
        "  ROOT %out = ({}) tuple({})",
        shapes.join(", "),
        opnds.join(", ")
    );
    e.out.push_str("}\n");

    let plan = NetTrainPlan {
        params,
        relu_keys: e.relu_keys,
        dz_keys: e.dz_keys,
        sparsity_feeds: e.feeds,
        strided_fwd: e.strided,
        input_dims: m.input_dims(),
        classes: m.classes,
    };
    Ok((e.out, plan))
}

/// The predict module for a zoo model: forward only, `(logits,)`.
pub fn net_predict_hlo(m: &NetModel) -> Result<String, String> {
    let items = topology(&m.spec)?;
    validate_emission(&m.spec, &items)?;
    let mut e = NetEmitter::new(m, items, false);
    let n = m.input_dims()[0];
    let cl = m.classes;
    let snl = sh(&[n, cl]);
    e.out.push_str(&net_fallback_marker(m));
    let _ = writeln!(e.out, "\nHloModule predict_{}\n", m.key());
    e.out.push_str(SCALAR_COMPS);
    let _ = writeln!(e.out, "\nENTRY %predict_{} {{", m.key());
    e.prelude();
    let (act, fdims) = e.forward();
    let kf = fdims[1];
    let snk = sh(&[n, kf]);
    e.ln(format!(
        "%gap_sum = {snk} reduce({act}, %zero), dimensions={{2,3}}, to_apply=%add_f32"
    ));
    e.ln(format!(
        "%inv_hw = f32[] constant({})",
        f32_text(1.0 / (fdims[2] * fdims[3]) as f32)
    ));
    e.ln(format!("%inv_hw_b = {snk} broadcast(%inv_hw), dimensions={{}}"));
    e.ln(format!("%pooled = {snk} multiply(%gap_sum, %inv_hw_b)"));
    e.ln(format!(
        "%logits0 = {snl} dot(%pooled, %wfc), lhs_contracting_dims={{1}}, \
         rhs_contracting_dims={{1}}"
    ));
    e.ln(format!("%bfc_b = {snl} broadcast(%bfc), dimensions={{1}}"));
    e.ln(format!("%logits = {snl} add(%logits0, %bfc_b)"));
    let _ = writeln!(e.out, "  ROOT %out = ({snl}) tuple(%logits)");
    e.out.push_str("}\n");
    Ok(e.out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every emitted module must parse and pass interpreter shape
    /// inference, at the paper geometry and at reduced ones.
    #[test]
    fn emitted_modules_compile() {
        for g in [Geometry::paper(), Geometry::tiny(), Geometry { n: 2, c_in: 3, hw: 5, c1: 4, c2: 6, classes: 2, lr: 0.1 }] {
            for (name, text) in [
                ("train_step", train_step_hlo(&g)),
                ("predict", predict_hlo(&g)),
                ("kernel_fwd", kernel_fwd_hlo(&g)),
            ] {
                assert!(
                    text.starts_with(&fallback_marker(&g)),
                    "{name} must carry the fallback fingerprint marker"
                );
                let module = xla::hlo::parse_module(&text)
                    .unwrap_or_else(|e| panic!("{name} at {g:?} fails to parse: {e}"));
                xla::eval::validate(&module)
                    .unwrap_or_else(|e| panic!("{name} at {g:?} fails validation: {e}"));
            }
        }
    }

    #[test]
    fn miri_tiny_train_step_compiles() {
        let text = train_step_hlo(&Geometry::tiny());
        let module = xla::hlo::parse_module(&text).unwrap();
        xla::eval::validate(&module).unwrap();
        // 6 params, 7-output tuple root
        let entry = &module.comps[module.entry];
        assert_eq!(entry.params.len(), 6);
        match &entry.instrs[entry.root].shape {
            xla::hlo::ShapeDecl::Tuple(shapes) => assert_eq!(shapes.len(), 7),
            other => panic!("root must be a tuple, got {other:?}"),
        }
    }

    #[test]
    fn miri_conv_probe_modules_compile_for_all_three_forms() {
        // (lhs, rhs, out, window, labels) for FWD / BWI / BWW probes at a
        // tiny geometry; each must parse and shape-check.
        let cases: [(&[usize], &[usize], &[usize], &str, &str); 3] = [
            (
                &[2, 4, 5, 5],
                &[4, 4, 3, 3],
                &[2, 4, 5, 5],
                "{size=3x3 pad=1_1x1_1}",
                "bf01_oi01->bf01",
            ),
            (
                &[2, 4, 5, 5],
                &[4, 4, 3, 3],
                &[2, 4, 5, 5],
                "{size=3x3 pad=1_1x1_1}",
                "bf01_io01->bf01",
            ),
            (
                &[2, 4, 5, 5],
                &[2, 4, 5, 5],
                &[4, 4, 3, 3],
                "{size=5x5 pad=1_1x1_1}",
                "fb01_io01->bf01",
            ),
        ];
        for (lhs, rhs, out, window, labels) in cases {
            let text = conv_module_hlo(lhs, rhs, out, window, labels);
            let module = xla::hlo::parse_module(&text)
                .unwrap_or_else(|e| panic!("{labels} probe fails to parse: {e}"));
            xla::eval::validate(&module)
                .unwrap_or_else(|e| panic!("{labels} probe fails validation: {e}"));
        }
    }

    /// Every zoo network must emit train/predict modules that parse and
    /// pass interpreter shape inference at the reduced scales, with a
    /// manifest that matches the emitted graph.
    #[test]
    fn net_modules_emit_parse_and_validate() {
        for network in Network::ALL {
            for scale in [Scale::Small, Scale::Medium] {
                let m = NetModel::new(network, scale);
                let (text, plan) = net_train_step_hlo(&m)
                    .unwrap_or_else(|e| panic!("{} emission failed: {e}", m.key()));
                assert!(text.starts_with(&net_fallback_marker(&m)), "{}", m.key());
                let module = xla::hlo::parse_module(&text)
                    .unwrap_or_else(|e| panic!("{} fails to parse: {e}", m.key()));
                xla::eval::validate(&module)
                    .unwrap_or_else(|e| panic!("{} fails validation: {e}", m.key()));
                let entry = &module.comps[module.entry];
                match &entry.instrs[entry.root].shape {
                    xla::hlo::ShapeDecl::Tuple(shapes) => assert_eq!(
                        shapes.len(),
                        plan.n_outputs(),
                        "{}: root arity vs manifest",
                        m.key()
                    ),
                    other => panic!("{}: root must be a tuple, got {other:?}", m.key()),
                }
                // one dz series per conv layer; one ReLU series per
                // activation (projection `_down` convs have no ReLU)
                let downs =
                    m.spec.layers.iter().filter(|l| l.name.ends_with("_down")).count();
                assert_eq!(
                    plan.relu_keys.len(),
                    m.spec.layers.len() - downs,
                    "{}",
                    m.key()
                );
                assert_eq!(plan.dz_keys.len(), m.spec.layers.len(), "{}", m.key());
                // every feed targets an emitted conv and an emitted series
                for (instr, series) in &plan.sparsity_feeds {
                    assert!(
                        text.contains(&format!("%{instr} = ")),
                        "{}: feed target %{instr} not emitted",
                        m.key()
                    );
                    assert!(
                        plan.relu_keys.contains(series) || plan.dz_keys.contains(series),
                        "{}: feed series {series} is not a measured key",
                        m.key()
                    );
                }
                // the ResNets hit strided downsample forms; VGG never does
                if network == Network::Vgg16 {
                    assert!(plan.strided_fwd.is_empty());
                } else {
                    assert!(
                        plan.strided_fwd.len() >= 4,
                        "{}: expected strided convs, got {:?}",
                        m.key(),
                        plan.strided_fwd
                    );
                }
                let predict = net_predict_hlo(&m).unwrap();
                let pm = xla::hlo::parse_module(&predict)
                    .unwrap_or_else(|e| panic!("predict {} fails to parse: {e}", m.key()));
                xla::eval::validate(&pm)
                    .unwrap_or_else(|e| panic!("predict {} fails validation: {e}", m.key()));
            }
        }
    }

    /// §2.3: where a conv is followed by BatchNorm, the backward graph must
    /// measure the *BN-backward* gradient (dense — the mean terms fill in
    /// every element), and where there is no BN (Fixup) it must measure the
    /// ReLU-masked gradient, which inherits the BWI sparsity.
    #[test]
    fn bn_position_rule_shapes_the_measured_gradient() {
        let bn = NetModel::new(Network::ResNet34, Scale::Small);
        let (text_bn, _) = net_train_step_hlo(&bn).unwrap();
        for l in &bn.spec.layers {
            assert!(l.has_bn, "resnet34 layers all carry BN");
            let nm = &l.name;
            assert!(
                text_bn.contains(&format!("%dq_{nm} = ")),
                "dz sparsity must be measured for {nm}"
            );
            // the measured tensor is the BN-backward output %dz_<nm>
            assert!(
                text_bn.contains(&format!("compare(%dz_{nm}, ")),
                "{nm}: measured gradient must be the (dense) BN-backward output"
            );
        }

        let fixup = NetModel::new(Network::FixupResNet50, Scale::Small);
        let (text_fx, plan_fx) = net_train_step_hlo(&fixup).unwrap();
        assert!(!text_fx.contains("%bn_"), "Fixup emits no BN at all");
        for l in &fixup.spec.layers {
            assert!(!l.has_bn);
            let nm = &l.name;
            let dq = text_fx
                .lines()
                .find(|ln| ln.trim_start().starts_with(&format!("%dq_{nm} = ")))
                .unwrap_or_else(|| panic!("{nm}: dz sparsity not measured"));
            // the measured tensor is a ReLU-masked gradient: either this
            // layer's private mask (%dm_*) or the block's shared
            // post-shortcut mask (%dres_*)
            assert!(
                dq.contains(&format!("compare(%dm_{nm}, ")) || dq.contains("compare(%dres_"),
                "{nm}: measured gradient must be ReLU-masked, got {dq}"
            );
        }
        // every non-first conv's BWI feed reads its own dz series
        for l in fixup.spec.layers.iter().filter(|l| !l.is_first) {
            assert!(
                plan_fx
                    .sparsity_feeds
                    .iter()
                    .any(|(i, s)| i == &format!("bwi_{}", l.name) && s == &format!("{}_dz", l.name)),
                "{}: BWI must be fed its dz series",
                l.name
            );
        }
    }

    #[test]
    fn f32_text_roundtrips_exactly() {
        for v in [0.2f32, 1.0 / 131072.0, -0.0625, f32::NEG_INFINITY, 1.0 / 36.0] {
            let parsed: f32 = f32_text(v).parse().unwrap();
            assert_eq!(parsed.to_bits(), v.to_bits(), "{v} -> {}", f32_text(v));
        }
    }
}
