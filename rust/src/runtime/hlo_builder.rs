//! Reference HLO-text emitters: the Rust-side artifact fallback.
//!
//! `python/compile/aot.py` is the primary artifact producer (real JAX +
//! Pallas, run via `make artifacts`). This module emits functionally
//! equivalent HLO text for the same three artifacts — `train_step`,
//! `predict`, `kernel_fwd` — straight from the [`Geometry`] constants, so
//! a cold checkout with **no Python and no pre-built artifacts** can still
//! light up the full `Trainer` loop through the vendored mini-HLO
//! interpreter (`xla::eval`).
//!
//! The train-step graph is the hand-lowered forward + backward + SGD of
//! `python/compile/model.py`: two 3×3 pad-1 convolutions with ReLU (and
//! measured ReLU-output sparsity, the paper's dynamic-sparsity signal),
//! global average pool, a fully-connected layer, numerically stable
//! softmax cross-entropy, and one SGD update. The input-gradient
//! convolution is expressed as `reverse` + `dim_labels=bf01_io01->bf01`;
//! the weight-gradient convolutions contract the batch dimension via
//! `dim_labels=fb01_io01->bf01` with the activation spatial extent as the
//! window. The backward graph is finite-difference-verified in
//! `rust/tests/e2e_train.rs`.

use super::artifacts::geometry;
use std::fmt::Write;

/// Training-problem geometry an emitted module is specialized to (AOT —
/// shapes are baked into the text, exactly like the JAX lowering).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Geometry {
    /// Batch size.
    pub n: usize,
    /// Input channels.
    pub c_in: usize,
    /// Input spatial size (H = W).
    pub hw: usize,
    /// conv1 / conv2 output channels.
    pub c1: usize,
    pub c2: usize,
    /// Label classes.
    pub classes: usize,
    /// SGD learning rate baked into the train-step graph.
    pub lr: f32,
}

impl Geometry {
    /// The artifact geometry (`runtime::artifacts::geometry`, kept in sync
    /// with `python/compile/model.py`).
    pub fn paper() -> Geometry {
        Geometry {
            n: geometry::N,
            c_in: geometry::C_IN,
            hw: geometry::HW,
            c1: geometry::C1,
            c2: geometry::C2,
            classes: geometry::CLASSES,
            lr: geometry::LR,
        }
    }

    /// A reduced geometry for fast interpreter tests (finite-difference
    /// gradient checks, parser fuzzing).
    pub fn tiny() -> Geometry {
        Geometry { n: 4, c_in: 4, hw: 6, c1: 4, c2: 4, classes: 3, lr: 0.2 }
    }
}

/// `f32[a,b,...]` shape text.
fn sh(dims: &[usize]) -> String {
    let mut s = String::from("f32[");
    for (i, d) in dims.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{d}");
    }
    s.push(']');
    s
}

/// `pred[a,b,...]` shape text.
fn shp(dims: &[usize]) -> String {
    format!("pred{}", &sh(dims)[3..])
}

/// Shortest-roundtrip f32 text (`{:?}` prints e.g. `0.2`, `7.6293945e-6`,
/// `-inf` — all exactly re-parsed by the interpreter's `str::parse::<f32>`).
fn f32_text(v: f32) -> String {
    format!("{v:?}")
}

/// First-line marker stamped on every emitted fallback artifact (the
/// parser skips `//` comment lines). `ArtifactSet::write_fallback` uses it
/// to tell its own output apart from real lowerings: files carrying the
/// prefix with a *different* fingerprint are stale fallback output and get
/// refreshed; files without it are real artifacts and are never touched.
pub const FALLBACK_PREFIX: &str = "// sparsetrain-offline-fallback";

/// Bump when the emitted graphs change without a geometry change, so
/// existing fallback artifacts regenerate.
pub const FALLBACK_VERSION: u32 = 1;

/// The exact marker line for `g` (version + full geometry fingerprint).
pub fn fallback_marker(g: &Geometry) -> String {
    format!("{FALLBACK_PREFIX} v{FALLBACK_VERSION} {g:?}")
}

const SCALAR_COMPS: &str = "%add_f32 {\n\
\x20 %p0 = f32[] parameter(0)\n\
\x20 %p1 = f32[] parameter(1)\n\
\x20 ROOT %add = f32[] add(%p0, %p1)\n\
}\n\
\n\
%max_f32 {\n\
\x20 %p0 = f32[] parameter(0)\n\
\x20 %p1 = f32[] parameter(1)\n\
\x20 ROOT %max = f32[] maximum(%p0, %p1)\n\
}\n";

/// Emit the shared forward pass: parameters 0-4 (`w1 w2 wfc bfc x`),
/// `%zero`, conv1/ReLU (`%z1`/`%a1`), conv2/ReLU (`%z2`/`%a2`), optional
/// ReLU-sparsity scalars (`%s1`/`%s2`), global average pool (`%pooled`,
/// plus `%inv_hw_b` which the backward pass reuses) and `%logits`.
fn emit_forward(out: &mut String, g: &Geometry, with_sparsity: bool) {
    let Geometry { n, c_in, hw, c1, c2, classes: cl, .. } = *g;
    let s4_1 = sh(&[n, c1, hw, hw]);
    let s4_2 = sh(&[n, c2, hw, hw]);
    let snl = sh(&[n, cl]);
    let a = |out: &mut String, line: String| {
        out.push_str("  ");
        out.push_str(&line);
        out.push('\n');
    };
    a(out, format!("%w1 = {} parameter(0)", sh(&[c1, c_in, 3, 3])));
    a(out, format!("%w2 = {} parameter(1)", sh(&[c2, c1, 3, 3])));
    a(out, format!("%wfc = {} parameter(2)", sh(&[cl, c2])));
    a(out, format!("%bfc = {} parameter(3)", sh(&[cl])));
    a(out, format!("%x = {} parameter(4)", sh(&[n, c_in, hw, hw])));
    a(out, "%zero = f32[] constant(0)".to_string());
    // conv1 + ReLU
    a(
        out,
        format!(
            "%z1 = {s4_1} convolution(%x, %w1), window={{size=3x3 pad=1_1x1_1}}, \
             dim_labels=bf01_oi01->bf01"
        ),
    );
    a(out, format!("%zeros1 = {s4_1} broadcast(%zero), dimensions={{}}"));
    a(out, format!("%a1 = {s4_1} maximum(%z1, %zeros1)"));
    // conv2 + ReLU
    a(
        out,
        format!(
            "%z2 = {s4_2} convolution(%a1, %w2), window={{size=3x3 pad=1_1x1_1}}, \
             dim_labels=bf01_oi01->bf01"
        ),
    );
    a(out, format!("%zeros2 = {s4_2} broadcast(%zero), dimensions={{}}"));
    a(out, format!("%a2 = {s4_2} maximum(%z2, %zeros2)"));
    if with_sparsity {
        // measured ReLU-output sparsity: mean(a == 0)
        a(out, format!("%a1_is0 = {} compare(%a1, %zeros1), direction=EQ", shp(&[n, c1, hw, hw])));
        a(out, format!("%a1_is0f = {s4_1} convert(%a1_is0)"));
        a(
            out,
            "%s1_sum = f32[] reduce(%a1_is0f, %zero), dimensions={0,1,2,3}, to_apply=%add_f32"
                .to_string(),
        );
        a(out, format!("%inv_e1 = f32[] constant({})", f32_text(1.0 / (n * c1 * hw * hw) as f32)));
        a(out, "%s1 = f32[] multiply(%s1_sum, %inv_e1)".to_string());
        a(out, format!("%a2_is0 = {} compare(%a2, %zeros2), direction=EQ", shp(&[n, c2, hw, hw])));
        a(out, format!("%a2_is0f = {s4_2} convert(%a2_is0)"));
        a(
            out,
            "%s2_sum = f32[] reduce(%a2_is0f, %zero), dimensions={0,1,2,3}, to_apply=%add_f32"
                .to_string(),
        );
        a(out, format!("%inv_e2 = f32[] constant({})", f32_text(1.0 / (n * c2 * hw * hw) as f32)));
        a(out, "%s2 = f32[] multiply(%s2_sum, %inv_e2)".to_string());
    }
    // global average pool → FC
    a(
        out,
        format!(
            "%pool_sum = {} reduce(%a2, %zero), dimensions={{2,3}}, to_apply=%add_f32",
            sh(&[n, c2])
        ),
    );
    a(out, format!("%inv_hw = f32[] constant({})", f32_text(1.0 / (hw * hw) as f32)));
    a(out, format!("%inv_hw_b = {} broadcast(%inv_hw), dimensions={{}}", sh(&[n, c2])));
    a(out, format!("%pooled = {} multiply(%pool_sum, %inv_hw_b)", sh(&[n, c2])));
    a(
        out,
        format!(
            "%logits0 = {snl} dot(%pooled, %wfc), lhs_contracting_dims={{1}}, \
             rhs_contracting_dims={{1}}"
        ),
    );
    a(out, format!("%bfc_b = {snl} broadcast(%bfc), dimensions={{1}}"));
    a(out, format!("%logits = {snl} add(%logits0, %bfc_b)"));
}

/// The full train-step module: forward + softmax-cross-entropy loss +
/// hand-lowered backward + SGD. Returns the 7-output tuple contract the
/// trainer expects: `(w1', w2', wfc', bfc', loss, s1, s2)`.
pub fn train_step_hlo(g: &Geometry) -> String {
    let Geometry { n, c_in, hw, c1, c2, classes: cl, lr } = *g;
    let s4_1 = sh(&[n, c1, hw, hw]);
    let s4_2 = sh(&[n, c2, hw, hw]);
    let p4_1 = shp(&[n, c1, hw, hw]);
    let p4_2 = shp(&[n, c2, hw, hw]);
    let snl = sh(&[n, cl]);
    let pnl = shp(&[n, cl]);

    let mut out = String::with_capacity(8192);
    out.push_str(&fallback_marker(g));
    out.push_str("\nHloModule train_step\n\n");
    out.push_str(SCALAR_COMPS);
    out.push_str("\nENTRY %train_step {\n");
    emit_forward(&mut out, g, true);
    let a = |out: &mut String, line: String| {
        out.push_str("  ");
        out.push_str(&line);
        out.push('\n');
    };
    a(&mut out, format!("%labels = s32[{n}] parameter(5)"));
    a(&mut out, "%neg_inf = f32[] constant(-inf)".to_string());
    // numerically stable log-softmax + probabilities
    a(
        &mut out,
        format!(
            "%row_max = {} reduce(%logits, %neg_inf), dimensions={{1}}, to_apply=%max_f32",
            sh(&[n])
        ),
    );
    a(&mut out, format!("%row_max_b = {snl} broadcast(%row_max), dimensions={{0}}"));
    a(&mut out, format!("%centered = {snl} subtract(%logits, %row_max_b)"));
    a(&mut out, format!("%exp_c = {snl} exponential(%centered)"));
    a(
        &mut out,
        format!(
            "%sum_exp = {} reduce(%exp_c, %zero), dimensions={{1}}, to_apply=%add_f32",
            sh(&[n])
        ),
    );
    a(&mut out, format!("%log_sum = {} log(%sum_exp)", sh(&[n])));
    a(&mut out, format!("%log_sum_b = {snl} broadcast(%log_sum), dimensions={{0}}"));
    a(&mut out, format!("%logp = {snl} subtract(%centered, %log_sum_b)"));
    a(&mut out, format!("%sum_exp_b = {snl} broadcast(%sum_exp), dimensions={{0}}"));
    a(&mut out, format!("%probs = {snl} divide(%exp_c, %sum_exp_b)"));
    // one-hot labels via iota + compare
    a(&mut out, format!("%iota_cl = s32[{n},{cl}] iota(), iota_dimension=1"));
    a(&mut out, format!("%labels_b = s32[{n},{cl}] broadcast(%labels), dimensions={{0}}"));
    a(&mut out, format!("%onehot_p = {pnl} compare(%labels_b, %iota_cl), direction=EQ"));
    a(&mut out, format!("%onehot = {snl} convert(%onehot_p)"));
    // loss = -(1/N) * Σ onehot ⊙ logp
    a(&mut out, format!("%picked = {snl} multiply(%onehot, %logp)"));
    a(
        &mut out,
        "%picked_sum = f32[] reduce(%picked, %zero), dimensions={0,1}, to_apply=%add_f32"
            .to_string(),
    );
    a(&mut out, format!("%neg_inv_n = f32[] constant({})", f32_text(-1.0 / n as f32)));
    a(&mut out, "%loss = f32[] multiply(%picked_sum, %neg_inv_n)".to_string());
    // backward: softmax-cross-entropy → dlogits = (probs - onehot)/N
    a(&mut out, format!("%pdiff = {snl} subtract(%probs, %onehot)"));
    a(&mut out, format!("%inv_n = f32[] constant({})", f32_text(1.0 / n as f32)));
    a(&mut out, format!("%inv_n_b = {snl} broadcast(%inv_n), dimensions={{}}"));
    a(&mut out, format!("%dlogits = {snl} multiply(%pdiff, %inv_n_b)"));
    // FC gradients
    a(
        &mut out,
        format!(
            "%g_bfc = {} reduce(%dlogits, %zero), dimensions={{0}}, to_apply=%add_f32",
            sh(&[cl])
        ),
    );
    a(
        &mut out,
        format!(
            "%g_wfc = {} dot(%dlogits, %pooled), lhs_contracting_dims={{0}}, \
             rhs_contracting_dims={{0}}",
            sh(&[cl, c2])
        ),
    );
    a(
        &mut out,
        format!(
            "%d_pooled = {} dot(%dlogits, %wfc), lhs_contracting_dims={{1}}, \
             rhs_contracting_dims={{0}}",
            sh(&[n, c2])
        ),
    );
    // backward through the mean pool
    a(&mut out, format!("%d_pool_scaled = {} multiply(%d_pooled, %inv_hw_b)", sh(&[n, c2])));
    a(&mut out, format!("%d_a2 = {s4_2} broadcast(%d_pool_scaled), dimensions={{0,1}}"));
    // ReLU2 mask
    a(&mut out, format!("%m2 = {p4_2} compare(%z2, %zeros2), direction=GT"));
    a(&mut out, format!("%d_z2 = {s4_2} select(%m2, %d_a2, %zeros2)"));
    // conv2 gradients: weight grad contracts batch (fb01_io01->bf01),
    // input grad is reverse(w) with io01 labels
    a(
        &mut out,
        format!(
            "%g_w2_t = {} convolution(%a1, %d_z2), window={{size={hw}x{hw} pad=1_1x1_1}}, \
             dim_labels=fb01_io01->bf01",
            sh(&[c1, c2, 3, 3])
        ),
    );
    a(&mut out, format!("%g_w2 = {} transpose(%g_w2_t), dimensions={{1,0,2,3}}", sh(&[c2, c1, 3, 3])));
    a(&mut out, format!("%w2_r = {} reverse(%w2), dimensions={{2,3}}", sh(&[c2, c1, 3, 3])));
    a(
        &mut out,
        format!(
            "%d_a1 = {s4_1} convolution(%d_z2, %w2_r), window={{size=3x3 pad=1_1x1_1}}, \
             dim_labels=bf01_io01->bf01"
        ),
    );
    // ReLU1 mask + conv1 weight gradient
    a(&mut out, format!("%m1 = {p4_1} compare(%z1, %zeros1), direction=GT"));
    a(&mut out, format!("%d_z1 = {s4_1} select(%m1, %d_a1, %zeros1)"));
    a(
        &mut out,
        format!(
            "%g_w1_t = {} convolution(%x, %d_z1), window={{size={hw}x{hw} pad=1_1x1_1}}, \
             dim_labels=fb01_io01->bf01",
            sh(&[c_in, c1, 3, 3])
        ),
    );
    a(&mut out, format!("%g_w1 = {} transpose(%g_w1_t), dimensions={{1,0,2,3}}", sh(&[c1, c_in, 3, 3])));
    // SGD: p' = p - lr * g
    a(&mut out, format!("%lr = f32[] constant({})", f32_text(lr)));
    for (nm, dims) in [
        ("w1", vec![c1, c_in, 3, 3]),
        ("w2", vec![c2, c1, 3, 3]),
        ("wfc", vec![cl, c2]),
        ("bfc", vec![cl]),
    ] {
        let s = sh(&dims);
        a(&mut out, format!("%lr_{nm} = {s} broadcast(%lr), dimensions={{}}"));
        a(&mut out, format!("%step_{nm} = {s} multiply(%lr_{nm}, %g_{nm})"));
        a(&mut out, format!("%new_{nm} = {s} subtract(%{nm}, %step_{nm})"));
    }
    a(
        &mut out,
        format!(
            "ROOT %out = ({}, {}, {}, {}, f32[], f32[], f32[]) \
             tuple(%new_w1, %new_w2, %new_wfc, %new_bfc, %loss, %s1, %s2)",
            sh(&[c1, c_in, 3, 3]),
            sh(&[c2, c1, 3, 3]),
            sh(&[cl, c2]),
            sh(&[cl]),
        ),
    );
    out.push_str("}\n");
    out
}

/// The predict module: forward only, `(logits,)`.
pub fn predict_hlo(g: &Geometry) -> String {
    let mut out = String::with_capacity(2048);
    out.push_str(&fallback_marker(g));
    out.push_str("\nHloModule predict\n\n");
    out.push_str(SCALAR_COMPS);
    out.push_str("\nENTRY %predict {\n");
    emit_forward(&mut out, g, false);
    let _ = writeln!(out, "  ROOT %out = ({}) tuple(%logits)", sh(&[g.n, g.classes]));
    out.push_str("}\n");
    out
}

/// The single-convolution kernel module: `(conv2d(x, w, pad 1),)` — the L1
/// kernel exposed for Rust-side validation (bit-compared against
/// `kernels::reference::conv_fwd` in the e2e tests).
pub fn kernel_fwd_hlo(g: &Geometry) -> String {
    let Geometry { n, c_in, hw, c1, .. } = *g;
    let mut out = String::with_capacity(512);
    out.push_str(&fallback_marker(g));
    out.push_str("\nHloModule kernel_fwd\n\nENTRY %kernel_fwd {\n");
    let _ = writeln!(out, "  %x = {} parameter(0)", sh(&[n, c_in, hw, hw]));
    let _ = writeln!(out, "  %w = {} parameter(1)", sh(&[c1, c_in, 3, 3]));
    let _ = writeln!(
        out,
        "  %y = {} convolution(%x, %w), window={{size=3x3 pad=1_1x1_1}}, \
         dim_labels=bf01_oi01->bf01",
        sh(&[n, c1, hw, hw])
    );
    let _ = writeln!(out, "  ROOT %out = ({}) tuple(%y)", sh(&[n, c1, hw, hw]));
    out.push_str("}\n");
    out
}

/// A single-convolution probe module (no artifact marker — this is test
/// plumbing, not a fallback artifact): `ROOT = convolution(lhs, rhs)` with
/// the given shapes and raw `window=`/`dim_labels=` attribute text. Used
/// by the conv-routing parity suite to drive the interpreter — naive and
/// kernel-routed — over arbitrary geometries and label permutations.
pub fn conv_module_hlo(
    lhs: &[usize],
    rhs: &[usize],
    out: &[usize],
    window: &str,
    dim_labels: &str,
) -> String {
    let mut text = String::with_capacity(256);
    text.push_str("HloModule conv_probe\n\nENTRY %conv_probe {\n");
    let _ = writeln!(text, "  %lhs = {} parameter(0)", sh(lhs));
    let _ = writeln!(text, "  %rhs = {} parameter(1)", sh(rhs));
    let _ = writeln!(
        text,
        "  ROOT %out = {} convolution(%lhs, %rhs), window={window}, dim_labels={dim_labels}",
        sh(out)
    );
    text.push_str("}\n");
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every emitted module must parse and pass interpreter shape
    /// inference, at the paper geometry and at reduced ones.
    #[test]
    fn emitted_modules_compile() {
        for g in [Geometry::paper(), Geometry::tiny(), Geometry { n: 2, c_in: 3, hw: 5, c1: 4, c2: 6, classes: 2, lr: 0.1 }] {
            for (name, text) in [
                ("train_step", train_step_hlo(&g)),
                ("predict", predict_hlo(&g)),
                ("kernel_fwd", kernel_fwd_hlo(&g)),
            ] {
                assert!(
                    text.starts_with(&fallback_marker(&g)),
                    "{name} must carry the fallback fingerprint marker"
                );
                let module = xla::hlo::parse_module(&text)
                    .unwrap_or_else(|e| panic!("{name} at {g:?} fails to parse: {e}"));
                xla::eval::validate(&module)
                    .unwrap_or_else(|e| panic!("{name} at {g:?} fails validation: {e}"));
            }
        }
    }

    #[test]
    fn miri_tiny_train_step_compiles() {
        let text = train_step_hlo(&Geometry::tiny());
        let module = xla::hlo::parse_module(&text).unwrap();
        xla::eval::validate(&module).unwrap();
        // 6 params, 7-output tuple root
        let entry = &module.comps[module.entry];
        assert_eq!(entry.params.len(), 6);
        match &entry.instrs[entry.root].shape {
            xla::hlo::ShapeDecl::Tuple(shapes) => assert_eq!(shapes.len(), 7),
            other => panic!("root must be a tuple, got {other:?}"),
        }
    }

    #[test]
    fn miri_conv_probe_modules_compile_for_all_three_forms() {
        // (lhs, rhs, out, window, labels) for FWD / BWI / BWW probes at a
        // tiny geometry; each must parse and shape-check.
        let cases: [(&[usize], &[usize], &[usize], &str, &str); 3] = [
            (
                &[2, 4, 5, 5],
                &[4, 4, 3, 3],
                &[2, 4, 5, 5],
                "{size=3x3 pad=1_1x1_1}",
                "bf01_oi01->bf01",
            ),
            (
                &[2, 4, 5, 5],
                &[4, 4, 3, 3],
                &[2, 4, 5, 5],
                "{size=3x3 pad=1_1x1_1}",
                "bf01_io01->bf01",
            ),
            (
                &[2, 4, 5, 5],
                &[2, 4, 5, 5],
                &[4, 4, 3, 3],
                "{size=5x5 pad=1_1x1_1}",
                "fb01_io01->bf01",
            ),
        ];
        for (lhs, rhs, out, window, labels) in cases {
            let text = conv_module_hlo(lhs, rhs, out, window, labels);
            let module = xla::hlo::parse_module(&text)
                .unwrap_or_else(|e| panic!("{labels} probe fails to parse: {e}"));
            xla::eval::validate(&module)
                .unwrap_or_else(|e| panic!("{labels} probe fails validation: {e}"));
        }
    }

    #[test]
    fn f32_text_roundtrips_exactly() {
        for v in [0.2f32, 1.0 / 131072.0, -0.0625, f32::NEG_INFINITY, 1.0 / 36.0] {
            let parsed: f32 = f32_text(v).parse().unwrap();
            assert_eq!(parsed.to_bits(), v.to_bits(), "{v} -> {}", f32_text(v));
        }
    }
}
