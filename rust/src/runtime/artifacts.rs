//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/*.hlo.txt` once, at build time) and the Rust runtime.

use std::path::{Path, PathBuf};

/// The artifacts the AOT pipeline produces and the trainer consumes.
/// Shapes are fixed at lowering time (AOT — no dynamic shapes).
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
}

/// Names of all expected artifacts.
pub const TRAIN_STEP: &str = "train_step";
pub const PREDICT: &str = "predict";
pub const KERNEL_FWD: &str = "kernel_fwd";

/// Training-problem geometry baked into the artifacts (must match
/// `python/compile/model.py`).
pub mod geometry {
    /// Batch size.
    pub const N: usize = 16;
    /// Input channels (multiple of V=16, matching the tiled layout story).
    pub const C_IN: usize = 16;
    /// Input spatial size.
    pub const HW: usize = 16;
    /// Conv channels.
    pub const C1: usize = 32;
    pub const C2: usize = 32;
    /// Classes.
    pub const CLASSES: usize = 8;
    /// SGD learning rate baked into the train-step graph.
    pub const LR: f32 = 0.2;
}

impl ArtifactSet {
    pub fn new<P: AsRef<Path>>(dir: P) -> ArtifactSet {
        ArtifactSet { dir: dir.as_ref().to_path_buf() }
    }

    /// Default location: `$SPARSETRAIN_ARTIFACTS` or `./artifacts`.
    pub fn default_location() -> ArtifactSet {
        let dir =
            std::env::var("SPARSETRAIN_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        ArtifactSet::new(dir)
    }

    pub fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.path_of(name).is_file()
    }

    /// All artifacts present? (Used to gate runtime tests/examples so
    /// `cargo test` works before `make artifacts`.)
    pub fn complete(&self) -> bool {
        [TRAIN_STEP, PREDICT, KERNEL_FWD].iter().all(|n| self.has(n))
    }

    /// Missing artifact names.
    pub fn missing(&self) -> Vec<&'static str> {
        [TRAIN_STEP, PREDICT, KERNEL_FWD].into_iter().filter(|n| !self.has(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_and_missing() {
        let a = ArtifactSet::new("/nonexistent-dir");
        assert_eq!(a.path_of("x"), PathBuf::from("/nonexistent-dir/x.hlo.txt"));
        assert!(!a.complete());
        assert_eq!(a.missing().len(), 3);
    }

    #[test]
    fn geometry_is_consistent() {
        use geometry::*;
        assert_eq!(N % crate::V, 0, "batch must tile by V for BWW");
        assert_eq!(C_IN % crate::V, 0);
        assert!(CLASSES > 1);
    }
}
