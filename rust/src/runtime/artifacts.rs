//! Artifact manifest: the contract between the artifact producers and the
//! Rust runtime.
//!
//! Two producers can satisfy the manifest:
//!
//! 1. `make artifacts` → `python/compile/aot.py` (real JAX + Pallas)
//!    writes `artifacts/*.hlo.txt` once at build time — the primary path
//!    when a Python toolchain is available. Point `SPARSETRAIN_ARTIFACTS`
//!    at the output directory to override the default `./artifacts`.
//! 2. [`ArtifactSet::write_fallback`] emits the Rust-side reference HLO
//!    (`runtime::hlo_builder`, derived from the same [`geometry`]
//!    constants as `python/compile/model.py`) for any *missing* artifact,
//!    so a cold checkout with no Python still trains end to end through
//!    the vendored mini-HLO interpreter. Files without the fallback
//!    marker (real lowerings) are never overwritten and always take
//!    precedence; the fallback's own output carries a version + geometry
//!    fingerprint (`hlo_builder::fallback_marker`) and is refreshed
//!    automatically when the emitter or the geometry changes.
//!
//! [`ArtifactSet::bootstrap_offline`] composes the two: use what's there,
//! fill the gaps with the fallback.
//!
//! Caveat: the offline interpreter consumes the reference HLO grammar and
//! op subset (`vendor/xla`'s `hlo` module). Raw `as_hlo_text()` dumps from
//! an arbitrary XLA build may use ops/syntax outside that subset and then
//! fail loudly at `Runtime::load` — executing those requires linking the
//! real `xla` crate (see ROADMAP), or deleting the files to fall back to
//! the reference emitter.

use std::io;
use std::path::{Path, PathBuf};

/// The artifacts the AOT pipeline produces and the trainer consumes.
/// Shapes are fixed at lowering time (AOT — no dynamic shapes).
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
}

/// Names of all expected artifacts.
pub const TRAIN_STEP: &str = "train_step";
pub const PREDICT: &str = "predict";
pub const KERNEL_FWD: &str = "kernel_fwd";

/// Training-problem geometry baked into the artifacts (must match
/// `python/compile/model.py`).
pub mod geometry {
    /// Batch size.
    pub const N: usize = 16;
    /// Input channels (multiple of V=16, matching the tiled layout story).
    pub const C_IN: usize = 16;
    /// Input spatial size.
    pub const HW: usize = 16;
    /// Conv channels.
    pub const C1: usize = 32;
    pub const C2: usize = 32;
    /// Classes.
    pub const CLASSES: usize = 8;
    /// SGD learning rate baked into the train-step graph.
    pub const LR: f32 = 0.2;
}

impl ArtifactSet {
    pub fn new<P: AsRef<Path>>(dir: P) -> ArtifactSet {
        ArtifactSet { dir: dir.as_ref().to_path_buf() }
    }

    /// Default location: `$SPARSETRAIN_ARTIFACTS` or `./artifacts`.
    pub fn default_location() -> ArtifactSet {
        let dir =
            std::env::var("SPARSETRAIN_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        ArtifactSet::new(dir)
    }

    pub fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.path_of(name).is_file()
    }

    /// All artifacts present? (`Trainer::new` requires this; callers that
    /// want a cold checkout to work use [`ArtifactSet::bootstrap_offline`].)
    pub fn complete(&self) -> bool {
        [TRAIN_STEP, PREDICT, KERNEL_FWD].iter().all(|n| self.has(n))
    }

    /// Missing artifact names.
    pub fn missing(&self) -> Vec<&'static str> {
        [TRAIN_STEP, PREDICT, KERNEL_FWD].into_iter().filter(|n| !self.has(n)).collect()
    }

    /// Write the Rust-emitted reference HLO for every artifact that is
    /// missing **or** is a *stale* fallback (first line carries
    /// `hlo_builder::FALLBACK_PREFIX` but an outdated version/geometry
    /// fingerprint — e.g. after a geometry change, so old fallback files
    /// can't silently pin an old graph). Files without the marker are real
    /// lowerings (`make artifacts`) and are never clobbered, even under
    /// races: new files are published with `hard_link`, which is atomic
    /// and fails (rather than replaces) when the target already exists.
    pub fn write_fallback(&self) -> io::Result<()> {
        use super::hlo_builder;
        let g = hlo_builder::Geometry::paper();
        for (name, text) in [
            (TRAIN_STEP, hlo_builder::train_step_hlo(&g)),
            (PREDICT, hlo_builder::predict_hlo(&g)),
            (KERNEL_FWD, hlo_builder::kernel_fwd_hlo(&g)),
        ] {
            self.publish_fallback_text(name, &text)?;
        }
        Ok(())
    }

    /// Publish one piece of emitted fallback HLO under `name`, using the
    /// text's first line as its marker (every `hlo_builder` fallback
    /// emitter stamps one). Skips real artifacts and current fallback
    /// output; refreshes stale fallback output; races resolve in favour of
    /// whoever publishes a real file first (atomic `hard_link`, no
    /// clobber). Also the publishing path for the per-net emitters
    /// (`train_step_<net>_<scale>` / `predict_<net>_<scale>`).
    pub fn publish_fallback_text(&self, name: &str, text: &str) -> io::Result<()> {
        use super::hlo_builder;
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SEQ: AtomicUsize = AtomicUsize::new(0);

        std::fs::create_dir_all(&self.dir)?;
        let marker = text.lines().next().unwrap_or("");
        debug_assert!(marker.starts_with(hlo_builder::FALLBACK_PREFIX));
        let path = self.path_of(name);
        let stale = match std::fs::read_to_string(&path) {
            Ok(existing) => {
                let first = existing.lines().next().unwrap_or("");
                if !first.starts_with(hlo_builder::FALLBACK_PREFIX) || first == marker {
                    return Ok(()); // a real artifact, or our current output
                }
                true
            }
            Err(_) => false,
        };
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = self.dir.join(format!(".{name}.{}.{seq}.tmp", std::process::id()));
        std::fs::write(&tmp, text)?;
        if stale {
            // Our own outdated output: unlink it, then publish through
            // the same no-clobber hard_link below — if a real lowering
            // lands in the window, AlreadyExists lets it win.
            let _ = std::fs::remove_file(&path);
        }
        let publish = std::fs::hard_link(&tmp, &path);
        let cleanup = std::fs::remove_file(&tmp);
        match publish {
            Ok(()) => {}
            // someone else (another test binary, `make artifacts`)
            // provided the artifact first — theirs wins
            Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {}
            Err(e) => return Err(e),
        }
        cleanup?;
        Ok(())
    }

    /// A scratch artifact set under the system temp dir, wiped on creation
    /// (so pid reuse cannot resurrect files from an older checkout) and
    /// populated with the offline fallback. Test-binary plumbing: keeps
    /// gating tests independent of whatever `./artifacts` holds.
    pub fn scratch_fallback(tag: &str) -> io::Result<ArtifactSet> {
        let dir = std::env::temp_dir()
            .join(format!("sparsetrain-scratch-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let set = ArtifactSet::new(dir);
        set.write_fallback()?;
        Ok(set)
    }

    /// The default location, materializing the offline fallback for any
    /// missing artifact — the cold-checkout entry point used by tests and
    /// examples so the trainer runs with no Python and no pre-built
    /// artifacts.
    pub fn bootstrap_offline() -> io::Result<ArtifactSet> {
        let set = Self::default_location();
        // Unconditional: write_fallback no-ops on real or current files and
        // refreshes stale fallback output, so the fingerprint-based
        // auto-refresh actually runs even when the manifest looks complete.
        set.write_fallback()?;
        Ok(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_and_missing() {
        let a = ArtifactSet::new("/nonexistent-dir");
        assert_eq!(a.path_of("x"), PathBuf::from("/nonexistent-dir/x.hlo.txt"));
        assert!(!a.complete());
        assert_eq!(a.missing().len(), 3);
    }

    #[test]
    fn geometry_is_consistent() {
        use geometry::*;
        assert_eq!(N % crate::V, 0, "batch must tile by V for BWW");
        assert_eq!(C_IN % crate::V, 0);
        assert!(CLASSES > 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // touches the real filesystem
    fn fallback_completes_a_cold_directory_and_never_overwrites() {
        let dir = std::env::temp_dir()
            .join(format!("sparsetrain-artifacts-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let set = ArtifactSet::new(&dir);
        assert!(!set.complete());
        set.write_fallback().unwrap();
        assert!(set.complete(), "fallback must satisfy the manifest");

        // a pre-existing (e.g. real JAX) artifact must be preserved
        std::fs::write(set.path_of(PREDICT), "HloModule sentinel\n").unwrap();
        set.write_fallback().unwrap();
        let kept = std::fs::read_to_string(set.path_of(PREDICT)).unwrap();
        assert!(kept.contains("sentinel"), "write_fallback overwrote a real artifact");

        // ...but our own *stale* fallback output (marker with an outdated
        // fingerprint) must be refreshed, not pinned forever
        let stale = format!("{} v0 Geometry {{ old }}\nHloModule old\n",
            crate::runtime::hlo_builder::FALLBACK_PREFIX);
        std::fs::write(set.path_of(TRAIN_STEP), stale).unwrap();
        set.write_fallback().unwrap();
        let refreshed = std::fs::read_to_string(set.path_of(TRAIN_STEP)).unwrap();
        assert!(
            !refreshed.contains("HloModule old"),
            "stale fallback output was not regenerated"
        );
        assert!(refreshed.contains("HloModule train_step"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
