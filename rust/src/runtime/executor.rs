//! Whole-graph op router: the bridge between the mini-HLO interpreter and
//! the SparseTrain kernel/scheduler stack (ISSUE 5 convs, ISSUE 6
//! everything else).
//!
//! [`OpRouter`] is installed as the vendored crate's [`xla::OpExecutor`]
//! hook, so the evaluator consults it for **every** f32 instruction. Per
//! op kind it serves:
//!
//! | op | route | numerics vs naive |
//! |---|---|---|
//! | `convolution` (the three train forms below) | sparse kernels on the scheduler pool | allclose (FMA + sweep order) |
//! | `dot` (rank-2 × rank-2, any contracting dims) | [`crate::kernels::gemm`] — blocked, SIMD-dispatched, panel-parallel | allclose (FMA) |
//! | `broadcast` (scalar / rank-1 into rank-2 / rank-2 into rank-4) | fill / `copy_from_slice` passes, no per-element index decompose | **bit-identical** |
//! | binary with a broadcast operand (bias add, ReLU `max(x, 0)`, scale, log-softmax subtract/divide) | single fused pass reading the scalar/vector directly | **bit-identical** |
//! | SGD `subtract(w, multiply(splat(lr), g))` | single fused pass, mul-then-sub roundings preserved | **bit-identical** |
//! | `select(compare(z, splat, GT), t, splat)` (ReLU backward) | single fused pass | **bit-identical** |
//! | `reduce` with a `bin(p0, p1)` body (sums, max) | row-major fold without index decompose | **bit-identical** |
//! | unary (`exponential`, `log`, `negate`) | parallel elementwise pass over the pool ([`xla::eval::un_f32`] per element) | **bit-identical** |
//! | `convert` to f32 (f32 copy, s32/pred cast, fused `convert(iota)` index fill) | parallel elementwise pass | **bit-identical** |
//!
//! The three convolution forms (unchanged from ISSUE 5):
//!
//! | `dim_labels` | training role | kernel entry |
//! |---|---|---|
//! | `bf01_oi01->bf01` | forward conv | `run_fwd` |
//! | `bf01_io01->bf01` (reversed filter) | input gradient (BWI) | `run_bwi` |
//! | `fb01_io01->bf01` (batch-contracting) | weight gradient (BWW) | `run_bww` |
//!
//! The thread-count-aware [`Selector`] picks the [`SkipMode`] per conv
//! call from the measured sparsity of the checked operand, so the trainer
//! exploits exactly the dynamic sparsity the paper's Table 2 measures.
//!
//! **Fallback contract.** Any instruction outside the envelope above —
//! non-f32 dots, rank-1 dots, elementwise chains the fusion matcher does
//! not recognize, convolutions with labels/tiling/padding outside the
//! three forms — is declined (`route_op` returns `false`) and the
//! interpreter's naive evaluator runs instead, **bit-identically**: the
//! router either fills the whole output buffer or touches nothing. Pinned
//! by `rust/tests/op_route_parity.rs` and `conv_route_parity.rs`. Routed
//! convs and dots carry kernel numerics (single-rounding FMAs,
//! deterministic across thread counts); every other routed path reproduces
//! the naive arithmetic bit for bit, as tabulated above.
//!
//! **Kill switches.** `SPARSETRAIN_CONV_ROUTE=off` disables conv routing,
//! `SPARSETRAIN_OP_ROUTE=off` disables everything else (both read at
//! router construction); [`OpRouter::stats`] exposes per-kind
//! routed/fallback/fused counters so silent fallback regressions show up
//! in the `train` CLI output.
//!
//! **Measured-cost autotuning (ISSUE 8).** When a
//! [`crate::coordinator::CostDb`] is attached (the default —
//! `SPARSETRAIN_COST_DB=off` detaches it), every routed conv and GEMM is
//! wrapped in monotonic-clock stamps and its wall time recorded under the
//! (component, geometry, sparsity bucket, threads, backend, mode) key;
//! the selector's `skip_mode` then consults those measurements first and
//! falls back to the analytic model while a key is cold. Because the
//! skip modes are mutually bit-identical, the DB changes wall time only,
//! never numerics — with the kill switch (or under Miri, where the DB is
//! always absent) the router behaves exactly as before the DB existed.
//!
//! **Dependency-scheduled execution (ISSUE 10).** The router also backs
//! the evaluator's DAG executor ([`xla::eval::execute_pipelined_in`]):
//! [`OpRouter::overlap_join`] is the fork-join primitive the
//! [`xla::PipelinePlanner`] uses to run two ready instructions
//! concurrently on the *same* persistent pool (one task stays on the
//! caller, the other runs on a parked worker), and
//! [`crate::coordinator::pipeline`] builds the planner's cost-gated
//! overlap predicate around this router's DB. When a conv executes on a
//! pool worker (i.e. as the co-scheduled half of a pair), its inner
//! parallel-for runs inline —
//! [`crate::util::threadpool::ThreadPool::for_chunk_slices`] detects
//! reentrancy — so `effective_threads` reports `1` there and
//! every selector decision and cost record keys on the thread budget the
//! op *actually* had. Overlapped runs therefore self-populate the
//! `threads = 1` DB rows the overlap gate reads. Kill switch:
//! `SPARSETRAIN_PIPELINE=off` ([`pipeline_enabled`]) restores strictly
//! sequential evaluation; either way results are bit-identical (pinned by
//! `rust/tests/pipeline_route_parity.rs`).

use crate::coordinator::costdb::{CostDb, CostKey};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::selector::Selector;
use crate::kernels::gemm;
use crate::kernels::regalloc::REG_BUDGET;
use crate::kernels::{Component, ConvConfig, SkipMode};
use crate::sim::Machine;
use crate::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use crate::V;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;
use xla::eval::{bin_f32, un_f32};
use xla::hlo::{BinKind, CmpDir, Op, UnaryKind};

/// The three SparseTrain-executable convolution forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Form {
    /// `bf01_oi01->bf01` — a plain forward convolution.
    Fwd,
    /// `bf01_io01->bf01` — the input-gradient convolution (the graph has
    /// already reversed the filter spatially; `io` swaps its channel dims).
    Bwi,
    /// `fb01_io01->bf01` — the batch-contracting weight-gradient
    /// convolution.
    Bww,
}

/// Classify a parsed `dim_labels` spec; `None` = not a canonical form.
pub(crate) fn classify(spec: &xla::hlo::ConvSpec) -> Option<Form> {
    if spec.lhs_s != [2, 3] || spec.rhs_s != [2, 3] || spec.out_s != [2, 3] {
        return None;
    }
    if spec.out_b != 0 || spec.out_f != 1 {
        return None;
    }
    match (spec.lhs_b, spec.lhs_f, spec.rhs_o, spec.rhs_i) {
        (0, 1, 0, 1) => Some(Form::Fwd),
        (0, 1, 1, 0) => Some(Form::Bwi),
        (1, 0, 1, 0) => Some(Form::Bww),
        _ => None,
    }
}

/// Tiling/planner envelope shared by all three forms. `validate()` covers
/// the V-multiple channel constraint and degenerate filters; the register
/// planner additionally needs `R ≤ REG_BUDGET` so `plan_fwd`/`plan_bww`
/// always find a feasible Q.
pub(crate) fn cfg_in_envelope(cfg: &ConvConfig) -> bool {
    cfg.n >= 1
        && cfg.k >= V
        && cfg.c >= V
        && cfg.r <= REG_BUDGET
        && cfg.validate().is_ok()
}

/// Per-op-kind routing counters (cumulative since router construction).
/// Surfaced at the end of a `train` CLI run so a silent fallback
/// regression — an op kind that used to route suddenly declining — is
/// visible without a profiler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RouteStats {
    /// Convolutions served by the sparse kernel stack.
    pub conv_routed: usize,
    /// Convolutions declined to the naive 7-loop.
    pub conv_fallback: usize,
    /// `dot` instructions served by the blocked GEMM.
    pub dot_routed: usize,
    /// `dot` instructions declined (non-rank-2, stale operands, …).
    pub dot_fallback: usize,
    /// Elementwise chains collapsed into a single fused pass.
    pub fused: usize,
    /// Broadcast/reduce fast paths served (unfused but routed).
    pub ew_routed: usize,
    /// Attempted elementwise/broadcast/reduce ops declined to the naive
    /// evaluator (op kinds the router never attempts are not counted).
    pub ew_fallback: usize,
}

/// Minimum output elements before an elementwise route spreads across the
/// pool; below this a serial in-place pass beats the launch handoff.
const PAR_EW_MIN: usize = 4096;

/// How one instruction was served (internal tri-state behind the
/// elementwise counters).
enum Served {
    /// A recognized chain collapsed into one pass.
    Fused,
    /// A fast path ran (no chain collapse, still bit-identical).
    Routed,
    /// Outside the envelope; the naive evaluator runs.
    Declined,
}

/// A whole-graph op executor over the SparseTrain kernel/scheduler stack.
///
/// Owns one [`Scheduler`] (and therefore one persistent thread pool) for
/// the lifetime of the runtime — every routed convolution *and* every
/// panel-parallel GEMM reuses the same parked workers — plus a
/// thread-count-aware [`Selector`] for the per-conv skip-mode decision.
pub struct OpRouter {
    sched: Scheduler,
    selector: Selector,
    /// `SPARSETRAIN_CONV_ROUTE` at construction: route convolutions?
    route_convs: bool,
    /// `SPARSETRAIN_OP_ROUTE` at construction: route everything else?
    route_ops: bool,
    /// Convolutions served by the kernel stack (legacy counter pair —
    /// conv-only, kept distinct from the [`RouteStats`] fields so ISSUE 5
    /// introspection keeps meaning "convolutions").
    routed: AtomicUsize,
    /// Convolutions declined to the interpreter's naive loop.
    fallback: AtomicUsize,
    dot_routed: AtomicUsize,
    dot_fallback: AtomicUsize,
    fused: AtomicUsize,
    ew_routed: AtomicUsize,
    ew_fallback: AtomicUsize,
    /// Per-conv-instruction (routed, fallback) counters, keyed by HLO
    /// instruction name (`z_s3b1_conv1`, `bww_conv1_2`, …). The
    /// per-layer breakdown the `train` CLI prints so a single layer
    /// silently falling back is visible, not averaged away.
    conv_by_instr: Mutex<BTreeMap<String, (usize, usize)>>,
    /// Profiler-measured sparsity per conv instruction name, fed each
    /// step by the trainer ([`OpRouter::set_profiled_sparsity`]). When a
    /// conv has an entry, the selector sees this instead of the checked
    /// operand's live zero count.
    profiled: Mutex<BTreeMap<String, f64>>,
    /// Measured-cost DB shared with the selector (ISSUE 8). `None` = kill
    /// switch or Miri: pure analytic selection, no timing stamps.
    cost_db: Option<Arc<CostDb>>,
    /// Instruction pairs the DAG executor co-scheduled through
    /// [`OpRouter::overlap_join`] (ISSUE 10). The `train` CLI prints this
    /// so a pipeline that never overlaps anything is visible.
    overlap_pairs: AtomicUsize,
}

impl OpRouter {
    /// A router running `threads` workers (`0` = host parallelism), with
    /// the process-default measured-cost DB ([`CostDb::from_env`]).
    pub fn new(threads: usize) -> OpRouter {
        Self::with_cost_db(threads, CostDb::from_env())
    }

    /// A router with an explicit measured-cost DB (or none — the
    /// kill-switch behavior, regardless of environment). Tests use this
    /// to pin each selector decision path deterministically.
    pub fn with_cost_db(threads: usize, cost_db: Option<Arc<CostDb>>) -> OpRouter {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        // Miri has no host clock: force the analytic path so hooked runs
        // never stamp time (and records never happen).
        let cost_db = if cfg!(miri) { None } else { cost_db };
        let sched = Scheduler::new(threads);
        let mut selector =
            Selector::with_threads(Machine::skylake_x(), threads).with_cost_db(cost_db.clone());
        // Key on the backend actually scheduled (env overrides included).
        selector.backend = sched.backend().name();
        OpRouter {
            sched,
            selector,
            route_convs: routing_enabled(),
            route_ops: op_routing_enabled(),
            routed: AtomicUsize::new(0),
            fallback: AtomicUsize::new(0),
            dot_routed: AtomicUsize::new(0),
            dot_fallback: AtomicUsize::new(0),
            fused: AtomicUsize::new(0),
            ew_routed: AtomicUsize::new(0),
            ew_fallback: AtomicUsize::new(0),
            conv_by_instr: Mutex::new(BTreeMap::new()),
            profiled: Mutex::new(BTreeMap::new()),
            cost_db,
            overlap_pairs: AtomicUsize::new(0),
        }
    }

    pub fn threads(&self) -> usize {
        self.sched.threads()
    }

    /// The thread budget the *current* call actually has: `1` when this
    /// thread is one of the scheduler pool's workers (an op co-scheduled by
    /// the DAG executor — its inner parallel-for runs inline because the
    /// pool detects reentrancy), the full configured count otherwise. Every
    /// selector decision and cost record keys on this, so overlapped runs
    /// self-populate the `threads = 1` DB rows the overlap gate consults.
    fn effective_threads(&self) -> usize {
        if self.sched.pool().on_worker_thread() {
            1
        } else {
            self.sched.threads()
        }
    }

    /// Structured fork-join for the DAG executor's [`xla::PipelinePlanner`]:
    /// run `a` and `b` concurrently on the persistent pool and return only
    /// when **both** have completed. One task runs on the calling thread,
    /// the other on a parked worker (via the pool's non-`'static` chunk
    /// scope), so a pair costs one handoff, not two. Bumps the overlap
    /// counter reported by [`OpRouter::overlap_pairs`].
    pub fn overlap_join(&self, a: xla::TaskBox<'_>, b: xla::TaskBox<'_>) {
        self.overlap_pairs.fetch_add(1, Ordering::Relaxed);
        let mut tasks: Vec<Option<xla::TaskBox<'_>>> = vec![Some(a), Some(b)];
        self.sched.pool().for_chunk_slices(&mut tasks, 2, |_ci, _start, chunk| {
            for t in chunk {
                if let Some(f) = t.take() {
                    f();
                }
            }
        });
    }

    /// Instruction pairs co-scheduled so far (cumulative).
    pub fn overlap_pairs(&self) -> usize {
        self.overlap_pairs.load(Ordering::Relaxed)
    }

    /// Busy-worker utilization EMA from the scheduler's timed conv chunks
    /// (`None` single-threaded, under Miri, or before the first timed run).
    pub fn pool_utilization(&self) -> Option<f64> {
        self.sched.pool_utilization()
    }

    /// The attached measured-cost DB, if any (for the CLI report and the
    /// bench harness).
    pub fn cost_db(&self) -> Option<&Arc<CostDb>> {
        self.cost_db.as_ref()
    }

    /// Name of the SIMD backend the scheduler dispatched — the cost-DB
    /// key field the pipeline overlap gate queries with.
    pub fn backend_name(&self) -> &'static str {
        self.sched.backend().name()
    }

    /// Convolutions served by the kernel stack so far.
    pub fn routed_calls(&self) -> usize {
        self.routed.load(Ordering::Relaxed)
    }

    /// Convolutions declined to the naive interpreter loop so far.
    pub fn fallback_calls(&self) -> usize {
        self.fallback.load(Ordering::Relaxed)
    }

    /// Snapshot of all per-kind routing counters.
    pub fn stats(&self) -> RouteStats {
        RouteStats {
            conv_routed: self.routed.load(Ordering::Relaxed),
            conv_fallback: self.fallback.load(Ordering::Relaxed),
            dot_routed: self.dot_routed.load(Ordering::Relaxed),
            dot_fallback: self.dot_fallback.load(Ordering::Relaxed),
            fused: self.fused.load(Ordering::Relaxed),
            ew_routed: self.ew_routed.load(Ordering::Relaxed),
            ew_fallback: self.ew_fallback.load(Ordering::Relaxed),
        }
    }

    /// Per-conv-instruction `(name, routed, fallback)` rows, sorted by
    /// instruction name. Empty until a conv reaches the router through the
    /// evaluator hook (the name comes from the HLO instruction).
    pub fn conv_layer_stats(&self) -> Vec<(String, usize, usize)> {
        self.conv_by_instr
            .lock()
            .unwrap()
            .iter()
            .map(|(nm, &(r, f))| (nm.clone(), r, f))
            .collect()
    }

    /// Install profiler-measured sparsities for conv instructions (name →
    /// expected checked-operand sparsity, clamped to `[0, 1]`). Replaces
    /// prior values for the given keys only; the trainer calls this every
    /// step with the recent-mean of each conv's feed series so the
    /// selector's skip-mode choice tracks the measured dynamic sparsity
    /// instead of each call's instantaneous zero count.
    pub fn set_profiled_sparsity<I>(&self, feeds: I)
    where
        I: IntoIterator<Item = (String, f64)>,
    {
        let mut map = self.profiled.lock().unwrap();
        for (nm, s) in feeds {
            map.insert(nm, s.clamp(0.0, 1.0));
        }
    }

    /// The sparsity the selector should plan with for conv `instr`: the
    /// profiled value when the trainer installed one, else the live
    /// operand measurement.
    fn sparsity_for(&self, instr: Option<&str>, live: f64) -> f64 {
        if let Some(nm) = instr {
            if let Some(&s) = self.profiled.lock().unwrap().get(nm) {
                return s;
            }
        }
        live
    }

    fn bump(&self, counter: &AtomicUsize) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    fn tally_ew(&self, served: Served) -> bool {
        match served {
            Served::Fused => {
                self.bump(&self.fused);
                true
            }
            Served::Routed => {
                self.bump(&self.ew_routed);
                true
            }
            Served::Declined => {
                self.bump(&self.ew_fallback);
                false
            }
        }
    }

    /// The [`xla::OpExecutor`] entry point: either fill `out` completely
    /// and return `true`, or return `false` having written nothing the
    /// evaluator will read (the arena recycles the buffer). Never panics —
    /// every kernel precondition is checked before any buffer is touched.
    pub fn route_op(&self, call: &xla::OpCall<'_>, out: &mut [f32]) -> bool {
        match call.op() {
            Op::Convolution { window, spec } => {
                if !self.route_convs {
                    return false;
                }
                let (Some((lhs, lhs_dims)), Some((rhs, rhs_dims))) =
                    (call.operand_f32(0), call.operand_f32(1))
                else {
                    return false;
                };
                let conv = xla::ConvCall {
                    window,
                    spec,
                    lhs,
                    lhs_dims,
                    rhs,
                    rhs_dims,
                    out_dims: call.out_dims(),
                };
                match self.route_named(&conv, Some(&call.instr().name)) {
                    Some(buf) if buf.len() == out.len() => {
                        out.copy_from_slice(&buf);
                        true
                    }
                    _ => false,
                }
            }
            _ if !self.route_ops => false,
            Op::Dot { lhs_c, rhs_c } => {
                let ok = self.route_dot(call, *lhs_c, *rhs_c, out);
                self.bump(if ok { &self.dot_routed } else { &self.dot_fallback });
                ok
            }
            Op::Binary(kind) => self.tally_ew(self.route_binary(call, *kind, out)),
            // Raw `iota` is s32-only, so the f32 hook never sees it; its
            // work is served by the fused `convert(iota)` path below.
            Op::Unary(kind) => self.tally_ew(self.route_unary(call, *kind, out)),
            Op::Convert => self.tally_ew(self.route_convert(call, out)),
            Op::Select => self.tally_ew(self.route_select(call, out)),
            Op::Broadcast { dims } => self.tally_ew(route_broadcast(call, dims, out)),
            Op::Reduce { dims, to_apply } => {
                self.tally_ew(route_reduce(call, dims, *to_apply, out))
            }
            _ => false,
        }
    }

    /// `dot` → the blocked GEMM. Rank-2 × rank-2 only; either contracting
    /// layout is normalized onto the row-major `a[m][k] · b[k][n]` kernel
    /// by packing a transpose. Output is the naive evaluator's row-major
    /// `m × n` (allclose, not bit-equal: the kernel contracts with FMAs).
    fn route_dot(&self, call: &xla::OpCall<'_>, lhs_c: usize, rhs_c: usize, out: &mut [f32]) -> bool {
        let (Some((a, ad)), Some((b, bd))) = (call.operand_f32(0), call.operand_f32(1)) else {
            return false;
        };
        if ad.len() != 2 || bd.len() != 2 || lhs_c > 1 || rhs_c > 1 {
            return false;
        }
        let (m, k) = if lhs_c == 1 { (ad[0], ad[1]) } else { (ad[1], ad[0]) };
        let (k2, n) = if rhs_c == 0 { (bd[0], bd[1]) } else { (bd[1], bd[0]) };
        if k2 != k || out.len() != m * n {
            return false;
        }
        let a_packed: Vec<f32>;
        let a_ref: &[f32] = if lhs_c == 1 {
            a
        } else {
            a_packed = gemm::pack_transpose(a, ad[0], ad[1]);
            &a_packed
        };
        let b_packed: Vec<f32>;
        let b_ref: &[f32] = if rhs_c == 0 {
            b
        } else {
            b_packed = gemm::pack_transpose(b, bd[0], bd[1]);
            &b_packed
        };
        out.fill(0.0);
        let bk = self.sched.backend();
        let eff = self.effective_threads();
        let t0 = self.cost_clock();
        if m <= gemm::MB {
            // One panel: the parallel path would enqueue a single task —
            // pay the pool handoff only when there is work to spread.
            gemm::gemm_with(bk, m, n, k, a_ref, b_ref, out);
            if let (Some(t0), Some(db)) = (t0, self.cost_db.as_ref()) {
                // Shape-level observability row (no chunk choice applies).
                db.record(
                    CostKey::gemm(m, n, k, eff, bk.name()),
                    t0.elapsed().as_nanos() as f64,
                );
            }
        } else {
            // Measured-cost GEMM policy (ISSUE 10 satellite): the selector
            // picks the panel-distribution chunk count for this shape from
            // recorded `c{chunks}` rows, exploring candidates while cold.
            // Every chunk count is bit-identical (row grouping only).
            let default = m.div_ceil(gemm::MB);
            let chunks = self.selector.gemm_chunks(m, n, k, eff, default);
            gemm::gemm_parallel_chunks(self.sched.pool(), bk, m, n, k, a_ref, b_ref, out, chunks);
            if let (Some(t0), Some(db)) = (t0, self.cost_db.as_ref()) {
                db.record(
                    CostKey::gemm_chunks(m, n, k, eff, bk.name(), chunks),
                    t0.elapsed().as_nanos() as f64,
                );
            }
        }
        true
    }

    /// Elementwise binaries: fuse broadcast operands (bias add, ReLU max,
    /// scalar scale, log-softmax row ops) and the SGD `w - lr·g` chain
    /// into single passes. All fused forms reproduce the unfused evaluator
    /// bit for bit — same per-element operations, same rounding count.
    fn route_binary(&self, call: &xla::OpCall<'_>, kind: BinKind, out: &mut [f32]) -> Served {
        let (Some((x, _)), Some((y, _))) = (call.operand_f32(0), call.operand_f32(1)) else {
            return Served::Declined;
        };

        // SGD update: subtract(w, multiply(splat(lr), g)) — read through
        // the multiply so the pass runs on `w` and `g` directly.
        if kind == BinKind::Sub && x.len() == out.len() {
            if let Some((s, g)) = scaled_operand(call, 1) {
                if g.len() == out.len() {
                    for ((o, &w), &gv) in out.iter_mut().zip(x).zip(g) {
                        // mul-round then sub-round, exactly like the
                        // unfused evaluator — deliberately NOT mul_add
                        *o = w - s * gv;
                    }
                    return Served::Fused;
                }
            }
        }

        // A scalar splat on either side: one pass, scalar in a register.
        if let Some(s) = splat_scalar(call, 1) {
            if x.len() == out.len() {
                for (o, &u) in out.iter_mut().zip(x) {
                    *o = bin_f32(kind, u, s);
                }
                return Served::Fused;
            }
        }
        if let Some(s) = splat_scalar(call, 0) {
            if y.len() == out.len() {
                for (o, &v) in out.iter_mut().zip(y) {
                    *o = bin_f32(kind, s, v);
                }
                return Served::Fused;
            }
        }

        // Rank-2 row/column vector broadcasts (bias add, log-softmax
        // subtract/divide): read the rank-1 vector instead of the
        // materialized broadcast.
        let od = call.out_dims();
        if od.len() == 2 && od[1] > 0 && out.len() == od[0] * od[1] {
            let c = od[1];
            if x.len() == out.len() {
                if let Some((bdim, v)) = vec_broadcast(call, 1) {
                    if bdim == 0 && v.len() == od[0] {
                        for ((orow, xrow), &s) in out.chunks_mut(c).zip(x.chunks(c)).zip(v) {
                            for (o, &u) in orow.iter_mut().zip(xrow) {
                                *o = bin_f32(kind, u, s);
                            }
                        }
                        return Served::Fused;
                    }
                    if bdim == 1 && v.len() == c {
                        for (orow, xrow) in out.chunks_mut(c).zip(x.chunks(c)) {
                            for ((o, &u), &s) in orow.iter_mut().zip(xrow).zip(v) {
                                *o = bin_f32(kind, u, s);
                            }
                        }
                        return Served::Fused;
                    }
                }
            }
            if y.len() == out.len() {
                if let Some((bdim, v)) = vec_broadcast(call, 0) {
                    if bdim == 0 && v.len() == od[0] {
                        for ((orow, yrow), &s) in out.chunks_mut(c).zip(y.chunks(c)).zip(v) {
                            for (o, &u) in orow.iter_mut().zip(yrow) {
                                *o = bin_f32(kind, s, u);
                            }
                        }
                        return Served::Fused;
                    }
                    if bdim == 1 && v.len() == c {
                        for (orow, yrow) in out.chunks_mut(c).zip(y.chunks(c)) {
                            for ((o, &u), &s) in orow.iter_mut().zip(yrow).zip(v) {
                                *o = bin_f32(kind, s, u);
                            }
                        }
                        return Served::Fused;
                    }
                }
            }
        }
        Served::Declined
    }

    /// The ReLU-backward chain `select(compare(z, splat, GT), t, splat)`
    /// as one pass. Same compare + select semantics as the naive pair —
    /// bit-identical.
    fn route_select(&self, call: &xla::OpCall<'_>, out: &mut [f32]) -> Served {
        let Some(pred) = call.operand_instr(0) else {
            return Served::Declined;
        };
        let Op::Compare(CmpDir::Gt) = &pred.op else {
            return Served::Declined;
        };
        let [z_idx, thr_idx] = pred.operands[..] else {
            return Served::Declined;
        };
        let Some(threshold) = splat_scalar_at(call, thr_idx) else {
            return Served::Declined;
        };
        let Some((z, _)) = call.value_f32(z_idx) else {
            return Served::Declined;
        };
        let Some((t, _)) = call.operand_f32(1) else {
            return Served::Declined;
        };
        let Some(on_false) = splat_scalar(call, 2) else {
            return Served::Declined;
        };
        if z.len() != out.len() || t.len() != out.len() {
            return Served::Declined;
        }
        for ((o, &zv), &tv) in out.iter_mut().zip(z).zip(t) {
            *o = if zv > threshold { tv } else { on_false };
        }
        Served::Fused
    }

    /// Run `f(start_offset, chunk)` over disjoint chunks of `out` — on
    /// the scheduler pool for large outputs, serially otherwise (below
    /// [`PAR_EW_MIN`] the pool handoff costs more than it saves). `f`
    /// must fill its chunk completely. Both paths apply the identical
    /// per-element map, so the partition cannot change numerics.
    fn par_elementwise<F>(&self, out: &mut [f32], f: F)
    where
        F: Fn(usize, &mut [f32]) + Send + Sync,
    {
        let threads = self.sched.threads();
        if out.len() < PAR_EW_MIN || threads < 2 {
            f(0, out);
        } else {
            let chunks = threads * 4;
            self.sched.pool().for_chunk_slices(out, chunks, |_ci, start, chunk| f(start, chunk));
        }
    }

    /// Elementwise unaries (`exponential`, `log`, `negate`): the naive
    /// evaluator's [`un_f32`] per element, spread across the pool —
    /// bit-identical (same scalar libm call per element, any partition).
    fn route_unary(&self, call: &xla::OpCall<'_>, kind: UnaryKind, out: &mut [f32]) -> Served {
        let Some((x, _)) = call.operand_f32(0) else {
            return Served::Declined;
        };
        if x.len() != out.len() {
            return Served::Declined;
        }
        self.par_elementwise(out, |start, chunk| {
            for (o, &u) in chunk.iter_mut().zip(&x[start..start + chunk.len()]) {
                *o = un_f32(kind, u);
            }
        });
        Served::Routed
    }

    /// `convert` to f32: parallel f32 copies and s32/pred casts, plus the
    /// fused `convert(iota)` index fill. Raw `iota` is s32-only (shape
    /// inference rejects anything else), so the f32 hook can never serve
    /// it directly — instead, when the operand's defining instruction is
    /// `iota`, the route skips the materialized s32 buffer entirely and
    /// fills `out[i] = ((i / stride) % extent) as i32 as f32`, exactly
    /// the naive `eval_iota`-then-convert chain. All paths reproduce the
    /// naive evaluator bit for bit (same per-element cast, any
    /// partition).
    fn route_convert(&self, call: &xla::OpCall<'_>, out: &mut [f32]) -> Served {
        if let Some(op) = call.operand_instr(0) {
            if let Op::Iota { dim } = op.op {
                let dims = call.out_dims();
                if dim < dims.len() && out.len() == dims.iter().product::<usize>() {
                    let extent = dims[dim];
                    let stride: usize = dims[dim + 1..].iter().product();
                    if extent > 0 && stride > 0 {
                        self.par_elementwise(out, |start, chunk| {
                            for (j, o) in chunk.iter_mut().enumerate() {
                                *o = (((start + j) / stride) % extent) as i32 as f32;
                            }
                        });
                        return Served::Routed;
                    }
                }
            }
        }
        if let Some((x, _)) = call.operand_f32(0) {
            if x.len() != out.len() {
                return Served::Declined;
            }
            self.par_elementwise(out, |start, chunk| {
                chunk.copy_from_slice(&x[start..start + chunk.len()]);
            });
            return Served::Routed;
        }
        if let Some((x, _)) = call.operand_s32(0) {
            if x.len() != out.len() {
                return Served::Declined;
            }
            self.par_elementwise(out, |start, chunk| {
                for (o, &v) in chunk.iter_mut().zip(&x[start..start + chunk.len()]) {
                    *o = v as f32;
                }
            });
            return Served::Routed;
        }
        if let Some((x, _)) = call.operand_pred(0) {
            if x.len() != out.len() {
                return Served::Declined;
            }
            self.par_elementwise(out, |start, chunk| {
                for (o, &v) in chunk.iter_mut().zip(&x[start..start + chunk.len()]) {
                    *o = if v { 1.0 } else { 0.0 };
                }
            });
            return Served::Routed;
        }
        Served::Declined
    }

    /// Skip mode for one call: measured-cost DB first (cheapest measured
    /// mode for this key), analytic model while the key is cold or the DB
    /// is detached — see [`Selector::skip_mode_decision`]. Keys on the
    /// *effective* thread budget, so a conv co-scheduled onto a pool
    /// worker (inner launch runs inline) is planned as single-threaded.
    /// Either way the modes are mutually bit-identical.
    fn skip_mode(&self, cfg: &ConvConfig, comp: Component, sparsity: f64) -> SkipMode {
        self.selector.skip_mode_decision_at(cfg, comp, sparsity, self.effective_threads()).0
    }

    /// Monotonic stamp for lazy DB population — `None` when no DB is
    /// attached, so the no-DB hot path pays zero clock reads.
    fn cost_clock(&self) -> Option<Instant> {
        if self.cost_db.is_some() && !cfg!(miri) {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Fold one timed conv execution into the DB (no-op without a stamp).
    fn record_conv_cost(
        &self,
        t0: Option<Instant>,
        comp: Component,
        cfg: &ConvConfig,
        sparsity: f64,
        mode: SkipMode,
    ) {
        if let (Some(t0), Some(db)) = (t0, self.cost_db.as_ref()) {
            db.record(
                CostKey::conv(
                    comp,
                    cfg,
                    sparsity,
                    // Same effective-threads key as the decision above: a
                    // co-scheduled conv's sample must not pollute the
                    // full-budget row it did not run under.
                    self.effective_threads(),
                    self.sched.backend().name(),
                    mode,
                ),
                t0.elapsed().as_nanos() as f64,
            );
        }
    }

    /// Try to execute one interpreter convolution on the kernel stack.
    /// `None` = outside the envelope; the caller falls back to the naive
    /// loop. Never panics: every precondition of the kernels is checked
    /// here first.
    pub fn route(&self, call: &xla::ConvCall<'_>) -> Option<Vec<f32>> {
        self.route_named(call, None)
    }

    /// [`OpRouter::route`] with the conv's HLO instruction name attached:
    /// tallies the per-instruction routed/fallback counter and lets the
    /// selector use the trainer's profiled sparsity for this instruction.
    pub fn route_named(&self, call: &xla::ConvCall<'_>, instr: Option<&str>) -> Option<Vec<f32>> {
        let out = self.try_route(call, instr);
        if out.is_some() {
            self.routed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.fallback.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(nm) = instr {
            let mut map = self.conv_by_instr.lock().unwrap();
            let e = map.entry(nm.to_string()).or_insert((0, 0));
            if out.is_some() {
                e.0 += 1;
            } else {
                e.1 += 1;
            }
        }
        out
    }

    fn try_route(&self, call: &xla::ConvCall<'_>, instr: Option<&str>) -> Option<Vec<f32>> {
        if call.lhs_dims.len() != 4 || call.rhs_dims.len() != 4 || call.out_dims.len() != 4 {
            return None;
        }
        // The interpreter validates shapes before calling the hook, but
        // `route` is public API — never index past a malformed call.
        let n_lhs: usize = call.lhs_dims.iter().product();
        let n_rhs: usize = call.rhs_dims.iter().product();
        if call.lhs.len() != n_lhs || call.rhs.len() != n_rhs {
            return None;
        }
        let w = call.window;
        // ConvConfig models symmetric padding only; the window size must
        // be the rhs spatial extent (shape-inference invariant).
        if w.pad_lo != w.pad_hi || w.size != [call.rhs_dims[2], call.rhs_dims[3]] {
            return None;
        }
        match classify(call.spec)? {
            Form::Fwd => self.route_fwd(call, instr),
            Form::Bwi => self.route_bwi(call, instr),
            Form::Bww => self.route_bww(call, instr),
        }
    }

    /// `bf01_oi01->bf01`: lhs `[N,C,H,W]`, rhs `[K,C,S,R]`, out
    /// `[N,K,H',W']` — exactly [`Scheduler::run_fwd`]'s contract after
    /// packing into the tiled layouts.
    fn route_fwd(&self, call: &xla::ConvCall<'_>, instr: Option<&str>) -> Option<Vec<f32>> {
        let (l, r, w) = (call.lhs_dims, call.rhs_dims, call.window);
        let cfg = ConvConfig {
            n: l[0],
            c: l[1],
            k: r[0],
            h: l[2],
            w: l[3],
            s: w.size[0],
            r: w.size[1],
            stride_p: w.stride[0],
            stride_o: w.stride[1],
            pad_h: w.pad_lo[0],
            pad_w: w.pad_lo[1],
        };
        if r[1] != cfg.c || !cfg_in_envelope(&cfg) {
            return None;
        }
        debug_assert_eq!(call.out_dims, &[cfg.n, cfg.k, cfg.out_h(), cfg.out_w()][..]);

        let d = ActTensor::from_nchw(cfg.n, cfg.c, cfg.h, cfg.w, call.lhs);
        let g = FilterTensor::from_kcsr(cfg.k, cfg.c, cfg.s, cfg.r, call.rhs);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let sparsity = self.sparsity_for(instr, d.sparsity());
        let mode = self.skip_mode(&cfg, Component::Fwd, sparsity);
        let t0 = self.cost_clock();
        self.sched.run_fwd(&cfg, &d, &g, &mut y, mode);
        self.record_conv_cost(t0, Component::Fwd, &cfg, sparsity, mode);
        Some(y.to_nchw())
    }

    /// `bf01_io01->bf01` with unit stride: the input-gradient convolution.
    /// Mapped onto [`Scheduler::run_bwi`] of the *forward* layer it
    /// differentiates: lhs is ∂L/∂Y `[N,K,H',W']`, the rhs `[K,C,S,R]` is
    /// the spatially reversed forward filter with swapped channel labels,
    /// and out is ∂L/∂D `[N,C,H,W]`. Undoing the graph-side reversal while
    /// packing the BWI kernel's channel-transposed filter recovers the
    /// forward filter's taps, and the pad identity `pad_fwd = S-1-pad_conv`
    /// makes the scatter geometry line up (checked below).
    fn route_bwi(&self, call: &xla::ConvCall<'_>, instr: Option<&str>) -> Option<Vec<f32>> {
        let (l, r, o, w) = (call.lhs_dims, call.rhs_dims, call.out_dims, call.window);
        if w.stride != [1, 1] {
            return None; // strided BWI needs window dilation — not emitted
        }
        let (s, rr) = (w.size[0], w.size[1]);
        if w.pad_lo[0] + 1 > s || w.pad_lo[1] + 1 > rr {
            return None; // pad_fwd = S-1-pad would underflow
        }
        let cfg = ConvConfig {
            n: l[0],
            c: r[1], // conv output features = the forward layer's inputs
            k: l[1], // contracted dim = the forward layer's outputs
            h: o[2],
            w: o[3],
            s,
            r: rr,
            stride_p: 1,
            stride_o: 1,
            pad_h: s - 1 - w.pad_lo[0],
            pad_w: rr - 1 - w.pad_lo[1],
        };
        if r[0] != cfg.k || !cfg_in_envelope(&cfg) {
            return None;
        }
        // The scatter geometry must reproduce the conv's shapes exactly.
        if cfg.out_h() != l[2] || cfg.out_w() != l[3] {
            return None;
        }
        debug_assert_eq!(o, &[cfg.n, cfg.c, cfg.h, cfg.w][..]);

        let dy = ActTensor::from_nchw(cfg.n, cfg.k, l[2], l[3], call.lhs);
        // gt[c_fwd, k_fwd, s, r] = G_fwd[k_fwd, c_fwd, s, r]
        //                        = rhs[k_fwd, c_fwd, S-1-s, R-1-r].
        let mut gt = FilterTensor::zeros(cfg.c, cfg.k, cfg.s, cfg.r);
        for ki in 0..cfg.k {
            for ci in 0..cfg.c {
                for ky in 0..cfg.s {
                    for kx in 0..cfg.r {
                        let v = call.rhs[((ki * cfg.c + ci) * cfg.s + ky) * cfg.r + kx];
                        gt.set(ci, ki, cfg.s - 1 - ky, cfg.r - 1 - kx, v);
                    }
                }
            }
        }
        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let sparsity = self.sparsity_for(instr, dy.sparsity());
        let mode = self.skip_mode(&cfg, Component::Bwi, sparsity);
        let t0 = self.cost_clock();
        self.sched.run_bwi(&cfg, &dy, &gt, &mut dd, mode);
        self.record_conv_cost(t0, Component::Bwi, &cfg, sparsity, mode);
        Some(dd.to_nchw())
    }

    /// `fb01_io01->bf01` with unit stride: the batch-contracting
    /// weight-gradient convolution. Both operands are plain NCHW buffers
    /// (lhs = forward activations `[N,C,H,W]` with batch relabeled as the
    /// contracted dim, rhs = ∂L/∂Z `[N,K,H',W']`), and the conv's output
    /// spatial extent is the filter tap grid — so this is exactly
    /// [`Scheduler::run_bww`] with the output transposed to `[C,K,S,R]`.
    fn route_bww(&self, call: &xla::ConvCall<'_>, instr: Option<&str>) -> Option<Vec<f32>> {
        let (l, r, o, w) = (call.lhs_dims, call.rhs_dims, call.out_dims, call.window);
        if w.stride != [1, 1] {
            return None; // strided-forward BWW needs rhs dilation
        }
        let cfg = ConvConfig {
            n: l[0], // contracted minibatch
            c: l[1],
            k: r[1],
            h: l[2],
            w: l[3],
            s: o[2], // conv output spatial = the weight tap grid
            r: o[3],
            stride_p: 1,
            stride_o: 1,
            pad_h: w.pad_lo[0],
            pad_w: w.pad_lo[1],
        };
        // §5.4: BWW's minibatch vectorization needs N % V == 0.
        if r[0] != cfg.n || cfg.n % V != 0 || !cfg_in_envelope(&cfg) {
            return None;
        }
        // The sweep geometry must reproduce the conv window (the rhs
        // spatial extent) exactly.
        if cfg.out_h() != w.size[0] || cfg.out_w() != w.size[1] {
            return None;
        }
        debug_assert_eq!(o, &[cfg.c, cfg.k, cfg.s, cfg.r][..]);

        let d_act = ActTensor::from_nchw(cfg.n, cfg.c, cfg.h, cfg.w, call.lhs);
        let d = BatchTiledTensor::from_act(&d_act);
        let dy = ActTensor::from_nchw(cfg.n, cfg.k, w.size[0], w.size[1], call.rhs);
        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let sparsity = self.sparsity_for(instr, d.sparsity());
        let mode = self.skip_mode(&cfg, Component::Bww, sparsity);
        let t0 = self.cost_clock();
        self.sched.run_bww(&cfg, &d, &dy, &mut dg, mode);
        self.record_conv_cost(t0, Component::Bww, &cfg, sparsity, mode);

        // Unpack dG[k,c,s,r] into the conv's [C,K,S,R] output layout.
        let mut out = vec![0.0f32; cfg.c * cfg.k * cfg.s * cfg.r];
        for ci in 0..cfg.c {
            for ki in 0..cfg.k {
                for si in 0..cfg.s {
                    for ri in 0..cfg.r {
                        out[((ci * cfg.k + ki) * cfg.s + si) * cfg.r + ri] =
                            dg.get(ki, ci, si, ri);
                    }
                }
            }
        }
        Some(out)
    }
}

/// The splat scalar behind instruction `idx`: `broadcast(s), dimensions={}`
/// of a live scalar f32 value.
fn splat_scalar_at(call: &xla::OpCall<'_>, idx: usize) -> Option<f32> {
    let instr = call.instr_at(idx)?;
    let Op::Broadcast { dims } = &instr.op else {
        return None;
    };
    if !dims.is_empty() {
        return None;
    }
    let src = *instr.operands.first()?;
    let (v, d) = call.value_f32(src)?;
    if d.is_empty() && v.len() == 1 {
        Some(v[0])
    } else {
        None
    }
}

/// [`splat_scalar_at`] for the `k`-th operand of the current instruction.
fn splat_scalar(call: &xla::OpCall<'_>, k: usize) -> Option<f32> {
    splat_scalar_at(call, call.operand_idx(k)?)
}

/// When operand `k` is `broadcast(v), dimensions={d}` of a live rank-1
/// vector, return `(d, v)`.
fn vec_broadcast<'a>(call: &xla::OpCall<'a>, k: usize) -> Option<(usize, &'a [f32])> {
    let instr = call.operand_instr(k)?;
    let Op::Broadcast { dims } = &instr.op else {
        return None;
    };
    let [bdim] = dims.as_slice() else {
        return None;
    };
    let src = *instr.operands.first()?;
    let (v, d) = call.value_f32(src)?;
    if d.len() == 1 {
        Some((*bdim, v))
    } else {
        None
    }
}

/// When operand `k` is `multiply(splat(s), g)` (either factor order) of
/// live f32 values, return `(s, g)` — the SGD chain's scaled gradient.
fn scaled_operand<'a>(call: &xla::OpCall<'a>, k: usize) -> Option<(f32, &'a [f32])> {
    let instr = call.operand_instr(k)?;
    if !matches!(instr.op, Op::Binary(BinKind::Mul)) {
        return None;
    }
    let [fa, fb] = instr.operands[..] else {
        return None;
    };
    if let Some(s) = splat_scalar_at(call, fa) {
        return Some((s, call.value_f32(fb)?.0));
    }
    if let Some(s) = splat_scalar_at(call, fb) {
        return Some((s, call.value_f32(fa)?.0));
    }
    None
}

/// Broadcast fast paths: plain fills and row copies instead of the naive
/// evaluator's per-element index decomposition. Exact copies of the naive
/// gather — bit-identical by construction.
fn route_broadcast(call: &xla::OpCall<'_>, dims: &[usize], out: &mut [f32]) -> Served {
    let Some((src, sd)) = call.operand_f32(0) else {
        return Served::Declined;
    };
    let od = call.out_dims();
    match dims {
        // scalar → any rank
        [] if src.len() == 1 => {
            out.fill(src[0]);
            Served::Routed
        }
        // rank-1 [n] → [n, c]: replicate each element across its row
        [0] if od.len() == 2 && od[1] > 0 && sd == [od[0]] && out.len() == od[0] * od[1] => {
            for (row, &v) in out.chunks_mut(od[1]).zip(src) {
                row.fill(v);
            }
            Served::Routed
        }
        // rank-1 [c] → [n, c]: copy the vector into every row
        [1] if od.len() == 2 && od[1] > 0 && sd == [od[1]] && out.len() == od[0] * od[1] => {
            for row in out.chunks_mut(od[1]) {
                row.copy_from_slice(src);
            }
            Served::Routed
        }
        // rank-2 [n, c] → [n, c, h, w]: fill each spatial block
        [0, 1]
            if od.len() == 4
                && od[2] * od[3] > 0
                && sd == [od[0], od[1]]
                && out.len() == src.len() * od[2] * od[3] =>
        {
            for (block, &v) in out.chunks_mut(od[2] * od[3]).zip(src) {
                block.fill(v);
            }
            Served::Routed
        }
        _ => Served::Declined,
    }
}

/// Reduce fast paths for plain `bin(p0, p1)` fold bodies: the naive
/// evaluator's row-major fold order reproduced without the per-element
/// index decomposition — bit-identical.
fn route_reduce(call: &xla::OpCall<'_>, dims: &[usize], to_apply: usize, out: &mut [f32]) -> Served {
    let Some(kind) = call.reduce_body_kind(to_apply) else {
        return Served::Declined;
    };
    let (Some((src, sd)), Some((init_v, init_d))) = (call.operand_f32(0), call.operand_f32(1))
    else {
        return Served::Declined;
    };
    if !init_d.is_empty() || init_v.len() != 1 {
        return Served::Declined;
    }
    let init = init_v[0];
    // Full reduction over every dimension → a scalar fold.
    if dims.len() == sd.len() && dims.iter().copied().eq(0..sd.len()) && out.len() == 1 {
        let mut acc = init;
        for &v in src {
            acc = bin_f32(kind, acc, v);
        }
        out[0] = acc;
        return Served::Routed;
    }
    match (sd.len(), dims) {
        // [n, c] over dim 0 → [c]: column accumulators, rows in order
        (2, [0]) if sd[1] > 0 && out.len() == sd[1] => {
            out.fill(init);
            for row in src.chunks(sd[1]) {
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = bin_f32(kind, *o, v);
                }
            }
            Served::Routed
        }
        // [n, c] over dim 1 → [n]: one fold per row
        (2, [1]) if sd[1] > 0 && out.len() == sd[0] => {
            for (o, row) in out.iter_mut().zip(src.chunks(sd[1])) {
                let mut acc = init;
                for &v in row {
                    acc = bin_f32(kind, acc, v);
                }
                *o = acc;
            }
            Served::Routed
        }
        // [n, k, h, w] over the spatial dims → [n, k]: one fold per block
        (4, [2, 3]) if sd[2] * sd[3] > 0 && out.len() == sd[0] * sd[1] => {
            for (o, block) in out.iter_mut().zip(src.chunks(sd[2] * sd[3])) {
                let mut acc = init;
                for &v in block {
                    acc = bin_f32(kind, acc, v);
                }
                *o = acc;
            }
            Served::Routed
        }
        _ => Served::Declined,
    }
}

/// Wrap a router as the vendored crate's hook type, ready for
/// [`xla::PjRtClient::set_op_executor`].
pub fn hook(router: Arc<OpRouter>) -> Arc<xla::OpExecutor> {
    Arc::new(move |call: &xla::OpCall<'_>, out: &mut [f32]| router.route_op(call, out))
}

/// `SPARSETRAIN_CONV_ROUTE=off|0` disables *convolution* kernel routing
/// process-wide (the naive 7-loop runs for every conv) — the A/B switch
/// for debugging and for the wallclock harness's naive baseline rows.
pub fn routing_enabled() -> bool {
    match std::env::var("SPARSETRAIN_CONV_ROUTE") {
        Ok(v) => !matches!(v.as_str(), "off" | "0" | "false"),
        Err(_) => true,
    }
}

/// `SPARSETRAIN_OP_ROUTE=off|0` disables every non-convolution route
/// (GEMM, fused elementwise chains, broadcast/reduce fast paths) — the
/// mirror kill switch of [`routing_enabled`], read at router construction.
pub fn op_routing_enabled() -> bool {
    match std::env::var("SPARSETRAIN_OP_ROUTE") {
        Ok(v) => !matches!(v.as_str(), "off" | "0" | "false"),
        Err(_) => true,
    }
}

/// `SPARSETRAIN_PIPELINE=off|0` disables the dependency-scheduled
/// evaluator — every instruction runs strictly sequentially, exactly the
/// pre-ISSUE-10 behavior. The third kill switch in the family; like the
/// other two it is read once, at runtime construction.
pub fn pipeline_enabled() -> bool {
    match std::env::var("SPARSETRAIN_PIPELINE") {
        Ok(v) => !matches!(v.as_str(), "off" | "0" | "false"),
        Err(_) => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{reference, KernelStats};
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;
    use xla::hlo::{ConvSpec, Window};

    fn spec(labels: &str) -> ConvSpec {
        // reuse the vendored parser through a one-instruction module
        let text = format!(
            "HloModule s\nENTRY %m {{\n  %x = f32[1,16,4,4] parameter(0)\n  \
             %w = f32[16,16,1,1] parameter(1)\n  ROOT %y = f32[1,16,4,4] \
             convolution(%x, %w), window={{size=1x1 pad=0_0x0_0}}, dim_labels={labels}\n}}\n"
        );
        let m = xla::hlo::parse_module(&text).unwrap();
        match &m.comps[0].instrs[2].op {
            xla::hlo::Op::Convolution { spec, .. } => *spec,
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn miri_classifies_the_three_forms_and_rejects_others() {
        assert_eq!(classify(&spec("bf01_oi01->bf01")), Some(Form::Fwd));
        assert_eq!(classify(&spec("bf01_io01->bf01")), Some(Form::Bwi));
        assert_eq!(classify(&spec("fb01_io01->bf01")), Some(Form::Bww));
        for odd in ["fb01_oi01->bf01", "bf01_oi01->fb01", "b01f_oi01->bf01", "bf10_oi01->bf01"] {
            assert_eq!(classify(&spec(odd)), None, "{odd}");
        }
    }

    #[test]
    fn miri_envelope_rejects_untileable_and_wide_filters() {
        let ok = ConvConfig::square(1, V, V, 4, 3, 1);
        assert!(cfg_in_envelope(&ok));
        let mut bad_c = ok;
        bad_c.c = V + 1;
        assert!(!cfg_in_envelope(&bad_c));
        let mut wide = ConvConfig::square(1, V, V, 64, 3, 1);
        wide.r = REG_BUDGET + 1;
        wide.pad_w = 0;
        assert!(!cfg_in_envelope(&wide));
    }

    /// FWD routing matches the scalar reference and reports itself routed.
    #[test]
    #[cfg_attr(miri, ignore = "full kernel launch is too slow under miri")]
    fn routed_fwd_matches_reference() {
        let cfg = ConvConfig::square(2, 16, 32, 6, 3, 1);
        let mut rng = Xorshift::new(9);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, 0.5);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let (lhs, rhs) = (d.to_nchw(), g.to_kcsr());

        let window = Window { size: [3, 3], stride: [1, 1], pad_lo: [1, 1], pad_hi: [1, 1] };
        let sp = spec("bf01_oi01->bf01");
        let router = OpRouter::new(2);
        // Query the mode BEFORE routing: with a cost DB attached (env
        // opt-in), routing records a sample, and a later query may flip
        // to an unexplored mode. All modes are mutually bit-identical,
        // but the serial re-check below must use the mode the routed
        // call actually ran.
        let mode = router.skip_mode(&cfg, Component::Fwd, d.sparsity());
        let out = router
            .route(&xla::ConvCall {
                window: &window,
                spec: &sp,
                lhs: &lhs,
                lhs_dims: &[cfg.n, cfg.c, cfg.h, cfg.w],
                rhs: &rhs,
                rhs_dims: &[cfg.k, cfg.c, cfg.s, cfg.r],
                out_dims: &[cfg.n, cfg.k, cfg.out_h(), cfg.out_w()],
            })
            .expect("in-envelope FWD must route");
        assert_eq!(router.routed_calls(), 1);
        let want = reference::conv_fwd(&cfg, &lhs, &rhs);
        assert!(allclose(&out, &want, 1e-4, 1e-5));

        // and it is bit-identical to the serial sparse kernel at the
        // selector's chosen mode (scheduler serial-parity, re-checked
        // through the routing path)
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        crate::kernels::sparse_fwd::fwd(&cfg, &d, &g, &mut y, mode, &mut st);
        assert_eq!(out, y.to_nchw(), "routed FWD must be bit-exact vs the serial kernel");
    }

    /// Out-of-envelope calls decline and count as fallbacks.
    #[test]
    fn miri_out_of_envelope_declines() {
        let window = Window { size: [1, 1], stride: [1, 1], pad_lo: [0, 0], pad_hi: [0, 0] };
        let sp = spec("bf01_oi01->bf01");
        let router = OpRouter::new(1);
        let lhs = vec![1.0f32; 12]; // [1,3,2,2]: C=3 is not a multiple of V
        let rhs = vec![1.0f32; 4 * 3];
        let out = router.route(&xla::ConvCall {
            window: &window,
            spec: &sp,
            lhs: &lhs,
            lhs_dims: &[1, 3, 2, 2],
            rhs: &rhs,
            rhs_dims: &[4, 3, 1, 1],
            out_dims: &[1, 4, 2, 2],
        });
        assert!(out.is_none());
        assert_eq!(router.fallback_calls(), 1);
        assert_eq!(router.routed_calls(), 0);
    }

    /// Per-instruction counters attribute routed/fallback to the HLO name,
    /// and profiled sparsity overrides the live measurement (clamped).
    #[test]
    fn miri_per_instr_counters_and_profiled_sparsity() {
        let window = Window { size: [1, 1], stride: [1, 1], pad_lo: [0, 0], pad_hi: [0, 0] };
        let sp = spec("bf01_oi01->bf01");
        let router = OpRouter::new(1);
        let lhs = vec![1.0f32; 12]; // [1,3,2,2]: C=3 declines (not a V multiple)
        let rhs = vec![1.0f32; 4 * 3];
        let call = xla::ConvCall {
            window: &window,
            spec: &sp,
            lhs: &lhs,
            lhs_dims: &[1, 3, 2, 2],
            rhs: &rhs,
            rhs_dims: &[4, 3, 1, 1],
            out_dims: &[1, 4, 2, 2],
        };
        assert!(router.route_named(&call, Some("z_stem")).is_none());
        assert!(router.route_named(&call, Some("z_stem")).is_none());
        assert_eq!(router.conv_layer_stats(), vec![("z_stem".to_string(), 0, 2)]);
        // anonymous route() calls keep the aggregate but not the breakdown
        assert!(router.route(&call).is_none());
        assert_eq!(router.fallback_calls(), 3);
        assert_eq!(router.conv_layer_stats().len(), 1);

        router.set_profiled_sparsity([("z_stem".to_string(), 2.0)]);
        assert_eq!(router.sparsity_for(Some("z_stem"), 0.3), 1.0, "clamped to [0,1]");
        assert_eq!(router.sparsity_for(Some("unprofiled"), 0.3), 0.3);
        assert_eq!(router.sparsity_for(None, 0.3), 0.3);
    }

    #[test]
    fn miri_routing_env_default_is_on() {
        // Routing defaults to enabled; only the explicit off-values disable
        // it. (The env var is process-global, so only the unset case is
        // asserted here; the off-values are covered by the match arms.)
        if std::env::var("SPARSETRAIN_CONV_ROUTE").is_err() {
            assert!(routing_enabled());
        }
    }

    #[test]
    fn miri_pipeline_env_default_is_on() {
        // Same contract as the conv/op switches: default on, explicit
        // off-values disable (covered by the match arms).
        if std::env::var("SPARSETRAIN_PIPELINE").is_err() {
            assert!(pipeline_enabled());
        }
    }

    /// `overlap_join` runs both tasks to completion (structured fork-join)
    /// and tallies exactly one pair per call, including when the caller is
    /// itself a pool worker (reentrant → both run inline).
    #[test]
    fn miri_overlap_join_runs_both_tasks_and_counts_pairs() {
        use std::sync::atomic::AtomicUsize as Counter;
        let router = Arc::new(OpRouter::new(2));
        let hits = Counter::new(0);
        router.overlap_join(
            Box::new(|| {
                hits.fetch_add(1, Ordering::Relaxed);
            }),
            Box::new(|| {
                hits.fetch_add(10, Ordering::Relaxed);
            }),
        );
        assert_eq!(hits.load(Ordering::Relaxed), 11);
        assert_eq!(router.overlap_pairs(), 1);
    }
}
