//! Kernel-routed convolution executor: the bridge between the mini-HLO
//! interpreter and the SparseTrain kernel/scheduler stack (ISSUE 5).
//!
//! The interpreter's naive single-threaded 7-loop convolution is what made
//! trainer steps cost ~0.3 s at the paper geometry while the explicit-SIMD
//! sparse kernels (PR 3) and the Miri-clean parallel scheduler (PR 1/2)
//! sat idle. [`ConvRouter`] closes that gap: installed as the vendored
//! crate's [`xla::ConvExecutor`] hook, it pattern-matches every
//! `convolution` instruction against the three SparseTrain-executable
//! forms the reference lowering (`runtime::hlo_builder`) emits and runs
//! them through [`Scheduler::run_fwd`] / [`Scheduler::run_bwi`] /
//! [`Scheduler::run_bww`] on the persistent thread pool:
//!
//! | `dim_labels` | training role | kernel entry |
//! |---|---|---|
//! | `bf01_oi01->bf01` | forward conv | `run_fwd` |
//! | `bf01_io01->bf01` (reversed filter) | input gradient (BWI) | `run_bwi` |
//! | `fb01_io01->bf01` (batch-contracting) | weight gradient (BWW) | `run_bww` |
//!
//! The thread-count-aware [`Selector`] picks the [`SkipMode`] per call
//! from the measured sparsity of the checked operand — dense layers run
//! the Dense loop, ReLU-sparse layers the Algorithm-3 mask loop — so the
//! trainer exploits exactly the dynamic sparsity the paper's Table 2
//! measures, at trainer-step granularity.
//!
//! **Fallback envelope.** Any call outside the supported envelope (labels
//! not one of the three forms, channels not multiples of `V`, asymmetric
//! padding, strided backward forms, filter too wide for the register
//! planner, …) returns `None` and the interpreter's naive loop runs —
//! bit-parity with the reference evaluator guaranteed, pinned by
//! `rust/tests/conv_route_parity.rs`. On the kernel path the results are
//! the sparse kernels' numerics: the same sums in the row-sweep order with
//! fused multiply-adds, deterministic across thread counts and backends
//! (scheduler bit-exactness), and equal to the naive evaluator within
//! tight floating-point reassociation tolerance (also pinned by the
//! parity suite).

use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::selector::Selector;
use crate::kernels::regalloc::REG_BUDGET;
use crate::kernels::{Component, ConvConfig, SkipMode};
use crate::sim::Machine;
use crate::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use crate::V;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// The three SparseTrain-executable convolution forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Form {
    /// `bf01_oi01->bf01` — a plain forward convolution.
    Fwd,
    /// `bf01_io01->bf01` — the input-gradient convolution (the graph has
    /// already reversed the filter spatially; `io` swaps its channel dims).
    Bwi,
    /// `fb01_io01->bf01` — the batch-contracting weight-gradient
    /// convolution.
    Bww,
}

/// Classify a parsed `dim_labels` spec; `None` = not a canonical form.
fn classify(spec: &xla::hlo::ConvSpec) -> Option<Form> {
    if spec.lhs_s != [2, 3] || spec.rhs_s != [2, 3] || spec.out_s != [2, 3] {
        return None;
    }
    if spec.out_b != 0 || spec.out_f != 1 {
        return None;
    }
    match (spec.lhs_b, spec.lhs_f, spec.rhs_o, spec.rhs_i) {
        (0, 1, 0, 1) => Some(Form::Fwd),
        (0, 1, 1, 0) => Some(Form::Bwi),
        (1, 0, 1, 0) => Some(Form::Bww),
        _ => None,
    }
}

/// Tiling/planner envelope shared by all three forms. `validate()` covers
/// the V-multiple channel constraint and degenerate filters; the register
/// planner additionally needs `R ≤ REG_BUDGET` so `plan_fwd`/`plan_bww`
/// always find a feasible Q.
fn cfg_in_envelope(cfg: &ConvConfig) -> bool {
    cfg.n >= 1
        && cfg.k >= V
        && cfg.c >= V
        && cfg.r <= REG_BUDGET
        && cfg.validate().is_ok()
}

/// A convolution executor over the SparseTrain kernel/scheduler stack.
///
/// Owns one [`Scheduler`] (and therefore one persistent thread pool) for
/// the lifetime of the runtime — every routed convolution reuses the same
/// parked workers — plus a thread-count-aware [`Selector`] for the
/// per-call skip-mode decision.
pub struct ConvRouter {
    sched: Scheduler,
    selector: Selector,
    /// Calls served by the kernel stack (introspection for tests/metrics).
    routed: AtomicUsize,
    /// Calls declined to the interpreter's naive loop.
    fallback: AtomicUsize,
}

impl ConvRouter {
    /// A router running `threads` workers (`0` = host parallelism).
    pub fn new(threads: usize) -> ConvRouter {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        ConvRouter {
            sched: Scheduler::new(threads),
            selector: Selector::with_threads(Machine::skylake_x(), threads),
            routed: AtomicUsize::new(0),
            fallback: AtomicUsize::new(0),
        }
    }

    pub fn threads(&self) -> usize {
        self.sched.threads()
    }

    /// Convolutions served by the kernel stack so far.
    pub fn routed_calls(&self) -> usize {
        self.routed.load(Ordering::Relaxed)
    }

    /// Convolutions declined to the naive interpreter loop so far.
    pub fn fallback_calls(&self) -> usize {
        self.fallback.load(Ordering::Relaxed)
    }

    /// Skip mode for one call: the thread-count-aware selector's combined
    /// policy at the measured operand sparsity, mapped onto the kernel's
    /// skip machinery (SparseTrain wins → Algorithm-3 mask loop, anything
    /// else → the Dense loop — still SIMD and still parallel).
    fn skip_mode(&self, cfg: &ConvConfig, comp: Component, sparsity: f64) -> SkipMode {
        self.selector.skip_mode(cfg, comp, sparsity)
    }

    /// Try to execute one interpreter convolution on the kernel stack.
    /// `None` = outside the envelope; the caller falls back to the naive
    /// loop. Never panics: every precondition of the kernels is checked
    /// here first.
    pub fn route(&self, call: &xla::ConvCall<'_>) -> Option<Vec<f32>> {
        let out = self.try_route(call);
        if out.is_some() {
            self.routed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.fallback.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    fn try_route(&self, call: &xla::ConvCall<'_>) -> Option<Vec<f32>> {
        if call.lhs_dims.len() != 4 || call.rhs_dims.len() != 4 || call.out_dims.len() != 4 {
            return None;
        }
        // The interpreter validates shapes before calling the hook, but
        // `route` is public API — never index past a malformed call.
        let n_lhs: usize = call.lhs_dims.iter().product();
        let n_rhs: usize = call.rhs_dims.iter().product();
        if call.lhs.len() != n_lhs || call.rhs.len() != n_rhs {
            return None;
        }
        let w = call.window;
        // ConvConfig models symmetric padding only; the window size must
        // be the rhs spatial extent (shape-inference invariant).
        if w.pad_lo != w.pad_hi || w.size != [call.rhs_dims[2], call.rhs_dims[3]] {
            return None;
        }
        match classify(call.spec)? {
            Form::Fwd => self.route_fwd(call),
            Form::Bwi => self.route_bwi(call),
            Form::Bww => self.route_bww(call),
        }
    }

    /// `bf01_oi01->bf01`: lhs `[N,C,H,W]`, rhs `[K,C,S,R]`, out
    /// `[N,K,H',W']` — exactly [`Scheduler::run_fwd`]'s contract after
    /// packing into the tiled layouts.
    fn route_fwd(&self, call: &xla::ConvCall<'_>) -> Option<Vec<f32>> {
        let (l, r, w) = (call.lhs_dims, call.rhs_dims, call.window);
        let cfg = ConvConfig {
            n: l[0],
            c: l[1],
            k: r[0],
            h: l[2],
            w: l[3],
            s: w.size[0],
            r: w.size[1],
            stride_p: w.stride[0],
            stride_o: w.stride[1],
            pad_h: w.pad_lo[0],
            pad_w: w.pad_lo[1],
        };
        if r[1] != cfg.c || !cfg_in_envelope(&cfg) {
            return None;
        }
        debug_assert_eq!(call.out_dims, &[cfg.n, cfg.k, cfg.out_h(), cfg.out_w()][..]);

        let d = ActTensor::from_nchw(cfg.n, cfg.c, cfg.h, cfg.w, call.lhs);
        let g = FilterTensor::from_kcsr(cfg.k, cfg.c, cfg.s, cfg.r, call.rhs);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mode = self.skip_mode(&cfg, Component::Fwd, d.sparsity());
        self.sched.run_fwd(&cfg, &d, &g, &mut y, mode);
        Some(y.to_nchw())
    }

    /// `bf01_io01->bf01` with unit stride: the input-gradient convolution.
    /// Mapped onto [`Scheduler::run_bwi`] of the *forward* layer it
    /// differentiates: lhs is ∂L/∂Y `[N,K,H',W']`, the rhs `[K,C,S,R]` is
    /// the spatially reversed forward filter with swapped channel labels,
    /// and out is ∂L/∂D `[N,C,H,W]`. Undoing the graph-side reversal while
    /// packing the BWI kernel's channel-transposed filter recovers the
    /// forward filter's taps, and the pad identity `pad_fwd = S-1-pad_conv`
    /// makes the scatter geometry line up (checked below).
    fn route_bwi(&self, call: &xla::ConvCall<'_>) -> Option<Vec<f32>> {
        let (l, r, o, w) = (call.lhs_dims, call.rhs_dims, call.out_dims, call.window);
        if w.stride != [1, 1] {
            return None; // strided BWI needs window dilation — not emitted
        }
        let (s, rr) = (w.size[0], w.size[1]);
        if w.pad_lo[0] + 1 > s || w.pad_lo[1] + 1 > rr {
            return None; // pad_fwd = S-1-pad would underflow
        }
        let cfg = ConvConfig {
            n: l[0],
            c: r[1], // conv output features = the forward layer's inputs
            k: l[1], // contracted dim = the forward layer's outputs
            h: o[2],
            w: o[3],
            s,
            r: rr,
            stride_p: 1,
            stride_o: 1,
            pad_h: s - 1 - w.pad_lo[0],
            pad_w: rr - 1 - w.pad_lo[1],
        };
        if r[0] != cfg.k || !cfg_in_envelope(&cfg) {
            return None;
        }
        // The scatter geometry must reproduce the conv's shapes exactly.
        if cfg.out_h() != l[2] || cfg.out_w() != l[3] {
            return None;
        }
        debug_assert_eq!(o, &[cfg.n, cfg.c, cfg.h, cfg.w][..]);

        let dy = ActTensor::from_nchw(cfg.n, cfg.k, l[2], l[3], call.lhs);
        // gt[c_fwd, k_fwd, s, r] = G_fwd[k_fwd, c_fwd, s, r]
        //                        = rhs[k_fwd, c_fwd, S-1-s, R-1-r].
        let mut gt = FilterTensor::zeros(cfg.c, cfg.k, cfg.s, cfg.r);
        for ki in 0..cfg.k {
            for ci in 0..cfg.c {
                for ky in 0..cfg.s {
                    for kx in 0..cfg.r {
                        let v = call.rhs[((ki * cfg.c + ci) * cfg.s + ky) * cfg.r + kx];
                        gt.set(ci, ki, cfg.s - 1 - ky, cfg.r - 1 - kx, v);
                    }
                }
            }
        }
        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mode = self.skip_mode(&cfg, Component::Bwi, dy.sparsity());
        self.sched.run_bwi(&cfg, &dy, &gt, &mut dd, mode);
        Some(dd.to_nchw())
    }

    /// `fb01_io01->bf01` with unit stride: the batch-contracting
    /// weight-gradient convolution. Both operands are plain NCHW buffers
    /// (lhs = forward activations `[N,C,H,W]` with batch relabeled as the
    /// contracted dim, rhs = ∂L/∂Z `[N,K,H',W']`), and the conv's output
    /// spatial extent is the filter tap grid — so this is exactly
    /// [`Scheduler::run_bww`] with the output transposed to `[C,K,S,R]`.
    fn route_bww(&self, call: &xla::ConvCall<'_>) -> Option<Vec<f32>> {
        let (l, r, o, w) = (call.lhs_dims, call.rhs_dims, call.out_dims, call.window);
        if w.stride != [1, 1] {
            return None; // strided-forward BWW needs rhs dilation
        }
        let cfg = ConvConfig {
            n: l[0], // contracted minibatch
            c: l[1],
            k: r[1],
            h: l[2],
            w: l[3],
            s: o[2], // conv output spatial = the weight tap grid
            r: o[3],
            stride_p: 1,
            stride_o: 1,
            pad_h: w.pad_lo[0],
            pad_w: w.pad_lo[1],
        };
        // §5.4: BWW's minibatch vectorization needs N % V == 0.
        if r[0] != cfg.n || cfg.n % V != 0 || !cfg_in_envelope(&cfg) {
            return None;
        }
        // The sweep geometry must reproduce the conv window (the rhs
        // spatial extent) exactly.
        if cfg.out_h() != w.size[0] || cfg.out_w() != w.size[1] {
            return None;
        }
        debug_assert_eq!(o, &[cfg.c, cfg.k, cfg.s, cfg.r][..]);

        let d_act = ActTensor::from_nchw(cfg.n, cfg.c, cfg.h, cfg.w, call.lhs);
        let d = BatchTiledTensor::from_act(&d_act);
        let dy = ActTensor::from_nchw(cfg.n, cfg.k, w.size[0], w.size[1], call.rhs);
        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mode = self.skip_mode(&cfg, Component::Bww, d.sparsity());
        self.sched.run_bww(&cfg, &d, &dy, &mut dg, mode);

        // Unpack dG[k,c,s,r] into the conv's [C,K,S,R] output layout.
        let mut out = vec![0.0f32; cfg.c * cfg.k * cfg.s * cfg.r];
        for ci in 0..cfg.c {
            for ki in 0..cfg.k {
                for si in 0..cfg.s {
                    for ri in 0..cfg.r {
                        out[((ci * cfg.k + ki) * cfg.s + si) * cfg.r + ri] =
                            dg.get(ki, ci, si, ri);
                    }
                }
            }
        }
        Some(out)
    }
}

/// Wrap a router as the vendored crate's hook type, ready for
/// [`xla::PjRtClient::set_conv_executor`].
pub fn hook(router: Arc<ConvRouter>) -> Arc<xla::ConvExecutor> {
    Arc::new(move |call: &xla::ConvCall<'_>| router.route(call))
}

/// `SPARSETRAIN_CONV_ROUTE=off|0` disables kernel routing process-wide
/// (the naive interpreter loop runs everywhere) — the A/B switch for
/// debugging and for the wallclock harness's naive baseline rows.
pub fn routing_enabled() -> bool {
    match std::env::var("SPARSETRAIN_CONV_ROUTE") {
        Ok(v) => !matches!(v.as_str(), "off" | "0" | "false"),
        Err(_) => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{reference, KernelStats};
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;
    use xla::hlo::{ConvSpec, Window};

    fn spec(labels: &str) -> ConvSpec {
        // reuse the vendored parser through a one-instruction module
        let text = format!(
            "HloModule s\nENTRY %m {{\n  %x = f32[1,16,4,4] parameter(0)\n  \
             %w = f32[16,16,1,1] parameter(1)\n  ROOT %y = f32[1,16,4,4] \
             convolution(%x, %w), window={{size=1x1 pad=0_0x0_0}}, dim_labels={labels}\n}}\n"
        );
        let m = xla::hlo::parse_module(&text).unwrap();
        match &m.comps[0].instrs[2].op {
            xla::hlo::Op::Convolution { spec, .. } => *spec,
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn miri_classifies_the_three_forms_and_rejects_others() {
        assert_eq!(classify(&spec("bf01_oi01->bf01")), Some(Form::Fwd));
        assert_eq!(classify(&spec("bf01_io01->bf01")), Some(Form::Bwi));
        assert_eq!(classify(&spec("fb01_io01->bf01")), Some(Form::Bww));
        for odd in ["fb01_oi01->bf01", "bf01_oi01->fb01", "b01f_oi01->bf01", "bf10_oi01->bf01"] {
            assert_eq!(classify(&spec(odd)), None, "{odd}");
        }
    }

    #[test]
    fn miri_envelope_rejects_untileable_and_wide_filters() {
        let ok = ConvConfig::square(1, V, V, 4, 3, 1);
        assert!(cfg_in_envelope(&ok));
        let mut bad_c = ok;
        bad_c.c = V + 1;
        assert!(!cfg_in_envelope(&bad_c));
        let mut wide = ConvConfig::square(1, V, V, 64, 3, 1);
        wide.r = REG_BUDGET + 1;
        wide.pad_w = 0;
        assert!(!cfg_in_envelope(&wide));
    }

    /// FWD routing matches the scalar reference and reports itself routed.
    #[test]
    #[cfg_attr(miri, ignore = "full kernel launch is too slow under miri")]
    fn routed_fwd_matches_reference() {
        let cfg = ConvConfig::square(2, 16, 32, 6, 3, 1);
        let mut rng = Xorshift::new(9);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, 0.5);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let (lhs, rhs) = (d.to_nchw(), g.to_kcsr());

        let window = Window { size: [3, 3], stride: [1, 1], pad_lo: [1, 1], pad_hi: [1, 1] };
        let sp = spec("bf01_oi01->bf01");
        let router = ConvRouter::new(2);
        let out = router
            .route(&xla::ConvCall {
                window: &window,
                spec: &sp,
                lhs: &lhs,
                lhs_dims: &[cfg.n, cfg.c, cfg.h, cfg.w],
                rhs: &rhs,
                rhs_dims: &[cfg.k, cfg.c, cfg.s, cfg.r],
                out_dims: &[cfg.n, cfg.k, cfg.out_h(), cfg.out_w()],
            })
            .expect("in-envelope FWD must route");
        assert_eq!(router.routed_calls(), 1);
        let want = reference::conv_fwd(&cfg, &lhs, &rhs);
        assert!(allclose(&out, &want, 1e-4, 1e-5));

        // and it is bit-identical to the serial sparse kernel at the
        // selector's chosen mode (scheduler serial-parity, re-checked
        // through the routing path)
        let mode = router.skip_mode(&cfg, Component::Fwd, d.sparsity());
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st = KernelStats::new();
        crate::kernels::sparse_fwd::fwd(&cfg, &d, &g, &mut y, mode, &mut st);
        assert_eq!(out, y.to_nchw(), "routed FWD must be bit-exact vs the serial kernel");
    }

    /// Out-of-envelope calls decline and count as fallbacks.
    #[test]
    fn miri_out_of_envelope_declines() {
        let window = Window { size: [1, 1], stride: [1, 1], pad_lo: [0, 0], pad_hi: [0, 0] };
        let sp = spec("bf01_oi01->bf01");
        let router = ConvRouter::new(1);
        let lhs = vec![1.0f32; 12]; // [1,3,2,2]: C=3 is not a multiple of V
        let rhs = vec![1.0f32; 4 * 3];
        let out = router.route(&xla::ConvCall {
            window: &window,
            spec: &sp,
            lhs: &lhs,
            lhs_dims: &[1, 3, 2, 2],
            rhs: &rhs,
            rhs_dims: &[4, 3, 1, 1],
            out_dims: &[1, 4, 2, 2],
        });
        assert!(out.is_none());
        assert_eq!(router.fallback_calls(), 1);
        assert_eq!(router.routed_calls(), 0);
    }

    #[test]
    fn miri_routing_env_default_is_on() {
        // Routing defaults to enabled; only the explicit off-values disable
        // it. (The env var is process-global, so only the unset case is
        // asserted here; the off-values are covered by the match arms.)
        if std::env::var("SPARSETRAIN_CONV_ROUTE").is_err() {
            assert!(routing_enabled());
        }
    }
}
