//! Thin wrapper over the `xla` crate: PJRT CPU client + compiled
//! executables keyed by artifact name.
//!
//! By default the client is built with the whole-graph op router installed
//! ([`super::executor::OpRouter`]): convolutions run through the sparse
//! kernels, `dot` through the blocked parallel GEMM, and recognized
//! elementwise chains as fused single passes — all on the
//! persistent-thread-pool scheduler instead of the interpreter's naive
//! evaluator. `SPARSETRAIN_CONV_ROUTE=off` / `SPARSETRAIN_OP_ROUTE=off`
//! (or [`Runtime::cpu_naive`]) restore the all-interpreter behavior — the
//! A/B levers the parity tests and the trainer-step wallclock rows use.
//!
//! When the router is installed with at least two workers, the client
//! additionally gets the ISSUE 10 pipeline planner
//! ([`crate::coordinator::pipeline`]): executables compiled by this
//! runtime evaluate through the dependency-scheduled executor, which
//! co-schedules cost-gated independent instruction pairs (BWI‖BWW) on
//! the router's pool — bit-identical to sequential evaluation.
//! `SPARSETRAIN_PIPELINE=off` (or the explicit override on
//! [`Runtime::cpu_with_options`]) keeps evaluation strictly sequential.

use super::executor::{self, OpRouter};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with the given input literals; returns the flattened tuple
    /// outputs (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let first = outs
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .context("no output buffers")?;
        let lit = first.to_literal_sync().context("device→host transfer")?;
        Ok(lit.to_tuple().context("untupling outputs")?)
    }
}

/// PJRT runtime bound to an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, usize>,
    loaded: Vec<Executable>,
    router: Option<Arc<OpRouter>>,
    /// Whether the pipeline planner was installed on the client (so
    /// executables compiled by this runtime evaluate through the DAG
    /// executor) — surfaced to the CLI's `pipeline:` report line.
    pipelined: bool,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`, with the
    /// whole-graph op router sized to the host parallelism (unless both
    /// `SPARSETRAIN_CONV_ROUTE=off` and `SPARSETRAIN_OP_ROUTE=off`).
    pub fn cpu<P: AsRef<Path>>(artifacts_dir: P) -> Result<Runtime> {
        Self::cpu_with_threads(artifacts_dir, 0)
    }

    /// [`Runtime::cpu`] with an explicit scheduler width (`0` = host
    /// parallelism). The router — and with it one persistent thread pool —
    /// lives as long as the runtime. It is installed when either routing
    /// class is enabled; the per-class kill switches are honored inside
    /// [`OpRouter::route_op`].
    pub fn cpu_with_threads<P: AsRef<Path>>(artifacts_dir: P, threads: usize) -> Result<Runtime> {
        Self::cpu_with_router(artifacts_dir, || OpRouter::new(threads), None)
    }

    /// [`Runtime::cpu_with_threads`] with an explicit cost database
    /// (`None` pins the analytic selector) instead of the
    /// `SPARSETRAIN_COST_DB` env default — the lever the wallclock bench
    /// uses to put analytic and measured selector rows side by side in one
    /// process.
    pub fn cpu_with_cost_db<P: AsRef<Path>>(
        artifacts_dir: P,
        threads: usize,
        cost_db: Option<Arc<crate::coordinator::CostDb>>,
    ) -> Result<Runtime> {
        Self::cpu_with_options(artifacts_dir, threads, cost_db, None)
    }

    /// The fully explicit constructor: scheduler width, cost DB, and the
    /// pipeline override. `pipeline: None` reads `SPARSETRAIN_PIPELINE`
    /// (default on); `Some(b)` pins it regardless of environment — the
    /// race-free lever the parity tests and the wallclock bench use to
    /// put pipelined and sequential rows side by side in one process.
    pub fn cpu_with_options<P: AsRef<Path>>(
        artifacts_dir: P,
        threads: usize,
        cost_db: Option<Arc<crate::coordinator::CostDb>>,
        pipeline: Option<bool>,
    ) -> Result<Runtime> {
        Self::cpu_with_router(artifacts_dir, || OpRouter::with_cost_db(threads, cost_db), pipeline)
    }

    fn cpu_with_router<P: AsRef<Path>>(
        artifacts_dir: P,
        make: impl FnOnce() -> OpRouter,
        pipeline: Option<bool>,
    ) -> Result<Runtime> {
        let mut client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let router = if executor::routing_enabled() || executor::op_routing_enabled() {
            let router = Arc::new(make());
            client.set_op_executor(executor::hook(Arc::clone(&router)));
            Some(router)
        } else {
            None
        };
        // The DAG executor needs a second worker to overlap onto and the
        // router's pool to join on; otherwise sequential evaluation is
        // both simpler and faster.
        let mut pipelined = false;
        if let Some(router) = &router {
            if pipeline.unwrap_or_else(executor::pipeline_enabled) && router.threads() >= 2 {
                client.set_pipeline_planner(crate::coordinator::pipeline::planner(router));
                pipelined = true;
            }
        }
        Ok(Runtime {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
            loaded: Vec::new(),
            router,
            pipelined,
        })
    }

    /// A runtime with **no** routing at all: every instruction runs the
    /// interpreter's naive reference evaluator. Baseline for parity tests
    /// and the `trainer_step` wallclock rows.
    pub fn cpu_naive<P: AsRef<Path>>(artifacts_dir: P) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
            loaded: Vec::new(),
            router: None,
            pipelined: false,
        })
    }

    /// The installed op router, if any (for introspection: per-op-kind
    /// routed/fallback/fused call counts, thread width).
    pub fn op_router(&self) -> Option<&OpRouter> {
        self.router.as_deref()
    }

    /// Whether executables compiled by this runtime evaluate through the
    /// dependency-scheduled (pipelined) executor.
    pub fn pipelined(&self) -> bool {
        self.pipelined
    }

    /// A clonable handle to the installed op router. The trainer grabs
    /// this *before* [`Runtime::load`] (whose returned `&Executable`
    /// borrows the runtime exclusively) so it can feed profiled sparsity
    /// into the router from inside the step loop.
    pub fn op_router_arc(&self) -> Option<Arc<OpRouter>> {
        self.router.clone()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// A previously [`Runtime::load`]ed executable, by name, through a
    /// shared borrow — the serve dispatch path preloads its whole batch
    /// ladder once, then looks rungs up here per request without taking
    /// `&mut self`.
    pub fn get(&self, name: &str) -> Option<&Executable> {
        self.cache.get(name).map(|&idx| &self.loaded[idx])
    }

    /// Load and compile `<name>.hlo.txt` (cached per runtime).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if let Some(&idx) = self.cache.get(name) {
            return Ok(&self.loaded[idx]);
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.loaded.push(Executable { exe, name: name.to_string() });
        self.cache.insert(name.to_string(), self.loaded.len() - 1);
        Ok(self.loaded.last().unwrap())
    }
}

/// Build an f32 literal of the given shape from a host buffer.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/product mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/product mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu("artifacts").unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        // default runtime carries an op router (unless env-disabled)
        if super::executor::routing_enabled() || super::executor::op_routing_enabled() {
            assert!(rt.op_router().is_some());
            assert!(rt.op_router().unwrap().threads() >= 1);
        }
        assert!(Runtime::cpu_naive("artifacts").unwrap().op_router().is_none());
        assert!(!Runtime::cpu_naive("artifacts").unwrap().pipelined());
    }

    #[test]
    fn pipeline_override_beats_environment() {
        // Explicit off: never pipelined, whatever the env says.
        let off = Runtime::cpu_with_options("artifacts", 2, None, Some(false)).unwrap();
        assert!(!off.pipelined());
        // Explicit on at 2 threads: pipelined iff a router is installed
        // (route kill switches can remove it process-wide).
        let on = Runtime::cpu_with_options("artifacts", 2, None, Some(true)).unwrap();
        assert_eq!(on.pipelined(), on.op_router().is_some());
        // One thread: nothing to overlap onto, even when forced on.
        let single = Runtime::cpu_with_options("artifacts", 1, None, Some(true)).unwrap();
        assert!(!single.pipelined());
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let mut rt = Runtime::cpu("artifacts").unwrap();
        let msg = match rt.load("definitely_not_there") {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("expected error"),
        };
        assert!(msg.contains("definitely_not_there"), "{msg}");
    }
}
