//! PJRT runtime: loads AOT-compiled JAX/Pallas artifacts (HLO **text**, see
//! DESIGN.md §2 and /opt/xla-example/README.md) and executes them on the
//! CPU PJRT client. Python never runs on this path — `make artifacts`
//! produces the `.hlo.txt` files once at build time.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::ArtifactSet;
pub use pjrt::{Executable, Runtime};
