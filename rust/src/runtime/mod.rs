//! PJRT runtime: loads AOT-compiled train-step artifacts (HLO **text**)
//! and executes them on the CPU PJRT client. Python never runs on this
//! path — on a cold checkout the Rust-side reference emitter
//! ([`hlo_builder`]) materializes the artifacts and the vendored `xla`
//! crate's mini-HLO interpreter compiles and executes the text offline.
//! Files already present (e.g. from `make artifacts`) take precedence and
//! are never overwritten, but the offline interpreter only understands
//! the reference HLO grammar/op subset — arbitrary XLA text dumps need
//! the real `xla` crate linked in. Set `SPARSETRAIN_ARTIFACTS` to point
//! the runtime at a different artifacts directory.
//!
//! **Kernel-routed convolutions (ISSUE 5).** The interpreter is no longer
//! a naive-only evaluator on this path: [`executor::ConvRouter`] plugs
//! into the vendored crate's convolution hook and dispatches the three
//! SparseTrain-executable conv forms (FWD / BWI / BWW, as emitted by
//! [`hlo_builder`]) to the explicit-SIMD sparse kernels running on the
//! persistent-thread-pool scheduler, with the thread-count-aware selector
//! picking the skip mode from the measured operand sparsity. Anything
//! outside the envelope falls back to the naive loop bit-identically.
//! `SPARSETRAIN_CONV_ROUTE=off` disables routing process-wide.

pub mod artifacts;
pub mod executor;
pub mod hlo_builder;
pub mod pjrt;

pub use artifacts::ArtifactSet;
pub use executor::ConvRouter;
pub use pjrt::{Executable, Runtime};
