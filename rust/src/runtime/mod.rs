//! PJRT runtime: loads AOT-compiled train-step artifacts (HLO **text**)
//! and executes them on the CPU PJRT client. Python never runs on this
//! path — on a cold checkout the Rust-side reference emitter
//! ([`hlo_builder`]) materializes the artifacts and the vendored `xla`
//! crate's mini-HLO interpreter compiles and executes the text offline.
//! Files already present (e.g. from `make artifacts`) take precedence and
//! are never overwritten, but the offline interpreter only understands
//! the reference HLO grammar/op subset — arbitrary XLA text dumps need
//! the real `xla` crate linked in. Set `SPARSETRAIN_ARTIFACTS` to point
//! the runtime at a different artifacts directory.
//!
//! **Whole-graph op routing (ISSUE 6, generalizing ISSUE 5's conv-only
//! hook).** The interpreter is no longer a naive-only evaluator on this
//! path: [`executor::OpRouter`] plugs into the vendored crate's
//! per-instruction [`xla::OpExecutor`] hook and serves three op classes:
//!
//! - **Convolutions** — the three SparseTrain-executable forms
//!   (FWD / BWI / BWW, as emitted by [`hlo_builder`]) dispatch to the
//!   explicit-SIMD sparse kernels on the persistent-thread-pool
//!   scheduler, with the thread-count-aware selector picking the skip
//!   mode from measured operand sparsity.
//! - **`dot`** — rank-2 × rank-2 f32 contractions run the blocked,
//!   SIMD-dispatched GEMM ([`crate::kernels::gemm`]), panel-parallel on
//!   the same pool once the output exceeds one row panel.
//! - **Elementwise chains** — recognized patterns (scalar-splat
//!   binaries, bias-style vector broadcasts, SGD `w - lr*g`, fused
//!   compare+select ReLU masks, common broadcast/reduce shapes) collapse
//!   into single fused passes, bit-identical to the unfused evaluator.
//!
//! *Buffer ownership*: the evaluator owns allocation. It hands the hook
//! an arena-recycled output buffer of exactly the declared element
//! count; the hook either fills it completely and returns `true`, or
//! returns `false` untouched and the arena reclaims it.
//!
//! *Fallback contract*: anything outside the envelope — non-f32 dots,
//! unrecognized chains, odd ranks — declines and runs the interpreter's
//! naive reference loop **bit-identically** (proven by
//! `rust/tests/op_route_parity.rs` and `conv_route_parity.rs`).
//!
//! Kill switches: `SPARSETRAIN_CONV_ROUTE=off` disables conv routing;
//! `SPARSETRAIN_OP_ROUTE=off` disables dot routing and fusion. Either
//! alone leaves the other class active; both together restore the
//! all-naive interpreter.
//!
//! **Per-net graphs (ISSUE 7).** [`hlo_builder`] also emits full
//! multi-layer train/predict modules for any `nets::zoo` inventory
//! (`train_step_<net>_<scale>` artifacts, published through
//! [`artifacts::ArtifactSet::publish_fallback_text`]). For those runs the
//! router additionally keeps **per-conv-instruction** routed/fallback
//! counters ([`executor::OpRouter::conv_layer_stats`]) so a downsample
//! conv silently dropping to the naive loop is visible, and accepts
//! **trainer-fed measured sparsity**
//! ([`executor::OpRouter::set_profiled_sparsity`]): the trainer pushes
//! each layer's recent-mean profiled sparsity before every step, and the
//! selector plans skip modes from that signal instead of the per-call
//! live zero count.
//!
//! **Measured-cost autotuning (ISSUE 8).** The router attaches a
//! persistent per-machine cost database ([`crate::coordinator::CostDb`],
//! `COSTDB_kernels.json` next to the bench baselines): every routed conv
//! and GEMM is timed with monotonic-clock stamps and folded into an EMA
//! keyed by (component, geometry, sparsity bucket, threads, SIMD
//! backend, mode), and the selector consults those measurements before
//! its analytic model — cold keys fall back to the analytic answer, so a
//! missing or corrupt DB only costs speed, never correctness (all skip
//! modes are mutually bit-identical). `SPARSETRAIN_COST_DB=off` detaches
//! the DB entirely; `=fresh` ignores any on-disk file;
//! `SPARSETRAIN_COST_DB_PATH` relocates it. The scheduler independently
//! feeds each sweep's per-chunk wall times into its chunk tuner so
//! imbalanced geometries split finer on the next call. New in the same
//! PR:
//! unary (`exponential`/`log`/`negate`) and `convert`-to-f32 (including
//! a fused `convert(iota)` index fill) route as parallel elementwise
//! passes, bit-identical to the naive evaluator.
//!
//! **Serving (ISSUE 9).** [`crate::coordinator::serve`] layers a batched
//! inference front end on this runtime: one `Runtime` per server preloads
//! a ladder of batch-size-specialized `predict_serve_b<N>` artifacts
//! (emitted by [`hlo_builder::predict_hlo`] at `Geometry { n: N, .. }`),
//! and dispatch-time lookups go through the shared-borrow
//! [`pjrt::Runtime::get`] so the hot path never re-loads. Because every
//! routed op above is per-sample independent, a zero-padded batch is
//! bit-identical per sample to sequential single-sample execution —
//! `rust/tests/serve.rs` pins that on both the routed and the all-naive
//! path.
//!
//! **Dependency-scheduled execution (ISSUE 10).** The interpreter no
//! longer walks each computation strictly in SSA order: when a
//! [`xla::PipelinePlanner`] is installed (the default at ≥ 2 threads),
//! the evaluator builds a data-dependency DAG over the instruction list
//! and may run two *ready, independent* instructions concurrently —
//! in practice the backward pass's BWI of layer *l* alongside BWW of
//! layer *l+1*, the overlap the paper's dataflow exposes. The planner
//! halves live here: [`crate::coordinator::pipeline`] gates each
//! candidate pair on measured costs (co-schedule only when the first
//! op's scaling under-fills the pool) and joins the pair on the router's
//! persistent pool ([`executor::OpRouter::overlap_join`]). *Buffer
//! ownership under overlap*: the two concurrent ops draw scratch from
//! disjoint arenas (main + spare, re-merged on retire) and each fully
//! owns its output slot, so results are **bit-identical** to sequential
//! evaluation at any thread count — pinned by
//! `rust/tests/pipeline_route_parity.rs`. `SPARSETRAIN_PIPELINE=off`
//! (third kill switch in the family) restores strictly sequential
//! evaluation; the `train` CLI prints the overlap-pair counter and the
//! pool-utilization EMA so a pipeline that never fires is visible.

pub mod artifacts;
pub mod executor;
pub mod hlo_builder;
pub mod pjrt;

pub use artifacts::ArtifactSet;
pub use executor::{OpRouter, RouteStats};
pub use pjrt::{Executable, Runtime};
