//! PJRT runtime: loads AOT-compiled train-step artifacts (HLO **text**)
//! and executes them on the CPU PJRT client. Python never runs on this
//! path — on a cold checkout the Rust-side reference emitter
//! ([`hlo_builder`]) materializes the artifacts and the vendored `xla`
//! crate's mini-HLO interpreter compiles and executes the text offline.
//! Files already present (e.g. from `make artifacts`) take precedence and
//! are never overwritten, but the offline interpreter only understands
//! the reference HLO grammar/op subset — arbitrary XLA text dumps need
//! the real `xla` crate linked in. Set `SPARSETRAIN_ARTIFACTS` to point
//! the runtime at a different artifacts directory.

pub mod artifacts;
pub mod hlo_builder;
pub mod pjrt;

pub use artifacts::ArtifactSet;
pub use pjrt::{Executable, Runtime};
