//! Batched sparse-inference serving front end (ISSUE 9).
//!
//! FWD-only serving is the sparsity story's best case: the backward triad
//! never runs, so the routed forward kernels' ReLU-skip wins land on every
//! request (the Shi & Chu direction, arXiv 1704.07724). This module turns
//! the routed predict artifact into a latency-bounded batch server:
//! single-sample requests coalesce into batches under a **size/deadline
//! policy**, run on the existing persistent-thread-pool `Scheduler` via
//! the [`crate::runtime::executor::OpRouter`] (the kernels are already
//! batch-parallel over `(i, oy, qb)` row tasks), and a bounded queue sheds
//! load with an explicit [`ServeReply::Rejected`] once depth exceeds the
//! configured limit.
//!
//! ## Determinism contract (the virtual clock)
//!
//! Async batching logic is notoriously timing-flaky to test, so every
//! coalescing decision here is driven by an injected [`Clock`] — a plain
//! `now() -> Nanos` source — never by `Instant::now()` or `sleep` inside
//! the decision logic:
//!
//! * [`MonotonicClock`] wraps `Instant` for production;
//! * [`VirtualClock`] is a manually-advanced atomic counter for tests.
//!
//! The layering makes the contract checkable:
//!
//! 1. [`Batcher`] is a **pure state machine**: every method takes an
//!    explicit `now` and performs no IO, no clock reads, no threads. Given
//!    the same (push, pop) call sequence with the same timestamps it makes
//!    bit-identical decisions — the property suite replays randomized
//!    arrival schedules on it directly.
//! 2. [`ServeSession`] binds a `Batcher` to a [`Clock`] and a
//!    [`BatchExecutor`], still **single-threaded and inline**: `submit` /
//!    `tick` / `shutdown` observe the clock once per call and run any due
//!    batch on the caller's thread. Tests drive it with a [`VirtualClock`]
//!    and zero sleeps; every decision is deterministically replayable.
//! 3. [`Server`] is the production shell: one service thread owning a
//!    `ServeSession`, fed by an `mpsc` channel, waking on
//!    `recv_timeout(next deadline)`. All timing still flows through the
//!    shared `Clock`, so an open-loop load generator
//!    ([`crate::bench::loadgen`]) measures latency on the same timebase
//!    the server batches on.
//!
//! ## Batch-size policy
//!
//! [`PredictExecutor`] compiles a **ladder** of predict artifacts (batch
//! sizes `1, 2, 4, …, max_batch`, each a [`Geometry`]-specialized
//! `predict` module — shapes are AOT, so one artifact per batch size) and
//! pads a partial batch up to the nearest rung with zero samples. Because
//! every routed op (conv row sweeps, per-row GEMM, reduce, elementwise) is
//! per-sample independent, padded and sequential execution are
//! **bit-identical** per sample — pinned by `rust/tests/serve.rs` — so
//! padding and batching can never change an answer, only its latency.
//!
//! Rung selection consults the PR 8 measured-cost DB when warm: the
//! planned batch size is the rung minimizing measured FWD ns/sample for
//! the two predict convolutions, falling back to the static `max_batch`
//! policy while any rung is cold or when the DB is detached
//! (`SPARSETRAIN_COST_DB=off`) — the same kill-switch discipline as the
//! skip-mode selector, and the same guarantee: a missing DB costs only
//! speed, never correctness.
//!
//! ## Deadline policy (ISSUE 10)
//!
//! The coalescing deadline is planned the same way the batch size is:
//! [`BatchExecutor::planned_delay_ns`] re-plans `max_delay_ns` on every
//! arrival. [`PredictExecutor`] derives it from the measured full-cap
//! FWD service time when the DB is warm — waiting much longer than a
//! few batch-execution times can only add latency, never throughput —
//! clamped so it never exceeds the configured static deadline and never
//! collapses below [`MIN_PLANNED_DELAY_NS`]. Cold or detached DB keeps
//! the static deadline, so `SPARSETRAIN_COST_DB=off` pins both policies
//! at once.

use crate::coordinator::costdb::{geom_sig, DbComponent};
use crate::kernels::ConvConfig;
use crate::runtime::artifacts::ArtifactSet;
use crate::runtime::hlo_builder::{self, Geometry};
use crate::runtime::pjrt::{literal_f32, Runtime};
use crate::util::prng::Xorshift;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server-relative timestamp in nanoseconds (origin = clock creation).
pub type Nanos = u64;

/// The server's only time source. `Send + Sync` so one clock can be
/// shared between the service thread and load generators — latency is
/// then measured on the exact timebase batching decisions were made on.
pub trait Clock: Send + Sync {
    fn now(&self) -> Nanos;
}

/// Production clock: nanoseconds since construction, via `Instant`.
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> Nanos {
        self.origin.elapsed().as_nanos() as Nanos
    }
}

/// Manually-advanced test clock. Time moves only when a test calls
/// [`VirtualClock::advance`] / [`VirtualClock::set`], so every deadline
/// decision in a test is an exact, replayable function of the script —
/// no sleeps, no flake. Shared via `Arc` between the test and (in the
/// executor-service-time pattern) the [`BatchExecutor`] itself.
#[derive(Debug, Default)]
pub struct VirtualClock {
    t: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { t: AtomicU64::new(0) }
    }

    /// Advance by `d` and return the new now.
    pub fn advance(&self, d: Nanos) -> Nanos {
        self.t.fetch_add(d, Ordering::SeqCst) + d
    }

    /// Jump to an absolute instant (tests must keep this monotonic).
    pub fn set(&self, t: Nanos) {
        self.t.store(t, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Nanos {
        self.t.load(Ordering::SeqCst)
    }
}

/// Batching/shedding policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Hard cap on coalesced batch size (also the top ladder rung).
    pub max_batch: usize,
    /// A batch closes when its **oldest** member has waited this long,
    /// even if under-full.
    pub max_delay_ns: Nanos,
    /// Bounded-queue shed limit: a request arriving while this many are
    /// already queued is rejected, never silently dropped.
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 8, max_delay_ns: 2_000_000, queue_depth: 64 }
    }
}

/// The pure size/deadline coalescing state machine. No clock, no IO:
/// every method takes an explicit `now`, which is what makes batching
/// decisions deterministically replayable (see the module docs).
pub struct Batcher<T> {
    max_batch: usize,
    max_delay_ns: Nanos,
    queue_depth: usize,
    /// Current coalescing target in `1..=max_batch` (the measured-cost
    /// policy may plan below the cap; see [`BatchExecutor::planned_batch`]).
    target: usize,
    queue: VecDeque<(Nanos, T)>,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_delay_ns: Nanos, queue_depth: usize) -> Batcher<T> {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        assert!(queue_depth >= 1, "queue_depth must be >= 1");
        Batcher { max_batch, max_delay_ns, queue_depth, target: max_batch, queue: VecDeque::new() }
    }

    pub fn target(&self) -> usize {
        self.target
    }

    /// Re-plan the coalescing target (clamped into `1..=max_batch`).
    pub fn set_target(&mut self, t: usize) {
        self.target = t.clamp(1, self.max_batch);
    }

    pub fn max_delay_ns(&self) -> Nanos {
        self.max_delay_ns
    }

    /// Re-plan the deadline-close window — the measured-cost deadline
    /// policy hook ([`BatchExecutor::planned_delay_ns`]). Applies to the
    /// queue head immediately: deadlines are computed from enqueue stamps
    /// on every query, not cached.
    pub fn set_max_delay(&mut self, d: Nanos) {
        self.max_delay_ns = d;
    }

    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue one item stamped `now`; `Err(item)` = shed (queue already
    /// at `queue_depth`).
    #[allow(clippy::result_large_err)] // Err carries the item back by design
    pub fn push(&mut self, item: T, now: Nanos) -> std::result::Result<(), T> {
        if self.queue.len() >= self.queue_depth {
            return Err(item);
        }
        self.queue.push_back((now, item));
        Ok(())
    }

    /// Pop the next due batch, FIFO, at most `target` items. A batch is
    /// due when the queue reached the target size ("size-closed") or the
    /// oldest member's age reached `max_delay_ns` ("deadline-closed" — at
    /// exactly the deadline tick, `now >= enqueued + max_delay`). `None`
    /// when nothing is due; callers loop until then.
    pub fn pop_ready(&mut self, now: Nanos) -> Option<Vec<(Nanos, T)>> {
        let (t0, _) = self.queue.front()?;
        let due = self.queue.len() >= self.target || now >= t0 + self.max_delay_ns;
        if !due {
            return None;
        }
        let n = self.queue.len().min(self.target);
        Some(self.queue.drain(..n).collect())
    }

    /// The instant the current queue head deadline-closes (`None` when
    /// empty). The threaded server sleeps exactly until this.
    pub fn next_deadline(&self) -> Option<Nanos> {
        self.queue.front().map(|&(t0, _)| t0 + self.max_delay_ns)
    }

    /// Flush everything immediately in FIFO batches of at most `target`
    /// items — the drained-shutdown path: zero accepted requests are lost.
    pub fn drain_all(&mut self) -> Vec<Vec<(Nanos, T)>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len().min(self.target);
            out.push(self.queue.drain(..n).collect());
        }
        out
    }
}

/// One completed prediction.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    /// Server-assigned submission id (FIFO order witness).
    pub id: u64,
    /// Logits for this sample (`classes` floats).
    pub output: Vec<f32>,
    /// Clock reading when the server enqueued the request.
    pub enqueued_at: Nanos,
    /// Clock reading when its batch finished executing.
    pub completed_at: Nanos,
    /// Size of the coalesced batch it rode in.
    pub batch_size: usize,
}

/// What a client's reply channel receives — exactly one per request.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeReply {
    Done(Prediction),
    /// Bounded-queue shed: depth was at the configured limit on arrival.
    Rejected { id: u64, depth: usize },
}

/// Runs one coalesced batch. `inputs[i]` is one sample (NCHW, flattened);
/// the result must hold exactly one output per input, in order.
pub trait BatchExecutor {
    fn run_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// The coalescing target to plan for, given the configured cap — the
    /// measured-cost policy hook. Defaults to the static policy (the cap).
    fn planned_batch(&self, max_batch: usize) -> usize {
        max_batch
    }

    /// The deadline-close window to plan for, given the configured static
    /// deadline — the measured-cost latency policy hook. Defaults to the
    /// static policy (the configured deadline, unchanged).
    fn planned_delay_ns(&self, static_delay_ns: Nanos) -> Nanos {
        static_delay_ns
    }

    /// Which policy drives [`BatchExecutor::planned_batch`] right now —
    /// `"static"` or `"measured"` — recorded in serve bench rows.
    fn policy(&self) -> &'static str {
        "static"
    }
}

/// Counters + batch-size observations for one session's lifetime.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// One entry per executed batch, in execution order.
    pub batch_sizes: Vec<usize>,
}

impl ServeStats {
    /// `(batch size, batches executed)` ascending by size.
    pub fn batch_hist(&self) -> Vec<(usize, usize)> {
        let mut hist = std::collections::BTreeMap::new();
        for &b in &self.batch_sizes {
            *hist.entry(b).or_insert(0usize) += 1;
        }
        hist.into_iter().collect()
    }
}

struct Pending {
    id: u64,
    input: Vec<f32>,
    reply: Sender<ServeReply>,
}

/// Deterministic single-threaded serving core: a [`Batcher`] bound to a
/// [`Clock`] and a [`BatchExecutor`]. All batch execution happens inline
/// on the caller's thread inside `submit`/`tick`/`shutdown`; the clock is
/// read once per call. Drive it with a [`VirtualClock`] for exact tests,
/// or let [`Server`] wrap it in a service thread for production.
pub struct ServeSession<E: BatchExecutor> {
    cfg: ServeConfig,
    clock: Arc<dyn Clock>,
    exec: E,
    batcher: Batcher<Pending>,
    next_id: u64,
    stats: ServeStats,
}

impl<E: BatchExecutor> ServeSession<E> {
    pub fn new(cfg: ServeConfig, clock: Arc<dyn Clock>, exec: E) -> ServeSession<E> {
        let batcher = Batcher::new(cfg.max_batch, cfg.max_delay_ns, cfg.queue_depth);
        ServeSession { cfg, clock, exec, batcher, next_id: 0, stats: ServeStats::default() }
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    pub fn depth(&self) -> usize {
        self.batcher.depth()
    }

    pub fn next_deadline(&self) -> Option<Nanos> {
        self.batcher.next_deadline()
    }

    pub fn executor(&self) -> &E {
        &self.exec
    }

    /// Accept (or shed) one request, then run every batch that is due at
    /// the current clock reading. Returns the assigned request id; a shed
    /// request still gets an id (echoed in its [`ServeReply::Rejected`]).
    /// `Err` means the executor failed — the server is broken, not the
    /// request.
    pub fn submit(&mut self, input: Vec<f32>, reply: Sender<ServeReply>) -> Result<u64> {
        let now = self.clock.now();
        let id = self.next_id;
        self.next_id += 1;
        // Re-plan the coalescing target and deadline on every arrival:
        // both measured policies tighten as the cost DB warms.
        let planned = self.exec.planned_batch(self.cfg.max_batch);
        self.batcher.set_target(planned);
        self.batcher.set_max_delay(self.exec.planned_delay_ns(self.cfg.max_delay_ns));
        match self.batcher.push(Pending { id, input, reply }, now) {
            Ok(()) => {
                self.stats.accepted += 1;
            }
            Err(p) => {
                self.stats.rejected += 1;
                let _ = p.reply.send(ServeReply::Rejected { id, depth: self.batcher.depth() });
            }
        }
        self.run_ready(now)?;
        Ok(id)
    }

    /// Run every batch due at the current clock reading (the deadline
    /// path; the threaded server calls this when its deadline wait fires).
    pub fn tick(&mut self) -> Result<()> {
        let now = self.clock.now();
        self.run_ready(now)
    }

    /// Flush all queued requests (in FIFO batches of at most the planned
    /// size) and return the stats. No accepted request is ever dropped.
    pub fn shutdown(mut self) -> Result<ServeStats> {
        let now = self.clock.now();
        self.run_ready(now)?;
        for batch in self.batcher.drain_all() {
            self.execute(batch)?;
        }
        Ok(self.stats)
    }

    fn run_ready(&mut self, now: Nanos) -> Result<()> {
        while let Some(batch) = self.batcher.pop_ready(now) {
            self.execute(batch)?;
        }
        Ok(())
    }

    fn execute(&mut self, batch: Vec<(Nanos, Pending)>) -> Result<()> {
        let bsz = batch.len();
        let (metas, inputs): (Vec<_>, Vec<_>) =
            batch.into_iter().map(|(t, p)| ((t, p.id, p.reply), p.input)).unzip();
        let outputs = self.exec.run_batch(&inputs)?;
        anyhow::ensure!(
            outputs.len() == bsz,
            "executor returned {} outputs for a batch of {bsz}",
            outputs.len()
        );
        let completed_at = self.clock.now();
        self.stats.batch_sizes.push(bsz);
        self.stats.completed += bsz as u64;
        for ((enqueued_at, id, reply), output) in metas.into_iter().zip(outputs) {
            // A gone client (dropped receiver) is not a server error.
            let _ = reply.send(ServeReply::Done(Prediction {
                id,
                output,
                enqueued_at,
                completed_at,
                batch_size: bsz,
            }));
        }
        Ok(())
    }
}

/// One queued request for the threaded [`Server`].
pub struct ServeRequest {
    /// One sample, NCHW flattened (`c_in * hw * hw` floats).
    pub input: Vec<f32>,
    /// Where the single [`ServeReply`] for this request goes.
    pub reply: Sender<ServeReply>,
}

enum Incoming {
    Req(ServeRequest),
    DeadlineFired,
    Closed,
}

/// Production shell: a service thread owning a [`ServeSession`], fed by
/// an `mpsc` channel. The thread sleeps in `recv_timeout` until either a
/// request arrives or the queue head's deadline fires — there is no
/// polling loop. Dropping every [`Server::handle`] clone and calling
/// [`Server::shutdown`] drains the queue (zero accepted requests lost)
/// and returns the stats.
pub struct Server {
    tx: Option<Sender<ServeRequest>>,
    join: Option<std::thread::JoinHandle<Result<ServeStats>>>,
}

impl Server {
    /// Spawn the service thread. `make_exec` runs **on** that thread (so
    /// the executor — runtime, compiled artifacts, thread pool — need not
    /// be `Send`); its error, like any executor error later, surfaces
    /// from [`Server::shutdown`].
    pub fn spawn<E, F>(cfg: ServeConfig, clock: Arc<dyn Clock>, make_exec: F) -> Server
    where
        E: BatchExecutor + 'static,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ServeRequest>();
        let join = std::thread::spawn(move || -> Result<ServeStats> {
            let exec = make_exec()?;
            let mut session = ServeSession::new(cfg, Arc::clone(&clock), exec);
            loop {
                let msg = match session.next_deadline() {
                    None => match rx.recv() {
                        Ok(r) => Incoming::Req(r),
                        Err(_) => Incoming::Closed,
                    },
                    Some(deadline) => {
                        let now = clock.now();
                        if deadline <= now {
                            session.tick()?;
                            continue;
                        }
                        match rx.recv_timeout(Duration::from_nanos(deadline - now)) {
                            Ok(r) => Incoming::Req(r),
                            Err(RecvTimeoutError::Timeout) => Incoming::DeadlineFired,
                            Err(RecvTimeoutError::Disconnected) => Incoming::Closed,
                        }
                    }
                };
                match msg {
                    Incoming::Req(r) => {
                        session.submit(r.input, r.reply)?;
                    }
                    Incoming::DeadlineFired => session.tick()?,
                    Incoming::Closed => break,
                }
            }
            session.shutdown()
        });
        Server { tx: Some(tx), join: Some(join) }
    }

    /// A clonable submission handle. All clones (and the server's own)
    /// must drop before the service thread drains and exits.
    pub fn handle(&self) -> Sender<ServeRequest> {
        self.tx.as_ref().expect("server already shut down").clone()
    }

    /// Close the channel, wait for the drain, return the stats (or the
    /// executor's error).
    pub fn shutdown(mut self) -> Result<ServeStats> {
        drop(self.tx.take());
        let join = self.join.take().expect("server already shut down");
        match join.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("serve thread panicked"),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Per-process unique suffix for serve artifact scratch dirs (two
/// executors in one test binary must not share a directory).
fn serve_seq() -> usize {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    SEQ.fetch_add(1, Ordering::Relaxed)
}

/// Powers-of-two rungs up to and including `max_batch`.
pub fn batch_ladder(max_batch: usize) -> Vec<usize> {
    assert!(max_batch >= 1, "max_batch must be >= 1");
    let mut out = Vec::new();
    let mut b = 1;
    while b < max_batch {
        out.push(b);
        b *= 2;
    }
    out.push(max_batch);
    out
}

/// Measured-deadline floor: the planned deadline never collapses below
/// this, however fast the measured batch is — a near-zero deadline would
/// close every batch at size 1 and spin the service thread.
pub const MIN_PLANNED_DELAY_NS: Nanos = 50_000;

/// The planned deadline is this multiple of one full-cap batch's measured
/// FWD service time: waiting a few service times to fill a batch is
/// worthwhile; waiting longer only adds latency.
const DELAY_SERVICE_MULTIPLE: f64 = 4.0;

/// The real [`BatchExecutor`]: the routed predict graph at a ladder of
/// batch sizes (see the module docs). Weights are seeded He init — the
/// same scheme the trainer uses — so two executors built with the same
/// seed serve bit-identical models.
pub struct PredictExecutor {
    runtime: Runtime,
    geometry: Geometry,
    ladder: Vec<usize>,
    names: Vec<String>,
    dir: PathBuf,
    sample_in: usize,
    sample_out: usize,
    w1: Vec<f32>,
    w2: Vec<f32>,
    wfc: Vec<f32>,
    bfc: Vec<f32>,
    policy_measured: bool,
}

impl PredictExecutor {
    /// Kernel-routed executor (`threads` sizes the op router's pool;
    /// 0 = host parallelism). The cost DB attaches per the usual env
    /// knobs; `SPARSETRAIN_COST_DB=off` pins the static batch policy.
    pub fn new(geometry: Geometry, max_batch: usize, threads: usize, seed: u64) -> Result<Self> {
        Self::build(geometry, max_batch, threads, seed, false)
    }

    /// All-naive-interpreter executor — the A/B lever the batched-vs-
    /// sequential parity suite uses on the unrouted path.
    pub fn new_naive(geometry: Geometry, max_batch: usize, seed: u64) -> Result<Self> {
        Self::build(geometry, max_batch, 0, seed, true)
    }

    fn build(
        geometry: Geometry,
        max_batch: usize,
        threads: usize,
        seed: u64,
        naive: bool,
    ) -> Result<Self> {
        let dir = std::env::temp_dir()
            .join(format!("sparsetrain-serve-{}-{}", std::process::id(), serve_seq()));
        let _ = std::fs::remove_dir_all(&dir);
        let arts = ArtifactSet::new(&dir);
        let ladder = batch_ladder(max_batch);
        let mut names = Vec::with_capacity(ladder.len());
        for &b in &ladder {
            let g = Geometry { n: b, ..geometry };
            let name = format!("predict_serve_b{b}");
            arts.publish_fallback_text(&name, &hlo_builder::predict_hlo(&g))
                .with_context(|| format!("publishing serve predict artifact (batch {b})"))?;
            names.push(name);
        }
        let mut runtime = if naive {
            Runtime::cpu_naive(&dir)?
        } else {
            Runtime::cpu_with_threads(&dir, threads)?
        };
        // Preload the whole ladder now: `Runtime::load` needs `&mut`, but
        // dispatch-time lookups go through the shared-borrow
        // `Runtime::get`, so a loaded executable per rung must exist first.
        for name in &names {
            runtime.load(name)?;
        }

        let mut rng = Xorshift::new(seed);
        let he = |rng: &mut Xorshift, n: usize, fan_in: usize| -> Vec<f32> {
            let bound = (2.0 / fan_in as f32).sqrt();
            (0..n).map(|_| rng.range_f32(-bound, bound)).collect()
        };
        let w1 = he(&mut rng, geometry.c1 * geometry.c_in * 9, geometry.c_in * 9);
        let w2 = he(&mut rng, geometry.c2 * geometry.c1 * 9, geometry.c1 * 9);
        let fc_bound = (1.0 / geometry.c2 as f32).sqrt();
        let wfc = (0..geometry.classes * geometry.c2)
            .map(|_| rng.range_f32(-fc_bound, fc_bound))
            .collect();
        let bfc = vec![0.0f32; geometry.classes];
        let policy_measured = runtime.op_router().and_then(|r| r.cost_db()).is_some();
        Ok(PredictExecutor {
            runtime,
            geometry,
            ladder,
            names,
            dir,
            sample_in: geometry.c_in * geometry.hw * geometry.hw,
            sample_out: geometry.classes,
            w1,
            w2,
            wfc,
            bfc,
            policy_measured,
        })
    }

    pub fn geometry(&self) -> Geometry {
        self.geometry
    }

    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }

    /// Expected per-sample input length (`c_in * hw * hw`).
    pub fn sample_len(&self) -> usize {
        self.sample_in
    }

    /// Single-sample convenience (runs the batch-1 rung) — the sequential
    /// baseline the parity suite compares batched output against.
    pub fn predict_one(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let mut outs = self.run_batch(&[input.to_vec()])?;
        Ok(outs.remove(0))
    }
}

impl Drop for PredictExecutor {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

impl BatchExecutor for PredictExecutor {
    fn run_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let bsz = inputs.len();
        let cap = *self.ladder.last().expect("ladder is non-empty");
        anyhow::ensure!(bsz >= 1 && bsz <= cap, "batch size {bsz} outside 1..={cap}");
        let idx = self
            .ladder
            .iter()
            .position(|&b| b >= bsz)
            .expect("ladder covers every size up to the cap");
        let art_b = self.ladder[idx];
        let g = self.geometry;
        // Zero-pad up to the rung: every routed op is per-sample
        // independent, so padding cannot perturb the live rows.
        let mut x = vec![0.0f32; art_b * self.sample_in];
        for (i, s) in inputs.iter().enumerate() {
            anyhow::ensure!(
                s.len() == self.sample_in,
                "sample {i} has {} floats, expected {}",
                s.len(),
                self.sample_in
            );
            x[i * self.sample_in..(i + 1) * self.sample_in].copy_from_slice(s);
        }
        let lits = [
            literal_f32(&self.w1, &[g.c1 as i64, g.c_in as i64, 3, 3])?,
            literal_f32(&self.w2, &[g.c2 as i64, g.c1 as i64, 3, 3])?,
            literal_f32(&self.wfc, &[g.classes as i64, g.c2 as i64])?,
            literal_f32(&self.bfc, &[g.classes as i64])?,
            literal_f32(&x, &[art_b as i64, g.c_in as i64, g.hw as i64, g.hw as i64])?,
        ];
        let exe = self
            .runtime
            .get(&self.names[idx])
            .context("serve predict artifact not preloaded")?;
        let outs = exe.run(&lits)?;
        anyhow::ensure!(outs.len() == 1, "predict returns exactly (logits,)");
        let logits = outs[0].to_vec::<f32>()?;
        anyhow::ensure!(
            logits.len() == art_b * self.sample_out,
            "logits length {} != {} * {}",
            logits.len(),
            art_b,
            self.sample_out
        );
        Ok((0..bsz)
            .map(|i| logits[i * self.sample_out..(i + 1) * self.sample_out].to_vec())
            .collect())
    }

    /// Measured-cost rung selection: minimize FWD ns/sample summed over
    /// the two predict convolutions. Any cold rung (or a detached DB)
    /// falls back to the static policy — the cap — until the DB warms;
    /// partial drain batches exercise the smaller rungs, which is what
    /// warms them.
    fn planned_batch(&self, max_batch: usize) -> usize {
        let cap = max_batch.min(*self.ladder.last().expect("ladder is non-empty"));
        let Some(router) = self.runtime.op_router() else { return cap };
        let Some(db) = router.cost_db() else { return cap };
        let threads = router.threads();
        let backend = crate::kernels::simd::dispatch().name();
        let g = self.geometry;
        let mut best: Option<(usize, f64)> = None;
        for &b in &self.ladder {
            if b > cap {
                break;
            }
            let conv1 = ConvConfig::square(b, g.c_in, g.c1, g.hw, 3, 1);
            let conv2 = ConvConfig::square(b, g.c1, g.c2, g.hw, 3, 1);
            let rung_ns = match (
                db.best_ns(DbComponent::Fwd, &geom_sig(&conv1), threads, backend),
                db.best_ns(DbComponent::Fwd, &geom_sig(&conv2), threads, backend),
            ) {
                (Some(a), Some(c)) => a + c,
                _ => return cap, // cold rung: static policy until warm
            };
            let per_sample = rung_ns / b as f64;
            let better = match best {
                None => true,
                Some((_, cur)) => per_sample < cur,
            };
            if better {
                best = Some((b, per_sample));
            }
        }
        match best {
            Some((b, _)) => b,
            None => cap,
        }
    }

    /// Measured-cost deadline (see the module docs): a small multiple of
    /// the full-cap rung's measured FWD time, clamped into
    /// `[MIN_PLANNED_DELAY_NS, static]`. Cold rung or detached DB keeps
    /// the static deadline.
    fn planned_delay_ns(&self, static_delay_ns: Nanos) -> Nanos {
        let Some(router) = self.runtime.op_router() else { return static_delay_ns };
        let Some(db) = router.cost_db() else { return static_delay_ns };
        let threads = router.threads();
        let backend = crate::kernels::simd::dispatch().name();
        let g = self.geometry;
        let b = *self.ladder.last().expect("ladder is non-empty");
        let conv1 = ConvConfig::square(b, g.c_in, g.c1, g.hw, 3, 1);
        let conv2 = ConvConfig::square(b, g.c1, g.c2, g.hw, 3, 1);
        match (
            db.best_ns(DbComponent::Fwd, &geom_sig(&conv1), threads, backend),
            db.best_ns(DbComponent::Fwd, &geom_sig(&conv2), threads, backend),
        ) {
            (Some(c1), Some(c2)) => {
                let planned = ((c1 + c2) * DELAY_SERVICE_MULTIPLE) as Nanos;
                planned.clamp(MIN_PLANNED_DELAY_NS.min(static_delay_ns), static_delay_ns)
            }
            _ => static_delay_ns, // cold rung: static deadline until warm
        }
    }

    fn policy(&self) -> &'static str {
        if self.policy_measured {
            "measured"
        } else {
            "static"
        }
    }
}

/// Block until `rx` yields its reply (test/bench convenience).
pub fn wait_reply(rx: &Receiver<ServeReply>) -> Result<ServeReply> {
    rx.recv().context("reply channel closed without a reply")
}

// ---------------------------------------------------------------------------
// Tests. The pure batcher/clock tests carry no IO and run in the Miri CI
// leg (`coordinator::serve` filter); executor tests touch the filesystem
// and real clocks and are cfg'd out under Miri.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_manual_and_monotonic() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        assert_eq!(c.advance(5), 5);
        assert_eq!(c.now(), 5);
        c.set(100);
        assert_eq!(c.now(), 100);
    }

    #[test]
    #[cfg_attr(miri, ignore = "Instant is unavailable under isolation")]
    fn monotonic_clock_never_goes_backwards() {
        let c = MonotonicClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn batch_ladder_covers_all_caps() {
        assert_eq!(batch_ladder(1), vec![1]);
        assert_eq!(batch_ladder(8), vec![1, 2, 4, 8]);
        assert_eq!(batch_ladder(6), vec![1, 2, 4, 6]);
        assert_eq!(batch_ladder(9), vec![1, 2, 4, 8, 9]);
    }

    #[test]
    fn batcher_size_closes_at_target_and_deadline_closes_at_tick() {
        let mut b: Batcher<u32> = Batcher::new(3, 100, 10);
        assert!(b.push(1, 0).is_ok());
        assert!(b.push(2, 10).is_ok());
        assert!(b.pop_ready(10).is_none(), "under target and under deadline");
        assert!(b.push(3, 20).is_ok());
        let batch = b.pop_ready(20).expect("size-closed at exactly target");
        assert_eq!(batch.iter().map(|&(_, v)| v).collect::<Vec<_>>(), vec![1, 2, 3]);

        assert!(b.push(4, 30).is_ok());
        assert_eq!(b.next_deadline(), Some(130));
        assert!(b.pop_ready(129).is_none(), "one tick before the deadline");
        let batch = b.pop_ready(130).expect("deadline-closed at exactly the tick");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn batcher_sheds_at_exact_depth_and_drains_in_target_chunks() {
        let mut b: Batcher<u32> = Batcher::new(8, 100, 2);
        assert!(b.push(1, 0).is_ok());
        assert!(b.push(2, 0).is_ok());
        assert_eq!(b.push(3, 0), Err(3), "third arrival sheds at depth 2");
        b.set_target(1);
        let batches = b.drain_all();
        assert_eq!(batches.len(), 2, "drain respects the planned batch size");
        assert_eq!(b.depth(), 0);
        assert!(b.push(4, 0).is_ok(), "shedding recovers once drained");
    }

    #[test]
    fn batcher_target_clamps_into_configured_range() {
        let mut b: Batcher<u32> = Batcher::new(4, 100, 10);
        b.set_target(0);
        assert_eq!(b.target(), 1);
        b.set_target(99);
        assert_eq!(b.target(), 4);
    }

    #[test]
    fn batcher_replanned_deadline_applies_to_queue_head() {
        let mut b: Batcher<u32> = Batcher::new(4, 1_000, 10);
        assert!(b.push(1, 0).is_ok());
        assert_eq!(b.next_deadline(), Some(1_000));
        b.set_max_delay(100);
        assert_eq!(b.max_delay_ns(), 100);
        assert_eq!(b.next_deadline(), Some(100), "deadlines recompute, not cache");
        assert!(b.pop_ready(99).is_none());
        assert!(b.pop_ready(100).is_some(), "closes at the planned deadline");
    }

    struct DoubleExec;
    impl BatchExecutor for DoubleExec {
        fn run_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(inputs.iter().map(|v| vec![v[0] * 2.0]).collect())
        }
    }

    /// Echo executor pinning a planned deadline below the static config —
    /// the measured-deadline policy shape, without a cost DB.
    struct PlannedDelayExec(Nanos);
    impl BatchExecutor for PlannedDelayExec {
        fn run_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(inputs.to_vec())
        }
        fn planned_delay_ns(&self, _static_delay_ns: Nanos) -> Nanos {
            self.0
        }
    }

    #[test]
    fn session_replans_deadline_from_executor_on_every_arrival() {
        let clock = Arc::new(VirtualClock::new());
        let cfg = ServeConfig { max_batch: 4, max_delay_ns: 2_000_000, queue_depth: 8 };
        let mut s =
            ServeSession::new(cfg, Arc::clone(&clock) as Arc<dyn Clock>, PlannedDelayExec(100));
        let (tx, rx) = mpsc::channel();
        s.submit(vec![1.0], tx).unwrap();
        assert_eq!(
            s.next_deadline(),
            Some(100),
            "planned deadline beats the static config deadline"
        );
        clock.advance(100);
        s.tick().unwrap();
        assert_eq!(s.depth(), 0, "deadline-closed at the planned tick");
        assert!(matches!(rx.try_recv().unwrap(), ServeReply::Done(_)));

        // The default trait policy is the static deadline, unchanged.
        let mut stat = ServeSession::new(cfg, clock as Arc<dyn Clock>, DoubleExec);
        let (tx2, _rx2) = mpsc::channel();
        stat.submit(vec![1.0], tx2).unwrap();
        let t0 = stat.next_deadline().expect("one queued request");
        assert_eq!(t0, 100 + cfg.max_delay_ns, "static policy: enqueue + configured deadline");
    }

    #[test]
    fn session_replies_exactly_once_in_fifo_order() {
        let clock = Arc::new(VirtualClock::new());
        let cfg = ServeConfig { max_batch: 2, max_delay_ns: 100, queue_depth: 8 };
        let mut s = ServeSession::new(cfg, Arc::clone(&clock) as Arc<dyn Clock>, DoubleExec);
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (tx, rx) = mpsc::channel();
            s.submit(vec![i as f32], tx).unwrap();
            rxs.push(rx);
        }
        // first two size-closed immediately; third still queued
        assert_eq!(s.depth(), 1);
        let stats = s.shutdown().unwrap();
        assert_eq!(stats.batch_sizes, vec![2, 1]);
        assert_eq!((stats.accepted, stats.rejected, stats.completed), (3, 0, 3));
        for (i, rx) in rxs.iter().enumerate() {
            match rx.try_recv().unwrap() {
                ServeReply::Done(p) => {
                    assert_eq!(p.id, i as u64, "FIFO ids");
                    assert_eq!(p.output, vec![i as f32 * 2.0], "no cross-request mixing");
                }
                other => panic!("expected Done, got {other:?}"),
            }
            assert!(rx.try_recv().is_err(), "exactly one reply per request");
        }
        assert_eq!(stats.batch_hist(), vec![(1, 1), (2, 1)]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spawns the PJRT runtime and touches the filesystem")]
    fn predict_executor_pads_partial_batches_and_bounds_sizes() {
        // Tiny geometry: channels below V keep the convs on the (equally
        // deterministic) interpreter fallback — this test pins executor
        // mechanics, not routing.
        let g = Geometry::tiny();
        let mut ex = PredictExecutor::new(g, 4, 1, 11).unwrap();
        assert_eq!(ex.ladder(), &[1, 2, 4]);
        assert_eq!(ex.sample_len(), g.c_in * g.hw * g.hw);
        let mut rng = Xorshift::new(3);
        let samples: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..ex.sample_len()).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            .collect();
        // 3 samples ride the 4-rung (padded); outputs stay per-sample.
        let outs = ex.run_batch(&samples).unwrap();
        assert_eq!(outs.len(), 3);
        assert!(outs.iter().all(|o| o.len() == g.classes && o.iter().all(|v| v.is_finite())));
        assert!(ex.run_batch(&[]).is_err(), "empty batch rejected");
        let too_many: Vec<Vec<f32>> = (0..5).map(|_| vec![0.0; ex.sample_len()]).collect();
        assert!(ex.run_batch(&too_many).is_err(), "over-cap batch rejected");
        let bad_len = vec![vec![0.0f32; 3]];
        assert!(ex.run_batch(&bad_len).is_err(), "wrong sample length rejected");
    }
}
