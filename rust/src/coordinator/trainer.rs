//! The PJRT-driven training loop: Rust owns the loop, the data, the
//! metrics, and the parameter state; the compiled train-step artifact does
//! the numerics. Python never runs here — on a cold checkout the artifact
//! is the Rust-emitted reference HLO (`runtime::hlo_builder`) executed by
//! the vendored mini-HLO interpreter. Pre-built artifacts in the same
//! reference grammar take precedence (see `runtime::artifacts` for the
//! real-XLA caveat).
//!
//! Since ISSUE 5 the interpreter's convolutions are **kernel-routed**, and
//! since ISSUE 6 the whole graph is: the runtime installs
//! `runtime::executor::OpRouter`, so the train step's FWD/BWI/BWW
//! convolutions run on the SparseTrain SIMD kernels, its `dot`s on the
//! blocked parallel GEMM, and its recognized elementwise chains as fused
//! single passes — all through the persistent-thread-pool scheduler
//! ([`TrainerConfig::threads`] wide), with the selector picking the conv
//! skip mode from measured sparsity. Since ISSUE 8 that selection is
//! additionally measured-cost-driven: the router's default
//! [`crate::coordinator::CostDb`] times every routed conv/GEMM and the
//! selector prefers the cheapest measured mode per (geometry, sparsity
//! bucket, threads, backend) key (`SPARSETRAIN_COST_DB=off` restores
//! pure analytic selection). The `train` CLI prints the DB's
//! hit/miss/update counters after the run.

use crate::coordinator::costdb::CostDb;
use crate::coordinator::metrics::MetricsRegistry;
use crate::kernels::layers::synthetic_batch;
use crate::nets::{Network, Scale};
use crate::runtime::artifacts::{geometry, ArtifactSet, TRAIN_STEP};
use crate::runtime::hlo_builder::{self, NetModel, NetTrainPlan};
use crate::runtime::pjrt::{literal_f32, literal_i32, Runtime};
use crate::sparsity::SparsityProfiler;
use crate::util::prng::Xorshift;
use anyhow::{Context, Result};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    /// Worker threads for the op router's kernel/GEMM executor
    /// (`0` = host parallelism). Ignored when routing is disabled via
    /// `SPARSETRAIN_CONV_ROUTE=off` + `SPARSETRAIN_OP_ROUTE=off`.
    pub threads: usize,
    /// Dependency-scheduled (pipelined) evaluation: `None` follows
    /// `SPARSETRAIN_PIPELINE` (default on), `Some(b)` pins it — the
    /// race-free per-trainer override the parity tests use instead of
    /// mutating process-global environment variables. Effective only
    /// with a router and ≥ 2 threads; results are bit-identical either
    /// way.
    pub pipeline: Option<bool>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig { steps: 200, seed: 7, log_every: 25, threads: 0, pipeline: None }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f64>,
    pub steps_per_sec: f64,
    /// Per-layer measured ReLU sparsity series (layer → per-step values).
    pub profiler: SparsityProfiler,
}

impl TrainReport {
    /// Loss must drop from its initial plateau for the run to count as
    /// "learning" (the E2E acceptance criterion).
    pub fn learned(&self) -> bool {
        if self.losses.len() < 20 {
            return false;
        }
        let head = crate::util::stats::mean(&self.losses[..10]);
        let tail = crate::util::stats::mean(&self.losses[self.losses.len() - 10..]);
        tail < head * 0.8
    }
}

/// A prepared zoo-network run: which artifact to load and the emission
/// manifest describing its feeds and outputs.
#[derive(Debug, Clone)]
struct NetRun {
    artifact: String,
    plan: NetTrainPlan,
}

/// Trainer over the AOT train-step artifact.
pub struct Trainer {
    runtime: Runtime,
    cfg: TrainerConfig,
    pub metrics: MetricsRegistry,
    /// `Some` when this trainer runs an emitted zoo network
    /// ([`Trainer::new_net`]) instead of the classic paper geometry.
    net: Option<NetRun>,
}

impl Trainer {
    pub fn new(artifacts: &ArtifactSet, cfg: TrainerConfig) -> Result<Trainer> {
        anyhow::ensure!(
            artifacts.complete(),
            "artifacts missing: {:?}; run `make artifacts` first",
            artifacts.missing()
        );
        // Kernel-routed by default: the runtime installs the SparseTrain
        // op router (persistent thread pool, selector-chosen conv skip
        // mode), so every train step's five convolutions, three dots, and
        // recognized elementwise chains run multi-threaded / fused instead
        // of through the interpreter's naive loop. At >= 2 threads the
        // pipeline planner additionally co-schedules independent
        // instruction pairs (unless cfg.pipeline / the env says off).
        let runtime = Runtime::cpu_with_options(
            &artifacts.dir,
            cfg.threads,
            CostDb::from_env(),
            cfg.pipeline,
        )?;
        Ok(Trainer { runtime, cfg, metrics: MetricsRegistry::new(), net: None })
    }

    /// A trainer over an emitted `nets::zoo` inventory at the given scale:
    /// the multi-layer train-step graph is emitted, published into the
    /// artifact directory under `train_step_<net>_<scale>` (same
    /// stale-marker/no-clobber contract as the classic fallback trio),
    /// and driven by the same kernel-routed runtime. Each step feeds the
    /// per-layer measured sparsity back into the router's selector.
    pub fn new_net(
        artifacts: &ArtifactSet,
        network: Network,
        scale: Scale,
        cfg: TrainerConfig,
    ) -> Result<Trainer> {
        let model = NetModel::new(network, scale);
        let (train_name, predict_name) = hlo_builder::net_artifact_names(&model);
        let (text, plan) = hlo_builder::net_train_step_hlo(&model)
            .map_err(|e| anyhow::anyhow!("emitting {train_name}: {e}"))?;
        artifacts
            .publish_fallback_text(&train_name, &text)
            .with_context(|| format!("publishing {train_name}"))?;
        let predict = hlo_builder::net_predict_hlo(&model)
            .map_err(|e| anyhow::anyhow!("emitting {predict_name}: {e}"))?;
        artifacts
            .publish_fallback_text(&predict_name, &predict)
            .with_context(|| format!("publishing {predict_name}"))?;
        let runtime = Runtime::cpu_with_options(
            &artifacts.dir,
            cfg.threads,
            CostDb::from_env(),
            cfg.pipeline,
        )?;
        Ok(Trainer {
            runtime,
            cfg,
            metrics: MetricsRegistry::new(),
            net: Some(NetRun { artifact: train_name, plan }),
        })
    }

    /// The emission manifest, when this trainer drives a zoo network.
    pub fn net_plan(&self) -> Option<&NetTrainPlan> {
        self.net.as_ref().map(|n| &n.plan)
    }

    /// The runtime's installed op router, if routing is enabled — exposes
    /// per-op-kind routed/fallback/fused counters for CLI reporting.
    pub fn op_router(&self) -> Option<&crate::runtime::OpRouter> {
        self.runtime.op_router()
    }

    /// Whether this trainer's executables evaluate through the
    /// dependency-scheduled (pipelined) executor — for the CLI's
    /// `pipeline:` report line.
    pub fn pipelined(&self) -> bool {
        self.runtime.pipelined()
    }

    /// He-style uniform init for a conv weight [k][c][s][r].
    fn init_conv(rng: &mut Xorshift, k: usize, c: usize, s: usize, r: usize) -> Vec<f32> {
        let fan_in = (c * s * r) as f32;
        let bound = (2.0 / fan_in).sqrt();
        (0..k * c * s * r).map(|_| rng.range_f32(-bound, bound)).collect()
    }

    /// Run the training loop (classic paper geometry or the emitted zoo
    /// network, depending on the constructor).
    pub fn run(&mut self) -> Result<TrainReport> {
        if self.net.is_some() {
            self.run_net()
        } else {
            self.run_classic()
        }
    }

    /// Parameter init by rank: conv weights He-uniform, FC weights
    /// `±sqrt(1/fan_in)`, biases zero — the shapes come straight from the
    /// emission manifest.
    fn init_param(rng: &mut Xorshift, dims: &[usize]) -> Result<Vec<f32>> {
        Ok(match dims {
            [k, c, s, r] => Self::init_conv(rng, *k, *c, *s, *r),
            [rows, cols] => {
                let bound = (1.0 / *cols as f32).sqrt();
                (0..rows * cols).map(|_| rng.range_f32(-bound, bound)).collect()
            }
            [len] => vec![0.0f32; *len],
            other => anyhow::bail!("unsupported parameter rank {}", other.len()),
        })
    }

    /// The zoo-network loop: same ownership story as the classic loop
    /// (Rust holds the parameters, the artifact does the numerics), but
    /// parameter inventory, output arity, and sparsity series all come
    /// from the [`NetTrainPlan`] — and each step pushes the recent-mean
    /// measured sparsity of every conv's feed series into the op router,
    /// so the selector plans with profiled sparsity instead of live
    /// operand zero counts.
    fn run_net(&mut self) -> Result<TrainReport> {
        let NetRun { artifact, plan } = self.net.clone().expect("run_net requires new_net");
        let mut rng = Xorshift::new(self.cfg.seed);
        let mut params: Vec<Vec<f32>> = Vec::with_capacity(plan.params.len());
        for (_, dims) in &plan.params {
            params.push(Self::init_param(&mut rng, dims)?);
        }

        let [n, c_in, hw, _] = plan.input_dims;
        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut profiler = SparsityProfiler::new();
        let t0 = std::time::Instant::now();

        // The router handle must be cloned out *before* `load`: the
        // returned `&Executable` holds the runtime borrow for the whole
        // loop.
        let router = self.runtime.op_router_arc();
        let exe = self.runtime.load(&artifact)?;

        for step in 0..self.cfg.steps {
            if let Some(rt) = &router {
                rt.set_profiled_sparsity(plan.sparsity_feeds.iter().filter_map(
                    |(instr, series)| {
                        profiler.recent_mean(series, 16).map(|m| (instr.clone(), m))
                    },
                ));
            }

            let (x, labels) = synthetic_batch(&mut rng, n, c_in, hw, plan.classes);
            let mut inputs = Vec::with_capacity(plan.params.len() + 2);
            for (vals, (_, dims)) in params.iter().zip(&plan.params) {
                let d64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                inputs.push(literal_f32(vals, &d64)?);
            }
            inputs.push(literal_f32(&x.to_nchw(), &[n as i64, c_in as i64, hw as i64, hw as i64])?);
            inputs.push(literal_i32(
                &labels.iter().map(|&l| l as i32).collect::<Vec<_>>(),
                &[n as i64],
            )?);

            let outs = exe.run(&inputs).context("net train step")?;
            anyhow::ensure!(
                outs.len() == plan.n_outputs(),
                "train step must return {} outputs, got {}",
                plan.n_outputs(),
                outs.len()
            );
            for (p, o) in params.iter_mut().zip(&outs) {
                *p = o.to_vec::<f32>()?;
            }
            let np = params.len();
            let loss = outs[np].to_vec::<f32>()?[0] as f64;
            losses.push(loss);

            let mut relu_sum = 0.0;
            for (j, key) in plan.relu_keys.iter().enumerate() {
                let s = outs[np + 1 + j].to_vec::<f32>()?[0] as f64;
                relu_sum += s;
                profiler.observe_value(key, s.clamp(0.0, 1.0));
            }
            for (j, key) in plan.dz_keys.iter().enumerate() {
                let s = outs[np + 1 + plan.relu_keys.len() + j].to_vec::<f32>()?[0] as f64;
                profiler.observe_value(key, s.clamp(0.0, 1.0));
            }
            self.metrics.push("loss", loss);
            self.metrics.inc("steps", 1);

            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                let mean_sp = relu_sum / plan.relu_keys.len().max(1) as f64;
                println!(
                    "step {step:>5}  loss {loss:>8.4}  mean relu sparsity {mean_sp:.3}  \
                     ({} layers)",
                    plan.relu_keys.len()
                );
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            losses,
            steps_per_sec: self.cfg.steps as f64 / dt.max(1e-9),
            profiler,
        })
    }

    /// The original hard-coded paper-geometry loop (two convs + FC).
    fn run_classic(&mut self) -> Result<TrainReport> {
        use geometry::*;
        let mut rng = Xorshift::new(self.cfg.seed);

        // Parameter state, host-side. Shapes match python/compile/model.py.
        let mut w1 = Self::init_conv(&mut rng, C1, C_IN, 3, 3);
        let mut w2 = Self::init_conv(&mut rng, C2, C1, 3, 3);
        let fan = C2 as f32;
        let mut wfc: Vec<f32> =
            (0..CLASSES * C2).map(|_| rng.range_f32(-(1.0 / fan).sqrt(), (1.0 / fan).sqrt())).collect();
        let mut bfc = vec![0.0f32; CLASSES];

        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut profiler = SparsityProfiler::new();
        let t0 = std::time::Instant::now();

        // Compile once and hold the executable across the whole loop:
        // `Runtime::load` caches, but re-resolving it every step still paid
        // a map lookup per step and — worse — made it easy to regress into
        // per-step compilation. The borrow is field-disjoint from
        // `self.metrics`/`self.cfg`, so the loop body is unaffected.
        let exe = self.runtime.load(TRAIN_STEP)?;

        for step in 0..self.cfg.steps {
            let (x, labels) = synthetic_batch(&mut rng, N, C_IN, HW, CLASSES);
            let x_lit = literal_f32(&x.to_nchw(), &[N as i64, C_IN as i64, HW as i64, HW as i64])?;
            let y_lit =
                literal_i32(&labels.iter().map(|&l| l as i32).collect::<Vec<_>>(), &[N as i64])?;

            let inputs = vec![
                literal_f32(&w1, &[C1 as i64, C_IN as i64, 3, 3])?,
                literal_f32(&w2, &[C2 as i64, C1 as i64, 3, 3])?,
                literal_f32(&wfc, &[CLASSES as i64, C2 as i64])?,
                literal_f32(&bfc, &[CLASSES as i64])?,
                x_lit,
                y_lit,
            ];
            let outs = exe.run(&inputs).context("train step")?;
            anyhow::ensure!(outs.len() == 7, "train_step must return 7 outputs, got {}", outs.len());

            w1 = outs[0].to_vec::<f32>()?;
            w2 = outs[1].to_vec::<f32>()?;
            wfc = outs[2].to_vec::<f32>()?;
            bfc = outs[3].to_vec::<f32>()?;
            let loss = outs[4].to_vec::<f32>()?[0] as f64;
            let s1 = outs[5].to_vec::<f32>()?[0] as f64;
            let s2 = outs[6].to_vec::<f32>()?[0] as f64;

            losses.push(loss);
            profiler.observe_value("conv1_relu", s1.clamp(0.0, 1.0));
            profiler.observe_value("conv2_relu", s2.clamp(0.0, 1.0));
            self.metrics.push("loss", loss);
            self.metrics.inc("steps", 1);
            self.metrics.set_gauge("sparsity/conv1", s1);
            self.metrics.set_gauge("sparsity/conv2", s2);

            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                println!(
                    "step {step:>5}  loss {loss:>8.4}  relu sparsity: conv1 {s1:.3} conv2 {s2:.3}"
                );
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            losses,
            steps_per_sec: self.cfg.steps as f64 / dt.max(1e-9),
            profiler,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_learned_criterion() {
        let falling: Vec<f64> = (0..100).map(|i| 2.0 - 1.5 * (i as f64 / 99.0)).collect();
        let flat = vec![2.0; 100];
        let mk = |losses: Vec<f64>| TrainReport {
            losses,
            steps_per_sec: 1.0,
            profiler: SparsityProfiler::new(),
        };
        assert!(mk(falling).learned());
        assert!(!mk(flat).learned());
        assert!(!mk(vec![1.0; 5]).learned());
    }

    #[test]
    fn trainer_requires_artifacts() {
        let missing = ArtifactSet::new("/definitely/not/here");
        let err = Trainer::new(&missing, TrainerConfig::default()).err().unwrap();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    /// Full loop through the interpreter — gating, no artifact or stub
    /// escape hatch: the Rust-emitted reference HLO is materialized into a
    /// scratch directory, so this passes on a cold checkout and is
    /// independent of whatever `./artifacts` holds. (The longer
    /// learning-curve assertions live in `rust/tests/e2e_train.rs`.)
    #[test]
    #[cfg_attr(miri, ignore)] // full-geometry interpreted train steps
    fn short_training_run_via_offline_fallback() {
        let arts = ArtifactSet::scratch_fallback("trainer-unit").unwrap();
        assert!(arts.complete(), "fallback must satisfy the manifest");
        let mut t =
            Trainer::new(
                &arts,
                TrainerConfig { steps: 5, seed: 1, log_every: 0, threads: 2, pipeline: None },
            )
            .unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.losses.len(), 5);
        assert!(report.losses.iter().all(|l| l.is_finite()));
        assert_eq!(report.profiler.series("conv1_relu").unwrap().len(), 5);
    }
}
