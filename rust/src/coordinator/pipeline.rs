//! Pipeline planner (ISSUE 10): the cost-gated overlap policy behind the
//! dependency-scheduled evaluator.
//!
//! The vendored interpreter builds the data-dependency DAG and proves
//! which instruction pairs are independent; this module supplies the two
//! host-side halves of [`xla::PipelinePlanner`]:
//!
//! - **`join`** — [`crate::runtime::executor::OpRouter::overlap_join`]:
//!   structured fork-join on the *same* persistent pool every routed
//!   kernel already uses (one task on the caller, one on a parked
//!   worker). No second pool, no thread spawns.
//! - **`overlap`** — [`should_overlap`]: co-schedule two ready
//!   instructions only when (a) both are canonical, in-envelope
//!   SparseTrain convolutions (the ops whose BWI‖BWW independence the
//!   paper's backward pass exposes — everything else is too cheap for a
//!   handoff to pay), and (b) the measured-cost DB says the first op's
//!   inner parallelism **under-fills** the configured thread count:
//!   `ns(1 thread) / ns(t threads) < 0.6·t`. Near-linear scaling means
//!   the op already saturates the pool and co-scheduling would only
//!   steal its workers; poor scaling means a worker is idle and the
//!   second op rides along for free. Off-DB or cold keys default to
//!   *allow* — co-scheduled ops key their selector decisions and cost
//!   records on an effective thread budget of 1, so overlapped runs are
//!   exactly what populates the `threads = 1` rows this gate reads.
//!
//! Numerics are not this module's concern: the evaluator only consults
//! `overlap` for pairs already proven independent, each op fully owns
//! its output buffer, and independent ops commute — so any gate answer
//! (including a random one) yields bit-identical results. Pinned by
//! `rust/tests/pipeline_route_parity.rs`; the kill switch
//! `SPARSETRAIN_PIPELINE=off` removes the planner entirely.

use crate::coordinator::costdb::{self, CostDb, DbComponent};
use crate::kernels::{Component, ConvConfig};
use crate::runtime::executor::{cfg_in_envelope, classify, Form, OpRouter};
use crate::V;
use std::sync::Arc;
use xla::hlo::{Computation, Op, ShapeDecl};

/// Parallel-efficiency floor below which an op is considered to
/// under-fill the pool (see the module docs' gate condition).
const SCALING_FLOOR: f64 = 0.6;

/// Rank-4 dims of instruction `idx`'s declared shape, if it has one.
fn dims4(comp: &Computation, idx: usize) -> Option<[usize; 4]> {
    let instr = comp.instrs.get(idx)?;
    let ShapeDecl::Single(sh) = &instr.shape else {
        return None;
    };
    match sh.dims[..] {
        [a, b, c, d] => Some([a, b, c, d]),
        _ => None,
    }
}

/// When instruction `idx` is a canonical, in-envelope SparseTrain
/// convolution, reconstruct the kernel config the router would run it
/// with — the same shape extraction as `OpRouter::route_fwd/bwi/bww`,
/// but from declared shapes (plan time) instead of live buffers (run
/// time). `validate()` at compile guarantees declared shapes are the
/// executed shapes, so the two never disagree.
pub(crate) fn conv_config_of(comp: &Computation, idx: usize) -> Option<(Component, ConvConfig)> {
    let instr = comp.instrs.get(idx)?;
    let Op::Convolution { window: w, spec } = &instr.op else {
        return None;
    };
    let [li, ri] = instr.operands[..] else {
        return None;
    };
    let l = dims4(comp, li)?;
    let r = dims4(comp, ri)?;
    let o = dims4(comp, idx)?;
    if w.pad_lo != w.pad_hi || w.size != [r[2], r[3]] {
        return None;
    }
    match classify(spec)? {
        Form::Fwd => {
            let cfg = ConvConfig {
                n: l[0],
                c: l[1],
                k: r[0],
                h: l[2],
                w: l[3],
                s: w.size[0],
                r: w.size[1],
                stride_p: w.stride[0],
                stride_o: w.stride[1],
                pad_h: w.pad_lo[0],
                pad_w: w.pad_lo[1],
            };
            (r[1] == cfg.c && cfg_in_envelope(&cfg)).then_some((Component::Fwd, cfg))
        }
        Form::Bwi => {
            if w.stride != [1, 1] {
                return None;
            }
            let (s, rr) = (w.size[0], w.size[1]);
            if w.pad_lo[0] + 1 > s || w.pad_lo[1] + 1 > rr {
                return None;
            }
            let cfg = ConvConfig {
                n: l[0],
                c: r[1],
                k: l[1],
                h: o[2],
                w: o[3],
                s,
                r: rr,
                stride_p: 1,
                stride_o: 1,
                pad_h: s - 1 - w.pad_lo[0],
                pad_w: rr - 1 - w.pad_lo[1],
            };
            (r[0] == cfg.k
                && cfg_in_envelope(&cfg)
                && cfg.out_h() == l[2]
                && cfg.out_w() == l[3])
                .then_some((Component::Bwi, cfg))
        }
        Form::Bww => {
            if w.stride != [1, 1] {
                return None;
            }
            let cfg = ConvConfig {
                n: l[0],
                c: l[1],
                k: r[1],
                h: l[2],
                w: l[3],
                s: o[2],
                r: o[3],
                stride_p: 1,
                stride_o: 1,
                pad_h: w.pad_lo[0],
                pad_w: w.pad_lo[1],
            };
            (r[0] == cfg.n
                && cfg.n % V == 0
                && cfg_in_envelope(&cfg)
                && cfg.out_h() == w.size[0]
                && cfg.out_w() == w.size[1])
                .then_some((Component::Bww, cfg))
        }
    }
}

/// The measured half of the gate, factored out of [`should_overlap`] so
/// it is testable without a live router (whose DB is forcibly detached
/// under Miri): does the measured scaling of `(comp, geom)` say the op
/// under-fills `threads` workers? Cold keys and a detached DB answer
/// `true` — co-scheduling is the exploration that records the
/// single-thread rows a warm answer needs.
pub(crate) fn scaling_underfills(
    db: Option<&CostDb>,
    comp: DbComponent,
    geom: &str,
    threads: usize,
    backend: &str,
) -> bool {
    let Some(db) = db else {
        return true;
    };
    match (db.best_ns(comp, geom, 1, backend), db.best_ns(comp, geom, threads, backend)) {
        (Some(ns_1), Some(ns_t)) if ns_t > 0.0 => {
            ns_1 / ns_t < SCALING_FLOOR * threads as f64
        }
        _ => true,
    }
}

/// The full overlap predicate the planner installs — see the module docs
/// for the policy. `a` is the instruction the evaluator is about to run
/// (the lowest-index ready one, whose measured scaling is queried);
/// `b` is the co-scheduling candidate.
pub fn should_overlap(router: &OpRouter, comp: &Computation, a: usize, b: usize) -> bool {
    let threads = router.threads();
    if threads < 2 {
        return false;
    }
    let Some((ka, cfg_a)) = conv_config_of(comp, a) else {
        return false;
    };
    if conv_config_of(comp, b).is_none() {
        return false;
    }
    scaling_underfills(
        router.cost_db().map(|d| d.as_ref()),
        DbComponent::from_kernel(ka),
        &costdb::geom_sig(&cfg_a),
        threads,
        router.backend_name(),
    )
}

/// Coerce a closure to the vendored crate's higher-ranked join type.
fn join_arc<F>(f: F) -> Arc<xla::JoinFn>
where
    F: for<'a> Fn(xla::TaskBox<'a>, xla::TaskBox<'a>) + Send + Sync + 'static,
{
    Arc::new(f)
}

/// Build the planner for a router: `join` forks onto the router's pool,
/// `overlap` applies [`should_overlap`]. Install with
/// [`xla::PjRtClient::set_pipeline_planner`] *before* compiling — the
/// runtime does this exactly when `SPARSETRAIN_PIPELINE` is on, the
/// router exists, and the pool has at least two workers.
pub fn planner(router: &Arc<OpRouter>) -> Arc<xla::PipelinePlanner> {
    let jr = Arc::clone(router);
    let or = Arc::clone(router);
    Arc::new(xla::PipelinePlanner {
        join: join_arc(move |a, b| jr.overlap_join(a, b)),
        overlap: Arc::new(move |comp: &Computation, a: usize, b: usize| {
            should_overlap(&or, comp, a, b)
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::costdb::CostKey;
    use crate::kernels::SkipMode;

    /// Two independent in-envelope FWD convs plus a plain multiply, all
    /// on 16-channel shapes (a `V` multiple for every supported width).
    fn two_conv_comp() -> xla::hlo::Module {
        let text = "HloModule p\nENTRY %m {\n  %x = f32[1,16,4,4] parameter(0)\n  \
                    %w1 = f32[16,16,3,3] parameter(1)\n  \
                    %a = f32[1,16,4,4] convolution(%x, %w1), \
                    window={size=3x3 pad=1_1x1_1}, dim_labels=bf01_oi01->bf01\n  \
                    %b = f32[1,16,4,4] convolution(%x, %w1), \
                    window={size=3x3 pad=1_1x1_1}, dim_labels=bf01_oi01->bf01\n  \
                    ROOT %e = f32[1,16,4,4] multiply(%a, %b)\n}\n";
        xla::hlo::parse_module(text).unwrap()
    }

    #[test]
    fn miri_conv_config_reconstructs_the_fwd_shape() {
        let m = two_conv_comp();
        let comp = &m.comps[m.entry];
        // instrs: 0 %x, 1 %w1, 2 %a, 3 %b, 4 %e
        let (component, cfg) = conv_config_of(comp, 2).expect("canonical FWD conv");
        assert_eq!(component, Component::Fwd);
        assert_eq!((cfg.n, cfg.c, cfg.k), (1, 16, 16));
        assert_eq!((cfg.h, cfg.w, cfg.s, cfg.r), (4, 4, 3, 3));
        assert_eq!((cfg.pad_h, cfg.pad_w, cfg.stride_p, cfg.stride_o), (1, 1, 1, 1));
        assert!(conv_config_of(comp, 4).is_none(), "multiply is not a conv");
        assert!(conv_config_of(comp, 0).is_none(), "parameter is not a conv");
    }

    #[test]
    fn miri_gate_requires_two_routable_convs_and_two_threads() {
        let m = two_conv_comp();
        let comp = &m.comps[m.entry];
        // No DB (forced under Miri anyway): the heuristic path. Two
        // independent convs at >= 2 threads overlap; anything else not.
        let router = OpRouter::with_cost_db(2, None);
        assert!(should_overlap(&router, comp, 2, 3));
        assert!(!should_overlap(&router, comp, 2, 4), "partner is a multiply");
        assert!(!should_overlap(&router, comp, 4, 3), "first op is a multiply");
        let single = OpRouter::with_cost_db(1, None);
        assert!(!should_overlap(&single, comp, 2, 3), "one thread: nothing to overlap onto");
    }

    #[test]
    fn miri_gate_scaling_threshold_cold_and_warm() {
        let cfg = ConvConfig::square(1, 16, 16, 4, 3, 1);
        let geom = costdb::geom_sig(&cfg);
        let record = |db: &CostDb, threads: usize, ns: f64| {
            db.record(
                CostKey::conv(Component::Fwd, &cfg, 0.5, threads, "t", SkipMode::Dense),
                ns,
            );
        };
        // Detached DB and cold keys both allow (exploration).
        assert!(scaling_underfills(None, DbComponent::Fwd, &geom, 2, "t"));
        let db = CostDb::in_memory();
        assert!(scaling_underfills(Some(&db), DbComponent::Fwd, &geom, 2, "t"), "cold slice");
        record(&db, 1, 2000.0);
        assert!(scaling_underfills(Some(&db), DbComponent::Fwd, &geom, 2, "t"), "t-row cold");
        // Near-linear scaling (2000 -> 1050, efficiency ~0.95): the op
        // fills the pool; keep it sequential.
        record(&db, 2, 1050.0);
        assert!(!scaling_underfills(Some(&db), DbComponent::Fwd, &geom, 2, "t"));
        // Poor scaling (2000 -> 1900, efficiency ~0.53 < 0.6): a worker
        // idles; co-schedule. Fresh DB so the EMA doesn't mix samples.
        let db2 = CostDb::in_memory();
        record(&db2, 1, 2000.0);
        record(&db2, 2, 1900.0);
        assert!(scaling_underfills(Some(&db2), DbComponent::Fwd, &geom, 2, "t"));
        // Mismatched backend slices stay invisible -> cold -> allow.
        assert!(scaling_underfills(Some(&db), DbComponent::Fwd, &geom, 2, "other"));
    }

    #[test]
    fn miri_planner_join_runs_both_and_overlap_matches_gate() {
        let m = two_conv_comp();
        let comp = &m.comps[m.entry];
        let router = Arc::new(OpRouter::with_cost_db(2, None));
        let p = planner(&router);
        assert!((p.overlap)(comp, 2, 3));
        assert!(!(p.overlap)(comp, 2, 4));
        let hits = std::sync::atomic::AtomicUsize::new(0);
        (p.join)(
            Box::new(|| {
                hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }),
            Box::new(|| {
                hits.fetch_add(10, std::sync::atomic::Ordering::Relaxed);
            }),
        );
        assert_eq!(hits.load(std::sync::atomic::Ordering::Relaxed), 11);
        assert_eq!(router.overlap_pairs(), 1);
    }
}
