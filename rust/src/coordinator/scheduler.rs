//! Output-parallel row-sweep scheduler (§3.2.2) for **all three** training
//! components.
//!
//! SparseTrain parallelizes at output-row × tile granularity; this module
//! carries that scheme through the full training triad:
//!
//! | component | task grid | tasks | disjoint writes |
//! |---|---|---|---|
//! | FWD ([`Scheduler::run_fwd`]) | `(i, oy, qb)` | `N·H'·K/Q` | output rows `Y[i][qb·Q..][oy]` |
//! | BWI ([`Scheduler::run_bwi`]) | `(i, iy, cb)` | `N·H·C/Q` | input-gradient rows `∂D[i][cb·Q..][iy]` |
//! | BWW ([`Scheduler::run_bww`]) | `(qb, c)` | `(K/Q)·C` | filter-gradient tiles `∂G[qb·Q..][c][*][*]` |
//!
//! ## The slice-view contract (who splits, who owns, why it's safe)
//!
//! Each run splits the output tensor into **owned disjoint task views**
//! *before* any worker starts — [`ActTensor::par_row_tiles_mut`] for
//! FWD/BWI rows, [`FilterTensor::par_qc_tiles_mut`] for BWW tiles. The
//! split is built on `chunks_mut`/`split_at_mut`, so every element belongs
//! to exactly one view and the views are non-aliasing `&mut` slices by
//! construction. [`ThreadPool::for_chunk_slices`] then hands each chunk
//! worker an **exclusive `&mut` sub-slice** of the view vector; a task
//! writes only through its own view (which also carries the `(i, y, qb)` /
//! `(qb, c)` index metadata, so tasks no longer recompute it).
//!
//! The split means data-race freedom is *proved by the borrow checker*,
//! not asserted by a safety comment: there is no `unsafe` anywhere in the
//! scheduler, no `Send`/`Sync` wrapper smuggling a whole-tensor `*mut`
//! across threads (the former raw-pointer idiom is retired), and the whole
//! parallel triad runs cleanly under `cargo +nightly miri test`. Workers
//! need no locks or
//! atomics on tensor data — only the chunk cursor and the stats merge
//! below. FWD/BWI parallelize over images × rows (the naïve input-parallel
//! alternative would need atomic output updates); BWW instead tiles the
//! *filter gradient*: §3.4's minibatch vectorization makes every sweep's
//! dG destination minibatch-invariant, so partitioning by `(Q-tile, input
//! channel)` gives atomic-free weight-gradient accumulation with no
//! per-thread dG slabs or post-barrier reduction.
//!
//! **Determinism.** The serial kernels iterate the *same* views in task
//! order, and each output element is written by exactly one task in the
//! same inner iteration order — so the parallel numerics are bit-identical
//! to the serial kernels for all three components (not merely allclose).
//!
//! **Stats merge.** Each chunk accumulates a private [`KernelStats`] and
//! merges it into the shared report under a mutex after its last task;
//! every counter is a sum (and `filter_bytes_per_sweep` a max), so the
//! merged stats equal the serial kernel's counters exactly, regardless of
//! thread count or chunk assignment. The per-sweep filter-footprint floor
//! is applied once after the merge, mirroring the serial kernels.
//!
//! **Zero-alloc hot path on persistent workers.** Each run hoists the
//! register plan, the sweep geometry / tap tables and the SIMD
//! [`Backend`] out of the task bodies, and
//! [`ThreadPool::for_chunk_slices_with`] gives every worker thread one
//! reusable [`Scratch`] accumulator — no task allocates, re-plans or
//! re-detects CPU features. Since ISSUE 5 the pool's workers are
//! **persistent** (spawned once, parked on a condvar between launches), so
//! repeated launches — e.g. the five convolutions of every kernel-routed
//! trainer step — stop paying a thread spawn/join round trip per call;
//! the scheduler itself still contains zero `unsafe` and runs under the
//! Miri CI gate. The backend is fixed at scheduler construction
//! ([`Scheduler::with_backend`] pins it for parity tests), and since every
//! backend computes bit-identical fused multiply-adds, the serial-parity
//! and cross-thread determinism guarantees above are backend-independent.
//!
//! **Feedback-driven chunk sizing (ISSUE 8).** Each launch stamps every
//! chunk's wall time (monotonic clock, disabled under Miri) next to the
//! long-reported `tasks_per_chunk`; a per-(component, task-count) tuner
//! doubles the chunks-per-worker multiplier when the slowest chunk
//! dominates (max/mean > 1.5) and decays it when chunks finish evenly,
//! bounded at 32×. Because every task owns a disjoint output view, chunk
//! count can never change numerics — the serial-parity and stats-merge
//! guarantees above hold for *any* chunking, so adaptation is pure
//! wall-time tuning.

use crate::kernels::direct::SweepGeom;
use crate::kernels::regalloc::{plan_bww, plan_fwd};
use crate::kernels::simd::{self, Backend};
use crate::kernels::{
    sparse_bwi, sparse_bww, sparse_fwd, Component, ConvConfig, KernelStats, Scratch, SkipMode,
};
use crate::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use crate::util::threadpool::ThreadPool;
use crate::V;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default chunks-per-worker: a few chunks per thread so early-finishing
/// threads rebalance, without shredding locality.
const CHUNK_MULT_DEFAULT: usize = 4;
/// Upper bound for the feedback-driven multiplier — past this, chunk
/// bookkeeping outweighs any remaining balance win.
const CHUNK_MULT_MAX: usize = 32;
/// Max-over-mean chunk-time ratio above which the next launch of the same
/// shape gets finer chunks.
const IMBALANCE_SPLIT: f64 = 1.5;
/// Ratio below which a raised multiplier decays back toward the default.
const IMBALANCE_RELAX: f64 = 1.1;

/// Feedback-driven chunk sizing (ISSUE 8 satellite): every run already
/// reports `tasks_per_chunk`, and now per-chunk wall times; when the
/// slowest chunk dominates (dynamic sparsity makes task cost uneven —
/// §3.2.2's whole point), the next launch of the *same* (component,
/// task-count) shape uses more, finer chunks so the pool's dynamic
/// claiming can rebalance; when chunks finish evenly the multiplier
/// decays back. Chunk count never affects numerics (each task owns its
/// output view), so adaptation is pure wall-time tuning.
struct ChunkTuner {
    mult: Mutex<HashMap<(u8, usize), usize>>,
}

impl ChunkTuner {
    fn new() -> ChunkTuner {
        ChunkTuner { mult: Mutex::new(HashMap::new()) }
    }

    fn multiplier(&self, key: (u8, usize)) -> usize {
        *self.mult.lock().unwrap().get(&key).unwrap_or(&CHUNK_MULT_DEFAULT)
    }

    fn observe(&self, key: (u8, usize), threads: usize, chunk_ns: &[u64], tasks: &[usize]) {
        if threads < 2 {
            return; // single worker: chunking cannot rebalance anything
        }
        let Some(imb) = imbalance(chunk_ns, tasks) else { return };
        let mut map = self.mult.lock().unwrap();
        let m = map.entry(key).or_insert(CHUNK_MULT_DEFAULT);
        if imb > IMBALANCE_SPLIT && *m < CHUNK_MULT_MAX {
            *m *= 2;
        } else if imb < IMBALANCE_RELAX && *m > CHUNK_MULT_DEFAULT {
            *m /= 2;
        }
    }
}

/// Max-over-mean across the chunks that actually ran, preferring wall
/// times and falling back to task counts when no times were captured
/// (Miri, or a future clockless build). `None` when fewer than two
/// chunks ran — nothing to balance.
fn imbalance(chunk_ns: &[u64], tasks: &[usize]) -> Option<f64> {
    let vals: Vec<f64> = if chunk_ns.iter().any(|&v| v > 0) {
        chunk_ns.iter().filter(|&&v| v > 0).map(|&v| v as f64).collect()
    } else {
        tasks.iter().filter(|&&t| t > 0).map(|&t| t as f64).collect()
    };
    if vals.len() < 2 {
        return None;
    }
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    let max = vals.iter().cloned().fold(f64::MIN, f64::max);
    if mean > 0.0 {
        Some(max / mean)
    } else {
        None
    }
}

/// Monotonic per-chunk stamp; disabled under Miri (the isolated
/// interpreter rejects host clocks), where the tuner then falls back to
/// task-count balance.
fn chunk_clock() -> Option<std::time::Instant> {
    if cfg!(miri) {
        None
    } else {
        Some(std::time::Instant::now())
    }
}

fn comp_tag(comp: Component) -> u8 {
    match comp {
        Component::Fwd => 0,
        Component::Bwi => 1,
        Component::Bww => 2,
    }
}

/// A parallel executor for SparseTrain kernels.
///
/// The SIMD [`Backend`] is resolved once at construction (the process-wide
/// dispatch) and threaded into every task; each worker thread owns one
/// reusable [`Scratch`] accumulator (created by the pool's per-worker
/// `init`), so the scheduled hot path performs no heap allocation and no
/// repeated feature detection.
pub struct Scheduler {
    pool: ThreadPool,
    backend: Backend,
    tuner: ChunkTuner,
    /// Busy-worker EMA over recent launches (see
    /// [`Scheduler::pool_utilization`]); `None` until a timed
    /// multi-thread launch has run.
    util_ema: Mutex<Option<f64>>,
}

/// Execution report: merged kernel stats + load-balance info.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub stats: KernelStats,
    /// Tasks executed per worker chunk (for balance assertions).
    pub tasks_per_chunk: Vec<usize>,
    /// Wall nanoseconds each chunk spent in its worker closure (zero for
    /// chunks that never ran, and everywhere under Miri). Feeds the
    /// chunk-size tuner; exported for balance diagnostics.
    pub chunk_ns: Vec<u64>,
    pub total_tasks: usize,
}

impl Scheduler {
    pub fn new(threads: usize) -> Scheduler {
        Scheduler {
            pool: ThreadPool::new(threads),
            backend: simd::dispatch(),
            tuner: ChunkTuner::new(),
            util_ema: Mutex::new(None),
        }
    }

    /// A scheduler sized to the host's available parallelism.
    pub fn with_host_parallelism() -> Scheduler {
        Scheduler {
            pool: ThreadPool::with_host_parallelism(),
            backend: simd::dispatch(),
            tuner: ChunkTuner::new(),
            util_ema: Mutex::new(None),
        }
    }

    /// A scheduler pinned to an explicit backend (parity tests, benches).
    pub fn with_backend(threads: usize, backend: Backend) -> Scheduler {
        Scheduler {
            pool: ThreadPool::new(threads),
            backend,
            tuner: ChunkTuner::new(),
            util_ema: Mutex::new(None),
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The SIMD backend every scheduled task runs on.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The persistent worker pool — shared with the op router's GEMM so
    /// routed `dot` instructions reuse the same parked workers as the
    /// sparse conv kernels.
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Number of parallel FWD tasks for a config (§3.2.2: `N·H'·K/Q`).
    pub fn fwd_task_count(cfg: &ConvConfig) -> usize {
        let plan = plan_fwd(cfg.k, cfg.r);
        cfg.n * cfg.out_h() * (cfg.k / plan.q)
    }

    /// Number of parallel BWI tasks: `N·H·C/Q` — BWI scatters into input
    /// rows, and its accumulators are C-vectors, so the Q tiling is over
    /// input channels (§3.3).
    pub fn bwi_task_count(cfg: &ConvConfig) -> usize {
        let plan = plan_fwd(cfg.c, cfg.r);
        cfg.n * cfg.h * (cfg.c / plan.q)
    }

    /// Number of parallel BWW tasks: `(K/Q)·C` — one per disjoint filter-
    /// gradient tile (§3.4).
    pub fn bww_task_count(cfg: &ConvConfig) -> usize {
        let plan = plan_bww(cfg.k, cfg.r);
        (cfg.k / plan.q) * cfg.c
    }

    /// Chunk count for a launch: the tuned chunks-per-worker multiplier
    /// for this (component, task-count) shape — starts at
    /// [`CHUNK_MULT_DEFAULT`], adapted by observed imbalance.
    fn chunks_for(&self, comp: Component, total: usize) -> usize {
        (self.pool.threads() * self.tuner.multiplier((comp_tag(comp), total))).min(total.max(1))
    }

    /// The current chunks-per-worker multiplier for a shape (introspection
    /// for tests and diagnostics).
    pub fn chunk_multiplier(&self, comp: Component, total_tasks: usize) -> usize {
        self.tuner.multiplier((comp_tag(comp), total_tasks))
    }

    /// Busy-worker utilization EMA over recent kernel launches:
    /// `Σ chunk_ns / (threads · max chunk_ns)` per launch (clamped to 1),
    /// folded at EMA weight 0.25 (matching the cost DB). A value well
    /// below 1 means the pool sat under-filled during sweeps — exactly
    /// the slack the ISSUE 10 pipeline executor co-schedules into. `None`
    /// single-threaded, under Miri (no clocks), or before any launch.
    pub fn pool_utilization(&self) -> Option<f64> {
        *self.util_ema.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Fold one launch's per-chunk wall times into the utilization EMA.
    fn note_utilization(&self, chunk_ns: &[u64]) {
        let threads = self.pool.threads();
        if threads < 2 {
            return;
        }
        let busy: u64 = chunk_ns.iter().sum();
        let max = chunk_ns.iter().copied().max().unwrap_or(0);
        if busy == 0 || max == 0 {
            return; // clockless (Miri) or nothing ran
        }
        let frac = (busy as f64 / (threads as f64 * max as f64)).min(1.0);
        let mut ema = self.util_ema.lock().unwrap_or_else(|p| p.into_inner());
        *ema = Some(match *ema {
            Some(prev) => 0.25 * frac + 0.75 * prev,
            None => frac,
        });
    }

    /// Run SparseTrain FWD with output parallelism. Tasks are `(i, oy, qb)`
    /// triples; each receives an owned disjoint [`crate::tensor::RowTileMut`]
    /// view of `y` and writes nothing else.
    pub fn run_fwd(
        &self,
        cfg: &ConvConfig,
        d: &ActTensor,
        g: &FilterTensor,
        y: &mut ActTensor,
        mode: SkipMode,
    ) -> RunReport {
        cfg.validate().expect("invalid conv config");
        let plan = plan_fwd(cfg.k, cfg.r);
        let geom = SweepGeom::fwd(cfg);
        let bk = self.backend;
        let total = Self::fwd_task_count(cfg);
        let chunks = self.chunks_for(Component::Fwd, total);

        // Split y into one view per task, in scheduler task order.
        let mut views = y.par_row_tiles_mut(plan.q / V);
        debug_assert_eq!(views.len(), total);
        let merged: Mutex<KernelStats> = Mutex::new(KernelStats::new());
        let tasks_per_chunk: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();
        let chunk_ns: Vec<AtomicU64> = (0..chunks).map(|_| AtomicU64::new(0)).collect();

        self.pool.for_chunk_slices_with(
            &mut views,
            chunks,
            Scratch::new,
            |ci, _start, chunk, scratch| {
                let t0 = chunk_clock();
                let mut local = KernelStats::new();
                for view in chunk.iter_mut() {
                    sparse_fwd::fwd_task(
                        cfg, d, g, view, mode, &plan, &geom, bk, scratch, &mut local,
                    );
                    tasks_per_chunk[ci].fetch_add(1, Ordering::Relaxed);
                }
                merged.lock().unwrap().merge(&local);
                if let Some(t0) = t0 {
                    chunk_ns[ci].store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            },
        );

        let mut stats = merged.into_inner().unwrap();
        // Serial-parity: the whole-layer kernels record the per-sweep
        // filter footprint once after their loops; do the same post-merge.
        stats.filter_bytes_per_sweep =
            stats.filter_bytes_per_sweep.max((cfg.s * cfg.r * plan.q * V * 4) as u64);
        let tasks_per_chunk: Vec<usize> =
            tasks_per_chunk.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let chunk_ns: Vec<u64> = chunk_ns.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        self.note_utilization(&chunk_ns);
        self.tuner.observe(
            (comp_tag(Component::Fwd), total),
            self.pool.threads(),
            &chunk_ns,
            &tasks_per_chunk,
        );
        RunReport { stats, tasks_per_chunk, chunk_ns, total_tasks: total }
    }

    /// Run SparseTrain BWI with output parallelism over `(i, iy, cb)`
    /// tasks: each task scatters every ∂L/∂Y row feeding input row `iy`
    /// into its owned disjoint view of `dd` (one input-gradient row × one
    /// Q tile of input channels).
    ///
    /// `gt` is the channel-transposed filter
    /// ([`FilterTensor::transpose_channels`]); `dd` must be
    /// zero-initialized, as for the serial [`sparse_bwi::bwi`].
    pub fn run_bwi(
        &self,
        cfg: &ConvConfig,
        dy: &ActTensor,
        gt: &FilterTensor,
        dd: &mut ActTensor,
        mode: SkipMode,
    ) -> RunReport {
        cfg.validate().expect("invalid conv config");
        let plan = plan_fwd(cfg.c, cfg.r); // BWI accumulators are C-vectors
        let taps = sparse_bwi::bwi_col_taps(cfg);
        let bk = self.backend;
        let total = Self::bwi_task_count(cfg);
        let chunks = self.chunks_for(Component::Bwi, total);

        // Split dd into one view per task, in scheduler task order.
        let mut views = dd.par_row_tiles_mut(plan.q / V);
        debug_assert_eq!(views.len(), total);
        let merged: Mutex<KernelStats> = Mutex::new(KernelStats::new());
        let tasks_per_chunk: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();
        let chunk_ns: Vec<AtomicU64> = (0..chunks).map(|_| AtomicU64::new(0)).collect();

        self.pool.for_chunk_slices_with(
            &mut views,
            chunks,
            Scratch::new,
            |ci, _start, chunk, scratch| {
                let t0 = chunk_clock();
                let mut local = KernelStats::new();
                for view in chunk.iter_mut() {
                    sparse_bwi::bwi_task(
                        cfg, dy, gt, view, &taps, mode, &plan, bk, scratch, &mut local,
                    );
                    tasks_per_chunk[ci].fetch_add(1, Ordering::Relaxed);
                }
                merged.lock().unwrap().merge(&local);
                if let Some(t0) = t0 {
                    chunk_ns[ci].store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            },
        );

        let mut stats = merged.into_inner().unwrap();
        stats.filter_bytes_per_sweep =
            stats.filter_bytes_per_sweep.max((cfg.s * cfg.r * plan.q * V * 4) as u64);
        let tasks_per_chunk: Vec<usize> =
            tasks_per_chunk.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let chunk_ns: Vec<u64> = chunk_ns.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        self.note_utilization(&chunk_ns);
        self.tuner.observe(
            (comp_tag(Component::Bwi), total),
            self.pool.threads(),
            &chunk_ns,
            &tasks_per_chunk,
        );
        RunReport { stats, tasks_per_chunk, chunk_ns, total_tasks: total }
    }

    /// Run SparseTrain BWW in parallel over `(qb, c)` tasks — one per
    /// disjoint filter-gradient tile view, so weight-gradient accumulation
    /// is atomic-free (§3.4: the minibatch-vectorized sweep's dG
    /// destination is minibatch-invariant, making the filter gradient
    /// partitionable).
    ///
    /// `d` is the N-tiled input ([`BatchTiledTensor`]); `dg` is accumulated
    /// into, exactly like the serial [`sparse_bww::bww`].
    pub fn run_bww(
        &self,
        cfg: &ConvConfig,
        d: &BatchTiledTensor,
        dy: &ActTensor,
        dg: &mut FilterTensor,
        mode: SkipMode,
    ) -> RunReport {
        cfg.validate().expect("invalid conv config");
        assert!(cfg.n % V == 0, "BWW requires batch size multiple of V (§5.4)");
        let plan = plan_bww(cfg.k, cfg.r);
        let taps = sparse_bww::bww_col_taps(cfg);
        let bk = self.backend;
        let total = Self::bww_task_count(cfg);
        let chunks = self.chunks_for(Component::Bww, total);

        // Split dg into one (qb, c) tile view per task, in task order.
        let mut views = dg.par_qc_tiles_mut(plan.q / V);
        debug_assert_eq!(views.len(), total);
        let merged: Mutex<KernelStats> = Mutex::new(KernelStats::new());
        let tasks_per_chunk: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();
        let chunk_ns: Vec<AtomicU64> = (0..chunks).map(|_| AtomicU64::new(0)).collect();

        self.pool.for_chunk_slices_with(
            &mut views,
            chunks,
            Scratch::new,
            |ci, _start, chunk, scratch| {
                let t0 = chunk_clock();
                let mut local = KernelStats::new();
                for view in chunk.iter_mut() {
                    sparse_bww::bww_task(
                        cfg, d, dy, view, &taps, mode, &plan, bk, scratch, &mut local,
                    );
                    tasks_per_chunk[ci].fetch_add(1, Ordering::Relaxed);
                }
                merged.lock().unwrap().merge(&local);
                if let Some(t0) = t0 {
                    chunk_ns[ci].store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            },
        );

        let mut stats = merged.into_inner().unwrap();
        stats.filter_bytes_per_sweep =
            stats.filter_bytes_per_sweep.max((cfg.r * plan.q * 4) as u64);
        let tasks_per_chunk: Vec<usize> =
            tasks_per_chunk.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let chunk_ns: Vec<u64> = chunk_ns.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        self.note_utilization(&chunk_ns);
        self.tuner.observe(
            (comp_tag(Component::Bww), total),
            self.pool.threads(),
            &chunk_ns,
            &tasks_per_chunk,
        );
        RunReport { stats, tasks_per_chunk, chunk_ns, total_tasks: total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::reference;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;
    use crate::util::proptest::{check, Config as PropConfig, UsizeIn};

    fn setup(cfg: &ConvConfig, sparsity: f64) -> (ActTensor, FilterTensor) {
        let mut rng = Xorshift::new(1234);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, sparsity);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        (d, g)
    }

    /// Signed, ReLU-sparse gradient tensor shaped like ∂L/∂Y.
    fn setup_dy(cfg: &ConvConfig, sparsity: f64, seed: u64) -> ActTensor {
        let mut rng = Xorshift::new(seed);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_relu_sparse(&mut rng, sparsity);
        for v in dy.data_mut().iter_mut() {
            if *v != 0.0 && rng.bernoulli(0.5) {
                *v = -*v;
            }
        }
        dy
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn parallel_matches_reference() {
        let cfg = ConvConfig::square(2, 32, 64, 8, 3, 1);
        let (d, g) = setup(&cfg, 0.5);
        let sched = Scheduler::new(4);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let report = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
        assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5));
        assert_eq!(report.total_tasks, Scheduler::fwd_task_count(&cfg));
        assert_eq!(report.tasks_per_chunk.iter().sum::<usize>(), report.total_tasks);
    }

    #[test]
    fn miri_pool_utilization_reports_only_on_timed_multithread_runs() {
        let cfg = ConvConfig::square(1, V, V, 6, 3, 1);
        let (d, g) = setup(&cfg, 0.5);

        // Single worker: utilization is meaningless and stays None.
        let s1 = Scheduler::with_backend(1, Backend::scalar());
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        s1.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        assert_eq!(s1.pool_utilization(), None);

        // Two workers: None before any run; after runs, either a valid
        // fraction (timed) or None (clockless — always the case under
        // Miri, possible off-Miri when a tiny launch lands under the
        // clock resolution).
        let s2 = Scheduler::with_backend(2, Backend::scalar());
        assert_eq!(s2.pool_utilization(), None);
        for _ in 0..3 {
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            s2.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        }
        if let Some(u) = s2.pool_utilization() {
            assert!(u > 0.0 && u <= 1.0, "utilization out of range: {u}");
            assert!(!cfg!(miri), "Miri has no clocks; utilization must stay None");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn parallel_stats_match_serial() {
        let cfg = ConvConfig::square(2, 32, 64, 8, 3, 1);
        let (d, g) = setup(&cfg, 0.4);
        let sched = Scheduler::new(3);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let report = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        let mut y2 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut serial = KernelStats::new();
        crate::kernels::sparse_fwd::fwd(&cfg, &d, &g, &mut y2, SkipMode::MaskLoop, &mut serial);
        // every counter (FMA, checks, hist, loads/stores, sweeps) merges
        // to exactly the serial values
        assert_eq!(report.stats, serial);
        assert_eq!(y.data(), y2.data());
    }

    #[test]
    fn task_count_formula() {
        // N·H'·K/Q (§3.2.2)
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let plan = plan_fwd(256, 3);
        assert_eq!(Scheduler::fwd_task_count(&cfg), 16 * 56 * (256 / plan.q));
    }

    #[test]
    fn bwi_bww_task_count_formulas() {
        // BWI: N·H·C/Q with Q planned over C; BWW: (K/Q)·C.
        let cfg = ConvConfig::square(16, 256, 128, 28, 3, 1);
        let pf = plan_fwd(cfg.c, cfg.r);
        assert_eq!(Scheduler::bwi_task_count(&cfg), 16 * 28 * (256 / pf.q));
        let pb = plan_bww(cfg.k, cfg.r);
        assert_eq!(Scheduler::bww_task_count(&cfg), (128 / pb.q) * 256);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn parallel_bwi_matches_serial_and_reference() {
        let cfg = ConvConfig::square(2, 32, 32, 8, 3, 1);
        let dy = setup_dy(&cfg, 0.5, 303);
        let (_, g) = setup(&cfg, 0.0);
        let gt = g.transpose_channels();
        let sched = Scheduler::new(4);

        let mut dd_par = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let report = sched.run_bwi(&cfg, &dy, &gt, &mut dd_par, SkipMode::MaskLoop);

        let mut dd_ser = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut serial = KernelStats::new();
        crate::kernels::sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd_ser, SkipMode::MaskLoop, &mut serial);

        assert_eq!(dd_par.data(), dd_ser.data(), "parallel BWI must be bit-exact");
        assert_eq!(report.stats, serial);
        assert_eq!(report.total_tasks, Scheduler::bwi_task_count(&cfg));
        assert_eq!(report.tasks_per_chunk.iter().sum::<usize>(), report.total_tasks);

        let ddref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
        assert!(allclose(&dd_par.to_nchw(), &ddref, 1e-4, 1e-5));
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn parallel_bww_matches_serial_and_reference() {
        let cfg = ConvConfig::square(16, 32, 32, 6, 3, 1);
        let (dsrc, _) = setup(&cfg, 0.5);
        let d = BatchTiledTensor::from_act(&dsrc);
        let mut rng = Xorshift::new(404);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_uniform(&mut rng, -1.0, 1.0);
        let sched = Scheduler::new(4);

        let mut dg_par = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let report = sched.run_bww(&cfg, &d, &dy, &mut dg_par, SkipMode::MaskLoop);

        let mut dg_ser = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut serial = KernelStats::new();
        crate::kernels::sparse_bww::bww(&cfg, &d, &dy, &mut dg_ser, SkipMode::MaskLoop, &mut serial);

        assert_eq!(dg_par.data(), dg_ser.data(), "parallel BWW must be bit-exact");
        assert_eq!(report.stats, serial);
        assert_eq!(report.total_tasks, Scheduler::bww_task_count(&cfg));
        assert_eq!(report.tasks_per_chunk.iter().sum::<usize>(), report.total_tasks);

        let dgref = reference::conv_bww(&cfg, &dsrc.to_nchw(), &dy.to_nchw());
        assert!(allclose(&dg_par.to_kcsr(), &dgref, 1e-3, 1e-4));
    }

    /// BWW accumulates *into* dg — running two scheduled half-batches must
    /// equal one scheduled full batch (the trainer's gradient-accumulation
    /// invariant, now under parallel execution).
    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn parallel_bww_accumulates() {
        let cfg = ConvConfig::square(16, 16, 16, 5, 3, 1);
        let (dsrc, _) = setup(&cfg, 0.5);
        let d = BatchTiledTensor::from_act(&dsrc);
        let mut rng = Xorshift::new(15);
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_uniform(&mut rng, -1.0, 1.0);
        let sched = Scheduler::new(3);
        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        sched.run_bww(&cfg, &d, &dy, &mut dg, SkipMode::MaskLoop);
        let once = dg.data().to_vec();
        sched.run_bww(&cfg, &d, &dy, &mut dg, SkipMode::MaskLoop);
        let twice: Vec<f32> = once.iter().map(|v| v * 2.0).collect();
        assert!(allclose(dg.data(), &twice, 1e-5, 1e-6));
    }

    /// Acceptance criterion: all three components match the serial kernels
    /// (numerics bit-exact, merged stats identical) for 1–8 threads.
    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn all_components_match_serial_for_threads_1_to_8() {
        let cfg = ConvConfig::square(16, 32, 32, 6, 3, 1);
        let (d, g) = setup(&cfg, 0.5);
        let dy = setup_dy(&cfg, 0.4, 99);
        let gt = g.transpose_channels();
        let dt = BatchTiledTensor::from_act(&d);

        // serial baselines
        let mut y_s = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st_f = KernelStats::new();
        crate::kernels::sparse_fwd::fwd(&cfg, &d, &g, &mut y_s, SkipMode::MaskLoop, &mut st_f);
        let mut dd_s = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st_i = KernelStats::new();
        crate::kernels::sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd_s, SkipMode::MaskLoop, &mut st_i);
        let mut dg_s = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut st_w = KernelStats::new();
        crate::kernels::sparse_bww::bww(&cfg, &dt, &dy, &mut dg_s, SkipMode::MaskLoop, &mut st_w);

        for threads in 1..=8 {
            let sched = Scheduler::new(threads);
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            let rf = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
            assert_eq!(y.data(), y_s.data(), "FWD numerics, threads={threads}");
            assert_eq!(rf.stats, st_f, "FWD stats, threads={threads}");

            let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let ri = sched.run_bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop);
            assert_eq!(dd.data(), dd_s.data(), "BWI numerics, threads={threads}");
            assert_eq!(ri.stats, st_i, "BWI stats, threads={threads}");

            let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            let rw = sched.run_bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop);
            assert_eq!(dg.data(), dg_s.data(), "BWW numerics, threads={threads}");
            assert_eq!(rw.stats, st_w, "BWW stats, threads={threads}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn property_parallel_equals_serial_over_random_shapes() {
        // Property: for random (hw, threads), parallel == serial output.
        let gen = UsizeIn { lo: 0, hi: 6 };
        check(PropConfig { cases: 8, seed: 77, max_shrink_steps: 16 }, &gen, |&case| {
            let hw = 4 + case; // 4..=10
            let threads = 1 + case % 4;
            let cfg = ConvConfig::square(1, 16, 32, hw, 3, 1);
            let (d, g) = setup(&cfg, 0.5);
            let sched = Scheduler::new(threads);
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
            let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
            if allclose(&y.to_nchw(), &yref, 1e-4, 1e-5) {
                Ok(())
            } else {
                Err(format!("mismatch at hw={hw} threads={threads}"))
            }
        });
    }

    /// Property: parallel BWI equals the serial kernel bit-for-bit (stats
    /// included) and the scalar reference within tolerance, across random
    /// spatial sizes, strides and thread counts.
    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn property_parallel_bwi_equals_serial_over_random_shapes() {
        let gen = UsizeIn { lo: 0, hi: 7 };
        check(PropConfig { cases: 8, seed: 909, max_shrink_steps: 16 }, &gen, |&case| {
            let hw = 4 + case; // 4..=11
            let threads = 1 + case % 4;
            let stride = 1 + case % 2;
            let cfg = ConvConfig::square(1, 32, 16, hw, 3, stride);
            if cfg.validate().is_err() {
                return Ok(());
            }
            let mut rng = Xorshift::new(4000 + case as u64);
            let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            dy.fill_relu_sparse(&mut rng, 0.5);
            let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            g.fill_uniform(&mut rng, -0.5, 0.5);
            let gt = g.transpose_channels();

            let sched = Scheduler::new(threads);
            let mut dd_par = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let report = sched.run_bwi(&cfg, &dy, &gt, &mut dd_par, SkipMode::MaskLoop);
            let mut dd_ser = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            let mut st = KernelStats::new();
            crate::kernels::sparse_bwi::bwi(
                &cfg, &dy, &gt, &mut dd_ser, SkipMode::MaskLoop, &mut st,
            );
            if dd_par.data() != dd_ser.data() {
                return Err(format!("BWI numerics diverge at hw={hw} threads={threads}"));
            }
            if report.stats != st {
                return Err(format!("BWI stats diverge at hw={hw} threads={threads}"));
            }
            let ddref = reference::conv_bwi(&cfg, &dy.to_nchw(), &g.to_kcsr());
            if !allclose(&dd_par.to_nchw(), &ddref, 1e-4, 1e-5) {
                return Err(format!("BWI reference mismatch at hw={hw} stride={stride}"));
            }
            Ok(())
        });
    }

    /// Property: parallel BWW equals the serial kernel bit-for-bit (stats
    /// included) and the scalar reference within tolerance, across random
    /// spatial sizes and thread counts.
    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn property_parallel_bww_equals_serial_over_random_shapes() {
        let gen = UsizeIn { lo: 0, hi: 5 };
        check(PropConfig { cases: 6, seed: 611, max_shrink_steps: 16 }, &gen, |&case| {
            let hw = 4 + case; // 4..=9
            let threads = 1 + case % 4;
            let cfg = ConvConfig::square(16, 16, 32, hw, 3, 1);
            let mut rng = Xorshift::new(6000 + case as u64);
            let mut dsrc = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
            dsrc.fill_relu_sparse(&mut rng, 0.5);
            let d = BatchTiledTensor::from_act(&dsrc);
            let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            dy.fill_uniform(&mut rng, -1.0, 1.0);

            let sched = Scheduler::new(threads);
            let mut dg_par = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            let report = sched.run_bww(&cfg, &d, &dy, &mut dg_par, SkipMode::MaskLoop);
            let mut dg_ser = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
            let mut st = KernelStats::new();
            crate::kernels::sparse_bww::bww(&cfg, &d, &dy, &mut dg_ser, SkipMode::MaskLoop, &mut st);
            if dg_par.data() != dg_ser.data() {
                return Err(format!("BWW numerics diverge at hw={hw} threads={threads}"));
            }
            if report.stats != st {
                return Err(format!("BWW stats diverge at hw={hw} threads={threads}"));
            }
            let dgref = reference::conv_bww(&cfg, &dsrc.to_nchw(), &dy.to_nchw());
            if !allclose(&dg_par.to_kcsr(), &dgref, 1e-3, 1e-4) {
                return Err(format!("BWW reference mismatch at hw={hw}"));
            }
            Ok(())
        });
    }

    /// The reduced-geometry triad the Miri CI gate runs: all three
    /// components through the parallel scheduler on a tiny layer,
    /// bit-exact against the serial kernels with identical merged stats.
    /// Natively this is a fast smoke test; under `cargo +nightly miri
    /// test` it is the proof that the slice-view scheduler is free of UB
    /// and data races (the retired raw-pointer idiom failed exactly here).
    #[test]
    fn miri_reduced_triad_matches_serial() {
        // n = V so BWW runs; spatial size shrinks further under the
        // interpreter to keep the CI gate fast.
        let hw = if cfg!(miri) { 3 } else { 6 };
        let cfg = ConvConfig::square(V, 16, 16, hw, 3, 1);
        let (d, g) = setup(&cfg, 0.5);
        let dy = setup_dy(&cfg, 0.4, 17);
        let gt = g.transpose_channels();
        let dt = BatchTiledTensor::from_act(&d);

        let mut y_s = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut st_f = KernelStats::new();
        crate::kernels::sparse_fwd::fwd(&cfg, &d, &g, &mut y_s, SkipMode::MaskLoop, &mut st_f);
        let mut dd_s = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut st_i = KernelStats::new();
        crate::kernels::sparse_bwi::bwi(&cfg, &dy, &gt, &mut dd_s, SkipMode::MaskLoop, &mut st_i);
        let mut dg_s = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut st_w = KernelStats::new();
        crate::kernels::sparse_bww::bww(&cfg, &dt, &dy, &mut dg_s, SkipMode::MaskLoop, &mut st_w);

        // 3 threads exercises real cross-thread view hand-off without
        // making the interpreted run crawl.
        let sched = Scheduler::new(3);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let rf = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        assert_eq!(y.data(), y_s.data(), "FWD numerics");
        assert_eq!(rf.stats, st_f, "FWD stats");

        let mut dd = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let ri = sched.run_bwi(&cfg, &dy, &gt, &mut dd, SkipMode::MaskLoop);
        assert_eq!(dd.data(), dd_s.data(), "BWI numerics");
        assert_eq!(ri.stats, st_i, "BWI stats");

        let mut dg = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let rw = sched.run_bww(&cfg, &dt, &dy, &mut dg, SkipMode::MaskLoop);
        assert_eq!(dg.data(), dg_s.data(), "BWW numerics");
        assert_eq!(rw.stats, st_w, "BWW stats");
    }

    /// A scheduler pinned to the forced-scalar backend must be bit-exact
    /// against the dispatched-backend scheduler on all three components —
    /// the scheduler-level half of the SIMD-vs-scalar parity contract.
    #[test]
    fn miri_scalar_and_dispatched_schedulers_bitexact() {
        let hw = if cfg!(miri) { 3 } else { 6 };
        let cfg = ConvConfig::square(V, 16, 16, hw, 3, 1);
        let (d, g) = setup(&cfg, 0.5);
        let dy = setup_dy(&cfg, 0.4, 55);
        let gt = g.transpose_channels();
        let dt = BatchTiledTensor::from_act(&d);
        let auto = Scheduler::new(3);
        let scalar = Scheduler::with_backend(3, crate::kernels::simd::Backend::scalar());

        let mut y_a = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut y_s = y_a.clone();
        let ra = auto.run_fwd(&cfg, &d, &g, &mut y_a, SkipMode::MaskLoop);
        let rs = scalar.run_fwd(&cfg, &d, &g, &mut y_s, SkipMode::MaskLoop);
        assert_eq!(y_a.data(), y_s.data(), "FWD backend parity");
        assert_eq!(ra.stats, rs.stats);

        let mut dd_a = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        let mut dd_s = dd_a.clone();
        let ra = auto.run_bwi(&cfg, &dy, &gt, &mut dd_a, SkipMode::MaskLoop);
        let rs = scalar.run_bwi(&cfg, &dy, &gt, &mut dd_s, SkipMode::MaskLoop);
        assert_eq!(dd_a.data(), dd_s.data(), "BWI backend parity");
        assert_eq!(ra.stats, rs.stats);

        let mut dg_a = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        let mut dg_s = dg_a.clone();
        let ra = auto.run_bww(&cfg, &dt, &dy, &mut dg_a, SkipMode::MaskLoop);
        let rs = scalar.run_bww(&cfg, &dt, &dy, &mut dg_s, SkipMode::MaskLoop);
        assert_eq!(dg_a.data(), dg_s.data(), "BWW backend parity");
        assert_eq!(ra.stats, rs.stats);
    }

    #[test]
    #[cfg_attr(miri, ignore = "too slow under miri; miri_* tests cover the reduced set")]
    fn load_balance_reasonable() {
        let cfg = ConvConfig::square(2, 32, 64, 16, 3, 1);
        let (d, g) = setup(&cfg, 0.5);
        let sched = Scheduler::new(4);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let report = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        let nonempty = report.tasks_per_chunk.iter().filter(|&&t| t > 0).count();
        assert!(nonempty > 1, "work not spread: {:?}", report.tasks_per_chunk);
        assert_eq!(report.chunk_ns.len(), report.tasks_per_chunk.len());
    }

    // -----------------------------------------------------------------
    // Chunk-size feedback (ISSUE 8 satellite): deterministic unit tests
    // on synthetic imbalance observations — no clocks, miri-safe.
    // -----------------------------------------------------------------

    #[test]
    fn miri_imbalance_math() {
        // Even chunks → ratio 1.0; one hot chunk → max/mean.
        assert_eq!(imbalance(&[100, 100, 100, 100], &[1; 4]), Some(1.0));
        let imb = imbalance(&[100, 100, 100, 700], &[1; 4]).unwrap();
        assert!((imb - 700.0 / 250.0).abs() < 1e-12);
        // Zero-ns chunks (never ran) are excluded.
        assert_eq!(imbalance(&[100, 100, 0, 0], &[1, 1, 0, 0]), Some(1.0));
        // No times at all (Miri) → task-count fallback.
        assert_eq!(imbalance(&[0, 0, 0], &[2, 2, 4]), Some(4.0 / (8.0 / 3.0)));
        // Fewer than two active chunks → nothing to balance.
        assert_eq!(imbalance(&[100, 0, 0], &[1, 0, 0]), None);
        assert_eq!(imbalance(&[], &[]), None);
    }

    #[test]
    fn miri_chunk_tuner_splits_caps_and_decays() {
        let t = ChunkTuner::new();
        let key = (comp_tag(Component::Fwd), 128);
        assert_eq!(t.multiplier(key), CHUNK_MULT_DEFAULT);
        // Heavy imbalance doubles the multiplier, up to the cap.
        let skew = [100u64, 100, 100, 1000];
        let tasks = [1usize; 4];
        let mut expect = CHUNK_MULT_DEFAULT;
        for _ in 0..8 {
            t.observe(key, 4, &skew, &tasks);
            expect = (expect * 2).min(CHUNK_MULT_MAX);
            assert_eq!(t.multiplier(key), expect);
        }
        assert_eq!(t.multiplier(key), CHUNK_MULT_MAX);
        // Even chunks decay it back down to (not below) the default.
        let even = [100u64; 4];
        for _ in 0..8 {
            t.observe(key, 4, &even, &tasks);
        }
        assert_eq!(t.multiplier(key), CHUNK_MULT_DEFAULT);
        // Other keys are untouched.
        assert_eq!(t.multiplier((comp_tag(Component::Bww), 128)), CHUNK_MULT_DEFAULT);
        // Single-threaded runs never adapt.
        t.observe(key, 1, &skew, &tasks);
        assert_eq!(t.multiplier(key), CHUNK_MULT_DEFAULT);
        // Mild imbalance (between the thresholds) holds steady.
        t.observe(key, 4, &[100, 100, 100, 130], &tasks);
        assert_eq!(t.multiplier(key), CHUNK_MULT_DEFAULT);
    }

    /// End to end through the scheduler: a run's observed balance feeds
    /// the *next* launch of the same shape, and whatever chunk count
    /// results, numerics stay bit-identical (chunking owns disjoint
    /// views; the invariant the adaptive path must never break).
    #[test]
    fn miri_adapted_chunking_keeps_numerics() {
        let hw = if cfg!(miri) { 3 } else { 6 };
        let cfg = ConvConfig::square(1, 16, 16, hw, 3, 1);
        let (d, g) = setup(&cfg, 0.9);
        let sched = Scheduler::new(2);
        let mut first = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let r1 = sched.run_fwd(&cfg, &d, &g, &mut first, SkipMode::MaskLoop);
        // Force the finest chunking and re-run: bit-identical output and
        // identical merged stats regardless of the multiplier.
        {
            let mut m = sched.tuner.mult.lock().unwrap();
            m.insert((comp_tag(Component::Fwd), r1.total_tasks), CHUNK_MULT_MAX);
        }
        let mut second = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let r2 = sched.run_fwd(&cfg, &d, &g, &mut second, SkipMode::MaskLoop);
        assert_eq!(first.data(), second.data(), "chunking changed numerics");
        assert_eq!(r1.stats, r2.stats, "chunking changed merged stats");
        assert_eq!(r2.tasks_per_chunk.iter().sum::<usize>(), r2.total_tasks);
    }
}
