//! Output-parallel row-sweep scheduler (§3.2.2).
//!
//! SparseTrain parallelizes at output-row × K-tile granularity: the FWD
//! task grid is `(i, oy, qb)` with `N·H'·K/Q` independent tasks (vs just
//! `N` for the naïve input-parallel version, which would need atomic output
//! updates). Tasks write disjoint output rows, so workers need no locks on
//! the data — only on the shared task cursor.

use crate::kernels::regalloc::plan_fwd;
use crate::kernels::{sparse_fwd, ConvConfig, KernelStats, SkipMode};
use crate::tensor::{ActTensor, FilterTensor};
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A parallel executor for SparseTrain kernels.
pub struct Scheduler {
    pool: ThreadPool,
}

/// Execution report: merged kernel stats + load-balance info.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub stats: KernelStats,
    /// Tasks executed per worker chunk (for balance assertions).
    pub tasks_per_chunk: Vec<usize>,
    pub total_tasks: usize,
}

impl Scheduler {
    pub fn new(threads: usize) -> Scheduler {
        Scheduler { pool: ThreadPool::new(threads) }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Number of parallel FWD tasks for a config (§3.2.2: `N·H'·K/Q`).
    pub fn fwd_task_count(cfg: &ConvConfig) -> usize {
        let plan = plan_fwd(cfg.k, cfg.r);
        cfg.n * cfg.out_h() * (cfg.k / plan.q)
    }

    /// Run SparseTrain FWD with output parallelism. Tasks are `(i, oy, qb)`
    /// triples; each writes a disjoint slice of `y`.
    pub fn run_fwd(
        &self,
        cfg: &ConvConfig,
        d: &ActTensor,
        g: &FilterTensor,
        y: &mut ActTensor,
        mode: SkipMode,
    ) -> RunReport {
        let plan = plan_fwd(cfg.k, cfg.r);
        let kq_count = cfg.k / plan.q;
        let oh = cfg.out_h();
        let total = Self::fwd_task_count(cfg);
        let chunks = (self.pool.threads() * 4).min(total.max(1));

        // Workers accumulate into per-chunk outputs merged at the end.
        // Because tasks write disjoint rows, we share `y` through a raw
        // pointer wrapper; disjointness is guaranteed by the task grid.
        struct YPtr(*mut ActTensor);
        unsafe impl Send for YPtr {}
        unsafe impl Sync for YPtr {}
        let yptr = YPtr(y as *mut ActTensor);

        let merged: Mutex<KernelStats> = Mutex::new(KernelStats::new());
        let tasks_per_chunk: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();

        self.pool.for_chunks(total, chunks, |ci, start, end| {
            let mut local = KernelStats::new();
            for t in start..end {
                let i = t / (oh * kq_count);
                let rem = t % (oh * kq_count);
                let oy = rem / kq_count;
                let qb = rem % kq_count;
                // SAFETY: (i, oy, qb) ranges over distinct output rows ×
                // K-tiles; fwd_task only writes y rows (i, qb·Q/V+j, oy).
                let y_mut: &mut ActTensor = unsafe { &mut *{ &yptr }.0 };
                sparse_fwd::fwd_task(cfg, d, g, y_mut, i, oy, qb, mode, &mut local);
                tasks_per_chunk[ci].fetch_add(1, Ordering::Relaxed);
            }
            merged.lock().unwrap().merge(&local);
        });

        RunReport {
            stats: merged.into_inner().unwrap(),
            tasks_per_chunk: tasks_per_chunk.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            total_tasks: total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::reference;
    use crate::tensor::allclose;
    use crate::util::prng::Xorshift;
    use crate::util::proptest::{check, Config as PropConfig, UsizeIn};

    fn setup(cfg: &ConvConfig, sparsity: f64) -> (ActTensor, FilterTensor) {
        let mut rng = Xorshift::new(1234);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, sparsity);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        (d, g)
    }

    #[test]
    fn parallel_matches_reference() {
        let cfg = ConvConfig::square(2, 32, 64, 8, 3, 1);
        let (d, g) = setup(&cfg, 0.5);
        let sched = Scheduler::new(4);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let report = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
        assert!(allclose(&y.to_nchw(), &yref, 1e-4, 1e-5));
        assert_eq!(report.total_tasks, Scheduler::fwd_task_count(&cfg));
        assert_eq!(report.tasks_per_chunk.iter().sum::<usize>(), report.total_tasks);
    }

    #[test]
    fn parallel_stats_match_serial() {
        let cfg = ConvConfig::square(2, 32, 64, 8, 3, 1);
        let (d, g) = setup(&cfg, 0.4);
        let sched = Scheduler::new(3);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let report = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        let mut y2 = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let mut serial = KernelStats::new();
        crate::kernels::sparse_fwd::fwd(&cfg, &d, &g, &mut y2, SkipMode::MaskLoop, &mut serial);
        assert_eq!(report.stats.fma_vec, serial.fma_vec);
        assert_eq!(report.stats.zero_checks, serial.zero_checks);
        assert_eq!(y.data(), y2.data());
    }

    #[test]
    fn task_count_formula() {
        // N·H'·K/Q (§3.2.2)
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let plan = plan_fwd(256, 3);
        assert_eq!(Scheduler::fwd_task_count(&cfg), 16 * 56 * (256 / plan.q));
    }

    #[test]
    fn property_parallel_equals_serial_over_random_shapes() {
        // Property: for random (hw, threads), parallel == serial output.
        let gen = UsizeIn { lo: 0, hi: 6 };
        check(PropConfig { cases: 8, seed: 77, max_shrink_steps: 16 }, &gen, |&case| {
            let hw = 4 + case; // 4..=10
            let threads = 1 + case % 4;
            let cfg = ConvConfig::square(1, 16, 32, hw, 3, 1);
            let (d, g) = setup(&cfg, 0.5);
            let sched = Scheduler::new(threads);
            let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
            sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
            let yref = reference::conv_fwd(&cfg, &d.to_nchw(), &g.to_kcsr());
            if allclose(&y.to_nchw(), &yref, 1e-4, 1e-5) {
                Ok(())
            } else {
                Err(format!("mismatch at hw={hw} threads={threads}"))
            }
        });
    }

    #[test]
    fn load_balance_reasonable() {
        let cfg = ConvConfig::square(2, 32, 64, 16, 3, 1);
        let (d, g) = setup(&cfg, 0.5);
        let sched = Scheduler::new(4);
        let mut y = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        let report = sched.run_fwd(&cfg, &d, &g, &mut y, SkipMode::MaskLoop);
        let nonempty = report.tasks_per_chunk.iter().filter(|&&t| t > 0).count();
        assert!(nonempty > 1, "work not spread: {:?}", report.tasks_per_chunk);
    }
}
