//! A small metrics registry (counters, gauges, per-step series) for the
//! trainer and the examples — the observability layer a deployed
//! coordinator would export.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    series: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    pub fn push(&self, name: &str, v: f64) {
        self.series.lock().unwrap().entry(name.to_string()).or_default().push(v);
    }

    pub fn series(&self, name: &str) -> Vec<f64> {
        self.series.lock().unwrap().get(name).cloned().unwrap_or_default()
    }

    /// Render all metrics as a text report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge   {k} = {v:.6}\n"));
        }
        for (k, v) in self.series.lock().unwrap().iter() {
            out.push_str(&format!(
                "series  {k}: n={} last={:.6} mean={:.6}\n",
                v.len(),
                v.last().copied().unwrap_or(0.0),
                crate::util::stats::mean(v)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = MetricsRegistry::new();
        m.inc("steps", 1);
        m.inc("steps", 2);
        assert_eq!(m.counter("steps"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.set_gauge("lr", 0.1);
        assert_eq!(m.gauge("lr"), Some(0.1));
    }

    #[test]
    fn series_accumulates() {
        let m = MetricsRegistry::new();
        m.push("loss", 2.0);
        m.push("loss", 1.0);
        assert_eq!(m.series("loss"), vec![2.0, 1.0]);
        assert!(m.report().contains("series  loss"));
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(MetricsRegistry::new());
        let pool = crate::util::threadpool::ThreadPool::new(4);
        for _ in 0..100 {
            let m = m.clone();
            pool.submit(move || m.inc("x", 1));
        }
        pool.wait_idle();
        assert_eq!(m.counter("x"), 100);
    }
}
