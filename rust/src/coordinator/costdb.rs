//! Persistent per-machine measured-cost database (ISSUE 8).
//!
//! The analytic model in [`crate::sim::cost`] prices the paper's skip
//! modes from calibrated constants and an assumption of perfect load
//! balance; the §5 crossovers it predicts are only as good as that
//! calibration. This module replaces prediction with *measurement* on
//! the machine actually running: every routed kernel execution is timed
//! with a monotonic-clock stamp and folded into an exponential moving
//! average, keyed by everything that changes the answer —
//!
//! `(component FWD/BWI/BWW/GEMM, geometry signature, sparsity bucket,
//!   thread count, SIMD backend, execution mode)`
//!
//! The DB is populated two ways:
//!
//! - **lazily**, by the [`crate::runtime::executor::OpRouter`] hot path:
//!   the first execution of a cold key runs the analytic choice and
//!   records its cost; the next execution of the same key runs the
//!   *other* branch-free candidate once (bounded exploration: only
//!   `Dense` and `MaskLoop`, the two modes the analytic selector can
//!   itself pick); thereafter the cheapest measured mode wins. Because
//!   the skip modes are mutually bit-identical (the long-standing
//!   invariant proven by `conv_route_parity.rs`), exploration can never
//!   change numerics — only wall time.
//! - **in bulk**, by the wallclock sweep ([`crate::bench::wallclock`]),
//!   which measures the full mode grid — including `PerLaneBranch`,
//!   which the lazy path never explores on its own but which the warm
//!   argmin will happily select once seeded.
//!
//! EMA updates (`EMA_ALPHA`) keep the entries tracking drift (thermal
//! throttling, co-tenant contention) instead of freezing the first
//! sample forever.
//!
//! ## Persistence
//!
//! The DB serializes to a versioned JSON file next to
//! `BENCH_kernels.json` (default `COSTDB_kernels.json` at the repo
//! root, overridable via `SPARSETRAIN_COST_DB_PATH`). Writes are atomic
//! (tmp + rename); loads are tolerant — a truncated, garbage, or
//! wrong-schema file is silently ignored and the selector falls back to
//! the analytic model, never panicking. To keep `cargo test` runs from
//! seeding the per-machine file with debug-build timings, the default
//! path does file IO **only in release builds** (an explicit
//! `SPARSETRAIN_COST_DB_PATH` always does IO); debug runs keep a purely
//! in-memory DB. Under Miri the DB is disabled entirely — the isolated
//! interpreter rejects both host clocks and file IO.
//!
//! ## Knobs
//!
//! - `SPARSETRAIN_COST_DB=off|0|false` — kill switch: no DB, pure
//!   analytic selection, no timing stamps (bit-identical to PR 7).
//! - `SPARSETRAIN_COST_DB=fresh` — reset: ignore any existing file and
//!   start empty (the file is overwritten on save).
//! - `SPARSETRAIN_COST_DB_PATH=<file>` — store location override.

use crate::kernels::{Component, ConvConfig, SkipMode};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Version tag embedded in (and required of) the JSON file. Bump on any
/// incompatible key/entry change; old files are then ignored, not
/// migrated.
pub const SCHEMA: &str = "sparsetrain-costdb-v1";

/// Weight of the newest sample in the exponential moving average.
pub const EMA_ALPHA: f64 = 0.25;

/// Sparsity is quantized to `round(sparsity * BUCKETS)`, i.e. buckets
/// 0..=10 at 10% granularity — coarse enough that a key re-warms in a
/// handful of steps, fine enough to resolve the §5 mode crossovers.
pub const BUCKETS: u8 = 10;

/// Which measured kernel a cost entry describes. `Gemm` extends the
/// paper's FWD/BWI/BWW triad with the router's blocked `dot` path so
/// fully-connected layers share the same store.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DbComponent {
    Fwd,
    Bwi,
    Bww,
    Gemm,
}

impl DbComponent {
    pub fn name(self) -> &'static str {
        match self {
            DbComponent::Fwd => "FWD",
            DbComponent::Bwi => "BWI",
            DbComponent::Bww => "BWW",
            DbComponent::Gemm => "GEMM",
        }
    }

    pub fn parse(s: &str) -> Option<DbComponent> {
        match s {
            "FWD" => Some(DbComponent::Fwd),
            "BWI" => Some(DbComponent::Bwi),
            "BWW" => Some(DbComponent::Bww),
            "GEMM" => Some(DbComponent::Gemm),
            _ => None,
        }
    }

    pub fn from_kernel(c: Component) -> DbComponent {
        match c {
            Component::Fwd => DbComponent::Fwd,
            Component::Bwi => DbComponent::Bwi,
            Component::Bww => DbComponent::Bww,
        }
    }
}

/// Stable string tag for a skip mode, used both in keys and in the JSON
/// file (mirrors the wallclock bench's mode labels).
pub fn mode_tag(mode: SkipMode) -> &'static str {
    match mode {
        SkipMode::Dense => "Dense",
        SkipMode::PerLaneBranch => "PerLaneBranch",
        SkipMode::MaskLoop => "MaskLoop",
    }
}

/// Canonical geometry signature for a convolution shape — every field
/// that changes the kernel's work, nothing that doesn't.
pub fn geom_sig(cfg: &ConvConfig) -> String {
    format!(
        "n{}c{}k{}h{}w{}s{}r{}sp{}so{}ph{}pw{}",
        cfg.n,
        cfg.c,
        cfg.k,
        cfg.h,
        cfg.w,
        cfg.s,
        cfg.r,
        cfg.stride_p,
        cfg.stride_o,
        cfg.pad_h,
        cfg.pad_w
    )
}

/// Geometry signature for a routed rank-2 GEMM.
pub fn gemm_sig(m: usize, n: usize, k: usize) -> String {
    format!("m{m}n{n}k{k}")
}

/// Quantize a sparsity fraction into a bucket (see [`BUCKETS`]).
/// Non-finite inputs map to bucket 0 (dense) rather than panicking.
pub fn sparsity_bucket(sparsity: f64) -> u8 {
    if !sparsity.is_finite() {
        return 0;
    }
    (sparsity.clamp(0.0, 1.0) * BUCKETS as f64).round() as u8
}

/// Full lookup key — see the module docs for the rationale behind each
/// dimension.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CostKey {
    pub component: DbComponent,
    pub geom: String,
    pub bucket: u8,
    pub threads: usize,
    pub backend: String,
    pub mode: String,
}

impl CostKey {
    /// Key for a routed convolution execution.
    pub fn conv(
        comp: Component,
        cfg: &ConvConfig,
        sparsity: f64,
        threads: usize,
        backend: &str,
        mode: SkipMode,
    ) -> CostKey {
        CostKey {
            component: DbComponent::from_kernel(comp),
            geom: geom_sig(cfg),
            bucket: sparsity_bucket(sparsity),
            threads,
            backend: backend.to_string(),
            mode: mode_tag(mode).to_string(),
        }
    }

    /// Key for a routed GEMM execution. GEMM has no skip modes and no
    /// sparsity dimension (bucket 0, mode "gemm"): the entry exists for
    /// observability and future dense-vs-sparse dot policies, not mode
    /// selection.
    pub fn gemm(m: usize, n: usize, k: usize, threads: usize, backend: &str) -> CostKey {
        CostKey {
            component: DbComponent::Gemm,
            geom: gemm_sig(m, n, k),
            bucket: 0,
            threads,
            backend: backend.to_string(),
            mode: "gemm".to_string(),
        }
    }

    /// Key for a parallel GEMM execution at an explicit work-distribution
    /// chunk count (mode `c<chunks>`). The selector's GEMM policy
    /// ([`crate::coordinator::Selector::gemm_chunks`]) explores a small
    /// candidate set of chunk counts per shape through these keys and
    /// then picks the cheapest measured one — every chunk count is
    /// bit-identical, so a cold or corrupt key only costs speed.
    pub fn gemm_chunks(
        m: usize,
        n: usize,
        k: usize,
        threads: usize,
        backend: &str,
        chunks: usize,
    ) -> CostKey {
        CostKey {
            component: DbComponent::Gemm,
            geom: gemm_sig(m, n, k),
            bucket: 0,
            threads,
            backend: backend.to_string(),
            mode: format!("c{chunks}"),
        }
    }
}

/// One measured cell: EMA over `samples` observations, in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEntry {
    pub ema_ns: f64,
    pub samples: u64,
}

/// How `skip_mode` arrived at its answer — surfaced so tests (and the
/// train CLI report) can distinguish the paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DbDecision {
    /// No DB attached (kill switch / Miri): pure analytic model.
    Analytic,
    /// Both lazily-explored candidates measured: cheapest measured mode.
    Hit,
    /// Key not fully measured yet: the returned mode is the one to
    /// measure next (analytic choice first, then the other candidate).
    Miss,
}

/// The database proper. Thread-safe: the map is behind a mutex (lookups
/// are rare — once per routed op — and the critical section is tiny),
/// counters are atomics. Dropping a dirty DB with a path saves it.
pub struct CostDb {
    path: Option<PathBuf>,
    map: Mutex<HashMap<CostKey, CostEntry>>,
    hits: AtomicU64,
    misses: AtomicU64,
    updates: AtomicU64,
    dirty: AtomicBool,
}

impl CostDb {
    /// An empty DB that never touches the filesystem.
    pub fn in_memory() -> CostDb {
        CostDb {
            path: None,
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            updates: AtomicU64::new(0),
            dirty: AtomicBool::new(false),
        }
    }

    /// A DB backed by `path`. With `load`, any existing file is parsed
    /// (tolerantly: corrupt or wrong-schema content is ignored);
    /// without, the DB starts empty and overwrites on save (`=fresh`).
    pub fn at_path(path: PathBuf, load: bool) -> CostDb {
        let mut db = CostDb::in_memory();
        if load {
            if let Ok(text) = std::fs::read_to_string(&path) {
                if let Some(entries) = parse_json(&text) {
                    let mut map = db.map.lock().unwrap();
                    for (k, e) in entries {
                        map.insert(k, e);
                    }
                }
            }
        }
        db.path = Some(path);
        db
    }

    /// The process-default DB per the environment knobs (module docs).
    /// Returns `None` when killed (`SPARSETRAIN_COST_DB=off`) or under
    /// Miri.
    pub fn from_env() -> Option<Arc<CostDb>> {
        if cfg!(miri) {
            return None;
        }
        let mode = std::env::var("SPARSETRAIN_COST_DB").unwrap_or_default();
        if matches!(mode.as_str(), "off" | "0" | "false") {
            return None;
        }
        let fresh = mode == "fresh";
        let explicit = std::env::var("SPARSETRAIN_COST_DB_PATH").ok().filter(|p| !p.is_empty());
        // Default-path file IO is release-only so debug `cargo test`
        // runs never seed the per-machine store with unrepresentative
        // timings (same rule BENCH_kernels.json follows).
        let file_io = explicit.is_some() || !cfg!(debug_assertions);
        let db = if file_io {
            let path = explicit.map(PathBuf::from).unwrap_or_else(Self::default_path);
            CostDb::at_path(path, !fresh)
        } else {
            CostDb::in_memory()
        };
        Some(Arc::new(db))
    }

    /// `COSTDB_kernels.json` next to `BENCH_kernels.json` at the repo
    /// root (the crate manifest dir).
    pub fn default_path() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("COSTDB_kernels.json")
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, misses, updates)` counters for the CLI report.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.updates.load(Ordering::Relaxed),
        )
    }

    pub fn lookup(&self, key: &CostKey) -> Option<CostEntry> {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).get(key).copied()
    }

    /// Cheapest measured EMA over **all** sparsity buckets and modes for
    /// a `(component, geometry, threads, backend)` slice — the serve
    /// batch planner's query ([`crate::coordinator::serve`]): it wants
    /// "how fast can this shape go here", whatever mode/sparsity the
    /// router picked when it recorded. `None` when the slice is cold.
    pub fn best_ns(
        &self,
        component: DbComponent,
        geom: &str,
        threads: usize,
        backend: &str,
    ) -> Option<f64> {
        let map = self.map.lock().unwrap_or_else(|p| p.into_inner());
        map.iter()
            .filter(|(k, _)| {
                k.component == component
                    && k.geom == geom
                    && k.threads == threads
                    && k.backend == backend
            })
            .map(|(_, e)| e.ema_ns)
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Fold one measured execution into the EMA for `key`. Non-finite
    /// or negative durations are dropped.
    pub fn record(&self, key: CostKey, ns: f64) {
        if !ns.is_finite() || ns < 0.0 {
            return;
        }
        {
            let mut map = self.map.lock().unwrap_or_else(|p| p.into_inner());
            let e = map.entry(key).or_insert(CostEntry { ema_ns: ns, samples: 0 });
            if e.samples > 0 {
                e.ema_ns = EMA_ALPHA * ns + (1.0 - EMA_ALPHA) * e.ema_ns;
            } else {
                e.ema_ns = ns;
            }
            e.samples = e.samples.saturating_add(1);
        }
        self.updates.fetch_add(1, Ordering::Relaxed);
        self.dirty.store(true, Ordering::Relaxed);
    }

    /// The measured-cost decision for a conv execution (see module docs
    /// for the exploration policy). `analytic` is the fallback choice
    /// from the analytic model; the caller is expected to *run* the
    /// returned mode and [`record`](Self::record) its duration, which
    /// is what advances a key from cold to warm.
    pub fn choose_mode(
        &self,
        component: DbComponent,
        geom: &str,
        bucket: u8,
        threads: usize,
        backend: &str,
        analytic: SkipMode,
    ) -> (SkipMode, DbDecision) {
        let key = |mode: SkipMode| CostKey {
            component,
            geom: geom.to_string(),
            bucket,
            threads,
            backend: backend.to_string(),
            mode: mode_tag(mode).to_string(),
        };
        let (dense, mask, plb) = {
            let map = self.map.lock().unwrap_or_else(|p| p.into_inner());
            (
                map.get(&key(SkipMode::Dense)).map(|e| e.ema_ns),
                map.get(&key(SkipMode::MaskLoop)).map(|e| e.ema_ns),
                map.get(&key(SkipMode::PerLaneBranch)).map(|e| e.ema_ns),
            )
        };
        // Cold key: measure the analytic choice first so the model's
        // pick is always priced before anything else runs.
        let analytic_cost = match analytic {
            SkipMode::Dense => dense,
            SkipMode::MaskLoop => mask,
            SkipMode::PerLaneBranch => plb,
        };
        if analytic_cost.is_none() {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return (analytic, DbDecision::Miss);
        }
        // Bounded exploration: price the other branch-free candidate
        // once. PerLaneBranch is never lazily explored (bulk seeding
        // only) — its per-lane branches lose on wide SIMD (§5) and the
        // hot path should not pay to rediscover that per key.
        if dense.is_none() {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return (SkipMode::Dense, DbDecision::Miss);
        }
        if mask.is_none() {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return (SkipMode::MaskLoop, DbDecision::Miss);
        }
        // Warm key: cheapest measured mode, PerLaneBranch included when
        // the sweep seeded it.
        let mut best = (SkipMode::Dense, dense.unwrap());
        let mask = mask.unwrap();
        if mask < best.1 {
            best = (SkipMode::MaskLoop, mask);
        }
        if let Some(p) = plb {
            if p < best.1 {
                best = (SkipMode::PerLaneBranch, p);
            }
        }
        self.hits.fetch_add(1, Ordering::Relaxed);
        (best.0, DbDecision::Hit)
    }

    /// Serialize the whole DB — schema header plus one entry per line
    /// (stable order: sorted by the key fields) so diffs and the
    /// tolerant line-oriented parser both stay simple.
    pub fn to_json(&self) -> String {
        let map = self.map.lock().unwrap_or_else(|p| p.into_inner());
        let mut rows: Vec<(String, String)> = map
            .iter()
            .map(|(k, e)| {
                let sort = format!(
                    "{}|{}|{:03}|{:06}|{}|{}",
                    k.component.name(),
                    k.geom,
                    k.bucket,
                    k.threads,
                    k.backend,
                    k.mode
                );
                let line = format!(
                    "    {{\"component\": \"{}\", \"geom\": \"{}\", \"bucket\": {}, \
                     \"threads\": {}, \"backend\": \"{}\", \"mode\": \"{}\", \
                     \"ema_ns\": {:.3}, \"samples\": {}}}",
                    k.component.name(),
                    k.geom,
                    k.bucket,
                    k.threads,
                    k.backend,
                    k.mode,
                    e.ema_ns,
                    e.samples
                );
                (sort, line)
            })
            .collect();
        drop(map);
        rows.sort();
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
        out.push_str("  \"entries\": [\n");
        for (i, (_, line)) in rows.iter().enumerate() {
            out.push_str(line);
            out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Atomic save (tmp + rename) to the configured path; a no-op for
    /// in-memory DBs. Clears the dirty flag on success.
    pub fn save(&self) -> std::io::Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json())?;
        std::fs::rename(&tmp, path)?;
        self.dirty.store(false, Ordering::Relaxed);
        Ok(())
    }
}

impl Drop for CostDb {
    fn drop(&mut self) {
        if self.path.is_some() && self.dirty.load(Ordering::Relaxed) {
            let _ = self.save();
        }
    }
}

// ---------------------------------------------------------------------------
// Tolerant line-oriented JSON parsing (no serde in the dependency set)
// ---------------------------------------------------------------------------

/// Extract a `"name": "value"` string field from one line.
fn field_str<'a>(line: &'a str, name: &str) -> Option<&'a str> {
    let pat = format!("\"{name}\": \"");
    let start = line.find(&pat)? + pat.len();
    let rest = line.get(start..)?;
    rest.get(..rest.find('"')?)
}

/// Extract a `"name": value` numeric field (as raw text) from one line.
fn field_raw<'a>(line: &'a str, name: &str) -> Option<&'a str> {
    let pat = format!("\"{name}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = line.get(start..)?;
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    Some(rest.get(..end)?.trim())
}

/// Parse a serialized DB. Returns `None` when the schema tag is absent
/// or wrong (stale file from another version — ignore wholesale);
/// otherwise returns every line that parses cleanly and silently skips
/// the rest (truncation, bit rot, hand edits). Must never panic: every
/// step is `Option`-checked, nothing indexes raw.
fn parse_json(text: &str) -> Option<Vec<(CostKey, CostEntry)>> {
    if !text.contains(&format!("\"schema\": \"{SCHEMA}\"")) {
        return None;
    }
    let mut out = Vec::new();
    for line in text.lines() {
        let Some(component) = field_str(line, "component").and_then(DbComponent::parse) else {
            continue;
        };
        let parsed = (|| {
            let geom = field_str(line, "geom")?.to_string();
            let bucket: u8 = field_raw(line, "bucket")?.parse().ok()?;
            let threads: usize = field_raw(line, "threads")?.parse().ok()?;
            let backend = field_str(line, "backend")?.to_string();
            let mode = field_str(line, "mode")?.to_string();
            let ema_ns: f64 = field_raw(line, "ema_ns")?.parse().ok()?;
            let samples: u64 = field_raw(line, "samples")?.parse().ok()?;
            if !ema_ns.is_finite() || ema_ns < 0.0 || samples == 0 || bucket > BUCKETS {
                return None;
            }
            Some((
                CostKey { component, geom, bucket, threads, backend, mode },
                CostEntry { ema_ns, samples: samples.max(1) },
            ))
        })();
        if let Some(kv) = parsed {
            out.push(kv);
        }
    }
    Some(out)
}

// ---------------------------------------------------------------------------
// Tests (miri_ prefixed: pure in-memory logic, no IO, no clocks — they
// run in the Miri CI leg)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn k(mode: SkipMode) -> CostKey {
        CostKey::conv(Component::Fwd, &ConvConfig::square(1, 16, 16, 8, 3, 1), 0.9, 2, "t", mode)
    }

    fn choose(db: &CostDb, analytic: SkipMode) -> (SkipMode, DbDecision) {
        let key = k(SkipMode::Dense);
        db.choose_mode(key.component, &key.geom, key.bucket, key.threads, &key.backend, analytic)
    }

    #[test]
    fn miri_costdb_bucket_edges() {
        assert_eq!(sparsity_bucket(0.0), 0);
        assert_eq!(sparsity_bucket(1.0), 10);
        assert_eq!(sparsity_bucket(0.95), 10);
        assert_eq!(sparsity_bucket(0.94), 9);
        assert_eq!(sparsity_bucket(-3.0), 0);
        assert_eq!(sparsity_bucket(7.0), 10);
        assert_eq!(sparsity_bucket(f64::NAN), 0);
    }

    #[test]
    fn miri_costdb_decision_sequence_cold_to_warm() {
        let db = CostDb::in_memory();
        // Cold: analytic choice, Miss.
        assert_eq!(choose(&db, SkipMode::MaskLoop), (SkipMode::MaskLoop, DbDecision::Miss));
        db.record(k(SkipMode::MaskLoop), 100.0);
        // Analytic measured, Dense not: explore Dense, still Miss.
        assert_eq!(choose(&db, SkipMode::MaskLoop), (SkipMode::Dense, DbDecision::Miss));
        db.record(k(SkipMode::Dense), 50.0);
        // Warm: cheapest measured wins, Hit.
        assert_eq!(choose(&db, SkipMode::MaskLoop), (SkipMode::Dense, DbDecision::Hit));
        // Bulk-seeded PerLaneBranch can win the argmin but is never the
        // exploration target.
        db.record(k(SkipMode::PerLaneBranch), 10.0);
        assert_eq!(choose(&db, SkipMode::MaskLoop), (SkipMode::PerLaneBranch, DbDecision::Hit));
        let (hits, misses, updates) = db.counters();
        assert_eq!((hits, misses, updates), (2, 2, 3));
    }

    #[test]
    fn miri_costdb_ema_tracks_drift() {
        let db = CostDb::in_memory();
        db.record(k(SkipMode::Dense), 100.0);
        assert_eq!(db.lookup(&k(SkipMode::Dense)).unwrap().ema_ns, 100.0);
        db.record(k(SkipMode::Dense), 200.0);
        let e = db.lookup(&k(SkipMode::Dense)).unwrap();
        assert_eq!(e.ema_ns, EMA_ALPHA * 200.0 + (1.0 - EMA_ALPHA) * 100.0);
        assert_eq!(e.samples, 2);
        // Garbage durations are dropped, not stored.
        db.record(k(SkipMode::Dense), f64::NAN);
        db.record(k(SkipMode::Dense), -1.0);
        assert_eq!(db.lookup(&k(SkipMode::Dense)).unwrap().samples, 2);
    }

    #[test]
    fn miri_costdb_json_round_trip() {
        let db = CostDb::in_memory();
        db.record(k(SkipMode::Dense), 123.5);
        db.record(k(SkipMode::MaskLoop), 77.0);
        db.record(CostKey::gemm(64, 32, 128, 4, "t"), 5.0);
        let text = db.to_json();
        let entries = parse_json(&text).expect("schema tag present");
        assert_eq!(entries.len(), 3);
        let back = CostDb::in_memory();
        {
            let mut map = back.map.lock().unwrap();
            for (key, e) in entries {
                map.insert(key, e);
            }
        }
        for key in [k(SkipMode::Dense), k(SkipMode::MaskLoop), CostKey::gemm(64, 32, 128, 4, "t")]
        {
            let a = db.lookup(&key).unwrap();
            let b = back.lookup(&key).unwrap();
            assert!((a.ema_ns - b.ema_ns).abs() < 1e-3, "{key:?}: {a:?} vs {b:?}");
            assert_eq!(a.samples, b.samples);
        }
    }

    #[test]
    fn miri_costdb_parser_never_panics_on_garbage() {
        // Wrong/missing schema: ignored wholesale.
        assert!(parse_json("").is_none());
        assert!(parse_json("{\"schema\": \"sparsetrain-costdb-v0\"}").is_none());
        assert!(parse_json("not json at all \x00\x01").is_none());
        // Right schema, garbage entries: bad lines skipped, good kept.
        let db = CostDb::in_memory();
        db.record(k(SkipMode::Dense), 9.0);
        let good = db.to_json();
        let good_line = good.lines().find(|l| l.contains("\"component\"")).unwrap();
        let text = format!(
            "{{\n  \"schema\": \"{SCHEMA}\",\n  \"entries\": [\n\
             {{\"component\": \"FWD\", \"geom\": \"tr\n\
             {{\"component\": \"NOPE\", \"geom\": \"x\", \"bucket\": 1}},\n\
             {{\"component\": \"FWD\", \"geom\": \"x\", \"bucket\": 99, \"threads\": 1, \
               \"backend\": \"t\", \"mode\": \"Dense\", \"ema_ns\": 1.0, \"samples\": 1}},\n\
             {{\"component\": \"FWD\", \"geom\": \"x\", \"bucket\": 1, \"threads\": 1, \
               \"backend\": \"t\", \"mode\": \"Dense\", \"ema_ns\": NaN, \"samples\": 1}},\n\
             {good_line}\n  ]\n}}\n"
        );
        let entries = parse_json(&text).expect("schema ok");
        assert_eq!(entries.len(), 1, "only the intact line survives");
        assert_eq!(entries[0].0, k(SkipMode::Dense));
    }

    #[test]
    fn miri_costdb_best_ns_spans_buckets_and_modes() {
        let db = CostDb::in_memory();
        let geom = k(SkipMode::Dense).geom;
        assert_eq!(db.best_ns(DbComponent::Fwd, &geom, 2, "t"), None, "cold slice");
        db.record(k(SkipMode::Dense), 100.0);
        db.record(k(SkipMode::MaskLoop), 40.0);
        // Different bucket, same slice: still a candidate.
        let mut other_bucket = k(SkipMode::Dense);
        other_bucket.bucket = 3;
        db.record(other_bucket, 25.0);
        assert_eq!(db.best_ns(DbComponent::Fwd, &geom, 2, "t"), Some(25.0));
        // Mismatched threads / backend / component slices stay invisible.
        assert_eq!(db.best_ns(DbComponent::Fwd, &geom, 4, "t"), None);
        assert_eq!(db.best_ns(DbComponent::Fwd, &geom, 2, "u"), None);
        assert_eq!(db.best_ns(DbComponent::Bwi, &geom, 2, "t"), None);
    }

    #[test]
    fn miri_costdb_empty_serializes_and_parses() {
        let db = CostDb::in_memory();
        assert!(db.is_empty());
        let entries = parse_json(&db.to_json()).unwrap();
        assert!(entries.is_empty());
    }
}
