//! The L3 coordinator: schedules the paper's output-parallel row-sweep
//! tasks across worker threads, selects the best convolution algorithm per
//! layer (static `combined` policy, the dynamic profiler-driven variant
//! §5.3 suggests, and the measured-cost database of ISSUE 8), drives the
//! PJRT training loop, batches inference requests for serving
//! (ISSUE 9, [`serve`]), and supplies the dependency-scheduled
//! evaluator's cost-gated overlap planner (ISSUE 10, [`pipeline`]).

pub mod costdb;
pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod selector;
pub mod serve;
pub mod trainer;

pub use costdb::{CostDb, CostEntry, CostKey, DbDecision};
pub use metrics::MetricsRegistry;
pub use scheduler::Scheduler;
pub use selector::{AlgoPolicy, Selector};
pub use serve::{
    BatchExecutor, Batcher, Clock, MonotonicClock, PredictExecutor, Prediction, ServeConfig,
    ServeReply, ServeRequest, ServeSession, ServeStats, Server, VirtualClock,
};
pub use trainer::{TrainReport, Trainer, TrainerConfig};
