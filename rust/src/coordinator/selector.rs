//! Per-layer algorithm selection — the `combined` policy of §5.3 and the
//! dynamic variant the paper sketches ("profile the sparsity of each layer
//! at intervals during training and then dynamically select the best
//! implementation").
//!
//! **Measured vs analytic (ISSUE 8).** `skip_mode` serves two masters:
//! when a [`CostDb`] is attached (`cost_db: Some(..)`, the release-run
//! default), the decision consults *measured* wall times first —
//! [`CostDb::choose_mode`] returns the cheapest measured skip mode for
//! the (component, geometry, sparsity bucket, threads, backend) key, and
//! only falls back to the analytic [`crate::sim::cost`] model while the
//! key is cold (reporting [`DbDecision::Miss`] and naming the mode to
//! measure next). With no DB (`SPARSETRAIN_COST_DB=off`, Miri, or plain
//! [`Selector::new`]) the decision is the pure analytic model, exactly
//! the PR 7 behavior ([`DbDecision::Analytic`]). The contract that makes
//! this safe: the skip modes are mutually bit-identical (proven by
//! `conv_route_parity.rs`), so the DB may only ever change *wall time*,
//! never numerics. Everything else the selector does (`select`, `cost`,
//! `select_dynamic`) remains purely analytic — the DB keys on executed
//! kernels, not on algorithm families the router cannot run.

use crate::coordinator::costdb::{self, CostDb, CostKey, DbDecision};
use crate::kernels::{simd, winograd, onebyone, Component, ConvConfig, SkipMode};
use crate::sim::{Algorithm, Machine};
use crate::sparsity::SparsityProfiler;
use crate::tensor::ActTensor;
use crate::util::prng::Xorshift;
use std::sync::Arc;

/// Selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoPolicy {
    /// Always the dense baseline.
    DirectOnly,
    /// Always SparseTrain (paper's "SparseTrain" bars; falls back to
    /// `direct` for BWI under BatchNorm, handled by the projector).
    SparseTrainOnly,
    /// Winograd where applicable, else the 1×1 kernel, else direct
    /// (paper's "win/1x1" bars).
    WinOr1x1,
    /// Per layer, the fastest of all applicable algorithms at the layer's
    /// (average) sparsity (paper's "combined" bars).
    Combined,
}

impl AlgoPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            AlgoPolicy::DirectOnly => "direct",
            AlgoPolicy::SparseTrainOnly => "SparseTrain",
            AlgoPolicy::WinOr1x1 => "win/1x1",
            AlgoPolicy::Combined => "combined",
        }
    }
}

/// The selector: evaluates candidate algorithms on the cost model.
pub struct Selector {
    pub machine: Machine,
    /// Worker threads the row-sweep scheduler will run with. The cost
    /// model sees this many active cores, so `combined` picks the best
    /// algorithm *for the parallelism actually available* — at low thread
    /// counts compute-bound kernels look relatively worse against the
    /// DRAM-bound floor, which can flip a selection.
    pub threads: usize,
    /// Seed for synthesizing pattern tensors at a given sparsity.
    pub seed: u64,
    /// Measured-cost database consulted first by [`Selector::skip_mode`]
    /// (ISSUE 8). `None` — kill switch, Miri, or a plain constructor —
    /// means pure analytic selection, the PR 7 behavior.
    pub cost_db: Option<Arc<CostDb>>,
    /// SIMD backend tag used in measured-cost keys: the *dispatched*
    /// backend actually executing (env override included), not the
    /// modeled `machine`.
    pub backend: &'static str,
}

impl Selector {
    pub fn new(machine: Machine) -> Selector {
        let threads = machine.cores;
        Selector {
            machine,
            threads,
            seed: 0xA11CE,
            cost_db: None,
            backend: simd::dispatch().name(),
        }
    }

    /// A selector whose cost estimates assume `threads` active cores —
    /// pair it with a [`crate::coordinator::Scheduler`] of the same width.
    pub fn with_threads(machine: Machine, threads: usize) -> Selector {
        Selector { threads: threads.max(1), ..Selector::new(machine) }
    }

    /// The machine as the cost model sees it: `threads` active cores,
    /// everything else as configured.
    fn effective_machine(&self) -> Machine {
        self.machine.with_cores(self.threads)
    }

    /// Candidate algorithms applicable to a layer/component.
    pub fn candidates(cfg: &ConvConfig, sparse_applicable: bool) -> Vec<Algorithm> {
        let mut v = vec![Algorithm::Direct];
        if winograd::applicable(cfg) {
            v.push(Algorithm::Winograd);
        }
        if onebyone::applicable(cfg) {
            v.push(Algorithm::OneByOne);
        }
        v.push(Algorithm::Im2col);
        if sparse_applicable {
            v.push(Algorithm::SparseTrain);
        }
        v
    }

    /// Synthesize an i.i.d. pattern tensor at `sparsity` shaped like the
    /// checked operand of (cfg, comp).
    pub fn pattern_for(&self, cfg: &ConvConfig, comp: Component, sparsity: f64) -> ActTensor {
        let mut rng = Xorshift::new(self.seed);
        let mut t = match comp {
            Component::Fwd | Component::Bww => ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w),
            Component::Bwi => ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w()),
        };
        t.fill_relu_sparse(&mut rng, sparsity);
        t
    }

    /// Estimated wall cycles of `alg` on (cfg, comp) at the given operand
    /// sparsity (i.i.d. closed form — see [`crate::sim::estimate_layer_iid`]),
    /// modeled at the selector's configured thread count.
    pub fn cost(&self, alg: Algorithm, cfg: &ConvConfig, comp: Component, sparsity: f64) -> f64 {
        crate::sim::estimate_layer_iid(&self.effective_machine(), alg, comp, cfg, sparsity).wall
    }

    /// Pick per policy. `sparse_applicable` is false when the checked
    /// operand carries no ReLU sparsity (first layer, or BWI after BN).
    pub fn select(
        &self,
        policy: AlgoPolicy,
        cfg: &ConvConfig,
        comp: Component,
        sparsity: f64,
        sparse_applicable: bool,
    ) -> Algorithm {
        match policy {
            AlgoPolicy::DirectOnly => Algorithm::Direct,
            AlgoPolicy::SparseTrainOnly => {
                if sparse_applicable {
                    Algorithm::SparseTrain
                } else {
                    Algorithm::Direct
                }
            }
            AlgoPolicy::WinOr1x1 => {
                if winograd::applicable(cfg) {
                    Algorithm::Winograd
                } else if onebyone::applicable(cfg) {
                    Algorithm::OneByOne
                } else {
                    Algorithm::Direct
                }
            }
            AlgoPolicy::Combined => {
                let mut best = (Algorithm::Direct, f64::INFINITY);
                for alg in Self::candidates(cfg, sparse_applicable) {
                    let c = self.cost(alg, cfg, comp, sparsity);
                    if c < best.1 {
                        best = (alg, c);
                    }
                }
                best.0
            }
        }
    }

    /// Attach (or detach) a measured-cost database, builder-style.
    pub fn with_cost_db(mut self, db: Option<Arc<CostDb>>) -> Selector {
        self.cost_db = db;
        self
    }

    /// The analytic-only skip mode (ISSUE 5; also the off-DB fallback):
    /// run the combined policy at the measured operand sparsity — when
    /// the cost model (at this selector's thread count) says the sparsity
    /// machinery pays for itself, use the Algorithm-3 mask loop;
    /// otherwise run the Dense loop, which is the same SIMD row-sweep
    /// without zero checks. Either way the launch stays parallel and
    /// bit-deterministic.
    pub fn skip_mode_analytic(&self, cfg: &ConvConfig, comp: Component, sparsity: f64) -> SkipMode {
        match self.select(AlgoPolicy::Combined, cfg, comp, sparsity, true) {
            Algorithm::SparseTrain => SkipMode::MaskLoop,
            _ => SkipMode::Dense,
        }
    }

    /// Skip mode plus how it was decided (measured-vs-analytic contract
    /// in the module docs). The decision is a pure function of the DB
    /// contents and the analytic choice — querying does not mutate the
    /// map, so query-then-execute sees a stable answer within a step.
    pub fn skip_mode_decision(
        &self,
        cfg: &ConvConfig,
        comp: Component,
        sparsity: f64,
    ) -> (SkipMode, DbDecision) {
        let analytic = self.skip_mode_analytic(cfg, comp, sparsity);
        match &self.cost_db {
            None => (analytic, DbDecision::Analytic),
            Some(db) => db.choose_mode(
                costdb::DbComponent::from_kernel(comp),
                &costdb::geom_sig(cfg),
                costdb::sparsity_bucket(sparsity),
                self.threads,
                self.backend,
                analytic,
            ),
        }
    }

    /// Skip mode for a kernel-routed convolution launch: measured-cost
    /// DB first, analytic model off-DB (see [`Self::skip_mode_decision`]).
    pub fn skip_mode(&self, cfg: &ConvConfig, comp: Component, sparsity: f64) -> SkipMode {
        self.skip_mode_decision(cfg, comp, sparsity).0
    }

    /// [`Self::skip_mode_decision`] at an explicit thread budget instead
    /// of the configured one. The pipeline executor (ISSUE 10) uses this
    /// for thread-budget splitting: an op co-scheduled onto a pool worker
    /// runs its inner parallel-for inline — effectively one thread — so
    /// both the analytic model and the measured-cost key must see that
    /// budget, not the pool width (which also self-populates the
    /// single-thread DB rows the overlap gate compares against).
    pub fn skip_mode_decision_at(
        &self,
        cfg: &ConvConfig,
        comp: Component,
        sparsity: f64,
        threads: usize,
    ) -> (SkipMode, DbDecision) {
        let at = Selector {
            machine: self.machine,
            threads: threads.max(1),
            seed: self.seed,
            cost_db: self.cost_db.clone(),
            backend: self.backend,
        };
        at.skip_mode_decision(cfg, comp, sparsity)
    }

    /// Work-distribution chunk count for a parallel GEMM of shape
    /// `m × n × k` at `threads` workers (ISSUE 10 satellite: the recorded
    /// `gemm` cost rows finally drive a policy). With no DB the static
    /// `default_chunks` (one chunk per `MB`-row panel) stands; with one,
    /// a small candidate set — the default plus 1×/2×/4× the thread
    /// count — is explored lazily through [`CostKey::gemm_chunks`] keys
    /// and the cheapest measured candidate wins. Every candidate is
    /// bit-identical (chunking only groups independent row panels), so a
    /// cold key costs at most one exploratory timing.
    pub fn gemm_chunks(
        &self,
        m: usize,
        n: usize,
        k: usize,
        threads: usize,
        default_chunks: usize,
    ) -> usize {
        let cap = m.max(1);
        let default_chunks = default_chunks.clamp(1, cap);
        let Some(db) = &self.cost_db else {
            return default_chunks;
        };
        let threads = threads.max(1);
        let mut cands = vec![default_chunks, threads, threads * 2, threads * 4];
        for c in &mut cands {
            *c = (*c).clamp(1, cap);
        }
        cands.sort_unstable();
        cands.dedup();
        let mut best: Option<(usize, f64)> = None;
        for &c in &cands {
            match db.lookup(&CostKey::gemm_chunks(m, n, k, threads, self.backend, c)) {
                // Cold candidate: run (and time) it next — lazy explore.
                None => return c,
                Some(e) => match best {
                    Some((_, b)) if b <= e.ema_ns => {}
                    _ => best = Some((c, e.ema_ns)),
                },
            }
        }
        best.map(|(c, _)| c).unwrap_or(default_chunks)
    }

    /// Dynamic selection from live profiler data (recent-window sparsity),
    /// falling back to 0.5 (the ReLU prior) with no observations.
    pub fn select_dynamic(
        &self,
        cfg: &ConvConfig,
        comp: Component,
        layer: &str,
        profiler: &SparsityProfiler,
        sparse_applicable: bool,
    ) -> Algorithm {
        let s = profiler.recent_mean(layer, 16).unwrap_or(0.5);
        self.select(AlgoPolicy::Combined, cfg, comp, s, sparse_applicable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel() -> Selector {
        Selector::new(Machine::skylake_x())
    }

    #[test]
    fn combined_picks_sparse_at_high_sparsity_3x3() {
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let alg = sel().select(AlgoPolicy::Combined, &cfg, Component::Fwd, 0.9, true);
        assert_eq!(alg, Algorithm::SparseTrain);
    }

    #[test]
    fn combined_prefers_winograd_at_low_sparsity_3x3() {
        // §5.1: it takes 50–60 % sparsity for SparseTrain to pass Winograd.
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let alg = sel().select(AlgoPolicy::Combined, &cfg, Component::Fwd, 0.1, true);
        assert_eq!(alg, Algorithm::Winograd);
    }

    #[test]
    fn winograd_never_selected_for_strided_or_1x1() {
        let strided = ConvConfig::square(16, 128, 128, 56, 3, 2);
        assert!(!Selector::candidates(&strided, true).contains(&Algorithm::Winograd));
        let one = ConvConfig::square(16, 256, 256, 28, 1, 1);
        assert!(!Selector::candidates(&one, true).contains(&Algorithm::Winograd));
        assert!(Selector::candidates(&one, true).contains(&Algorithm::OneByOne));
    }

    #[test]
    fn sparse_inapplicable_falls_back_to_direct() {
        let cfg = ConvConfig::square(16, 64, 64, 56, 3, 1);
        let alg = sel().select(AlgoPolicy::SparseTrainOnly, &cfg, Component::Bwi, 0.9, false);
        assert_eq!(alg, Algorithm::Direct);
    }

    #[test]
    fn dynamic_uses_profiled_sparsity() {
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let s = sel();
        let mut prof = SparsityProfiler::new();
        for _ in 0..20 {
            prof.observe_value("l", 0.92);
        }
        let alg = s.select_dynamic(&cfg, Component::Fwd, "l", &prof, true);
        assert_eq!(alg, Algorithm::SparseTrain);
        // unknown layer → prior 0.5 → winograd or sparse, but never im2col
        let alg2 = s.select_dynamic(&cfg, Component::Fwd, "unknown", &prof, true);
        assert_ne!(alg2, Algorithm::Im2col);
    }

    #[test]
    fn skip_mode_tracks_sparsity() {
        // High sparsity on a big 3x3 layer → the mask loop; a dense operand
        // (sparsity 0) must never pick the skip machinery over Winograd.
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let s = sel();
        assert_eq!(s.skip_mode(&cfg, Component::Fwd, 0.9), SkipMode::MaskLoop);
        assert_eq!(s.skip_mode(&cfg, Component::Fwd, 0.0), SkipMode::Dense);
    }

    #[test]
    fn miri_skip_mode_consults_cost_db_first() {
        use crate::coordinator::costdb::CostKey;
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let db = Arc::new(CostDb::in_memory());
        let s = Selector::with_threads(Machine::skylake_x(), 2).with_cost_db(Some(db.clone()));
        // Cold key: analytic choice (MaskLoop at 0.9), reported as a miss.
        assert_eq!(
            s.skip_mode_decision(&cfg, Component::Fwd, 0.9),
            (SkipMode::MaskLoop, DbDecision::Miss)
        );
        db.record(CostKey::conv(Component::Fwd, &cfg, 0.9, 2, s.backend, SkipMode::MaskLoop), 100.0);
        // Analytic priced → explore the other candidate once.
        assert_eq!(
            s.skip_mode_decision(&cfg, Component::Fwd, 0.9),
            (SkipMode::Dense, DbDecision::Miss)
        );
        db.record(CostKey::conv(Component::Fwd, &cfg, 0.9, 2, s.backend, SkipMode::Dense), 10.0);
        // Warm: the measurement overrides the analytic model.
        assert_eq!(
            s.skip_mode_decision(&cfg, Component::Fwd, 0.9),
            (SkipMode::Dense, DbDecision::Hit)
        );
        // skip_mode is the decision's mode.
        assert_eq!(s.skip_mode(&cfg, Component::Fwd, 0.9), SkipMode::Dense);
        // No DB (kill switch / plain constructor): pure analytic.
        let off = Selector::with_threads(Machine::skylake_x(), 2);
        assert_eq!(
            off.skip_mode_decision(&cfg, Component::Fwd, 0.9),
            (SkipMode::MaskLoop, DbDecision::Analytic)
        );
    }

    #[test]
    fn miri_gemm_chunks_explores_then_picks_cheapest_measured() {
        use crate::coordinator::costdb::{CostDb, CostKey};
        let (m, n, k) = (64usize, 10, 512);
        // No DB: the static default stands.
        let off = Selector::with_threads(Machine::skylake_x(), 2);
        assert_eq!(off.gemm_chunks(m, n, k, 2, 2), 2);
        let db = Arc::new(CostDb::in_memory());
        let s = Selector::with_threads(Machine::skylake_x(), 2).with_cost_db(Some(db.clone()));
        // Candidates at threads=2, default 2: {2, 4, 8}. All cold → the
        // lowest is the one to explore; measuring it moves on to the next.
        assert_eq!(s.gemm_chunks(m, n, k, 2, 2), 2);
        db.record(CostKey::gemm_chunks(m, n, k, 2, s.backend, 2), 300.0);
        assert_eq!(s.gemm_chunks(m, n, k, 2, 2), 4);
        db.record(CostKey::gemm_chunks(m, n, k, 2, s.backend, 4), 100.0);
        assert_eq!(s.gemm_chunks(m, n, k, 2, 2), 8);
        db.record(CostKey::gemm_chunks(m, n, k, 2, s.backend, 8), 200.0);
        // Warm: cheapest measured candidate wins.
        assert_eq!(s.gemm_chunks(m, n, k, 2, 2), 4);
        // Candidates never exceed the row count.
        assert_eq!(s.gemm_chunks(1, n, k, 2, 16), 1);
    }

    #[test]
    fn miri_skip_mode_decision_at_keys_on_the_given_thread_budget() {
        use crate::coordinator::costdb::{CostDb, CostKey};
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let db = Arc::new(CostDb::in_memory());
        let s = Selector::with_threads(Machine::skylake_x(), 4).with_cost_db(Some(db.clone()));
        // Warm both candidate modes at threads=1 only: the t=1 decision
        // must hit while the configured-width decision stays a miss.
        db.record(CostKey::conv(Component::Fwd, &cfg, 0.9, 1, s.backend, SkipMode::MaskLoop), 90.0);
        db.record(CostKey::conv(Component::Fwd, &cfg, 0.9, 1, s.backend, SkipMode::Dense), 400.0);
        assert_eq!(
            s.skip_mode_decision_at(&cfg, Component::Fwd, 0.9, 1),
            (SkipMode::MaskLoop, DbDecision::Hit)
        );
        assert_eq!(s.skip_mode_decision(&cfg, Component::Fwd, 0.9).1, DbDecision::Miss);
        // At the configured width the _at variant is the plain decision.
        assert_eq!(
            s.skip_mode_decision_at(&cfg, Component::Fwd, 0.9, 4),
            s.skip_mode_decision(&cfg, Component::Fwd, 0.9)
        );
    }

    #[test]
    fn policy_names() {
        assert_eq!(AlgoPolicy::Combined.name(), "combined");
        assert_eq!(AlgoPolicy::WinOr1x1.name(), "win/1x1");
    }

    #[test]
    fn default_threads_match_machine_cores() {
        let s = sel();
        assert_eq!(s.threads, Machine::skylake_x().cores);
    }

    #[test]
    fn thread_aware_cost_scales_with_threads() {
        let m = Machine::skylake_x();
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let s1 = Selector::with_threads(m, 1);
        let s6 = Selector::with_threads(m, 6);
        let c1 = s1.cost(Algorithm::SparseTrain, &cfg, Component::Fwd, 0.5);
        let c6 = s6.cost(Algorithm::SparseTrain, &cfg, Component::Fwd, 0.5);
        assert!(c6 < c1, "more threads must be cheaper: 6-core {c6} vs 1-core {c1}");
        assert!(c1 / c6 <= 6.0 + 1e-9, "speedup cannot exceed the thread count");
        // zero clamps to one thread
        assert_eq!(Selector::with_threads(m, 0).threads, 1);
    }

    #[test]
    fn selection_can_depend_on_thread_count() {
        // At equal sparsity the *ordering* of candidates may change with
        // the modeled core count (bandwidth-bound vs compute-bound). At
        // minimum, every thread count still returns an applicable
        // algorithm and the combined policy never picks something more
        // expensive than SparseTrain when SparseTrain is modeled fastest.
        let m = Machine::skylake_x();
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        for threads in [1, 2, 4, 6, 8] {
            let s = Selector::with_threads(m, threads);
            let alg = s.select(AlgoPolicy::Combined, &cfg, Component::Fwd, 0.9, true);
            let best_cost = s.cost(alg, &cfg, Component::Fwd, 0.9);
            for cand in Selector::candidates(&cfg, true) {
                assert!(
                    best_cost <= s.cost(cand, &cfg, Component::Fwd, 0.9) + 1e-9,
                    "threads={threads}: combined pick {alg:?} beaten by {cand:?}"
                );
            }
        }
    }
}
