//! The modeled machine: an Intel Core i7-7800X (Skylake-X), the paper's
//! evaluation platform (§2.4, §4).
//!
//! Per core and cycle: two AVX-512 FMA pipes, two 64 B loads, one 64 B
//! store, four retired µops; 32 zmm registers; 32 KB L1D, 1 MB L2,
//! 1.375 MB/core non-inclusive shared L3. Hyperthreading and frequency
//! scaling disabled, 2 MB pages (§4).

/// Machine parameters for the analytical model. All bandwidths in bytes
/// per cycle, capacities in bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Machine {
    pub cores: usize,
    /// V-wide FMA issue per cycle per core.
    pub fma_per_cycle: f64,
    /// Vector loads per cycle per core (L1 read ports).
    pub loads_per_cycle: f64,
    /// Vector stores per cycle per core.
    pub stores_per_cycle: f64,
    /// Retired µops per cycle per core (fused domain).
    pub retire_per_cycle: f64,
    /// Scalar/integer ALU µops per cycle available alongside vector work.
    pub int_per_cycle: f64,
    pub l1d_bytes: usize,
    pub l2_bytes: usize,
    /// Shared L3 capacity (total).
    pub l3_bytes: usize,
    /// L2→L1 fill bandwidth per core.
    pub l2_bw: f64,
    /// L3→L2 bandwidth per core.
    pub l3_bw: f64,
    /// DRAM bandwidth, total across the package.
    pub dram_bw_total: f64,
    /// Branch misprediction penalty in cycles.
    pub mispredict_penalty: f64,
    /// Fixed per-row-sweep overhead (loop setup, pointer arithmetic), cycles.
    pub sweep_overhead: f64,
    /// Unoverlapped serial latency per zero-check (the mask-loop dependency
    /// chain); binds only when per-check work is small (high sparsity).
    pub check_serial_base: f64,
    /// Additional per-check serial cost per unit of T = R·Q/V (front-end +
    /// register pressure of the unrolled FMA block).
    pub check_serial_per_t: f64,
}

impl Machine {
    /// The paper's testbed: 6-core Skylake-X i7-7800X.
    pub fn skylake_x() -> Machine {
        Machine {
            cores: 6,
            fma_per_cycle: 2.0,
            loads_per_cycle: 2.0,
            stores_per_cycle: 1.0,
            retire_per_cycle: 4.0,
            int_per_cycle: 2.0,
            l1d_bytes: 32 * 1024,
            l2_bytes: 1024 * 1024,
            l3_bytes: (8.25 * 1024.0 * 1024.0) as usize,
            // ~64 B/cycle sustained L2 read on SKX
            l2_bw: 64.0,
            l3_bw: 30.0,
            // ~4 channels DDR4-2666 ≈ 85 GB/s at 3.5 GHz ≈ 24 B/cycle total
            dram_bw_total: 24.0,
            mispredict_penalty: 16.0,
            sweep_overhead: 25.0,
            check_serial_base: 8.0,
            check_serial_per_t: 2.2,
        }
    }

    /// A single-core variant (used by unit tests for determinism).
    pub fn single_core() -> Machine {
        Machine::skylake_x().with_cores(1)
    }

    /// The same machine restricted to `cores` active cores (clamped to at
    /// least one) — the single source of the "model fewer threads" rule
    /// used by the selector, the benches and the CLI.
    pub fn with_cores(&self, cores: usize) -> Machine {
        Machine { cores: cores.max(1), ..*self }
    }

    /// DRAM bandwidth available per active core.
    pub fn dram_bw_per_core(&self, active_cores: usize) -> f64 {
        self.dram_bw_total / active_cores.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skylake_parameters_match_paper() {
        let m = Machine::skylake_x();
        assert_eq!(m.cores, 6);
        assert_eq!(m.fma_per_cycle, 2.0);
        assert_eq!(m.l1d_bytes, 32 * 1024);
        assert_eq!(m.l2_bytes, 1024 * 1024);
        assert_eq!(m.l3_bytes, (8.25 * 1024.0 * 1024.0) as usize);
    }

    #[test]
    fn dram_bw_splits_across_cores() {
        let m = Machine::skylake_x();
        assert!((m.dram_bw_per_core(6) - m.dram_bw_total / 6.0).abs() < 1e-12);
        assert_eq!(m.dram_bw_per_core(0), m.dram_bw_total);
    }
}
