//! Analytical cycle model: micro-op counts → per-layer wall cycles on the
//! modeled Skylake-X (bottleneck analysis, not cycle-accurate simulation).
//!
//! The model charges, per kernel invocation over a whole layer:
//! * **FP ports**: V-wide FMAs + vector compares (zero checks) + transform
//!   FP ops at 2/cycle/core;
//! * **load/store ports**: every FMA's memory operand + explicit stream
//!   loads/stores at 2 loads + 1 store per cycle;
//! * **retire**: fused-domain µops at 4/cycle;
//! * **integer**: the mask-loop bookkeeping at 2/cycle alongside;
//! * **L2 bandwidth**: per-sweep stream refills + filter-tile refills
//!   (amortized by the minibatch tiling M — §3.2.5) at 64 B/cycle/core;
//! * **DRAM bandwidth**: compulsory tensor traffic at the shared package
//!   bandwidth;
//! * **branch mispredictions**: from the mask statistics ([`super::branch`]);
//! * **sweep overhead**: fixed setup cost per row sweep.
//!
//! Wall time = max(core-bound share, L2 share, DRAM) — reported with the
//! full breakdown so benches can show *why* a kernel wins.
//!
//! Since ISSUE 8 this model is also the **fallback tier** of the runtime
//! skip-mode decision: [`crate::coordinator::Selector`] consults the
//! measured-cost database ([`crate::coordinator::CostDb`]) first and
//! prices a mode analytically only while the key is cold or the DB is
//! detached — so the constants here decide the *first* execution of each
//! shape, and measurements take over from the second.

use super::branch::mispredict_cycles;
use super::machine::Machine;
use crate::kernels::{Component, ConvConfig, KernelStats, SkipMode};

/// Which algorithm produced the stats (memory behavior differs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Direct,
    SparseTrain,
    Im2col,
    Winograd,
    OneByOne,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Direct => "direct",
            Algorithm::SparseTrain => "SparseTrain",
            Algorithm::Im2col => "im2col",
            Algorithm::Winograd => "winograd",
            Algorithm::OneByOne => "1x1",
        }
    }
}

/// Minibatch tile size M used to amortize filter refills (§3.2.5).
pub const M_TILE: f64 = 16.0;

/// Cycle breakdown for one kernel invocation over a layer.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CycleBreakdown {
    /// FP-port-bound cycles (total across cores).
    pub fp: f64,
    /// Load-port-bound cycles.
    pub load: f64,
    /// Store-port-bound cycles.
    pub store: f64,
    /// Retire-bound cycles.
    pub retire: f64,
    /// Integer-op cycles (mask machinery).
    pub int: f64,
    /// L2-bandwidth cycles.
    pub l2: f64,
    /// DRAM-bandwidth cycles (package-wide).
    pub dram: f64,
    /// Branch-misprediction penalty cycles.
    pub mispredict: f64,
    /// Per-sweep fixed overhead cycles.
    pub overhead: f64,
    /// Final wall-clock cycle estimate for the layer.
    pub wall: f64,
}

impl CycleBreakdown {
    /// The dominant core-side bottleneck name (for reports).
    pub fn bottleneck(&self) -> &'static str {
        let mut best = ("fp", self.fp);
        for (n, v) in [
            ("load", self.load),
            ("store", self.store),
            ("retire", self.retire),
            ("int", self.int),
            ("l2", self.l2),
            ("dram", self.dram),
            ("mispredict", self.mispredict),
        ] {
            if v > best.1 {
                best = (n, v);
            }
        }
        best.0
    }
}

/// Estimate wall cycles for a kernel run over a layer.
pub fn estimate(
    m: &Machine,
    alg: Algorithm,
    comp: Component,
    mode: SkipMode,
    cfg: &ConvConfig,
    stats: &KernelStats,
) -> CycleBreakdown {
    let fma = stats.fma_vec as f64;
    let checks = stats.zero_checks as f64;
    // SparseTrain broadcasts each processed input element into a register
    // (one vbroadcastss per nonzero lane) because its FMA memory operand is
    // the *filter* vector; the tuned dense kernel instead embeds the
    // broadcast in the FMA's memory operand ({1to16}) and pays nothing.
    // This shuffle-port op is the main §5.1 "92–95 % of direct at 0 %" cost.
    let broadcasts = if alg == Algorithm::SparseTrain && mode != SkipMode::Dense {
        stats
            .popcount_hist
            .iter()
            .enumerate()
            .map(|(k, &h)| k as f64 * h as f64)
            .sum::<f64>()
    } else {
        0.0
    };
    // Calibration (§4/§5.1 measured baselines): the lowered GEMM achieves a
    // fraction of the JIT direct kernel's FMA efficiency — tall-skinny
    // panels with strided B access and internal repacking. 3×3 lowering
    // also duplicates data 9×; 1×1 lowering is a near-reshape.
    let gemm_eff = match (alg, cfg.r) {
        (Algorithm::Im2col, 1) => 0.55,
        (Algorithm::Im2col, _) => 0.40,
        // Winograd's elementwise stage + transforms run at a fraction of
        // the JIT direct kernel's FMA efficiency (short dot products in
        // Winograd space, shuffle-heavy transforms): the paper measures
        // 1.44–1.48× end-to-end from a 2.25× MAC reduction.
        (Algorithm::Winograd, _) => 0.70,
        _ => 1.0,
    };
    // vbroadcastss from memory is a pure load-port µop on SKX.
    let fp_uops = (fma / gemm_eff) + checks + stats.vec_fp_ops as f64;
    let load_uops =
        fma /* memory operand */ + broadcasts + (stats.loads_in + stats.loads_out) as f64;
    let store_uops = stats.stores_out as f64;
    // im2col lowering: per-element scalar address math + bounds + copy.
    let lowering_ops = if alg == Algorithm::Im2col {
        3.0 * (cfg.c * cfg.s * cfg.r * cfg.n * cfg.out_h() * cfg.out_w()) as f64
    } else {
        0.0
    };
    // fused-domain: FMA+load fuse; checks, int ops, stores retire separately
    let retire_uops = fma + checks + broadcasts + stats.int_ops as f64 + store_uops
        + (stats.loads_in + stats.loads_out) as f64
        + lowering_ops;

    let mut b = CycleBreakdown {
        fp: fp_uops / m.fma_per_cycle,
        load: load_uops / m.loads_per_cycle,
        store: store_uops / m.stores_per_cycle,
        retire: retire_uops / m.retire_per_cycle,
        int: (stats.int_ops as f64 + lowering_ops) / m.int_per_cycle,
        ..Default::default()
    };

    // --- L2 traffic (lines of 64 B) ---
    let stream_lines = (stats.loads_in + stats.loads_out + stats.stores_out) as f64;
    let filter_refill_lines = match (alg, comp) {
        // FWD/BWI amortize the per-sweep filter set over the M-image tile.
        (Algorithm::Direct | Algorithm::SparseTrain, Component::Fwd | Component::Bwi) => {
            stats.sweeps as f64 * (stats.filter_bytes_per_sweep as f64 / 64.0) / M_TILE
        }
        // BWW's "filter" set is the accumulator (tiny, charged in streams).
        (_, Component::Bww) => 0.0,
        // gemm-style kernels: operand panels already counted in streams.
        _ => 0.0,
    };
    // BWW's ∂L/∂Y FMA operand working set: SparseTrain sweeps V images at
    // once (footprint V·ow·Q/V lines ≫ L1 → refilled from L2 each use,
    // reuse only across the R-tap window); the dense baseline iterates one
    // image at a time and keeps the row L1-resident across the C loop.
    let bww_dy_lines = match (alg, comp) {
        (Algorithm::SparseTrain, Component::Bww) => fma / (1.4 * cfg.r as f64),
        (Algorithm::Direct, Component::Bww) => fma / (cfg.r as f64 * cfg.c as f64).max(1.0),
        _ => 0.0,
    };
    let l2_lines = stream_lines + filter_refill_lines + bww_dy_lines;
    b.l2 = l2_lines * 64.0 / m.l2_bw;

    // --- DRAM compulsory traffic (bytes) ---
    let f = 4.0; // f32
    let d_bytes = (cfg.n * cfg.c * cfg.h * cfg.w) as f64 * f;
    let y_bytes = (cfg.n * cfg.k * cfg.out_h() * cfg.out_w()) as f64 * f;
    let g_bytes = (cfg.k * cfg.c * cfg.s * cfg.r) as f64 * f;
    let dram_bytes = match (alg, comp) {
        (Algorithm::Im2col, _) => {
            let col = (cfg.c * cfg.s * cfg.r * cfg.n * cfg.out_h() * cfg.out_w()) as f64 * f;
            d_bytes + g_bytes + 2.0 * y_bytes + 2.0 * col
        }
        (Algorithm::Winograd, _) => {
            let u = (cfg.k * cfg.c * 16) as f64 * f;
            d_bytes + u + 2.0 * y_bytes
        }
        (_, Component::Fwd) => d_bytes + g_bytes + 2.0 * y_bytes,
        (_, Component::Bwi) => y_bytes + g_bytes + 2.0 * d_bytes,
        (_, Component::Bww) => d_bytes + y_bytes + 2.0 * g_bytes,
    };
    b.dram = dram_bytes / m.dram_bw_total;

    b.mispredict = mispredict_cycles(stats, mode, m.mispredict_penalty);
    b.overhead = stats.sweeps as f64 * m.sweep_overhead;

    // Per-check serial floor: each zero-check heads a dependency chain
    // (vcmpps → kmov → popcnt → tzcnt → pointer arithmetic → broadcast →
    // first FMA) that out-of-order execution cannot fully overlap when the
    // per-check work is small, plus front-end/register-pressure cost that
    // grows with the unrolled T-FMA loop body. At dense inputs the T FMAs
    // per lane dwarf the chain and the floor vanishes under `max`; at high
    // sparsity it is what caps the paper's measured speedup (§5.1: FWD
    // tops out at ~2.5× at 90 % despite 10× fewer FMAs; 1×1 layers, with
    // smaller T, saturate lower). Constants calibrated to Tables 4/5.
    let t_avg = if stats.zero_checks > 0 {
        stats.fma_total() as f64 / (stats.zero_checks as f64 * crate::V as f64)
    } else {
        0.0
    };
    let serial_floor =
        stats.zero_checks as f64 * (m.check_serial_base + m.check_serial_per_t * t_avg);

    // Core-bound time: the binding port plus serializing penalties.
    let core_total = b
        .fp
        .max(b.load)
        .max(b.store)
        .max(b.retire)
        .max(b.int)
        .max(serial_floor)
        + b.mispredict
        + b.overhead;
    let cores = m.cores as f64;
    b.wall = (core_total / cores).max(b.l2 / cores).max(b.dram);
    b
}

/// Convenience: seconds at a nominal frequency (ratios are the real output;
/// absolute time only contextualizes reports).
pub fn wall_seconds(b: &CycleBreakdown, ghz: f64) -> f64 {
    b.wall / (ghz * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::stats_model::{direct_fwd_stats, sparse_fwd_stats};
    use crate::tensor::ActTensor;
    use crate::util::prng::Xorshift;

    fn layer() -> ConvConfig {
        ConvConfig::square(16, 256, 256, 56, 3, 1)
    }

    fn sparse_input(cfg: &ConvConfig, s: f64) -> ActTensor {
        let mut rng = Xorshift::new(99);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, s);
        d
    }

    #[test]
    fn dense_sparsetrain_slightly_slower_than_direct() {
        // Paper: 92–95 % of direct at 0 % sparsity.
        let m = Machine::skylake_x();
        let cfg = layer();
        let d = sparse_input(&cfg, 0.0);
        let st_direct = direct_fwd_stats(&cfg);
        let st_sparse = sparse_fwd_stats(&cfg, &d, SkipMode::MaskLoop);
        let t_direct =
            estimate(&m, Algorithm::Direct, Component::Fwd, SkipMode::Dense, &cfg, &st_direct);
        let t_sparse = estimate(
            &m,
            Algorithm::SparseTrain,
            Component::Fwd,
            SkipMode::MaskLoop,
            &cfg,
            &st_sparse,
        );
        let ratio = t_direct.wall / t_sparse.wall;
        assert!(
            ratio > 0.85 && ratio < 1.0,
            "dense overhead out of range: {ratio}"
        );
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        let m = Machine::skylake_x();
        let cfg = layer();
        let base = estimate(
            &m,
            Algorithm::Direct,
            Component::Fwd,
            SkipMode::Dense,
            &cfg,
            &direct_fwd_stats(&cfg),
        )
        .wall;
        let mut last = 0.0;
        for s in [0.2, 0.5, 0.8] {
            let d = sparse_input(&cfg, s);
            let st = sparse_fwd_stats(&cfg, &d, SkipMode::MaskLoop);
            let t = estimate(
                &m,
                Algorithm::SparseTrain,
                Component::Fwd,
                SkipMode::MaskLoop,
                &cfg,
                &st,
            );
            let speedup = base / t.wall;
            assert!(speedup > last, "not monotone at s={s}: {speedup} <= {last}");
            last = speedup;
        }
        assert!(last > 1.5, "80% sparsity speedup too low: {last}");
    }

    #[test]
    fn breakdown_bottleneck_is_reported() {
        let m = Machine::skylake_x();
        let cfg = layer();
        let st = direct_fwd_stats(&cfg);
        let b = estimate(&m, Algorithm::Direct, Component::Fwd, SkipMode::Dense, &cfg, &st);
        assert!(!b.bottleneck().is_empty());
        assert!(b.wall > 0.0);
    }

    #[test]
    fn wall_seconds_scales() {
        let b = CycleBreakdown { wall: 3.5e9, ..Default::default() };
        assert!((wall_seconds(&b, 3.5) - 1.0).abs() < 1e-9);
    }
}
