//! Branch-misprediction model driven by the zero-check mask statistics.
//!
//! The paper (§3.2.4, §5.4): the mask-loop transform (Algorithm 3) replaces
//! 16 data-dependent branches per check with one loop whose trip count is
//! the mask popcount — mispredictions remain "noticeable" because the trip
//! count is low (≤ V) and data-dependent.
//!
//! Model:
//! * **per-lane branches** (Algorithm 2): each lane is a biased coin with
//!   P(taken) = lane density `p`; a TAGE-like predictor on an i.i.d. biased
//!   coin mispredicts at ≈ min(p, 1-p) per branch → `V·min(p,1-p)`
//!   mispredictions per check.
//! * **mask loop** (Algorithm 3): the loop-exit branch mispredicts when the
//!   trip count differs from the predictor's expectation; for an i.i.d.
//!   trip-count distribution the collision probability Σₖ P(k)² is the
//!   chance the count repeats → `1 − Σₖ P(k)²` mispredictions per check
//!   (zero for constant masks, e.g. fully dense or fully zero inputs).

use crate::kernels::{KernelStats, SkipMode};

/// Expected mispredictions per zero-check given the observed popcount
/// histogram and the skip mode.
pub fn mispredicts_per_check(hist: &[u64], mode: SkipMode) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let v = (hist.len() - 1) as f64;
    match mode {
        SkipMode::Dense => 0.0,
        SkipMode::PerLaneBranch => {
            // density p from the histogram mean
            let mean: f64 = hist
                .iter()
                .enumerate()
                .map(|(k, &h)| k as f64 * h as f64)
                .sum::<f64>()
                / total as f64;
            let p = mean / v;
            v * p.min(1.0 - p)
        }
        SkipMode::MaskLoop => {
            // Loop predictors track the recent trip count and absorb ±1
            // jitter; a mispredict happens when the count moves further
            // than that between consecutive checks (i.i.d. approximation).
            let p: Vec<f64> = hist.iter().map(|&h| h as f64 / total as f64).collect();
            let within: f64 = p
                .iter()
                .enumerate()
                .map(|(k, &pk)| {
                    let lo = k.saturating_sub(1);
                    let hi = (k + 1).min(p.len() - 1);
                    pk * p[lo..=hi].iter().sum::<f64>()
                })
                .sum();
            1.0 - within
        }
    }
}

/// Total mispredict-cycle estimate for a kernel run.
pub fn mispredict_cycles(stats: &KernelStats, mode: SkipMode, penalty: f64) -> f64 {
    mispredicts_per_check(&stats.popcount_hist, mode) * stats.zero_checks as f64 * penalty
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::V;

    fn hist_constant(k: usize, n: u64) -> Vec<u64> {
        let mut h = vec![0u64; V + 1];
        h[k] = n;
        h
    }

    fn hist_binomial(p: f64, n: u64) -> Vec<u64> {
        // crude binomial pmf scaled to counts
        let mut h = vec![0u64; V + 1];
        for k in 0..=V {
            let mut logp = 0.0f64;
            for i in 0..k {
                logp += ((V - i) as f64 / (i + 1) as f64).ln();
            }
            logp += k as f64 * p.ln() + (V - k) as f64 * (1.0 - p).ln();
            h[k] = (logp.exp() * n as f64).round() as u64;
        }
        h
    }

    #[test]
    fn dense_input_never_mispredicts() {
        let h = hist_constant(V, 1000);
        assert_eq!(mispredicts_per_check(&h, SkipMode::MaskLoop), 0.0);
        assert_eq!(mispredicts_per_check(&h, SkipMode::PerLaneBranch), 0.0);
    }

    #[test]
    fn all_zero_input_never_mispredicts() {
        let h = hist_constant(0, 1000);
        assert_eq!(mispredicts_per_check(&h, SkipMode::MaskLoop), 0.0);
    }

    #[test]
    fn per_lane_worst_at_half_density() {
        let h50 = hist_binomial(0.5, 100_000);
        let h90 = hist_binomial(0.1, 100_000);
        let m50 = mispredicts_per_check(&h50, SkipMode::PerLaneBranch);
        let m90 = mispredicts_per_check(&h90, SkipMode::PerLaneBranch);
        assert!(m50 > m90, "m50={m50} m90={m90}");
        assert!((m50 - 8.0).abs() < 0.5); // 16 * 0.5
    }

    #[test]
    fn mask_loop_beats_per_lane_at_moderate_sparsity() {
        // The whole point of Algorithm 3.
        let h = hist_binomial(0.5, 100_000);
        let loop_m = mispredicts_per_check(&h, SkipMode::MaskLoop);
        let lane_m = mispredicts_per_check(&h, SkipMode::PerLaneBranch);
        assert!(loop_m < lane_m / 4.0, "loop={loop_m} lane={lane_m}");
        assert!(loop_m <= 1.0);
    }

    #[test]
    fn empty_hist_is_zero() {
        assert_eq!(mispredicts_per_check(&vec![0; V + 1], SkipMode::MaskLoop), 0.0);
    }
}
