//! Analytical Skylake-X performance model — the testbed substitute.
//!
//! The paper measures wallclock on a 6-core i7-7800X; this module turns the
//! kernels' micro-op accounting ([`crate::kernels::KernelStats`]) into
//! cycle estimates via bottleneck analysis over the machine's issue ports,
//! cache/DRAM bandwidths and branch predictor (see [`cost::estimate`]).
//! All experiment outputs are *ratios* against the modeled `direct`
//! baseline, mirroring the paper's tables.

pub mod branch;
pub mod cost;
pub mod machine;

pub use cost::{estimate, Algorithm, CycleBreakdown};
pub use machine::Machine;

use crate::kernels::stats_model;
use crate::kernels::{Component, ConvConfig, SkipMode};
use crate::tensor::{ActTensor, BatchTiledTensor};

/// Estimate the wall cycles of one (algorithm, component) on a layer whose
/// relevant operand has the given zero pattern.
///
/// For SparseTrain the pattern tensor is scanned exactly; for the dense
/// baselines the estimate is data-independent.
pub fn estimate_layer(
    m: &Machine,
    alg: Algorithm,
    comp: Component,
    cfg: &ConvConfig,
    pattern: Option<&ActTensor>,
) -> CycleBreakdown {
    match (alg, comp) {
        (Algorithm::SparseTrain, Component::Fwd) => {
            let d = pattern.expect("SparseTrain FWD needs the input pattern");
            let st = stats_model::sparse_fwd_stats(cfg, d, SkipMode::MaskLoop);
            cost::estimate(m, alg, comp, SkipMode::MaskLoop, cfg, &st)
        }
        (Algorithm::SparseTrain, Component::Bwi) => {
            let dy = pattern.expect("SparseTrain BWI needs the ∂L/∂Y pattern");
            let st = stats_model::sparse_bwi_stats(cfg, dy, SkipMode::MaskLoop);
            cost::estimate(m, alg, comp, SkipMode::MaskLoop, cfg, &st)
        }
        (Algorithm::SparseTrain, Component::Bww) => {
            let d = pattern.expect("SparseTrain BWW needs the checked pattern");
            let bt = BatchTiledTensor::from_act(d);
            let st = stats_model::sparse_bww_stats(cfg, &bt, SkipMode::MaskLoop);
            cost::estimate(m, alg, comp, SkipMode::MaskLoop, cfg, &st)
        }
        (Algorithm::Direct, Component::Fwd) => {
            let st = stats_model::direct_fwd_stats(cfg);
            cost::estimate(m, alg, comp, SkipMode::Dense, cfg, &st)
        }
        (Algorithm::Direct, Component::Bwi) => {
            let st = stats_model::direct_bwi_stats(cfg);
            cost::estimate(m, alg, comp, SkipMode::Dense, cfg, &st)
        }
        (Algorithm::Direct, Component::Bww) => {
            let st = stats_model::direct_bww_stats(cfg);
            cost::estimate(m, alg, comp, SkipMode::Dense, cfg, &st)
        }
        (Algorithm::Im2col, _) => {
            // im2col cost is component-symmetric to first order (the GEMM
            // dims permute); charge the FWD formulation.
            let mut st = crate::kernels::KernelStats::new();
            crate::kernels::im2col::stats_only(cfg, &mut st);
            cost::estimate(m, alg, comp, SkipMode::Dense, cfg, &st)
        }
        (Algorithm::Winograd, _) => {
            assert!(
                crate::kernels::winograd::applicable(cfg),
                "winograd inapplicable to {cfg:?}"
            );
            let mut st = crate::kernels::KernelStats::new();
            crate::kernels::winograd::stats_only(cfg, &mut st);
            cost::estimate(m, alg, comp, SkipMode::Dense, cfg, &st)
        }
        (Algorithm::OneByOne, _) => {
            assert!(
                crate::kernels::onebyone::applicable(cfg),
                "1x1 kernel inapplicable to {cfg:?}"
            );
            let mut st = crate::kernels::KernelStats::new();
            crate::kernels::onebyone::stats_only(cfg, &mut st);
            cost::estimate(m, alg, comp, SkipMode::Dense, cfg, &st)
        }
    }
}

/// Like [`estimate_layer`], but with the SparseTrain operand modeled as an
/// i.i.d. Bernoulli pattern of the given sparsity (closed-form expected
/// stats — no tensor materialization). The dense baselines ignore
/// `sparsity`.
pub fn estimate_layer_iid(
    m: &Machine,
    alg: Algorithm,
    comp: Component,
    cfg: &ConvConfig,
    sparsity: f64,
) -> CycleBreakdown {
    if alg == Algorithm::SparseTrain {
        let st = match comp {
            Component::Fwd => stats_model::sparse_fwd_stats_iid(cfg, sparsity, SkipMode::MaskLoop),
            Component::Bwi => stats_model::sparse_bwi_stats_iid(cfg, sparsity, SkipMode::MaskLoop),
            Component::Bww => stats_model::sparse_bww_stats_iid(cfg, sparsity, SkipMode::MaskLoop),
        };
        cost::estimate(m, alg, comp, SkipMode::MaskLoop, cfg, &st)
    } else {
        estimate_layer(m, alg, comp, cfg, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xorshift;

    #[test]
    fn estimate_layer_all_algorithms_run() {
        let m = Machine::skylake_x();
        let cfg = ConvConfig::square(16, 64, 64, 14, 3, 1);
        let mut rng = Xorshift::new(1);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, 0.5);
        for comp in Component::ALL {
            let ts = estimate_layer(&m, Algorithm::SparseTrain, comp, &cfg, Some(&d));
            let td = estimate_layer(&m, Algorithm::Direct, comp, &cfg, None);
            assert!(ts.wall > 0.0 && td.wall > 0.0, "{comp:?}");
        }
        assert!(estimate_layer(&m, Algorithm::Winograd, Component::Fwd, &cfg, None).wall > 0.0);
        assert!(estimate_layer(&m, Algorithm::Im2col, Component::Fwd, &cfg, None).wall > 0.0);
    }

    #[test]
    fn im2col_much_slower_than_direct_on_3x3() {
        // Paper Table 4: im2col ≈ 0.33–0.37× of direct.
        let m = Machine::skylake_x();
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let td = estimate_layer(&m, Algorithm::Direct, Component::Fwd, &cfg, None).wall;
        let ti = estimate_layer(&m, Algorithm::Im2col, Component::Fwd, &cfg, None).wall;
        let ratio = td / ti;
        assert!(ratio < 0.7, "im2col should lose clearly, ratio={ratio}");
    }

    #[test]
    fn winograd_beats_direct_on_3x3() {
        // Paper Table 4: winograd ≈ 1.44–1.48× of direct on stride-1 3×3.
        let m = Machine::skylake_x();
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let td = estimate_layer(&m, Algorithm::Direct, Component::Fwd, &cfg, None).wall;
        let tw = estimate_layer(&m, Algorithm::Winograd, Component::Fwd, &cfg, None).wall;
        let ratio = td / tw;
        assert!(ratio > 1.1 && ratio < 2.25, "winograd ratio={ratio}");
    }
}
