//! Tiled tensor layouts from §3.2.5 of the paper.
//!
//! * [`ActTensor`] — activations (D, Y, ∂L/∂D, ∂L/∂Y) in **NCHWc** layout:
//!   the lowest dimension is a channel tile of size [`V`](crate::V), so a
//!   vector instruction (or a Rust `[f32; V]` loop the compiler vectorizes)
//!   operates on one cache line of channel data.
//! * [`FilterTensor`] — weights (G, ∂L/∂G) with lowest dim an output-channel
//!   (K) vector of length V, then the input channel within a C-tile, then
//!   the filter width R — the exact layout §3.2.5 chooses so the hardware
//!   prefetcher streams the next input channel's filter vectors.
//! * [`BatchTiledTensor`] — the BWW input layout (§3.4): lowest dimension is
//!   a minibatch tile of size V so the zero-check vectorizes along N.
//!
//! All layouts require the tiled dimension (C, K, or N) to be a multiple of
//! V; the paper's evaluated configurations (Table 2, batch 16) all satisfy
//! this, and §5.4 notes the same restriction for BWW.
//!
//! For parallel execution, the tensors split into **owned disjoint task
//! views** — [`RowTileMut`] (one `(i, y, qb)` row-sweep destination) and
//! [`FilterTileMut`] (one `(qb, c)` filter-gradient tile) — carved with
//! `chunks_mut` so the borrow checker itself proves the scheduler's writes
//! race-free (no `unsafe` pointer sharing; see
//! [`crate::coordinator::scheduler`]).

mod act;
mod batch_tiled;
mod filter;

pub use act::{ActTensor, RowTileMut};
pub use batch_tiled::BatchTiledTensor;
pub use filter::{FilterTensor, FilterTileMut};

use crate::util::prng::Xorshift;
use crate::V;

/// Shared helpers for filling tensors.
pub(crate) fn fill_uniform(data: &mut [f32], rng: &mut Xorshift, lo: f32, hi: f32) {
    for x in data.iter_mut() {
        *x = rng.range_f32(lo, hi);
    }
}

/// Zero out elements with probability `sparsity`, emulating a ReLU output
/// with the given dynamic sparsity. Nonzero values stay strictly positive
/// (as a real ReLU output would be).
pub(crate) fn fill_relu_sparse(data: &mut [f32], rng: &mut Xorshift, sparsity: f64) {
    for x in data.iter_mut() {
        if rng.bernoulli(sparsity) {
            *x = 0.0;
        } else {
            // strictly positive, bounded away from 0
            *x = 0.05 + rng.next_f32();
        }
    }
}

/// Measured fraction of zeros in a buffer.
pub(crate) fn measured_sparsity(data: &[f32]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    data.iter().filter(|&&x| x == 0.0).count() as f64 / data.len() as f64
}

/// Maximum absolute difference between two buffers.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "buffer length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Relative tolerance check used by kernel equivalence tests: passes when
/// `|a-b| <= atol + rtol*max(|a|,|b|)` element-wise.
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= atol + rtol * x.abs().max(y.abs()))
}

/// Assert that a channel-like dimension is tileable by V.
#[inline]
pub(crate) fn assert_tiled(dim: usize, name: &str) {
    assert!(
        dim % V == 0 && dim > 0,
        "{name}={dim} must be a positive multiple of V={V}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_sparse_fill_hits_target() {
        let mut rng = Xorshift::new(5);
        let mut buf = vec![1.0f32; 100_000];
        fill_relu_sparse(&mut buf, &mut rng, 0.7);
        let s = measured_sparsity(&buf);
        assert!((s - 0.7).abs() < 0.01, "sparsity={s}");
        assert!(buf.iter().all(|&x| x == 0.0 || x > 0.0));
    }

    #[test]
    fn allclose_tolerances() {
        assert!(allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6));
    }

    #[test]
    #[should_panic(expected = "must be a positive multiple")]
    fn tiled_assert_fires() {
        assert_tiled(17, "C");
    }
}
