//! Activation tensor in NCHWc layout (§3.2.5): dims `[N][C/V][H][W][V]`.
//!
//! The channel tile of size V is the lowest dimension, aligned with the SIMD
//! width and the cache-line size on the paper's platform, so a vector
//! load/compare/FMA touches exactly one `[f32; V]` slice.

use super::{assert_tiled, fill_relu_sparse, fill_uniform, measured_sparsity};
use crate::util::prng::Xorshift;
use crate::V;

/// NCHWc-tiled activation tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ActTensor {
    /// Minibatch size.
    pub n: usize,
    /// Channels (multiple of V).
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    data: Vec<f32>,
}

impl ActTensor {
    /// Zero-initialized tensor.
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> ActTensor {
        assert_tiled(c, "C");
        ActTensor { n, c, h, w, data: vec![0.0; n * c * h * w] }
    }

    /// Number of channel tiles `C/V`.
    #[inline]
    pub fn c_blocks(&self) -> usize {
        self.c / V
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat offset of the V-vector at (i, cb, y, x).
    #[inline(always)]
    pub fn vec_offset(&self, i: usize, cb: usize, y: usize, x: usize) -> usize {
        debug_assert!(i < self.n && cb < self.c_blocks() && y < self.h && x < self.w);
        (((i * self.c_blocks() + cb) * self.h + y) * self.w + x) * V
    }

    /// Channel vector at (i, cb, y, x) as a `[f32; V]` slice.
    #[inline(always)]
    pub fn vec(&self, i: usize, cb: usize, y: usize, x: usize) -> &[f32] {
        let o = self.vec_offset(i, cb, y, x);
        &self.data[o..o + V]
    }

    /// Mutable channel vector.
    #[inline(always)]
    pub fn vec_mut(&mut self, i: usize, cb: usize, y: usize, x: usize) -> &mut [f32] {
        let o = self.vec_offset(i, cb, y, x);
        &mut self.data[o..o + V]
    }

    /// Scalar accessor in logical NCHW coordinates (for references/tests).
    #[inline]
    pub fn get(&self, i: usize, c: usize, y: usize, x: usize) -> f32 {
        self.data[self.vec_offset(i, c / V, y, x) + c % V]
    }

    /// Scalar setter in logical NCHW coordinates.
    #[inline]
    pub fn set(&mut self, i: usize, c: usize, y: usize, x: usize, v: f32) {
        let o = self.vec_offset(i, c / V, y, x) + c % V;
        self.data[o] = v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// A whole image row (W consecutive V-vectors) for one (i, cb, y).
    #[inline(always)]
    pub fn row(&self, i: usize, cb: usize, y: usize) -> &[f32] {
        let o = self.vec_offset(i, cb, y, 0);
        &self.data[o..o + self.w * V]
    }

    /// Mutable image row.
    #[inline(always)]
    pub fn row_mut(&mut self, i: usize, cb: usize, y: usize) -> &mut [f32] {
        let o = self.vec_offset(i, cb, y, 0);
        &mut self.data[o..o + self.w * V]
    }

    /// Fill with uniform random values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, rng: &mut Xorshift, lo: f32, hi: f32) {
        fill_uniform(&mut self.data, rng, lo, hi);
    }

    /// Fill as a ReLU output with the given dynamic sparsity.
    pub fn fill_relu_sparse(&mut self, rng: &mut Xorshift, sparsity: f64) {
        fill_relu_sparse(&mut self.data, rng, sparsity);
    }

    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Fraction of zero elements.
    pub fn sparsity(&self) -> f64 {
        measured_sparsity(&self.data)
    }

    /// Convert from a plain NCHW buffer (tests / PJRT interchange).
    pub fn from_nchw(n: usize, c: usize, h: usize, w: usize, src: &[f32]) -> ActTensor {
        assert_eq!(src.len(), n * c * h * w);
        let mut t = ActTensor::zeros(n, c, h, w);
        for i in 0..n {
            for ch in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        t.set(i, ch, y, x, src[((i * c + ch) * h + y) * w + x]);
                    }
                }
            }
        }
        t
    }

    /// Convert to a plain NCHW buffer.
    pub fn to_nchw(&self) -> Vec<f32> {
        let (n, c, h, w) = (self.n, self.c, self.h, self.w);
        let mut out = vec![0.0; n * c * h * w];
        for i in 0..n {
            for ch in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        out[((i * c + ch) * h + y) * w + x] = self.get(i, ch, y, x);
                    }
                }
            }
        }
        out
    }

    /// Bytes occupied by the tensor payload.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nchw() {
        let (n, c, h, w) = (2, 32, 3, 5);
        let src: Vec<f32> = (0..n * c * h * w).map(|i| i as f32).collect();
        let t = ActTensor::from_nchw(n, c, h, w, &src);
        assert_eq!(t.to_nchw(), src);
    }

    #[test]
    fn vec_is_channel_tile() {
        let mut t = ActTensor::zeros(1, 32, 2, 2);
        for ch in 0..32 {
            t.set(0, ch, 1, 1, ch as f32);
        }
        let v0 = t.vec(0, 0, 1, 1);
        let v1 = t.vec(0, 1, 1, 1);
        assert_eq!(v0, (0..16).map(|x| x as f32).collect::<Vec<_>>().as_slice());
        assert_eq!(v1, (16..32).map(|x| x as f32).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn row_is_contiguous_w_vectors() {
        let mut t = ActTensor::zeros(1, 16, 2, 4);
        for x in 0..4 {
            t.set(0, 3, 1, x, x as f32 + 1.0);
        }
        let row = t.row(0, 0, 1);
        assert_eq!(row.len(), 4 * V);
        for x in 0..4 {
            assert_eq!(row[x * V + 3], x as f32 + 1.0);
        }
    }

    #[test]
    fn sparsity_measures() {
        let mut rng = Xorshift::new(1);
        let mut t = ActTensor::zeros(2, 64, 8, 8);
        t.fill_relu_sparse(&mut rng, 0.5);
        assert!((t.sparsity() - 0.5).abs() < 0.03);
    }

    #[test]
    #[should_panic]
    fn rejects_untiled_channels() {
        ActTensor::zeros(1, 17, 2, 2);
    }
}
