//! Activation tensor in NCHWc layout (§3.2.5): dims `[N][C/V][H][W][V]`.
//!
//! The channel tile of size V is the lowest dimension, aligned with the SIMD
//! width and the cache-line size on the paper's platform, so a vector
//! load/compare/FMA touches exactly one `[f32; V]` slice.

use super::{assert_tiled, fill_relu_sparse, fill_uniform, measured_sparsity};
use crate::util::prng::Xorshift;
use crate::V;

/// An owned, disjoint view of one scheduler task's output: the `qv` image
/// rows of image `i`, row `y`, channel tiles `qb·qv .. (qb+1)·qv` — exactly
/// the slice a `(i, y, qb)` row-sweep task is allowed to write (§3.2.2).
///
/// Views are produced by [`ActTensor::par_row_tiles_mut`], which carves the
/// tensor's backing buffer with `chunks_mut`, so two views can never alias:
/// the borrow checker, not a safety comment, guarantees data-race freedom
/// when views are moved to worker threads.
#[derive(Debug)]
pub struct RowTileMut<'a> {
    /// Image (minibatch) index.
    pub i: usize,
    /// Spatial row index.
    pub y: usize,
    /// Q-tile index: this view covers channel tiles `qb*qv + j`, `j < qv`.
    pub qb: usize,
    /// Row `j` is channel tile `qb*qv + j`; each slice is `W·V` long.
    rows: Vec<&'a mut [f32]>,
}

impl<'a> RowTileMut<'a> {
    /// Number of channel-tile rows in this view (the plan's `Q/V`).
    #[inline]
    pub fn tiles(&self) -> usize {
        self.rows.len()
    }

    /// Image row for channel tile `qb*qv + j` (read side: the sweep
    /// protocol loads the previous output row once per task).
    #[inline(always)]
    pub fn row(&self, j: usize) -> &[f32] {
        &self.rows[j][..]
    }

    /// Mutable image row for channel tile `qb*qv + j`.
    #[inline(always)]
    pub fn row_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.rows[j][..]
    }
}

/// NCHWc-tiled activation tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ActTensor {
    /// Minibatch size.
    pub n: usize,
    /// Channels (multiple of V).
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    data: Vec<f32>,
}

impl ActTensor {
    /// Zero-initialized tensor.
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> ActTensor {
        assert_tiled(c, "C");
        ActTensor { n, c, h, w, data: vec![0.0; n * c * h * w] }
    }

    /// Number of channel tiles `C/V`.
    #[inline]
    pub fn c_blocks(&self) -> usize {
        self.c / V
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat offset of the V-vector at (i, cb, y, x).
    #[inline(always)]
    pub fn vec_offset(&self, i: usize, cb: usize, y: usize, x: usize) -> usize {
        debug_assert!(i < self.n && cb < self.c_blocks() && y < self.h && x < self.w);
        (((i * self.c_blocks() + cb) * self.h + y) * self.w + x) * V
    }

    /// Channel vector at (i, cb, y, x) as a `[f32; V]` slice.
    #[inline(always)]
    pub fn vec(&self, i: usize, cb: usize, y: usize, x: usize) -> &[f32] {
        let o = self.vec_offset(i, cb, y, x);
        &self.data[o..o + V]
    }

    /// Mutable channel vector.
    #[inline(always)]
    pub fn vec_mut(&mut self, i: usize, cb: usize, y: usize, x: usize) -> &mut [f32] {
        let o = self.vec_offset(i, cb, y, x);
        &mut self.data[o..o + V]
    }

    /// Channel vector as a fixed-size array reference — the operand shape
    /// the [`crate::kernels::simd::Backend`] primitives take (compile-time
    /// V-lane guarantee, no per-call length check in release builds).
    #[inline(always)]
    pub fn vec_arr(&self, i: usize, cb: usize, y: usize, x: usize) -> &[f32; V] {
        let o = self.vec_offset(i, cb, y, x);
        self.data[o..o + V].try_into().expect("tiled layout stores whole V-vectors")
    }

    /// Scalar accessor in logical NCHW coordinates (for references/tests).
    #[inline]
    pub fn get(&self, i: usize, c: usize, y: usize, x: usize) -> f32 {
        self.data[self.vec_offset(i, c / V, y, x) + c % V]
    }

    /// Scalar setter in logical NCHW coordinates.
    #[inline]
    pub fn set(&mut self, i: usize, c: usize, y: usize, x: usize, v: f32) {
        let o = self.vec_offset(i, c / V, y, x) + c % V;
        self.data[o] = v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// A whole image row (W consecutive V-vectors) for one (i, cb, y).
    #[inline(always)]
    pub fn row(&self, i: usize, cb: usize, y: usize) -> &[f32] {
        let o = self.vec_offset(i, cb, y, 0);
        &self.data[o..o + self.w * V]
    }

    /// Mutable image row.
    #[inline(always)]
    pub fn row_mut(&mut self, i: usize, cb: usize, y: usize) -> &mut [f32] {
        let o = self.vec_offset(i, cb, y, 0);
        &mut self.data[o..o + self.w * V]
    }

    /// Fill with uniform random values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, rng: &mut Xorshift, lo: f32, hi: f32) {
        fill_uniform(&mut self.data, rng, lo, hi);
    }

    /// Fill as a ReLU output with the given dynamic sparsity.
    pub fn fill_relu_sparse(&mut self, rng: &mut Xorshift, sparsity: f64) {
        fill_relu_sparse(&mut self.data, rng, sparsity);
    }

    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Fraction of zero elements.
    pub fn sparsity(&self) -> f64 {
        measured_sparsity(&self.data)
    }

    /// Convert from a plain NCHW buffer (tests / PJRT interchange).
    pub fn from_nchw(n: usize, c: usize, h: usize, w: usize, src: &[f32]) -> ActTensor {
        assert_eq!(src.len(), n * c * h * w);
        let mut t = ActTensor::zeros(n, c, h, w);
        for i in 0..n {
            for ch in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        t.set(i, ch, y, x, src[((i * c + ch) * h + y) * w + x]);
                    }
                }
            }
        }
        t
    }

    /// Convert to a plain NCHW buffer.
    pub fn to_nchw(&self) -> Vec<f32> {
        let (n, c, h, w) = (self.n, self.c, self.h, self.w);
        let mut out = vec![0.0; n * c * h * w];
        for i in 0..n {
            for ch in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        out[((i * c + ch) * h + y) * w + x] = self.get(i, ch, y, x);
                    }
                }
            }
        }
        out
    }

    /// Split the tensor into per-task disjoint row-tile views, one per
    /// `(i, y, qb)` triple, ordered so that view index
    /// `(i·H + y)·(C/V/qv) + qb` matches the scheduler's task numbering.
    ///
    /// `qv` is the number of channel tiles per view (the register plan's
    /// `Q/V`); it must divide `C/V`. Every element of the tensor belongs to
    /// exactly one view, so the views can be distributed across threads —
    /// the replacement for the scheduler's retired raw-pointer sharing.
    pub fn par_row_tiles_mut(&mut self, qv: usize) -> Vec<RowTileMut<'_>> {
        let cb_count = self.c_blocks();
        assert!(qv >= 1 && cb_count % qv == 0, "qv={qv} must divide C/V={cb_count}");
        let (h, w, n) = (self.h, self.w, self.n);
        let qb_count = cb_count / qv;
        let mut views: Vec<RowTileMut<'_>> = Vec::with_capacity(n * h * qb_count);
        for i in 0..n {
            for y in 0..h {
                for qb in 0..qb_count {
                    views.push(RowTileMut { i, y, qb, rows: Vec::with_capacity(qv) });
                }
            }
        }
        // Memory order is (i, cb, y): walk the buffer once and route each
        // image row to its owning view. For a fixed view, rows arrive in
        // ascending cb order, i.e. already in `j` order.
        for (ridx, row) in self.data.chunks_mut(w * V).enumerate() {
            let y = ridx % h;
            let icb = ridx / h;
            let cb = icb % cb_count;
            let i = icb / cb_count;
            let tid = (i * h + y) * qb_count + cb / qv;
            views[tid].rows.push(row);
        }
        views
    }

    /// Bytes occupied by the tensor payload.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nchw() {
        let (n, c, h, w) = (2, 32, 3, 5);
        let src: Vec<f32> = (0..n * c * h * w).map(|i| i as f32).collect();
        let t = ActTensor::from_nchw(n, c, h, w, &src);
        assert_eq!(t.to_nchw(), src);
    }

    #[test]
    fn vec_is_channel_tile() {
        let mut t = ActTensor::zeros(1, 32, 2, 2);
        for ch in 0..32 {
            t.set(0, ch, 1, 1, ch as f32);
        }
        let v0 = t.vec(0, 0, 1, 1);
        let v1 = t.vec(0, 1, 1, 1);
        assert_eq!(v0, (0..16).map(|x| x as f32).collect::<Vec<_>>().as_slice());
        assert_eq!(v1, (16..32).map(|x| x as f32).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn row_is_contiguous_w_vectors() {
        let mut t = ActTensor::zeros(1, 16, 2, 4);
        for x in 0..4 {
            t.set(0, 3, 1, x, x as f32 + 1.0);
        }
        let row = t.row(0, 0, 1);
        assert_eq!(row.len(), 4 * V);
        for x in 0..4 {
            assert_eq!(row[x * V + 3], x as f32 + 1.0);
        }
    }

    #[test]
    fn sparsity_measures() {
        let mut rng = Xorshift::new(1);
        let mut t = ActTensor::zeros(2, 64, 8, 8);
        t.fill_relu_sparse(&mut rng, 0.5);
        assert!((t.sparsity() - 0.5).abs() < 0.03);
    }

    #[test]
    #[should_panic]
    fn rejects_untiled_channels() {
        ActTensor::zeros(1, 17, 2, 2);
    }

    #[test]
    fn par_row_tiles_cover_tensor_disjointly() {
        // Writing view index + j through every view must touch every
        // element exactly once, at the position row()/row_mut() promise.
        let (n, c, h, w) = (2, 64, 3, 4);
        let qv = 2; // 4 channel tiles → 2 tiles per view
        let mut t = ActTensor::zeros(n, c, h, w);
        let qb_count = t.c_blocks() / qv;
        {
            let mut views = t.par_row_tiles_mut(qv);
            assert_eq!(views.len(), n * h * qb_count);
            for (tid, view) in views.iter_mut().enumerate() {
                // scheduler task numbering: (i, y, qb)
                assert_eq!(tid, (view.i * h + view.y) * qb_count + view.qb);
                assert_eq!(view.tiles(), qv);
                for j in 0..qv {
                    assert_eq!(view.row(j).len(), w * V);
                    for (x, v) in view.row_mut(j).iter_mut().enumerate() {
                        *v += (tid * qv + j) as f32 + x as f32 / 1000.0;
                    }
                }
            }
        }
        // Check against the direct accessors: row j of view (i, y, qb) is
        // image row (i, qb*qv + j, y).
        for i in 0..n {
            for y in 0..h {
                for qb in 0..qb_count {
                    let tid = (i * h + y) * qb_count + qb;
                    for j in 0..qv {
                        let row = t.row(i, qb * qv + j, y);
                        for (x, &v) in row.iter().enumerate() {
                            let expect = (tid * qv + j) as f32 + x as f32 / 1000.0;
                            assert_eq!(v, expect, "i={i} y={y} qb={qb} j={j} x={x}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn par_row_tiles_full_width_tile() {
        // qv == C/V: one view per (i, y), covering every channel tile.
        let mut t = ActTensor::zeros(1, 32, 2, 3);
        let views = t.par_row_tiles_mut(2);
        assert_eq!(views.len(), 2);
        assert!(views.iter().all(|v| v.qb == 0 && v.tiles() == 2));
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn par_row_tiles_rejects_non_dividing_qv() {
        let mut t = ActTensor::zeros(1, 48, 2, 2); // 3 channel tiles
        let _ = t.par_row_tiles_mut(2);
    }
}
