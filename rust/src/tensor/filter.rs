//! Filter tensor in the layout §3.2.5 prescribes:
//! dims `[K/V][C/V][S][R][V_c][V_k]`.
//!
//! Lowest dimension is an output-channel (K) vector of length V — the FMA
//! memory operand. Next is the input channel within a C-tile, then the
//! filter width R, so that while the kernel works on input channel `c` the
//! hardware prefetcher pulls the filter vectors for `c+1`.

use super::{assert_tiled, fill_uniform};
use crate::util::prng::Xorshift;
use crate::V;

/// Tiled filter tensor (G or ∂L/∂G).
#[derive(Debug, Clone, PartialEq)]
pub struct FilterTensor {
    /// Output channels (multiple of V).
    pub k: usize,
    /// Input channels (multiple of V).
    pub c: usize,
    /// Filter height S.
    pub s: usize,
    /// Filter width R.
    pub r: usize,
    data: Vec<f32>,
}

impl FilterTensor {
    pub fn zeros(k: usize, c: usize, s: usize, r: usize) -> FilterTensor {
        assert_tiled(k, "K");
        assert_tiled(c, "C");
        FilterTensor { k, c, s, r, data: vec![0.0; k * c * s * r] }
    }

    #[inline]
    pub fn k_blocks(&self) -> usize {
        self.k / V
    }

    #[inline]
    pub fn c_blocks(&self) -> usize {
        self.c / V
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat offset of the K-vector for (kb, cb, s, r, cv):
    /// `((((kb*CB + cb)*S + s)*R + r)*V + cv)*V`.
    #[inline(always)]
    pub fn vec_offset(&self, kb: usize, cb: usize, s: usize, r: usize, cv: usize) -> usize {
        debug_assert!(
            kb < self.k_blocks() && cb < self.c_blocks() && s < self.s && r < self.r && cv < V
        );
        ((((kb * self.c_blocks() + cb) * self.s + s) * self.r + r) * V + cv) * V
    }

    /// K-vector of filter weights for input channel `cb*V+cv`, tap (s, r).
    #[inline(always)]
    pub fn vec(&self, kb: usize, cb: usize, s: usize, r: usize, cv: usize) -> &[f32] {
        let o = self.vec_offset(kb, cb, s, r, cv);
        &self.data[o..o + V]
    }

    /// Mutable K-vector.
    #[inline(always)]
    pub fn vec_mut(&mut self, kb: usize, cb: usize, s: usize, r: usize, cv: usize) -> &mut [f32] {
        let o = self.vec_offset(kb, cb, s, r, cv);
        &mut self.data[o..o + V]
    }

    /// Scalar accessor in logical KCSR coordinates (for references/tests).
    #[inline]
    pub fn get(&self, k: usize, c: usize, s: usize, r: usize) -> f32 {
        self.data[self.vec_offset(k / V, c / V, s, r, c % V) + k % V]
    }

    /// Scalar setter in logical KCSR coordinates.
    #[inline]
    pub fn set(&mut self, k: usize, c: usize, s: usize, r: usize, v: f32) {
        let o = self.vec_offset(k / V, c / V, s, r, c % V) + k % V;
        self.data[o] = v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Fill with uniform random weights (centered at 0, as after init).
    pub fn fill_uniform(&mut self, rng: &mut Xorshift, lo: f32, hi: f32) {
        fill_uniform(&mut self.data, rng, lo, hi);
    }

    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Convert from a plain KCSR (i.e. KCHW-of-filters) buffer.
    pub fn from_kcsr(k: usize, c: usize, s: usize, r: usize, src: &[f32]) -> FilterTensor {
        assert_eq!(src.len(), k * c * s * r);
        let mut t = FilterTensor::zeros(k, c, s, r);
        for ko in 0..k {
            for co in 0..c {
                for si in 0..s {
                    for ri in 0..r {
                        t.set(ko, co, si, ri, src[((ko * c + co) * s + si) * r + ri]);
                    }
                }
            }
        }
        t
    }

    /// Convert to a plain KCSR buffer.
    pub fn to_kcsr(&self) -> Vec<f32> {
        let (k, c, s, r) = (self.k, self.c, self.s, self.r);
        let mut out = vec![0.0; k * c * s * r];
        for ko in 0..k {
            for co in 0..c {
                for si in 0..s {
                    for ri in 0..r {
                        out[((ko * c + co) * s + si) * r + ri] = self.get(ko, co, si, ri);
                    }
                }
            }
        }
        out
    }

    /// Channel transpose (K↔C swapped, taps unchanged):
    /// `G'[c,k,s,r] = G[k,c,s,r]`. This is the filter copy the BWI scatter
    /// kernel keeps so its FMA memory operand is a C-vector.
    pub fn transpose_channels(&self) -> FilterTensor {
        let mut t = FilterTensor::zeros(self.c, self.k, self.s, self.r);
        for ko in 0..self.k {
            for co in 0..self.c {
                for si in 0..self.s {
                    for ri in 0..self.r {
                        t.set(co, ko, si, ri, self.get(ko, co, si, ri));
                    }
                }
            }
        }
        t
    }

    /// The transposed filter view used by BWI: BWI convolves ∂L/∂Y with the
    /// filters transposed (K↔C swapped, taps mirrored). Produces a new
    /// FilterTensor with k=self.c, c=self.k, G'[c,k,s,r] = G[k,c,S-1-s,R-1-r].
    pub fn transpose_for_bwi(&self) -> FilterTensor {
        let mut t = FilterTensor::zeros(self.c, self.k, self.s, self.r);
        for ko in 0..self.k {
            for co in 0..self.c {
                for si in 0..self.s {
                    for ri in 0..self.r {
                        t.set(
                            co,
                            ko,
                            self.s - 1 - si,
                            self.r - 1 - ri,
                            self.get(ko, co, si, ri),
                        );
                    }
                }
            }
        }
        t
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_kcsr() {
        let (k, c, s, r) = (32, 16, 3, 3);
        let src: Vec<f32> = (0..k * c * s * r).map(|i| i as f32).collect();
        let t = FilterTensor::from_kcsr(k, c, s, r, &src);
        assert_eq!(t.to_kcsr(), src);
    }

    #[test]
    fn vec_is_k_tile() {
        let mut t = FilterTensor::zeros(32, 16, 1, 1);
        for ko in 0..32 {
            t.set(ko, 5, 0, 0, ko as f32);
        }
        assert_eq!(t.vec(0, 0, 0, 0, 5), (0..16).map(|x| x as f32).collect::<Vec<_>>().as_slice());
        assert_eq!(
            t.vec(1, 0, 0, 0, 5),
            (16..32).map(|x| x as f32).collect::<Vec<_>>().as_slice()
        );
    }

    #[test]
    fn bwi_transpose_swaps_and_mirrors() {
        let mut rng = Xorshift::new(3);
        let mut g = FilterTensor::zeros(16, 32, 3, 3);
        g.fill_uniform(&mut rng, -1.0, 1.0);
        let gt = g.transpose_for_bwi();
        assert_eq!((gt.k, gt.c, gt.s, gt.r), (32, 16, 3, 3));
        for ko in 0..16 {
            for co in 0..32 {
                for si in 0..3 {
                    for ri in 0..3 {
                        assert_eq!(gt.get(co, ko, 2 - si, 2 - ri), g.get(ko, co, si, ri));
                    }
                }
            }
        }
        // double transpose is identity
        let gtt = gt.transpose_for_bwi();
        assert_eq!(gtt.to_kcsr(), g.to_kcsr());
    }

    #[test]
    fn filter_layout_r_strides() {
        // Vectors for consecutive r must be V*V apart (the prefetch-friendly
        // property: R is above [Vc][Vk]).
        let t = FilterTensor::zeros(16, 16, 3, 3);
        let o0 = t.vec_offset(0, 0, 0, 0, 0);
        let o1 = t.vec_offset(0, 0, 0, 1, 0);
        assert_eq!(o1 - o0, V * V);
        // consecutive cv are V apart
        assert_eq!(t.vec_offset(0, 0, 0, 0, 1) - o0, V);
    }
}
