//! Filter tensor in the layout §3.2.5 prescribes:
//! dims `[K/V][C/V][S][R][V_c][V_k]`.
//!
//! Lowest dimension is an output-channel (K) vector of length V — the FMA
//! memory operand. Next is the input channel within a C-tile, then the
//! filter width R, so that while the kernel works on input channel `c` the
//! hardware prefetcher pulls the filter vectors for `c+1`.

use super::{assert_tiled, fill_uniform};
use crate::util::prng::Xorshift;
use crate::V;

/// An owned, disjoint view of one BWW task's filter-gradient tile: every
/// dG K-vector for output-channel tiles `qb·qv .. (qb+1)·qv` × single input
/// channel `c`, i.e. the `(qb, c)` partition §3.4's minibatch-invariant
/// sweep destination makes atomic-free.
///
/// Produced by [`FilterTensor::par_qc_tiles_mut`], which carves the backing
/// buffer with `chunks_mut` at V-vector granularity — two views can never
/// alias, so handing them to worker threads needs no `unsafe`.
#[derive(Debug)]
pub struct FilterTileMut<'a> {
    /// Q-tile index: this view covers K-tiles `qb*qv + j`, `j < qv`.
    pub qb: usize,
    /// The single input channel this view owns.
    pub c: usize,
    s: usize,
    r: usize,
    /// Indexed `(j·S + s)·R + r`; each slice is one K-vector of length V.
    vecs: Vec<&'a mut [f32]>,
}

impl<'a> FilterTileMut<'a> {
    /// Number of K-tiles in this view (the plan's `Q/V`).
    #[inline]
    pub fn tiles(&self) -> usize {
        self.vecs.len() / (self.s * self.r)
    }

    /// The dG K-vector for K-tile `qb*qv + j`, tap `(s, r)`, input channel
    /// `self.c` — the slice the sweep's end-of-row fold accumulates into.
    #[inline(always)]
    pub fn vec_mut(&mut self, j: usize, s: usize, r: usize) -> &mut [f32] {
        &mut self.vecs[(j * self.s + s) * self.r + r][..]
    }
}

/// Tiled filter tensor (G or ∂L/∂G).
#[derive(Debug, Clone, PartialEq)]
pub struct FilterTensor {
    /// Output channels (multiple of V).
    pub k: usize,
    /// Input channels (multiple of V).
    pub c: usize,
    /// Filter height S.
    pub s: usize,
    /// Filter width R.
    pub r: usize,
    data: Vec<f32>,
}

impl FilterTensor {
    pub fn zeros(k: usize, c: usize, s: usize, r: usize) -> FilterTensor {
        assert_tiled(k, "K");
        assert_tiled(c, "C");
        FilterTensor { k, c, s, r, data: vec![0.0; k * c * s * r] }
    }

    #[inline]
    pub fn k_blocks(&self) -> usize {
        self.k / V
    }

    #[inline]
    pub fn c_blocks(&self) -> usize {
        self.c / V
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat offset of the K-vector for (kb, cb, s, r, cv):
    /// `((((kb*CB + cb)*S + s)*R + r)*V + cv)*V`.
    #[inline(always)]
    pub fn vec_offset(&self, kb: usize, cb: usize, s: usize, r: usize, cv: usize) -> usize {
        debug_assert!(
            kb < self.k_blocks() && cb < self.c_blocks() && s < self.s && r < self.r && cv < V
        );
        ((((kb * self.c_blocks() + cb) * self.s + s) * self.r + r) * V + cv) * V
    }

    /// K-vector of filter weights for input channel `cb*V+cv`, tap (s, r).
    #[inline(always)]
    pub fn vec(&self, kb: usize, cb: usize, s: usize, r: usize, cv: usize) -> &[f32] {
        let o = self.vec_offset(kb, cb, s, r, cv);
        &self.data[o..o + V]
    }

    /// Mutable K-vector.
    #[inline(always)]
    pub fn vec_mut(&mut self, kb: usize, cb: usize, s: usize, r: usize, cv: usize) -> &mut [f32] {
        let o = self.vec_offset(kb, cb, s, r, cv);
        &mut self.data[o..o + V]
    }

    /// Scalar accessor in logical KCSR coordinates (for references/tests).
    #[inline]
    pub fn get(&self, k: usize, c: usize, s: usize, r: usize) -> f32 {
        self.data[self.vec_offset(k / V, c / V, s, r, c % V) + k % V]
    }

    /// Scalar setter in logical KCSR coordinates.
    #[inline]
    pub fn set(&mut self, k: usize, c: usize, s: usize, r: usize, v: f32) {
        let o = self.vec_offset(k / V, c / V, s, r, c % V) + k % V;
        self.data[o] = v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Fill with uniform random weights (centered at 0, as after init).
    pub fn fill_uniform(&mut self, rng: &mut Xorshift, lo: f32, hi: f32) {
        fill_uniform(&mut self.data, rng, lo, hi);
    }

    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Convert from a plain KCSR (i.e. KCHW-of-filters) buffer.
    pub fn from_kcsr(k: usize, c: usize, s: usize, r: usize, src: &[f32]) -> FilterTensor {
        assert_eq!(src.len(), k * c * s * r);
        let mut t = FilterTensor::zeros(k, c, s, r);
        for ko in 0..k {
            for co in 0..c {
                for si in 0..s {
                    for ri in 0..r {
                        t.set(ko, co, si, ri, src[((ko * c + co) * s + si) * r + ri]);
                    }
                }
            }
        }
        t
    }

    /// Convert to a plain KCSR buffer.
    pub fn to_kcsr(&self) -> Vec<f32> {
        let (k, c, s, r) = (self.k, self.c, self.s, self.r);
        let mut out = vec![0.0; k * c * s * r];
        for ko in 0..k {
            for co in 0..c {
                for si in 0..s {
                    for ri in 0..r {
                        out[((ko * c + co) * s + si) * r + ri] = self.get(ko, co, si, ri);
                    }
                }
            }
        }
        out
    }

    /// Channel transpose (K↔C swapped, taps unchanged):
    /// `G'[c,k,s,r] = G[k,c,s,r]`. This is the filter copy the BWI scatter
    /// kernel keeps so its FMA memory operand is a C-vector.
    pub fn transpose_channels(&self) -> FilterTensor {
        let mut t = FilterTensor::zeros(self.c, self.k, self.s, self.r);
        for ko in 0..self.k {
            for co in 0..self.c {
                for si in 0..self.s {
                    for ri in 0..self.r {
                        t.set(co, ko, si, ri, self.get(ko, co, si, ri));
                    }
                }
            }
        }
        t
    }

    /// The transposed filter view used by BWI: BWI convolves ∂L/∂Y with the
    /// filters transposed (K↔C swapped, taps mirrored). Produces a new
    /// FilterTensor with k=self.c, c=self.k, G'[c,k,s,r] = G[k,c,S-1-s,R-1-r].
    pub fn transpose_for_bwi(&self) -> FilterTensor {
        let mut t = FilterTensor::zeros(self.c, self.k, self.s, self.r);
        for ko in 0..self.k {
            for co in 0..self.c {
                for si in 0..self.s {
                    for ri in 0..self.r {
                        t.set(
                            co,
                            ko,
                            self.s - 1 - si,
                            self.r - 1 - ri,
                            self.get(ko, co, si, ri),
                        );
                    }
                }
            }
        }
        t
    }

    /// Split the tensor into per-task disjoint `(qb, c)` tile views,
    /// ordered so that view index `qb·C + c` matches the BWW scheduler's
    /// task numbering.
    ///
    /// `qv` is the number of K-tiles per view (the BWW plan's `Q/V`); it
    /// must divide `K/V`. Every K-vector of the tensor belongs to exactly
    /// one view — the property that makes parallel filter-gradient
    /// accumulation lock- and atomic-free (§3.4).
    pub fn par_qc_tiles_mut(&mut self, qv: usize) -> Vec<FilterTileMut<'_>> {
        let kb_count = self.k_blocks();
        assert!(qv >= 1 && kb_count % qv == 0, "qv={qv} must divide K/V={kb_count}");
        let (c, s, r) = (self.c, self.s, self.r);
        let cb_count = self.c_blocks();
        let qb_count = kb_count / qv;
        let mut views: Vec<FilterTileMut<'_>> = Vec::with_capacity(qb_count * c);
        for qb in 0..qb_count {
            for ch in 0..c {
                views.push(FilterTileMut { qb, c: ch, s, r, vecs: Vec::with_capacity(qv * s * r) });
            }
        }
        // Memory order is (kb, cb, s, r, cv): walk the buffer one K-vector
        // at a time and route it to the view owning (kb/qv, cb·V + cv).
        // For a fixed view, vectors arrive in (j, s, r) order — exactly the
        // `vec_mut` index layout.
        for (vidx, kvec) in self.data.chunks_mut(V).enumerate() {
            let cv = vidx % V;
            let rest = vidx / (V * r * s); // drop the (s, r, cv) coordinates
            let cb = rest % cb_count;
            let kb = rest / cb_count;
            let tid = (kb / qv) * c + (cb * V + cv);
            views[tid].vecs.push(kvec);
        }
        views
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_kcsr() {
        let (k, c, s, r) = (32, 16, 3, 3);
        let src: Vec<f32> = (0..k * c * s * r).map(|i| i as f32).collect();
        let t = FilterTensor::from_kcsr(k, c, s, r, &src);
        assert_eq!(t.to_kcsr(), src);
    }

    #[test]
    fn vec_is_k_tile() {
        let mut t = FilterTensor::zeros(32, 16, 1, 1);
        for ko in 0..32 {
            t.set(ko, 5, 0, 0, ko as f32);
        }
        assert_eq!(t.vec(0, 0, 0, 0, 5), (0..16).map(|x| x as f32).collect::<Vec<_>>().as_slice());
        assert_eq!(
            t.vec(1, 0, 0, 0, 5),
            (16..32).map(|x| x as f32).collect::<Vec<_>>().as_slice()
        );
    }

    #[test]
    fn bwi_transpose_swaps_and_mirrors() {
        let mut rng = Xorshift::new(3);
        let mut g = FilterTensor::zeros(16, 32, 3, 3);
        g.fill_uniform(&mut rng, -1.0, 1.0);
        let gt = g.transpose_for_bwi();
        assert_eq!((gt.k, gt.c, gt.s, gt.r), (32, 16, 3, 3));
        for ko in 0..16 {
            for co in 0..32 {
                for si in 0..3 {
                    for ri in 0..3 {
                        assert_eq!(gt.get(co, ko, 2 - si, 2 - ri), g.get(ko, co, si, ri));
                    }
                }
            }
        }
        // double transpose is identity
        let gtt = gt.transpose_for_bwi();
        assert_eq!(gtt.to_kcsr(), g.to_kcsr());
    }

    #[test]
    fn par_qc_tiles_cover_tensor_disjointly() {
        // Writing a view-unique value through every vec_mut slot must
        // reach every element exactly once, at the position the scalar
        // accessor predicts.
        let (k, c, s, r) = (32, 32, 2, 3);
        let qv = 2; // 2 K-tiles → 1 view per (qb=0, c)
        let mut t = FilterTensor::zeros(k, c, s, r);
        let qb_count = t.k_blocks() / qv;
        {
            let mut views = t.par_qc_tiles_mut(qv);
            assert_eq!(views.len(), qb_count * c);
            for (tid, view) in views.iter_mut().enumerate() {
                // BWW task numbering: (qb, c)
                assert_eq!(tid, view.qb * c + view.c);
                assert_eq!(view.tiles(), qv);
                for j in 0..qv {
                    for si in 0..s {
                        for ri in 0..r {
                            let vec = view.vec_mut(j, si, ri);
                            assert_eq!(vec.len(), V);
                            for (l, v) in vec.iter_mut().enumerate() {
                                *v = (((tid * qv + j) * s + si) * r + ri) as f32
                                    + l as f32 / 100.0;
                            }
                        }
                    }
                }
            }
        }
        // vec (j, si, ri) of view (qb, ch) is K-vector (qb*qv+j, ch/V, si,
        // ri, ch%V); lane l is logical K index (qb*qv+j)*V + l.
        for qb in 0..qb_count {
            for ch in 0..c {
                let tid = qb * c + ch;
                for j in 0..qv {
                    for si in 0..s {
                        for ri in 0..r {
                            let vec = t.vec(qb * qv + j, ch / V, si, ri, ch % V);
                            for (l, &v) in vec.iter().enumerate() {
                                let expect = (((tid * qv + j) * s + si) * r + ri) as f32
                                    + l as f32 / 100.0;
                                assert_eq!(v, expect, "qb={qb} c={ch} j={j} s={si} r={ri} l={l}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn par_qc_tiles_rejects_non_dividing_qv() {
        let mut t = FilterTensor::zeros(48, 16, 3, 3); // 3 K-tiles
        let _ = t.par_qc_tiles_mut(2);
    }

    #[test]
    fn filter_layout_r_strides() {
        // Vectors for consecutive r must be V*V apart (the prefetch-friendly
        // property: R is above [Vc][Vk]).
        let t = FilterTensor::zeros(16, 16, 3, 3);
        let o0 = t.vec_offset(0, 0, 0, 0, 0);
        let o1 = t.vec_offset(0, 0, 0, 1, 0);
        assert_eq!(o1 - o0, V * V);
        // consecutive cv are V apart
        assert_eq!(t.vec_offset(0, 0, 0, 0, 1) - o0, V);
    }
}
