//! BWW input layout (§3.4): dims `[N/V][C][H][W][V_n]`.
//!
//! BWW vectorizes the zero-check along the minibatch dimension (so all V
//! lanes update the same dG vectors, avoiding register spills); the input D
//! is transposed so the lowest dimension is a minibatch tile of size V and
//! the check needs no gather.

use super::{assert_tiled, measured_sparsity};
use crate::tensor::ActTensor;
use crate::V;

/// N-tiled activation tensor used as the BWW input.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchTiledTensor {
    /// Minibatch size (multiple of V).
    pub n: usize,
    /// Channels.
    pub c: usize,
    pub h: usize,
    pub w: usize,
    data: Vec<f32>,
}

impl BatchTiledTensor {
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> BatchTiledTensor {
        assert_tiled(n, "N");
        BatchTiledTensor { n, c, h, w, data: vec![0.0; n * c * h * w] }
    }

    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.n / V
    }

    /// Flat offset of the minibatch V-vector at (nb, c, y, x).
    #[inline(always)]
    pub fn vec_offset(&self, nb: usize, c: usize, y: usize, x: usize) -> usize {
        debug_assert!(nb < self.n_blocks() && c < self.c && y < self.h && x < self.w);
        (((nb * self.c + c) * self.h + y) * self.w + x) * V
    }

    /// Minibatch vector `D[nb*V .. nb*V+V, c, y, x]`.
    #[inline(always)]
    pub fn vec(&self, nb: usize, c: usize, y: usize, x: usize) -> &[f32] {
        let o = self.vec_offset(nb, c, y, x);
        &self.data[o..o + V]
    }

    /// Minibatch vector as a fixed-size array reference — the zero-check
    /// operand shape for [`crate::kernels::simd::Backend::nonzero_mask`].
    #[inline(always)]
    pub fn vec_arr(&self, nb: usize, c: usize, y: usize, x: usize) -> &[f32; V] {
        let o = self.vec_offset(nb, c, y, x);
        self.data[o..o + V].try_into().expect("tiled layout stores whole V-vectors")
    }

    /// Scalar accessor in logical (i, c, y, x) coordinates.
    #[inline]
    pub fn get(&self, i: usize, c: usize, y: usize, x: usize) -> f32 {
        self.data[self.vec_offset(i / V, c, y, x) + i % V]
    }

    /// Scalar setter.
    #[inline]
    pub fn set(&mut self, i: usize, c: usize, y: usize, x: usize, v: f32) {
        let o = self.vec_offset(i / V, c, y, x) + i % V;
        self.data[o] = v;
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Transpose from the NCHWc activation layout (the explicit data-layout
    /// transformation the paper performs before BWW).
    pub fn from_act(src: &ActTensor) -> BatchTiledTensor {
        let mut t = BatchTiledTensor::zeros(src.n, src.c, src.h, src.w);
        for i in 0..src.n {
            for c in 0..src.c {
                for y in 0..src.h {
                    for x in 0..src.w {
                        t.set(i, c, y, x, src.get(i, c, y, x));
                    }
                }
            }
        }
        t
    }

    pub fn sparsity(&self) -> f64 {
        measured_sparsity(&self.data)
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xorshift;

    #[test]
    fn transpose_preserves_values() {
        let mut rng = Xorshift::new(7);
        let mut a = ActTensor::zeros(16, 32, 3, 4);
        a.fill_uniform(&mut rng, -1.0, 1.0);
        let b = BatchTiledTensor::from_act(&a);
        for i in 0..16 {
            for c in 0..32 {
                for y in 0..3 {
                    for x in 0..4 {
                        assert_eq!(b.get(i, c, y, x), a.get(i, c, y, x));
                    }
                }
            }
        }
    }

    #[test]
    fn vec_is_minibatch_tile() {
        let mut t = BatchTiledTensor::zeros(16, 4, 2, 2);
        for i in 0..16 {
            t.set(i, 2, 1, 0, i as f32);
        }
        assert_eq!(t.vec(0, 2, 1, 0), (0..16).map(|x| x as f32).collect::<Vec<_>>().as_slice());
    }

    #[test]
    #[should_panic]
    fn rejects_untiled_batch() {
        BatchTiledTensor::zeros(10, 4, 2, 2);
    }
}
