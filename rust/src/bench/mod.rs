//! Hand-rolled benchmark harness (offline substitute for `criterion`).
//!
//! Used by every target in `rust/benches/`. Provides warmup, adaptive
//! iteration counts, outlier-trimmed summaries, and a `black_box` to defeat
//! dead-code elimination. [`wallclock`] layers the real-kernel wall-clock
//! sweep (→ `BENCH_kernels.json`) on top of it; [`loadgen`] drives the
//! serving front end open loop (→ `BENCH_serve.json`).

pub mod experiments;
pub mod loadgen;
pub mod wallclock;

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Harness configuration. Defaults target ~quick but stable measurements;
/// override with env `SPARSETRAIN_BENCH_FAST=1` for smoke runs.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Minimum wall time spent in warmup.
    pub warmup: Duration,
    /// Minimum wall time spent measuring.
    pub measure: Duration,
    /// Minimum number of measured samples.
    pub min_samples: usize,
    /// Maximum number of measured samples.
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if std::env::var("SPARSETRAIN_BENCH_FAST").is_ok() {
            BenchConfig {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(80),
                min_samples: 3,
                max_samples: 20,
            }
        } else {
            BenchConfig {
                warmup: Duration::from_millis(150),
                measure: Duration::from_millis(600),
                min_samples: 7,
                max_samples: 200,
            }
        }
    }
}

/// Result of one benchmark: per-iteration wall time in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    /// Outlier-trimmed central estimate (median).
    pub fn ns(&self) -> f64 {
        self.summary().median
    }

    pub fn report_line(&self) -> String {
        let s = self.summary();
        format!(
            "{:<44} {:>12}  (±{:>10}, n={})",
            self.name,
            crate::util::table::fmt_duration_ns(s.median),
            crate::util::table::fmt_duration_ns(s.stddev),
            s.n
        )
    }
}

/// Measure `f`, which performs ONE unit of work per call.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup: run until warmup duration elapsed (at least once).
    let t0 = Instant::now();
    let mut warm_iters: u64 = 0;
    loop {
        f();
        warm_iters += 1;
        if t0.elapsed() >= cfg.warmup {
            break;
        }
    }
    // Estimate per-iter time to choose inner batch size so each sample is
    // at least ~200 µs (amortizes timer overhead) unless calls are long.
    let per_iter = t0.elapsed().as_nanos() as f64 / warm_iters as f64;
    let batch = ((200_000.0 / per_iter.max(1.0)).ceil() as u64).max(1);

    let mut samples = Vec::new();
    let t1 = Instant::now();
    while (samples.len() < cfg.min_samples)
        || (t1.elapsed() < cfg.measure && samples.len() < cfg.max_samples)
    {
        let s = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(s.elapsed().as_nanos() as f64 / batch as f64);
    }
    BenchResult { name: name.to_string(), samples_ns: samples }
}

/// A named group of benchmarks that prints a criterion-like report.
pub struct BenchGroup {
    title: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchGroup {
    pub fn new(title: &str) -> BenchGroup {
        BenchGroup { title: title.to_string(), cfg: BenchConfig::default(), results: Vec::new() }
    }

    pub fn with_config(title: &str, cfg: BenchConfig) -> BenchGroup {
        BenchGroup { title: title.to_string(), cfg, results: Vec::new() }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        let r = bench(name, &self.cfg, f);
        println!("  {}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn start(&self) {
        println!("\n### {} ###", self.title);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Median time of a previously-run benchmark by name.
    pub fn ns_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            min_samples: 3,
            max_samples: 10,
        }
    }

    #[test]
    fn measures_something_positive() {
        let r = bench("noop-ish", &fast_cfg(), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.ns() > 0.0);
        assert!(r.samples_ns.len() >= 3);
    }

    #[test]
    fn longer_work_measures_longer() {
        let cfg = fast_cfg();
        let short = bench("short", &cfg, || {
            black_box((0..100u64).map(|x| x * x).sum::<u64>());
        });
        let long = bench("long", &cfg, || {
            black_box((0..20_000u64).map(|x| x * x).sum::<u64>());
        });
        assert!(
            long.ns() > short.ns() * 5.0,
            "long={} short={}",
            long.ns(),
            short.ns()
        );
    }

    #[test]
    fn group_collects_results() {
        let mut g = BenchGroup::with_config("t", fast_cfg());
        g.bench("a", || {
            black_box(1 + 1);
        });
        assert!(g.ns_of("a").is_some());
        assert!(g.ns_of("b").is_none());
    }
}
