//! Wall-clock kernel benchmark harness → `BENCH_kernels.json`.
//!
//! Everything else under `bench/` times the *model* (`sim::cost`); this
//! module times the **real kernels** on the host CPU: FWD/BWI/BWW × {dense
//! `direct`, [`SkipMode::Dense`], [`SkipMode::PerLaneBranch`],
//! [`SkipMode::MaskLoop`]} × sparsity grid × thread counts, on Table-2
//! layers, through the dispatched SIMD backend. The JSON report it writes
//! is the repo's perf trajectory: every future PR can regenerate it
//! (`cargo run --release --example wallclock`) and diff medians against
//! the committed history in ROADMAP.md's Perf log.
//!
//! Two speedups are recorded per row:
//! * `speedup_vs_direct1` — serial dense `direct` time ÷ row time: the
//!   headline "sparse training beats a tuned dense kernel" number
//!   (includes parallel scaling for multi-thread rows);
//! * `speedup_vs_dense_same_threads` — Dense-mode time at the same thread
//!   count ÷ row time: isolates the skip machinery's benefit from both
//!   parallelism and loop-structure effects.
//!
//! Schema v2 (ISSUE 5) adds two row families:
//! * `mode: "direct_pre"` (BWI only) — the dense baseline over the
//!   pre-transposed filter copy, removing the per-tap gather that made the
//!   original `direct` BWI unfairly slow;
//! * `component: "trainer_step"` — median ns per **full train step** at
//!   the paper geometry through the offline artifact, `naive-interp`
//!   (interpreter-only) vs `kernel-routed` (SparseTrain executor) at each
//!   thread count; `speedup_vs_direct1` on these rows is the speedup over
//!   the naive interpreter, the trainer-level perf trajectory. (Release
//!   builds only; `sparsity` is recorded as 0.0 — the routed step measures
//!   its operand sparsity live per convolution.)
//!
//! Since ISSUE 6 the `kernel-routed` rows measure the **whole-graph op
//! router**: convolutions on the sparse kernels, `dot` on the blocked
//! parallel GEMM, and recognized elementwise chains fused. The PR 5
//! floor (routed ≥ 2× naive at 2 threads) is CI-enforced via the
//! example's `--min-trainer-speedup` flag; the ISSUE 6 target is ≥ 5×.
//!
//! Schema v3 (ISSUE 8) adds the measured-cost autotuning dimension:
//! * every record carries a `selector` field — `"none"` for kernel
//!   rows and the naive-interp baseline, `"analytic"` for routed
//!   trainer rows with the cost DB detached (the analytic model picks
//!   every skip mode), `"measured"` for routed trainer rows with a
//!   fresh in-memory [`CostDb`] warmed by untimed steps first, so the
//!   selector runs on measured costs — the analytic-vs-measured pair is
//!   the autotuner's acceptance readout
//!   ([`WallclockReport::measured_vs_analytic`]);
//! * `layer: "resnet34_small"` trainer rows put the same pair on a
//!   multi-layer zoo net whose per-layer sparsities differ (full sweep
//!   only — the smoke config skips them);
//! * when a cost DB is attached to the sweep
//!   ([`WallclockConfig::cost_db`], CLI `--cost-db`), every timed
//!   kernel cell's median is folded into it — the **bulk population**
//!   path that seeds `PerLaneBranch` entries the router's lazy
//!   exploration never tries on its own.

use crate::bench::{bench, black_box, BenchConfig, BenchResult};
use crate::coordinator::costdb::{CostDb, CostKey};
use crate::coordinator::scheduler::Scheduler;
use crate::kernels::layers::synthetic_batch;
use crate::kernels::simd::{self, Backend};
use crate::kernels::{direct, sparse_bwi, sparse_bww, sparse_fwd};
use crate::kernels::{Component, ConvConfig, KernelStats, Scratch, SkipMode};
use crate::nets::table2::{layer_by_name, NamedLayer};
use crate::nets::{Network, Scale};
use crate::runtime::artifacts::{geometry, ArtifactSet, TRAIN_STEP};
use crate::runtime::hlo_builder::{self, NetModel};
use crate::runtime::pjrt::{literal_f32, literal_i32, Runtime};
use crate::tensor::{ActTensor, BatchTiledTensor, FilterTensor};
use crate::util::prng::Xorshift;
use crate::V;
use std::sync::Arc;

/// The report schema version. v2 (ISSUE 5) added the pre-transposed dense
/// BWI baseline rows (`mode: "direct_pre"`) and the end-to-end
/// `trainer_step` rows; v3 (ISSUE 8) adds the per-record `selector` field
/// ("none" / "analytic" / "measured") and the zoo-net trainer pair; v4
/// (ISSUE 9) adds optional serving-latency fields on `component: "serve"`
/// rows ([`ServeExtra`]: p50/p95/p99 latency, throughput, request and
/// reject counts, batch-size histogram) emitted by the
/// [`crate::bench::loadgen`] load generator; v5 (ISSUE 10) adds the
/// per-record `pipeline` field ("on" / "off" / "none") and a zoo-net
/// trainer pair timed with the dependency-scheduled evaluator explicitly
/// on vs off at the same selector and thread count
/// ([`WallclockReport::pipeline_speedup`]).
pub const SCHEMA: &str = "sparsetrain-wallclock-v5";

/// Untimed steps run before timing a `selector: "measured"` trainer row:
/// enough for every per-step conv key to go cold → explored → warm (the
/// lazy path needs at most three executions per key), so the timed
/// region measures DB-hit selection, not exploration.
pub const COSTDB_WARMUP_STEPS: usize = 3;

/// Default Table-2 layer set: three 3×3 shapes (one strided) and one 1×1,
/// small enough that a full sweep finishes in minutes, large enough that
/// the working sets exceed L2.
pub const DEFAULT_LAYERS: [&str; 4] = ["resnet5_2", "resnet4_2", "resnet3_2/r", "resnet5_1a"];

/// Sparsity grid from the acceptance criteria.
pub const DEFAULT_SPARSITIES: [f64; 3] = [0.0, 0.5, 0.9];

/// Harness configuration.
pub struct WallclockConfig {
    pub layers: Vec<NamedLayer>,
    pub sparsities: Vec<f64>,
    /// Thread counts to sweep (deduplicated, each ≥ 1).
    pub threads: Vec<usize>,
    pub bench: BenchConfig,
    pub seed: u64,
    /// Bulk-population target: when set, every timed kernel cell's median
    /// is recorded into this cost DB (the caller saves it afterwards).
    pub cost_db: Option<Arc<CostDb>>,
    /// Also time the zoo-net trainer pair (`resnet34_small`, analytic vs
    /// measured) — minutes of extra wall time, so full sweeps only.
    pub zoo_trainer: bool,
}

impl WallclockConfig {
    /// The default sweep: [`DEFAULT_LAYERS`] × [`DEFAULT_SPARSITIES`] ×
    /// powers-of-two threads up to the host parallelism.
    pub fn default_sweep() -> WallclockConfig {
        let layers = DEFAULT_LAYERS
            .iter()
            .map(|n| layer_by_name(n).expect("default layer must exist in Table 2"))
            .collect();
        WallclockConfig {
            layers,
            sparsities: DEFAULT_SPARSITIES.to_vec(),
            threads: host_thread_sweep(),
            bench: BenchConfig::default(),
            seed: 0xBE_BC,
            cost_db: None,
            zoo_trainer: true,
        }
    }

    /// A seconds-scale smoke sweep on one tiny 3×3 layer — exercised by
    /// `cargo test` and the CI smoke leg so the JSON emitter cannot rot.
    pub fn smoke() -> WallclockConfig {
        WallclockConfig {
            layers: vec![NamedLayer {
                name: "tiny3x3",
                cfg: ConvConfig::square(V, 16, 16, 4, 3, 1),
            }],
            sparsities: vec![0.0, 0.9],
            threads: vec![1, 2],
            bench: BenchConfig {
                warmup: std::time::Duration::from_millis(2),
                measure: std::time::Duration::from_millis(10),
                min_samples: 2,
                max_samples: 10,
            },
            seed: 7,
            cost_db: None,
            zoo_trainer: false,
        }
    }
}

/// `1, 2, 4, …` up to and including the host's available parallelism.
pub fn host_thread_sweep() -> Vec<usize> {
    let max = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut out = Vec::new();
    let mut t = 1;
    while t < max {
        out.push(t);
        t *= 2;
    }
    out.push(max);
    out.dedup();
    out
}

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct WallclockRecord {
    pub layer: String,
    /// Filter size R (= S) of the layer — lets readers split 3×3 vs 1×1.
    pub rs: usize,
    pub component: &'static str,
    /// "direct" (dense baseline kernel) or the `SkipMode` name.
    pub mode: &'static str,
    /// Skip-mode decision source for routed trainer rows: `"analytic"`
    /// (cost DB detached) or `"measured"` (warmed DB consulted first).
    /// `"none"` for kernel cells and the naive baseline, where no
    /// selector runs.
    pub selector: &'static str,
    /// Schema v5: whether the dependency-scheduled (pipelined) evaluator
    /// ran this row — `"on"` / `"off"` for trainer-step rows, `"none"`
    /// for kernel cells and serve rows, where it never applies.
    pub pipeline: &'static str,
    pub sparsity: f64,
    pub threads: usize,
    pub median_ns: f64,
    /// Effective (dense-equivalent) GFLOP/s: dense FLOPs ÷ wall time.
    pub gflops: f64,
    pub speedup_vs_direct1: f64,
    pub speedup_vs_dense_same_threads: f64,
    /// Serving-latency extension (schema v4+): present exactly on
    /// `component: "serve"` rows, `None` on every kernel/trainer row.
    pub serve: Option<ServeExtra>,
}

/// The v4 serving fields carried by `component: "serve"` rows — tail
/// latency, throughput, and the batch-size histogram from one
/// [`crate::bench::loadgen`] scenario run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeExtra {
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub throughput_rps: f64,
    /// Requests submitted by the generator (accepted + rejected).
    pub requests: usize,
    /// Requests shed by the bounded queue.
    pub rejected: usize,
    /// `(batch size, batches executed)` ascending by size.
    pub batch_hist: Vec<(usize, usize)>,
}

/// The full report: detected backend + all records.
#[derive(Debug)]
pub struct WallclockReport {
    pub backend: &'static str,
    /// "release" or "debug" — debug timings must never be compared against
    /// release trajectories.
    pub profile: &'static str,
    pub threads_available: usize,
    pub records: Vec<WallclockRecord>,
}

/// The build profile of this binary, as recorded in the report.
pub fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

fn mode_name(mode: SkipMode) -> &'static str {
    match mode {
        SkipMode::Dense => "Dense",
        SkipMode::PerLaneBranch => "PerLaneBranch",
        SkipMode::MaskLoop => "MaskLoop",
    }
}

/// Per-layer fixture: inputs at one sparsity plus reusable outputs.
struct Fixture {
    cfg: ConvConfig,
    d: ActTensor,
    g: FilterTensor,
    gt: FilterTensor,
    dt: BatchTiledTensor,
    dy: ActTensor,
    y: ActTensor,
    dd: ActTensor,
    dg: FilterTensor,
}

impl Fixture {
    fn new(cfg: &ConvConfig, sparsity: f64, seed: u64) -> Fixture {
        let mut rng = Xorshift::new(seed);
        let mut d = ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w);
        d.fill_relu_sparse(&mut rng, sparsity);
        let mut g = FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r);
        g.fill_uniform(&mut rng, -0.5, 0.5);
        let gt = g.transpose_channels();
        let dt = BatchTiledTensor::from_act(&d);
        // ∂L/∂Y carries the same ReLU sparsity (signed) — it is the BWI
        // checked operand and the BWW memory operand.
        let mut dy = ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w());
        dy.fill_relu_sparse(&mut rng, sparsity);
        for v in dy.data_mut().iter_mut() {
            if *v != 0.0 && rng.bernoulli(0.5) {
                *v = -*v;
            }
        }
        Fixture {
            cfg: *cfg,
            y: ActTensor::zeros(cfg.n, cfg.k, cfg.out_h(), cfg.out_w()),
            dd: ActTensor::zeros(cfg.n, cfg.c, cfg.h, cfg.w),
            dg: FilterTensor::zeros(cfg.k, cfg.c, cfg.s, cfg.r),
            d,
            g,
            gt,
            dt,
            dy,
        }
    }
}

/// Time one (component, mode) cell. The output tensor is re-zeroed inside
/// the timed closure (the kernels accumulate), so every iteration performs
/// the same work.
fn time_cell(
    fx: &mut Fixture,
    comp: Component,
    mode: Option<SkipMode>, // None = dense `direct` baseline
    threads: usize,
    bk: Backend,
    bcfg: &BenchConfig,
) -> BenchResult {
    let cfg = fx.cfg;
    let name = format!("{} {} t{threads}", comp.name(), mode.map_or("direct", mode_name));
    // One stats block reused across iterations: counters just accumulate
    // (never read here), keeping the timed loop allocation-free.
    let mut st = KernelStats::new();
    match (mode, comp) {
        (None, Component::Fwd) => {
            let mut scratch = Scratch::new();
            let (d, g, y) = (&fx.d, &fx.g, &mut fx.y);
            bench(&name, bcfg, || {
                y.fill_zero();
                direct::fwd_with(&cfg, d, g, y, bk, &mut scratch, &mut st);
            })
        }
        (None, Component::Bwi) => {
            let (dy, g, dd) = (&fx.dy, &fx.g, &mut fx.dd);
            bench(&name, bcfg, || {
                dd.fill_zero();
                direct::bwi_with(&cfg, dy, g, dd, bk, &mut st);
            })
        }
        (None, Component::Bww) => {
            let mut scratch = Scratch::new();
            let (dt, dy, dg) = (&fx.dt, &fx.dy, &mut fx.dg);
            bench(&name, bcfg, || {
                dg.fill_zero();
                direct::bww_with(&cfg, dt, dy, dg, bk, &mut scratch, &mut st);
            })
        }
        (Some(mode), comp) if threads == 1 => {
            // serial drivers: the zero-alloc `*_with` entry points
            let mut scratch = Scratch::new();
            match comp {
                Component::Fwd => {
                    let (d, g, y) = (&fx.d, &fx.g, &mut fx.y);
                    bench(&name, bcfg, || {
                        y.fill_zero();
                        sparse_fwd::fwd_with(&cfg, d, g, y, mode, bk, &mut scratch, &mut st);
                    })
                }
                Component::Bwi => {
                    let (dy, gt, dd) = (&fx.dy, &fx.gt, &mut fx.dd);
                    bench(&name, bcfg, || {
                        dd.fill_zero();
                        sparse_bwi::bwi_with(&cfg, dy, gt, dd, mode, bk, &mut scratch, &mut st);
                    })
                }
                Component::Bww => {
                    let (dt, dy, dg) = (&fx.dt, &fx.dy, &mut fx.dg);
                    bench(&name, bcfg, || {
                        dg.fill_zero();
                        sparse_bww::bww_with(&cfg, dt, dy, dg, mode, bk, &mut scratch, &mut st);
                    })
                }
            }
        }
        (Some(mode), comp) => {
            let sched = Scheduler::with_backend(threads, bk);
            match comp {
                Component::Fwd => {
                    let (d, g, y) = (&fx.d, &fx.g, &mut fx.y);
                    bench(&name, bcfg, || {
                        y.fill_zero();
                        sched.run_fwd(&cfg, d, g, y, mode);
                    })
                }
                Component::Bwi => {
                    let (dy, gt, dd) = (&fx.dy, &fx.gt, &mut fx.dd);
                    bench(&name, bcfg, || {
                        dd.fill_zero();
                        sched.run_bwi(&cfg, dy, gt, dd, mode);
                    })
                }
                Component::Bww => {
                    let (dt, dy, dg) = (&fx.dt, &fx.dy, &mut fx.dg);
                    bench(&name, bcfg, || {
                        dg.fill_zero();
                        sched.run_bww(&cfg, dt, dy, dg, mode);
                    })
                }
            }
        }
    }
}

/// Whether the end-to-end `trainer_step` rows run: release builds by
/// default, overridable either way with `SPARSETRAIN_TRAINER_BENCH`
/// (`1`/`on` forces them into debug runs, `0`/`off` suppresses them) — an
/// interpreted + kernel-routed train step in an unoptimized build is too
/// slow to put in every `cargo test`, and debug timings must not enter
/// the trajectory.
pub fn trainer_rows_enabled() -> bool {
    match std::env::var("SPARSETRAIN_TRAINER_BENCH") {
        Ok(v) => !matches!(v.as_str(), "" | "0" | "off" | "false"),
        Err(_) => build_profile() == "release",
    }
}

/// What drives the skip-mode decision in a routed trainer row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SelectorVariant {
    /// Cost DB detached: the analytic model picks every mode.
    Analytic,
    /// Fresh in-memory cost DB, warmed by [`COSTDB_WARMUP_STEPS`] untimed
    /// steps so the timed region runs on DB hits.
    Measured,
}

impl SelectorVariant {
    fn name(self) -> &'static str {
        match self {
            SelectorVariant::Analytic => "analytic",
            SelectorVariant::Measured => "measured",
        }
    }
}

/// Per-call unique scratch-dir sequence: scratch_fallback wipes on
/// creation, and two tests in one process may time trainer steps
/// concurrently.
fn scratch_seq() -> usize {
    static SEQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}

/// Median ns per full train step at the paper geometry, through the
/// offline fallback artifact: `routed = None` times the naive
/// interpreter, `Some((t, variant))` the kernel-routed runtime at `t`
/// scheduler threads with the given selector. Returns the median plus
/// whether the runtime actually pipelined (env default — off at one
/// thread or under the kill switch). `None` result = environment failure
/// (scratch dir unwritable) or routing disabled.
fn time_trainer_step(
    routed: Option<(usize, SelectorVariant)>,
    bcfg: &BenchConfig,
) -> Option<(f64, bool)> {
    use geometry::{CLASSES, C1, C2, C_IN, HW, N};
    // A "kernel-routed" row must actually be kernel-routed: when the
    // process-wide kill switch disables routing, the runtime constructors
    // would silently hand back a naive runtime and the trajectory would
    // record mislabeled data — skip the routed rows instead.
    if routed.is_some()
        && !(crate::runtime::executor::routing_enabled()
            || crate::runtime::executor::op_routing_enabled())
    {
        return None;
    }
    let tag = match routed {
        None => "naive".to_string(),
        Some((t, v)) => format!("routed-t{t}-{}", v.name()),
    };
    let arts = ArtifactSet::scratch_fallback(&format!("wallclock-{tag}-{}", scratch_seq())).ok()?;
    // The analytic row pins the DB off (not the env default) so the pair
    // is a clean A/B regardless of `SPARSETRAIN_COST_DB`.
    let mut rt = match routed {
        None => Runtime::cpu_naive(&arts.dir).ok()?,
        Some((t, SelectorVariant::Analytic)) => Runtime::cpu_with_cost_db(&arts.dir, t, None).ok()?,
        Some((t, SelectorVariant::Measured)) => {
            Runtime::cpu_with_cost_db(&arts.dir, t, Some(Arc::new(CostDb::in_memory()))).ok()?
        }
    };
    let pipelined = rt.pipelined();
    let exe = rt.load(TRAIN_STEP).ok()?;

    // One fixed batch + parameter set (same He init as the trainer), so
    // every sample times identical work on both runtimes.
    let mut rng = Xorshift::new(0xBE11);
    let he = |rng: &mut Xorshift, n: usize, fan_in: usize| -> Vec<f32> {
        let bound = (2.0 / fan_in as f32).sqrt();
        (0..n).map(|_| rng.range_f32(-bound, bound)).collect()
    };
    let w1 = he(&mut rng, C1 * C_IN * 9, C_IN * 9);
    let w2 = he(&mut rng, C2 * C1 * 9, C1 * 9);
    let wfc = he(&mut rng, CLASSES * C2, C2);
    let bfc = vec![0.0f32; CLASSES];
    let (x, labels) = synthetic_batch(&mut rng, N, C_IN, HW, CLASSES);
    let inputs = vec![
        literal_f32(&w1, &[C1 as i64, C_IN as i64, 3, 3]).ok()?,
        literal_f32(&w2, &[C2 as i64, C1 as i64, 3, 3]).ok()?,
        literal_f32(&wfc, &[CLASSES as i64, C2 as i64]).ok()?,
        literal_f32(&bfc, &[CLASSES as i64]).ok()?,
        literal_f32(&x.to_nchw(), &[N as i64, C_IN as i64, HW as i64, HW as i64]).ok()?,
        literal_i32(&labels.iter().map(|&l| l as i32).collect::<Vec<_>>(), &[N as i64]).ok()?,
    ];
    // Warm the measured selector's DB off the clock: the inputs are fixed,
    // so every conv key repeats and reaches the DB-hit state before timing.
    if matches!(routed, Some((_, SelectorVariant::Measured))) {
        for _ in 0..COSTDB_WARMUP_STEPS {
            black_box(exe.run(&inputs).ok()?);
        }
    }
    let r = bench(&format!("trainer_step {tag}"), bcfg, || {
        black_box(exe.run(&inputs).expect("train step"));
    });
    let ns = r.ns();
    let _ = std::fs::remove_dir_all(&arts.dir);
    Some((ns, pipelined))
}

/// Dense-equivalent FLOPs of one train step's five convolutions (conv1
/// appears in FWD + its weight gradient, conv2 in FWD + input gradient +
/// weight gradient) — the denominator for the trainer rows' GFLOP/s.
fn trainer_step_flops() -> f64 {
    use geometry::{C1, C2, C_IN, HW, N};
    let conv1 = ConvConfig::square(N, C_IN, C1, HW, 3, 1);
    let conv2 = ConvConfig::square(N, C1, C2, HW, 3, 1);
    (2 * conv1.fwd_flops() + 3 * conv2.fwd_flops()) as f64
}

/// Append the end-to-end `trainer_step` rows: one naive-interpreter
/// baseline plus an analytic/measured kernel-routed pair per requested
/// thread count (the autotuner's acceptance readout — a measured row no
/// faster than its analytic twin means the cost DB is not paying off).
fn trainer_step_records(threads: &[usize], bcfg: &BenchConfig, records: &mut Vec<WallclockRecord>) {
    let flops = trainer_step_flops();
    let Some((naive_ns, _)) = time_trainer_step(None, bcfg) else {
        println!("trainer_step: scratch artifacts unavailable; rows skipped");
        return;
    };
    println!(
        "{:<12} trainer_step naive-interp   t=1  {:>12.0} ns  {:>7.2} GF/s",
        "paper", naive_ns, flops / naive_ns
    );
    records.push(WallclockRecord {
        layer: "paper".to_string(),
        rs: 3,
        component: "trainer_step",
        mode: "naive-interp",
        selector: "none",
        pipeline: "off",
        sparsity: 0.0,
        threads: 1,
        median_ns: naive_ns,
        gflops: flops / naive_ns,
        speedup_vs_direct1: 1.0,
        speedup_vs_dense_same_threads: 1.0,
        serve: None,
    });
    for &t in threads {
        for variant in [SelectorVariant::Analytic, SelectorVariant::Measured] {
            let Some((ns, pipelined)) = time_trainer_step(Some((t, variant)), bcfg) else {
                continue;
            };
            println!(
                "{:<12} trainer_step kernel-routed  t={t}  sel={:<8} pipe={:<3}  {:>12.0} ns  \
                 {:>7.2} GF/s  {:>5.2}x vs naive",
                "paper",
                variant.name(),
                if pipelined { "on" } else { "off" },
                ns,
                flops / ns,
                naive_ns / ns
            );
            records.push(WallclockRecord {
                layer: "paper".to_string(),
                rs: 3,
                component: "trainer_step",
                mode: "kernel-routed",
                selector: variant.name(),
                pipeline: if pipelined { "on" } else { "off" },
                sparsity: 0.0,
                threads: t,
                median_ns: ns,
                gflops: flops / ns,
                speedup_vs_direct1: naive_ns / ns,
                speedup_vs_dense_same_threads: naive_ns / ns,
                serve: None,
            });
        }
    }
}

/// He-style init for one zoo-net parameter, mirroring the trainer's
/// scheme exactly (conv weights He-uniform, FC `±sqrt(1/fan_in)`, rank-1
/// zeros) so the benched step does the same arithmetic a real run does.
fn init_net_param(rng: &mut Xorshift, dims: &[usize]) -> Option<Vec<f32>> {
    Some(match dims {
        [k, c, s, r] => {
            let bound = (2.0 / (c * s * r) as f32).sqrt();
            (0..k * c * s * r).map(|_| rng.range_f32(-bound, bound)).collect()
        }
        [rows, cols] => {
            let bound = (1.0 / *cols as f32).sqrt();
            (0..rows * cols).map(|_| rng.range_f32(-bound, bound)).collect()
        }
        [len] => vec![0.0f32; *len],
        _ => return None,
    })
}

/// Median ns per train step on the emitted `resnet34_small` zoo graph —
/// a multi-layer net whose per-layer sparsities differ, so the measured
/// selector has real mode crossovers to exploit. `pipeline` pins the
/// dependency-scheduled evaluator explicitly (the v5 on/off A/B must not
/// depend on `SPARSETRAIN_PIPELINE`); the returned flag is what the
/// runtime actually did.
fn time_net_trainer_step(
    variant: SelectorVariant,
    threads: usize,
    pipeline: Option<bool>,
    bcfg: &BenchConfig,
) -> Option<(f64, bool)> {
    if !(crate::runtime::executor::routing_enabled()
        || crate::runtime::executor::op_routing_enabled())
    {
        return None;
    }
    let model = NetModel::new(Network::ResNet34, Scale::Small);
    let (train_name, _) = hlo_builder::net_artifact_names(&model);
    let (text, plan) = hlo_builder::net_train_step_hlo(&model).ok()?;
    let tag = format!("wallclock-zoo-{}-{}", variant.name(), scratch_seq());
    let arts = ArtifactSet::scratch_fallback(&tag).ok()?;
    arts.publish_fallback_text(&train_name, &text).ok()?;
    let db = match variant {
        SelectorVariant::Analytic => None,
        SelectorVariant::Measured => Some(Arc::new(CostDb::in_memory())),
    };
    let mut rt = Runtime::cpu_with_options(&arts.dir, threads, db, pipeline).ok()?;
    let pipelined = rt.pipelined();
    let exe = rt.load(&train_name).ok()?;

    let mut rng = Xorshift::new(0x500);
    let mut inputs = Vec::with_capacity(plan.params.len() + 2);
    for (_, dims) in &plan.params {
        let vals = init_net_param(&mut rng, dims)?;
        let d64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        inputs.push(literal_f32(&vals, &d64).ok()?);
    }
    let [n, c_in, hw, _] = plan.input_dims;
    let (x, labels) = synthetic_batch(&mut rng, n, c_in, hw, plan.classes);
    inputs.push(literal_f32(&x.to_nchw(), &[n as i64, c_in as i64, hw as i64, hw as i64]).ok()?);
    inputs
        .push(literal_i32(&labels.iter().map(|&l| l as i32).collect::<Vec<_>>(), &[n as i64]).ok()?);

    if variant == SelectorVariant::Measured {
        for _ in 0..COSTDB_WARMUP_STEPS {
            black_box(exe.run(&inputs).ok()?);
        }
    }
    let r = bench(&format!("trainer_step zoo {}", variant.name()), bcfg, || {
        black_box(exe.run(&inputs).expect("zoo train step"));
    });
    let ns = r.ns();
    let _ = std::fs::remove_dir_all(&arts.dir);
    Some((ns, pipelined))
}

/// Append the `resnet34_small` zoo trainer rows at 2 threads (skipped
/// when routing is disabled or the graph fails to emit):
///
/// * the ISSUE 8 analytic/measured selector pair, both with the pipeline
///   explicitly **on** — `speedup_vs_direct1` on these rows is relative
///   to the analytic twin, ≥ 1.0 on the measured row is that PR's bar;
/// * the ISSUE 10 pipeline **off** twin of the analytic row — the
///   on/off pair at the same selector and thread count is the schema-v5
///   acceptance readout ([`WallclockReport::pipeline_speedup`]).
///
/// Pipeline state is pinned per row (not read from the environment) so
/// the A/B survives any ambient `SPARSETRAIN_PIPELINE`.
fn net_trainer_step_records(bcfg: &BenchConfig, records: &mut Vec<WallclockRecord>) {
    const ZOO_THREADS: usize = 2;
    let Some((analytic_ns, _)) =
        time_net_trainer_step(SelectorVariant::Analytic, ZOO_THREADS, Some(true), bcfg)
    else {
        println!("trainer_step zoo: unavailable; rows skipped");
        return;
    };
    let cells: [(SelectorVariant, bool, Option<(f64, bool)>); 3] = [
        (SelectorVariant::Analytic, true, Some((analytic_ns, true))),
        (
            SelectorVariant::Measured,
            true,
            time_net_trainer_step(SelectorVariant::Measured, ZOO_THREADS, Some(true), bcfg),
        ),
        (
            SelectorVariant::Analytic,
            false,
            time_net_trainer_step(SelectorVariant::Analytic, ZOO_THREADS, Some(false), bcfg),
        ),
    ];
    for (variant, pipe, ns) in cells {
        let Some((ns, _)) = ns else { continue };
        println!(
            "{:<12} trainer_step kernel-routed  t={ZOO_THREADS}  sel={:<8} pipe={:<3}  \
             {:>12.0} ns  {:>5.2}x vs analytic/on",
            "resnet34_sm",
            variant.name(),
            if pipe { "on" } else { "off" },
            ns,
            analytic_ns / ns
        );
        records.push(WallclockRecord {
            layer: "resnet34_small".to_string(),
            rs: 3,
            component: "trainer_step",
            mode: "kernel-routed",
            selector: variant.name(),
            pipeline: if pipe { "on" } else { "off" },
            sparsity: 0.0,
            threads: ZOO_THREADS,
            median_ns: ns,
            gflops: 0.0,
            speedup_vs_direct1: analytic_ns / ns,
            speedup_vs_dense_same_threads: analytic_ns / ns,
            serve: None,
        });
    }
}

/// Run the full sweep and build the report. Prints one line per cell so
/// long runs show progress.
pub fn run(wcfg: &WallclockConfig) -> WallclockReport {
    let bk = simd::dispatch();
    let mut records = Vec::new();
    for nl in &wcfg.layers {
        let flops = nl.cfg.fwd_flops() as f64;
        // Dense-filled inputs for the `direct` baselines: built once per
        // layer, shared by all three components.
        let mut dense_fx = Fixture::new(&nl.cfg, 0.0, wcfg.seed);
        for comp in Component::ALL {
            // Dense `direct` baseline: sparsity-independent, serial.
            let direct_ns = time_cell(&mut dense_fx, comp, None, 1, bk, &wcfg.bench).ns();
            println!(
                "{:<12} {} direct            t=1  {:>12.0} ns  {:>7.2} GF/s",
                nl.name, comp.name(), direct_ns, flops / direct_ns
            );
            records.push(WallclockRecord {
                layer: nl.name.to_string(),
                rs: nl.cfg.r,
                component: comp.name(),
                mode: "direct",
                selector: "none",
                pipeline: "none",
                sparsity: 0.0,
                threads: 1,
                median_ns: direct_ns,
                gflops: flops / direct_ns,
                speedup_vs_direct1: 1.0,
                speedup_vs_dense_same_threads: 1.0,
                serve: None,
            });

            // Fair dense-BWI baseline (ISSUE 5 satellite): the
            // pre-transposed filter copy, no per-tap gather.
            if comp == Component::Bwi {
                let cfg = dense_fx.cfg;
                let mut st = KernelStats::new();
                let pre_ns = {
                    let (dy, gt, dd) = (&dense_fx.dy, &dense_fx.gt, &mut dense_fx.dd);
                    bench(&format!("BWI direct_pre t1 {}", nl.name), &wcfg.bench, || {
                        dd.fill_zero();
                        direct::bwi_pre_with(&cfg, dy, gt, dd, bk, &mut st);
                    })
                    .ns()
                };
                println!(
                    "{:<12} {} direct_pre        t=1  {:>12.0} ns  {:>7.2} GF/s",
                    nl.name,
                    comp.name(),
                    pre_ns,
                    flops / pre_ns
                );
                records.push(WallclockRecord {
                    layer: nl.name.to_string(),
                    rs: nl.cfg.r,
                    component: comp.name(),
                    mode: "direct_pre",
                    selector: "none",
                    pipeline: "none",
                    sparsity: 0.0,
                    threads: 1,
                    median_ns: pre_ns,
                    gflops: flops / pre_ns,
                    speedup_vs_direct1: direct_ns / pre_ns,
                    speedup_vs_dense_same_threads: 1.0,
                    serve: None,
                });
            }

            for &sparsity in &wcfg.sparsities {
                let mut fx = Fixture::new(&nl.cfg, sparsity, wcfg.seed);
                for &threads in &wcfg.threads {
                    let mut dense_same_ns = f64::NAN;
                    for mode in [SkipMode::Dense, SkipMode::PerLaneBranch, SkipMode::MaskLoop] {
                        let r = time_cell(&mut fx, comp, Some(mode), threads, bk, &wcfg.bench);
                        let ns = r.ns();
                        if mode == SkipMode::Dense {
                            dense_same_ns = ns;
                        }
                        // Bulk population: seed the measured-cost DB with
                        // this cell's median — including PerLaneBranch,
                        // which the router's lazy path never explores.
                        if let Some(db) = &wcfg.cost_db {
                            db.record(
                                CostKey::conv(comp, &nl.cfg, sparsity, threads, bk.name(), mode),
                                ns,
                            );
                        }
                        println!(
                            "{:<12} {} {:<14} s={sparsity:.1} t={threads}  {:>12.0} ns  \
                             {:>7.2} GF/s  {:>5.2}x vs direct",
                            nl.name, comp.name(), mode_name(mode), ns, flops / ns, direct_ns / ns
                        );
                        records.push(WallclockRecord {
                            layer: nl.name.to_string(),
                            rs: nl.cfg.r,
                            component: comp.name(),
                            mode: mode_name(mode),
                            selector: "none",
                            pipeline: "none",
                            sparsity,
                            threads,
                            median_ns: ns,
                            gflops: flops / ns,
                            speedup_vs_direct1: direct_ns / ns,
                            speedup_vs_dense_same_threads: dense_same_ns / ns,
                            serve: None,
                        });
                    }
                }
            }
        }
    }
    // End-to-end trainer-step rows (ISSUE 5 satellite): tie the perf
    // trajectory to `Trainer`, not just isolated kernels. ISSUE 8 adds
    // the analytic/measured selector pairs and the zoo-net pair.
    if trainer_rows_enabled() {
        trainer_step_records(&wcfg.threads, &wcfg.bench, &mut records);
        if wcfg.zoo_trainer {
            net_trainer_step_records(&wcfg.bench, &mut records);
        }
    }
    WallclockReport {
        backend: bk.name(),
        profile: build_profile(),
        threads_available: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        records,
    }
}

impl WallclockReport {
    /// Serialize to the `BENCH_kernels.json` schema (hand-rolled — the
    /// offline environment has no serde).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096 + self.records.len() * 256);
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
        out.push_str(&format!("  \"backend\": \"{}\",\n", self.backend));
        out.push_str(&format!("  \"profile\": \"{}\",\n", self.profile));
        out.push_str(&format!("  \"v\": {V},\n"));
        out.push_str(&format!("  \"threads_available\": {},\n", self.threads_available));
        out.push_str("  \"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"layer\": \"{}\", \"rs\": {}, \"component\": \"{}\", \"mode\": \"{}\", \
                 \"selector\": \"{}\", \"pipeline\": \"{}\", \
                 \"sparsity\": {:.2}, \"threads\": {}, \"median_ns\": {:.1}, \
                 \"gflops\": {:.3}, \"speedup_vs_direct1\": {:.3}, \
                 \"speedup_vs_dense_same_threads\": {:.3}",
                r.layer,
                r.rs,
                r.component,
                r.mode,
                r.selector,
                r.pipeline,
                r.sparsity,
                r.threads,
                r.median_ns,
                r.gflops,
                r.speedup_vs_direct1,
                r.speedup_vs_dense_same_threads,
            ));
            // v4: serve rows append their latency/throughput fields on the
            // same line so the report stays one record per line.
            if let Some(s) = &r.serve {
                let hist: Vec<String> =
                    s.batch_hist.iter().map(|(b, n)| format!("\"{b}\": {n}")).collect();
                out.push_str(&format!(
                    ", \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"p99_ns\": {:.1}, \
                     \"throughput_rps\": {:.3}, \"requests\": {}, \"rejected\": {}, \
                     \"batch_hist\": {{{}}}",
                    s.p50_ns,
                    s.p95_ns,
                    s.p99_ns,
                    s.throughput_rps,
                    s.requests,
                    s.rejected,
                    hist.join(", ")
                ));
            }
            out.push_str(if i + 1 < self.records.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON atomically (temp file + rename).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json())?;
        std::fs::rename(&tmp, path)
    }

    /// Kernel-routed trainer-step speedup over the naive interpreter at
    /// the given thread count — the trainer-level acceptance readout
    /// (PR 5 floor ≥ 2×, ISSUE 6 target ≥ 5×, at 2 threads on the paper
    /// geometry). Recomputed from the two rows' medians rather than
    /// trusting a stored ratio, and `None` whenever **either** row is
    /// missing or has a non-positive median — a report with routed rows
    /// but no `naive-interp` baseline (e.g. filtered or partially
    /// recorded) must not yield a garbage ratio. Since schema v3 only the
    /// `selector: "analytic"` routed row on the paper geometry counts —
    /// the measured rows are a separate readout
    /// ([`WallclockReport::measured_vs_analytic`]), and mixing them here
    /// would let the autotuner inflate the baseline floor.
    pub fn trainer_step_speedup(&self, threads: usize) -> Option<f64> {
        let naive = self.records.iter().find(|r| {
            r.component == "trainer_step" && r.mode == "naive-interp" && r.median_ns > 0.0
        })?;
        let routed = self.records.iter().find(|r| {
            r.component == "trainer_step"
                && r.mode == "kernel-routed"
                && r.selector == "analytic"
                && r.layer == "paper"
                && r.threads == threads
                && r.median_ns > 0.0
        })?;
        Some(naive.median_ns / routed.median_ns)
    }

    /// Analytic-time ÷ measured-time per (layer, threads) trainer pair —
    /// the ISSUE 8 acceptance readout: every ratio should be ≥ 1.0 (the
    /// warmed DB never loses to the analytic model) and > 1.0 somewhere.
    /// Pairs missing either row are omitted. Since schema v5 the twin
    /// must also match on `pipeline` — a measured/pipelined row compared
    /// against an analytic/sequential one would conflate the two levers.
    pub fn measured_vs_analytic(&self) -> Vec<(String, usize, f64)> {
        let mut out = Vec::new();
        for m in &self.records {
            if m.component != "trainer_step" || m.selector != "measured" || m.median_ns <= 0.0 {
                continue;
            }
            if let Some(a) = self.records.iter().find(|a| {
                a.component == "trainer_step"
                    && a.selector == "analytic"
                    && a.layer == m.layer
                    && a.threads == m.threads
                    && a.pipeline == m.pipeline
                    && a.median_ns > 0.0
            }) {
                out.push((m.layer.clone(), m.threads, a.median_ns / m.median_ns));
            }
        }
        out
    }

    /// Sequential-time ÷ pipelined-time for the trainer pair at
    /// (layer, threads) with the **same selector** — the ISSUE 10
    /// acceptance readout: ≥ 1.0 means the dependency-scheduled evaluator
    /// is no slower than strict SSA-order evaluation. `None` when either
    /// twin is missing or has a non-positive median.
    pub fn pipeline_speedup(&self, layer: &str, threads: usize) -> Option<f64> {
        let row = |pipe: &str| {
            self.records.iter().find(|r| {
                r.component == "trainer_step"
                    && r.mode == "kernel-routed"
                    && r.layer == layer
                    && r.threads == threads
                    && r.pipeline == pipe
                    && r.median_ns > 0.0
            })
        };
        let on = row("on")?;
        let off = self.records.iter().find(|r| {
            r.component == "trainer_step"
                && r.mode == "kernel-routed"
                && r.layer == layer
                && r.threads == threads
                && r.pipeline == "off"
                && r.selector == on.selector
                && r.median_ns > 0.0
        })?;
        Some(off.median_ns / on.median_ns)
    }

    /// Best `speedup_vs_direct1` over MaskLoop rows of **3×3 layers** at
    /// the given sparsity and thread count — the acceptance-criterion
    /// readout (1×1 rows are excluded: the criterion names 3×3 layers).
    pub fn best_maskloop_speedup(&self, sparsity: f64, threads: usize) -> Option<f64> {
        self.records
            .iter()
            .filter(|r| {
                r.mode == "MaskLoop"
                    && r.rs == 3
                    && r.threads == threads
                    && (r.sparsity - sparsity).abs() < 1e-9
            })
            .map(|r| r.speedup_vs_direct1)
            .max_by(|a, b| a.total_cmp(b))
    }
}

/// One parsed `component: "serve"` row from a v4 report — what CI and
/// offline analysis read back out of `BENCH_serve.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRow {
    pub layer: String,
    pub selector: String,
    pub threads: usize,
    pub median_ns: f64,
    pub extra: ServeExtra,
}

/// Extract a `"name": "value"` string field from one record line.
fn row_str<'a>(line: &'a str, name: &str) -> Option<&'a str> {
    let pat = format!("\"{name}\": \"");
    let start = line.find(&pat)? + pat.len();
    let rest = line.get(start..)?;
    rest.get(..rest.find('"')?)
}

/// Extract a `"name": value` numeric field (as raw text) from one line.
fn row_raw<'a>(line: &'a str, name: &str) -> Option<&'a str> {
    let pat = format!("\"{name}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = line.get(start..)?;
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    Some(rest.get(..end)?.trim())
}

/// Parse the inline `"batch_hist": {"1": 2, "8": 5}` object.
fn row_hist(line: &str) -> Option<Vec<(usize, usize)>> {
    let pat = "\"batch_hist\": {";
    let start = line.find(pat)? + pat.len();
    let rest = line.get(start..)?;
    let body = rest.get(..rest.find('}')?)?;
    let mut out = Vec::new();
    for pair in body.split(',') {
        let pair = pair.trim();
        if pair.is_empty() {
            continue;
        }
        let (k, v) = pair.split_once(':')?;
        let b: usize = k.trim().trim_matches('"').parse().ok()?;
        let n: usize = v.trim().parse().ok()?;
        out.push((b, n));
    }
    Some(out)
}

/// Read every `component: "serve"` row back out of a serialized v4
/// report. Same tolerance contract as the cost-DB parser: lines that
/// fail to parse are skipped, never panicked on; a non-v4 report (no
/// schema tag) yields an empty vec.
pub fn parse_serve_rows(json: &str) -> Vec<ServeRow> {
    if !json.contains(&format!("\"schema\": \"{SCHEMA}\"")) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for line in json.lines() {
        if row_str(line, "component") != Some("serve") {
            continue;
        }
        let parsed = (|| {
            Some(ServeRow {
                layer: row_str(line, "layer")?.to_string(),
                selector: row_str(line, "selector")?.to_string(),
                threads: row_raw(line, "threads")?.parse().ok()?,
                median_ns: row_raw(line, "median_ns")?.parse().ok()?,
                extra: ServeExtra {
                    p50_ns: row_raw(line, "p50_ns")?.parse().ok()?,
                    p95_ns: row_raw(line, "p95_ns")?.parse().ok()?,
                    p99_ns: row_raw(line, "p99_ns")?.parse().ok()?,
                    throughput_rps: row_raw(line, "throughput_rps")?.parse().ok()?,
                    requests: row_raw(line, "requests")?.parse().ok()?,
                    rejected: row_raw(line, "rejected")?.parse().ok()?,
                    batch_hist: row_hist(line)?,
                },
            })
        })();
        if let Some(row) = parsed {
            out.push(row);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trainer_row(mode: &'static str, threads: usize, median_ns: f64) -> WallclockRecord {
        WallclockRecord {
            layer: "paper".to_string(),
            rs: 3,
            component: "trainer_step",
            mode,
            selector: if mode == "kernel-routed" { "analytic" } else { "none" },
            pipeline: if mode == "kernel-routed" { "on" } else { "off" },
            sparsity: 0.0,
            threads,
            median_ns,
            gflops: 1.0,
            speedup_vs_direct1: 1.0,
            speedup_vs_dense_same_threads: 1.0,
            serve: None,
        }
    }

    /// Partial reports must never yield a garbage ratio: no rows → `None`,
    /// routed-only (no `naive-interp` baseline) → `None`, and only with
    /// both rows present does the speedup come back — recomputed from the
    /// medians, not a stored field.
    #[test]
    fn trainer_step_speedup_tolerates_partial_reports() {
        let mk = |records: Vec<WallclockRecord>| WallclockReport {
            backend: "scalar",
            profile: "debug",
            threads_available: 1,
            records,
        };
        assert_eq!(mk(Vec::new()).trainer_step_speedup(2), None);
        // routed rows without the naive baseline: the ISSUE 6 bugfix case
        assert_eq!(
            mk(vec![trainer_row("kernel-routed", 2, 100.0)]).trainer_step_speedup(2),
            None
        );
        // naive baseline without a routed row at the requested width
        assert_eq!(
            mk(vec![trainer_row("naive-interp", 1, 800.0), trainer_row("kernel-routed", 4, 100.0)])
                .trainer_step_speedup(2),
            None
        );
        // zeroed medians must not divide through
        assert_eq!(
            mk(vec![trainer_row("naive-interp", 1, 0.0), trainer_row("kernel-routed", 2, 100.0)])
                .trainer_step_speedup(2),
            None
        );
        let full =
            mk(vec![trainer_row("naive-interp", 1, 800.0), trainer_row("kernel-routed", 2, 100.0)]);
        assert_eq!(full.trainer_step_speedup(2), Some(8.0));
        // a measured row must NOT satisfy the analytic baseline floor
        let mut measured = trainer_row("kernel-routed", 2, 50.0);
        measured.selector = "measured";
        let report =
            mk(vec![trainer_row("naive-interp", 1, 800.0), measured]);
        assert_eq!(report.trainer_step_speedup(2), None);
    }

    /// The v5 acceptance readout pairs the pipelined row with its
    /// sequential twin at the same (layer, threads, selector); an
    /// off-only or on-only report yields `None`, never a garbage ratio.
    #[test]
    fn miri_pipeline_speedup_pairs_on_off_rows() {
        let mk = |records: Vec<WallclockRecord>| WallclockReport {
            backend: "scalar",
            profile: "debug",
            threads_available: 2,
            records,
        };
        let on = trainer_row("kernel-routed", 2, 100.0); // pipeline: "on"
        let mut off = trainer_row("kernel-routed", 2, 150.0);
        off.pipeline = "off";
        assert_eq!(mk(vec![on.clone()]).pipeline_speedup("paper", 2), None);
        assert_eq!(mk(vec![off.clone()]).pipeline_speedup("paper", 2), None);
        let report = mk(vec![on.clone(), off.clone()]);
        assert_eq!(report.pipeline_speedup("paper", 2), Some(1.5));
        assert_eq!(report.pipeline_speedup("paper", 4), None, "thread count must match");
        assert_eq!(report.pipeline_speedup("resnet34_small", 2), None, "layer must match");
        // The off twin must share the selector — a measured/off row does
        // not pair with an analytic/on row.
        let mut mismatched = off;
        mismatched.selector = "measured";
        assert_eq!(mk(vec![on, mismatched]).pipeline_speedup("paper", 2), None);
    }

    /// The v3 acceptance readout pairs measured rows with their analytic
    /// twin by (layer, threads) and ignores incomplete pairs.
    #[test]
    fn measured_vs_analytic_pairs_rows() {
        let mut analytic = trainer_row("kernel-routed", 2, 200.0);
        analytic.selector = "analytic";
        let mut measured = trainer_row("kernel-routed", 2, 100.0);
        measured.selector = "measured";
        let mut zoo_measured = trainer_row("kernel-routed", 2, 70.0);
        zoo_measured.selector = "measured";
        zoo_measured.layer = "resnet34_small".to_string();
        let report = WallclockReport {
            backend: "scalar",
            profile: "debug",
            threads_available: 2,
            records: vec![
                trainer_row("naive-interp", 1, 800.0),
                analytic,
                measured,
                zoo_measured, // no analytic twin → omitted
            ],
        };
        assert_eq!(report.measured_vs_analytic(), vec![("paper".to_string(), 2, 2.0)]);
    }

    /// Serve rows survive a serialize → parse round trip bit-exactly
    /// (every numeric is chosen exactly representable at the emitter's
    /// printed precision), kernel rows stay serve-free, and the parser
    /// ignores input from any other schema version wholesale.
    #[test]
    fn miri_serve_rows_round_trip_through_v4_json() {
        let extra = ServeExtra {
            p50_ns: 1200.5,
            p95_ns: 850_000.1,
            p99_ns: 999_999.9,
            throughput_rps: 1234.125,
            requests: 400,
            rejected: 7,
            batch_hist: vec![(1, 3), (4, 2), (8, 40)],
        };
        let serve_row = WallclockRecord {
            layer: "paper".to_string(),
            rs: 3,
            component: "serve",
            mode: "batched",
            selector: "measured",
            pipeline: "none",
            sparsity: 0.0,
            threads: 2,
            median_ns: 1200.5,
            gflops: 0.0,
            speedup_vs_direct1: 1.0,
            speedup_vs_dense_same_threads: 1.0,
            serve: Some(extra.clone()),
        };
        let report = WallclockReport {
            backend: "scalar",
            profile: "debug",
            threads_available: 2,
            records: vec![trainer_row("naive-interp", 1, 800.0), serve_row],
        };
        let json = report.to_json();
        assert_eq!(json.matches("\"layer\"").count(), 2, "one line per record");
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "inline hist object keeps braces balanced"
        );
        let rows = parse_serve_rows(&json);
        assert_eq!(rows.len(), 1, "kernel rows are not serve rows");
        assert_eq!(
            rows[0],
            ServeRow {
                layer: "paper".to_string(),
                selector: "measured".to_string(),
                threads: 2,
                median_ns: 1200.5,
                extra,
            }
        );
        // Wrong schema tag: ignored wholesale.
        assert!(parse_serve_rows(&json.replace(SCHEMA, "sparsetrain-wallclock-v3")).is_empty());
        // An empty hist parses as empty, not as a failure.
        let empty_hist = json.replace("{\"1\": 3, \"4\": 2, \"8\": 40}", "{}");
        assert_eq!(parse_serve_rows(&empty_hist)[0].extra.batch_hist, Vec::new());
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock timing is meaningless under the interpreter")]
    fn smoke_sweep_produces_complete_report() {
        let mut wcfg = WallclockConfig::smoke();
        // Bulk population rides along: every timed mode cell lands in the
        // attached DB (1 layer × 3 comps × 2 sparsity buckets × 2 thread
        // counts × 3 modes).
        let db = Arc::new(CostDb::in_memory());
        wcfg.cost_db = Some(Arc::clone(&db));
        let report = run(&wcfg);
        assert_eq!(db.len(), 3 * 2 * 2 * 3, "bulk sweep must seed every mode cell");
        // 3 components × (1 direct + 2 sparsities × 2 threads × 3 modes)
        // + 1 direct_pre BWI baseline, + the trainer rows (1 naive + an
        // analytic/measured pair per thread count) in release builds
        let kernel_rows = 3 * (1 + 2 * 2 * 3) + 1;
        let routed_rows = if crate::runtime::executor::routing_enabled()
            || crate::runtime::executor::op_routing_enabled()
        {
            2 * wcfg.threads.len()
        } else {
            0
        };
        let trainer_rows = if trainer_rows_enabled() { 1 + routed_rows } else { 0 };
        assert_eq!(report.records.len(), kernel_rows + trainer_rows);
        assert!(report.records.iter().all(|r| r.median_ns > 0.0 && r.gflops > 0.0));
        assert!(report
            .records
            .iter()
            .all(|r| matches!(r.selector, "none" | "analytic" | "measured")));
        assert!(report.records.iter().all(|r| r.speedup_vs_direct1 > 0.0));
        assert!(!report.backend.is_empty());
        assert!(report.best_maskloop_speedup(0.9, 1).is_some());
        assert!(report
            .records
            .iter()
            .any(|r| r.component == "BWI" && r.mode == "direct_pre" && r.threads == 1));
        if trainer_rows_enabled() {
            assert!(
                report
                    .records
                    .iter()
                    .any(|r| r.component == "trainer_step" && r.mode == "naive-interp"),
                "trainer baseline row missing"
            );
            if crate::runtime::executor::routing_enabled()
                || crate::runtime::executor::op_routing_enabled()
            {
                assert!(report.trainer_step_speedup(2).is_some(), "routed trainer rows missing");
                // every measured row has an analytic twin at the same
                // (layer, threads) — the v3 pairing invariant
                assert_eq!(
                    report.measured_vs_analytic().len(),
                    report
                        .records
                        .iter()
                        .filter(|r| r.selector == "measured")
                        .count(),
                    "measured trainer rows must pair with analytic twins"
                );
            }
        }

        let json = report.to_json();
        assert!(json.contains(&format!("\"schema\": \"{SCHEMA}\"")));
        assert!(json.contains("\"selector\""));
        assert!(json.contains("\"pipeline\": \"none\""), "v5 field on every kernel row");
        assert!(json.contains("\"backend\""));
        assert!(json.contains("MaskLoop"));
        assert!(json.contains("direct_pre"));
        // structurally sound: balanced braces/brackets, one object per record
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert_eq!(json.matches("\"layer\"").count(), report.records.len());
    }

    /// Tier-1 materialization of the perf trajectory: a `cargo test
    /// --release` run writes `BENCH_kernels.json` at the repo root when it
    /// is missing (or when `SPARSETRAIN_RECORD_BENCH=1` forces a refresh),
    /// so any dev/CI machine produces real measured numbers with the
    /// detected backend and build profile recorded. Debug builds never
    /// record (unless forced): debug timings must not seed the trajectory
    /// future release runs are compared against. The full-sweep file comes
    /// from `cargo run --release --example wallclock`.
    #[test]
    #[cfg_attr(miri, ignore = "wall-clock timing is meaningless under the interpreter")]
    fn smoke_records_bench_json_at_repo_root() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_kernels.json");
        let force = std::env::var("SPARSETRAIN_RECORD_BENCH").is_ok();
        if (path.exists() || build_profile() == "debug") && !force {
            return; // keep existing trajectories; never seed one from debug
        }
        let report = run(&WallclockConfig::smoke());
        report.write_json(&path).expect("write BENCH_kernels.json");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains(SCHEMA));
        assert!(body.contains(&format!("\"profile\": \"{}\"", build_profile())));
    }
}
