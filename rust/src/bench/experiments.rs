//! Experiment generators: one function per paper table/figure, shared by
//! `rust/benches/*`, `examples/*` and the CLI. Each returns structured rows
//! plus a rendered [`Table`], so benches can both print and assert on them.

use crate::coordinator::selector::{AlgoPolicy, Selector};
use crate::kernels::{winograd, Component, ConvConfig};
use crate::nets::table2::{layers_1x1, layers_3x3, NamedLayer};
use crate::nets::zoo::{NetSpec, Network};
use crate::sim::{estimate_layer_iid, Algorithm, Machine};
use crate::sparsity::TrajectoryModel;
use crate::util::stats::geomean;
use crate::util::table::Table;

/// Sparsity grid of the paper's Tables 4/5.
pub const SPARSITY_GRID: [f64; 10] = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

/// The modeled machine restricted to `threads` active cores — the cost
/// model's view of running the row-sweep scheduler at that width. Every
/// experiment path (`fig1`/`fig2`/`fig4`, the benches, and the CLI) routes
/// its `--threads` knob through here so model and host runs agree on the
/// core count. Speedups reported *relative to direct* are computed with
/// both sides at the same width.
pub fn machine_with_threads(base: &Machine, threads: usize) -> Machine {
    base.with_cores(threads)
}

/// Speedup of `alg` over modeled `direct` for one (layer, component,
/// sparsity) cell.
pub fn speedup_over_direct(
    m: &Machine,
    alg: Algorithm,
    cfg: &ConvConfig,
    comp: Component,
    sparsity: f64,
) -> f64 {
    let direct = estimate_layer_iid(m, Algorithm::Direct, comp, cfg, 0.0).wall;
    let t = estimate_layer_iid(m, alg, comp, cfg, sparsity).wall;
    direct / t
}

/// One row of Figure 1/2: per-layer speedups across the sparsity grid plus
/// baseline algorithm columns.
#[derive(Debug, Clone)]
pub struct LayerRow {
    pub layer: String,
    pub comp: Component,
    /// SparseTrain speedup at each grid sparsity.
    pub sparse_speedups: Vec<f64>,
    /// im2col speedup (sparsity-independent).
    pub im2col: f64,
    /// winograd (3×3 s1) or 1x1 kernel speedup; None when inapplicable.
    pub alt: Option<f64>,
}

/// Figure 1 (per-layer) + Table 4 (geo-mean) over the 3×3 layers.
pub fn fig1_table4(m: &Machine) -> (Vec<LayerRow>, Table, Table) {
    per_layer_experiment(m, layers_3x3(), "3x3")
}

/// Figure 2 (per-layer) + Table 5 (geo-mean) over the 1×1 layers.
pub fn fig2_table5(m: &Machine) -> (Vec<LayerRow>, Table, Table) {
    per_layer_experiment(m, layers_1x1(), "1x1")
}

fn per_layer_experiment(
    m: &Machine,
    layers: Vec<NamedLayer>,
    kind: &str,
) -> (Vec<LayerRow>, Table, Table) {
    let mut rows = Vec::new();
    for nl in &layers {
        for comp in Component::ALL {
            let sparse_speedups: Vec<f64> = SPARSITY_GRID
                .iter()
                .map(|&s| speedup_over_direct(m, Algorithm::SparseTrain, &nl.cfg, comp, s))
                .collect();
            let im2col = speedup_over_direct(m, Algorithm::Im2col, &nl.cfg, comp, 0.0);
            let alt = if winograd::applicable(&nl.cfg) {
                Some(speedup_over_direct(m, Algorithm::Winograd, &nl.cfg, comp, 0.0))
            } else if crate::kernels::onebyone::applicable(&nl.cfg) {
                Some(speedup_over_direct(m, Algorithm::OneByOne, &nl.cfg, comp, 0.0))
            } else {
                None
            };
            rows.push(LayerRow {
                layer: nl.name.to_string(),
                comp,
                sparse_speedups,
                im2col,
                alt,
            });
        }
    }

    // Figure table: per layer, speedup at 20/40/60/80 % (the figure's grid).
    let alt_name = if kind == "3x3" { "winograd" } else { "1x1" };
    let mut fig = Table::new(&format!(
        "Figure {}: speedup over direct, {} layers (modeled Skylake-X)",
        if kind == "3x3" { "1" } else { "2" },
        kind
    ))
    .header(&["layer", "comp", "20%", "40%", "60%", "80%", "im2col", alt_name]);
    for r in &rows {
        fig.row_strings(vec![
            r.layer.clone(),
            r.comp.name().to_string(),
            format!("{:.2}", r.sparse_speedups[2]),
            format!("{:.2}", r.sparse_speedups[4]),
            format!("{:.2}", r.sparse_speedups[6]),
            format!("{:.2}", r.sparse_speedups[8]),
            format!("{:.2}", r.im2col),
            r.alt.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }

    // Table 4/5: geo-mean per component across layers.
    let mut tab = Table::new(&format!(
        "Table {}: geo-mean speedup at each sparsity, {} layers",
        if kind == "3x3" { "4" } else { "5" },
        kind
    ))
    .header(&[
        "comp", "0%", "10%", "20%", "30%", "40%", "50%", "60%", "70%", "80%", "90%", "im2c.",
        alt_name,
    ]);
    for comp in Component::ALL {
        let comp_rows: Vec<&LayerRow> = rows.iter().filter(|r| r.comp == comp).collect();
        let mut cells = vec![comp.name().to_string()];
        for si in 0..SPARSITY_GRID.len() {
            let g = geomean(&comp_rows.iter().map(|r| r.sparse_speedups[si]).collect::<Vec<_>>());
            cells.push(format!("{g:.2}"));
        }
        cells.push(format!(
            "{:.2}",
            geomean(&comp_rows.iter().map(|r| r.im2col).collect::<Vec<_>>())
        ));
        let alts: Vec<f64> = comp_rows.iter().filter_map(|r| r.alt).collect();
        cells.push(if alts.is_empty() { "-".into() } else { format!("{:.2}", geomean(&alts)) });
        tab.row_strings(cells);
    }
    (rows, fig, tab)
}

/// Figure 3: sparsity trajectories — returns `[layer][epoch]` per network.
pub fn fig3(epochs: usize) -> Vec<(Network, Vec<Vec<f64>>)> {
    [Network::ResNet34, Network::ResNet50, Network::FixupResNet50]
        .into_iter()
        .map(|net| {
            let spec = NetSpec::build(net);
            let relu_layers = spec.non_initial().count();
            let model = TrajectoryModel::new(net.trajectory(), relu_layers, epochs);
            (net, model.matrix())
        })
        .collect()
}

/// Per-layer mean operand sparsities used in the projection.
pub struct LayerSparsity {
    /// Input (ReLU of previous layer) — FWD and BWW-checked-on-D.
    pub input: f64,
    /// ∂L/∂Y (own ReLU, surviving only without BN) — BWI, BWW alternative.
    pub grad: Option<f64>,
}

/// Mean per-layer sparsities for a network over a training run.
pub fn layer_sparsities(spec: &NetSpec, epochs: usize) -> Vec<LayerSparsity> {
    let mut params = spec.network.trajectory();
    let dip = params.shortcut_dip;
    params.shortcut_dip = 0.0; // applied from the layer flags instead
    params.block_period = 0;
    let n_layers = spec.layers.len();
    let model = TrajectoryModel::new(params, n_layers.max(2), epochs);
    spec.layers
        .iter()
        .enumerate()
        .map(|(idx, l)| {
            // own ReLU output sparsity
            let own = (model.mean_sparsity(idx) - if l.after_shortcut { dip } else { 0.0 })
                .clamp(0.05, 0.97);
            // input sparsity = previous layer's ReLU output (0 for first)
            let input = if l.is_first || idx == 0 {
                0.0
            } else {
                let prev = &spec.layers[idx - 1];
                (model.mean_sparsity(idx - 1)
                    - if prev.after_shortcut { dip } else { 0.0 })
                .clamp(0.05, 0.97)
            };
            let grad = (!l.has_bn).then_some(own);
            LayerSparsity { input, grad }
        })
        .collect()
}

/// One network's projection: per-policy, per-component modeled cycles.
#[derive(Debug, Clone)]
pub struct Projection {
    pub network: Network,
    /// policy → (first-layer, fwd, bwi, bww) total cycles.
    pub by_policy: Vec<(AlgoPolicy, [f64; 4])>,
}

impl Projection {
    fn total(parts: &[f64; 4]) -> f64 {
        parts.iter().sum()
    }

    /// Speedup vs the direct policy, incl. the first layer.
    pub fn speedup_incl_first(&self, policy: AlgoPolicy) -> f64 {
        let direct = self.cycles(AlgoPolicy::DirectOnly);
        Self::total(&direct) / Self::total(&self.cycles(policy))
    }

    /// Speedup vs direct, excluding the first layer (paper's second block).
    pub fn speedup_excl_first(&self, policy: AlgoPolicy) -> f64 {
        let d = self.cycles(AlgoPolicy::DirectOnly);
        let p = self.cycles(policy);
        (d[1] + d[2] + d[3]) / (p[1] + p[2] + p[3])
    }

    pub fn cycles(&self, policy: AlgoPolicy) -> [f64; 4] {
        self.by_policy
            .iter()
            .find(|(p, _)| *p == policy)
            .map(|(_, c)| *c)
            .expect("policy present")
    }
}

/// Figure 4 + Table 6: end-to-end conv-layer projection for all networks.
pub fn fig4_table6(m: &Machine, epochs: usize) -> (Vec<Projection>, Table, Table) {
    let sel = Selector::new(*m);
    let policies = [
        AlgoPolicy::DirectOnly,
        AlgoPolicy::SparseTrainOnly,
        AlgoPolicy::WinOr1x1,
        AlgoPolicy::Combined,
    ];
    let mut projections = Vec::new();
    for net in Network::ALL {
        let spec = NetSpec::build(net);
        let sparsities = layer_sparsities(&spec, epochs);
        let mut by_policy = Vec::new();
        for policy in policies {
            let mut parts = [0.0f64; 4];
            for (l, sp) in spec.layers.iter().zip(&sparsities) {
                for comp in Component::ALL {
                    // which operand carries sparsity for this component?
                    let (sparsity, applicable) = match comp {
                        Component::Fwd => (sp.input, !l.is_first && sp.input > 0.0),
                        Component::Bwi => match sp.grad {
                            Some(g) => (g, true),
                            None => (0.0, false), // BN wiped it → direct
                        },
                        Component::Bww => {
                            // check the sparser operand (§5.3)
                            let best = sp.grad.map_or(sp.input, |g| g.max(sp.input));
                            (best, !l.is_first && best > 0.0)
                        }
                    };
                    let alg = sel.select(policy, &l.cfg, comp, sparsity, applicable);
                    let cycles = estimate_layer_iid(m, alg, comp, &l.cfg, sparsity).wall;
                    if l.is_first {
                        parts[0] += cycles;
                    } else {
                        parts[1 + comp as usize] += cycles;
                    }
                }
            }
            by_policy.push((policy, parts));
        }
        projections.push(Projection { network: net, by_policy });
    }

    // Figure 4: stacked breakdown normalized to direct.
    let mut fig = Table::new("Figure 4: conv-layer time breakdown, normalized to direct")
        .header(&["network", "policy", "first", "FWD", "BWI", "BWW", "total"]);
    for p in &projections {
        let direct_total = Projection::total(&p.cycles(AlgoPolicy::DirectOnly));
        for (policy, parts) in &p.by_policy {
            fig.row_strings(vec![
                p.network.name().to_string(),
                policy.name().to_string(),
                format!("{:.3}", parts[0] / direct_total),
                format!("{:.3}", parts[1] / direct_total),
                format!("{:.3}", parts[2] / direct_total),
                format!("{:.3}", parts[3] / direct_total),
                format!("{:.3}", Projection::total(parts) / direct_total),
            ]);
        }
    }

    // Table 6: projected speedups incl./excl. first layer.
    let mut tab = Table::new("Table 6: projected speedup on all conv layers").header(&[
        "network",
        "ST incl1",
        "win/1x1 incl1",
        "comb incl1",
        "ST excl1",
        "win/1x1 excl1",
        "comb excl1",
    ]);
    for p in &projections {
        tab.row_strings(vec![
            p.network.name().to_string(),
            format!("{:.2}", p.speedup_incl_first(AlgoPolicy::SparseTrainOnly)),
            format!("{:.2}", p.speedup_incl_first(AlgoPolicy::WinOr1x1)),
            format!("{:.2}", p.speedup_incl_first(AlgoPolicy::Combined)),
            format!("{:.2}", p.speedup_excl_first(AlgoPolicy::SparseTrainOnly)),
            format!("{:.2}", p.speedup_excl_first(AlgoPolicy::WinOr1x1)),
            format!("{:.2}", p.speedup_excl_first(AlgoPolicy::Combined)),
        ]);
    }
    (projections, fig, tab)
}

/// §5.3 extension ("future work" in the paper): *dynamic* per-epoch
/// algorithm selection. The static `combined` policy picks once from the
/// training-average sparsity; the dynamic policy re-selects each epoch
/// from that epoch's sparsity — profitable early in training when
/// sparsity is still near 50 % and Winograd wins, and late when
/// SparseTrain dominates.
///
/// Returns (static-combined cycles, dynamic cycles, dynamic/static gain)
/// summed over FWD of all non-initial layers across the training run.
pub fn dynamic_vs_static(m: &Machine, net: Network, epochs: usize) -> (f64, f64, f64) {
    let sel = Selector::new(*m);
    let spec = NetSpec::build(net);
    let mut params = net.trajectory();
    let dip = params.shortcut_dip;
    params.shortcut_dip = 0.0;
    params.block_period = 0;
    let model = TrajectoryModel::new(params, spec.layers.len().max(2), epochs);

    let mut static_total = 0.0;
    let mut dynamic_total = 0.0;
    for (idx, l) in spec.layers.iter().enumerate() {
        if l.is_first || idx == 0 {
            continue;
        }
        let prev = &spec.layers[idx - 1];
        let s_at = |e: usize| {
            (model.sparsity(idx - 1, e) - if prev.after_shortcut { dip } else { 0.0 })
                .clamp(0.05, 0.97)
        };
        // static: one algorithm from the mean sparsity, used all epochs
        let s_mean = (0..epochs).map(s_at).sum::<f64>() / epochs as f64;
        let alg_static = sel.select(AlgoPolicy::Combined, &l.cfg, Component::Fwd, s_mean, true);
        for e in 0..epochs {
            let s = s_at(e);
            static_total += estimate_layer_iid(m, alg_static, Component::Fwd, &l.cfg, s).wall;
            // dynamic: re-select at this epoch's sparsity
            let alg_dyn = sel.select(AlgoPolicy::Combined, &l.cfg, Component::Fwd, s, true);
            dynamic_total += estimate_layer_iid(m, alg_dyn, Component::Fwd, &l.cfg, s).wall;
        }
    }
    (static_total, dynamic_total, static_total / dynamic_total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Machine {
        Machine::skylake_x()
    }

    #[test]
    fn machine_with_threads_overrides_cores_only() {
        let base = m();
        let m1 = machine_with_threads(&base, 1);
        assert_eq!(m1.cores, 1);
        assert_eq!(m1.fma_per_cycle, base.fma_per_cycle);
        assert_eq!(m1.dram_bw_total, base.dram_bw_total);
        assert_eq!(machine_with_threads(&base, 0).cores, 1);
        // fewer modeled cores → more wall cycles for a compute-bound layer
        let cfg = ConvConfig::square(16, 256, 256, 56, 3, 1);
        let t1 = estimate_layer_iid(&m1, Algorithm::SparseTrain, Component::Fwd, &cfg, 0.5).wall;
        let t6 = estimate_layer_iid(&base, Algorithm::SparseTrain, Component::Fwd, &cfg, 0.5).wall;
        assert!(t1 > t6, "1-core {t1} must exceed 6-core {t6}");
    }

    #[test]
    fn table4_shape_holds() {
        let (rows, _fig, tab) = fig1_table4(&m());
        assert!(!rows.is_empty());
        assert!(!tab.is_empty());
        // E9: dense overhead ≤ ~10 %, monotone growth, >2x at 90 %
        for comp in Component::ALL {
            let comp_rows: Vec<&LayerRow> = rows.iter().filter(|r| r.comp == comp).collect();
            let g0 = geomean(&comp_rows.iter().map(|r| r.sparse_speedups[0]).collect::<Vec<_>>());
            let g9 = geomean(&comp_rows.iter().map(|r| r.sparse_speedups[9]).collect::<Vec<_>>());
            assert!(g0 > 0.80 && g0 <= 1.0, "{comp:?} 0% geomean={g0}");
            assert!(g9 > 1.8, "{comp:?} 90% geomean={g9}");
        }
    }

    #[test]
    fn crossover_between_10_and_30_percent() {
        // E9: the paper's crossover is 10–20 %; allow one grid step slack.
        let (rows, _, _) = fig1_table4(&m());
        for comp in Component::ALL {
            let comp_rows: Vec<&LayerRow> = rows.iter().filter(|r| r.comp == comp).collect();
            let g = |si: usize| {
                geomean(&comp_rows.iter().map(|r| r.sparse_speedups[si]).collect::<Vec<_>>())
            };
            assert!(g(3) > 1.0, "{comp:?}: no crossover by 30%: {}", g(3));
        }
    }

    #[test]
    fn fig3_trajectories_have_expected_shape() {
        let trajs = fig3(100);
        assert_eq!(trajs.len(), 3);
        for (net, m) in &trajs {
            assert!(!m.is_empty(), "{net:?}");
            assert_eq!(m[0].len(), 100);
        }
    }

    #[test]
    fn dynamic_selection_never_loses_and_sometimes_wins() {
        // Per-epoch re-selection can only improve on the single static
        // choice (it has strictly more information), and on ResNet-34
        // (strong early/late sparsity swing) it should show real gain.
        for net in [Network::Vgg16, Network::ResNet34] {
            let (stat, dynamic, gain) = dynamic_vs_static(&m(), net, 60);
            assert!(dynamic <= stat * 1.0001, "{net:?}: dynamic worse: {gain}");
            assert!(gain >= 1.0, "{net:?}: gain {gain}");
        }
        let (_, _, gain34) = dynamic_vs_static(&m(), Network::ResNet34, 60);
        assert!(gain34 > 1.0, "resnet34 dynamic gain {gain34}");
    }

    #[test]
    fn table6_orderings_match_paper() {
        let (projections, _, tab) = fig4_table6(&m(), 100);
        assert!(!tab.is_empty());
        let get = |net: Network| projections.iter().find(|p| p.network == net).unwrap();
        // VGG16 benefits most (no BN, high sparsity, all 3×3)
        let vgg = get(Network::Vgg16).speedup_excl_first(AlgoPolicy::SparseTrainOnly);
        let r50 = get(Network::ResNet50).speedup_excl_first(AlgoPolicy::SparseTrainOnly);
        let fix = get(Network::FixupResNet50).speedup_excl_first(AlgoPolicy::SparseTrainOnly);
        assert!(vgg > fix && fix > r50, "ordering: vgg={vgg:.2} fixup={fix:.2} r50={r50:.2}");
        // all speedups > 1 and combined ≥ SparseTrain-only
        for p in &projections {
            let st = p.speedup_incl_first(AlgoPolicy::SparseTrainOnly);
            let comb = p.speedup_incl_first(AlgoPolicy::Combined);
            assert!(st > 1.0, "{}: {st}", p.network.name());
            assert!(comb >= st * 0.98, "{}: comb={comb} st={st}", p.network.name());
        }
    }
}
