//! Open-loop synthetic load generator for the serving front end
//! (ISSUE 9) → `component: "serve"` rows in `BENCH_serve.json`.
//!
//! Drives [`crate::coordinator::serve::Server`] the way a latency bench
//! should be driven: **open loop** — arrivals follow a precomputed
//! schedule (seeded Poisson or uniform inter-arrival gaps,
//! [`arrival_offsets`]) and are *never* gated on earlier replies, so
//! queueing delay under overload is measured instead of hidden
//! (closed-loop generators famously under-report tail latency). The
//! whole schedule is a pure function of `(rate, requests, seed, kind)`:
//! CI replays the exact same arrival process every run, and the only
//! nondeterminism left in a report is the machine's actual speed.
//!
//! Latency is measured on the **same [`Clock`] the server batches on**
//! (one shared [`MonotonicClock`]): a request's latency is the server's
//! batch-completion stamp minus the generator's send stamp, so clock
//! skew between generator and server cannot exist by construction. The
//! deterministic *logic* tests live in `rust/tests/serve.rs` on the
//! virtual clock; this module is the wall-clock measurement rig.
//!
//! Scenarios pair the paper geometry with zoo-inspired variants
//! ([`scenarios`]): a higher-resolution input and a wider-channel net,
//! so batching policy is exercised across distinct compute/latency
//! ratios. Reports ([`LoadReport`]) carry p50/p95/p99 latency,
//! throughput, and the batch-size histogram, and serialize as
//! `component: "serve"` rows in the wallclock v5 schema
//! ([`crate::bench::wallclock::ServeExtra`]).

use crate::bench::wallclock::{
    build_profile, ServeExtra, WallclockRecord, WallclockReport, SCHEMA,
};
use crate::coordinator::costdb::CostDb;
use crate::coordinator::serve::{
    Clock, MonotonicClock, Nanos, PredictExecutor, ServeConfig, ServeReply, ServeRequest, Server,
};
use crate::kernels::layers::synthetic_batch;
use crate::kernels::simd;
use crate::runtime::hlo_builder::Geometry;
use crate::util::prng::Xorshift;
use crate::util::stats::percentile;
use anyhow::Result;
use std::sync::{mpsc, Arc};

/// One serving workload: a name plus the model geometry to compile the
/// predict ladder for (`n` is ignored — the server picks batch sizes).
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub name: &'static str,
    pub geometry: Geometry,
}

/// The mixed zoo-net geometry set: the paper model plus a
/// higher-resolution and a wider-channel variant, so the batch policy
/// sees distinct compute-per-sample profiles.
pub fn scenarios() -> Vec<Scenario> {
    let paper = Geometry::paper();
    vec![
        Scenario { name: "paper", geometry: paper },
        Scenario { name: "hires32", geometry: Geometry { hw: 32, ..paper } },
        Scenario { name: "wide64", geometry: Geometry { c1: 64, c2: 64, ..paper } },
    ]
}

pub fn scenario_by_name(name: &str) -> Option<Scenario> {
    scenarios().into_iter().find(|s| s.name == name)
}

/// Shape of the synthetic arrival process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Exponential inter-arrival gaps (memoryless — the standard
    /// serving-bench arrival model; produces natural burstiness).
    Poisson,
    /// Fixed gaps at exactly the configured rate (worst case for
    /// deadline-closed batches: arrivals never cluster).
    Uniform,
}

/// The deterministic arrival schedule: nanosecond offsets from bench
/// start, one per request, non-decreasing. Pure in `(rate, requests,
/// seed, kind)` — same inputs, same schedule, on every machine.
pub fn arrival_offsets(
    rate_rps: f64,
    requests: usize,
    seed: u64,
    kind: ArrivalKind,
) -> Vec<Nanos> {
    assert!(rate_rps > 0.0 && rate_rps.is_finite(), "arrival rate must be positive");
    let mean_gap_ns = 1e9 / rate_rps;
    let mut rng = Xorshift::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(requests);
    for _ in 0..requests {
        out.push(t as Nanos);
        t += match kind {
            ArrivalKind::Uniform => mean_gap_ns,
            // Inverse-CDF exponential; 1 - u ∈ (0, 1] keeps ln() finite.
            ArrivalKind::Poisson => -mean_gap_ns * (1.0 - rng.next_f64()).ln(),
        };
    }
    out
}

/// Load-generator configuration for one scenario run.
#[derive(Debug, Clone, Copy)]
pub struct ServeBenchConfig {
    /// Mean arrival rate, requests per second.
    pub rate_rps: f64,
    /// Total requests to submit.
    pub requests: usize,
    /// Seeds the arrival schedule, the synthetic inputs, and (xored)
    /// the served model's weights.
    pub seed: u64,
    pub serve: ServeConfig,
    /// Worker threads for the op router's scheduler pool.
    pub threads: usize,
    pub arrivals: ArrivalKind,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        ServeBenchConfig {
            rate_rps: 400.0,
            requests: 400,
            seed: 42,
            serve: ServeConfig::default(),
            threads: 2,
            arrivals: ArrivalKind::Poisson,
        }
    }
}

/// The measured outcome of one scenario run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub scenario: String,
    pub threads: usize,
    /// Batch-size policy in effect: `"measured"` (warm-capable cost DB
    /// attached to the router) or `"static"`.
    pub selector: &'static str,
    /// Requests submitted (accepted + rejected).
    pub requests: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// Per-completed-request latency (send stamp → batch completion
    /// stamp, shared clock), nanoseconds.
    pub latencies_ns: Vec<f64>,
    /// Wall time from first send to full drain.
    pub wall_ns: Nanos,
    pub batch_hist: Vec<(usize, usize)>,
}

impl LoadReport {
    pub fn completed(&self) -> usize {
        self.latencies_ns.len()
    }

    pub fn p50_ns(&self) -> f64 {
        percentile(&self.latencies_ns, 50.0)
    }

    pub fn p95_ns(&self) -> f64 {
        percentile(&self.latencies_ns, 95.0)
    }

    pub fn p99_ns(&self) -> f64 {
        percentile(&self.latencies_ns, 99.0)
    }

    /// Completed requests per second of wall time.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.completed() as f64 * 1e9 / self.wall_ns as f64
    }

    /// This run as a wallclock v5 `component: "serve"` row.
    pub fn to_record(&self) -> WallclockRecord {
        WallclockRecord {
            layer: self.scenario.clone(),
            rs: 3,
            component: "serve",
            mode: "batched",
            selector: self.selector,
            pipeline: "none",
            sparsity: 0.0,
            threads: self.threads,
            median_ns: self.p50_ns(),
            gflops: 0.0,
            speedup_vs_direct1: 1.0,
            speedup_vs_dense_same_threads: 1.0,
            serve: Some(ServeExtra {
                p50_ns: self.p50_ns(),
                p95_ns: self.p95_ns(),
                p99_ns: self.p99_ns(),
                throughput_rps: self.throughput_rps(),
                requests: self.requests,
                rejected: self.rejected,
                batch_hist: self.batch_hist.clone(),
            }),
        }
    }

    pub fn print(&self) {
        let ms = |ns: f64| ns / 1e6;
        let hist: Vec<String> =
            self.batch_hist.iter().map(|(b, n)| format!("{b}:{n}")).collect();
        println!(
            "{:<10} t={} sel={:<8} {:>5} req ({} rej)  p50 {:>8.3} ms  p95 {:>8.3} ms  \
             p99 {:>8.3} ms  {:>8.1} req/s  batches [{}]",
            self.scenario,
            self.threads,
            self.selector,
            self.requests,
            self.rejected,
            ms(self.p50_ns()),
            ms(self.p95_ns()),
            ms(self.p99_ns()),
            self.throughput_rps(),
            hist.join(" ")
        );
    }
}

/// The batch policy label for the current process environment — mirrors
/// what [`PredictExecutor::policy`] will report once built: `"measured"`
/// only when routing is on *and* the cost DB is not killed.
fn selector_label() -> &'static str {
    let routing = crate::runtime::executor::routing_enabled()
        || crate::runtime::executor::op_routing_enabled();
    if routing && CostDb::from_env().is_some() {
        "measured"
    } else {
        "static"
    }
}

/// Run one scenario: spawn the server, replay the arrival schedule open
/// loop, drain, and collect per-request latencies. Errors if the server
/// died early or any accepted request went unanswered (the
/// drained-shutdown contract).
pub fn run_scenario(sc: &Scenario, cfg: &ServeBenchConfig) -> Result<LoadReport> {
    let clock: Arc<dyn Clock> = Arc::new(MonotonicClock::new());
    let g = sc.geometry;
    let (max_batch, threads) = (cfg.serve.max_batch, cfg.threads);
    let exec_seed = cfg.seed ^ 0x5EED;
    let server = Server::spawn(cfg.serve, Arc::clone(&clock), move || {
        PredictExecutor::new(g, max_batch, threads, exec_seed)
    });
    let tx = server.handle();
    let offsets = arrival_offsets(cfg.rate_rps, cfg.requests, cfg.seed, cfg.arrivals);
    let mut rng = Xorshift::new(cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1);
    let mut pending = Vec::with_capacity(cfg.requests);
    let t_start = clock.now();
    for &off in &offsets {
        // Open loop: pace to the schedule, never to replies.
        let target = t_start + off;
        let now = clock.now();
        if target > now {
            std::thread::sleep(std::time::Duration::from_nanos(target - now));
        }
        let (x, _) = synthetic_batch(&mut rng, 1, g.c_in, g.hw, g.classes);
        let (reply_tx, reply_rx) = mpsc::channel();
        let sent_at = clock.now();
        if tx.send(ServeRequest { input: x.to_nchw(), reply: reply_tx }).is_err() {
            drop(tx);
            server.shutdown()?; // surface the executor's error
            anyhow::bail!("serve thread exited before the schedule finished");
        }
        pending.push((sent_at, reply_rx));
    }
    drop(tx);
    let stats = server.shutdown()?;
    let wall_ns = clock.now().saturating_sub(t_start);

    let mut latencies_ns = Vec::with_capacity(pending.len());
    let mut rejected = 0usize;
    for (i, (sent_at, reply_rx)) in pending.iter().enumerate() {
        match reply_rx.try_recv() {
            Ok(ServeReply::Done(p)) => {
                latencies_ns.push(p.completed_at.saturating_sub(*sent_at) as f64);
            }
            Ok(ServeReply::Rejected { .. }) => rejected += 1,
            Err(_) => anyhow::bail!("request {i} got no reply after drained shutdown"),
        }
    }
    anyhow::ensure!(
        rejected as u64 == stats.rejected && latencies_ns.len() as u64 == stats.completed,
        "reply tally (done {}, rejected {rejected}) disagrees with server stats {stats:?}",
        latencies_ns.len()
    );
    Ok(LoadReport {
        scenario: sc.name.to_string(),
        threads: cfg.threads,
        selector: selector_label(),
        requests: cfg.requests,
        accepted: stats.accepted as usize,
        rejected,
        latencies_ns,
        wall_ns,
        batch_hist: stats.batch_hist(),
    })
}

/// Run every scenario in order, printing each report line.
pub fn run_serve_bench(scs: &[Scenario], cfg: &ServeBenchConfig) -> Result<Vec<LoadReport>> {
    let mut out = Vec::with_capacity(scs.len());
    for sc in scs {
        let report = run_scenario(sc, cfg)?;
        report.print();
        out.push(report);
    }
    Ok(out)
}

/// Wrap serve reports in the wallclock v5 envelope for `BENCH_serve.json`.
pub fn wallclock_report(reports: &[LoadReport]) -> WallclockReport {
    WallclockReport {
        backend: simd::dispatch().name(),
        profile: build_profile(),
        threads_available: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        records: reports.iter().map(|r| r.to_record()).collect(),
    }
}

/// The CI smoke gate: at a low configured rate with a deep queue, a
/// healthy server rejects nothing, completes everything, and posts
/// finite tail latency. Returns one message per violation (empty =
/// pass); machine *speed* is deliberately not gated — only invariants
/// that hold on any machine.
pub fn smoke_violations(reports: &[LoadReport]) -> Vec<String> {
    let mut out = Vec::new();
    if reports.is_empty() {
        out.push("no scenarios ran".to_string());
    }
    for r in reports {
        if r.rejected != 0 {
            out.push(format!("{}: {} requests rejected at smoke rate", r.scenario, r.rejected));
        }
        if r.completed() + r.rejected != r.requests {
            out.push(format!(
                "{}: {} completed + {} rejected != {} submitted",
                r.scenario,
                r.completed(),
                r.rejected,
                r.requests
            ));
        }
        if !(r.throughput_rps() > 0.0) {
            out.push(format!("{}: throughput {} not positive", r.scenario, r.throughput_rps()));
        }
        let p99 = r.p99_ns();
        if !p99.is_finite() || p99 <= 0.0 {
            out.push(format!("{}: p99 {} not finite/positive", r.scenario, p99));
        }
    }
    out
}

/// The schema tag serve reports are written under (re-exported so the
/// CLI can print it without importing wallclock directly).
pub fn schema() -> &'static str {
    SCHEMA
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::wallclock::parse_serve_rows;

    // ---- percentile goldens (the latency reporter's math, pinned) ----

    #[test]
    fn miri_percentile_small_sample_goldens() {
        // n = 1: every percentile is the sample.
        assert_eq!(percentile(&[42.0], 50.0), 42.0);
        assert_eq!(percentile(&[42.0], 99.0), 42.0);
        // n = 10, values 10..=100: rank = (p/100)·(n−1), interpolated.
        let v: Vec<f64> = (1..=10).map(|i| (i * 10) as f64).collect();
        assert!((percentile(&v, 50.0) - 55.0).abs() < 1e-9, "p50 interpolates 50|60");
        assert!((percentile(&v, 95.0) - 95.5).abs() < 1e-9, "p95 = 90·0.45 + 100·0.55");
        assert!((percentile(&v, 99.0) - 99.1).abs() < 1e-9, "p99 = 90·0.09 + 100·0.91");
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
    }

    #[test]
    fn miri_percentile_duplicates_and_empty() {
        // Duplicate-heavy small sample: interpolation crosses the jump.
        let v = [5.0, 5.0, 5.0, 9.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert!((percentile(&v, 99.0) - 8.88).abs() < 1e-9, "p99 = 5·0.03 + 9·0.97");
        // All-equal: every percentile is that value.
        assert_eq!(percentile(&[7.0; 5], 99.0), 7.0);
        // Empty: defined as 0.0, which smoke_violations rejects as a
        // non-positive p99 rather than letting it read as "fast".
        assert_eq!(percentile(&[], 99.0), 0.0);
        // Unsorted input is sorted internally.
        assert_eq!(percentile(&[9.0, 5.0, 5.0, 5.0], 50.0), 5.0);
    }

    // ---- arrival schedule determinism ----

    #[test]
    fn miri_arrivals_are_deterministic_and_monotone() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Uniform] {
            let a = arrival_offsets(1000.0, 50, 7, kind);
            let b = arrival_offsets(1000.0, 50, 7, kind);
            assert_eq!(a, b, "same seed, same schedule ({kind:?})");
            assert_eq!(a.len(), 50);
            assert_eq!(a[0], 0, "first arrival at t=0");
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "non-decreasing ({kind:?})");
        }
        let a = arrival_offsets(1000.0, 50, 7, ArrivalKind::Poisson);
        let c = arrival_offsets(1000.0, 50, 8, ArrivalKind::Poisson);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn miri_uniform_arrivals_hit_exact_rate() {
        // 1000 rps → exactly 1 ms gaps.
        let a = arrival_offsets(1000.0, 4, 0, ArrivalKind::Uniform);
        assert_eq!(a, vec![0, 1_000_000, 2_000_000, 3_000_000]);
    }

    #[test]
    fn miri_poisson_mean_gap_tracks_rate() {
        // Long-run mean gap ≈ 1/rate (law of large numbers; generous
        // tolerance keeps this deterministic-seed test robust).
        let a = arrival_offsets(10_000.0, 4000, 3, ArrivalKind::Poisson);
        let mean_gap = *a.last().unwrap() as f64 / (a.len() - 1) as f64;
        let expect = 1e9 / 10_000.0;
        assert!(
            (mean_gap - expect).abs() < expect * 0.2,
            "mean gap {mean_gap} vs expected {expect}"
        );
    }

    // ---- smoke gate + record plumbing ----

    fn report(rejected: usize, latencies: Vec<f64>) -> LoadReport {
        let requests = latencies.len() + rejected;
        LoadReport {
            scenario: "paper".to_string(),
            threads: 2,
            selector: "static",
            requests,
            accepted: latencies.len(),
            rejected,
            latencies_ns: latencies,
            wall_ns: 1_000_000_000,
            batch_hist: vec![(1, 2), (8, 1)],
        }
    }

    #[test]
    fn miri_smoke_violations_gate() {
        let healthy = report(0, vec![1000.0, 2000.0, 3000.0]);
        assert!(smoke_violations(&[healthy]).is_empty());
        assert_eq!(smoke_violations(&[]), vec!["no scenarios ran".to_string()]);
        let rejected = report(2, vec![1000.0]);
        assert!(smoke_violations(&[rejected]).iter().any(|m| m.contains("rejected")));
        // Zero completions: p99 = 0.0 and throughput 0 both trip.
        let empty = report(0, Vec::new());
        let v = smoke_violations(&[empty]);
        assert!(v.iter().any(|m| m.contains("throughput")));
        assert!(v.iter().any(|m| m.contains("p99")));
        // A lost reply shows up as completed + rejected != submitted.
        let mut lost = report(0, vec![1000.0]);
        lost.requests = 2;
        assert!(smoke_violations(&[lost]).iter().any(|m| m.contains("submitted")));
    }

    #[test]
    fn miri_load_report_serializes_as_v4_serve_row() {
        let r = report(1, vec![1000.0, 2000.0, 4000.0]);
        let json = wallclock_report(&[r.clone()]).to_json();
        assert!(json.contains(&format!("\"schema\": \"{}\"", schema())));
        let rows = parse_serve_rows(&json);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].layer, "paper");
        assert_eq!(rows[0].extra.requests, 4);
        assert_eq!(rows[0].extra.rejected, 1);
        assert_eq!(rows[0].extra.batch_hist, vec![(1, 2), (8, 1)]);
        assert_eq!(rows[0].extra.p50_ns, 2000.0);
        // throughput: 3 completed over exactly 1 s of wall time
        assert!((rows[0].extra.throughput_rps - 3.0).abs() < 1e-9);
    }
}
