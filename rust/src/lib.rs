//! # SparseTrain
//!
//! A reproduction of *"SparseTrain: Leveraging Dynamic Sparsity in Training
//! DNNs on General-Purpose SIMD Processors"* (Gong et al.) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate contains:
//!
//! * [`tensor`] — the NCHWc16 tiled tensor layout the paper's kernels operate
//!   on (lowest dimension = channel tile of `V`, §3.2.5 of the paper).
//! * [`kernels`] — functional + cost-accounted implementations of the paper's
//!   convolution kernels: SparseTrain FWD/BWI/BWW, dense `direct`,
//!   `im2col`+GEMM, Winograd F(2×2,3×3), and the specialized `1x1` kernel.
//!
//!   **SIMD backend dispatch.** The three hot primitives — the vectorized
//!   zero-check (`vcmpps` → lane mask), the V-wide FMA group body
//!   (`vfmadd231ps`), and the V-vector copy — live in [`kernels::simd`]
//!   behind a [`kernels::simd::Backend`] of plain function pointers,
//!   resolved **once per process** with `is_x86_feature_detected!`:
//!   AVX-512F (one 512-bit op per primitive; needs the `avx512` cargo
//!   feature and rustc ≥ 1.89) → AVX2+FMA (two 256-bit ops) → NEON on
//!   AArch64 (four 128-bit ops) → portable scalar. The scalar path is the
//!   *reference and Miri* implementation: `cfg!(miri)` forces it (the
//!   interpreter cannot execute vendor intrinsics), `SPARSETRAIN_BACKEND=
//!   scalar` forces it anywhere, and because every backend computes the
//!   same correctly-rounded fused multiply-add (`f32::mul_add` ↔ hardware
//!   FMA) and IEEE `!= 0.0` compare, all backends are **bit-identical** —
//!   pinned by the `backend_parity` test suite across every `SkipMode`,
//!   geometry sweep, and all three components.
//! * [`sim`] — an analytical Skylake-X core model used to turn per-kernel
//!   micro-op counts into cycle estimates (the paper's testbed substitute).
//! * [`sparsity`] — synthetic sparsity generators, the Fig-3 trajectory
//!   model, and an activation profiler.
//! * [`nets`] — the paper's Table 2 layer configurations and full conv-layer
//!   inventories for VGG16 / ResNet-34 / ResNet-50 / Fixup ResNet-50.
//! * [`coordinator`] — the L3 runtime: the output-parallel row-sweep
//!   scheduler (all three training components — FWD over `(i, oy, qb)`
//!   output-row tasks, BWI over `(i, iy, cb)` input-row tasks, BWW over
//!   `(qb, c)` disjoint filter-gradient tiles, each atomic-free with
//!   per-chunk stats merged to exact serial parity), the
//!   thread-count-aware per-layer algorithm selector, the PJRT-driven
//!   training loop, and [`coordinator::serve`] — the batched inference
//!   front end (size/deadline request coalescing on an injected `Clock`,
//!   bounded-queue shedding, a ladder of batch-specialized predict
//!   artifacts with measured-cost rung selection).
//!
//!   **Parallel execution model.** The scheduler never shares a `&mut`
//!   tensor across threads: before a run it splits the output tensor into
//!   owned disjoint task views ([`tensor::RowTileMut`] /
//!   [`tensor::FilterTileMut`], carved with `chunks_mut`), and the thread
//!   pool hands each worker an exclusive `&mut` sub-slice of those views.
//!   Every per-task kernel body writes only through its own view, so
//!   data-race freedom is enforced by the borrow checker — zero `unsafe`
//!   in the scheduling path — and verified continuously by a `cargo
//!   +nightly miri test` CI gate plus 1–8-thread bit-exactness property
//!   tests. Each run hoists the register plan, sweep geometry/tap tables
//!   and the SIMD backend out of the task bodies, and every worker thread
//!   owns one reusable [`kernels::Scratch`] accumulator (per-worker state
//!   through `ThreadPool::for_chunk_slices_with`), so the scheduled hot
//!   path performs no heap allocation. See [`coordinator::scheduler`] for
//!   the full contract (who splits, who owns, why it's safe).
//! * [`runtime`] — PJRT client wrapper that loads AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them.
//!
//!   **Whole-graph op routing (ISSUE 5 convs, ISSUE 6 everything else).**
//!   The offline interpreter is no longer naive-only:
//!   [`runtime::executor::OpRouter`] is installed as the vendored crate's
//!   per-instruction [`xla::OpExecutor`] hook. Convolutions in the three
//!   SparseTrain-executable forms — FWD (`bf01_oi01->bf01`), BWI
//!   (reversed-filter `bf01_io01->bf01`) and BWW (batch-contracting
//!   `fb01_io01->bf01`) — dispatch to [`coordinator::Scheduler`] over the
//!   explicit-SIMD sparse kernels, with the thread-count-aware
//!   [`coordinator::Selector`] choosing the skip mode from measured
//!   operand sparsity; rank-2 `dot`s run the blocked, panel-parallel
//!   [`kernels::gemm`] on the same pool; and recognized elementwise
//!   chains (bias+ReLU, SGD `w - lr·g`, log-softmax row ops, ReLU-backward
//!   select) collapse into single fused passes that reproduce the naive
//!   arithmetic bit for bit. *Buffer ownership*: the evaluator owns
//!   allocation — it hands the hook an arena-recycled output buffer
//!   ([`xla::Arena`], per-executable scratch keyed by output size with
//!   last-use recycling), and the hook either fills it completely or
//!   declines untouched. *Fallback contract*: anything outside the
//!   envelope runs the interpreter's reference loop **bit-identically**
//!   (`rust/tests/conv_route_parity.rs` and `op_route_parity.rs` pin both
//!   halves), so `cargo run --release -- train` is multi-threaded and
//!   sparsity-exploiting end to end. `SPARSETRAIN_CONV_ROUTE=off` /
//!   `SPARSETRAIN_OP_ROUTE=off` kill the two routing classes. The
//!   [`util::threadpool::ThreadPool`] underneath keeps **persistent
//!   workers** parked between launches, so small launches no longer pay
//!   per-call thread-spawn overhead.
//! * [`bench`] — the hand-rolled benchmark harness shared by `rust/benches`,
//!   plus [`bench::wallclock`]: the real-kernel wall-clock sweep behind
//!   `cargo run --release --example wallclock` → `BENCH_kernels.json`,
//!   and [`bench::loadgen`]: the seeded open-loop serving load generator
//!   behind `sparsetrain serve` → `BENCH_serve.json`.
//! * [`util`] — substrates built from scratch for the offline environment:
//!   PRNG, statistics, thread pool, CLI parsing, text tables, and a mini
//!   property-testing framework.

pub mod bench;
pub mod coordinator;
pub mod kernels;
pub mod nets;
pub mod runtime;
pub mod sim;
pub mod sparsity;
pub mod tensor;
pub mod util;

/// SIMD vector width in f32 lanes (AVX-512: 16 × f32). The tiled tensor
/// layout, the kernels and the machine model all assume this width.
pub const V: usize = 16;
