//! Small statistics helpers used by the benchmark harness and the
//! experiment reports (geo-means over layers, percentiles over samples, …).

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0.0 for fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean; all inputs must be > 0. Returns 0.0 for empty input.
/// This is the aggregation the paper uses for Tables 4/5.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean over non-positive value");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median (linear-interpolated). Returns 0.0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Percentile in `[0, 100]` with linear interpolation between ranks.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Minimum; NaNs are ignored. Returns +inf for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::INFINITY, f64::min)
}

/// Maximum; NaNs are ignored. Returns -inf for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::NEG_INFINITY, f64::max)
}

/// Summary of a sample set (used by the bench harness report).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            stddev: stddev(xs),
            min: min(xs),
            max: max(xs),
            p05: percentile(xs, 5.0),
            p95: percentile(xs, 95.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        // geomean is invariant to ratios: gm(2x)/gm(x) = 2
        let a = geomean(&[1.5, 2.5, 3.5]);
        let b = geomean(&[3.0, 5.0, 7.0]);
        assert!((b / a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [10.0, 20.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 30.0);
        assert_eq!(percentile(&xs, 50.0), 20.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_consistent() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.mean, 2.5);
    }
}
