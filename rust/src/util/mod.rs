//! Substrate utilities built from scratch.
//!
//! The offline build environment only vendors the `xla` crate's dependency
//! closure, so everything a well-maintained project would normally pull from
//! crates.io (`rand`, `rayon`, `clap`, `criterion`, `proptest`, …) is
//! implemented here as small, tested modules.

pub mod cli;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
pub mod threadpool;

pub use prng::Xorshift;
pub use stats::{geomean, mean, median, percentile, stddev};
