//! A work-stealing-free thread pool (offline substitute for `rayon`), used
//! by the coordinator's row-sweep scheduler.
//!
//! **Persistent workers (ISSUE 5).** Earlier revisions ran the parallel-for
//! primitives on `std::thread::scope`, spawning fresh OS threads per call.
//! That was safe and simple but charged every scheduler launch a
//! thread-spawn/join round trip — measurable on small layers, and paid five
//! times per kernel-routed trainer step. All primitives now run on one set
//! of persistent worker threads, spawned lazily on first use and **parked
//! on a condvar between launches**; a launch hands the parked workers a
//! borrowed job through a [`Launch`] handoff cell and blocks until every
//! participant has finished, so the borrow can never outlive the call.
//!
//! Primitives:
//!
//! * [`ThreadPool::submit`] / [`ThreadPool::wait_idle`] — fire-and-forget
//!   `'static` tasks (a mutex+condvar injector queue). Worker threads wrap
//!   each task in `catch_unwind`, so a panicking task can neither kill a
//!   worker nor wedge `wait_idle`; the panic count is available via
//!   [`ThreadPool::panicked_tasks`].
//! * [`ThreadPool::for_chunks`] — a plain parallel-for: split `0..n` into
//!   chunks and run a borrowed closure per chunk, blocking until all
//!   complete. Chunks are handed out through a shared atomic cursor, so at
//!   most [`ThreadPool::threads`] chunks run concurrently and
//!   early-finishing workers pick up the remaining ones (the paper's
//!   dynamic row-sweep scheduling, §3.2.2). A panic in any chunk
//!   propagates to the caller after the remaining in-flight chunks finish,
//!   and the pool stays usable.
//! * [`ThreadPool::for_chunk_slices`] — the ownership-passing variant the
//!   kernel scheduler uses: the caller brings a `&mut [T]` of per-task
//!   items (e.g. disjoint tensor views) and each chunk worker receives an
//!   **exclusive `&mut` sub-slice** of it, carved with `chunks_mut` before
//!   any thread starts. Exclusivity is enforced by the borrow checker — no
//!   aliased `&mut`, nothing for Miri to object to.
//! * [`ThreadPool::for_chunk_slices_with`] — the same, plus a per-worker
//!   state value (`init()` at most once per participating thread, `&mut S`
//!   into every chunk that worker runs): the zero-alloc-hot-path hook the
//!   kernel scheduler uses to hand each worker one reusable scratch
//!   accumulator.
//!
//! ## Safety of the borrowed-job handoff
//!
//! The *scheduler* stays zero-`unsafe`: disjointness of tensor writes is
//! still proved by the borrow checker through the carved sub-slices. The
//! one `unsafe` in this module is the lifetime erasure that lets parked
//! `'static` worker threads call a stack-borrowed closure: [`broadcast`]
//! stores `&(dyn Fn() + Sync)` as a raw pointer in an `Arc<Launch>` and
//! **does not return until every claimed participation has finished**
//! (tracked by a mutex-guarded count and condvar), so the pointee strictly
//! outlives every dereference. Publication of the pointer to workers and
//! the completion signal back to the caller both travel through mutexes,
//! giving the necessary happens-before edges — the whole module runs under
//! the Miri CI gate (`util::threadpool` is in the miri filter), which is
//! exactly the referee for this kind of construction.
//!
//! [`broadcast`]: ThreadPool::broadcast

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Lifetime-erased pointer to a launch's borrowed job closure. Sound to
/// send across threads because [`ThreadPool::broadcast`] blocks until every
/// participation finished — see the module docs.
struct JobPtr(*const (dyn Fn() + Sync));

// SAFETY: the pointee is a `&(dyn Fn() + Sync)` borrowed from the
// broadcasting caller's stack; `broadcast` does not return (or unwind past
// its wait loop) until `Launch::pending` reaches zero, i.e. until no worker
// can dereference the pointer anymore. `Sync` on the pointee makes calling
// it from several threads at once sound.
unsafe impl Send for JobPtr {}
unsafe impl Sync for JobPtr {}

/// One borrowed parallel launch: the job pointer plus completion tracking.
struct Launch {
    job: JobPtr,
    /// Participations handed to workers that have not finished yet.
    pending: Mutex<usize>,
    done: Condvar,
    /// First panic payload from any worker participation.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// A queued launch with the number of worker participations still to hand
/// out. Workers claim participations one at a time; the entry leaves the
/// queue when none remain.
struct LaunchTicket {
    state: Arc<Launch>,
    starts_left: usize,
}

/// Worker-visible pool state: the submit queue and the launch queue behind
/// one mutex (no lock-order hazards), plus the shutdown flag.
struct Inner {
    queue: std::collections::VecDeque<Task>,
    launches: std::collections::VecDeque<LaunchTicket>,
    shutdown: bool,
}

struct Shared {
    inner: Mutex<Inner>,
    cv: Condvar,
    /// Submitted fire-and-forget tasks not yet finished (for `wait_idle`).
    inflight: AtomicUsize,
    /// Submitted tasks that panicked (they still count as finished).
    panicked: AtomicUsize,
    idle_cv: Condvar,
    idle_mx: Mutex<()>,
}

enum Work {
    Task(Task),
    Launch(Arc<Launch>),
}

thread_local! {
    /// Identity of the pool whose worker loop is running on this thread
    /// (0 = not a worker). Lets [`ThreadPool::broadcast`] detect reentrant
    /// launches — a parallel-for issued from inside one of this pool's own
    /// tasks — and run them inline instead of deadlocking on workers that
    /// can never become free (the scoped-thread implementation this
    /// replaced spawned fresh threads and so allowed that pattern).
    static CURRENT_POOL: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Fixed-size thread pool with persistent workers. The workers are spawned
/// lazily on the first call that needs them ([`ThreadPool::submit`] or any
/// multi-thread parallel-for) and then **parked between launches** on the
/// pool condvar — repeated scheduler launches reuse the same OS threads
/// instead of paying a spawn/join per call.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Create a pool that will use `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                queue: std::collections::VecDeque::new(),
                launches: std::collections::VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            inflight: AtomicUsize::new(0),
            panicked: AtomicUsize::new(0),
            idle_cv: Condvar::new(),
            idle_mx: Mutex::new(()),
        });
        ThreadPool { shared, workers: Mutex::new(Vec::new()), n_threads: n }
    }

    /// Spawn the persistent workers if they are not running yet.
    fn ensure_workers(&self) {
        let mut workers = self.workers.lock().unwrap();
        if !workers.is_empty() {
            return;
        }
        for i in 0..self.n_threads {
            let sh = Arc::clone(&self.shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sparsetrain-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker"),
            );
        }
    }

    /// Pool sized to available host parallelism.
    pub fn with_host_parallelism() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn threads(&self) -> usize {
        self.n_threads
    }

    /// Submit a fire-and-forget task (spawns the persistent workers on
    /// first use).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.ensure_workers();
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        let mut inner = self.shared.inner.lock().unwrap();
        inner.queue.push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Block until every submitted task has finished (panicked tasks count
    /// as finished — see [`ThreadPool::panicked_tasks`]).
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
    }

    /// Number of submitted tasks that panicked since pool creation.
    pub fn panicked_tasks(&self) -> usize {
        self.shared.panicked.load(Ordering::SeqCst)
    }

    /// Whether the calling thread is one of *this* pool's workers. Lets a
    /// caller that may run either on the coordinator thread or inside a
    /// pool task (e.g. an op co-scheduled by the pipeline executor) size
    /// its decisions to its effective parallelism: parallel-for issued
    /// from a worker runs inline (see [`ThreadPool::broadcast`]), i.e. at
    /// an effective thread count of 1.
    pub fn on_worker_thread(&self) -> bool {
        CURRENT_POOL.with(|c| c.get()) == Arc::as_ptr(&self.shared) as usize
    }

    /// Run `work` once on the calling thread and once per `extra` parked
    /// worker threads, blocking until every invocation has returned. This
    /// is the core the parallel-for primitives are built on: `work` is the
    /// per-participant chunk-claiming loop, borrowed from the caller's
    /// stack.
    ///
    /// Panic contract: if any invocation panics, the first payload is
    /// re-raised on the caller *after* all other invocations finished (a
    /// panic on the caller's own invocation wins), and the pool stays
    /// usable afterwards.
    fn broadcast(&self, extra: usize, work: &(dyn Fn() + Sync)) {
        // Reentrant launch from one of this pool's own workers: every
        // other worker may be busy (possibly blocked on *this* call's
        // siblings), so waiting for them could deadlock. Run the whole
        // claim loop inline — correct, just not parallel.
        let reentrant =
            CURRENT_POOL.with(|c| c.get()) == Arc::as_ptr(&self.shared) as usize;
        if extra == 0 || reentrant {
            // Single participant: run inline; a panic unwinds directly.
            work();
            return;
        }
        self.ensure_workers();
        let launch = Arc::new(Launch {
            job: JobPtr(work as *const (dyn Fn() + Sync)),
            pending: Mutex::new(extra),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner
                .launches
                .push_back(LaunchTicket { state: Arc::clone(&launch), starts_left: extra });
            self.shared.cv.notify_all();
        }
        // The caller participates too (so `threads == 1` still makes
        // progress and small launches don't context-switch).
        let mine = catch_unwind(AssertUnwindSafe(|| work()));
        // Do not return — and do not let `work`'s borrow end — before every
        // worker participation has finished with the job pointer.
        {
            let mut pending = launch.pending.lock().unwrap();
            while *pending != 0 {
                pending = launch.done.wait(pending).unwrap();
            }
        }
        if let Err(payload) = mine {
            resume_unwind(payload);
        }
        if let Some(payload) = launch.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// Parallel-for over `0..n` in up to `chunks` contiguous chunks.
    /// `f(chunk_idx, start, end)` runs on up to [`ThreadPool::threads`]
    /// threads (the calling thread participates); blocks until all chunks
    /// finish. `f` must be `Sync` because multiple workers call it
    /// concurrently.
    ///
    /// A panic inside `f` is propagated to the caller once every other
    /// in-flight chunk has finished — callers observe the original panic
    /// payload instead of a deadlock, and the pool stays usable.
    pub fn for_chunks<F>(&self, n: usize, chunks: usize, f: F)
    where
        F: Fn(usize, usize, usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = chunks.clamp(1, n);
        let chunk_len = n.div_ceil(chunks);
        // Number of non-empty chunks actually dispatched.
        let n_chunks = n.div_ceil(chunk_len);
        let workers = self.n_threads.min(n_chunks);
        let cursor = AtomicUsize::new(0);

        let run = || loop {
            let ci = cursor.fetch_add(1, Ordering::Relaxed);
            if ci >= n_chunks {
                break;
            }
            let start = ci * chunk_len;
            let end = (start + chunk_len).min(n);
            f(ci, start, end);
        };
        self.broadcast(workers - 1, &run);
    }

    /// Parallel-for over a slice of per-task items, handing each chunk
    /// worker an **exclusive** `&mut` sub-slice of `items`.
    ///
    /// `f(chunk_idx, start, chunk_items)` runs once per non-empty chunk;
    /// `start` is the index of `chunk_items[0]` within `items`. The
    /// sub-slices are produced by `chunks_mut` *before* any worker starts,
    /// so every `&mut [T]` a worker sees is disjoint by construction and
    /// checked by the compiler — this is the primitive that lets the kernel
    /// scheduler pass owned tensor views into tasks without any raw-pointer
    /// sharing of tensor data.
    ///
    /// Chunk → worker assignment is dynamic (shared atomic cursor), so
    /// early-finishing workers pick up remaining chunks. A panic inside
    /// `f` propagates to the caller once the launch drains, and the pool
    /// stays usable afterwards.
    pub fn for_chunk_slices<T, F>(&self, items: &mut [T], chunks: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Send + Sync,
    {
        self.for_chunk_slices_with(items, chunks, || (), |ci, start, chunk, _| f(ci, start, chunk));
    }

    /// [`ThreadPool::for_chunk_slices`] with **per-worker state**: each
    /// participating thread calls `init()` at most once (lazily, before its
    /// first claimed chunk) and passes the resulting `&mut S` to every
    /// chunk it runs. This is how the kernel scheduler gives each worker
    /// one reusable [`crate::kernels::Scratch`] accumulator — tasks stop
    /// allocating per-task buffers while the state never crosses threads
    /// (so `S` needs no `Send`/`Sync`).
    ///
    /// Same chunk carving, dynamic cursor assignment and panic propagation
    /// as [`ThreadPool::for_chunk_slices`].
    pub fn for_chunk_slices_with<T, S, I, F>(&self, items: &mut [T], chunks: usize, init: I, f: F)
    where
        T: Send,
        I: Fn() -> S + Send + Sync,
        F: Fn(usize, usize, &mut [T], &mut S) + Send + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let chunks = chunks.clamp(1, n);
        let chunk_len = n.div_ceil(chunks);
        // Carve `items` into disjoint sub-slices up front. Each slot is
        // taken exactly once (by whichever worker claims that chunk index
        // from the cursor); the Mutex<Option<..>> is only the hand-off
        // cell, not a lock anything contends on.
        let parts: Vec<Mutex<Option<(usize, &mut [T])>>> = items
            .chunks_mut(chunk_len)
            .enumerate()
            .map(|(i, chunk)| Mutex::new(Some((i * chunk_len, chunk))))
            .collect();
        let n_chunks = parts.len();
        let workers = self.n_threads.min(n_chunks);
        let cursor = AtomicUsize::new(0);

        let run = || {
            // Per-participant state, created lazily so a participant that
            // claims no chunk (everything already taken) never inits.
            let mut state: Option<S> = None;
            loop {
                let ci = cursor.fetch_add(1, Ordering::Relaxed);
                if ci >= n_chunks {
                    break;
                }
                let (chunk_start, chunk_items) =
                    parts[ci].lock().unwrap().take().expect("chunk claimed exactly once");
                let st = state.get_or_insert_with(&init);
                f(ci, chunk_start, chunk_items, st);
            }
        };
        self.broadcast(workers - 1, &run);
    }
}

fn worker_loop(sh: Arc<Shared>) {
    CURRENT_POOL.with(|c| c.set(Arc::as_ptr(&sh) as usize));
    loop {
        let work = {
            let mut inner = sh.inner.lock().unwrap();
            loop {
                // Launches first: parallel-for callers are blocked on them.
                if let Some(ticket) = inner.launches.front_mut() {
                    ticket.starts_left -= 1;
                    let state = Arc::clone(&ticket.state);
                    if ticket.starts_left == 0 {
                        inner.launches.pop_front();
                    }
                    break Work::Launch(state);
                }
                if let Some(t) = inner.queue.pop_front() {
                    break Work::Task(t);
                }
                if inner.shutdown {
                    return;
                }
                // Park until the next submit/launch/shutdown.
                inner = sh.cv.wait(inner).unwrap();
            }
        };
        match work {
            Work::Task(task) => {
                // A panicking task must not kill the worker or leak an
                // inflight count (which would deadlock `wait_idle`).
                if catch_unwind(AssertUnwindSafe(task)).is_err() {
                    sh.panicked.fetch_add(1, Ordering::SeqCst);
                }
                if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = sh.idle_mx.lock().unwrap();
                    sh.idle_cv.notify_all();
                }
            }
            Work::Launch(launch) => {
                // SAFETY: the broadcasting caller blocks until this
                // participation decrements `pending` below, so the borrowed
                // closure behind the pointer is still alive here.
                let job: &(dyn Fn() + Sync) = unsafe { &*launch.job.0 };
                if let Err(payload) = catch_unwind(AssertUnwindSafe(job)) {
                    let mut slot = launch.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                let mut pending = launch.pending.lock().unwrap();
                *pending -= 1;
                if *pending == 0 {
                    launch.done.notify_all();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn for_chunks_covers_range_exactly_once() {
        let pool = ThreadPool::new(3);
        let n = 1013;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.for_chunks(n, 8, |_ci, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn for_chunks_handles_more_chunks_than_items() {
        let pool = ThreadPool::new(2);
        let n = 3;
        let sum = AtomicU64::new(0);
        pool.for_chunks(n, 16, |_ci, s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::SeqCst);
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3); // 0 + 1 + 2
    }

    #[test]
    fn for_chunks_empty_range() {
        let pool = ThreadPool::new(2);
        pool.for_chunks(0, 4, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn for_chunks_single_thread_runs_inline() {
        let pool = ThreadPool::new(1);
        let caller = std::thread::current().id();
        let same_thread = AtomicU64::new(1);
        pool.for_chunks(10, 4, |_, _, _| {
            if std::thread::current().id() != caller {
                same_thread.store(0, Ordering::SeqCst);
            }
        });
        assert_eq!(same_thread.load(Ordering::SeqCst), 1);
    }

    /// Regression: a panicking chunk used to leave the completion counter
    /// short, blocking the caller forever. Now the panic propagates and
    /// the pool survives.
    #[test]
    fn for_chunks_panic_propagates_instead_of_deadlocking() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.for_chunks(100, 8, |_ci, s, _e| {
                if s == 0 {
                    panic!("task boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must reach the caller");

        // The pool is fully usable afterwards.
        let sum = AtomicU64::new(0);
        pool.for_chunks(10, 4, |_ci, s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::SeqCst);
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn for_chunk_slices_visits_every_item_exactly_once() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<u64> = vec![0; 1013];
        pool.for_chunk_slices(&mut items, 8, |_ci, start, chunk| {
            for (off, item) in chunk.iter_mut().enumerate() {
                // record which index the worker believes it owns
                *item += (start + off) as u64 + 1;
            }
        });
        for (i, item) in items.iter().enumerate() {
            assert_eq!(*item, i as u64 + 1, "item {i} visited wrong number of times");
        }
    }

    /// Per-worker state: `init` runs at most once per participating
    /// thread, the state is reused across every chunk that worker claims,
    /// and all items are still visited exactly once.
    #[test]
    fn for_chunk_slices_with_reuses_worker_state() {
        let pool = ThreadPool::new(3);
        let inits = AtomicU64::new(0);
        let mut items: Vec<u64> = vec![0; 257];
        pool.for_chunk_slices_with(
            &mut items,
            12,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                // per-worker chunk counter, never shared across threads
                0u64
            },
            |_ci, _start, chunk, state| {
                *state += 1;
                for item in chunk.iter_mut() {
                    *item += *state; // nonzero: state survives across chunks
                }
            },
        );
        let n_inits = inits.load(Ordering::SeqCst);
        assert!((1..=3).contains(&n_inits), "one init per worker, got {n_inits}");
        assert!(items.iter().all(|&v| v >= 1), "every item visited with live state");
    }

    #[test]
    fn for_chunk_slices_empty_and_oversubscribed() {
        let pool = ThreadPool::new(4);
        let mut empty: Vec<u32> = Vec::new();
        pool.for_chunk_slices(&mut empty, 8, |_, _, _| panic!("must not run"));

        let mut small = vec![0u32; 3];
        pool.for_chunk_slices(&mut small, 16, |_ci, _start, chunk| {
            for item in chunk.iter_mut() {
                *item += 1;
            }
        });
        assert_eq!(small, vec![1, 1, 1]);
    }

    /// Stress test (ISSUE 2 satellite, re-pinned for the persistent pool):
    /// a task that panics mid-chunk must propagate the panic to the caller
    /// — no deadlock, no poisoned pool — under *repeated* invocations of
    /// both parallel-for primitives, with the same parked workers serving
    /// every round.
    #[test]
    fn repeated_panics_propagate_without_poisoning_the_pool() {
        let pool = ThreadPool::new(4);
        let rounds: usize = if cfg!(miri) { 3 } else { 20 };
        for round in 0..rounds {
            // for_chunks: panic in a different chunk each round.
            let boom = (round * 13) % 100;
            let result = catch_unwind(AssertUnwindSafe(|| {
                pool.for_chunks(100, 8, |_ci, s, e| {
                    if (s..e).contains(&boom) {
                        panic!("for_chunks boom round {round}");
                    }
                });
            }));
            assert!(result.is_err(), "round {round}: panic must reach the caller");

            // for_chunk_slices: same, through the ownership-passing path.
            let mut items = vec![0u8; 64];
            let result = catch_unwind(AssertUnwindSafe(|| {
                pool.for_chunk_slices(&mut items, 8, |_ci, start, chunk| {
                    if (start..start + chunk.len()).contains(&(boom % 64)) {
                        panic!("for_chunk_slices boom round {round}");
                    }
                    for item in chunk.iter_mut() {
                        *item = 1;
                    }
                });
            }));
            assert!(result.is_err(), "round {round}: slice panic must reach the caller");

            // The pool must stay fully usable between panicking rounds.
            let sum = AtomicU64::new(0);
            pool.for_chunks(10, 4, |_ci, s, e| {
                for i in s..e {
                    sum.fetch_add(i as u64, Ordering::SeqCst);
                }
            });
            assert_eq!(sum.load(Ordering::SeqCst), 45, "round {round}: pool wedged");

            let mut ok = vec![0u64; 32];
            pool.for_chunk_slices(&mut ok, 4, |_ci, _start, chunk| {
                for item in chunk.iter_mut() {
                    *item += 1;
                }
            });
            assert!(ok.iter().all(|&v| v == 1), "round {round}: slice pool wedged");
        }
    }

    /// Regression: a panicking submitted task must not wedge `wait_idle`
    /// or kill the worker thread.
    #[test]
    fn submit_panic_does_not_wedge_wait_idle() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("submitted boom"));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 10);
        assert_eq!(pool.panicked_tasks(), 1);
    }

    /// ISSUE 5 tentpole pin: the parallel-for primitives run on the
    /// persistent worker set — spawned once on the first multi-thread
    /// launch, **reused** (not respawned) across launches, and shared with
    /// the submit queue.
    #[test]
    fn miri_for_chunks_reuses_persistent_workers() {
        let pool = ThreadPool::new(3);
        assert!(pool.workers.lock().unwrap().is_empty(), "workers spawn lazily");
        let launches = if cfg!(miri) { 3 } else { 25 };
        for round in 0..launches {
            let sum = AtomicU64::new(0);
            pool.for_chunks(30, 6, |_ci, s, e| {
                for i in s..e {
                    sum.fetch_add(i as u64, Ordering::SeqCst);
                }
            });
            assert_eq!(sum.load(Ordering::SeqCst), (0..30u64).sum(), "round {round}");
            // same worker set every round: parked between launches, never
            // respawned
            assert_eq!(pool.workers.lock().unwrap().len(), 3, "round {round}");
        }
        // the same workers serve the fire-and-forget queue
        let c = Arc::new(AtomicU64::new(0));
        let cc = Arc::clone(&c);
        pool.submit(move || {
            cc.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 1);
        assert_eq!(pool.workers.lock().unwrap().len(), 3);
    }

    /// Park/unpark smoke for the Miri gate: alternating slice launches and
    /// panicking launches over the same parked workers — the persistent
    /// hand-off must stay UB-free and recover from panics repeatedly.
    #[test]
    fn miri_persistent_pool_park_unpark_and_panic_recovery() {
        let pool = ThreadPool::new(2);
        for round in 0..3 {
            let mut items = vec![0u32; 16];
            pool.for_chunk_slices_with(
                &mut items,
                4,
                || 1u32,
                |_ci, _start, chunk, one| {
                    for item in chunk.iter_mut() {
                        *item += *one;
                    }
                },
            );
            assert!(items.iter().all(|&v| v == 1), "round {round}");

            let boomed = catch_unwind(AssertUnwindSafe(|| {
                pool.for_chunks(8, 4, |ci, _s, _e| {
                    if ci == round % 2 {
                        panic!("park/unpark boom");
                    }
                });
            }));
            assert!(boomed.is_err(), "round {round}: panic must propagate");
        }
    }

    /// A parallel-for issued from inside one of the pool's own tasks must
    /// complete (inline on that worker) instead of deadlocking on workers
    /// that can never become free — the capability the scoped-thread
    /// implementation had, preserved across the persistent-pool rewrite.
    #[test]
    fn miri_nested_parallel_for_from_pool_task_does_not_deadlock() {
        let pool = Arc::new(ThreadPool::new(2));
        let sum = Arc::new(AtomicU64::new(0));
        for _ in 0..2 {
            let (p, s) = (Arc::clone(&pool), Arc::clone(&sum));
            pool.submit(move || {
                p.for_chunks(10, 4, |_ci, lo, hi| {
                    for i in lo..hi {
                        s.fetch_add(i as u64, Ordering::SeqCst);
                    }
                });
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::SeqCst), 2 * 45);

        // ...and the outer-caller path still parallelizes afterwards.
        let outer = AtomicU64::new(0);
        pool.for_chunks(10, 4, |_ci, lo, hi| {
            for i in lo..hi {
                outer.fetch_add(i as u64, Ordering::SeqCst);
            }
        });
        assert_eq!(outer.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn miri_on_worker_thread_identifies_this_pools_workers() {
        let pool = ThreadPool::new(2);
        let other = ThreadPool::new(2);
        assert!(!pool.on_worker_thread(), "coordinator thread is not a worker");
        let caller = std::thread::current().id();
        let mismatches = AtomicU64::new(0);
        pool.for_chunks(8, 4, |_ci, _s, _e| {
            let on_caller = std::thread::current().id() == caller;
            // A participant is a pool worker iff it is not the caller,
            // and never a worker of an unrelated pool.
            if pool.on_worker_thread() != !on_caller || other.on_worker_thread() {
                mismatches.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(mismatches.load(Ordering::SeqCst), 0);
        assert!(!pool.on_worker_thread(), "flag does not leak back to the caller");
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
